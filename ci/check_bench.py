#!/usr/bin/env python3
"""Bench-smoke gate for the linalg microbench.

Usage: check_bench.py BENCH_linalg.json benches/linalg_baseline.json

Validates that the bench emitted well-formed JSON containing every
expected op key, then compares the measured *speedup ratios* (threaded vs
single-thread, blocked vs seed reference) against the checked-in
baseline: a drop of more than `regression_margin` (default 25%) below a
baseline ratio fails the job. Ratios, not absolute times, keep the gate
portable across CI hardware generations.

The bench's `meta` record must carry the machine's worker count in an
explicit `workers` field. The deprecated fallback that read it from the
`gflops` field (where pre-`workers` BENCH files smuggled it) has been
removed after its one-release grace period: a meta record without
`workers` is rejected outright — regenerate the BENCH file.

Since ISSUE 5 the meta record also carries `isa` — which SIMD path the
bench dispatched ("avx2" / "scalar"). Baseline keys listed in
`simd_keys` compare a dispatched microkernel against its scalar twin:
their floors apply as written only when the meta says "avx2"; on any
other path the two ops run identical code, so the floor is capped at
parity (1.0) and a scalar-fallback runner is never misread as a SIMD
regression. A missing `isa` field (pre-ISSUE-5 BENCH file) is treated
as "scalar".

Since ISSUE 8 the gate also serves the fleet bench
(`BENCH_fleet.json` vs `benches/fleet_baseline.json`): fleet records
carry `requests_per_s` (completed fleet requests per second) instead of
`gflops` — grouped orchestration has no FLOP model. A non-meta record
must carry one of the two throughput fields; a record with neither, or
with a negative value in either, is malformed and fails the gate.

Since ISSUE 10 the fleet bench also emits `service_*` ops (the jobs
routed through the deadline-aware `FleetService` front end). A
`service_*` record must carry all three scheduling counters — `shed`,
`retries`, `deadline_miss` — and every counter, on any record, must be
non-negative; a missing counter on a service op or a negative counter
anywhere is a malformed BENCH file and fails the gate.

Since ISSUE 6 the meta record may carry `solve_report` — the
degradation-ladder rung a healthy probe solve came back on. The value
must be one of "primary"/"ridge"/"failed" (an unknown rung is a
malformed BENCH file and fails the gate); "primary" is silent, anything
else warns that the bench machine's solve substrate degraded before the
perf numbers were taken. Absent is fine (pre-ISSUE-6 BENCH file).

`ci/test_check_bench.py` is the self-test for this gate — run it (pytest)
before trusting a gate change.
"""

import json
import sys


def die(msg: str) -> None:
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def meta_workers(recs: list) -> float:
    """Worker count of the machine the bench ran on, from the meta record.

    Requires the explicit `workers` field. The legacy `gflops` smuggle
    served its one-release deprecation window and is no longer honored: a
    meta record without `workers` dies, whatever else it carries.
    """
    for r in recs:
        if r.get("op") != "meta":
            continue
        if "workers" not in r:
            die(
                "meta record carries no 'workers' field (the legacy gflops "
                "smuggle is no longer honored — regenerate BENCH_linalg.json)"
            )
        return max(1.0, float(r["workers"]))
    return 1.0  # no meta record: required_ops normally catches this first


def meta_isa(recs: list) -> str:
    """SIMD path the bench dispatched, from the meta record's `isa` field.

    Pre-ISSUE-5 BENCH files have no `isa`; they predate the pinned-width
    microkernels, so "scalar" is the faithful default.
    """
    for r in recs:
        if r.get("op") == "meta":
            return str(r.get("isa", "scalar"))
    return "scalar"


KNOWN_RUNGS = ("primary", "ridge", "failed")

# scheduling counters every `service_*` record must carry (and that must
# be non-negative wherever they appear)
SERVICE_COUNTERS = ("shed", "retries", "deadline_miss")


def check_solve_report(recs: list) -> None:
    """Validate the meta record's `solve_report` rung, when present.

    Dies on a rung outside the SolveReport vocabulary (a malformed or
    corrupted BENCH file); warns when the healthy probe solve did not come
    back on the primary rung — perf numbers from a machine whose solve
    substrate is already degrading are suspect, but not a hard failure.
    """
    for r in recs:
        if r.get("op") != "meta" or "solve_report" not in r:
            continue
        rung = r["solve_report"]
        if rung not in KNOWN_RUNGS:
            die(
                f"meta solve_report {rung!r} is not a known rung "
                f"(expected one of {KNOWN_RUNGS})"
            )
        if rung != "primary":
            print(
                f"WARN: bench machine's healthy probe solve degraded to "
                f"{rung!r} — perf numbers may reflect a ridge-fallback path"
            )


def run(bench_path: str, baseline_path: str) -> None:
    try:
        with open(bench_path) as f:
            recs = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        die(f"cannot parse {bench_path}: {e}")
    with open(baseline_path) as f:
        base = json.load(f)

    if not isinstance(recs, list) or not recs:
        die(f"{bench_path}: expected a non-empty record array")
    for i, r in enumerate(recs):
        for key in ("op", "shape", "ns_per_iter"):
            if key not in r:
                die(f"record {i} missing {key!r}: {r}")
        if not isinstance(r["op"], str) or not r["op"]:
            die(f"record {i} has a bad op: {r}")
        if r["op"] == "meta":
            continue  # shape/throughput fields don't apply to metadata
        if "gflops" not in r and "requests_per_s" not in r:
            die(
                f"record {i} carries neither 'gflops' nor 'requests_per_s': {r}"
            )
        if "gflops" in r and float(r["gflops"]) < 0:
            die(f"record {i} has negative gflops: {r}")
        if "requests_per_s" in r and float(r["requests_per_s"]) < 0:
            die(f"record {i} has negative requests_per_s: {r}")
        if not (float(r["ns_per_iter"]) > 0):
            die(f"record {i} has non-positive ns_per_iter: {r}")
        # gbps (achieved bandwidth vs the compulsory-traffic model) is
        # informational but must be well-formed when present
        if "gbps" in r and float(r["gbps"]) < 0:
            die(f"record {i} has negative gbps: {r}")
        # service scheduling counters: mandatory on service_* ops,
        # non-negative everywhere
        for counter in SERVICE_COUNTERS:
            if r["op"].startswith("service_") and counter not in r:
                die(f"service record {i} missing counter {counter!r}: {r}")
            if counter in r and float(r[counter]) < 0:
                die(f"record {i} has negative {counter}: {r}")

    check_solve_report(recs)

    ops = {r["op"] for r in recs}
    missing = [op for op in base["required_ops"] if op not in ops]
    if missing:
        die(f"missing op keys: {missing} (present: {sorted(ops)})")
    print(f"ok: {len(recs)} records, all {len(base['required_ops'])} op keys present")

    # threaded floors scale with the bench machine's worker count (a
    # 2-vCPU CI runner is not held to an 8-core threaded-speedup
    # baseline); SIMD-microkernel floors apply only when the meta record
    # says the AVX2 path was dispatched
    workers = meta_workers(recs)
    isa = meta_isa(recs)
    threaded_keys = set(base.get("threaded_keys", []))
    simd_keys = set(base.get("simd_keys", []))

    margin = float(base.get("regression_margin", 0.25))
    failures = []
    for key, want in base["min_speedups"].items():
        op, _, shape = key.partition("@")
        cands = [
            r
            for r in recs
            if r["op"] == op
            and (not shape or r["shape"] == shape)
            and "speedup_vs_reference" in r
        ]
        if not cands:
            failures.append(f"{key}: no record carries a speedup_vs_reference")
            continue
        got = max(float(r["speedup_vs_reference"]) for r in cands)
        want = float(want)
        if key in threaded_keys:
            want = min(want, 0.6 * workers)
        if key in simd_keys and isa != "avx2":
            # dispatched == scalar on this runner: parity is the honest cap
            want = min(want, 1.0)
        floor = want * (1.0 - margin)
        status = "ok" if got >= floor else "REGRESSION"
        print(
            f"{status}: {key}: speedup {got:.2f}x "
            f"(baseline {want:.2f}x, floor {floor:.2f}x, workers {workers:.0f}, isa {isa})"
        )
        if got < floor:
            failures.append(
                f"{key}: speedup {got:.2f}x fell below floor {floor:.2f}x "
                f"(baseline {want:.2f}x - {margin:.0%} margin)"
            )

    if failures:
        die("; ".join(failures))
    print("bench gate passed")


def main() -> None:
    if len(sys.argv) != 3:
        die(f"usage: {sys.argv[0]} BENCH_linalg.json linalg_baseline.json")
    run(sys.argv[1], sys.argv[2])


if __name__ == "__main__":
    main()
