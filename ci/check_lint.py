#!/usr/bin/env python3
"""CI gate for the pallas-lint determinism-contract linter.

Usage: check_lint.py <rust-root>

Runs the linter in `--json` mode over `<rust-root>` and gates on its
findings: zero unwaived findings passes (waived findings are reported but
green), any unwaived finding fails with the finding list on stderr, and a
linter that crashes, emits unparseable output, or emits JSON that does
not match the documented schema is itself a hard failure — a broken gate
must never read as a green one.

The linter command defaults to the Rust binary via cargo
(`cargo run -q -p pallas-lint --`), so the default invocation expects to
run with the cargo workspace as the working directory:

    cd rust && python3 ../ci/check_lint.py .

Set `PALLAS_LINT_CMD` to substitute any command with the same CLI
contract — CI's lint job also runs the gate through the Python mirror
(`PALLAS_LINT_CMD="python3 ci/pallas_lint.py"`) so the two
implementations cross-check each other on every push, and
`ci/test_lint.py` uses the same hook to prove the gate fails on a seeded
fixture violation.

Exit codes: 0 clean, 1 unwaived findings, 2 gate/linter breakage.
"""

import json
import os
import shlex
import subprocess
import sys

SCHEMA_KEYS = ("tool", "findings", "unwaived", "waived")
FINDING_KEYS = ("rule", "path", "line", "message", "waived")


def die(msg: str, code: int = 2) -> None:
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(code)


def lint_cmd() -> list:
    """The linter argv prefix: `PALLAS_LINT_CMD` or the cargo default."""
    env = os.environ.get("PALLAS_LINT_CMD", "").strip()
    if env:
        return shlex.split(env)
    return ["cargo", "run", "-q", "-p", "pallas-lint", "--"]


def run_linter(root: str) -> dict:
    """Run the linter over `root` and return its validated JSON report."""
    cmd = lint_cmd() + [root, "--json"]
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True)
    except OSError as e:
        die(f"cannot launch linter {cmd}: {e}")
    if proc.returncode not in (0, 1):
        # exit 1 still carries a findings report; anything else is breakage
        die(
            f"linter exited {proc.returncode} (expected 0 or 1): "
            f"{proc.stderr.strip() or proc.stdout.strip()}"
        )
    try:
        report = json.loads(proc.stdout)
    except json.JSONDecodeError as e:
        die(f"linter emitted unparseable JSON: {e}\n--- stdout ---\n{proc.stdout}")
    validate(report)
    return report


def validate(report: dict) -> None:
    """Reject reports that drift from the documented JSON schema."""
    if not isinstance(report, dict):
        die(f"report is not an object: {report!r}")
    for key in SCHEMA_KEYS:
        if key not in report:
            die(f"report missing {key!r}: {sorted(report)}")
    if report["tool"] != "pallas-lint":
        die(f"report from unexpected tool {report['tool']!r}")
    if not isinstance(report["findings"], list):
        die("report 'findings' is not an array")
    for i, f in enumerate(report["findings"]):
        for key in FINDING_KEYS:
            if key not in f:
                die(f"finding {i} missing {key!r}: {f}")
    unwaived = sum(1 for f in report["findings"] if not f["waived"])
    if unwaived != report["unwaived"]:
        die(
            f"report counter disagrees with its own findings: "
            f"unwaived={report['unwaived']} but {unwaived} findings are unwaived"
        )


def main() -> None:
    if len(sys.argv) != 2:
        die(f"usage: {sys.argv[0]} <rust-root>")
    report = run_linter(sys.argv[1])
    for f in report["findings"]:
        tag = "waived" if f["waived"] else "FAIL"
        reason = f" ({f.get('reason')})" if f["waived"] and f.get("reason") else ""
        print(
            f"{tag}: {f['rule']}: {f['path']}:{f['line']}: {f['message']}{reason}",
            file=sys.stderr if not f["waived"] else sys.stdout,
        )
    if report["unwaived"]:
        die(f"{report['unwaived']} unwaived lint finding(s)", code=1)
    print(
        f"lint gate passed: 0 unwaived, {report['waived']} waived "
        f"finding(s) across the tree"
    )


if __name__ == "__main__":
    main()
