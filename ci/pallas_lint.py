#!/usr/bin/env python3
"""Python mirror of the ``pallas-lint`` determinism-contract analyzer.

``rust/lint`` is the authoritative implementation (it runs as the blocking
CI job); this mirror exists so environments without a Rust toolchain — the
development container and the pytest tier — can still run the analyzer and
verify the tree is clean. The two implementations are kept in sync by the
shared fixture suite under ``rust/lint/fixtures/``: every pass/fail fixture
must produce the same verdict from both. When you change a rule, change it
in both places and extend the fixtures to pin the new behavior.

The logic is a line-for-line port: a comment/string-masking lexer (no
``syn``-style parsing on either side), then six lexical, conservative
rules. See ``docs/ARCHITECTURE.md`` ("Statically-enforced invariants")
for the rule table and waiver syntax.

Usage::

    python ci/pallas_lint.py [--json] [--fixture] <rust-root-or-src>

Exit codes: 0 clean, 1 unwaived findings, 2 usage/IO error.
"""

from __future__ import annotations

import bisect
import json
import sys
from pathlib import Path

# --- rule names and scopes (mirror rust/lint/src/rules.rs) -----------------

RULE_UNSAFE = "unsafe-confinement"
RULE_TWIN = "scalar-twin"
RULE_HASH = "hash-order"
RULE_THREAD = "thread-confinement"
RULE_FOLD = "fold-order"
RULE_ASSERT = "assert-discipline"
RULE_WAIVER = "waiver-reason"
RULES = [RULE_UNSAFE, RULE_TWIN, RULE_HASH, RULE_THREAD, RULE_FOLD, RULE_ASSERT, RULE_WAIVER]

UNSAFE_FILE = "linalg/simd.rs"
FORBID_EXEMPT = ["lib.rs", "linalg/mod.rs"]
THREAD_ALLOWED = [
    "linalg/policy.rs",
    "linalg/tsqr.rs",
    "coordinator/pipeline.rs",
    "coordinator/service.rs",
]
HASH_SCOPE = ["coordinator/", "linalg/", "elm/"]
KERNEL_SCOPE = ["linalg/", "elm/arch/"]
TWIN_TEST_FILE = "tests/simd_props.rs"

HASH_ITER_METHODS = [
    "iter", "iter_mut", "keys", "values", "values_mut",
    "drain", "into_iter", "into_keys", "into_values", "retain",
]


# --- lexer (mirror rust/lint/src/lexer.rs) ----------------------------------

def is_ident_char(c: str) -> bool:
    return c.isalnum() or c == "_"


def _prev_is_ident(raw: str, i: int) -> bool:
    return i > 0 and is_ident_char(raw[i - 1])


def _raw_string_end(raw: str, i: int):
    n = len(raw)
    j = i
    if raw[j] == "b":
        j += 1
        if j >= n or raw[j] != "r":
            return None
    if raw[j] != "r":
        return None
    j += 1
    hashes = 0
    while j < n and raw[j] == "#":
        hashes += 1
        j += 1
    if j >= n or raw[j] != '"':
        return None
    j += 1
    while j < n:
        if raw[j] == '"':
            k = j + 1
            seen = 0
            while k < n and raw[k] == "#" and seen < hashes:
                seen += 1
                k += 1
            if seen == hashes:
                return k
        j += 1
    return n


def _mask(raw: str):
    """Blank comments and literal payloads; return (masked, comment_spans)."""
    n = len(raw)
    out: list[str] = []
    comments: list[tuple[int, int]] = []
    i = 0

    def blank(c: str) -> str:
        return "\n" if c == "\n" else " "

    while i < n:
        c = raw[i]
        if c == "/" and i + 1 < n and raw[i + 1] == "/":
            start = i
            while i < n and raw[i] != "\n":
                out.append(" ")
                i += 1
            comments.append((start, i))
            continue
        if c == "/" and i + 1 < n and raw[i + 1] == "*":
            start = i
            depth = 0
            while i < n:
                if raw[i] == "/" and i + 1 < n and raw[i + 1] == "*":
                    depth += 1
                    out.append(" ")
                    out.append(" ")
                    i += 2
                elif raw[i] == "*" and i + 1 < n and raw[i + 1] == "/":
                    depth -= 1
                    out.append(" ")
                    out.append(" ")
                    i += 2
                    if depth == 0:
                        break
                else:
                    out.append(blank(raw[i]))
                    i += 1
            comments.append((start, i))
            continue
        if c in ("r", "b") and not _prev_is_ident(raw, i):
            end = _raw_string_end(raw, i)
            if end is not None:
                while i < end:
                    out.append(blank(raw[i]))
                    i += 1
                continue
        if c == '"':
            out.append(" ")
            i += 1
            while i < n:
                if raw[i] == "\\" and i + 1 < n:
                    out.append(" ")
                    out.append(blank(raw[i + 1]))
                    i += 2
                elif raw[i] == '"':
                    out.append(" ")
                    i += 1
                    break
                else:
                    out.append(blank(raw[i]))
                    i += 1
            continue
        if c == "'":
            if i + 1 < n and raw[i + 1] == "\\":
                out.append(" ")
                out.append(" ")
                i += 2
                while i < n and raw[i] != "'":
                    out.append(blank(raw[i]))
                    i += 1
                if i < n:
                    out.append(" ")
                    i += 1
                continue
            if i + 2 < n and raw[i + 2] == "'" and raw[i + 1] != "'":
                out.append(" ")
                out.append(" ")
                out.append(" ")
                i += 3
                continue
            out.append("'")
            i += 1
            continue
        out.append(c)
        i += 1
    return "".join(out), comments


def find_seq_in(hay: str, needle: str) -> list[int]:
    out = []
    start = 0
    while True:
        pos = hay.find(needle, start)
        if pos < 0:
            return out
        out.append(pos)
        start = pos + 1


def find_word_in(hay: str, needle: str) -> list[int]:
    out = []
    for pos in find_seq_in(hay, needle):
        left_ok = pos == 0 or not is_ident_char(hay[pos - 1])
        end = pos + len(needle)
        right_ok = end >= len(hay) or not is_ident_char(hay[end])
        if left_ok and right_ok:
            out.append(pos)
    return out


class FileView:
    """Masked view of one source file (mirror of the Rust ``FileView``)."""

    def __init__(self, text: str):
        self.raw = text
        self.chars, self.comments = _mask(text)
        self.line_starts = [0]
        for i, c in enumerate(text):
            if c == "\n":
                self.line_starts.append(i + 1)

    def line_of(self, pos: int) -> int:
        return bisect.bisect_right(self.line_starts, pos)

    def find_word(self, needle: str) -> list[int]:
        return find_word_in(self.chars, needle)

    def find_seq(self, needle: str) -> list[int]:
        return find_seq_in(self.chars, needle)

    def range_contains(self, lo: int, hi: int, needle: str) -> bool:
        hi = min(hi, len(self.chars))
        return lo < hi and needle in self.chars[lo:hi]

    def skip_ws(self, pos: int) -> int:
        while pos < len(self.chars) and self.chars[pos].isspace():
            pos += 1
        return pos

    def prev_non_ws(self, pos: int):
        i = pos
        while i > 0:
            i -= 1
            if not self.chars[i].isspace():
                return i
        return None

    def ident_ending_at(self, end: int):
        start = end
        while start > 0 and is_ident_char(self.chars[start - 1]):
            start -= 1
        if start == end:
            return None
        return start, self.chars[start:end]

    def ident_starting_at(self, pos: int):
        end = pos
        while end < len(self.chars) and is_ident_char(self.chars[end]):
            end += 1
        if end == pos:
            return None
        return self.chars[pos:end]

    def match_brace(self, open_pos: int):
        depth = 0
        for off in range(open_pos, len(self.chars)):
            c = self.chars[off]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
                if depth == 0:
                    return off
        return None


def _leading_pub(view: FileView, pos: int) -> bool:
    end = pos
    while True:
        last = view.prev_non_ws(end)
        if last is None:
            return False
        ident = view.ident_ending_at(last + 1)
        if ident is None:
            return False
        start, word = ident
        if word in ("unsafe", "const", "async"):
            end = start
            continue
        return word == "pub"


def fn_spans(view: FileView):
    """Every ``fn`` item: dicts of name / is_pub / pos / body span."""
    out = []
    for pos in view.find_word("fn"):
        name_start = view.skip_ws(pos + 2)
        name = view.ident_starting_at(name_start)
        if name is None:
            continue
        is_pub = _leading_pub(view, pos)
        body = None
        j = name_start + len(name)
        while j < len(view.chars):
            c = view.chars[j]
            if c == "{":
                close = view.match_brace(j)
                if close is not None:
                    body = (j, close)
                break
            if c == ";":
                break
            j += 1
        out.append({"name": name, "is_pub": is_pub, "pos": pos, "body": body})
    return out


def cfg_test_spans(view: FileView):
    out = []
    for pos in view.find_seq("#[cfg(test)]"):
        window_end = min(pos + 200, len(view.chars))
        mods = find_word_in(view.chars[pos:window_end], "mod")
        if not mods:
            continue
        j = pos + mods[0]
        while j < len(view.chars) and view.chars[j] != "{":
            j += 1
        if j < len(view.chars):
            close = view.match_brace(j)
            if close is not None:
                out.append((pos, close + 1))
    return out


def in_spans(pos: int, spans) -> bool:
    return any(lo <= pos < hi for lo, hi in spans)


# --- waivers (mirror rules.rs collect_waivers) ------------------------------

def collect_waivers(view: FileView):
    waivers = []
    malformed = []
    for lo, hi in view.comments:
        text = view.raw[lo:hi]
        idx = text.find("lint:")
        if idx < 0:
            continue
        line = view.line_of(lo)
        body = text[idx + len("lint:"):].strip()
        if body.startswith("allow("):
            stripped = body[len("allow("):]
            close = stripped.find(")")
            if close < 0:
                malformed.append((line, "unterminated `lint: allow(…)`"))
                continue
            rule = stripped[:close].strip()
            rest = stripped[close + 1:].strip()
        elif body.startswith("fold-order-pinned"):
            rule = RULE_FOLD
            rest = body[len("fold-order-pinned"):].strip()
        else:
            malformed.append((line, f"unknown lint control comment `lint: {body}`"))
            continue
        if rule not in RULES or rule == RULE_WAIVER:
            malformed.append((line, f"waiver names unknown rule `{rule}`"))
            continue
        reason = rest[2:].strip() if rest.startswith("--") else None
        if reason:
            waivers.append({"rule": rule, "reason": reason, "line": line})
        else:
            malformed.append((
                line,
                f"waiver for `{rule}` is missing its mandatory reason "
                f"(`-- <why this site is exempt>`)",
            ))
    return waivers, malformed


# --- rules (mirror rules.rs) -------------------------------------------------

class Prepared:
    def __init__(self, path: str, text: str):
        self.path = path
        self.rel = path[len("src/"):] if path.startswith("src/") else ""
        self.view = FileView(text)
        self.test_spans = cfg_test_spans(self.view)
        self.fns = fn_spans(self.view)

    def finding(self, rule, pos, message):
        return self.finding_at_line(rule, self.view.line_of(pos), message)

    def finding_at_line(self, rule, line, message):
        return {
            "rule": rule, "path": self.path, "line": line,
            "message": message, "waived": False, "reason": None,
        }


def rule_unsafe(p: Prepared, out: list):
    if p.rel == UNSAFE_FILE:
        if not p.view.find_seq("#![deny(unsafe_op_in_unsafe_fn)]"):
            out.append(p.finding_at_line(
                RULE_UNSAFE, 1,
                f"{UNSAFE_FILE} must carry `#![deny(unsafe_op_in_unsafe_fn)]` so every "
                "unsafe operation sits in an explicit `unsafe` block"))
        return
    for pos in p.view.find_word("unsafe"):
        out.append(p.finding(
            RULE_UNSAFE, pos,
            f"`unsafe` outside {UNSAFE_FILE}: the determinism contract confines all "
            "unsafe code to the SIMD microkernel module"))
    if p.rel not in FORBID_EXEMPT and not p.view.find_seq("#![forbid(unsafe_code)]"):
        out.append(p.finding_at_line(
            RULE_UNSAFE, 1,
            "missing `#![forbid(unsafe_code)]` module header (compiler-backed rule A)"))


def rule_twin(p: Prepared, twin_tests, out: list):
    if p.rel != UNSAFE_FILE:
        return
    live = [f for f in p.fns if f["is_pub"] and not in_spans(f["pos"], p.test_spans)]
    names = [f["name"] for f in live]
    for f in live:
        if f["name"].endswith("_scalar"):
            continue
        twin = f["name"] + "_scalar"
        dispatched = (
            f["body"] is not None
            and p.view.range_contains(f["body"][0], f["body"][1], "avx2::")
        ) or twin in names
        if not dispatched:
            continue
        if twin not in names:
            out.append(p.finding(
                RULE_TWIN, f["pos"],
                f"dispatched kernel `{f['name']}` has no `{twin}` twin: every SIMD kernel "
                "needs a scalar oracle that is also the portable fallback"))
            continue
        referenced = twin_tests is not None and bool(twin_tests.view.find_word(twin))
        if not referenced:
            out.append(p.finding(
                RULE_TWIN, f["pos"],
                f"scalar twin `{twin}` is never referenced by {TWIN_TEST_FILE}: the "
                f"dispatched-vs-scalar bit-identity of `{f['name']}` is unpinned"))


def _hash_binding_name(view: FileView, pos: int):
    while True:
        prev = view.prev_non_ws(pos)
        if prev is None:
            return None
        if prev >= 1 and view.chars[prev] == ":" and view.chars[prev - 1] == ":":
            before = view.prev_non_ws(prev - 1)
            if before is None:
                return None
            ident = view.ident_ending_at(before + 1)
            if ident is None:
                return None
            pos = ident[0]
            continue
        if view.chars[prev] == ":":
            last = view.prev_non_ws(prev)
            if last is None:
                return None
            ident = view.ident_ending_at(last + 1)
            return ident[1] if ident else None
        if view.chars[prev] == "=":
            if prev >= 1 and view.chars[prev - 1] == "=":
                return None
            last = view.prev_non_ws(prev)
            if last is None:
                return None
            ident = view.ident_ending_at(last + 1)
            return ident[1] if ident else None
        return None


def _hash_iter_method(view: FileView, end: int):
    dot = view.skip_ws(end)
    if dot >= len(view.chars) or view.chars[dot] != ".":
        return None
    m = view.ident_starting_at(view.skip_ws(dot + 1))
    return m if m in HASH_ITER_METHODS else None


def _for_loop_target(view: FileView, pos: int) -> bool:
    end = pos
    while True:
        prev = view.prev_non_ws(end)
        if prev is None:
            return False
        if view.chars[prev] in "&.()":
            end = prev
            continue
        ident = view.ident_ending_at(prev + 1)
        if ident is None:
            return False
        start, word = ident
        if word in ("mut", "self"):
            end = start
            continue
        return word == "in"


def rule_hash(p: Prepared, out: list):
    if not any(p.rel.startswith(s) for s in HASH_SCOPE):
        return
    bound = []
    for ty in ("HashMap", "HashSet"):
        for pos in p.view.find_word(ty):
            name = _hash_binding_name(p.view, pos)
            if name and name not in bound:
                bound.append(name)
    flagged = set()
    for name in bound:
        for pos in p.view.find_word(name):
            if in_spans(pos, p.test_spans):
                continue
            end = pos + len(name)
            if _hash_iter_method(p.view, end) is None and not _for_loop_target(p.view, pos):
                continue
            line = p.view.line_of(pos)
            if line in flagged:
                continue
            flagged.add(line)
            out.append(p.finding(
                RULE_HASH, pos,
                f"iteration over hash-ordered `{name}`: visit order is nondeterministic — "
                "use BTreeMap/BTreeSet or sort before iterating (keyed lookup is fine)"))


def rule_thread(p: Prepared, out: list):
    if p.rel in THREAD_ALLOWED:
        return
    sites = list(p.view.find_seq("std::thread"))
    for pat in ("thread::spawn", "thread::scope", "thread::Builder"):
        for pos in p.view.find_seq(pat):
            if pos < 2 or p.view.chars[pos - 1] != ":":
                sites.append(pos)
    flagged = set()
    for pos in sorted(sites):
        line = p.view.line_of(pos)
        if line in flagged:
            continue
        flagged.add(line)
        out.append(p.finding(
            RULE_THREAD, pos,
            "thread spawn/scope outside the ParallelPolicy substrate: worker-count "
            "bit-invariance is only proven for the fixed-schedule machinery"))


def rule_fold(p: Prepared, waivers, out: list):
    if not any(p.rel.startswith(s) for s in KERNEL_SCOPE):
        return
    sites = []
    for pat in (".sum()", ".sum::<", ".fold("):
        sites.extend(p.view.find_seq(pat))
    for pos in sorted(sites):
        if in_spans(pos, p.test_spans):
            continue
        line = p.view.line_of(pos)
        annotated = any(
            w["rule"] == RULE_FOLD and w["line"] in (line, line - 1) for w in waivers
        )
        if not annotated:
            out.append(p.finding(
                RULE_FOLD, pos,
                "float fold without a `// lint: fold-order-pinned -- <why>` annotation: "
                "reduction order must be pinned (or provably order-free) in kernel modules"))


def rule_assert(p: Prepared, out: list):
    if not any(p.rel.startswith(s) for s in KERNEL_SCOPE):
        return
    pub_bodies = [
        f["body"] for f in p.fns
        if f["is_pub"] and not in_spans(f["pos"], p.test_spans) and f["body"] is not None
    ]
    sites = (
        p.view.find_word("debug_assert")
        + p.view.find_word("debug_assert_eq")
        + p.view.find_word("debug_assert_ne")
    )
    for pos in sites:
        if in_spans(pos, p.test_spans) or not in_spans(pos, pub_bodies):
            continue
        out.append(p.finding(
            RULE_ASSERT, pos,
            "`debug_assert!` in a pub kernel entry point: promote to `assert!` with a "
            "message — release builds must fail loudly on shape/stride violations"))


# --- orchestration (mirror lib.rs) -------------------------------------------

def analyze_sources(sources):
    """``sources`` is a list of (path, text); returns finding dicts."""
    prepared = [Prepared(path, text) for path, text in sources]
    twin_tests = next((p for p in prepared if p.path.endswith(TWIN_TEST_FILE)), None)
    findings = []
    for p in prepared:
        if not p.rel:
            continue
        waivers, malformed = collect_waivers(p.view)
        for line, message in malformed:
            findings.append({
                "rule": RULE_WAIVER, "path": p.path, "line": line,
                "message": message, "waived": False, "reason": None,
            })
        file_findings: list = []
        rule_unsafe(p, file_findings)
        rule_twin(p, twin_tests, file_findings)
        rule_hash(p, file_findings)
        rule_thread(p, file_findings)
        rule_fold(p, waivers, file_findings)
        rule_assert(p, file_findings)
        for f in file_findings:
            for w in waivers:
                if w["rule"] == f["rule"] and w["line"] in (f["line"], f["line"] - 1):
                    f["waived"] = True
                    f["reason"] = w["reason"]
                    break
        findings.extend(file_findings)
    findings.sort(key=lambda f: (f["path"], f["line"], f["rule"]))
    return findings


def unwaived_count(findings) -> int:
    return sum(1 for f in findings if not f["waived"])


def fixture_sources(directory: Path):
    sources = []
    for path in sorted(directory.iterdir()):
        if path.suffix != ".rs":
            continue
        text = path.read_text()
        first = text.splitlines()[0] if text else ""
        if first.startswith("//@ path:"):
            virt = first[len("//@ path:"):].strip()
        else:
            virt = f"src/{path.name}"
        sources.append((virt, text))
    return sources


def tree_sources(root: Path):
    if (root / "src").is_dir():
        src_dir, tests_dir = root / "src", root / "tests"
    else:
        src_dir, tests_dir = root, root.parent / "tests"
    sources = []
    for path in sorted(src_dir.rglob("*.rs")):
        rel = path.relative_to(src_dir)
        sources.append((f"src/{rel.as_posix()}", path.read_text()))
    twin = tests_dir / "simd_props.rs"
    if twin.is_file():
        sources.append((TWIN_TEST_FILE, twin.read_text()))
    return sources


def render_json(findings) -> str:
    return json.dumps({
        "tool": "pallas-lint",
        "findings": findings,
        "unwaived": unwaived_count(findings),
        "waived": len(findings) - unwaived_count(findings),
    }) + "\n"


def render_human(findings) -> str:
    lines = []
    for f in findings:
        tail = f" (waived: {f['reason']})" if f["waived"] else ""
        lines.append(f"{f['path']}:{f['line']}: [{f['rule']}] {f['message']}{tail}")
    unwaived = unwaived_count(findings)
    lines.append(
        f"pallas-lint: {len(findings)} finding(s), {unwaived} unwaived, "
        f"{len(findings) - unwaived} waived"
    )
    return "\n".join(lines) + "\n"


def main(argv) -> int:
    as_json = False
    fixture = False
    path = None
    for arg in argv:
        if arg == "--json":
            as_json = True
        elif arg == "--fixture":
            fixture = True
        elif arg in ("--help", "-h"):
            print("usage: pallas_lint.py [--json] [--fixture] <rust-root-or-src>",
                  file=sys.stderr)
            return 0
        elif arg.startswith("-"):
            print(f"pallas_lint.py: unknown flag `{arg}`", file=sys.stderr)
            return 2
        elif path is not None:
            print("pallas_lint.py: expected exactly one path argument", file=sys.stderr)
            return 2
        else:
            path = Path(arg)
    if path is None:
        print("usage: pallas_lint.py [--json] [--fixture] <rust-root-or-src>",
              file=sys.stderr)
        return 2
    try:
        sources = fixture_sources(path) if fixture else tree_sources(path)
    except OSError as exc:
        print(f"pallas_lint.py: cannot read `{path}`: {exc}", file=sys.stderr)
        return 2
    findings = analyze_sources(sources)
    sys.stdout.write(render_json(findings) if as_json else render_human(findings))
    return 0 if unwaived_count(findings) == 0 else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
