"""Self-test for ci/check_bench.py (run with pytest, or directly).

Exercises the paths a broken gate would silently wave through: a passing
bench, a genuine speedup regression, a missing required op, the
meta-record worker-count cases (explicit `workers` field honored; the
retired gflops smuggle and a bare meta both rejected), and the ISSUE-5
`isa`-aware SIMD-microkernel floors (gated as written on an "avx2" meta,
capped at parity on a scalar/missing meta so non-AVX2 runners are not
misread as regressions), and the ISSUE-8 fleet-bench records
(`requests_per_s` accepted in place of `gflops`, neither-field and
negative-value records rejected, the grouped-vs-solo parity floor), and
the ISSUE-10 service counters (`shed`/`retries`/`deadline_miss`
mandatory on `service_*` ops, non-negative everywhere).
"""

import json
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).parent))
import check_bench  # noqa: E402


def rec(op, shape="512x512x512", speedup=None, **extra):
    r = {"op": op, "shape": shape, "ns_per_iter": 100.0, "gflops": 1.0, **extra}
    if speedup is not None:
        r["speedup_vs_reference"] = speedup
    return r


META = {"op": "meta", "shape": "workers=4", "ns_per_iter": 1.0, "workers": 4.0}

BASELINE = {
    "regression_margin": 0.25,
    "threaded_keys": ["matmul_threaded@512x512x512"],
    "required_ops": ["meta", "matmul", "matmul_threaded"],
    # floor for a >= 8-worker machine: 2.7 * 0.75; capped at 0.6*workers
    "min_speedups": {"matmul_threaded@512x512x512": 2.7},
}


def gate(recs, baseline=BASELINE):
    """Run the gate on in-memory records; returns None on pass, raises
    SystemExit on failure (check_bench.die calls sys.exit(1))."""
    with tempfile.TemporaryDirectory() as d:
        bench = pathlib.Path(d) / "BENCH_linalg.json"
        base = pathlib.Path(d) / "baseline.json"
        bench.write_text(json.dumps(recs))
        base.write_text(json.dumps(baseline))
        check_bench.run(str(bench), str(base))


def expect_fail(recs, baseline=BASELINE):
    try:
        gate(recs, baseline)
    except SystemExit as e:
        assert e.code == 1, f"gate failed with unexpected code {e.code}"
        return
    raise AssertionError("gate passed but a FAIL was expected")


def test_passes_on_healthy_bench():
    # workers=4 caps the threaded floor at 0.6*4 = 2.4 → floor 1.8
    gate([META, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


def test_fails_on_speedup_regression():
    expect_fail([META, rec("matmul"), rec("matmul_threaded", speedup=1.0)])


def test_fails_on_missing_required_op():
    expect_fail([META, rec("matmul_threaded", speedup=2.0)])  # no "matmul"


def test_meta_workers_field_scales_threaded_floor():
    # 2-worker machine: cap = 1.2, floor = 0.9 → 1.0x passes there
    two = dict(META, workers=2.0)
    gate([two, rec("matmul"), rec("matmul_threaded", speedup=1.0)])
    # but the same 1.0x is a regression on an 8-worker machine (floor 2.02)
    eight = dict(META, workers=8.0)
    expect_fail([eight, rec("matmul"), rec("matmul_threaded", speedup=1.0)])


def test_meta_gflops_smuggle_no_longer_honored():
    # legacy BENCH file: worker count smuggled through gflops, no workers.
    # The one-release deprecation window is over — this is now rejected
    # even on a bench that would otherwise pass.
    legacy = {"op": "meta", "shape": "workers=2", "ns_per_iter": 1.0, "gflops": 2.0}
    expect_fail([legacy, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


def test_meta_missing_workers_rejected():
    bare = {"op": "meta", "shape": "workers=?", "ns_per_iter": 1.0}
    expect_fail([bare, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


def test_non_meta_record_must_carry_a_throughput_field():
    # neither gflops nor requests_per_s: malformed (ISSUE-8 rule)
    bad = {"op": "matmul", "shape": "512x512x512", "ns_per_iter": 100.0}
    expect_fail([META, bad, rec("matmul_threaded", speedup=2.0)])


# --- ISSUE-8 fleet-bench records: requests_per_s in place of gflops ------

FLEET_BASELINE = {
    "regression_margin": 0.25,
    "required_ops": [
        "meta",
        "fleet_train_grouped",
        "fleet_train_solo",
    ],
    # grouped-vs-solo parity floor: 1.0 before margin, 0.75 after
    "min_speedups": {"fleet_train_grouped": 1.0},
}


def fleet_rec(op, rps=120.0, speedup=None):
    r = {
        "op": op,
        "shape": "tenants8_n160_m16_q4",
        "ns_per_iter": 100.0,
        "requests_per_s": rps,
    }
    if speedup is not None:
        r["speedup_vs_reference"] = speedup
    return r


def test_requests_per_s_accepted_in_place_of_gflops():
    gate(
        [META, fleet_rec("fleet_train_grouped", speedup=1.4),
         fleet_rec("fleet_train_solo")],
        FLEET_BASELINE,
    )


def test_fleet_grouped_speedup_regression_fails():
    # 0.5x grouped-vs-solo is below the 0.75 parity floor
    expect_fail(
        [META, fleet_rec("fleet_train_grouped", speedup=0.5),
         fleet_rec("fleet_train_solo")],
        FLEET_BASELINE,
    )


def test_negative_requests_per_s_rejected():
    expect_fail(
        [META, fleet_rec("fleet_train_grouped", rps=-1.0, speedup=1.4),
         fleet_rec("fleet_train_solo")],
        FLEET_BASELINE,
    )


def test_negative_gflops_rejected():
    bad = rec("matmul", gflops=-1.0)
    expect_fail([META, bad, rec("matmul_threaded", speedup=2.0)])


# --- ISSUE-10 service ops: mandatory scheduling counters -----------------

SERVICE_BASELINE = {
    "regression_margin": 0.25,
    "required_ops": ["meta", "service_async_train", "service_overload_shed"],
    "min_speedups": {},
}


def service_rec(op, rps=120.0, **counters):
    r = fleet_rec(op, rps=rps)
    r.update({"shed": 0.0, "retries": 0.0, "deadline_miss": 0.0})
    r.update(counters)
    return r


def test_service_ops_with_counters_pass():
    gate(
        [META, service_rec("service_async_train"),
         service_rec("service_overload_shed", shed=15.0, deadline_miss=1.0)],
        SERVICE_BASELINE,
    )


def test_service_op_missing_counter_rejected():
    incomplete = service_rec("service_async_train")
    del incomplete["retries"]
    expect_fail(
        [META, incomplete, service_rec("service_overload_shed")],
        SERVICE_BASELINE,
    )


def test_negative_counter_rejected_on_any_record():
    # on a service op …
    expect_fail(
        [META, service_rec("service_async_train", shed=-1.0),
         service_rec("service_overload_shed")],
        SERVICE_BASELINE,
    )
    # … and even on a non-service record that happens to carry one
    stray = dict(rec("matmul"), retries=-2.0)
    expect_fail([META, stray, rec("matmul_threaded", speedup=2.0)])


def test_non_service_record_need_not_carry_counters():
    # plain fleet/linalg records stay valid without any counter fields
    gate([META, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


SIMD_BASELINE = {
    "regression_margin": 0.25,
    "simd_keys": ["axpy_simd"],
    "required_ops": ["meta", "axpy_simd", "axpy_scalar"],
    # a hypothetical raised SIMD floor: 2.0 on an AVX2 runner (floor 1.5),
    # capped at parity (floor 0.75) anywhere else
    "min_speedups": {"axpy_simd": 2.0},
}


def simd_recs(speedup, isa):
    meta = {"op": "meta", "shape": f"workers=4 isa={isa}", "ns_per_iter": 1.0,
            "workers": 4.0}
    if isa is not None:
        meta["isa"] = isa
    return [meta, rec("axpy_simd", shape="len4096", speedup=speedup),
            rec("axpy_scalar", shape="len4096")]


def test_simd_floor_gates_on_avx2_meta():
    gate(simd_recs(1.8, "avx2"), SIMD_BASELINE)  # above floor 1.5
    expect_fail(simd_recs(1.0, "avx2"), SIMD_BASELINE)  # parity is a regression


def test_simd_floor_capped_on_scalar_runner():
    # dispatched == scalar there: ~1.0 must pass (floor capped to 0.75) …
    gate(simd_recs(0.97, "scalar"), SIMD_BASELINE)
    # … but a real dispatcher overhead still fails
    expect_fail(simd_recs(0.5, "scalar"), SIMD_BASELINE)


def test_simd_floor_capped_when_isa_missing():
    # pre-ISSUE-5 BENCH file: no isa field → treated as scalar
    legacy = simd_recs(0.97, None)
    assert "isa" not in legacy[0]
    gate(legacy, SIMD_BASELINE)


def test_solve_report_primary_and_absent_pass():
    # ISSUE-6 meta: a primary rung is healthy …
    primary = dict(META, solve_report="primary")
    gate([primary, rec("matmul"), rec("matmul_threaded", speedup=2.0)])
    # … and a pre-ISSUE-6 BENCH file (no field) still gates
    gate([META, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


def test_solve_report_degraded_rung_warns_but_passes():
    ridge = dict(META, solve_report="ridge")
    gate([ridge, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


def test_solve_report_unknown_rung_rejected():
    bogus = dict(META, solve_report="panic")
    expect_fail([bogus, rec("matmul"), rec("matmul_threaded", speedup=2.0)])


def test_malformed_bench_json_rejected():
    with tempfile.TemporaryDirectory() as d:
        bench = pathlib.Path(d) / "BENCH_linalg.json"
        base = pathlib.Path(d) / "baseline.json"
        bench.write_text("not json")
        base.write_text(json.dumps(BASELINE))
        try:
            check_bench.run(str(bench), str(base))
        except SystemExit as e:
            assert e.code == 1
            return
        raise AssertionError("malformed JSON passed the gate")


if __name__ == "__main__":
    failures = 0
    for name, fn in sorted(globals().items()):
        if name.startswith("test_") and callable(fn):
            try:
                fn()
                print(f"ok: {name}")
            except AssertionError as e:
                failures += 1
                print(f"FAIL: {name}: {e}", file=sys.stderr)
    sys.exit(1 if failures else 0)
