"""Self-test for the pallas-lint gate (run with pytest).

Three layers, mirroring how the gate can break:

1. **Fixture verdicts.** Every rule's pass/fail fixture pair under
   `rust/lint/fixtures/` must produce its labelled verdict from the
   Python mirror (`ci/pallas_lint.py`). The Rust implementation asserts
   the same fixtures in `rust/lint/src/lib.rs`, so this shared suite is
   the sync contract between the two implementations — a rule change
   that lands on one side only fails here or there, never silently.
2. **Real tree.** The mirror must report the actual `rust/` tree clean
   (zero unwaived findings, every waiver carrying its reason) — the same
   bar CI's blocking lint job holds the Rust binary to.
3. **Wrapper process contract.** `ci/check_lint.py` must exit 0 on a
   clean tree, 1 on a seeded fixture violation, and 2 when the linter
   underneath crashes or emits garbage — all driven through the
   `PALLAS_LINT_CMD` hook the CI cross-check also uses.
"""

import os
import pathlib
import shlex
import subprocess
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent))
import pallas_lint  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "rust" / "lint" / "fixtures"
CHECK = REPO / "ci" / "check_lint.py"
MIRROR = REPO / "ci" / "pallas_lint.py"


def analyze_fixture(directory):
    return pallas_lint.analyze_sources(pallas_lint.fixture_sources(directory))


# --- layer 1: fixture verdicts ---------------------------------------------


def test_fixture_suite_covers_every_rule_exactly():
    dirs = sorted(p.name for p in FIXTURES.iterdir() if p.is_dir())
    assert dirs == sorted(pallas_lint.RULES)


def test_pass_fixtures_clean_and_fail_fixtures_fire_their_rule():
    for rule in pallas_lint.RULES:
        clean = analyze_fixture(FIXTURES / rule / "pass")
        assert pallas_lint.unwaived_count(clean) == 0, (rule, clean)
        fired = [
            f
            for f in analyze_fixture(FIXTURES / rule / "fail")
            if not f["waived"]
        ]
        assert fired, f"{rule}: fail fixture produced no findings"
        assert any(f["rule"] == rule for f in fired), (rule, fired)


def test_waiver_pass_fixture_records_reasons():
    findings = analyze_fixture(FIXTURES / "waiver-reason" / "pass")
    waived = [f for f in findings if f["waived"]]
    assert waived, "waiver pass fixture should produce waived findings"
    assert all(f["reason"] for f in waived)


# --- layer 2: the real tree ------------------------------------------------


def test_real_tree_is_clean_with_reasoned_waivers():
    findings = pallas_lint.analyze_sources(
        pallas_lint.tree_sources(REPO / "rust")
    )
    unwaived = [f for f in findings if not f["waived"]]
    assert unwaived == [], unwaived
    for f in findings:
        assert f["reason"], f"waiver without a reason survived: {f}"


# --- layer 3: the check_lint.py wrapper ------------------------------------


def run_wrapper(root, cmd):
    env = dict(os.environ, PALLAS_LINT_CMD=cmd)
    return subprocess.run(
        [sys.executable, str(CHECK), str(root)],
        capture_output=True,
        text=True,
        env=env,
    )


def mirror_cmd(fixture=False):
    cmd = f"{shlex.quote(sys.executable)} {shlex.quote(str(MIRROR))}"
    return f"{cmd} --fixture" if fixture else cmd


def test_wrapper_passes_on_clean_tree():
    proc = run_wrapper(REPO / "rust", mirror_cmd())
    assert proc.returncode == 0, (proc.stdout, proc.stderr)
    assert "lint gate passed" in proc.stdout


def test_wrapper_fails_on_seeded_fixture_violation():
    proc = run_wrapper(FIXTURES / "hash-order" / "fail", mirror_cmd(fixture=True))
    assert proc.returncode == 1, (proc.stdout, proc.stderr)
    assert "hash-order" in proc.stderr


def test_wrapper_passes_on_pass_fixture():
    proc = run_wrapper(FIXTURES / "hash-order" / "pass", mirror_cmd(fixture=True))
    assert proc.returncode == 0, (proc.stdout, proc.stderr)


def test_wrapper_hard_fails_on_non_json_linter():
    proc = run_wrapper(REPO / "rust", "echo not-json")
    assert proc.returncode == 2, (proc.stdout, proc.stderr)


def test_wrapper_hard_fails_on_linter_crash():
    crash = f"{shlex.quote(sys.executable)} -c \"import sys; sys.exit(3)\""
    proc = run_wrapper(REPO / "rust", crash)
    assert proc.returncode == 2, (proc.stdout, proc.stderr)
