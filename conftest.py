# Make `pytest python/tests/` work from the repo root: the compile/tests
# packages live under python/.
import sys, pathlib

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
