//! End-to-end driver (§7.6 / Fig 5 / Table 6): non-iterative Opt-PR-ELM
//! against iterative P-BPTT on a real small workload, exercising every
//! layer of the stack — data generator → windowing → rust coordinator →
//! PJRT → Pallas-lowered H kernels / jax fwd+bwd Adam step.
//!
//! Trains an LSTM (M = 10) on the Japan-population benchmark: P-BPTT for
//! 10 epochs (batch 64, Adam, MSE — the paper's setup), logging the loss
//! curve; Opt-PR-ELM in one shot. Writes `results/elm_vs_bptt.md`.
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example elm_vs_bptt
//! ```

use std::fmt::Write as _;

use opt_pr_elm::bptt::{BpttArch, BpttTrainer};
use opt_pr_elm::coordinator::PrElmTrainer;
use opt_pr_elm::data::spec::by_name;
use opt_pr_elm::elm::Arch;
use opt_pr_elm::report::prep::prepare;
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let spec = by_name("japan_population").expect("registry");
    // japan is the smallest benchmark: run it at full published size
    let (train, test) = prepare(&spec, 1.0, 7)?;
    println!(
        "japan_population (full size): {} train / {} test windows, Q = {}",
        train.n, test.n, train.q
    );

    // ---- P-BPTT: 10 epochs, batch 64, Adam ------------------------------
    let bptt = BpttTrainer::new(&default_artifacts_dir())?;
    let (bptt_model, log) = bptt.train(BpttArch::Lstm, &train, 10, 7)?;
    let bptt_mse = bptt.mse(&bptt_model, &test)?;
    println!(
        "\nP-BPTT     : {:.2}s over {} steps; test MSE {bptt_mse:.6}",
        log.total_s, log.steps
    );

    // ---- Opt-PR-ELM: one shot -------------------------------------------
    let elm = PrElmTrainer::new(&default_artifacts_dir(), 2)?;
    let t0 = std::time::Instant::now();
    let (elm_model, bd) = elm.train(Arch::Lstm, &train, 10, 7)?;
    let elm_s = t0.elapsed().as_secs_f64();
    let elm_rmse = elm.rmse(&elm_model, &test)?;
    let elm_mse = elm_rmse * elm_rmse;
    println!(
        "Opt-PR-ELM : {elm_s:.4}s ({} blocks); test MSE {elm_mse:.6}",
        bd.blocks
    );
    println!("ratio      : P-BPTT / Opt-PR-ELM = {:.0}x", log.total_s / elm_s);

    // time for BPTT to first touch the ELM's MSE (the paper's "69 s" read)
    let crossing = log.points.iter().find(|p| p.mse <= elm_mse);
    match crossing {
        Some(p) => println!(
            "P-BPTT reaches ELM-level MSE after {:.2}s ({}x the ELM's training time)",
            p.t_s,
            (p.t_s / elm_s).round()
        ),
        None => println!("P-BPTT never reaches the ELM's MSE within 10 epochs"),
    }

    // ---- Fig-5-style loss curve → results/ ------------------------------
    let mut md = String::new();
    let _ = writeln!(md, "# ELM vs BPTT (japan_population, LSTM, M=10)\n");
    let _ = writeln!(md, "| t (s) | minibatch MSE |");
    let _ = writeln!(md, "|-------|---------------|");
    let stride = (log.points.len() / 30).max(1);
    for p in log.points.iter().step_by(stride) {
        let _ = writeln!(md, "| {:.3} | {:.6} |", p.t_s, p.mse);
    }
    let _ = writeln!(
        md,
        "\nOpt-PR-ELM point: {elm_s:.4} s, test MSE {elm_mse:.6}\n\
         P-BPTT total: {:.2} s, test MSE {bptt_mse:.6}",
        log.total_s
    );
    std::fs::create_dir_all("results")?;
    std::fs::write("results/elm_vs_bptt.md", md)?;
    println!("\nwrote results/elm_vs_bptt.md");
    Ok(())
}
