//! Electricity-load forecasting with model selection — the §3.2.2
//! motivation: "even with non-iterative training ... model selection is
//! performed to avoid over-fitting". Sweeps the hidden-layer width M over
//! the AOT grid on a validation split, picks the best GRU, and reports
//! the held-out error; the parallel pipeline makes the sweep cheap.
//!
//! ```sh
//! cargo run --release --example forecast_electricity
//! ```

use opt_pr_elm::coordinator::PrElmTrainer;
use opt_pr_elm::data::spec::by_name;
use opt_pr_elm::elm::Arch;
use opt_pr_elm::report::prep::prepare;
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let spec = by_name("energy_consumption").expect("registry");
    let (train_all, test) = prepare(&spec, 0.08, 11)?;
    // carve a validation tail off the training windows (time-ordered)
    let (train, val) = train_all.split(0.85);
    println!(
        "energy_consumption: {} train / {} val / {} test windows (Q = {})",
        train.n, val.n, test.n, train.q
    );

    let trainer = PrElmTrainer::new(&default_artifacts_dir(), 2)?;
    let t0 = std::time::Instant::now();
    let mut best: Option<(usize, f64)> = None;
    println!("\n M   val RMSE   train (s)");
    for m in [5usize, 10, 20, 50, 100] {
        let ts = std::time::Instant::now();
        let (model, _bd) = trainer.train(Arch::Gru, &train, m, 3)?;
        let rmse = trainer.rmse(&model, &val)?;
        println!("{m:>3}   {rmse:.5}    {:.3}", ts.elapsed().as_secs_f64());
        if best.map_or(true, |(_, r)| rmse < r) {
            best = Some((m, rmse));
        }
    }
    let (m_star, val_rmse) = best.expect("sweep ran");
    println!("\nselected M = {m_star} (val RMSE {val_rmse:.5})");

    // refit on train+val, evaluate held-out
    let (model, _bd) = trainer.train(Arch::Gru, &train_all, m_star, 3)?;
    let test_rmse = trainer.rmse(&model, &test)?;
    println!(
        "held-out test RMSE {test_rmse:.5}; whole sweep + refit took {:.2}s",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
