//! Portability study (§7.4 / Table 5): Opt-PR-ELM speedups on the two GPU
//! architectures through the calibrated gpusim model, at the paper's full
//! dataset sizes — how architecture-dependent is the algorithm?
//!
//! ```sh
//! cargo run --release --example portability
//! ```

use opt_pr_elm::data::spec::registry;
use opt_pr_elm::elm::ALL_ARCHS;
use opt_pr_elm::gpusim::{cpu_host, quadro_k2000, simulate, tesla_k20m, SimConfig, Variant};

fn main() {
    let host = cpu_host();
    println!(
        "{:<8} {:<20} {:>12} {:>12} {:>8}",
        "arch", "dataset", "Tesla K20m", "Quadro K2000", "ratio"
    );
    for arch in ALL_ARCHS {
        for d in registry() {
            let cfg = SimConfig {
                arch,
                variant: Variant::Opt,
                n: d.n_instances.saturating_sub(d.q_paper.min(64)),
                s: 1,
                q: d.q_paper.min(64),
                m: 50,
                bs: 32,
            };
            let t = simulate(&cfg, &tesla_k20m(), &host);
            let q = simulate(&cfg, &quadro_k2000(), &host);
            println!(
                "{:<8} {:<20} {:>11.0}x {:>11.0}x {:>8.2}",
                arch.name(),
                d.name,
                t.speedup,
                q.speedup,
                t.speedup / q.speedup
            );
        }
        println!();
    }
    println!(
        "Portability verdict (paper §7.4): the algorithm keeps high speedups on the\n\
         much smaller Quadro because large-dataset runs are dominated by the shared\n\
         host-side β solve and transfers, not the kernel."
    );
}
