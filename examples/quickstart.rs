//! Quickstart: train an Elman RNN non-iteratively on the AEMO electricity
//! demand benchmark through the full three-layer stack (rust coordinator →
//! PJRT → Pallas-lowered H kernels), and compare with the sequential
//! baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use opt_pr_elm::coordinator::PrElmTrainer;
use opt_pr_elm::data::spec::by_name;
use opt_pr_elm::elm::{Arch, SrElmModel, TrainOptions};
use opt_pr_elm::report::prep::prepare;
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let spec = by_name("aemo").expect("registry");
    // 10% of the published dataset size keeps the demo fast
    let (train, test) = prepare(&spec, 0.10, 42)?;
    println!("AEMO: {} train / {} test windows, Q = {}", train.n, test.n, train.q);

    // --- parallel: Opt-PR-ELM over the AOT artifacts --------------------
    let trainer = PrElmTrainer::new(&default_artifacts_dir(), 2)?;
    let t0 = std::time::Instant::now();
    let (model, bd) = trainer.train(Arch::Elman, &train, 50, 1)?;
    let par_s = t0.elapsed().as_secs_f64();
    let par_rmse = trainer.rmse(&model, &test)?;
    println!(
        "Opt-PR-ELM  : {par_s:.3}s ({} blocks; exec {:.3}s, solve {:.4}s) test RMSE {par_rmse:.5}",
        bd.blocks, bd.exec_s, bd.solve_s
    );

    // --- sequential baseline --------------------------------------------
    let t1 = std::time::Instant::now();
    let seq = SrElmModel::train(Arch::Elman, &train, &TrainOptions::new(50, 1))?;
    let seq_s = t1.elapsed().as_secs_f64();
    println!("S-R-ELM     : {seq_s:.3}s test RMSE {:.5}", seq.rmse(&test));
    println!("speedup     : {:.1}x", seq_s / par_s);

    // one-step-ahead forecast sample
    let preds = trainer.predict(&model, &test)?;
    println!("\nfirst 5 one-step forecasts vs truth:");
    for i in 0..5.min(test.n) {
        println!("  t+{i}: pred {:.4}  true {:.4}", preds[i], test.y[i]);
    }
    Ok(())
}
