"""AOT driver: lower every manifest artifact to HLO text.

HLO *text* (not ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    cd python && python -m compile.aot --out ../artifacts [--jobs N] [--force]

Python runs ONLY here; the rust binary is self-contained once artifacts/
exists. Idempotent: artifacts newer than the compile/ sources are skipped.
"""

from __future__ import annotations

import argparse
import concurrent.futures as cf
import json
import os
import pathlib
import sys
import time


def _sources_mtime() -> float:
    root = pathlib.Path(__file__).parent
    return max(p.stat().st_mtime for p in root.rglob("*.py"))


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple ABI)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name: str, out_dir: str) -> str:
    """Lower one artifact by name (runs in a worker process)."""
    import jax

    from compile import manifest

    spec = {s.name: s for s in manifest.specs()}[name]
    fn, inputs, _outputs = spec.build()
    args = [jax.ShapeDtypeStruct(tuple(shape), jax.numpy.float32) for _n, shape in inputs]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return f"{name}: {len(text)} chars in {time.time() - t0:.1f}s"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--jobs", type=int, default=min(8, os.cpu_count() or 1))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", help="comma-separated artifact name filter")
    args = ap.parse_args()

    from compile import manifest

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    all_specs = manifest.specs()
    if args.only:
        keep = set(args.only.split(","))
        all_specs = [s for s in all_specs if s.name in keep]

    src_mtime = _sources_mtime()
    todo = []
    for s in all_specs:
        path = os.path.join(out_dir, f"{s.name}.hlo.txt")
        if args.force or not os.path.exists(path) or os.path.getmtime(path) < src_mtime:
            todo.append(s.name)

    print(f"{len(all_specs)} artifacts, {len(todo)} to lower (jobs={args.jobs})")
    t0 = time.time()
    failed = []
    if todo:
        with cf.ProcessPoolExecutor(max_workers=args.jobs) as ex:
            futs = {ex.submit(lower_one, n, out_dir): n for n in todo}
            for fut in cf.as_completed(futs):
                name = futs[fut]
                try:
                    print("  " + fut.result())
                except Exception as e:  # pragma: no cover - surfaced to make
                    failed.append(name)
                    print(f"  {name}: FAILED: {e}", file=sys.stderr)

    # manifest.json covers the full grid (cheap: builder metadata only).
    entries = [manifest.manifest_entry(s) for s in manifest.specs()]
    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump({"artifacts": entries}, f, indent=1)
    print(f"wrote {man_path} ({len(entries)} entries) in {time.time() - t0:.0f}s total")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
