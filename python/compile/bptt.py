"""L2: P-BPTT comparator — full fwd/bwd Adam train step (§7.6, Table 6, Fig 5).

The paper compares Opt-PR-ELM against TensorFlow BPTT [11] on the fully
connected, LSTM and GRU architectures (M = 10, batch 64, MSE, Adam, 10
epochs). We reproduce that comparator as a jax train step — loss through the
unrolled recurrence, reverse-mode gradients, Adam update — AOT-lowered to one
HLO executable that the rust `bptt` driver invokes per minibatch.

Unlike the ELM H kernels (diagonal recurrence per the paper's thread model),
the BPTT cells are the *standard full* cells, matching what TensorFlow's
layers implement.

Parameter order (the ABI recorded in the manifest):
    fc:   wx (S, M),  wh (M, M),  b (M,),  wo (M,), bo (1,)
    lstm: wx (S, 4M), wh (M, 4M), b (4M,), wo (M,), bo (1,)   gates [i, f, g, o]
    gru:  wx (S, 3M), wh (M, 3M), b (3M,), wo (M,), bo (1,)   gates [z, r, n]

Step signature:
    (t, x (B,S,Q), y (B,), *params, *m, *v) -> (loss, *params', *m', *v')
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from compile.common import DTYPE, sigmoid

BPTT_ARCHS = ("fc", "lstm", "gru")

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
ADAM_LR = 1e-2


def param_shapes(arch: str, s: int, m: int) -> List[Tuple[str, Tuple[int, ...]]]:
    gates = {"fc": 1, "lstm": 4, "gru": 3}[arch]
    return [
        ("wx", (s, gates * m)),
        ("wh", (m, gates * m)),
        ("b", (gates * m,)),
        ("wo", (m,)),
        ("bo", (1,)),
    ]


def _forward(arch: str, m: int, x, params):
    """x: (B, S, Q) -> yhat (B,). Scan over the Q timesteps."""
    wx, wh, b, wo, bo = params
    xs = jnp.moveaxis(x, 2, 0)  # (Q, B, S)
    batch = x.shape[0]

    if arch == "fc":

        def step(h, x_t):
            h_new = jnp.tanh(x_t @ wx + h @ wh + b)
            return h_new, None

        h0 = jnp.zeros((batch, m), x.dtype)
        h, _ = jax.lax.scan(step, h0, xs)
        return h @ wo + bo[0]

    if arch == "lstm":

        def step(carry, x_t):
            h, c = carry
            z = x_t @ wx + h @ wh + b
            i = sigmoid(z[:, 0 * m : 1 * m])
            f = sigmoid(z[:, 1 * m : 2 * m])
            g = jnp.tanh(z[:, 2 * m : 3 * m])
            o = sigmoid(z[:, 3 * m : 4 * m])
            c_new = f * c + i * g
            h_new = o * jnp.tanh(c_new)
            return (h_new, c_new), None

        zeros = jnp.zeros((batch, m), x.dtype)
        (h, _c), _ = jax.lax.scan(step, (zeros, zeros), xs)
        return h @ wo + bo[0]

    if arch == "gru":

        def step(h, x_t):
            zx = x_t @ wx + b
            zh = h @ wh
            z = sigmoid(zx[:, 0 * m : 1 * m] + zh[:, 0 * m : 1 * m])
            r = sigmoid(zx[:, 1 * m : 2 * m] + zh[:, 1 * m : 2 * m])
            n = jnp.tanh(zx[:, 2 * m : 3 * m] + r * zh[:, 2 * m : 3 * m])
            h_new = (1.0 - z) * h + z * n
            return h_new, None

        h0 = jnp.zeros((batch, m), x.dtype)
        h, _ = jax.lax.scan(step, h0, xs)
        return h @ wo + bo[0]

    raise ValueError(arch)


def loss_fn(arch: str, m: int, x, y, params):
    yhat = _forward(arch, m, x, params)
    return jnp.mean(jnp.square(yhat - y))


def bptt_step(
    arch: str, batch: int, s: int, q: int, m: int
) -> Tuple[Callable, List[Tuple[str, Tuple[int, ...]]], List[str]]:
    """Build the train-step graph; returns (fn, input_specs, output_names)."""
    if arch not in BPTT_ARCHS:
        raise ValueError(f"bptt arch must be one of {BPTT_ARCHS}, got {arch}")
    pshapes = param_shapes(arch, s, m)
    n_params = len(pshapes)

    inputs: List[Tuple[str, Tuple[int, ...]]] = [
        ("t", (1,)),
        ("x", (batch, s, q)),
        ("y", (batch,)),
    ]
    inputs += [(f"p_{n}", shp) for n, shp in pshapes]
    inputs += [(f"m_{n}", shp) for n, shp in pshapes]
    inputs += [(f"v_{n}", shp) for n, shp in pshapes]
    outputs = (
        ["loss"]
        + [f"p_{n}" for n, _ in pshapes]
        + [f"m_{n}" for n, _ in pshapes]
        + [f"v_{n}" for n, _ in pshapes]
    )

    def fn(*args):
        t, x, y = args[0], args[1], args[2]
        params = list(args[3 : 3 + n_params])
        ms = list(args[3 + n_params : 3 + 2 * n_params])
        vs = list(args[3 + 2 * n_params : 3 + 3 * n_params])

        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(arch, m, x, y, ps)
        )(params)

        tt = t[0]
        bc1 = 1.0 - jnp.power(ADAM_B1, tt)
        bc2 = 1.0 - jnp.power(ADAM_B2, tt)
        new_p, new_m, new_v = [], [], []
        for p, mm, vv, g in zip(params, ms, vs, grads):
            mm = ADAM_B1 * mm + (1.0 - ADAM_B1) * g
            vv = ADAM_B2 * vv + (1.0 - ADAM_B2) * jnp.square(g)
            update = ADAM_LR * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS)
            new_p.append(p - update)
            new_m.append(mm)
            new_v.append(vv)
        return tuple([jnp.reshape(loss, (1,))] + new_p + new_m + new_v)

    return fn, inputs, outputs


def bptt_predict(
    arch: str, batch: int, s: int, q: int, m: int
) -> Tuple[Callable, List[Tuple[str, Tuple[int, ...]]], List[str]]:
    """Inference graph for the comparator: (x, *params) -> (yhat,)."""
    pshapes = param_shapes(arch, s, m)
    inputs = [("x", (batch, s, q))] + [(f"p_{n}", shp) for n, shp in pshapes]

    def fn(x, *params):
        return (_forward(arch, m, x, list(params)),)

    return fn, inputs, ["yhat"]
