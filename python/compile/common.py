"""Shared conventions for the compile path (L1 kernels + L2 graphs).

Parameter layouts are positional and fixed per architecture so the rust
runtime can marshal literals without any python at runtime. The canonical
order for every ELM graph is::

    X (R, S, Q) [, Yhist (R, Qy)] [, Ehist (R, Qe)], <params...> [, Y, mask]

and the per-architecture parameter lists are defined by ``param_specs``.

All arrays are float32. ``R`` is the row-block size (the coordinator streams
datasets through fixed-shape blocks, padding the tail block and masking the
padded rows out of the Gram/TSQR accumulation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp

ARCHS = ("elman", "jordan", "narmax", "fc", "lstm", "gru")

#: Architectures whose H recurrence feeds back hidden state (true loop over t).
RECURRENT_ARCHS = ("elman", "fc", "lstm", "gru")

#: Architectures whose feedback is exogenous (targets / residuals): H(Q) is a
#: direct function of the inputs, no hidden-state loop (see DESIGN.md §2).
EXOGENOUS_ARCHS = ("jordan", "narmax")

DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """Static shape configuration of one compiled ELM graph."""

    arch: str
    rows: int  # R: row-block size
    s: int  # S: input features per timestep
    q: int  # Q: time dependency length
    m: int  # M: hidden neurons
    variant: str = "opt"  # "basic" (untiled) | "opt" (VMEM-tiled)
    block_rows: int = 32  # BS/TW of the paper, applied to the row dimension

    def __post_init__(self) -> None:
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}")
        if self.variant not in ("basic", "opt"):
            raise ValueError(f"unknown variant {self.variant!r}")
        for field in ("rows", "s", "q", "m", "block_rows"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be positive")
        if self.variant == "opt" and self.rows % self.block_rows != 0:
            raise ValueError(
                f"rows={self.rows} not divisible by block_rows={self.block_rows}"
            )


def param_specs(cfg: ShapeCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of the random ELM parameters of ``cfg``.

    These are the paper's W, alpha, b (and gate variants): randomly generated
    once by the coordinator, fixed during training.
    """
    s, q, m = cfg.s, cfg.q, cfg.m
    if cfg.arch == "elman":
        return [("w", (s, m)), ("b", (m,)), ("alpha", (m, q))]
    if cfg.arch == "jordan":
        return [("w", (s, m)), ("b", (m,)), ("alpha", (m, q))]
    if cfg.arch == "narmax":
        # F = R = Q: output- and error-feedback window both span the lag window.
        return [("w", (s, m)), ("b", (m,)), ("wp", (m, q)), ("wpp", (m, q))]
    if cfg.arch == "fc":
        return [("w", (s, m)), ("b", (m,)), ("alpha", (m, m, q))]
    if cfg.arch == "lstm":
        # Gate order: [o, c~, lambda(forget), in] — stacked on axis 1 (resp. 0).
        return [("w4", (s, 4, m)), ("u4", (4, m)), ("b4", (4, m))]
    if cfg.arch == "gru":
        # Gate order: [z, r, f].
        return [("w3", (s, 3, m)), ("u3", (3, m)), ("b3", (3, m))]
    raise ValueError(cfg.arch)


def extra_input_specs(cfg: ShapeCfg) -> List[Tuple[str, Tuple[int, ...]]]:
    """Exogenous feedback inputs (before params): Jordan / NARMAX histories."""
    if cfg.arch == "jordan":
        return [("yhist", (cfg.rows, cfg.q))]
    if cfg.arch == "narmax":
        return [("yhist", (cfg.rows, cfg.q)), ("ehist", (cfg.rows, cfg.q))]
    return []


def sigmoid(x):
    return jnp.reciprocal(1.0 + jnp.exp(-x))
