"""L1: Pallas H-recurrence kernels, one module per RNN architecture.

``h_pallas(cfg)`` dispatches to the architecture module and returns a
callable ``(x, *extras, *params) -> H`` with the canonical input order of
``compile.common``. ``ref.h_ref`` is the pure-jnp oracle each kernel is
tested against.
"""

from __future__ import annotations

from compile.common import ShapeCfg
from compile.kernels import elman, fc, gru, jordan, lstm, narmax

_BUILDERS = {
    "elman": elman.build,
    "jordan": jordan.build,
    "narmax": narmax.build,
    "fc": fc.build,
    "lstm": lstm.build,
    "gru": gru.build,
}


def h_pallas(cfg: ShapeCfg):
    """Pallas H computation for ``cfg`` (interpret mode)."""
    return _BUILDERS[cfg.arch](cfg)
