"""Shared Pallas harness: grid/BlockSpec construction for H kernels.

The CUDA → TPU mapping (DESIGN.md §Hardware-Adaptation):

* paper thread block (BS × BS)            →  grid cell over a row tile
* shared-memory tiles of W / X (Alg 3)    →  BlockSpec staging into VMEM
* per-thread register history ``H_loc``   →  fori_loop carry inside the cell
* ``basic`` variant (Alg 2, no tiling)    →  single grid cell, full arrays

Because the number of hidden neurons M is small (5-100) relative to the row
block R (256), tiling is applied to the row (sample) dimension: one grid cell
computes an ``(block_rows × M)`` tile of H. Under ``interpret=True`` both
variants are numerically identical; the cost difference between them is what
``gpusim`` models (Table 2 / §5 of the paper).
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
from jax.experimental import pallas as pl

from compile.common import DTYPE, ShapeCfg, extra_input_specs, param_specs


def _tile_geometry(cfg: ShapeCfg) -> Tuple[Tuple[int, ...], int]:
    """(grid, block_rows) for the given variant."""
    if cfg.variant == "basic":
        return (1,), cfg.rows
    return (cfg.rows // cfg.block_rows,), cfg.block_rows


def _row_spec(shape: Tuple[int, ...], br: int) -> pl.BlockSpec:
    """Block over the leading (row) dimension, full trailing dims."""
    blk = (br,) + tuple(shape[1:])
    ndim = len(shape)
    return pl.BlockSpec(blk, lambda i, _nd=ndim: (i,) + (0,) * (_nd - 1))


def _full_spec(shape: Tuple[int, ...]) -> pl.BlockSpec:
    """Whole-array block, replicated to every grid cell (W, alpha, b...)."""
    ndim = len(shape)
    return pl.BlockSpec(tuple(shape), lambda i, _nd=ndim: (0,) * _nd)


def make_h(cfg: ShapeCfg, kernel: Callable) -> Callable:
    """Wrap an architecture kernel body into a pallas_call.

    ``kernel`` receives refs in the canonical order
    ``(x_ref, *extra_refs, *param_refs, o_ref)`` where x/extras are row-tiled
    and params are whole-array; it writes the ``(block_rows, M)`` H tile.
    """
    grid, br = _tile_geometry(cfg)
    x_shape = (cfg.rows, cfg.s, cfg.q)
    in_specs: List[pl.BlockSpec] = [_row_spec(x_shape, br)]
    for _name, shape in extra_input_specs(cfg):
        in_specs.append(_row_spec(shape, br))
    for _name, shape in param_specs(cfg):
        in_specs.append(_full_spec(shape))

    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((cfg.rows, cfg.m), DTYPE),
        grid=grid,
        in_specs=in_specs,
        out_specs=_row_spec((cfg.rows, cfg.m), br),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )

    def h(x, *rest):
        return call(x, *rest)

    return h


def vmem_bytes(cfg: ShapeCfg) -> int:
    """Estimated VMEM footprint of one grid cell (bytes, f32).

    Used by the perf pass and gpusim to check block shapes against the
    16 MiB/core VMEM budget (the TPU analog of the K20m's 48 KiB shared
    memory constraint).
    """
    _grid, br = _tile_geometry(cfg)
    f32 = 4
    tile_in = br * cfg.s * cfg.q  # X tile
    params = sum(
        int(__import__("math").prod(shape)) for _n, shape in param_specs(cfg)
    )
    extras = sum(br * shape[1] for _n, shape in extra_input_specs(cfg))
    # carried history: Q states of (br, M) for recurrent archs, 2 for
    # lstm (f, c), 1 otherwise; plus the per-t input projection cache.
    hist = {"elman": cfg.q, "fc": cfg.q, "lstm": 2, "gru": 1}.get(cfg.arch, 0)
    carry = hist * br * cfg.m
    gates = {"lstm": 4, "gru": 3}.get(cfg.arch, 1)
    wx_cache = cfg.q * gates * br * cfg.m
    out = br * cfg.m
    return f32 * (tile_in + params + extras + carry + wx_cache + out)
