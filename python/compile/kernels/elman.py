"""Elman H kernel (Eq 6 / Alg 2-3 of the paper).

One grid cell computes a ``(block_rows, M)`` tile of H(Q). The per-thread
register file ``H_loc`` of Alg 3 becomes a fori_loop carry holding the last
Q hidden states of the tile; the shared-memory W/X tiles become the
BlockSpec-staged VMEM blocks (see kernels.common).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.common import ShapeCfg
from compile.kernels.common import make_h


def _kernel(q: int):
    def kernel(x_ref, w_ref, b_ref, alpha_ref, o_ref):
        x = x_ref[...]  # (br, S, Q)   VMEM tile (Alg 3 line 10)
        w = w_ref[...]  # (S, M)       VMEM tile (Alg 3 line 9)
        b = b_ref[...]  # (M,)         loaded once per cell (Alg 3 line 16)
        alpha = alpha_ref[...]  # (M, Q)

        br = x.shape[0]
        m = w.shape[1]
        # Input projection for all timesteps at once: the tiled dot product
        # of Alg 3 lines 8-13, hoisted out of the t loop.
        wx = jnp.einsum("rsq,sm->qrm", x, w)

        # Ring-buffer history (the register file H_loc of Alg 3): slot
        # t mod Q holds h(t). Instead of shifting the large (Q, br, M)
        # history every step (O(Q·br·M) copies), we gather the *small*
        # (M, Q) alpha into slot order — §Perf L1 optimization, ~4x on
        # Q = 50 blocks (EXPERIMENTS.md).
        slots = jnp.arange(q)

        def step(t, hist):
            # slot j holds h(t-k) with k = (t - j) mod Q  ⇒  the weight
            # for slot j is alpha[:, (t - 1 - j) mod Q]
            a_idx = jnp.mod(t - 1 - slots, q)
            a_slot = jnp.take(alpha, a_idx, axis=1)  # (M, Q)
            rec = jnp.einsum("mj,jrm->rm", a_slot, hist)
            h_t = jnp.tanh(wx[t] + b[None, :] + rec)
            return jax.lax.dynamic_update_index_in_dim(
                hist, h_t, jnp.mod(t, q), axis=0
            )

        hist0 = jnp.zeros((q, br, m), x.dtype)
        hist = jax.lax.fori_loop(0, q, step, hist0)
        # final state h(Q-1) lives in slot (Q-1) mod Q = Q-1
        o_ref[...] = hist[q - 1]

    return kernel


def build(cfg: ShapeCfg):
    """(x, w, b, alpha) -> H of shape (rows, M)."""
    assert cfg.arch == "elman"
    return make_h(cfg, _kernel(cfg.q))
