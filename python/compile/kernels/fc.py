"""Fully connected RNN H kernel (Eq 9).

Unlike the diagonal architectures, every neuron sees every neuron's history
(alpha is (M, M, Q)), so the neuron dimension cannot be tiled — the grid
tiles rows (samples) only and each cell carries the full (Q, br, M) history.
This is the paper's most compute-heavy architecture (Table 2: FLOPS grow
with 2QM per element).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.common import ShapeCfg
from compile.kernels.common import make_h


def _kernel(q: int):
    def kernel(x_ref, w_ref, b_ref, alpha_ref, o_ref):
        x = x_ref[...]  # (br, S, Q)
        w = w_ref[...]  # (S, M)
        b = b_ref[...]  # (M,)
        alpha = alpha_ref[...]  # (M, M, Q): alpha[j, l, k]

        br = x.shape[0]
        m = w.shape[1]
        wx = jnp.einsum("rsq,sm->qrm", x, w)

        # NOTE on the history layout: unlike elman.py's ring buffer, FC
        # keeps the shifted (k-ordered) history. The elman trick gathers
        # alpha into slot order, but FC's alpha is (M, M, Q) — the gather
        # would move M²Q elements/step vs Q·br·M for the shift, which is
        # *more* for every benchmark shape; and the O(M²·Q·br) recurrence
        # einsum dominates either way (§Perf).
        def step(t, hist):
            # hist[k-1] == h(t-k) for all neurons: (Q, br, M)
            rec = jnp.einsum("mlk,krl->rm", alpha, hist)
            h_t = jnp.tanh(wx[t] + b[None, :] + rec)
            return jnp.roll(hist, 1, axis=0).at[0].set(h_t)

        hist0 = jnp.zeros((q, br, m), x.dtype)
        hist = jax.lax.fori_loop(0, q, step, hist0)
        o_ref[...] = hist[0]

    return kernel


def build(cfg: ShapeCfg):
    """(x, w, b, alpha) -> H of shape (rows, M)."""
    assert cfg.arch == "fc"
    return make_h(cfg, _kernel(cfg.q))
