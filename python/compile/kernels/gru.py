"""GRU H kernel (Eq 11), diagonal recurrence. Gate order: [z, r, f]."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.common import ShapeCfg, sigmoid
from compile.kernels.common import make_h


def _kernel(q: int):
    def kernel(x_ref, w3_ref, u3_ref, b3_ref, o_ref):
        x = x_ref[...]  # (br, S, Q)
        w3 = w3_ref[...]  # (S, 3, M)
        u3 = u3_ref[...]  # (3, M)
        b3 = b3_ref[...]  # (3, M)

        br = x.shape[0]
        m = w3.shape[2]
        wx = jnp.einsum("rsq,sgm->qgrm", x, w3)  # (Q, 3, br, M)

        def step(t, f_prev):
            wx_t = wx[t]
            z = sigmoid(wx_t[0] + u3[0][None, :] * f_prev + b3[0][None, :])
            r = sigmoid(wx_t[1] + u3[1][None, :] * f_prev + b3[1][None, :])
            cand = jnp.tanh(
                wx_t[2] + u3[2][None, :] * (r * f_prev) + b3[2][None, :]
            )
            return (1.0 - z) * f_prev + z * cand

        f0 = jnp.zeros((br, m), x.dtype)
        f = jax.lax.fori_loop(0, q, step, f0)
        o_ref[...] = f

    return kernel


def build(cfg: ShapeCfg):
    """(x, w3, u3, b3) -> H of shape (rows, M)."""
    assert cfg.arch == "gru"
    return make_h(cfg, _kernel(cfg.q))
