"""Jordan H kernel (Eq 7).

The Jordan recurrence feeds back *outputs*, which are teacher-forced during
training (DESIGN.md §2), so H(Q) is a direct function of the inputs: no
hidden-state loop. The kernel is a tiled projection + target-history matvec.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.common import ShapeCfg
from compile.kernels.common import make_h


def _kernel():
    def kernel(x_ref, yhist_ref, w_ref, b_ref, alpha_ref, o_ref):
        x_q = x_ref[...][:, :, -1]  # (br, S): input at the final timestep
        yh = yhist_ref[...]  # (br, Q): yh[i, k-1] = y(t-k)
        w = w_ref[...]  # (S, M)
        b = b_ref[...]  # (M,)
        alpha = alpha_ref[...]  # (M, Q)

        wx = jnp.einsum("rs,sm->rm", x_q, w)
        rec = jnp.einsum("mk,rk->rm", alpha, yh)
        o_ref[...] = jnp.tanh(wx + b[None, :] + rec)

    return kernel


def build(cfg: ShapeCfg):
    """(x, yhist, w, b, alpha) -> H of shape (rows, M)."""
    assert cfg.arch == "jordan"
    return make_h(cfg, _kernel())
