"""LSTM H kernel (Eq 10), diagonal recurrence.

Each neuron's gates see only its own previous output f(t-1) — exactly the
per-(i, j) thread independence the paper exploits. Gate order on the stacked
parameter axis: [o, c~, lambda (forget), in]. Carry: (f, c) pairs, the
register-file state of Alg 3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.common import ShapeCfg, sigmoid
from compile.kernels.common import make_h


def _kernel(q: int):
    def kernel(x_ref, w4_ref, u4_ref, b4_ref, o_ref):
        x = x_ref[...]  # (br, S, Q)
        w4 = w4_ref[...]  # (S, 4, M)
        u4 = u4_ref[...]  # (4, M) diagonal recurrent weights
        b4 = b4_ref[...]  # (4, M)

        br = x.shape[0]
        m = w4.shape[2]
        wx = jnp.einsum("rsq,sgm->qgrm", x, w4)  # (Q, 4, br, M)

        def step(t, carry):
            f_prev, c_prev = carry
            pre = wx[t] + u4[:, None, :] * f_prev[None, :, :] + b4[:, None, :]
            o = sigmoid(pre[0])
            c_tilde = jnp.tanh(pre[1])
            lam = sigmoid(pre[2])
            inp = sigmoid(pre[3])
            c = lam * c_prev + inp * c_tilde
            f = o * jnp.tanh(c)
            return (f, c)

        zeros = jnp.zeros((br, m), x.dtype)
        f, _c = jax.lax.fori_loop(0, q, step, (zeros, zeros))
        o_ref[...] = f

    return kernel


def build(cfg: ShapeCfg):
    """(x, w4, u4, b4) -> H of shape (rows, M)."""
    assert cfg.arch == "lstm"
    return make_h(cfg, _kernel(cfg.q))
