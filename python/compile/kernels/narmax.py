"""NARMAX H kernel (Eq 8), F = R = Q.

Output- and error-feedback are exogenous (two-pass extended least squares,
DESIGN.md §2): pass 1 runs with ehist = 0, pass 2 with pass-1 residuals.
H(Q) is a direct tiled projection, like Jordan, with two feedback matvecs.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.common import ShapeCfg
from compile.kernels.common import make_h


def _kernel():
    def kernel(x_ref, yhist_ref, ehist_ref, w_ref, b_ref, wp_ref, wpp_ref, o_ref):
        x_q = x_ref[...][:, :, -1]  # (br, S)
        yh = yhist_ref[...]  # (br, Q)
        eh = ehist_ref[...]  # (br, Q)
        w = w_ref[...]  # (S, M)
        b = b_ref[...]  # (M,)
        wp = wp_ref[...]  # (M, Q)  output-feedback weights W'
        wpp = wpp_ref[...]  # (M, Q)  error-feedback weights W''

        wx = jnp.einsum("rs,sm->rm", x_q, w)
        rec_y = jnp.einsum("mk,rk->rm", wp, yh)
        rec_e = jnp.einsum("mk,rk->rm", wpp, eh)
        o_ref[...] = jnp.tanh(wx + b[None, :] + rec_y + rec_e)

    return kernel


def build(cfg: ShapeCfg):
    """(x, yhist, ehist, w, b, wp, wpp) -> H of shape (rows, M)."""
    assert cfg.arch == "narmax"
    return make_h(cfg, _kernel())
