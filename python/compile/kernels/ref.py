"""Pure-jnp oracles for every architecture's H recurrence (Eq 6-11).

These are the CORE correctness signal: every Pallas kernel (basic and opt
variants, every tile size) is checked against these with assert_allclose in
``python/tests``. They are written with ``lax.scan`` in the most direct
transcription of the paper's equations; no tiling, no pallas.

Shape conventions (see compile.common):
    x      (R, S, Q)   lag-window input block
    w      (S, M)      input weights, fixed random
    b      (M,)        biases
    alpha  (M, Q)      diagonal recurrent weights (elman/jordan)
    alpha  (M, M, Q)   full recurrent weights (fc)
    yhist  (R, Q)      target history, yhist[i, k-1] = y(t-k)   (jordan/narmax)
    ehist  (R, Q)      residual history, same alignment          (narmax)
returns H(Q) of shape (R, M) — the ELM design-matrix block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.common import sigmoid


def _wx(x, w):
    """Per-timestep input projections: (Q, R, M)."""
    return jnp.einsum("rsq,sm->qrm", x, w)


def elman_h(x, w, b, alpha):
    """Eq 6: h_j(t) = g(w_j.x(t) + b_j + sum_k alpha[j,k] h_j(t-k))."""
    q = x.shape[2]
    wx = _wx(x, w)

    def step(hist, wx_t):
        # hist[k-1] == h(t-k), shape (Q, R, M)
        rec = jnp.einsum("mk,krm->rm", alpha, hist)
        h_t = jnp.tanh(wx_t + b[None, :] + rec)
        hist = jnp.roll(hist, 1, axis=0).at[0].set(h_t)
        return hist, None

    hist0 = jnp.zeros((q,) + wx.shape[1:], wx.dtype)
    hist, _ = jax.lax.scan(step, hist0, wx)
    return hist[0]


def jordan_h(x, w, b, alpha, yhist):
    """Eq 7 at t=Q: the recurrence is through the (teacher-forced) targets
    only, so H(Q) is a direct function of the inputs (DESIGN.md §2)."""
    wx_q = jnp.einsum("rs,sm->rm", x[:, :, -1], w)
    rec = jnp.einsum("mk,rk->rm", alpha, yhist)
    return jnp.tanh(wx_q + b[None, :] + rec)


def narmax_h(x, w, b, wp, wpp, yhist, ehist):
    """Eq 8 at t=Q: exogenous output- and error-feedback (F = R = Q)."""
    wx_q = jnp.einsum("rs,sm->rm", x[:, :, -1], w)
    rec_y = jnp.einsum("mk,rk->rm", wp, yhist)
    rec_e = jnp.einsum("mk,rk->rm", wpp, ehist)
    return jnp.tanh(wx_q + b[None, :] + rec_y + rec_e)


def fc_h(x, w, b, alpha):
    """Eq 9 with the true cross-neuron coupling: alpha[j,l,k] h_l(t-k)."""
    q = x.shape[2]
    wx = _wx(x, w)

    def step(hist, wx_t):
        # hist (Q, R, M); contribution sum_{k,l} alpha[j,l,k] h_l(t-k)
        rec = jnp.einsum("mlk,krl->rm", alpha, hist)
        h_t = jnp.tanh(wx_t + b[None, :] + rec)
        hist = jnp.roll(hist, 1, axis=0).at[0].set(h_t)
        return hist, None

    hist0 = jnp.zeros((q,) + wx.shape[1:], wx.dtype)
    hist, _ = jax.lax.scan(step, hist0, wx)
    return hist[0]


def lstm_h(x, w4, u4, b4):
    """Eq 10, diagonal recurrence (one thread per (i, j) in the paper).

    Gate order on the stacked axis: [o, c~, lambda (forget), in].
    """
    wx = jnp.einsum("rsq,sgm->qgrm", x, w4)  # (Q, 4, R, M)

    def step(carry, wx_t):
        f_prev, c_prev = carry
        pre = wx_t + u4[:, None, :] * f_prev[None, :, :] + b4[:, None, :]
        o = sigmoid(pre[0])
        c_tilde = jnp.tanh(pre[1])
        lam = sigmoid(pre[2])
        inp = sigmoid(pre[3])
        c = lam * c_prev + inp * c_tilde
        f = o * jnp.tanh(c)
        return (f, c), None

    r, m = x.shape[0], w4.shape[2]
    zeros = jnp.zeros((r, m), x.dtype)
    (f, _c), _ = jax.lax.scan(step, (zeros, zeros), wx)
    return f


def gru_h(x, w3, u3, b3):
    """Eq 11, diagonal recurrence. Gate order: [z, r, f]."""
    wx = jnp.einsum("rsq,sgm->qgrm", x, w3)  # (Q, 3, R, M)

    def step(f_prev, wx_t):
        z = sigmoid(wx_t[0] + u3[0][None, :] * f_prev + b3[0][None, :])
        r = sigmoid(wx_t[1] + u3[1][None, :] * f_prev + b3[1][None, :])
        cand = jnp.tanh(wx_t[2] + u3[2][None, :] * (r * f_prev) + b3[2][None, :])
        f = (1.0 - z) * f_prev + z * cand
        return f, None

    rr, m = x.shape[0], w3.shape[2]
    f0 = jnp.zeros((rr, m), x.dtype)
    f, _ = jax.lax.scan(step, f0, wx)
    return f


def h_ref(arch, x, extras, params):
    """Uniform entry point: extras/params in compile.common order."""
    if arch == "elman":
        return elman_h(x, *params)
    if arch == "jordan":
        (w, b, alpha) = params
        (yhist,) = extras
        return jordan_h(x, w, b, alpha, yhist)
    if arch == "narmax":
        (w, b, wp, wpp) = params
        (yhist, ehist) = extras
        return narmax_h(x, w, b, wp, wpp, yhist, ehist)
    if arch == "fc":
        return fc_h(x, *params)
    if arch == "lstm":
        return lstm_h(x, *params)
    if arch == "gru":
        return gru_h(x, *params)
    raise ValueError(arch)
