"""The artifact grid: every HLO executable the experiments need.

This file is the single source of truth shared between the compile path
(aot.py lowers exactly these) and the rust runtime (artifacts/manifest.json
records the ABI — input/output names, shapes, dtypes — for each artifact).

The grid is derived from DESIGN.md §4 (experiment index):

* ``elm_gram``  — the workhorse: streaming H + Gram block step.
    Q=10  × M ∈ {5, 10, 20, 50, 100}  (Figs 3-4, Tables 4-6: Q=10 datasets)
    Q=50  × M ∈ {20, 50}              (hourly-weather/stock/temperature sets)
    Q=64  × M ∈ {100}                 (exoplanet, Q capped — DESIGN.md §3)
* ``elm_h``     — raw H block for the TSQR path and integration tests.
* ``elm_predict`` — inference for the RMSE evaluations (Table 4).
* ``bptt_step`` / ``bptt_predict`` — the P-BPTT comparator (Table 6, Fig 5).

Row-block size R = 256, S = 1 (univariate series), opt variant, BS = 32.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from compile import bptt as bptt_mod
from compile import model
from compile.common import ARCHS, ShapeCfg

ROWS = 256
S = 1
BLOCK_ROWS = 32
BPTT_BATCH = 64
BPTT_M = 10

#: (Q, M) grid for the gram graphs.
GRAM_QM: List[Tuple[int, int]] = [
    (10, 5),
    (10, 10),
    (10, 20),
    (10, 50),
    (10, 100),
    (50, 10),  # Table 6: M=10 on the Q=50 datasets
    (50, 20),
    (50, 50),
    (64, 100),
]

#: (Q, M) grid for the predict graphs: the full gram grid — the parallel
#: NARMAX trainer needs a predict executable wherever a gram one exists
#: (two-pass ELS), and Table 4 evaluates RMSE at its (Q, M) selections.
PREDICT_QM: List[Tuple[int, int]] = list(GRAM_QM)

#: (Q, M) grid for the raw-H graphs (TSQR path).
H_QM: List[Tuple[int, int]] = [(10, 50)]

BPTT_Q: List[int] = [10, 50]


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """One lowered executable: its name, builder inputs, and ABI."""

    name: str
    kind: str  # elm_h | elm_gram | elm_predict | bptt_step | bptt_predict
    arch: str
    q: int
    m: int
    rows: int  # row block (elm_*) or batch (bptt_*)
    s: int = S
    variant: str = "opt"
    block_rows: int = BLOCK_ROWS

    def cfg(self) -> ShapeCfg:
        return ShapeCfg(
            arch=self.arch,
            rows=self.rows,
            s=self.s,
            q=self.q,
            m=self.m,
            variant=self.variant,
            block_rows=self.block_rows,
        )

    def build(self):
        """Returns (fn, input_specs, output_names)."""
        if self.kind == "elm_h":
            return model.elm_h(self.cfg())
        if self.kind == "elm_gram":
            return model.elm_gram(self.cfg())
        if self.kind == "elm_predict":
            return model.elm_predict(self.cfg())
        if self.kind == "bptt_step":
            return bptt_mod.bptt_step(self.arch, self.rows, self.s, self.q, self.m)
        if self.kind == "bptt_predict":
            return bptt_mod.bptt_predict(
                self.arch, self.rows, self.s, self.q, self.m
            )
        raise ValueError(self.kind)


def _name(kind: str, arch: str, q: int, m: int, rows: int) -> str:
    return f"{kind}_{arch}_r{rows}_s{S}_q{q}_m{m}"


def specs() -> List[ArtifactSpec]:
    out: List[ArtifactSpec] = []
    for arch in ARCHS:
        for q, m in GRAM_QM:
            out.append(
                ArtifactSpec(_name("elm_gram", arch, q, m, ROWS), "elm_gram", arch, q, m, ROWS)
            )
        for q, m in PREDICT_QM:
            out.append(
                ArtifactSpec(
                    _name("elm_predict", arch, q, m, ROWS), "elm_predict", arch, q, m, ROWS
                )
            )
        for q, m in H_QM:
            out.append(
                ArtifactSpec(_name("elm_h", arch, q, m, ROWS), "elm_h", arch, q, m, ROWS)
            )
    for arch in bptt_mod.BPTT_ARCHS:
        for q in BPTT_Q:
            out.append(
                ArtifactSpec(
                    _name("bptt_step", arch, q, BPTT_M, BPTT_BATCH),
                    "bptt_step",
                    arch,
                    q,
                    BPTT_M,
                    BPTT_BATCH,
                )
            )
            out.append(
                ArtifactSpec(
                    _name("bptt_predict", arch, q, BPTT_M, BPTT_BATCH),
                    "bptt_predict",
                    arch,
                    q,
                    BPTT_M,
                    BPTT_BATCH,
                )
            )
    names = [s.name for s in out]
    assert len(names) == len(set(names)), "artifact names must be unique"
    return out


def manifest_entry(spec: ArtifactSpec) -> Dict:
    _fn, inputs, outputs = spec.build()
    return {
        "name": spec.name,
        "file": f"{spec.name}.hlo.txt",
        "kind": spec.kind,
        "arch": spec.arch,
        "variant": spec.variant,
        "rows": spec.rows,
        "block_rows": spec.block_rows,
        "s": spec.s,
        "q": spec.q,
        "m": spec.m,
        "inputs": [
            {"name": n, "shape": list(shape), "dtype": "f32"} for n, shape in inputs
        ],
        "outputs": outputs,
    }
