"""L2: the jax compute graphs lowered to HLO artifacts.

Each builder returns ``(fn, input_specs, output_names)`` where ``fn`` takes
positional jnp arrays in the recorded order — the rust runtime marshals
literals by ``artifacts/manifest.json``, so the order here is the ABI.

Graphs:

* ``elm_h``      — H row-block via the L1 Pallas kernel (TSQR path).
* ``elm_gram``   — fused block step: H, then masked partial sums HᵀH, HᵀY
                   (streaming normal-equations path; one executable per
                   block, no recompute of H — see DESIGN.md §7).
* ``elm_predict``— H @ beta for a block (inference path).
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax.numpy as jnp

from compile import kernels
from compile.common import (
    DTYPE,
    ShapeCfg,
    extra_input_specs,
    param_specs,
)

InputSpec = Tuple[str, Tuple[int, ...]]


def _base_inputs(cfg: ShapeCfg) -> List[InputSpec]:
    inputs: List[InputSpec] = [("x", (cfg.rows, cfg.s, cfg.q))]
    inputs.extend(extra_input_specs(cfg))
    inputs.extend(param_specs(cfg))
    return inputs


def elm_h(cfg: ShapeCfg) -> Tuple[Callable, List[InputSpec], List[str]]:
    """H block: (x, *extras, *params) -> (h,)."""
    h_fn = kernels.h_pallas(cfg)

    def fn(*args):
        return (h_fn(*args),)

    return fn, _base_inputs(cfg), ["h"]


def elm_gram(cfg: ShapeCfg) -> Tuple[Callable, List[InputSpec], List[str]]:
    """Fused block step: (x, *extras, *params, y, mask) -> (hth, hty).

    ``mask`` zeroes padded tail rows out of both partial sums, so the
    coordinator can stream any dataset length through a fixed block shape.
    """
    h_fn = kernels.h_pallas(cfg)
    inputs = _base_inputs(cfg) + [("y", (cfg.rows,)), ("mask", (cfg.rows,))]

    def fn(*args):
        *head, y, mask = args
        h = h_fn(*head)
        hm = h * mask[:, None]
        hth = hm.T @ hm
        hty = hm.T @ (y * mask)
        return (hth, hty)

    return fn, inputs, ["hth", "hty"]


def elm_predict(cfg: ShapeCfg) -> Tuple[Callable, List[InputSpec], List[str]]:
    """Inference block: (x, *extras, *params, beta) -> (yhat,)."""
    h_fn = kernels.h_pallas(cfg)
    inputs = _base_inputs(cfg) + [("beta", (cfg.m,))]

    def fn(*args):
        *head, beta = args
        h = h_fn(*head)
        return (h @ beta,)

    return fn, inputs, ["yhat"]


def zeros_like_specs(specs: List[InputSpec]):
    """Example arrays for lowering (shapes only; values irrelevant)."""
    import jax

    return [jax.ShapeDtypeStruct(shape, DTYPE) for _n, shape in specs]
