"""Shared fixtures: deterministic random inputs for any ShapeCfg."""

from __future__ import annotations

import numpy as np
import pytest

from compile.common import ShapeCfg, extra_input_specs, param_specs


def make_inputs(cfg: ShapeCfg, seed: int = 0):
    """(x, extras, params) matching the canonical ABI order, float32.

    Params are drawn uniform [-0.5, 0.5] (the ELM random-weight regime);
    extras (target/error histories) are scaled down to keep tanh unsaturated
    so allclose comparisons stay meaningful.
    """
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((cfg.rows, cfg.s, cfg.q), dtype=np.float32)
    extras = [
        (rng.standard_normal(shape, dtype=np.float32) * 0.1)
        for _n, shape in extra_input_specs(cfg)
    ]
    params = [
        rng.uniform(-0.5, 0.5, shape).astype(np.float32)
        for _n, shape in param_specs(cfg)
    ]
    return x, extras, params


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
