"""AOT path: HLO text generation round-trips through the XLA parser."""

from __future__ import annotations

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, manifest, model
from compile.common import ShapeCfg


def _lower_small():
    cfg = ShapeCfg(arch="elman", rows=32, s=1, q=4, m=4, variant="opt", block_rows=16)
    fn, inputs, _o = model.elm_gram(cfg)
    args = [jax.ShapeDtypeStruct(shape, jax.numpy.float32) for _n, shape in inputs]
    return jax.jit(fn).lower(*args), inputs


def test_hlo_text_structure():
    lowered, _inputs = _lower_small()
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple ABI: root is a tuple (rust unwraps with to_tuple)
    assert "tuple(" in text or "(f32[" in text


def test_hlo_text_reparses():
    """The text must round-trip through XLA's own parser — the exact
    mechanism the rust runtime uses (HloModuleProto::from_text_file)."""
    from jax._src.lib import xla_client as xc

    lowered, _inputs = _lower_small()
    text = aot.to_hlo_text(lowered)
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_param_count_matches_abi():
    lowered, inputs = _lower_small()
    text = aot.to_hlo_text(lowered)
    # every declared input appears as a parameter in the entry computation
    assert text.count("parameter(") >= len(inputs)


def test_written_artifact_and_manifest(tmp_path):
    import subprocess
    import sys

    name = "elm_h_gru_r256_s1_q10_m50"
    env = os.environ.copy()
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(tmp_path),
            "--only",
            name,
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert res.returncode == 0, res.stderr
    hlo = (tmp_path / f"{name}.hlo.txt").read_text()
    assert hlo.startswith("HloModule")
    man = json.loads((tmp_path / "manifest.json").read_text())
    entries = {e["name"]: e for e in man["artifacts"]}
    assert name in entries
    assert entries[name]["outputs"] == ["h"]
