"""P-BPTT comparator step: gradient flow, Adam semantics, learnability."""

from __future__ import annotations

import numpy as np
import pytest

from compile import bptt


def _init(arch, s, m, seed=0):
    rng = np.random.default_rng(seed)
    params = [
        (rng.standard_normal(shape) * 0.2).astype(np.float32)
        for _n, shape in bptt.param_shapes(arch, s, m)
    ]
    zeros = [np.zeros_like(p) for p in params]
    return params, [z.copy() for z in zeros], [z.copy() for z in zeros]


def _data(batch, s, q, seed=0):
    """Synthetic AR(1)-flavoured task: y = mean of last two inputs."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, s, q)).astype(np.float32)
    y = 0.5 * (x[:, 0, -1] + x[:, 0, -2]).astype(np.float32)
    return x, y


@pytest.mark.parametrize("arch", bptt.BPTT_ARCHS)
def test_loss_decreases(arch):
    batch, s, q, m = 64, 1, 6, 10
    fn, inputs, outputs = bptt.bptt_step(arch, batch, s, q, m)
    params, ms, vs = _init(arch, s, m, seed=1)
    x, y = _data(batch, s, q, seed=2)

    losses = []
    for t in range(1, 41):
        out = fn(np.array([float(t)], np.float32), x, y, *params, *ms, *vs)
        losses.append(float(out[0][0]))
        n = len(params)
        params = [np.asarray(a) for a in out[1 : 1 + n]]
        ms = [np.asarray(a) for a in out[1 + n : 1 + 2 * n]]
        vs = [np.asarray(a) for a in out[1 + 2 * n : 1 + 3 * n]]
    assert losses[-1] < 0.5 * losses[0], (arch, losses[0], losses[-1])


@pytest.mark.parametrize("arch", bptt.BPTT_ARCHS)
def test_step_abi(arch):
    """Output count/order matches the manifest ABI: loss, params, m, v."""
    batch, s, q, m = 8, 1, 3, 4
    fn, inputs, outputs = bptt.bptt_step(arch, batch, s, q, m)
    n = len(bptt.param_shapes(arch, s, m))
    assert len(outputs) == 1 + 3 * n
    assert outputs[0] == "loss"
    arrays = [np.zeros(shape, np.float32) for _n, shape in inputs]
    arrays[0] = np.array([1.0], np.float32)
    out = fn(*arrays)
    assert len(out) == len(outputs)
    for got, (name, shape) in zip(out[1:], inputs[3:]):
        assert np.asarray(got).shape == tuple(shape), name


def test_adam_first_step_magnitude():
    """At t=1 with bias correction, |update| ~ lr for any nonzero grad."""
    arch, batch, s, q, m = "fc", 16, 1, 3, 4
    fn, _i, _o = bptt.bptt_step(arch, batch, s, q, m)
    params, ms, vs = _init(arch, s, m, seed=3)
    x, y = _data(batch, s, q, seed=4)
    out = fn(np.array([1.0], np.float32), x, y, *params, *ms, *vs)
    new_params = [np.asarray(a) for a in out[1 : 1 + len(params)]]
    deltas = np.concatenate(
        [np.abs(n - p).ravel() for n, p in zip(new_params, params)]
    )
    # updates are lr * m_hat / (sqrt(v_hat) + eps) ~= lr * sign(g)
    assert np.all(deltas <= bptt.ADAM_LR * 1.01)
    assert np.median(deltas[deltas > 0]) > 0.1 * bptt.ADAM_LR


@pytest.mark.parametrize("arch", bptt.BPTT_ARCHS)
def test_predict_matches_forward(arch):
    batch, s, q, m = 8, 1, 4, 5
    fn, inputs, _o = bptt.bptt_predict(arch, batch, s, q, m)
    params, _m, _v = _init(arch, s, m, seed=5)
    x, _y = _data(batch, s, q, seed=6)
    yhat = np.asarray(fn(x, *params)[0])
    assert yhat.shape == (batch,)
    assert np.all(np.isfinite(yhat))
    # deterministic: same inputs, same outputs
    yhat2 = np.asarray(fn(x, *params)[0])
    np.testing.assert_array_equal(yhat, yhat2)


def test_unknown_arch_rejected():
    with pytest.raises(ValueError):
        bptt.bptt_step("elman", 8, 1, 3, 4)
