"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Every architecture, both variants (basic = Alg 2 untiled, opt = Alg 3
tiled), multiple tile widths, non-trivial S/Q/M. Numerics must agree to
float32 tolerance because under interpret=True the two paths compute the
same graph with different blocking.
"""

from __future__ import annotations

import numpy as np
import pytest

from compile.common import ARCHS, ShapeCfg
from compile.kernels import h_pallas, ref
from tests.conftest import make_inputs

TOL = dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("variant", ["basic", "opt"])
def test_kernel_matches_ref(arch, variant):
    cfg = ShapeCfg(arch=arch, rows=64, s=3, q=7, m=6, variant=variant, block_rows=32)
    x, extras, params = make_inputs(cfg, seed=7)
    got = np.asarray(h_pallas(cfg)(x, *extras, *params))
    want = np.asarray(ref.h_ref(arch, x, extras, params))
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("block_rows", [16, 32])
def test_tile_width_invariance(arch, block_rows):
    """BS = 16 and BS = 32 (the paper's two configurations) must agree."""
    cfg = ShapeCfg(arch=arch, rows=64, s=2, q=5, m=4, variant="opt", block_rows=block_rows)
    x, extras, params = make_inputs(cfg, seed=11)
    got = np.asarray(h_pallas(cfg)(x, *extras, *params))
    want = np.asarray(ref.h_ref(arch, x, extras, params))
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("arch", ARCHS)
def test_basic_equals_opt(arch):
    """Tiling must not change numerics (paper §7.3 robustness claim)."""
    kw = dict(arch=arch, rows=96, s=2, q=6, m=5)
    x, extras, params = make_inputs(ShapeCfg(variant="basic", **kw), seed=3)
    basic = np.asarray(h_pallas(ShapeCfg(variant="basic", **kw))(x, *extras, *params))
    opt = np.asarray(
        h_pallas(ShapeCfg(variant="opt", block_rows=32, **kw))(x, *extras, *params)
    )
    np.testing.assert_allclose(basic, opt, **TOL)


@pytest.mark.parametrize("arch", ARCHS)
def test_output_shape_and_dtype(arch):
    cfg = ShapeCfg(arch=arch, rows=32, s=1, q=10, m=13, variant="opt", block_rows=16)
    x, extras, params = make_inputs(cfg)
    h = h_pallas(cfg)(x, *extras, *params)
    assert h.shape == (cfg.rows, cfg.m)
    assert str(h.dtype) == "float32"


@pytest.mark.parametrize("arch", ARCHS)
def test_row_independence(arch):
    """Permuting sample rows permutes H rows: thread (i, j) independence —
    the property Basic-PR-ELM's parallelization rests on (§4.1.1)."""
    cfg = ShapeCfg(arch=arch, rows=64, s=2, q=5, m=4, variant="opt", block_rows=32)
    x, extras, params = make_inputs(cfg, seed=5)
    perm = np.random.default_rng(0).permutation(cfg.rows)
    h = np.asarray(h_pallas(cfg)(x, *extras, *params))
    hp = np.asarray(
        h_pallas(cfg)(x[perm], *[e[perm] for e in extras], *params)
    )
    np.testing.assert_allclose(hp, h[perm], **TOL)


def test_bad_cfg_rejected():
    with pytest.raises(ValueError):
        ShapeCfg(arch="elman", rows=30, s=1, q=5, m=4, variant="opt", block_rows=32)
    with pytest.raises(ValueError):
        ShapeCfg(arch="nope", rows=32, s=1, q=5, m=4)
    with pytest.raises(ValueError):
        ShapeCfg(arch="elman", rows=32, s=0, q=5, m=4)
    with pytest.raises(ValueError):
        ShapeCfg(arch="elman", rows=32, s=1, q=5, m=4, variant="fast")
