"""Hypothesis sweeps: kernel vs ref over randomized shapes and inputs.

Complements test_kernel.py's fixed shapes with property-based coverage of
the (rows, S, Q, M, block_rows, variant) space — the L1 deliverable's
"hypothesis sweeps the Pallas kernel's shapes/dtypes" requirement.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.common import ARCHS, ShapeCfg, extra_input_specs, param_specs
from compile.kernels import h_pallas, ref

TOL = dict(rtol=3e-5, atol=3e-5)


def _inputs_from(data, cfg):
    """Draw float32 inputs via hypothesis' random module."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    x = rng.standard_normal((cfg.rows, cfg.s, cfg.q), dtype=np.float32)
    extras = [
        (rng.standard_normal(shape, dtype=np.float32) * 0.2)
        for _n, shape in extra_input_specs(cfg)
    ]
    params = [
        rng.uniform(-0.6, 0.6, shape).astype(np.float32)
        for _n, shape in param_specs(cfg)
    ]
    return x, extras, params


@st.composite
def shape_cfgs(draw):
    arch = draw(st.sampled_from(ARCHS))
    block_rows = draw(st.sampled_from([8, 16, 32]))
    rows = block_rows * draw(st.integers(1, 3))
    s = draw(st.integers(1, 4))
    q = draw(st.integers(1, 12))
    m = draw(st.integers(1, 24))
    variant = draw(st.sampled_from(["basic", "opt"]))
    return ShapeCfg(
        arch=arch, rows=rows, s=s, q=q, m=m, variant=variant, block_rows=block_rows
    )


@settings(max_examples=40, deadline=None)
@given(cfg=shape_cfgs(), data=st.data())
def test_kernel_matches_ref_over_shape_space(cfg, data):
    x, extras, params = _inputs_from(data, cfg)
    got = np.asarray(h_pallas(cfg)(x, *extras, *params))
    want = np.asarray(ref.h_ref(cfg.arch, x, extras, params))
    assert got.shape == (cfg.rows, cfg.m)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(cfg=shape_cfgs(), data=st.data())
def test_kernel_is_deterministic(cfg, data):
    x, extras, params = _inputs_from(data, cfg)
    fn = h_pallas(cfg)
    a = np.asarray(fn(x, *extras, *params))
    b = np.asarray(fn(x, *extras, *params))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=20, deadline=None)
@given(
    arch=st.sampled_from(ARCHS),
    q=st.integers(1, 10),
    m=st.integers(1, 16),
    data=st.data(),
)
def test_outputs_bounded(arch, q, m, data):
    """|H| <= 1 for every architecture (tanh / gated-tanh output)."""
    cfg = ShapeCfg(arch=arch, rows=16, s=2, q=q, m=m, variant="basic")
    x, extras, params = _inputs_from(data, cfg)
    h = np.asarray(h_pallas(cfg)(x, *extras, *params))
    assert np.all(np.isfinite(h))
    assert np.all(np.abs(h) <= 1.0 + 1e-5)


@settings(max_examples=15, deadline=None)
@given(cfg=shape_cfgs(), data=st.data())
def test_row_independence_property(cfg, data):
    """Thread-(i, j) independence (§4.1.1): permuting rows permutes H."""
    x, extras, params = _inputs_from(data, cfg)
    perm = np.random.default_rng(0).permutation(cfg.rows)
    fn = h_pallas(cfg)
    h = np.asarray(fn(x, *extras, *params))
    hp = np.asarray(fn(x[perm], *[e[perm] for e in extras], *params))
    np.testing.assert_allclose(hp, h[perm], **TOL)
