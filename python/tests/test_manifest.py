"""Manifest grid sanity: unique names, well-formed ABI entries, coverage."""

from __future__ import annotations

import numpy as np
import pytest

from compile import manifest
from compile.common import ARCHS


def test_names_unique():
    specs = manifest.specs()
    names = [s.name for s in specs]
    assert len(names) == len(set(names))


def test_grid_covers_experiments():
    """Every config the experiment index (DESIGN.md §4) needs must exist."""
    specs = manifest.specs()
    key = {(s.kind, s.arch, s.q, s.m) for s in specs}
    for arch in ARCHS:
        # Fig 4: M sweep at Q=10
        for m in (5, 10, 20, 50, 100):
            assert ("elm_gram", arch, 10, m) in key
        # Fig 3 / Table 5: M=50 at both Q regimes
        assert ("elm_gram", arch, 50, 50) in key
        # Table 4 eval configs
        assert ("elm_predict", arch, 10, 10) in key
        assert ("elm_predict", arch, 50, 20) in key
        assert ("elm_predict", arch, 64, 100) in key
    for arch in ("fc", "lstm", "gru"):
        for q in (10, 50):
            assert ("bptt_step", arch, q, 10) in key
            assert ("bptt_predict", arch, q, 10) in key


def test_entries_well_formed():
    for spec in manifest.specs()[:12]:
        e = manifest.manifest_entry(spec)
        assert e["file"] == e["name"] + ".hlo.txt"
        assert e["outputs"], e["name"]
        assert all(i["dtype"] == "f32" for i in e["inputs"])
        assert all(all(d > 0 for d in i["shape"]) for i in e["inputs"])
        # input names unique within an entry (positional ABI sanity)
        names = [i["name"] for i in e["inputs"]]
        assert len(names) == len(set(names))


@pytest.mark.parametrize("kind", ["elm_gram", "elm_predict", "elm_h"])
def test_builders_run(kind):
    """Every ELM builder in the grid must trace with its declared shapes."""
    spec = next(s for s in manifest.specs() if s.kind == kind and s.arch == "gru")
    fn, inputs, outputs = spec.build()
    args = [np.zeros(shape, np.float32) for _n, shape in inputs]
    out = fn(*args)
    assert len(out) == len(outputs)


def test_rows_divisible_by_block():
    for s in manifest.specs():
        if s.kind.startswith("elm_"):
            assert s.rows % s.block_rows == 0
