"""L2 graph semantics: gram fusion, masking, predict."""

from __future__ import annotations

import numpy as np
import pytest

from compile import model
from compile.common import ARCHS, ShapeCfg
from compile.kernels import ref
from tests.conftest import make_inputs

TOL = dict(rtol=5e-4, atol=5e-5)


def _cfg(arch, **kw):
    d = dict(rows=64, s=2, q=5, m=4, variant="opt", block_rows=32)
    d.update(kw)
    return ShapeCfg(arch=arch, **d)


@pytest.mark.parametrize("arch", ARCHS)
def test_gram_equals_explicit(arch):
    """elm_gram's fused HtH/HtY must equal the oracle H's products."""
    cfg = _cfg(arch)
    x, extras, params = make_inputs(cfg, seed=21)
    rng = np.random.default_rng(22)
    y = rng.standard_normal(cfg.rows).astype(np.float32)
    mask = np.ones(cfg.rows, np.float32)

    fn, inputs, outputs = model.elm_gram(cfg)
    assert outputs == ["hth", "hty"]
    hth, hty = fn(x, *extras, *params, y, mask)

    h = np.asarray(ref.h_ref(arch, x, extras, params))
    np.testing.assert_allclose(np.asarray(hth), h.T @ h, **TOL)
    np.testing.assert_allclose(np.asarray(hty), h.T @ y, **TOL)


@pytest.mark.parametrize("arch", ["elman", "lstm"])
def test_gram_mask_excludes_padded_rows(arch):
    """Masked rows must contribute nothing: streaming a padded tail block
    must equal the unpadded computation (coordinator invariant)."""
    cfg = _cfg(arch)
    x, extras, params = make_inputs(cfg, seed=30)
    rng = np.random.default_rng(31)
    y = rng.standard_normal(cfg.rows).astype(np.float32)
    keep = 40
    mask = np.zeros(cfg.rows, np.float32)
    mask[:keep] = 1.0
    # poison the padded region: must not leak into the sums
    x = x.copy()
    x[keep:] = 1e6
    y = y.copy()
    y[keep:] = 1e6

    fn, _i, _o = model.elm_gram(cfg)
    hth, hty = fn(x, *extras, *params, y, mask)

    h = np.asarray(ref.h_ref(arch, x, extras, params))[:keep]
    np.testing.assert_allclose(np.asarray(hth), h.T @ h, **TOL)
    np.testing.assert_allclose(np.asarray(hty), h.T @ y[:keep], **TOL)


@pytest.mark.parametrize("arch", ARCHS)
def test_predict_is_h_dot_beta(arch):
    cfg = _cfg(arch)
    x, extras, params = make_inputs(cfg, seed=40)
    beta = np.random.default_rng(41).standard_normal(cfg.m).astype(np.float32)
    fn, _i, _o = model.elm_predict(cfg)
    yhat = np.asarray(fn(x, *extras, *params, beta)[0])
    h = np.asarray(ref.h_ref(arch, x, extras, params))
    np.testing.assert_allclose(yhat, h @ beta, **TOL)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_match_abi(arch):
    """The recorded input specs must exactly describe what fn accepts."""
    cfg = _cfg(arch)
    fn, inputs, _o = model.elm_gram(cfg)
    arrays = [np.zeros(shape, np.float32) for _n, shape in inputs]
    hth, hty = fn(*arrays)
    assert np.asarray(hth).shape == (cfg.m, cfg.m)
    assert np.asarray(hty).shape == (cfg.m,)
    names = [n for n, _s in inputs]
    assert names[0] == "x" and names[-2:] == ["y", "mask"]
    assert len(set(names)) == len(names)


def test_gram_solve_recovers_linear_model():
    """End-to-end ELM property: with enough random neurons, solving
    (HtH + lam I) beta = HtY fits a smooth target to low error."""
    cfg = _cfg("elman", rows=256, m=50, q=5, s=1)
    x, extras, params = make_inputs(cfg, seed=50)
    h = np.asarray(ref.h_ref("elman", x, extras, params)).astype(np.float64)
    # target: a smooth function of the inputs
    y = np.tanh(x[:, 0, -1] * 0.7 + 0.3 * x[:, 0, 0])
    g = h.T @ h + 1e-8 * np.eye(cfg.m)
    beta = np.linalg.solve(g, h.T @ y)
    resid = h @ beta - y
    assert np.sqrt(np.mean(resid**2)) < 0.05
