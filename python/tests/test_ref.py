"""Semantic properties of the oracles themselves (Eq 6-11 fidelity).

The kernels are tested *against* ref.py; these tests pin ref.py to the
paper's equations so the whole chain is anchored.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from compile.common import ShapeCfg, sigmoid
from compile.kernels import ref
from tests.conftest import make_inputs


def _mk(arch, seed=0, **kw):
    cfg = ShapeCfg(arch=arch, variant="basic", **kw)
    return (cfg,) + make_inputs(cfg, seed)


def test_elman_zero_alpha_is_feedforward():
    """With alpha = 0, Eq 6 collapses to g(w.x(Q) + b): a plain SLFN on the
    last timestep."""
    cfg, x, _e, (w, b, alpha) = _mk("elman", rows=16, s=3, q=5, m=4)
    h = ref.elman_h(x, w, b, np.zeros_like(alpha))
    want = np.tanh(x[:, :, -1] @ w + b[None, :])
    np.testing.assert_allclose(np.asarray(h), want, rtol=1e-5, atol=1e-6)


def test_elman_one_step_recurrence():
    """Q = 2: h(2) = g(w.x(2) + b + alpha[:,0] * h(1)) exactly."""
    cfg, x, _e, (w, b, alpha) = _mk("elman", rows=8, s=2, q=2, m=3, seed=9)
    h1 = np.tanh(x[:, :, 0] @ w + b[None, :])
    want = np.tanh(x[:, :, 1] @ w + b[None, :] + alpha[:, 0][None, :] * h1)
    got = np.asarray(ref.elman_h(x, w, b, alpha))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_jordan_is_affine_in_yhist():
    """Eq 7 pre-activation is linear in the teacher-forced targets."""
    cfg, x, (yh,), (w, b, alpha) = _mk("jordan", rows=8, s=2, q=4, m=3)
    h0 = np.arctanh(np.asarray(ref.jordan_h(x, w, b, alpha, np.zeros_like(yh))))
    h1 = np.arctanh(np.asarray(ref.jordan_h(x, w, b, alpha, yh)))
    h2 = np.arctanh(np.asarray(ref.jordan_h(x, w, b, alpha, 2.0 * yh)))
    np.testing.assert_allclose(h2 - h0, 2.0 * (h1 - h0), rtol=1e-3, atol=1e-4)


def test_narmax_zero_error_matches_jordan_form():
    """With W'' = 0 / ehist = 0, NARMAX (Eq 8) equals Jordan with wp as
    alpha (both feed back outputs only)."""
    cfg, x, (yh, eh), (w, b, wp, wpp) = _mk("narmax", rows=8, s=2, q=4, m=3)
    nm = np.asarray(ref.narmax_h(x, w, b, wp, wpp, yh, np.zeros_like(eh)))
    jd = np.asarray(ref.jordan_h(x, w, b, wp, yh))
    np.testing.assert_allclose(nm, jd, rtol=1e-5, atol=1e-6)


def test_fc_diagonal_alpha_equals_elman():
    """Eq 9 with alpha[j, l, k] = delta_jl * a[j, k] reduces to Eq 6."""
    cfg, x, _e, (w, b, alpha2) = _mk("elman", rows=8, s=2, q=4, m=3, seed=4)
    m, q = alpha2.shape
    alpha3 = np.zeros((m, m, q), np.float32)
    for j in range(m):
        alpha3[j, j, :] = alpha2[j, :]
    fc = np.asarray(ref.fc_h(x, w, b, alpha3))
    el = np.asarray(ref.elman_h(x, w, b, alpha2))
    np.testing.assert_allclose(fc, el, rtol=1e-5, atol=1e-6)


def test_lstm_forget_gate_zero_kills_memory():
    """Large negative forget-gate bias => c(t) ~ in*c~ only: output at Q
    depends only on x(Q), not on earlier timesteps."""
    cfg, x, _e, (w4, u4, b4) = _mk("lstm", rows=8, s=2, q=5, m=3, seed=2)
    u4 = u4.copy()
    b4 = b4.copy()
    u4[2, :] = 0.0  # forget gate: no recurrent term
    b4[2, :] = -30.0  # sigmoid -> 0
    u4[0, :] = 0.0  # output gate: no recurrent term
    u4[1, :] = 0.0  # candidate: no recurrent term
    u4[3, :] = 0.0  # input gate: no recurrent term
    h = np.asarray(ref.lstm_h(x, w4, u4, b4))
    x2 = x.copy()
    x2[:, :, :-1] = 7.7  # scramble every timestep except the last
    h2 = np.asarray(ref.lstm_h(x2, w4, u4, b4))
    np.testing.assert_allclose(h, h2, rtol=1e-4, atol=1e-5)


def test_gru_z_zero_freezes_state():
    """z(t) = 0 (large negative bias) => f(t) = f(t-1) = ... = 0."""
    cfg, x, _e, (w3, u3, b3) = _mk("gru", rows=8, s=2, q=5, m=3, seed=2)
    b3 = b3.copy()
    b3[0, :] = -30.0  # update gate z -> 0
    h = np.asarray(ref.gru_h(x, w3, u3, b3))
    np.testing.assert_allclose(h, np.zeros_like(h), atol=1e-5)


def test_gru_z_one_is_memoryless_candidate():
    """z(t) = 1 => f(t) = tanh(W_f x(t) + ...) with f(t-1)=... prev-state
    terms only through r*f_prev; with u3[f]=0 it's purely feedforward."""
    cfg, x, _e, (w3, u3, b3) = _mk("gru", rows=8, s=2, q=4, m=3, seed=8)
    b3 = b3.copy()
    u3 = u3.copy()
    b3[0, :] = 30.0  # z -> 1
    u3[2, :] = 0.0  # candidate ignores previous state
    h = np.asarray(ref.gru_h(x, w3, u3, b3))
    want = np.tanh(x[:, :, -1] @ w3[:, 2, :] + b3[2][None, :])
    np.testing.assert_allclose(h, want, rtol=1e-4, atol=1e-5)


def test_outputs_bounded_by_activation():
    """tanh output layer => |H| <= 1 for every architecture."""
    for arch in ("elman", "jordan", "narmax", "fc", "gru"):
        cfg, x, extras, params = _mk(arch, rows=16, s=2, q=4, m=3, seed=1)
        h = np.asarray(ref.h_ref(arch, x, extras, params))
        assert np.all(np.abs(h) <= 1.0 + 1e-6), arch
    # LSTM: f = o * tanh(c), o in (0,1) => also bounded by 1.
    cfg, x, extras, params = _mk("lstm", rows=16, s=2, q=4, m=3, seed=1)
    h = np.asarray(ref.h_ref("lstm", x, extras, params))
    assert np.all(np.abs(h) <= 1.0 + 1e-6)


def test_sigmoid_matches_numpy():
    z = np.linspace(-10, 10, 101).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(sigmoid(jnp.asarray(z))), 1.0 / (1.0 + np.exp(-z)), rtol=1e-6
    )
