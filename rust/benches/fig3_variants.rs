//! Fig 3 regeneration: Basic vs Opt (BS 16/32) speedups per architecture
//! across the ten datasets (gpusim at paper sizes).

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    let ctx = ReportCtx::new(default_artifacts_dir());
    for t in run_report("fig3", &ctx).expect("fig3 is analytic") {
        println!("{}", t.to_markdown());
    }
}
