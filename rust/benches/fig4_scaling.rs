//! Fig 4 regeneration: speedup vs M (modeled at paper size + measured
//! pipeline sweep on this machine).

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping fig4 bench: run `make artifacts` first");
        return;
    }
    let mut ctx = ReportCtx::new(default_artifacts_dir());
    ctx.scale = 0.01;
    let t0 = std::time::Instant::now();
    for t in run_report("fig4", &ctx).expect("fig4") {
        println!("{}", t.to_markdown());
    }
    eprintln!("fig4 in {:.1}s", t0.elapsed().as_secs_f64());
}
