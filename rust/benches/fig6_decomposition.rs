//! Fig 6 regeneration: the phase decomposition of one Opt-PR-ELM run,
//! measured from the pipeline clocks + modeled at paper size. Also covers
//! Fig 5 (the BPTT loss-vs-time race) in bench-sized form.

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping fig5/6 bench: run `make artifacts` first");
        return;
    }
    let mut ctx = ReportCtx::new(default_artifacts_dir());
    ctx.scale = 0.5;
    for id in ["fig6", "fig5"] {
        let t0 = std::time::Instant::now();
        for t in run_report(id, &ctx).expect(id) {
            println!("{}", t.to_markdown());
        }
        eprintln!("{id} in {:.1}s", t0.elapsed().as_secs_f64());
    }
}
