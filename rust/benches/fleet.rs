//! Fleet-trainer throughput bench: many small same-shape tenants trained
//! (and served) through one `FleetTrainer` drain vs one-at-a-time solo
//! `CpuElmTrainer` runs over the identical jobs.
//!
//! The grouped path does the same numeric work as the solo loop — the
//! fleet's contract is bit-identical per-tenant β — so the measured win
//! is pure orchestration: one flattened block-diagonal stream and one
//! thread-pool barrier per drain instead of one per tenant (and, on the
//! predict side, one packed group-GEMM instead of per-tenant matvec
//! sweeps).
//!
//! Emits `BENCH_fleet.json` records carrying `requests_per_s` (the
//! fleet's unit of throughput — these ops have no meaningful GFLOP/s
//! column) and the grouped-vs-solo `speedup_vs_reference`, gated by
//! `ci/check_bench.py` against `benches/fleet_baseline.json`. The
//! `service_*` ops route the same jobs through the deadline-aware
//! `FleetService` front end and additionally carry the `shed` /
//! `retries` / `deadline_miss` counters the gate validates. Override
//! the output path with `BENCH_FLEET_OUT=…`; set `BENCH_FLEET_QUICK=1`
//! for the CI smoke mode (fewer tenants and rows, every op key still
//! emitted).

use std::time::Duration;

use opt_pr_elm::coordinator::accumulator::SolveStrategy;
use opt_pr_elm::coordinator::pipeline::CpuElmTrainer;
use opt_pr_elm::coordinator::{
    FleetOutcome, FleetRequest, FleetService, FleetTrainer, ServiceConfig, ServiceStats,
};
use opt_pr_elm::data::window::Windowed;
use opt_pr_elm::elm::Arch;
use opt_pr_elm::linalg::ParallelPolicy;
use opt_pr_elm::util::json::{num, obj, s, Json};
use opt_pr_elm::util::timer::{bench, BenchResult};

/// One emitted measurement.
struct Rec {
    op: String,
    shape: String,
    ns_per_iter: f64,
    /// fleet requests completed per second (the gate accepts this in
    /// place of `gflops` — orchestration ops have no FLOP model)
    requests_per_s: Option<f64>,
    speedup_vs_reference: Option<f64>,
    /// bench machine's worker count — set on the `meta` record only
    workers: Option<f64>,
    /// requests shed by the overload ladder — set on `service_*` ops only
    shed: Option<f64>,
    /// retry re-queues of degraded solves — set on `service_*` ops only
    retries: Option<f64>,
    /// typed deadline misses — set on `service_*` ops only
    deadline_miss: Option<f64>,
}

/// Attach the service counters to the record just pushed (`service_*`
/// ops must carry all three — `ci/check_bench.py` enforces it).
fn mark_service_counters(records: &mut [Rec], stats: &ServiceStats) {
    let last = records.last_mut().expect("a record was just pushed");
    last.shed = Some(stats.shed as f64);
    last.retries = Some(stats.retries as f64);
    last.deadline_miss = Some(stats.deadline_miss as f64);
}

fn push(
    records: &mut Vec<Rec>,
    r: &BenchResult,
    op: &str,
    shape: &str,
    requests: f64,
) -> f64 {
    println!("{}", r.summary());
    let secs = r.mean_secs();
    let rps = if secs > 0.0 { requests / secs } else { 0.0 };
    records.push(Rec {
        op: op.to_string(),
        shape: shape.to_string(),
        ns_per_iter: secs * 1e9,
        requests_per_s: Some(rps),
        speedup_vs_reference: None,
        workers: None,
        shed: None,
        retries: None,
        deadline_miss: None,
    });
    secs * 1e9
}

/// Attach the measured speedup to the record `back` positions from the
/// end (2 = the grouped record of a (grouped, solo) pair just pushed).
fn mark_speedup_at(records: &mut [Rec], back: usize, speedup: f64) {
    let i = records.len() - back;
    records[i].speedup_vs_reference = Some(speedup);
}

/// Chaotic logistic-map series, one distinct orbit per tenant.
fn series(len: usize, seed: u64) -> Vec<f64> {
    let mut x = 0.37 + (seed % 97) as f64 * 1e-3;
    (0..len)
        .map(|_| {
            x = 3.7 * x * (1.0 - x);
            x - 0.5
        })
        .collect()
}

fn main() {
    let quick =
        std::env::var("BENCH_FLEET_QUICK").map_or(false, |v| v != "0" && !v.is_empty());
    let budget = Duration::from_millis(if quick { 150 } else { 400 });
    let policy = ParallelPolicy::auto();
    let tenants = if quick { 8usize } else { 24 };
    let n = if quick { 160usize } else { 480 };
    let (m, q) = (16usize, 4usize);
    println!(
        "== fleet trainer bench (grouped vs solo){} — {} tenants, n={}, m={}, \
         threaded policy: {} workers ==",
        if quick { " [quick]" } else { "" },
        tenants,
        n,
        m,
        policy.workers
    );

    let mut records: Vec<Rec> = Vec::new();
    records.push(Rec {
        op: "meta".to_string(),
        shape: format!("workers={}", policy.workers),
        ns_per_iter: 1.0,
        requests_per_s: None,
        speedup_vs_reference: None,
        workers: Some(policy.workers as f64),
        shed: None,
        retries: None,
        deadline_miss: None,
    });

    let datasets: Vec<Windowed> = (0..tenants)
        .map(|i| Windowed::from_series(&series(n + q, 1000 + i as u64), q).unwrap())
        .collect();
    let shape = format!("tenants{tenants}_n{n}_m{m}_q{q}");
    let solo = CpuElmTrainer {
        policy,
        block_rows: 256,
        strategy: SolveStrategy::Gram,
        lambda: 1e-6,
    };

    // grouped: every tenant through ONE block-diagonal drain
    let r = bench(&format!("fleet_train_grouped {shape}"), 1, budget, 30, || {
        let mut fleet = FleetTrainer::with_policy(policy);
        for (i, d) in datasets.iter().enumerate() {
            fleet
                .submit(FleetRequest::Train {
                    tenant: format!("t{i}"),
                    arch: Arch::Elman,
                    m,
                    seed: 7 + i as u64,
                    data: d.clone(),
                })
                .unwrap();
        }
        let out = fleet.drain();
        assert!(out.iter().all(|(_, o)| matches!(o, FleetOutcome::Trained { .. })));
        out.len()
    });
    let t_grouped = push(&mut records, &r, "fleet_train_grouped", &shape, tenants as f64);

    // solo reference: the identical jobs, one CpuElmTrainer run each
    let r = bench(&format!("fleet_train_solo {shape}"), 1, budget, 30, || {
        let mut betas = 0usize;
        for (i, d) in datasets.iter().enumerate() {
            let (model, _) = solo.train(Arch::Elman, d, m, 7 + i as u64).unwrap();
            betas += model.beta.len();
        }
        betas
    });
    let t_solo = push(&mut records, &r, "fleet_train_solo", &shape, tenants as f64);
    mark_speedup_at(&mut records, 2, t_solo / t_grouped);
    println!("  -> grouped train speedup vs solo loop: {:.2}x", t_solo / t_grouped);

    // predict throughput against a warm cache: one flattened H stream +
    // one packed group-GEMM vs per-tenant solo predicts
    let mut warm = FleetTrainer::with_policy(policy);
    for (i, d) in datasets.iter().enumerate() {
        warm.submit(FleetRequest::Train {
            tenant: format!("t{i}"),
            arch: Arch::Elman,
            m,
            seed: 7 + i as u64,
            data: d.clone(),
        })
        .unwrap();
    }
    warm.drain();
    let models: Vec<_> =
        (0..tenants).map(|i| warm.model(&format!("t{i}")).unwrap().clone()).collect();

    let r = bench(&format!("fleet_predict_grouped {shape}"), 1, budget, 30, || {
        for (i, d) in datasets.iter().enumerate() {
            warm.submit(FleetRequest::Predict {
                tenant: format!("t{i}"),
                data: d.clone(),
            })
            .unwrap();
        }
        let out = warm.drain();
        assert!(out.iter().all(|(_, o)| matches!(o, FleetOutcome::Predicted { .. })));
        out.len()
    });
    let t_grouped =
        push(&mut records, &r, "fleet_predict_grouped", &shape, tenants as f64);

    let r = bench(&format!("fleet_predict_solo {shape}"), 1, budget, 30, || {
        let mut total = 0usize;
        for (model, d) in models.iter().zip(&datasets) {
            total += solo.predict(model, d).unwrap().len();
        }
        total
    });
    let t_solo = push(&mut records, &r, "fleet_predict_solo", &shape, tenants as f64);
    mark_speedup_at(&mut records, 2, t_solo / t_grouped);
    println!("  -> grouped predict speedup vs solo loop: {:.2}x", t_solo / t_grouped);

    // async service train: the identical jobs through the deadline-aware
    // FleetService front end (unbounded, no deadlines) — the service
    // contract says this is the same numeric work as one sync drain, so
    // the delta over fleet_train_grouped is pure scheduling overhead
    let run_async = |stats_out: &mut ServiceStats| {
        let mut svc = FleetService::new(FleetTrainer::with_policy(policy));
        for (i, d) in datasets.iter().enumerate() {
            svc.submit(
                FleetRequest::Train {
                    tenant: format!("t{i}"),
                    arch: Arch::Elman,
                    m,
                    seed: 7 + i as u64,
                    data: d.clone(),
                },
                None,
                0,
            )
            .unwrap();
        }
        let done = svc.run_to_idle();
        assert!(done.iter().all(|c| c.outcome.is_ok()));
        *stats_out = svc.stats();
        done.len()
    };
    let r = bench(&format!("service_async_train {shape}"), 1, budget, 30, || {
        let mut stats = ServiceStats::default();
        run_async(&mut stats)
    });
    let _ = push(&mut records, &r, "service_async_train", &shape, tenants as f64);
    let mut stats = ServiceStats::default();
    run_async(&mut stats);
    mark_service_counters(&mut records, &stats);
    println!(
        "  -> async service train: completed={} retries={} shed={}",
        stats.completed, stats.retries, stats.shed
    );

    // overload shedding: a bounded queue offered more trains than it
    // admits plus one doomed low-priority predict — exercises the ladder
    // (RejectTrains at 90% occupancy) and the typed deadline path
    let cap = 10usize;
    let offered = tenants.max(12);
    let run_overload = |stats_out: &mut ServiceStats| {
        let mut svc = FleetService::with_config(
            FleetTrainer::with_policy(policy),
            ServiceConfig { capacity: Some(cap), ..ServiceConfig::default() },
        );
        for i in 0..offered {
            let d = &datasets[i % datasets.len()];
            let _ = svc.submit(
                FleetRequest::Train {
                    tenant: format!("t{i}"),
                    arch: Arch::Elman,
                    m,
                    seed: 7 + i as u64,
                    data: d.clone(),
                },
                None,
                0,
            );
        }
        let _ = svc.submit(
            FleetRequest::Predict { tenant: "t0".to_string(), data: datasets[0].clone() },
            Some(0),
            0,
        );
        let done = svc.run_to_idle();
        *stats_out = svc.stats();
        done.len()
    };
    let r = bench(&format!("service_overload_shed {shape}"), 1, budget, 30, || {
        let mut stats = ServiceStats::default();
        run_overload(&mut stats)
    });
    push(&mut records, &r, "service_overload_shed", &shape, (offered + 1) as f64);
    let mut stats = ServiceStats::default();
    run_overload(&mut stats);
    mark_service_counters(&mut records, &stats);
    println!(
        "  -> overload ladder: shed={} deadline_miss={} of {} offered",
        stats.shed,
        stats.deadline_miss,
        offered + 1
    );

    let out_path = std::env::var("BENCH_FLEET_OUT")
        .unwrap_or_else(|_| "BENCH_fleet.json".to_string());
    let json = Json::Arr(
        records
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("op", s(&r.op)),
                    ("shape", s(&r.shape)),
                    ("ns_per_iter", num(r.ns_per_iter)),
                ];
                if let Some(x) = r.requests_per_s {
                    pairs.push(("requests_per_s", num(x)));
                }
                if let Some(x) = r.workers {
                    pairs.push(("workers", num(x)));
                }
                if let Some(x) = r.speedup_vs_reference {
                    pairs.push(("speedup_vs_reference", num(x)));
                }
                if let Some(x) = r.shed {
                    pairs.push(("shed", num(x)));
                }
                if let Some(x) = r.retries {
                    pairs.push(("retries", num(x)));
                }
                if let Some(x) = r.deadline_miss {
                    pairs.push(("deadline_miss", num(x)));
                }
                obj(pairs)
            })
            .collect(),
    );
    match std::fs::write(&out_path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {out_path} ({} records)", records.len()),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
