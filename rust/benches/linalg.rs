//! Microbenches for the β-solve substrate: Householder QR vs TSQR vs the
//! ridge/Cholesky path at ELM-shaped sizes (tall-skinny, M ≤ 100).

use std::time::Duration;

use opt_pr_elm::linalg::{householder_qr, lstsq_qr, lstsq_ridge, Matrix, TsqrAccumulator};
use opt_pr_elm::util::rng::Rng;
use opt_pr_elm::util::timer::bench;

fn main() {
    let budget = Duration::from_millis(400);
    println!("== linalg microbench (β solve substrate) ==");
    for (n, m) in [(1000usize, 20usize), (5000, 50), (20000, 50), (5000, 100)] {
        let mut rng = Rng::new(1);
        let a = Matrix::random(n, m, &mut rng);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

        let r = bench(&format!("householder_qr {n}x{m}"), 1, budget, 50, || {
            householder_qr(&a).unwrap()
        });
        println!("{}", r.summary());

        let r = bench(&format!("lstsq_qr {n}x{m}"), 1, budget, 50, || {
            lstsq_qr(&a, &b).unwrap()
        });
        println!("{}", r.summary());

        let r = bench(&format!("lstsq_ridge {n}x{m}"), 1, budget, 50, || {
            lstsq_ridge(&a, &b, 1e-8).unwrap()
        });
        println!("{}", r.summary());

        let r = bench(&format!("tsqr(block=256) {n}x{m}"), 1, budget, 50, || {
            let mut acc = TsqrAccumulator::new(m);
            let mut i = 0;
            while i < n {
                let hi = (i + 256).min(n);
                let rows: Vec<Vec<f64>> = (i..hi).map(|r| a.row(r).to_vec()).collect();
                acc.push_block(&Matrix::from_rows(&rows), &b[i..hi]).unwrap();
                i = hi;
            }
            acc.solve().unwrap()
        });
        println!("{}", r.summary());
        println!();
    }
}
