//! Microbenches for the β-solve substrate: blocked QR vs the seed scalar
//! reference, tiled GEMM/Gram vs the naive loops, the accumulate-widen
//! (f32 wire / f64 accumulate) kernels vs their f64 twins, TSQR streaming
//! vs the parallel tree, the GEMM-lifted FC `h_block` vs its scalar
//! loop, and the sequence-parallel chunked recurrence vs the sequential
//! time loop at long horizons — at ELM-shaped sizes (tall-skinny,
//! M ≤ 100).
//!
//! Besides the human-readable summary lines, the run emits a
//! machine-readable `BENCH_linalg.json` (op, shape, ns/iter, GFLOP/s,
//! GB/s, and the speedup over the reference where one exists) so future
//! PRs have a perf trajectory to regress against. The GB/s figure is
//! *achieved bandwidth against the compulsory-traffic model* (operands
//! read once + result written once, at wire width); it exists to make the
//! halved-traffic claim of the widen kernels measurable — compare
//! `matmul` vs `matmul_widen` bytes at equal FLOPs. Override the output
//! path with `BENCH_LINALG_OUT=…`; set `BENCH_LINALG_QUICK=1` for the CI
//! smoke mode (smaller budgets and shapes, every op key still emitted —
//! `ci/check_bench.py` gates the speedup ratios against
//! `benches/linalg_baseline.json`).

use std::time::Duration;

use opt_pr_elm::elm::arch::{self as arch, fc, SampleBlock};
use opt_pr_elm::elm::{Arch, ElmParams};
use opt_pr_elm::linalg::{
    householder_qr, householder_qr_reference, lstsq_qr, lstsq_qr_report,
    lstsq_ridge, lstsq_tsqr, simd, solve_upper_triangular, FmaMode, Matrix,
    MatrixF32, ParallelPolicy, RecurrenceMode, TsqrAccumulator,
};
use opt_pr_elm::util::json::{num, obj, s, Json};
use opt_pr_elm::util::rng::Rng;
use opt_pr_elm::util::timer::{bench, BenchResult};

/// One emitted measurement.
struct Rec {
    op: String,
    shape: String,
    ns_per_iter: f64,
    gflops: f64,
    /// achieved bandwidth vs the compulsory-traffic model (GB/s)
    gbps: f64,
    speedup_vs_reference: Option<f64>,
    /// bench machine's worker count — set on the `meta` record only, as
    /// an explicit field (the one-release gflops smuggle is gone;
    /// `ci/check_bench.py` now requires `workers` outright)
    workers: Option<f64>,
    /// which SIMD path the run dispatched ("avx2" / "scalar") — set on the
    /// `meta` record only, so the CI gate does not hold a scalar-fallback
    /// runner to AVX2 microkernel floors
    isa: Option<String>,
    /// degradation-ladder rung a healthy probe solve reported ("primary" /
    /// "ridge" / "failed") — set on the `meta` record only; the CI gate
    /// dies on unknown rungs and warns when a bench machine's healthy
    /// probe degraded off the primary path
    solve_report: Option<String>,
}

fn push(
    records: &mut Vec<Rec>,
    r: &BenchResult,
    op: &str,
    shape: &str,
    flops: f64,
    bytes: f64,
) -> f64 {
    println!("{}", r.summary());
    let ns = r.mean_secs() * 1e9;
    let gflops = if flops > 0.0 && ns > 0.0 { flops / ns } else { 0.0 };
    let gbps = if bytes > 0.0 && ns > 0.0 { bytes / ns } else { 0.0 };
    records.push(Rec {
        op: op.to_string(),
        shape: shape.to_string(),
        ns_per_iter: ns,
        gflops,
        gbps,
        speedup_vs_reference: None,
        workers: None,
        isa: None,
        solve_report: None,
    });
    ns
}

/// The seed's unblocked gram loop (zero-skip branch and all), kept here as
/// the measurement baseline.
fn gram_reference(a: &Matrix) -> Matrix {
    let n = a.cols;
    let mut g = Matrix::zeros(n, n);
    for i in 0..a.rows {
        let r = a.row(i);
        for x in 0..n {
            let rx = r[x];
            if rx == 0.0 {
                continue;
            }
            for y in x..n {
                g[(x, y)] += rx * r[y];
            }
        }
    }
    for x in 0..n {
        for y in 0..x {
            g[(x, y)] = g[(y, x)];
        }
    }
    g
}

/// Least squares through the seed scalar QR (the speedup baseline).
fn lstsq_qr_reference(a: &Matrix, b: &[f64]) -> Vec<f64> {
    let f = householder_qr_reference(a).unwrap();
    let mut z = b.to_vec();
    f.apply_qt(&mut z);
    solve_upper_triangular(&f.r(), &z[..a.cols]).unwrap()
}

fn main() {
    let quick = std::env::var("BENCH_LINALG_QUICK").map_or(false, |v| v != "0" && !v.is_empty());
    let budget = Duration::from_millis(if quick { 150 } else { 400 });
    let threaded = ParallelPolicy::auto();
    let mut records: Vec<Rec> = Vec::new();
    println!(
        "== linalg microbench (β solve substrate){} — threaded policy: {} workers, simd: {} ==",
        if quick { " [quick]" } else { "" },
        threaded.workers,
        simd::isa_name()
    );
    // meta record: lets the CI gate scale the threaded-speedup floors to
    // the machine it actually ran on, and records which SIMD path was
    // dispatched (`isa`) so microkernel floors are not misread on
    // scalar-fallback runners. The worker count travels in the explicit
    // `workers` field only — the deprecated gflops mirror is retired.
    // healthy probe solve: a well-conditioned system must come back on the
    // ladder's primary rung — anything else means this machine's solve
    // substrate is degraded, which the CI gate warns about before holding
    // its numbers to the perf floors
    let probe_rung = {
        let mut rng = Rng::new(7);
        let a = Matrix::random(64, 8, &mut rng);
        let b: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let (_, report) = lstsq_qr_report(&a, &b, threaded).expect("probe solve");
        report.rung_name()
    };
    records.push(Rec {
        op: "meta".to_string(),
        shape: format!("workers={} isa={}", threaded.workers, simd::isa_name()),
        ns_per_iter: 1.0,
        gflops: 0.0,
        gbps: 0.0,
        speedup_vs_reference: None,
        workers: Some(threaded.workers as f64),
        isa: Some(simd::isa_name().to_string()),
        solve_report: Some(probe_rung.to_string()),
    });

    let tall: &[(usize, usize)] = if quick {
        &[(1000, 20), (5000, 50)]
    } else {
        &[(1000, 20), (5000, 50), (20000, 50), (5000, 100)]
    };
    for &(n, m) in tall {
        let mut rng = Rng::new(1);
        let a = Matrix::random(n, m, &mut rng);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shape = format!("{n}x{m}");
        let qr_flops = 2.0 * n as f64 * (m * m) as f64 - 2.0 / 3.0 * (m * m * m) as f64;
        let gram_flops = (n * m * (m + 1)) as f64;
        // compulsory traffic: A in + factors/G out, at wire width
        let qr_bytes = 8.0 * 2.0 * (n * m) as f64;
        let gram_bytes = 8.0 * ((n * m) as f64 + (m * m) as f64);
        let gram_widen_bytes = 4.0 * (n * m) as f64 + 8.0 * (m * m) as f64;

        let r = bench(&format!("householder_qr {shape}"), 1, budget, 50, || {
            householder_qr(&a).unwrap()
        });
        let t_blk = push(&mut records, &r, "householder_qr", &shape, qr_flops, qr_bytes);
        let r = bench(&format!("householder_qr_ref {shape}"), 1, budget, 50, || {
            householder_qr_reference(&a).unwrap()
        });
        let t_ref = push(&mut records, &r, "householder_qr_ref", &shape, qr_flops, qr_bytes);
        mark_speedup_at(&mut records, 2, t_ref / t_blk);
        println!("  -> blocked QR speedup vs seed scalar: {:.2}x", t_ref / t_blk);

        let r = bench(&format!("lstsq_qr {shape}"), 1, budget, 50, || {
            lstsq_qr(&a, &b).unwrap()
        });
        let t_blk = push(&mut records, &r, "lstsq_qr", &shape, qr_flops, qr_bytes);
        let r = bench(&format!("lstsq_qr_ref {shape}"), 1, budget, 50, || {
            lstsq_qr_reference(&a, &b)
        });
        let t_ref = push(&mut records, &r, "lstsq_qr_ref", &shape, qr_flops, qr_bytes);
        mark_speedup_at(&mut records, 2, t_ref / t_blk);
        println!("  -> lstsq_qr speedup vs seed scalar: {:.2}x", t_ref / t_blk);

        let r = bench(&format!("lstsq_ridge {shape}"), 1, budget, 50, || {
            lstsq_ridge(&a, &b, 1e-8).unwrap()
        });
        push(&mut records, &r, "lstsq_ridge", &shape, gram_flops, gram_bytes);

        // panel-resident Qᵀb vs the seed column-at-a-time loop, on each
        // path's own factors (what lstsq_qr / lstsq_qr_reference execute)
        let qt_flops = 4.0 * (n * m) as f64;
        let qt_bytes = 8.0 * ((n * m) as f64 + n as f64);
        let f_blk = householder_qr(&a).unwrap();
        let f_ref = householder_qr_reference(&a).unwrap();
        let r = bench(&format!("apply_qt {shape}"), 1, budget, 200, || {
            let mut z = b.clone();
            f_blk.apply_qt(&mut z);
            z
        });
        let t_blk = push(&mut records, &r, "apply_qt", &shape, qt_flops, qt_bytes);
        let r = bench(&format!("apply_qt_ref {shape}"), 1, budget, 200, || {
            let mut z = b.clone();
            f_ref.apply_qt(&mut z);
            z
        });
        let t_ref = push(&mut records, &r, "apply_qt_ref", &shape, qt_flops, qt_bytes);
        mark_speedup_at(&mut records, 2, t_ref / t_blk);
        println!("  -> panel apply_qt speedup vs column loop: {:.2}x", t_ref / t_blk);

        let r = bench(&format!("gram {shape}"), 1, budget, 50, || a.gram());
        let t_blk = push(&mut records, &r, "gram", &shape, gram_flops, gram_bytes);
        let r = bench(&format!("gram_ref {shape}"), 1, budget, 50, || {
            gram_reference(&a)
        });
        let t_ref = push(&mut records, &r, "gram_ref", &shape, gram_flops, gram_bytes);
        mark_speedup_at(&mut records, 2, t_ref / t_blk);
        println!("  -> gram speedup vs seed scalar: {:.2}x", t_ref / t_blk);

        // accumulate-widen Gram: f32 operand stream, f64 accumulator —
        // same FLOPs, half the operand bytes (speedup recorded vs the f64
        // tiled gram just measured)
        let a32 = MatrixF32::from_matrix(&a);
        let r = bench(&format!("gram_widen {shape}"), 1, budget, 50, || {
            a32.gram_widen(ParallelPolicy::sequential())
        });
        let t_widen = push(&mut records, &r, "gram_widen", &shape, gram_flops, gram_widen_bytes);
        mark_speedup_at(&mut records, 1, t_blk / t_widen);
        println!("  -> widen gram speedup vs f64 gram: {:.2}x", t_blk / t_widen);

        let r = bench(&format!("gram_threaded {shape}"), 1, budget, 50, || {
            a.gram_with(threaded)
        });
        let t_thr = push(&mut records, &r, "gram_threaded", &shape, gram_flops, gram_bytes);
        mark_speedup_at(&mut records, 1, t_blk / t_thr);
        println!("  -> threaded gram speedup vs single-thread: {:.2}x", t_blk / t_thr);

        let r = bench(&format!("tsqr(block=256) {shape}"), 1, budget, 50, || {
            let mut acc = TsqrAccumulator::new(m);
            let mut i = 0;
            while i < n {
                let hi = (i + 256).min(n);
                acc.push_block(a.submatrix(i, hi, 0, m), &b[i..hi]).unwrap();
                i = hi;
            }
            acc.solve().unwrap()
        });
        push(&mut records, &r, "tsqr_stream", &shape, qr_flops, qr_bytes);

        for workers in [1usize, 2, 4, 8] {
            let r = bench(
                &format!("lstsq_tsqr(w={workers}) {shape}"),
                1,
                budget,
                50,
                || lstsq_tsqr(&a, &b, ParallelPolicy::with_workers(workers)).unwrap(),
            );
            push(&mut records, &r, &format!("lstsq_tsqr_w{workers}"), &shape, qr_flops, qr_bytes);
        }
        println!();
    }

    // square GEMM: the kernel behind the QR trailing updates and h_block;
    // 512 is the acceptance shape for the threaded speedup gate
    let dims: &[usize] = if quick { &[128, 512] } else { &[128, 384, 512] };
    for &dim in dims {
        let mut rng = Rng::new(2);
        let a = Matrix::random(dim, dim, &mut rng);
        let b = Matrix::random(dim, dim, &mut rng);
        let shape = format!("{dim}x{dim}x{dim}");
        let flops = 2.0 * (dim * dim * dim) as f64;
        let d2 = (dim * dim) as f64;
        let mm_bytes = 8.0 * 3.0 * d2;
        let widen_bytes = 4.0 * 2.0 * d2 + 8.0 * d2;
        let r = bench(&format!("matmul {shape}"), 1, budget, 50, || a.matmul(&b));
        let t_seq = push(&mut records, &r, "matmul", &shape, flops, mm_bytes);
        let r = bench(&format!("matmul_threaded {shape}"), 1, budget, 50, || {
            a.matmul_with(&b, threaded)
        });
        let t_thr = push(&mut records, &r, "matmul_threaded", &shape, flops, mm_bytes);
        mark_speedup_at(&mut records, 1, t_seq / t_thr);
        println!(
            "  -> threaded matmul {dim} speedup vs single-thread: {:.2}x",
            t_seq / t_thr
        );

        // accumulate-widen GEMM: half the operand traffic of the f64 GEMM
        // at identical FLOPs and tile schedule
        let a32 = MatrixF32::from_matrix(&a);
        let b32 = MatrixF32::from_matrix(&b);
        let r = bench(&format!("matmul_widen {shape}"), 1, budget, 50, || {
            a32.matmul_widen(&b32, ParallelPolicy::sequential())
        });
        let t_widen = push(&mut records, &r, "matmul_widen", &shape, flops, widen_bytes);
        mark_speedup_at(&mut records, 1, t_seq / t_widen);
        println!(
            "  -> widen matmul {dim} speedup vs f64 matmul: {:.2}x",
            t_seq / t_widen
        );
    }
    println!();

    // GEMM-lifted FC h_block vs its scalar reference loop: the recurrence
    // whose per-timestep work was a strided GEMV per sample
    {
        let (rows, s, q, m) =
            if quick { (128usize, 1usize, 12usize, 48usize) } else { (256, 1, 16, 64) };
        let p = ElmParams::init(Arch::Fc, s, q, m, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..rows * s * q).map(|_| rng.normal() as f32).collect();
        let yh = vec![0f32; rows * q];
        let eh = vec![0f32; rows * q];
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        let shape = format!("rows{rows}_q{q}_m{m}");
        // recurrence flops: rows · Σ_t Σ_{k<=t} 2m² ≈ rows·q²·m²
        let flops = rows as f64 * (q * q) as f64 * (m * m) as f64;
        let bytes = 4.0 * ((rows * s * q) as f64 + (m * m * q) as f64) + 8.0 * (rows * m) as f64;
        let r = bench(&format!("fc_h_block {shape}"), 1, budget, 50, || {
            fc::h_block(&p, &blk)
        });
        let t_blk = push(&mut records, &r, "fc_h_block", &shape, flops, bytes);
        let r = bench(&format!("fc_h_block_ref {shape}"), 1, budget, 50, || {
            fc::h_block_reference(&p, &blk)
        });
        let t_ref = push(&mut records, &r, "fc_h_block_ref", &shape, flops, bytes);
        mark_speedup_at(&mut records, 2, t_ref / t_blk);
        println!("  -> batched FC h_block speedup vs scalar loop: {:.2}x", t_ref / t_blk);

        // f32-born H: same GEMM-lifted recurrence, but the coupling
        // history slabs and the output block never materialize in f64 —
        // the compulsory output traffic halves (4-byte H), which the gbps
        // column makes visible next to fc_h_block's
        let bytes_f32 =
            4.0 * ((rows * s * q) as f64 + (m * m * q) as f64) + 4.0 * (rows * m) as f64;
        let r = bench(&format!("fc_h_block_f32 {shape}"), 1, budget, 50, || {
            fc::h_block_f32(&p, &blk)
        });
        let t_f32 = push(&mut records, &r, "fc_h_block_f32", &shape, flops, bytes_f32);
        mark_speedup_at(&mut records, 1, t_ref / t_f32);
        println!(
            "  -> f32-born FC h_block speedup vs scalar loop: {:.2}x",
            t_ref / t_f32
        );
        println!();
    }

    // long-horizon recurrence: the sequential GRU time loop vs the
    // sequence-parallel chunked engine at 10⁵-scale horizons (quick mode
    // keeps the same op keys at a smoke-sized horizon). The chunked mode
    // evaluates only the tail chunk plus a lag-contraction warm-up, so
    // its win is truncation-driven — it grows with the horizon and is
    // gated in `linalg_baseline.json` as a plain (non-threaded) floor.
    {
        let (rows, s, m) = (64usize, 1usize, 32usize);
        let q = if quick { 4096usize } else { 131_072 };
        let (chunk, warmup) = (1024usize, 128usize);
        let p = ElmParams::init(Arch::Gru, s, q, m, 6);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..rows * s * q).map(|_| rng.normal() as f32).collect();
        let yh = vec![0f32; rows * q];
        let eh = vec![0f32; rows * q];
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        let shape = format!("rows{rows}_q{q}_m{m}");
        // lag-1 leaky recurrence: ~12 flops per (sample, step, unit)
        let flops = 12.0 * (rows * q * m) as f64;
        let bytes = 4.0 * ((rows * s * q) as f64 + (rows * m) as f64);
        let r = bench(&format!("h_block_long_horizon {shape}"), 1, budget, 20, || {
            arch::h_block_f32(&p, &blk)
        });
        let t_seq = push(&mut records, &r, "h_block_long_horizon", &shape, flops, bytes);
        let chunked_policy = ParallelPolicy::auto()
            .with_recurrence(RecurrenceMode::Chunked { chunk, warmup });
        let chunked_steps = (chunk + warmup).min(q);
        let chunked_flops = 12.0 * (rows * chunked_steps * m) as f64;
        let r = bench(
            &format!("h_block_long_horizon_chunked {shape}"),
            1,
            budget,
            20,
            || arch::h_block_f32_with(&p, &blk, chunked_policy),
        );
        let t_chk = push(
            &mut records,
            &r,
            "h_block_long_horizon_chunked",
            &shape,
            chunked_flops,
            bytes,
        );
        mark_speedup_at(&mut records, 1, t_seq / t_chk);
        println!(
            "  -> chunked long-horizon h_block (q={q}, chunk={chunk}+{warmup} warm-up) \
             speedup vs sequential time loop: {:.2}x",
            t_seq / t_chk
        );
        println!();
    }

    // microkernel-level ops: the dispatched SIMD kernels against their
    // scalar twins (the exact fallback code), at a panel-resident working
    // set. On an AVX2 host these quantify the pinned-width win over the
    // autovectorized scalar loops; on a scalar-fallback host the ratio is
    // ~1.0 by construction (and the CI gate, reading the meta `isa`
    // field, expects exactly that).
    {
        let len = 4096usize;
        let reps = 64usize;
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f64; len];
        let shape = format!("len{len}_reps{reps}");
        let flops = 2.0 * (len * reps) as f64;
        let bytes = 8.0 * 3.0 * (len * reps) as f64; // x in + out in/out
        let r = bench(&format!("axpy_simd {shape}"), 1, budget, 400, || {
            for i in 0..reps {
                simd::axpy_f64(1e-3 * (i as f64 + 1.0), &x, &mut out);
            }
            out[0]
        });
        let t_simd = push(&mut records, &r, "axpy_simd", &shape, flops, bytes);
        let r = bench(&format!("axpy_scalar {shape}"), 1, budget, 400, || {
            for i in 0..reps {
                simd::axpy_f64_scalar(1e-3 * (i as f64 + 1.0), &x, &mut out);
            }
            out[0]
        });
        let t_ref = push(&mut records, &r, "axpy_scalar", &shape, flops, bytes);
        mark_speedup_at(&mut records, 2, t_ref / t_simd);
        println!(
            "  -> dispatched axpy ({}) speedup vs scalar twin: {:.2}x",
            simd::isa_name(),
            t_ref / t_simd
        );

        // rank-4 Gram row update: the register-dense kernel where the
        // pinned-width path has the most to win (4 row streams + G row)
        let n = 512usize;
        let rows: Vec<Vec<f64>> =
            (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let mut grow = vec![0.0f64; n];
        let gshape = format!("n{n}_reps{reps}");
        let gflops_total = 8.0 * (n * reps) as f64; // 4 mul + 4 add per element
        let gbytes = 8.0 * 6.0 * (n * reps) as f64; // 4 rows in + grow in/out
        let r = bench(&format!("gram_microkernel {gshape}"), 1, budget, 400, || {
            for i in 0..reps {
                let xi = 1e-3 * (i as f64 + 1.0);
                simd::gram4_f64(
                    [xi, -xi, 0.5 * xi, 0.25 * xi],
                    [&rows[0], &rows[1], &rows[2], &rows[3]],
                    &mut grow,
                    FmaMode::Exact,
                );
            }
            grow[0]
        });
        let t_simd = push(&mut records, &r, "gram_microkernel", &gshape, gflops_total, gbytes);
        let r = bench(&format!("gram_microkernel_scalar {gshape}"), 1, budget, 400, || {
            for i in 0..reps {
                let xi = 1e-3 * (i as f64 + 1.0);
                simd::gram4_f64_scalar(
                    [xi, -xi, 0.5 * xi, 0.25 * xi],
                    [&rows[0], &rows[1], &rows[2], &rows[3]],
                    &mut grow,
                );
            }
            grow[0]
        });
        let t_ref =
            push(&mut records, &r, "gram_microkernel_scalar", &gshape, gflops_total, gbytes);
        mark_speedup_at(&mut records, 2, t_ref / t_simd);
        println!(
            "  -> dispatched gram microkernel ({}) speedup vs scalar twin: {:.2}x",
            simd::isa_name(),
            t_ref / t_simd
        );
        println!();
    }

    let out_path = std::env::var("BENCH_LINALG_OUT")
        .unwrap_or_else(|_| "BENCH_linalg.json".to_string());
    let json = Json::Arr(
        records
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("op", s(&r.op)),
                    ("shape", s(&r.shape)),
                    ("ns_per_iter", num(r.ns_per_iter)),
                    ("gflops", num(r.gflops)),
                    ("gbps", num(r.gbps)),
                ];
                if let Some(x) = r.workers {
                    pairs.push(("workers", num(x)));
                }
                if let Some(x) = &r.isa {
                    pairs.push(("isa", s(x)));
                }
                if let Some(x) = &r.solve_report {
                    pairs.push(("solve_report", s(x)));
                }
                if let Some(x) = r.speedup_vs_reference {
                    pairs.push(("speedup_vs_reference", num(x)));
                }
                obj(pairs)
            })
            .collect(),
    );
    match std::fs::write(&out_path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {out_path} ({} records)", records.len()),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}

/// Attach the measured speedup to the record `back` positions from the
/// end: 1 = the record just pushed (threaded-vs-single-thread and
/// widen-vs-f64 pairs, reference measured earlier), 2 = the non-reference
/// record of a (new, reference) pair just pushed.
fn mark_speedup_at(records: &mut [Rec], back: usize, speedup: f64) {
    let i = records.len() - back;
    records[i].speedup_vs_reference = Some(speedup);
}
