//! Table 2 regeneration: the per-architecture operation counts and the
//! Basic→Opt read-reduction sweep over tile widths.

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    let ctx = ReportCtx::new(default_artifacts_dir());
    for t in run_report("table2", &ctx).expect("table2 is analytic") {
        println!("{}", t.to_markdown());
    }
    // extra: read-reduction vs tile width (the §5 TW² claim)
    use opt_pr_elm::elm::ALL_ARCHS;
    use opt_pr_elm::gpusim::counts::op_counts;
    use opt_pr_elm::gpusim::Variant;
    println!("### read reduction vs TW (S=1, Q=50, M=50)\n");
    print!("| arch |");
    for tw in [4, 8, 16, 32] {
        print!(" TW={tw} |");
    }
    println!();
    println!("|------|------|------|------|------|");
    for arch in ALL_ARCHS {
        print!("| {} |", arch.name());
        for tw in [4usize, 8, 16, 32] {
            let b = op_counts(arch, Variant::Basic, 1, 50, 50, tw);
            let o = op_counts(arch, Variant::Opt, 1, 50, 50, tw);
            print!(" {:.0}x |", b.reads / o.reads);
        }
        println!();
    }
}
