//! Table 4 regeneration (measured): RMSE ± std of S-R-ELM vs Opt-PR-ELM.
//! Bench-sized by default; `repro report table4 --scale ... --reps 5` runs
//! the fuller version.

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping table4 bench: run `make artifacts` first");
        return;
    }
    let mut ctx = ReportCtx::new(default_artifacts_dir());
    ctx.scale = 0.01;
    ctx.reps = 2;
    let t0 = std::time::Instant::now();
    for t in run_report("table4", &ctx).expect("table4") {
        println!("{}", t.to_markdown());
    }
    eprintln!(
        "table4 (scale {}, reps {}) in {:.1}s",
        ctx.scale,
        ctx.reps,
        t0.elapsed().as_secs_f64()
    );
}
