//! Table 5 regeneration: modeled Tesla/Quadro speedups at paper sizes +
//! the measured pipeline-vs-sequential column on this machine.

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping table5 bench: run `make artifacts` first");
        return;
    }
    let mut ctx = ReportCtx::new(default_artifacts_dir());
    ctx.scale = 0.02;
    let t0 = std::time::Instant::now();
    for t in run_report("table5", &ctx).expect("table5") {
        println!("{}", t.to_markdown());
    }
    eprintln!("table5 in {:.1}s", t0.elapsed().as_secs_f64());
}
