//! Table 6 regeneration (measured): Opt-PR-ELM vs P-BPTT runtimes.

use opt_pr_elm::report::{run_report, ReportCtx};
use opt_pr_elm::runtime::default_artifacts_dir;

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping table6 bench: run `make artifacts` first");
        return;
    }
    let mut ctx = ReportCtx::new(default_artifacts_dir());
    ctx.scale = 0.02;
    let t0 = std::time::Instant::now();
    for t in run_report("table6", &ctx).expect("table6") {
        println!("{}", t.to_markdown());
    }
    eprintln!("table6 in {:.1}s", t0.elapsed().as_secs_f64());
}
