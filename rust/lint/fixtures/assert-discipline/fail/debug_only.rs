//@ path: src/elm/arch/demo.rs
//! Fixture: a pub kernel entry point whose shape check vanishes in
//! release builds — exactly the class of bug PR 4's contract bans.
#![forbid(unsafe_code)]

/// Writes `2 * x` into `out`; shape check is debug-only (wrong).
pub fn double_into(x: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, xi) in out.iter_mut().zip(x) {
        *o = 2.0 * xi;
    }
}
