//@ path: src/elm/arch/demo.rs
//! Fixture: a pub kernel entry point validating shapes with `assert!`
//! (release-mode too); `debug_assert!` stays legal in private helpers.
#![forbid(unsafe_code)]

/// Writes `2 * x` into `out`; shape-checked in all build profiles.
pub fn double_into(x: &[f64], out: &mut [f64]) {
    assert!(x.len() == out.len(), "double_into: x and out lengths must match");
    for (o, xi) in out.iter_mut().zip(x) {
        *o = 2.0 * xi;
    }
}

fn helper(x: &[f64]) -> f64 {
    debug_assert!(!x.is_empty());
    x[0]
}
