//@ path: src/linalg/demo.rs
//! Fixture: a float fold in a kernel module with no fold-order
//! annotation — the reduction order contract is undeclared.
#![forbid(unsafe_code)]

/// Sums the slice without declaring its reduction-order contract.
pub fn total(x: &[f64]) -> f64 {
    x.iter().sum()
}
