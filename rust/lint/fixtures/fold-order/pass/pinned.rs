//@ path: src/linalg/demo.rs
//! Fixture: a float fold in a kernel module with its per-site
//! fold-order annotation.
#![forbid(unsafe_code)]

/// Sums the slice left to right.
pub fn total(x: &[f64]) -> f64 {
    // lint: fold-order-pinned -- sequential left-to-right over one pinned slice
    x.iter().sum()
}
