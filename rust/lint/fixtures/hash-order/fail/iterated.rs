//@ path: src/elm/demo.rs
//! Fixture: iterating a `HashMap` in a deterministic module — visit
//! order is hash-order, which RUSTC_HASH seed changes can move.
#![forbid(unsafe_code)]

use std::collections::HashMap;

/// Sums every value — in whatever order the hash table produces.
pub fn total() -> f64 {
    let mut ext: HashMap<(usize, usize), f64> = HashMap::new();
    ext.insert((0, 0), 1.0);
    ext.insert((0, 1), 2.0);
    let mut acc = 0.0;
    for v in ext.values() {
        acc += v;
    }
    acc
}
