//@ path: src/elm/demo.rs
//! Fixture: a `HashMap` used only through keyed lookup — rule C permits
//! this (visit order never matters when nothing is visited in order).
#![forbid(unsafe_code)]

use std::collections::HashMap;

/// Sums the values at the requested keys, in the order the caller asks.
pub fn gather(keys: &[(usize, usize)]) -> f64 {
    let mut ext: HashMap<(usize, usize), f64> = HashMap::new();
    ext.insert((0, 0), 1.0);
    ext.insert((0, 1), 2.0);
    let mut acc = 0.0;
    for k in keys {
        acc += ext.get(k).copied().unwrap_or(0.0);
    }
    acc
}
