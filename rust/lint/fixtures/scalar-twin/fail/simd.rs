//@ path: src/linalg/simd.rs
//! Fixture: the dispatched kernel has a scalar twin, but the sibling
//! tests/simd_props.rs fixture never references it — the bit-identity
//! of the dispatched path is unpinned.
#![deny(unsafe_op_in_unsafe_fn)]

mod avx2 {
    pub(super) fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }
}

/// Dispatched entry point: routes to the SIMD body when available.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    avx2::axpy(a, x, y);
}

/// Scalar oracle for [`axpy`] — defined but never tested.
pub fn axpy_scalar(a: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}
