//@ path: tests/simd_props.rs
//! Fixture: the conformance suite exists but exercises nothing — no
//! scalar twin is referenced here.

#[test]
fn placeholder() {
    assert_eq!(1 + 1, 2);
}
