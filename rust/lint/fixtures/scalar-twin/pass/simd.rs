//@ path: src/linalg/simd.rs
//! Fixture: a dispatched kernel with its scalar twin defined here and
//! referenced by tests/simd_props.rs (sibling fixture file).
#![deny(unsafe_op_in_unsafe_fn)]

mod avx2 {
    pub(super) fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }
}

/// Dispatched entry point: routes to the SIMD body when available.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    avx2::axpy(a, x, y);
}

/// Scalar oracle and portable fallback for [`axpy`].
pub fn axpy_scalar(a: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}
