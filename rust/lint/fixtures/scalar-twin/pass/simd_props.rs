//@ path: tests/simd_props.rs
//! Fixture: the conformance suite references the scalar twin, pinning
//! dispatched-vs-scalar bit-identity.

#[test]
fn axpy_matches_scalar() {
    let x = [1.0, 2.0, 3.0];
    let mut y = [0.5, 0.5, 0.5];
    let mut y_ref = y;
    kernels::axpy(2.0, &x, &mut y);
    kernels::axpy_scalar(2.0, &x, &mut y_ref);
    assert_eq!(y.map(f64::to_bits), y_ref.map(f64::to_bits));
}
