//@ path: src/coordinator/fleet.rs
//! Fixture: the fleet trainer itself is NOT on the thread allowlist —
//! only the service wrapper's audited drain thread is. A scope here must
//! still be flagged.
#![forbid(unsafe_code)]

/// Drains a batch on an unaudited ad-hoc scope.
pub fn rogue_drain(batch: Vec<u64>) -> Vec<u64> {
    std::thread::scope(|s| {
        s.spawn(move || batch.into_iter().map(|x| x + 1).collect())
            .join()
            .expect("rogue")
    })
}
