//@ path: src/runtime/demo.rs
//! Fixture: ad-hoc thread spawn outside the ParallelPolicy substrate —
//! worker-count bit-invariance is unproven for this path.
#![forbid(unsafe_code)]

/// Spawns a rogue background worker.
pub fn fire_and_forget(x: f64) {
    std::thread::spawn(move || {
        let _ = x * 2.0;
    });
}
