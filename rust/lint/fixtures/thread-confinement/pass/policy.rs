//@ path: src/linalg/policy.rs
//! Fixture: thread scoping inside the ParallelPolicy substrate — one of
//! the four audited files where scheduled fan-out may live.
#![forbid(unsafe_code)]

/// Runs `f` on each chunk from a scoped worker (fixture stand-in for the
/// real policy fan-out).
pub fn fan_out(chunks: &[&[f64]], f: fn(&[f64]) -> f64) -> Vec<f64> {
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks.iter().map(|c| s.spawn(move || f(c))).collect();
        handles.into_iter().map(|h| h.join().expect("worker")).collect()
    })
}
