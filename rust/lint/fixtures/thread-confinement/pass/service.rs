//@ path: src/coordinator/service.rs
//! Fixture: the fleet service's scoped drain thread — the fourth audited
//! scheduler file admitted to the thread-confinement allowlist.
#![forbid(unsafe_code)]

/// Drains a batch on one scoped worker thread (fixture stand-in for the
/// real `FleetService::cycle` dispatch).
pub fn drain_on_worker(batch: Vec<u64>) -> Vec<u64> {
    std::thread::scope(|s| {
        s.spawn(move || batch.into_iter().map(|x| x + 1).collect())
            .join()
            .expect("service drain thread")
    })
}
