//@ path: src/runtime/escape.rs
//! Fixture: `unsafe` outside linalg/simd.rs, and the forbid header is
//! missing — both are rule A findings.

/// Reads through a raw pointer outside the confined module.
pub fn peek(p: *const f64) -> f64 {
    unsafe { *p }
}
