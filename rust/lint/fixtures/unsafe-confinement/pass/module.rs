//@ path: src/runtime/demo.rs
//! Fixture: an ordinary module with the compiler-backed forbid header
//! and no `unsafe` tokens.
#![forbid(unsafe_code)]

/// Doubles the input (safe code only).
pub fn double(x: f64) -> f64 {
    2.0 * x
}
