//@ path: src/linalg/simd.rs
//! Fixture: `unsafe` is permitted here, and the file carries the
//! mandatory deny attribute.
#![deny(unsafe_op_in_unsafe_fn)]

/// Read one element through a raw pointer (fixture stand-in for the
/// intrinsic paths of the real microkernel module).
pub fn peek(p: *const f64) -> f64 {
    unsafe { *p }
}
