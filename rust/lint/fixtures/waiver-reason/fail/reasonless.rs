//@ path: src/runtime/demo.rs
//! Fixture: a waiver without its mandatory `-- <reason>` tail — the
//! waiver itself becomes an unwaivable `waiver-reason` finding.
#![forbid(unsafe_code)]

/// Names a worker thread for a non-deterministic side channel.
pub fn named_worker(x: f64) {
    // lint: allow(thread-confinement)
    let builder = std::thread::Builder::new().name("demo".to_string());
    let _ = builder.spawn(move || {
        let _ = x * 2.0;
    });
}
