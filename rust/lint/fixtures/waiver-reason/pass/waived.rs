//@ path: src/runtime/demo.rs
//! Fixture: a rule D finding waived with the mandatory reason — the
//! finding is recorded as waived, and the tree stays clean.
#![forbid(unsafe_code)]

/// Names a worker thread for a non-deterministic side channel.
pub fn named_worker(x: f64) {
    // lint: allow(thread-confinement) -- fixture: logging thread, off the solve path
    let builder = std::thread::Builder::new().name("demo".to_string());
    let _ = builder.spawn(move || {
        let _ = x * 2.0;
    });
}
