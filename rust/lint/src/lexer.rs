//! Comment/string masking and token utilities — the lexical substrate the
//! rules run on.
//!
//! [`FileView::new`] produces a *masked* copy of the source: every comment
//! and every string/char-literal payload is replaced by spaces (newlines
//! preserved), so the masked text has exactly the raw text's shape but
//! contains only code tokens. Rules pattern-match the masked text — a
//! `thread::spawn` inside a doc comment or a format string can never
//! trigger a finding — while waiver parsing reads the recorded comment
//! spans from the raw text.
//!
//! The lexer understands line comments, nested block comments, plain and
//! raw (`r#"…"#`, `br#"…"#`) string literals, byte strings, and char
//! literals vs lifetimes (`'a'` vs `'a`). It does not expand macros and it
//! does not resolve types — the rules built on top are deliberately
//! lexical and conservative (see the crate docs for the contract).

/// A prepared source file: masked char stream plus line bookkeeping.
pub struct FileView {
    /// Masked text as a char vector (same length/shape as the raw text).
    pub chars: Vec<char>,
    /// Raw text, for waiver/annotation extraction inside comment spans.
    pub raw: Vec<char>,
    /// Char spans (start, end-exclusive) of every comment in the file.
    pub comments: Vec<(usize, usize)>,
    /// Char index where each line starts (line 1 at index 0).
    line_starts: Vec<usize>,
}

/// True for characters that may appear in a Rust identifier.
pub fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

impl FileView {
    /// Lex `text` into a masked view.
    pub fn new(text: &str) -> FileView {
        let raw: Vec<char> = text.chars().collect();
        let (chars, comments) = mask(&raw);
        let mut line_starts = vec![0usize];
        for (i, &c) in raw.iter().enumerate() {
            if c == '\n' {
                line_starts.push(i + 1);
            }
        }
        FileView { chars, raw, comments, line_starts }
    }

    /// 1-based line number of a char position.
    pub fn line_of(&self, pos: usize) -> usize {
        match self.line_starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// All char positions where `needle` occurs in the masked text with
    /// identifier boundaries on both sides.
    pub fn find_word(&self, needle: &str) -> Vec<usize> {
        find_word_in(&self.chars, needle)
    }

    /// All char positions where `needle` occurs in the masked text
    /// (no boundary requirement).
    pub fn find_seq(&self, needle: &str) -> Vec<usize> {
        find_seq_in(&self.chars, needle)
    }

    /// Whether the masked range [lo, hi) contains `needle`.
    pub fn range_contains(&self, lo: usize, hi: usize, needle: &str) -> bool {
        let hi = hi.min(self.chars.len());
        if lo >= hi {
            return false;
        }
        !find_seq_in(&self.chars[lo..hi], needle).is_empty()
    }

    /// First non-whitespace char position at or after `pos` in the masked
    /// text.
    pub fn skip_ws(&self, mut pos: usize) -> usize {
        while pos < self.chars.len() && self.chars[pos].is_whitespace() {
            pos += 1;
        }
        pos
    }

    /// Last non-whitespace char position strictly before `pos`, if any.
    pub fn prev_non_ws(&self, pos: usize) -> Option<usize> {
        let mut i = pos;
        while i > 0 {
            i -= 1;
            if !self.chars[i].is_whitespace() {
                return Some(i);
            }
        }
        None
    }

    /// The identifier ending at `end` (exclusive), if the preceding chars
    /// form one.
    pub fn ident_ending_at(&self, end: usize) -> Option<(usize, String)> {
        let mut start = end;
        while start > 0 && is_ident_char(self.chars[start - 1]) {
            start -= 1;
        }
        if start == end {
            None
        } else {
            Some((start, self.chars[start..end].iter().collect()))
        }
    }

    /// The identifier starting at `pos`, if any.
    pub fn ident_starting_at(&self, pos: usize) -> Option<String> {
        let mut end = pos;
        while end < self.chars.len() && is_ident_char(self.chars[end]) {
            end += 1;
        }
        if end == pos {
            None
        } else {
            Some(self.chars[pos..end].iter().collect())
        }
    }

    /// Matching `}` for the `{` at `open`, by depth counting over the
    /// masked text (strings and comments are already blanked).
    pub fn match_brace(&self, open: usize) -> Option<usize> {
        debug_assert_eq!(self.chars[open], '{');
        let mut depth = 0usize;
        for (off, &c) in self.chars[open..].iter().enumerate() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(open + off);
                    }
                }
                _ => {}
            }
        }
        None
    }
}

/// Word-boundary search over a char slice.
pub fn find_word_in(hay: &[char], needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for pos in find_seq_in(hay, needle) {
        let left_ok = pos == 0 || !is_ident_char(hay[pos - 1]);
        let end = pos + needle.chars().count();
        let right_ok = end >= hay.len() || !is_ident_char(hay[end]);
        if left_ok && right_ok {
            out.push(pos);
        }
    }
    out
}

/// Plain subsequence search over a char slice.
pub fn find_seq_in(hay: &[char], needle: &str) -> Vec<usize> {
    let nd: Vec<char> = needle.chars().collect();
    if nd.is_empty() || hay.len() < nd.len() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for i in 0..=hay.len() - nd.len() {
        if hay[i..i + nd.len()] == nd[..] {
            out.push(i);
        }
    }
    out
}

/// Mask comments and literal payloads: returns the masked chars (same
/// length as the input) plus the comment spans.
fn mask(raw: &[char]) -> (Vec<char>, Vec<(usize, usize)>) {
    let n = raw.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut comments: Vec<(usize, usize)> = Vec::new();
    let mut i = 0usize;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < n {
        let c = raw[i];
        // line comment (covers `///` and `//!` doc comments too)
        if c == '/' && raw.get(i + 1) == Some(&'/') {
            let start = i;
            while i < n && raw[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            comments.push((start, i));
            continue;
        }
        // block comment, nesting per the Rust grammar
        if c == '/' && raw.get(i + 1) == Some(&'*') {
            let start = i;
            let mut depth = 0usize;
            while i < n {
                if raw[i] == '/' && raw.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if raw[i] == '*' && raw.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(blank(raw[i]));
                    i += 1;
                }
            }
            comments.push((start, i));
            continue;
        }
        // raw string (r"…", r#"…"#, br#"…"#) — only when the prefix is not
        // the tail of an identifier
        if (c == 'r' || c == 'b') && !prev_is_ident(raw, i) {
            if let Some(end) = raw_string_end(raw, i) {
                while i < end {
                    out.push(blank(raw[i]));
                    i += 1;
                }
                continue;
            }
        }
        // plain / byte string
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if raw[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(blank(raw[i + 1]));
                    i += 2;
                } else if raw[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(raw[i]));
                    i += 1;
                }
            }
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if raw.get(i + 1) == Some(&'\\') {
                // escaped char literal: consume through the closing quote
                out.push(' ');
                out.push(' ');
                i += 2;
                while i < n && raw[i] != '\'' {
                    out.push(blank(raw[i]));
                    i += 1;
                }
                if i < n {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && raw[i + 2] == '\'' && raw[i + 1] != '\'' {
                // 'x' char literal
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
                continue;
            }
            // lifetime / loop label: keep the quote, keep going
            out.push('\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    (out, comments)
}

fn prev_is_ident(raw: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(raw[i - 1])
}

/// If `raw[i..]` starts a raw (byte) string literal, return the exclusive
/// end position, else None.
fn raw_string_end(raw: &[char], i: usize) -> Option<usize> {
    let n = raw.len();
    let mut j = i;
    if raw[j] == 'b' {
        j += 1;
        if j >= n || raw[j] != 'r' {
            return None;
        }
    }
    if raw[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < n && raw[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || raw[j] != '"' {
        return None;
    }
    j += 1;
    // scan for `"` followed by `hashes` hash marks
    while j < n {
        if raw[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && raw[k] == '#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(n)
}

/// A function item found in the masked text.
pub struct FnSpan {
    /// Function name.
    pub name: String,
    /// Declared exactly `pub` (not `pub(crate)` / `pub(super)`).
    pub is_pub: bool,
    /// Char position of the `fn` keyword.
    pub pos: usize,
    /// Body span (open-brace position, close-brace position), if any.
    pub body: Option<(usize, usize)>,
}

/// Collect every `fn` item in the view (including nested ones).
pub fn fn_spans(view: &FileView) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for pos in view.find_word("fn") {
        let name_start = view.skip_ws(pos + 2);
        let Some(name) = view.ident_starting_at(name_start) else {
            continue; // `fn(…)` pointer type or malformed
        };
        let is_pub = leading_pub(view, pos);
        // body: first `{` before any `;` after the name
        let mut body = None;
        let mut j = name_start + name.chars().count();
        while j < view.chars.len() {
            match view.chars[j] {
                '{' => {
                    body = view.match_brace(j).map(|close| (j, close));
                    break;
                }
                ';' => break,
                _ => j += 1,
            }
        }
        out.push(FnSpan { name, is_pub, pos, body });
    }
    out
}

/// Whether the `fn` at `pos` is preceded by a bare `pub` (skipping the
/// `unsafe` / `const` / `async` qualifiers).
fn leading_pub(view: &FileView, pos: usize) -> bool {
    let mut end = pos;
    loop {
        let Some(last) = view.prev_non_ws(end) else {
            return false;
        };
        let Some((start, word)) = view.ident_ending_at(last + 1) else {
            return false; // `)` of pub(crate), `>`, `;`, `}` …
        };
        match word.as_str() {
            "unsafe" | "const" | "async" => end = start,
            "pub" => return true,
            _ => return false,
        }
    }
}

/// Char spans of `#[cfg(test)] mod … { … }` regions — rules that guard
/// runtime determinism skip findings inside them.
pub fn cfg_test_spans(view: &FileView) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for pos in view.find_seq("#[cfg(test)]") {
        // the `mod` keyword should follow within the next few tokens
        let window_end = (pos + 200).min(view.chars.len());
        let Some(mod_pos) = find_word_in(&view.chars[pos..window_end], "mod").first().copied()
        else {
            continue;
        };
        let mut j = pos + mod_pos;
        while j < view.chars.len() && view.chars[j] != '{' {
            j += 1;
        }
        if j < view.chars.len() {
            if let Some(close) = view.match_brace(j) {
                out.push((pos, close + 1));
            }
        }
    }
    out
}

/// Whether `pos` falls inside any of the (sorted or unsorted) spans.
pub fn in_spans(pos: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(lo, hi)| pos >= lo && pos < hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings_but_keeps_code() {
        let v = FileView::new("let x = \"unsafe\"; // unsafe here\nunsafe {}\n");
        let masked: String = v.chars.iter().collect();
        assert!(!masked[..masked.find('\n').unwrap()].contains("unsafe"));
        assert_eq!(v.find_word("unsafe").len(), 1);
        assert_eq!(v.line_of(v.find_word("unsafe")[0]), 2);
    }

    #[test]
    fn masks_nested_block_comments_and_raw_strings() {
        let v = FileView::new("/* a /* b */ c */ fn f() {}\nlet s = r#\"thread::spawn\"#;\n");
        assert_eq!(v.find_seq("thread::spawn").len(), 0);
        assert_eq!(fn_spans(&v).len(), 1);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let v = FileView::new("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x';\n");
        let spans = fn_spans(&v);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "f");
        assert!(spans[0].body.is_some());
        // the char literal payload is masked: only the param and the
        // return expression remain as `x` tokens
        assert_eq!(v.find_word("x").len(), 2);
    }

    #[test]
    fn pub_detection_distinguishes_scoped_pub() {
        let src = "pub fn a() {}\npub(crate) fn b() {}\npub unsafe fn c() {}\nfn d() {}\n";
        let v = FileView::new(src);
        let spans = fn_spans(&v);
        let pubs: Vec<(&str, bool)> =
            spans.iter().map(|s| (s.name.as_str(), s.is_pub)).collect();
        assert_eq!(pubs, vec![("a", true), ("b", false), ("c", true), ("d", false)]);
    }

    #[test]
    fn cfg_test_span_covers_the_test_module() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n";
        let v = FileView::new(src);
        let spans = cfg_test_spans(&v);
        assert_eq!(spans.len(), 1);
        let t_pos = v.find_word("t")[0];
        assert!(in_spans(t_pos, &spans));
        assert!(!in_spans(v.find_word("live")[0], &spans));
    }
}
