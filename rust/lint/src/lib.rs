//! pallas-lint: static analyzer for the opt-pr-elm determinism contract.
//!
//! The repo's central guarantee — every threaded/SIMD/chunked path is
//! bit-identical to its sequential scalar oracle — rests on conventions
//! the compiler never checks. This crate makes six of them machine
//! checked (see [`rules`] for the rule table) over a masked token stream
//! (see [`lexer`]); it deliberately has **zero dependencies** because the
//! offline build environment cannot resolve crates.io, so no `syn`.
//!
//! Findings can be waived per site with
//! `// lint: allow(<rule>) -- <reason>` on the flagged line or the line
//! directly above it; the reason is mandatory (a reasonless waiver is
//! itself an unwaivable `waiver-reason` finding). Rule E's annotation is
//! `// lint: fold-order-pinned -- <reason>`.
//!
//! The rule semantics are locked by the fixture suite under `fixtures/`,
//! which the Python mirror `ci/pallas_lint.py` must also pass — the
//! fixtures are the sync contract between the two implementations.

#![forbid(unsafe_code)]
#![deny(missing_docs)]

pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::Path;

use rules::{collect_waivers, Prepared, RULE_WAIVER, TWIN_TEST_FILE};

/// One source file handed to the analyzer: a repo-relative path (e.g.
/// `src/linalg/simd.rs`) plus its text. Fixture files carry virtual paths
/// via a `//@ path: …` first-line directive (see [`fixture_sources`]).
pub struct Source {
    /// Path relative to the `rust/` crate root (`src/…` or `tests/…`).
    pub path: String,
    /// Full file contents.
    pub text: String,
}

/// One rule violation (possibly waived).
#[derive(Clone)]
pub struct Finding {
    /// Rule name (one of [`rules::RULES`]).
    pub rule: &'static str,
    /// Path of the offending file, as given in [`Source::path`].
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description of the violation and the contract.
    pub message: String,
    /// Whether a `lint: allow(…)` waiver covers this site.
    pub waived: bool,
    /// The waiver's reason, when waived.
    pub waive_reason: Option<String>,
}

/// Run every rule over `sources` and apply waivers. Rules only fire on
/// `src/…` files; `tests/simd_props.rs` participates solely as the
/// scalar-twin reference corpus for rule B.
pub fn analyze_sources(sources: &[Source]) -> Vec<Finding> {
    let prepared: Vec<Prepared> = sources.iter().map(Prepared::new).collect();
    let twin_tests = prepared.iter().find(|p| p.path.ends_with(TWIN_TEST_FILE));
    let mut findings = Vec::new();
    for p in &prepared {
        if p.rel.is_empty() {
            continue; // non-src file: reference corpus only
        }
        let (waivers, malformed) = collect_waivers(&p.view);
        for (line, message) in malformed {
            findings.push(Finding {
                rule: RULE_WAIVER,
                path: p.path.clone(),
                line,
                message,
                waived: false,
                waive_reason: None,
            });
        }
        let mut file_findings = Vec::new();
        rules::rule_unsafe(p, &mut file_findings);
        rules::rule_twin(p, twin_tests, &mut file_findings);
        rules::rule_hash(p, &mut file_findings);
        rules::rule_thread(p, &mut file_findings);
        rules::rule_fold(p, &waivers, &mut file_findings);
        rules::rule_assert(p, &mut file_findings);
        for f in &mut file_findings {
            if let Some(w) = waivers
                .iter()
                .find(|w| w.rule == f.rule && (w.line == f.line || w.line + 1 == f.line))
            {
                f.waived = true;
                f.waive_reason = w.reason.clone();
            }
        }
        findings.extend(file_findings);
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    findings
}

/// Number of findings not covered by a waiver.
pub fn unwaived_count(findings: &[Finding]) -> usize {
    findings.iter().filter(|f| !f.waived).count()
}

/// Render findings as the stable JSON schema consumed by `ci/check_lint.py`:
/// `{"tool":"pallas-lint","findings":[…],"unwaived":N,"waived":M}`.
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("{\"tool\":\"pallas-lint\",\"findings\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"rule\":");
        out.push_str(&json_str(f.rule));
        out.push_str(",\"path\":");
        out.push_str(&json_str(&f.path));
        out.push_str(",\"line\":");
        out.push_str(&f.line.to_string());
        out.push_str(",\"message\":");
        out.push_str(&json_str(&f.message));
        out.push_str(",\"waived\":");
        out.push_str(if f.waived { "true" } else { "false" });
        out.push_str(",\"reason\":");
        match &f.waive_reason {
            Some(r) => out.push_str(&json_str(r)),
            None => out.push_str("null"),
        }
        out.push('}');
    }
    out.push_str("],\"unwaived\":");
    out.push_str(&unwaived_count(findings).to_string());
    out.push_str(",\"waived\":");
    out.push_str(&(findings.len() - unwaived_count(findings)).to_string());
    out.push_str("}\n");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render findings for terminals: one `path:line: [rule] message` per
/// finding, waived sites suffixed with their reason, then a summary line.
pub fn render_human(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message));
        if f.waived {
            let reason = f.waive_reason.as_deref().unwrap_or("");
            out.push_str(&format!(" (waived: {reason})"));
        }
        out.push('\n');
    }
    let unwaived = unwaived_count(findings);
    out.push_str(&format!(
        "pallas-lint: {} finding(s), {} unwaived, {} waived\n",
        findings.len(),
        unwaived,
        findings.len() - unwaived
    ));
    out
}

/// Load every `.rs` file in `dir` (non-recursive, sorted) as a fixture
/// source. The first line may be a `//@ path: src/…` directive assigning
/// the file a virtual tree path (the directive stays in the text — it is
/// a comment, so the lexer masks it); without one, the file name is used.
pub fn fixture_sources(dir: &Path) -> io::Result<Vec<Source>> {
    let mut paths: Vec<_> = fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|e| e == "rs"))
        .collect();
    paths.sort();
    let mut sources = Vec::new();
    for p in paths {
        let text = fs::read_to_string(&p)?;
        let virt = text
            .lines()
            .next()
            .and_then(|l| l.strip_prefix("//@ path:"))
            .map(|v| v.trim().to_string())
            .unwrap_or_else(|| {
                format!("src/{}", p.file_name().unwrap().to_string_lossy())
            });
        sources.push(Source { path: virt, text });
    }
    Ok(sources)
}

/// Load the real tree: every `.rs` under `<root>/src` (recursive, sorted)
/// plus `<root>/tests/simd_props.rs` when present. `root` may be the
/// `rust/` crate root or its `src/` directory directly.
pub fn tree_sources(root: &Path) -> io::Result<Vec<Source>> {
    let (src_dir, tests_dir) = if root.join("src").is_dir() {
        (root.join("src"), root.join("tests"))
    } else {
        let parent = root.parent().unwrap_or(Path::new(".")).to_path_buf();
        (root.to_path_buf(), parent.join("tests"))
    };
    let mut sources = Vec::new();
    let mut files = Vec::new();
    walk_rs(&src_dir, &mut files)?;
    files.sort();
    for f in files {
        let rel = f.strip_prefix(&src_dir).unwrap_or(&f);
        sources.push(Source {
            path: format!("src/{}", rel.display()),
            text: fs::read_to_string(&f)?,
        });
    }
    let twin = tests_dir.join("simd_props.rs");
    if twin.is_file() {
        sources.push(Source {
            path: TWIN_TEST_FILE.to_string(),
            text: fs::read_to_string(&twin)?,
        });
    }
    Ok(sources)
}

fn walk_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixtures_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures")
    }

    /// Every rule's pass fixture is clean and its fail fixture trips that
    /// exact rule — the executable spec shared with ci/pallas_lint.py.
    #[test]
    fn fixtures_pass_and_fail_as_labelled() {
        let root = fixtures_root();
        let mut rule_dirs: Vec<_> = fs::read_dir(&root)
            .expect("fixtures dir")
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.is_dir())
            .collect();
        rule_dirs.sort();
        assert_eq!(rule_dirs.len(), 7, "one fixture dir per rule + waiver-reason");
        for dir in rule_dirs {
            let rule = dir.file_name().unwrap().to_string_lossy().to_string();
            let pass = analyze_sources(&fixture_sources(&dir.join("pass")).unwrap());
            assert_eq!(
                unwaived_count(&pass),
                0,
                "pass fixture for `{rule}` must be clean, got:\n{}",
                render_human(&pass)
            );
            let fail = analyze_sources(&fixture_sources(&dir.join("fail")).unwrap());
            assert!(
                fail.iter().any(|f| !f.waived && f.rule == rule),
                "fail fixture for `{rule}` must trip it, got:\n{}",
                render_human(&fail)
            );
        }
    }

    /// The waiver pass fixture exercises the waiver path: at least one
    /// finding is present but waived, with its reason carried through.
    #[test]
    fn waiver_pass_fixture_records_reasons() {
        let dir = fixtures_root().join("waiver-reason").join("pass");
        let findings = analyze_sources(&fixture_sources(&dir).unwrap());
        assert_eq!(unwaived_count(&findings), 0);
        let waived: Vec<_> = findings.iter().filter(|f| f.waived).collect();
        assert!(!waived.is_empty(), "waiver pass fixture must contain waived findings");
        assert!(waived.iter().all(|f| f.waive_reason.is_some()));
    }

    /// The acceptance gate: the real tree has zero unwaived findings.
    #[test]
    fn real_tree_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .to_path_buf();
        let findings = analyze_sources(&tree_sources(&root).unwrap());
        assert_eq!(
            unwaived_count(&findings),
            0,
            "tree must be lint-clean:\n{}",
            render_human(&findings)
        );
    }

    #[test]
    fn json_schema_is_stable() {
        let findings = vec![Finding {
            rule: "hash-order",
            path: "src/x.rs".to_string(),
            line: 3,
            message: "m \"q\"".to_string(),
            waived: true,
            waive_reason: Some("r".to_string()),
        }];
        let json = render_json(&findings);
        assert!(json.starts_with("{\"tool\":\"pallas-lint\",\"findings\":["));
        assert!(json.contains("\"rule\":\"hash-order\""));
        assert!(json.contains("\"message\":\"m \\\"q\\\"\""));
        assert!(json.contains("\"unwaived\":0"));
        assert!(json.contains("\"waived\":1"));
    }

    #[test]
    fn reasonless_waiver_is_unwaivable() {
        let src = Source {
            path: "src/linalg/demo.rs".to_string(),
            text: "#![forbid(unsafe_code)]\n\
                   // lint: allow(hash-order)\n\
                   pub fn f() {}\n"
                .to_string(),
        };
        let findings = analyze_sources(&[src]);
        assert!(findings
            .iter()
            .any(|f| f.rule == RULE_WAIVER && !f.waived && f.line == 2));
    }
}
