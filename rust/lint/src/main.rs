//! CLI for pallas-lint.
//!
//! ```text
//! pallas-lint [--json] [--fixture] <path>
//! ```
//!
//! `<path>` is the `rust/` crate root or its `src/` directory (tree
//! mode), or — with `--fixture` — a directory of fixture files carrying
//! `//@ path:` virtual-path directives. Exit codes: 0 clean, 1 unwaived
//! findings, 2 usage/IO error.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{
    analyze_sources, fixture_sources, render_human, render_json, tree_sources, unwaived_count,
};

fn main() -> ExitCode {
    let mut json = false;
    let mut fixture = false;
    let mut path: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--fixture" => fixture = true,
            "--help" | "-h" => {
                eprintln!("usage: pallas-lint [--json] [--fixture] <rust-root-or-src>");
                return ExitCode::from(0);
            }
            a if a.starts_with('-') => {
                eprintln!("pallas-lint: unknown flag `{a}`");
                return ExitCode::from(2);
            }
            a => {
                if path.is_some() {
                    eprintln!("pallas-lint: expected exactly one path argument");
                    return ExitCode::from(2);
                }
                path = Some(PathBuf::from(a));
            }
        }
    }
    let Some(path) = path else {
        eprintln!("usage: pallas-lint [--json] [--fixture] <rust-root-or-src>");
        return ExitCode::from(2);
    };
    let sources = if fixture { fixture_sources(&path) } else { tree_sources(&path) };
    let sources = match sources {
        Ok(s) => s,
        Err(e) => {
            eprintln!("pallas-lint: cannot read `{}`: {e}", path.display());
            return ExitCode::from(2);
        }
    };
    let findings = analyze_sources(&sources);
    if json {
        print!("{}", render_json(&findings));
    } else {
        print!("{}", render_human(&findings));
    }
    if unwaived_count(&findings) == 0 {
        ExitCode::from(0)
    } else {
        ExitCode::from(1)
    }
}
