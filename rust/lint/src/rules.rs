//! The six determinism-contract rules plus the waiver mechanism.
//!
//! Every rule is lexical and conservative: it pattern-matches the masked
//! token stream (comments and literals blanked — see [`crate::lexer`]),
//! never type information. Where a rule cannot prove a site is safe it
//! flags it, and the site either gets fixed or carries a
//! `// lint: allow(<rule>) -- <reason>` waiver with a mandatory reason.
//! The rule table, the contract each rule protects, and the waiver syntax
//! are documented in docs/ARCHITECTURE.md ("Statically-enforced
//! invariants").

use crate::lexer::{cfg_test_spans, fn_spans, in_spans, FileView, FnSpan};
use crate::{Finding, Source};

/// Rule names, as they appear in findings, waivers, and fixture
/// directories.
pub const RULES: [&str; 7] = [
    RULE_UNSAFE,
    RULE_TWIN,
    RULE_HASH,
    RULE_THREAD,
    RULE_FOLD,
    RULE_ASSERT,
    RULE_WAIVER,
];

/// Rule A: `unsafe` confined to `linalg/simd.rs`.
pub const RULE_UNSAFE: &str = "unsafe-confinement";
/// Rule B: every dispatched SIMD kernel has a tested `*_scalar` twin.
pub const RULE_TWIN: &str = "scalar-twin";
/// Rule C: no `HashMap`/`HashSet` iteration in deterministic modules.
pub const RULE_HASH: &str = "hash-order";
/// Rule D: thread spawning confined to the `ParallelPolicy` substrate.
pub const RULE_THREAD: &str = "thread-confinement";
/// Rule E: float folds in kernel modules carry a fold-order annotation.
pub const RULE_FOLD: &str = "fold-order";
/// Rule F: no `debug_assert!` in `pub` kernel entry points.
pub const RULE_ASSERT: &str = "assert-discipline";
/// Meta rule: waivers/annotations must name a known rule and give a
/// reason. Not waivable.
pub const RULE_WAIVER: &str = "waiver-reason";

/// The one file `unsafe` may appear in (path relative to `src/`).
pub const UNSAFE_FILE: &str = "linalg/simd.rs";
/// Files exempt from the `#![forbid(unsafe_code)]` header: the crate root
/// and `linalg/mod.rs` are ancestors of `simd.rs`, and a `forbid` there
/// would cascade onto it (forbid cannot be relaxed down the module tree).
pub const FORBID_EXEMPT: [&str; 2] = ["lib.rs", "linalg/mod.rs"];
/// Files allowed to spawn/scope threads: the `ParallelPolicy` machinery,
/// the TSQR tree, the coordinator pipeline, and the fleet service (whose
/// scoped drain thread is its only threading site — the audit is the
/// async≡sync bit-identity suite in `tests/service_props.rs`).
pub const THREAD_ALLOWED: [&str; 4] = [
    "linalg/policy.rs",
    "linalg/tsqr.rs",
    "coordinator/pipeline.rs",
    "coordinator/service.rs",
];
/// Modules whose results feed deterministic β solves: hash-order scope.
pub const HASH_SCOPE: [&str; 3] = ["coordinator/", "linalg/", "elm/"];
/// Kernel modules: fold-order and assert-discipline scope.
pub const KERNEL_SCOPE: [&str; 2] = ["linalg/", "elm/arch/"];
/// The conformance suite rule B requires scalar twins to be referenced in.
pub const TWIN_TEST_FILE: &str = "tests/simd_props.rs";

/// Map/set iteration methods whose visit order is hash-order dependent.
const HASH_ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// A parsed `// lint: …` control comment.
pub struct Waiver {
    /// Rule being waived, or [`RULE_FOLD`] for `fold-order-pinned`.
    pub rule: String,
    /// Justification text after `--`; `None` when missing (an error).
    pub reason: Option<String>,
    /// 1-based line the comment sits on.
    pub line: usize,
}

/// Parse every `lint:` control comment in the file. Returns
/// `(waivers, malformed)` where malformed entries already carry
/// [`RULE_WAIVER`] findings' metadata (line + message in `reason`).
pub fn collect_waivers(view: &FileView) -> (Vec<Waiver>, Vec<(usize, String)>) {
    let mut waivers = Vec::new();
    let mut malformed = Vec::new();
    for &(lo, hi) in &view.comments {
        let text: String = view.raw[lo..hi].iter().collect();
        let Some(idx) = text.find("lint:") else {
            continue;
        };
        let line = view.line_of(lo);
        let body = text[idx + "lint:".len()..].trim();
        let (rule, rest) = if let Some(stripped) = body.strip_prefix("allow(") {
            let Some(close) = stripped.find(')') else {
                malformed.push((line, "unterminated `lint: allow(…)`".to_string()));
                continue;
            };
            (stripped[..close].trim().to_string(), stripped[close + 1..].trim())
        } else if let Some(stripped) = body.strip_prefix("fold-order-pinned") {
            (RULE_FOLD.to_string(), stripped.trim())
        } else {
            malformed.push((
                line,
                format!("unknown lint control comment `lint: {body}`"),
            ));
            continue;
        };
        if !RULES.contains(&rule.as_str()) || rule == RULE_WAIVER {
            malformed.push((line, format!("waiver names unknown rule `{rule}`")));
            continue;
        }
        let reason = rest.strip_prefix("--").map(|r| r.trim().to_string());
        match reason {
            Some(r) if !r.is_empty() => {
                waivers.push(Waiver { rule, reason: Some(r), line });
            }
            _ => {
                malformed.push((
                    line,
                    format!(
                        "waiver for `{rule}` is missing its mandatory reason \
                         (`-- <why this site is exempt>`)"
                    ),
                ));
            }
        }
    }
    (waivers, malformed)
}

/// A source file prepared for rule evaluation.
pub struct Prepared {
    /// Path as given (e.g. `src/linalg/simd.rs`).
    pub path: String,
    /// Path relative to `src/` (empty for non-src files).
    pub rel: String,
    /// Masked view.
    pub view: FileView,
    /// `#[cfg(test)] mod` spans.
    pub test_spans: Vec<(usize, usize)>,
    /// Function items.
    pub fns: Vec<FnSpan>,
}

impl Prepared {
    /// Prepare a source for analysis.
    pub fn new(src: &Source) -> Prepared {
        let view = FileView::new(&src.text);
        let test_spans = cfg_test_spans(&view);
        let fns = fn_spans(&view);
        let rel = src
            .path
            .strip_prefix("src/")
            .map(str::to_string)
            .unwrap_or_default();
        Prepared { path: src.path.clone(), rel, view, test_spans, fns }
    }

    fn finding(&self, rule: &'static str, pos: usize, message: String) -> Finding {
        Finding {
            rule,
            path: self.path.clone(),
            line: self.view.line_of(pos),
            message,
            waived: false,
            waive_reason: None,
        }
    }

    fn finding_at_line(&self, rule: &'static str, line: usize, message: String) -> Finding {
        Finding {
            rule,
            path: self.path.clone(),
            line,
            message,
            waived: false,
            waive_reason: None,
        }
    }
}

/// Rule A on one file: `unsafe` tokens outside [`UNSAFE_FILE`] are errors;
/// [`UNSAFE_FILE`] must deny `unsafe_op_in_unsafe_fn`; every other file
/// (except [`FORBID_EXEMPT`]) must carry `#![forbid(unsafe_code)]`.
pub fn rule_unsafe(p: &Prepared, out: &mut Vec<Finding>) {
    if p.rel == UNSAFE_FILE {
        if p.view.find_seq("#![deny(unsafe_op_in_unsafe_fn)]").is_empty() {
            out.push(p.finding_at_line(
                RULE_UNSAFE,
                1,
                format!(
                    "{UNSAFE_FILE} must carry `#![deny(unsafe_op_in_unsafe_fn)]` so every \
                     unsafe operation sits in an explicit `unsafe` block"
                ),
            ));
        }
        return;
    }
    for pos in p.view.find_word("unsafe") {
        out.push(p.finding(
            RULE_UNSAFE,
            pos,
            format!(
                "`unsafe` outside {UNSAFE_FILE}: the determinism contract confines all \
                 unsafe code to the SIMD microkernel module"
            ),
        ));
    }
    if !FORBID_EXEMPT.contains(&p.rel.as_str())
        && p.view.find_seq("#![forbid(unsafe_code)]").is_empty()
    {
        out.push(p.finding_at_line(
            RULE_UNSAFE,
            1,
            "missing `#![forbid(unsafe_code)]` module header (compiler-backed rule A)"
                .to_string(),
        ));
    }
}

/// Rule B: in [`UNSAFE_FILE`], every dispatched kernel (a non-test `pub fn`
/// whose body references `avx2::`, or that has a `*_scalar` sibling) must
/// have its scalar twin defined and referenced by [`TWIN_TEST_FILE`].
pub fn rule_twin(p: &Prepared, twin_tests: Option<&Prepared>, out: &mut Vec<Finding>) {
    if p.rel != UNSAFE_FILE {
        return;
    }
    let live: Vec<&FnSpan> = p
        .fns
        .iter()
        .filter(|f| f.is_pub && !in_spans(f.pos, &p.test_spans))
        .collect();
    let names: Vec<&str> = live.iter().map(|f| f.name.as_str()).collect();
    for f in &live {
        if f.name.ends_with("_scalar") {
            continue;
        }
        let twin = format!("{}_scalar", f.name);
        let dispatched = f
            .body
            .map(|(lo, hi)| p.view.range_contains(lo, hi, "avx2::"))
            .unwrap_or(false)
            || names.contains(&twin.as_str());
        if !dispatched {
            continue;
        }
        if !names.contains(&twin.as_str()) {
            out.push(p.finding(
                RULE_TWIN,
                f.pos,
                format!(
                    "dispatched kernel `{}` has no `{twin}` twin: every SIMD kernel needs \
                     a scalar oracle that is also the portable fallback",
                    f.name
                ),
            ));
            continue;
        }
        let referenced = twin_tests
            .map(|t| !t.view.find_word(&twin).is_empty())
            .unwrap_or(false);
        if !referenced {
            out.push(p.finding(
                RULE_TWIN,
                f.pos,
                format!(
                    "scalar twin `{twin}` is never referenced by {TWIN_TEST_FILE}: the \
                     dispatched-vs-scalar bit-identity of `{}` is unpinned",
                    f.name
                ),
            ));
        }
    }
}

/// Rule C: in [`HASH_SCOPE`] modules, iterating a binding declared as
/// `HashMap`/`HashSet` (or built from `HashMap::…`/`HashSet::…`) is an
/// error — iteration order is hash-order. Keyed lookup is fine.
pub fn rule_hash(p: &Prepared, out: &mut Vec<Finding>) {
    if !HASH_SCOPE.iter().any(|s| p.rel.starts_with(s)) {
        return;
    }
    let mut bound: Vec<String> = Vec::new();
    for ty in ["HashMap", "HashSet"] {
        for pos in p.view.find_word(ty) {
            if let Some(name) = hash_binding_name(&p.view, pos) {
                if !bound.contains(&name) {
                    bound.push(name);
                }
            }
        }
    }
    let mut flagged_lines: Vec<usize> = Vec::new();
    for name in &bound {
        for pos in p.view.find_word(name) {
            if in_spans(pos, &p.test_spans) {
                continue;
            }
            let end = pos + name.chars().count();
            let hit = hash_iter_method(&p.view, end).is_some() || for_loop_target(&p.view, pos);
            if !hit {
                continue;
            }
            let line = p.view.line_of(pos);
            if flagged_lines.contains(&line) {
                continue;
            }
            flagged_lines.push(line);
            out.push(p.finding(
                RULE_HASH,
                pos,
                format!(
                    "iteration over hash-ordered `{name}`: visit order is nondeterministic — \
                     use BTreeMap/BTreeSet or sort before iterating (keyed lookup is fine)"
                ),
            ));
        }
    }
}

/// The binding name a `HashMap`/`HashSet` occurrence declares, if any:
/// `name: HashMap<…>` (field/param/let-with-type) or
/// `let name = HashMap::new()` (also `name = HashMap::with_capacity(…)`).
fn hash_binding_name(view: &FileView, mut pos: usize) -> Option<String> {
    // walk back over a `path::to::` prefix
    loop {
        let prev = view.prev_non_ws(pos)?;
        if prev >= 1 && view.chars[prev] == ':' && view.chars[prev - 1] == ':' {
            let (seg_start, _) = view.ident_ending_at(view.prev_non_ws(prev - 1)? + 1)?;
            pos = seg_start;
            continue;
        }
        if view.chars[prev] == ':' {
            // `name : HashMap<…>`
            let last = view.prev_non_ws(prev)?;
            return view.ident_ending_at(last + 1).map(|(_, n)| n);
        }
        if view.chars[prev] == '=' {
            // `name = HashMap::new()` — only when it is a plain `=`
            if view.chars.get(prev.wrapping_sub(1)) == Some(&'=') {
                return None; // `==` comparison
            }
            let last = view.prev_non_ws(prev)?;
            return view.ident_ending_at(last + 1).map(|(_, n)| n);
        }
        return None;
    }
}

/// If the chars after `end` are `.method(` with `method` in
/// [`HASH_ITER_METHODS`], return the method name.
fn hash_iter_method(view: &FileView, end: usize) -> Option<&'static str> {
    let dot = view.skip_ws(end);
    if view.chars.get(dot) != Some(&'.') {
        return None;
    }
    let m_start = view.skip_ws(dot + 1);
    let m = view.ident_starting_at(m_start)?;
    HASH_ITER_METHODS.iter().find(|&&cand| cand == m).copied()
}

/// Whether the identifier at `pos` is the target of a `for … in` loop
/// (walking back over `&`, `mut`, `self`, `.`, and parens to the `in`
/// keyword).
fn for_loop_target(view: &FileView, pos: usize) -> bool {
    let mut end = pos;
    loop {
        let Some(prev) = view.prev_non_ws(end) else {
            return false;
        };
        match view.chars[prev] {
            '&' | '.' | '(' | ')' => {
                end = prev;
                continue;
            }
            _ => {}
        }
        let Some((start, word)) = view.ident_ending_at(prev + 1) else {
            return false;
        };
        match word.as_str() {
            "mut" | "self" => {
                end = start;
                continue;
            }
            "in" => return true,
            _ => return false,
        }
    }
}

/// Rule D: `std::thread` / `thread::spawn` / `thread::scope` /
/// `thread::Builder` outside [`THREAD_ALLOWED`] is an error — all
/// threading must route through the `ParallelPolicy` fixed-schedule
/// machinery.
pub fn rule_thread(p: &Prepared, out: &mut Vec<Finding>) {
    if THREAD_ALLOWED.contains(&p.rel.as_str()) {
        return;
    }
    let mut flagged_lines: Vec<usize> = Vec::new();
    let mut sites: Vec<usize> = p.view.find_seq("std::thread");
    for pat in ["thread::spawn", "thread::scope", "thread::Builder"] {
        for pos in p.view.find_seq(pat) {
            // skip when it is the tail of `std::thread::…` (already found)
            let bounded = pos < 2 || view_char(p, pos - 1) != ':';
            if bounded {
                sites.push(pos);
            }
        }
    }
    sites.sort_unstable();
    for pos in sites {
        let line = p.view.line_of(pos);
        if flagged_lines.contains(&line) {
            continue;
        }
        flagged_lines.push(line);
        out.push(p.finding(
            RULE_THREAD,
            pos,
            "thread spawn/scope outside the ParallelPolicy substrate: worker-count \
             bit-invariance is only proven for the fixed-schedule machinery"
                .to_string(),
        ));
    }
}

fn view_char(p: &Prepared, pos: usize) -> char {
    p.view.chars.get(pos).copied().unwrap_or(' ')
}

/// Rule E: in [`KERNEL_SCOPE`] modules, `.sum()` / `.fold(` sites outside
/// tests must carry a `// lint: fold-order-pinned -- <why>` annotation on
/// the same or the preceding line.
pub fn rule_fold(p: &Prepared, waivers: &[Waiver], out: &mut Vec<Finding>) {
    if !KERNEL_SCOPE.iter().any(|s| p.rel.starts_with(s)) {
        return;
    }
    let mut sites: Vec<usize> = Vec::new();
    for pat in [".sum()", ".sum::<", ".fold("] {
        sites.extend(p.view.find_seq(pat));
    }
    sites.sort_unstable();
    for pos in sites {
        if in_spans(pos, &p.test_spans) {
            continue;
        }
        let line = p.view.line_of(pos);
        let annotated = waivers
            .iter()
            .any(|w| w.rule == RULE_FOLD && (w.line == line || w.line + 1 == line));
        if !annotated {
            out.push(p.finding(
                RULE_FOLD,
                pos,
                "float fold without a `// lint: fold-order-pinned -- <why>` annotation: \
                 reduction order must be pinned (or provably order-free) in kernel modules"
                    .to_string(),
            ));
        }
    }
}

/// Rule F: in [`KERNEL_SCOPE`] modules, `debug_assert!` inside a `pub fn`
/// is an error — public kernel entry points must validate shapes/strides
/// in release builds too (PR 4's contract).
pub fn rule_assert(p: &Prepared, out: &mut Vec<Finding>) {
    if !KERNEL_SCOPE.iter().any(|s| p.rel.starts_with(s)) {
        return;
    }
    let pub_bodies: Vec<(usize, usize)> = p
        .fns
        .iter()
        .filter(|f| f.is_pub && !in_spans(f.pos, &p.test_spans))
        .filter_map(|f| f.body)
        .collect();
    for pos in p.view.find_word("debug_assert")
        .into_iter()
        .chain(p.view.find_word("debug_assert_eq"))
        .chain(p.view.find_word("debug_assert_ne"))
    {
        if in_spans(pos, &p.test_spans) || !in_spans(pos, &pub_bodies) {
            continue;
        }
        out.push(p.finding(
            RULE_ASSERT,
            pos,
            "`debug_assert!` in a pub kernel entry point: promote to `assert!` with a \
             message — release builds must fail loudly on shape/stride violations"
                .to_string(),
        ));
    }
}
