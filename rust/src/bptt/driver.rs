//! The P-BPTT epoch loop: minibatch → `bptt_step` artifact → updated
//! parameter/optimizer state, with wall-clock MSE logging (Fig 5).

#![forbid(unsafe_code)]

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::data::window::Windowed;
use crate::runtime::{Buf, EnginePool, Manifest};

use super::init::{bptt_param_shapes, init_params, BpttArch};

/// One point of the Fig-5 loss curve.
#[derive(Debug, Clone, Copy)]
pub struct LossPoint {
    /// seconds since training started
    pub t_s: f64,
    /// minibatch MSE at that moment
    pub mse: f64,
    pub step: usize,
}

#[derive(Debug, Clone)]
pub struct TrainLog {
    pub points: Vec<LossPoint>,
    pub total_s: f64,
    pub epochs: usize,
    pub steps: usize,
}

/// A trained comparator model.
#[derive(Debug, Clone)]
pub struct BpttModel {
    pub arch: BpttArch,
    pub s: usize,
    pub q: usize,
    pub m: usize,
    pub params: Vec<Vec<f32>>,
}

/// Drives the AOT train-step executable.
pub struct BpttTrainer {
    pool: EnginePool,
    manifest: Manifest,
    pub epochs: usize,
    pub batch: usize,
}

impl BpttTrainer {
    pub fn new(artifacts_dir: &Path) -> Result<BpttTrainer> {
        Ok(BpttTrainer {
            // one engine: the step is inherently sequential (state carry)
            pool: EnginePool::new(artifacts_dir, 1)?,
            manifest: Manifest::load(artifacts_dir)?,
            epochs: 10, // §7.6: "trained for 10 epochs with 64 as batch size"
            batch: 64,
        })
    }

    /// Train on `data`; returns the model and the MSE-vs-time log.
    pub fn train(
        &self,
        arch: BpttArch,
        data: &Windowed,
        m: usize,
        seed: u64,
    ) -> Result<(BpttModel, TrainLog)> {
        let meta = self
            .manifest
            .find("bptt_step", arch.name(), data.q, m)
            .context("selecting bptt_step artifact")?
            .clone();
        if meta.rows != self.batch {
            return Err(anyhow!(
                "bptt_step artifact batch {} != configured batch {}",
                meta.rows,
                self.batch
            ));
        }
        let shapes = bptt_param_shapes(arch, data.s, m);
        let mut params = init_params(arch, data.s, m, seed);
        let mut ms: Vec<Vec<f32>> = params.iter().map(|p| vec![0f32; p.len()]).collect();
        let mut vs = ms.clone();
        let n_params = params.len();

        // warm the executable so compile time is not charged to training
        self.pool.prepare_all(&meta.name)?;

        let n_batches = data.n / self.batch; // drop the ragged tail
        if n_batches == 0 {
            return Err(anyhow!("dataset too small for batch {}", self.batch));
        }
        let sq = data.s * data.q;
        let mut points = Vec::new();
        let t0 = Instant::now();
        let mut step = 0usize;
        for _epoch in 0..self.epochs {
            for b in 0..n_batches {
                step += 1;
                let lo = b * self.batch;
                let hi = lo + self.batch;
                let mut inputs = Vec::with_capacity(3 + 3 * n_params);
                inputs.push(Buf::scalarish(step as f32));
                inputs.push(Buf::new(
                    vec![self.batch, data.s, data.q],
                    data.x[lo * sq..hi * sq].to_vec(),
                ));
                inputs.push(Buf::new(vec![self.batch], data.y[lo..hi].to_vec()));
                for p in &params {
                    inputs.push(Buf::vec(p.clone()));
                }
                for mm in &ms {
                    inputs.push(Buf::vec(mm.clone()));
                }
                for vv in &vs {
                    inputs.push(Buf::vec(vv.clone()));
                }
                // reshape flat bufs to declared ABI dims
                for (buf, spec) in inputs.iter_mut().zip(&meta.inputs) {
                    buf.dims = spec.shape.clone();
                }
                let out = self.pool.run_on(0, &meta.name, inputs)?;
                let loss = out[0].data[0] as f64;
                for (i, p) in params.iter_mut().enumerate() {
                    *p = out[1 + i].data.clone();
                }
                for (i, mm) in ms.iter_mut().enumerate() {
                    *mm = out[1 + n_params + i].data.clone();
                }
                for (i, vv) in vs.iter_mut().enumerate() {
                    *vv = out[1 + 2 * n_params + i].data.clone();
                }
                points.push(LossPoint { t_s: t0.elapsed().as_secs_f64(), mse: loss, step });
            }
        }
        let total_s = t0.elapsed().as_secs_f64();
        let log = TrainLog { points, total_s, epochs: self.epochs, steps: step };
        let model = BpttModel { arch, s: data.s, q: data.q, m, params };
        Ok((model, log))
    }

    /// Batched predictions via the `bptt_predict` artifact (padded tail);
    /// without a matching artifact (offline builds) the batched-GEMM CPU
    /// forward pass computes the same recurrence host-side. Manifest
    /// *errors* (e.g. ambiguous selection) still propagate.
    pub fn predict(&self, model: &BpttModel, data: &Windowed) -> Result<Vec<f64>> {
        let meta = match self
            .manifest
            .find_optional("bptt_predict", model.arch.name(), data.q, model.m)?
        {
            Some(meta) => meta.clone(),
            None => return Ok(super::forward::forward_cpu(model, data)),
        };
        let b = meta.rows;
        let sq = data.s * data.q;
        let mut out = vec![0f64; data.n];
        let mut lo = 0usize;
        while lo < data.n {
            let hi = (lo + b).min(data.n);
            let valid = hi - lo;
            let mut x = vec![0f32; b * sq];
            x[..valid * sq].copy_from_slice(&data.x[lo * sq..hi * sq]);
            let mut inputs = vec![Buf::new(vec![b, data.s, data.q], x)];
            for (p, spec) in model.params.iter().zip(&meta.inputs[1..]) {
                inputs.push(Buf::new(spec.shape.clone(), p.clone()));
            }
            let res = self.pool.run_on(0, &meta.name, inputs)?;
            for r in 0..valid {
                out[lo + r] = res[0].data[r] as f64;
            }
            lo = hi;
        }
        Ok(out)
    }

    /// Test MSE through the predict path.
    pub fn mse(&self, model: &BpttModel, data: &Windowed) -> Result<f64> {
        let pred = self.predict(model, data)?;
        let truth: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        Ok(crate::data::stats::mse(&pred, &truth))
    }
}
