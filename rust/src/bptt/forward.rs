//! Batched CPU forward pass for the BPTT comparator models.
//!
//! Semantics mirror `python/compile/bptt.py::_forward` exactly (full —
//! not diagonal — cells; gate orders fc: none, lstm: [i, f, g, o],
//! gru: [z, r, n]). The per-step work is fused into batched GEMMs through
//! the tiled [`Matrix::matmul`], Appleyard-style:
//!
//! * the input projections of *all* timesteps are one (B·Q, S)×(S, G·M)
//!   GEMM up front (`x @ wx`),
//! * each timestep is then one (B, M)×(M, G·M) GEMM for the recurrent
//!   term (`h @ wh`) plus elementwise gate math over the batch.
//!
//! This is the artifact-free predict path: `BpttTrainer::predict` falls
//! back to it when no `bptt_predict` executable is in the manifest (e.g.
//! offline builds), and it doubles as the CPU oracle for the AOT graph.

#![forbid(unsafe_code)]

use crate::data::window::Windowed;
use crate::elm::activation::{sigmoid, tanh};
use crate::elm::arch::block_ranges;
use crate::linalg::{Matrix, MatrixF32, ParallelPolicy, Precision};

use super::driver::BpttModel;
use super::init::BpttArch;

/// Rows per forward chunk (bounds the lifted-projection buffer).
const CHUNK: usize = 256;

/// One-step-ahead predictions for every row of `data` (f64 recurrent
/// wire — see [`forward_cpu_with`] for the mixed-precision variant).
pub fn forward_cpu(model: &BpttModel, data: &Windowed) -> Vec<f64> {
    forward_cpu_with(model, data, Precision::F64)
}

/// One-step-ahead predictions with an explicit wire precision.
///
/// The lifted input projection `x @ wx` always runs on the f32 wire
/// (both operands are f32 parameters/data, so the widen GEMM is
/// bit-identical to the f64 one — see the `linalg::matrix32` contract).
/// `precision` selects the wire the hidden state *lives on*:
///
/// * [`Precision::F64`] — the reference; `h` stays f64 end to end.
/// * [`Precision::MixedF32`] — `h` is **f32-born**: the state matrix is
///   `MatrixF32`, gate outputs are stored into it directly, and the
///   per-step recurrent GEMM `h @ wh` reads it through `matmul_widen` —
///   no per-step f64 H materialization or rounding pass (the old wire
///   rounded a fresh f64 `h` every step). For the FC and GRU cells the
///   hidden state is exactly f32-representable (FC: a tanh of an f32;
///   GRU: an all-f32 gate update), so those paths are **bit-identical**
///   to the f64 wire. Only LSTM differs: its cell state `c` is carried in
///   f64 (`fg·c + ig·gg` products), so `h = o·tanh(c)` rounds once at the
///   f32 store — tests bound the output difference at 1e-4 on unit-scale
///   data.
pub fn forward_cpu_with(model: &BpttModel, data: &Windowed, precision: Precision) -> Vec<f64> {
    let mut out = Vec::with_capacity(data.n);
    for (lo, hi) in block_ranges(data.n, CHUNK) {
        forward_chunk(model, data, lo, hi, precision, &mut out);
    }
    out
}

fn forward_chunk(
    model: &BpttModel,
    data: &Windowed,
    lo: usize,
    hi: usize,
    precision: Precision,
    out: &mut Vec<f64>,
) {
    let (s, q, m) = (model.s, model.q, model.m);
    let g = model.arch.gates();
    let gm = g * m;
    let b_rows = hi - lo;
    let seq = ParallelPolicy::sequential();
    let wx = MatrixF32::from_slice(s, gm, &model.params[0]);
    // only the selected wire's wh representation is materialized
    enum RecurrentW {
        F64(Matrix),
        Mixed(MatrixF32),
    }
    let wh = match precision {
        Precision::F64 => RecurrentW::F64(Matrix::from_f32(m, gm, &model.params[1])),
        Precision::MixedF32 => {
            RecurrentW::Mixed(MatrixF32::from_slice(m, gm, &model.params[1]))
        }
    };
    // the hidden state lives on the selected wire: f32-born under
    // MixedF32 (get/set are exact for the all-f32 FC/GRU updates; LSTM's
    // f64 `o·tanh(c)` rounds once at the store, replacing the old
    // per-step from_matrix rounding of a whole f64 state matrix)
    enum HState {
        F64(Matrix),
        F32(MatrixF32),
    }
    impl HState {
        #[inline]
        fn get(&self, i: usize, j: usize) -> f64 {
            match self {
                HState::F64(h) => h[(i, j)],
                HState::F32(h) => h[(i, j)] as f64,
            }
        }
        #[inline]
        fn set(&mut self, i: usize, j: usize, v: f64) {
            match self {
                HState::F64(h) => h[(i, j)] = v,
                HState::F32(h) => h[(i, j)] = v as f32,
            }
        }
    }
    let bias = &model.params[2];
    let wo = &model.params[3];
    let bo = model.params[4][0] as f64;

    // lift every timestep's input projection into one GEMM on the f32
    // wire: (B·Q, S) @ (S, G·M), bit-identical to the f64 GEMM (f32
    // sources, exact products)
    let mut xb = MatrixF32::zeros(b_rows * q, s);
    for i in 0..b_rows {
        let xi = data.x_row(lo + i);
        for si in 0..s {
            for t in 0..q {
                xb[(i * q + t, si)] = xi[si * q + t];
            }
        }
    }
    let zx_all = xb.matmul_widen(&wx, seq); // (B·Q, G·M)

    let mut h = match precision {
        Precision::F64 => HState::F64(Matrix::zeros(b_rows, m)),
        Precision::MixedF32 => HState::F32(MatrixF32::zeros(b_rows, m)),
    };
    let mut c = Matrix::zeros(b_rows, m); // lstm cell state (unused otherwise)
    for t in 0..q {
        // (B, G·M): the per-step batched GEMM on the state's own wire —
        // the f32-born state feeds matmul_widen directly
        let zh = match (&h, &wh) {
            (HState::F64(h), RecurrentW::F64(w)) => h.matmul(w),
            (HState::F32(h32), RecurrentW::Mixed(w)) => h32.matmul_widen(w, seq),
            _ => unreachable!("state and weight wires are selected together"),
        };
        for i in 0..b_rows {
            let zx = zx_all.row(i * q + t);
            let zh_row = zh.row(i);
            match model.arch {
                BpttArch::Fc => {
                    for j in 0..m {
                        let pre = (zx[j] + zh_row[j]) as f32 + bias[j];
                        h.set(i, j, tanh(pre) as f64);
                    }
                }
                BpttArch::Lstm => {
                    for j in 0..m {
                        let z = |gi: usize| {
                            (zx[gi * m + j] + zh_row[gi * m + j]) as f32 + bias[gi * m + j]
                        };
                        let ig = sigmoid(z(0));
                        let fg = sigmoid(z(1));
                        let gg = tanh(z(2));
                        let og = sigmoid(z(3));
                        let cn = fg as f64 * c[(i, j)] + (ig * gg) as f64;
                        c[(i, j)] = cn;
                        h.set(i, j, og as f64 * (cn as f32).tanh() as f64);
                    }
                }
                BpttArch::Gru => {
                    for j in 0..m {
                        // python keeps zx (with bias) and zh separate: the
                        // candidate gate multiplies zh by r before adding
                        let zxg = |gi: usize| zx[gi * m + j] as f32 + bias[gi * m + j];
                        let zhg = |gi: usize| zh_row[gi * m + j] as f32;
                        let zg = sigmoid(zxg(0) + zhg(0));
                        let rg = sigmoid(zxg(1) + zhg(1));
                        let ng = tanh(zxg(2) + rg * zhg(2));
                        let prev = h.get(i, j) as f32;
                        h.set(i, j, ((1.0 - zg) * prev + zg * ng) as f64);
                    }
                }
            }
        }
    }
    for i in 0..b_rows {
        let mut yhat = bo;
        for j in 0..m {
            yhat += h.get(i, j) * wo[j] as f64;
        }
        out.push(yhat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bptt::init::{bptt_param_shapes, init_params};
    use crate::util::rng::Rng;

    fn toy(n: usize, q: usize, seed: u64) -> Windowed {
        let mut rng = Rng::new(seed);
        let series: Vec<f64> = (0..n + q).map(|_| rng.range(0.0, 1.0)).collect();
        Windowed::from_series(&series, q).unwrap()
    }

    fn model(arch: BpttArch, s: usize, q: usize, m: usize, seed: u64) -> BpttModel {
        BpttModel { arch, s, q, m, params: init_params(arch, s, m, seed) }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let w = toy(300, 6, 1);
        for arch in [BpttArch::Fc, BpttArch::Lstm, BpttArch::Gru] {
            let mdl = model(arch, w.s, w.q, 8, 2);
            let y = forward_cpu(&mdl, &w);
            assert_eq!(y.len(), w.n);
            assert!(y.iter().all(|v| v.is_finite()), "{}", arch.name());
        }
    }

    #[test]
    fn fc_zero_recurrence_is_closed_form() {
        // wh = 0 ⇒ h(Q) = tanh(x_{Q-1} @ wx + b); with zero bias init the
        // prediction is wo · tanh(x_last · wx)
        let (s, q, m) = (1usize, 4usize, 3usize);
        let w = toy(50, q, 3);
        let mut mdl = model(BpttArch::Fc, s, q, m, 4);
        mdl.params[1].iter_mut().for_each(|v| *v = 0.0); // wh
        let y = forward_cpu(&mdl, &w);
        let wx = &mdl.params[0];
        let wo = &mdl.params[3];
        for i in 0..w.n {
            let xl = w.x_row(i)[q - 1];
            let mut want = mdl.params[4][0] as f64;
            for j in 0..m {
                want += ((xl * wx[j]).tanh() * wo[j]) as f64;
            }
            assert!((y[i] - want).abs() < 1e-5, "row {i}: {} vs {want}", y[i]);
        }
    }

    #[test]
    fn chunking_does_not_change_results() {
        // n > CHUNK exercises the chunk seam; the recurrence is per-sample,
        // so rows on BOTH sides of the boundary must match a single-row
        // recomputation bit for bit (catches state leaking across chunks)
        let w = toy(CHUNK + 37, 5, 5);
        let mdl = model(BpttArch::Gru, w.s, w.q, 6, 6);
        let full = forward_cpu(&mdl, &w);
        for i in [0usize, 10, CHUNK - 1, CHUNK, CHUNK + 5, CHUNK + 36] {
            let one = forward_cpu(&mdl, &w.slice(i, i + 1));
            assert_eq!(full[i], one[0], "row {i}");
        }
    }

    // the mixed-wire contract (FC/GRU bit-identical, LSTM bounded) is
    // pinned by the integration suite: tests/mixed_precision_props.rs

    #[test]
    fn param_shapes_consistent_with_forward() {
        for arch in [BpttArch::Fc, BpttArch::Lstm, BpttArch::Gru] {
            let shapes = bptt_param_shapes(arch, 2, 5);
            let params = init_params(arch, 2, 5, 1);
            for ((_, shape), buf) in shapes.iter().zip(&params) {
                assert_eq!(shape.iter().product::<usize>(), buf.len());
            }
        }
    }
}
