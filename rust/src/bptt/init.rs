//! BPTT parameter initialization — shapes mirror
//! `python/compile/bptt.py::param_shapes` (the artifact ABI).

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// The three architectures the paper's §7.6 comparison covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BpttArch {
    Fc,
    Lstm,
    Gru,
}

impl BpttArch {
    pub fn name(&self) -> &'static str {
        match self {
            BpttArch::Fc => "fc",
            BpttArch::Lstm => "lstm",
            BpttArch::Gru => "gru",
        }
    }

    pub fn parse(s: &str) -> Result<BpttArch> {
        Ok(match s {
            "fc" => BpttArch::Fc,
            "lstm" => BpttArch::Lstm,
            "gru" => BpttArch::Gru,
            other => bail!("P-BPTT covers fc/lstm/gru, not {other:?}"),
        })
    }

    pub fn gates(&self) -> usize {
        match self {
            BpttArch::Fc => 1,
            BpttArch::Lstm => 4,
            BpttArch::Gru => 3,
        }
    }
}

/// (name, shape) in ABI order: wx, wh, b, wo, bo.
pub fn bptt_param_shapes(arch: BpttArch, s: usize, m: usize) -> Vec<(&'static str, Vec<usize>)> {
    let g = arch.gates();
    vec![
        ("wx", vec![s, g * m]),
        ("wh", vec![m, g * m]),
        ("b", vec![g * m]),
        ("wo", vec![m]),
        ("bo", vec![1]),
    ]
}

/// Glorot-ish initialization (matches what TF's defaults would roughly do).
pub fn init_params(arch: BpttArch, s: usize, m: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    bptt_param_shapes(arch, s, m)
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let fan_in = shape.first().copied().unwrap_or(1).max(1) as f64;
            let scale = match *name {
                "b" | "bo" => 0.0,
                _ => (1.0 / fan_in).sqrt(),
            };
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_python_abi() {
        let shapes = bptt_param_shapes(BpttArch::Lstm, 1, 10);
        assert_eq!(shapes[0], ("wx", vec![1, 40]));
        assert_eq!(shapes[1], ("wh", vec![10, 40]));
        assert_eq!(shapes[2], ("b", vec![40]));
        assert_eq!(shapes[3], ("wo", vec![10]));
        assert_eq!(shapes[4], ("bo", vec![1]));
    }

    #[test]
    fn init_deterministic_biases_zero() {
        let a = init_params(BpttArch::Gru, 1, 8, 5);
        let b = init_params(BpttArch::Gru, 1, 8, 5);
        assert_eq!(a, b);
        assert!(a[2].iter().all(|&v| v == 0.0), "b starts at zero");
        assert!(a[4].iter().all(|&v| v == 0.0), "bo starts at zero");
        assert!(a[0].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn parse_rejects_elm_only_archs() {
        assert!(BpttArch::parse("elman").is_err());
        assert_eq!(BpttArch::parse("lstm").unwrap(), BpttArch::Lstm);
    }
}
