//! P-BPTT comparator (§7.6, Table 6, Fig 5): iterative Adam training of
//! standard full FC-RNN / LSTM / GRU cells, driven from rust.
//!
//! The fwd/bwd/Adam train step is a single AOT HLO executable per
//! architecture (`bptt_step_*`, lowered by `python/compile/bptt.py` with
//! `jax.value_and_grad`); this module owns the epoch loop, minibatching,
//! parameter state, and the MSE-vs-wallclock log the paper plots in Fig 5.
//! Matching the paper's setup: 10 epochs, batch 64, MSE loss, Adam.

#![forbid(unsafe_code)]

pub mod driver;
pub mod forward;
pub mod init;

pub use driver::{BpttModel, BpttTrainer, LossPoint, TrainLog};
pub use forward::{forward_cpu, forward_cpu_with};
pub use init::{bptt_param_shapes, init_params, BpttArch};
