//! Normal-equation / TSQR accumulation of per-block partials.
//!
//! Three solve strategies, selectable per job:
//!
//! * `Gram` — fold the (HᵀH, HᵀY) partials the `elm_gram` artifacts emit
//!   (f32 on the wire, widened to f64 on accumulation), solve the ridge
//!   system by Cholesky. One artifact execution per block; O(M²) traffic.
//! * `Tsqr` — fold raw H blocks (`elm_h` artifacts) into the
//!   communication-avoiding QR accumulator. Exact least squares (no
//!   condition-number squaring); O(R·M) traffic per block.
//! * `DirectQr` — assemble the full H in block order and run the threaded
//!   blocked Householder QR (`lstsq_qr_with`). O(N·M) memory — the only
//!   non-streaming strategy — but **bit-identical to the sequential
//!   `lstsq_qr` path** at any worker count: the conformance anchor the
//!   architecture-sweep e2e suite pins all six architectures to.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::linalg::{Matrix, TsqrAccumulator};
use crate::robust::ladder::ridge_ladder_solve;
use crate::robust::{SolveError, SolveReport, SolveStrategyKind};

/// Which β-solve pipeline a trainer runs (see the module docs for the
/// trade-offs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveStrategy {
    /// Fold (HᵀH, HᵀY) partials, ridge-solve by Cholesky. In
    /// `CpuElmTrainer` this pipeline honors the `Precision` knob (f32
    /// wire) — as do the NARMAX passes and the other strategies'
    /// rank-deficiency fallbacks, which all route through it.
    Gram,
    /// Fold raw H blocks into the communication-avoiding TSQR accumulator
    /// (exact least squares).
    Tsqr,
    /// Assemble H and run the threaded blocked QR — bit-identical to the
    /// sequential `lstsq_qr` (the e2e conformance anchor).
    DirectQr,
}

/// Streaming (HᵀH, HᵀY) accumulator (f64).
pub struct GramAccumulator {
    m: usize,
    g: Matrix,
    c: Vec<f64>,
    rows: usize,
    lambda: f64,
}

impl GramAccumulator {
    /// Empty M-wide accumulator with ridge λ.
    pub fn new(m: usize, lambda: f64) -> GramAccumulator {
        GramAccumulator { m, g: Matrix::zeros(m, m), c: vec![0.0; m], rows: 0, lambda }
    }

    /// Fold one block's partial sums (row-major M×M and length-M, f32).
    pub fn push_partials(&mut self, hth: &[f32], hty: &[f32], valid_rows: usize) -> Result<()> {
        if hth.len() != self.m * self.m || hty.len() != self.m {
            bail!(
                "partial shapes ({}, {}) do not match M = {}",
                hth.len(),
                hty.len(),
                self.m
            );
        }
        for a in 0..self.m {
            for b in 0..self.m {
                self.g[(a, b)] += hth[a * self.m + b] as f64;
            }
        }
        for (cj, &v) in self.c.iter_mut().zip(hty) {
            *cj += v as f64;
        }
        self.rows += valid_rows;
        Ok(())
    }

    /// Total valid rows folded in so far.
    pub fn rows_seen(&self) -> usize {
        self.rows
    }

    /// Solve (G + λI)β = c. The partials arrive as f32 sums, so a nearly
    /// singular G can be numerically indefinite; [`Self::solve_reported`]
    /// climbs the degradation ladder until a rung yields a finite β.
    pub fn solve(&self) -> Result<Vec<f64>> {
        self.solve_reported().map(|(beta, _)| beta)
    }

    /// [`Self::solve`] returning the [`SolveReport`] alongside β: the base
    /// λ is rung 0 (`primary`), and the escalation rungs come from the
    /// uniform [`RIDGE_LADDER`](crate::robust::RIDGE_LADDER) — for the
    /// default λ = 1e-6 those are the same 100× steps (1e-4, 1e-2) the
    /// accumulator always escalated through, so recovery behavior (and
    /// every recovered β bit) is unchanged; what's new is the report and
    /// the finiteness gate on every rung.
    pub fn solve_reported(&self) -> Result<(Vec<f64>, SolveReport)> {
        let mut report = SolveReport::new(SolveStrategyKind::Gram);
        if self.rows < self.m {
            return Err(
                SolveError::Underdetermined { rows: self.rows, cols: self.m }.into()
            );
        }
        let beta = ridge_ladder_solve(&self.g, &self.c, self.lambda, true, &mut report)?;
        Ok((beta, report))
    }

    /// Merge a peer accumulator (tree reduction).
    pub fn merge(&mut self, other: &GramAccumulator) -> Result<()> {
        if other.m != self.m {
            bail!("accumulator width mismatch");
        }
        for a in 0..self.m {
            for b in 0..self.m {
                self.g[(a, b)] += other.g[(a, b)];
            }
        }
        for (cj, v) in self.c.iter_mut().zip(&other.c) {
            *cj += v;
        }
        self.rows += other.rows;
        Ok(())
    }
}

/// Unified accumulator over both streaming strategies.
pub enum BetaAccumulator {
    /// Normal-equation folding (ridge Cholesky solve).
    Gram(GramAccumulator),
    /// Communication-avoiding QR folding (exact least squares).
    Tsqr(TsqrAccumulator),
}

impl BetaAccumulator {
    /// Accumulator for a streaming strategy; panics on `DirectQr` (not a
    /// streaming strategy — see the variant docs).
    pub fn new(strategy: SolveStrategy, m: usize) -> BetaAccumulator {
        match strategy {
            SolveStrategy::Gram => BetaAccumulator::Gram(GramAccumulator::new(m, 1e-8)),
            SolveStrategy::Tsqr => BetaAccumulator::Tsqr(TsqrAccumulator::new(m)),
            // refuse rather than silently substitute TSQR bits: DirectQr's
            // whole contract is bit-equality with the sequential lstsq_qr,
            // which no streaming accumulator can honor
            SolveStrategy::DirectQr => panic!(
                "DirectQr is not a streaming strategy; use CpuElmTrainer, which \
                 assembles H and runs the threaded lstsq_qr_with"
            ),
        }
    }

    /// Solve for β through whichever strategy this accumulator wraps.
    pub fn solve(&self) -> Result<Vec<f64>> {
        match self {
            BetaAccumulator::Gram(g) => g.solve(),
            BetaAccumulator::Tsqr(t) => t.solve(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_h_y(n: usize, m: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let h: Vec<f32> = (0..n * m).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (h, y)
    }

    fn partials(h: &[f32], y: &[f32], m: usize) -> (Vec<f32>, Vec<f32>) {
        let n = y.len();
        let mut hth = vec![0f32; m * m];
        let mut hty = vec![0f32; m];
        for i in 0..n {
            for a in 0..m {
                for b in 0..m {
                    hth[a * m + b] += h[i * m + a] * h[i * m + b];
                }
                hty[a] += h[i * m + a] * y[i];
            }
        }
        (hth, hty)
    }

    #[test]
    fn streaming_equals_batch() {
        let (n, m) = (120, 6);
        let (h, y) = random_h_y(n, m, 1);
        // batch
        let mut batch = GramAccumulator::new(m, 1e-10);
        let (hth, hty) = partials(&h, &y, m);
        batch.push_partials(&hth, &hty, n).unwrap();
        // streamed in 5 blocks
        let mut stream = GramAccumulator::new(m, 1e-10);
        for c in 0..5 {
            let lo = c * 24;
            let hi = lo + 24;
            let (p, q) = partials(&h[lo * m..hi * m], &y[lo..hi], m);
            stream.push_partials(&p, &q, 24).unwrap();
        }
        let a = batch.solve().unwrap();
        let b = stream.solve().unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn merge_equals_single() {
        let (n, m) = (90, 4);
        let (h, y) = random_h_y(n, m, 2);
        let mut all = GramAccumulator::new(m, 1e-10);
        let (p, q) = partials(&h, &y, m);
        all.push_partials(&p, &q, n).unwrap();

        let mut w1 = GramAccumulator::new(m, 1e-10);
        let mut w2 = GramAccumulator::new(m, 1e-10);
        let (p1, q1) = partials(&h[..45 * m], &y[..45], m);
        let (p2, q2) = partials(&h[45 * m..], &y[45..], m);
        w1.push_partials(&p1, &q1, 45).unwrap();
        w2.push_partials(&p2, &q2, 45).unwrap();
        w1.merge(&w2).unwrap();
        assert_eq!(w1.rows_seen(), n);
        let a = all.solve().unwrap();
        let b = w1.solve().unwrap();
        for (x, y) in a.iter().zip(&b) {
            // the f32 *test helper* sums 90 terms in one pass vs 45+45:
            // rounding differs by design; the accumulator itself is f64
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn underdetermined_rejected() {
        let m = 8;
        let acc = GramAccumulator::new(m, 1e-8);
        let err = acc.solve().unwrap_err();
        assert_eq!(
            *crate::robust::as_solve_error(&err).expect("typed error"),
            crate::robust::SolveError::Underdetermined { rows: 0, cols: 8 }
        );
    }

    #[test]
    fn solve_reported_healthy_is_primary_and_bit_equal() {
        use crate::robust::DegradationRung;
        let (n, m) = (120, 6);
        let (h, y) = random_h_y(n, m, 7);
        let mut acc = GramAccumulator::new(m, 1e-10);
        let (p, q) = partials(&h, &y, m);
        acc.push_partials(&p, &q, n).unwrap();
        let (beta, report) = acc.solve_reported().unwrap();
        assert_eq!(report.strategy, SolveStrategyKind::Gram);
        assert_eq!(report.rung, DegradationRung::Primary);
        assert_eq!(report.effective_lambda, 1e-10);
        // the plain solve() is the same call minus the report
        assert_eq!(beta, acc.solve().unwrap());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut acc = GramAccumulator::new(4, 1e-8);
        assert!(acc.push_partials(&[0.0; 9], &[0.0; 4], 3).is_err());
        assert!(acc.push_partials(&[0.0; 16], &[0.0; 3], 3).is_err());
    }

    #[test]
    fn gram_and_tsqr_agree() {
        // identical data through both strategies
        let (n, m) = (200, 5);
        let (h, y) = random_h_y(n, m, 3);
        let mut gram = GramAccumulator::new(m, 1e-12);
        let (p, q) = partials(&h, &y, m);
        gram.push_partials(&p, &q, n).unwrap();

        let mut tsqr = TsqrAccumulator::new(m);
        let hmat = Matrix::from_f32(n, m, &h);
        let yv: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        tsqr.push_block(hmat, &yv).unwrap();

        let a = gram.solve().unwrap();
        let b = tsqr.solve().unwrap();
        for (x, z) in a.iter().zip(&b) {
            assert!((x - z).abs() < 1e-3, "{x} vs {z}");
        }
    }
}
