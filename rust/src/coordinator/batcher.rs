//! Row-block batching: dataset → fixed-shape blocks + validity masks.
//!
//! HLO executables have static shapes; the coordinator streams any dataset
//! length through blocks of `rows` samples. The tail block is zero-padded
//! and its padded rows masked out (the `elm_gram` graph multiplies rows by
//! the mask before accumulating, so padding contributes exactly zero).

#![forbid(unsafe_code)]

use crate::data::window::Windowed;

/// One fixed-shape block in artifact layout.
#[derive(Debug, Clone)]
pub struct Block {
    /// (rows, s, q) row-major
    pub x: Vec<f32>,
    /// (rows, q)
    pub yhist: Vec<f32>,
    /// (rows,)
    pub y: Vec<f32>,
    /// (rows,) 1.0 = real row, 0.0 = padding
    pub mask: Vec<f32>,
    /// number of real rows (== mask.sum())
    pub valid: usize,
    /// index of the first real row within the source dataset
    pub offset: usize,
}

impl Block {
    /// True when any value of any real row (x window, y history, target)
    /// is NaN/Inf — the cheap screen before [`Block::quarantine_non_finite`].
    pub fn has_non_finite(&self) -> bool {
        let rows = self.mask.len();
        let (sq, q) = (self.x.len() / rows, self.yhist.len() / rows);
        (0..rows).any(|r| {
            self.mask[r] != 0.0
                && (!self.y[r].is_finite()
                    || self.x[r * sq..(r + 1) * sq].iter().any(|v| !v.is_finite())
                    || self.yhist[r * q..(r + 1) * q].iter().any(|v| !v.is_finite()))
        })
    }

    /// Quarantine poisoned rows in place: any row whose x window, y
    /// history, or target is non-finite is zeroed and masked out — so the
    /// `elm_gram` graph (which multiplies rows by the mask before
    /// accumulating) sees it contribute exactly zero — and `valid` drops
    /// by the quarantined count (preserving the `valid == mask.sum()`
    /// invariant). Returns how many rows were quarantined.
    ///
    /// Note: after quarantine the real rows are no longer necessarily a
    /// contiguous prefix; the Gram path only uses `valid` as a row *count*,
    /// which stays correct.
    pub fn quarantine_non_finite(&mut self) -> usize {
        let rows = self.mask.len();
        let (sq, q) = (self.x.len() / rows, self.yhist.len() / rows);
        let mut dropped = 0usize;
        for r in 0..rows {
            if self.mask[r] == 0.0 {
                continue;
            }
            let bad = !self.y[r].is_finite()
                || self.x[r * sq..(r + 1) * sq].iter().any(|v| !v.is_finite())
                || self.yhist[r * q..(r + 1) * q].iter().any(|v| !v.is_finite());
            if bad {
                self.x[r * sq..(r + 1) * sq].fill(0.0);
                self.yhist[r * q..(r + 1) * q].fill(0.0);
                self.y[r] = 0.0;
                self.mask[r] = 0.0;
                dropped += 1;
            }
        }
        self.valid -= dropped;
        dropped
    }
}

/// Iterator of fixed-shape blocks over a windowed dataset.
pub struct RowBlockBatcher<'a> {
    data: &'a Windowed,
    rows: usize,
    pos: usize,
}

impl<'a> RowBlockBatcher<'a> {
    /// Batcher over `data` in fixed `rows`-high blocks (rows > 0).
    pub fn new(data: &'a Windowed, rows: usize) -> RowBlockBatcher<'a> {
        assert!(rows > 0);
        RowBlockBatcher { data, rows, pos: 0 }
    }

    /// Number of blocks the iteration will yield (tail included).
    pub fn n_blocks(&self) -> usize {
        self.data.n.div_ceil(self.rows)
    }
}

impl<'a> Iterator for RowBlockBatcher<'a> {
    type Item = Block;

    fn next(&mut self) -> Option<Block> {
        if self.pos >= self.data.n {
            return None;
        }
        let lo = self.pos;
        let hi = (lo + self.rows).min(self.data.n);
        let valid = hi - lo;
        let (s, q, rows) = (self.data.s, self.data.q, self.rows);

        let mut x = vec![0f32; rows * s * q];
        let mut yhist = vec![0f32; rows * q];
        let mut y = vec![0f32; rows];
        let mut mask = vec![0f32; rows];
        x[..valid * s * q].copy_from_slice(&self.data.x[lo * s * q..hi * s * q]);
        yhist[..valid * q].copy_from_slice(&self.data.yhist[lo * q..hi * q]);
        y[..valid].copy_from_slice(&self.data.y[lo..hi]);
        mask[..valid].fill(1.0);

        self.pos = hi;
        Some(Block { x, yhist, y, mask, valid, offset: lo })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, q: usize) -> Windowed {
        let series: Vec<f64> = (0..n + q).map(|i| i as f64).collect();
        Windowed::from_series(&series, q).unwrap()
    }

    #[test]
    fn covers_every_row_exactly_once() {
        let w = toy(100, 4);
        let blocks: Vec<Block> = RowBlockBatcher::new(&w, 32).collect();
        assert_eq!(blocks.len(), 4);
        let total: usize = blocks.iter().map(|b| b.valid).sum();
        assert_eq!(total, 100);
        // offsets tile the dataset
        let mut seen = 0;
        for b in &blocks {
            assert_eq!(b.offset, seen);
            seen += b.valid;
        }
    }

    #[test]
    fn tail_block_is_padded_and_masked() {
        let w = toy(70, 3);
        let blocks: Vec<Block> = RowBlockBatcher::new(&w, 32).collect();
        let tail = blocks.last().unwrap();
        assert_eq!(tail.valid, 6);
        assert_eq!(tail.mask.iter().map(|&m| m as usize).sum::<usize>(), 6);
        // padded region must be zero
        assert!(tail.x[6 * 3..].iter().all(|&v| v == 0.0));
        assert!(tail.y[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn block_content_matches_source() {
        let w = toy(64, 5);
        let blocks: Vec<Block> = RowBlockBatcher::new(&w, 32).collect();
        let b1 = &blocks[1];
        assert_eq!(b1.offset, 32);
        assert_eq!(&b1.x[..5], w.x_row(32));
        assert_eq!(b1.y[0], w.y[32]);
        assert_eq!(&b1.yhist[..5], w.yhist_row(32));
    }

    #[test]
    fn exact_multiple_has_no_padding() {
        let w = toy(64, 2);
        let blocks: Vec<Block> = RowBlockBatcher::new(&w, 32).collect();
        assert_eq!(blocks.len(), 2);
        assert!(blocks.iter().all(|b| b.valid == 32));
        assert!(blocks.iter().all(|b| b.mask.iter().all(|&m| m == 1.0)));
    }

    #[test]
    fn quarantine_zeroes_and_unmasks_poisoned_rows() {
        let w = toy(20, 3);
        let mut b = RowBlockBatcher::new(&w, 32).next().unwrap();
        assert!(!b.has_non_finite());
        assert_eq!(b.quarantine_non_finite(), 0); // clean block untouched
        assert_eq!(b.valid, 20);

        b.x[5 * 3 + 1] = f32::NAN; // row 5's window
        b.y[9] = f32::INFINITY; // row 9's target
        assert!(b.has_non_finite());
        let dropped = b.quarantine_non_finite();
        assert_eq!(dropped, 2);
        assert_eq!(b.valid, 18);
        assert_eq!(b.mask[5], 0.0);
        assert_eq!(b.mask[9], 0.0);
        assert!(b.x[5 * 3..6 * 3].iter().all(|&v| v == 0.0));
        assert_eq!(b.y[9], 0.0);
        // invariant: valid == mask.sum(); padding rows stay untouched
        assert_eq!(b.mask.iter().map(|&m| m as usize).sum::<usize>(), b.valid);
        assert!(!b.has_non_finite());
    }

    #[test]
    fn n_blocks_matches_iteration() {
        for n in [1usize, 31, 32, 33, 255, 256, 257] {
            let w = toy(n, 2);
            let batcher = RowBlockBatcher::new(&w, 32);
            let expected = batcher.n_blocks();
            assert_eq!(batcher.count(), expected, "n={n}");
        }
    }
}
