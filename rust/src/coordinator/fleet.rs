//! `FleetTrainer` — multi-tenant block-diagonal batched training for many
//! small models.
//!
//! A fleet deployment trains hundreds of independent little ELMs (one per
//! tenant/sensor/series). Training each alone pays one thread-pool
//! spawn/join barrier, one block schedule, and one solve per model. This
//! module batches them instead:
//!
//! * **Grouping rule.** Queued `Train` requests are grouped by
//!   [`GroupKey`] = `(arch, M, s, q)` — the exact shape tuple that decides
//!   every kernel and schedule downstream. Groups form in first-seen
//!   submission order; within a group, members keep submission order.
//! * **Block-diagonal stream.** Each group runs as ONE flattened parallel
//!   stream: every member's fixed `block_ranges` schedule is concatenated
//!   (member-major, block order) into a single task list executed by one
//!   `par_map`/`par_map_isolated` barrier. Tasks never mix tenants — the
//!   implied global system is block-diagonal, one block per tenant — so
//!   each tenant's partials/blocks are produced by the *identical* code
//!   (`compute_h_block_inj`, `checked_gram_partials`,
//!   `CpuElmTrainer::solve_blocks`) with the *identical* per-tenant
//!   schedule and fold order as a solo [`CpuElmTrainer`] run. That is the
//!   fleet's contract: **per-tenant β is bit-identical to training that
//!   model alone**, at any worker count, on either `Precision` wire.
//! * **Per-tenant fault isolation.** Stream tasks return their tenant's
//!   result as a value, so one tenant's poisoned blocks produce a typed
//!   [`SolveError`] in that tenant's [`FleetOutcome::Failed`] while
//!   group-mates train to completion bit-identically. The fleet's own
//!   injection site is [`inject::Site::FleetJob`], keyed by the tenant's
//!   train-submission index within the drain batch. (Worker-panic retry
//!   counts are shared by the whole group's stream and reported on every
//!   member; a panic that fails its sequential retry aborts the group.)
//! * **Hot-tenant updates.** Trained models are cached (LRU, capacity
//!   [`FleetTrainer::cache_capacity`]). An `Update` request routes new
//!   rows through [`OnlineElm`] RLS: the filter is lazily seeded from the
//!   training run's pre-ridge Gram matrix via
//!   [`OnlineElm::from_state`], so after any number of updates β stays
//!   equal (to solver precision) to batch ridge over *all rows seen* —
//!   training rows plus every applied update. Retraining a tenant
//!   replaces the cache entry and resets its filter.
//! * **Grouped predict.** Non-NARMAX `Predict` requests across the whole
//!   drain run as one flattened H-block stream followed by a single
//!   [`Matrix::matmul_group`] packed group-GEMM over every `(H block, β)`
//!   pair. NARMAX predicts delegate to [`CpuElmTrainer::predict`]
//!   per tenant (the two-pass ELS refinement is inherently sequential
//!   across its passes).
//!
//! Drain semantics: [`FleetTrainer::drain`] processes every queued
//! `Train` first (grouped), then every `Update` in submission order, then
//! every `Predict` — so an update or predict queued behind its tenant's
//! queued train still sees the freshly trained model. Malformed requests
//! never get that far: [`FleetTrainer::submit`] screens duplicates and
//! unknown tenants with typed errors at submission time (see its docs).
//! Outcomes are returned in submission order.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::accumulator::SolveStrategy;
use crate::coordinator::pipeline::{
    block_gram_partials, checked_gram_partials, compute_h_block, compute_h_block_inj,
    fold_partials, CpuElmTrainer, TrainBreakdown,
};
use crate::data::window::Windowed;
use crate::elm::arch::{block_ranges, HBlock};
use crate::elm::trainer::shift_history;
use crate::elm::{Arch, ElmParams, OnlineElm, RlsOutcome, SrElmModel, TrainOptions};
use crate::linalg::policy::{par_map, par_map_isolated};
use crate::linalg::{cholesky_solve, Matrix, ParallelPolicy};
use crate::robust::journal::{RlsSnapshot, TenantSnapshot};
use crate::robust::{
    as_solve_error, inject, quarantine, ridge_ladder_solve, DegradationRung,
    SolveError, SolveReport, SolveStrategyKind,
};

/// The shape tuple that decides every kernel and schedule downstream —
/// two tenants share a grouped stream iff their keys are equal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GroupKey {
    /// Recurrent architecture of the model.
    pub arch: Arch,
    /// Hidden width M.
    pub m: usize,
    /// Input window length s.
    pub s: usize,
    /// History depth q.
    pub q: usize,
}

/// One queued unit of fleet work, addressed by tenant id.
#[derive(Debug, Clone)]
pub enum FleetRequest {
    /// Train (or retrain) this tenant's model from scratch.
    Train {
        /// Tenant id the trained model is cached under.
        tenant: String,
        /// Recurrent architecture to train.
        arch: Arch,
        /// Hidden width M.
        m: usize,
        /// Random-parameter seed.
        seed: u64,
        /// Training windows.
        data: Windowed,
    },
    /// Fold new rows into this tenant's cached model via RLS.
    Update {
        /// Tenant whose cached model receives the rows.
        tenant: String,
        /// The new windows (same (s, q) as the trained model).
        data: Windowed,
    },
    /// One-step-ahead predictions from this tenant's cached model.
    Predict {
        /// Tenant whose cached model predicts.
        tenant: String,
        /// The windows to predict on (same (s, q) as the trained model).
        data: Windowed,
    },
}

impl FleetRequest {
    /// The tenant id this request addresses.
    pub fn tenant(&self) -> &str {
        match self {
            FleetRequest::Train { tenant, .. }
            | FleetRequest::Update { tenant, .. }
            | FleetRequest::Predict { tenant, .. } => tenant,
        }
    }
}

/// Per-request result of a [`FleetTrainer::drain`], in submission order.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetOutcome {
    /// Training succeeded; the model is cached under its tenant id.
    Trained {
        /// How β was produced (strategy, degradation rung, retries, …).
        report: SolveReport,
        /// Row blocks processed for this tenant (both NARMAX passes).
        blocks: usize,
    },
    /// An RLS update was applied to the cached model.
    Updated {
        /// The most severe per-block filter outcome (quarantined-input
        /// counts summed across blocks).
        outcome: RlsOutcome,
        /// Total rows folded into the model so far (train + updates).
        rows_seen: usize,
    },
    /// Predictions from the cached model.
    Predicted {
        /// One-step-ahead predictions, one per input window.
        yhat: Vec<f64>,
    },
    /// The request failed; group-mates are unaffected.
    Failed {
        /// The typed failure.
        error: SolveError,
        /// The report of the failed attempt (rung = `Failed`).
        report: SolveReport,
    },
}

/// A tenant's cached model plus the state needed to keep it warm.
struct CacheEntry {
    model: SrElmModel,
    /// Report of the training run (its `effective_lambda` seeds the RLS
    /// ridge prior).
    report: SolveReport,
    /// Pre-ridge HᵀH over the rows the model was trained on — the seed
    /// for the lazily constructed RLS covariance.
    gram: Matrix,
    /// Rows folded so far (training rows, then + update rows).
    rows: usize,
    /// The random-parameter seed the model was trained with — journaled
    /// so [`FleetTrainer::restore`] can regenerate the (deterministic)
    /// `ElmParams` instead of serializing the weight buffers.
    seed: u64,
    /// Lazily seeded RLS filter; `None` until the first `Update`.
    rls: Option<OnlineElm>,
    /// Logical-clock timestamp of the last train/update/predict touch.
    last_used: u64,
}

/// One member's view inside a grouped stream (borrows the drain batch).
#[derive(Clone, Copy)]
struct GroupMember<'a> {
    params: &'a ElmParams,
    data: &'a Windowed,
    ehist: Option<&'a [f32]>,
    /// The tenant's train-submission index in the drain batch — the
    /// `Site::FleetJob` fault key.
    fleet_idx: usize,
    /// Rows the quarantine screen dropped for this member.
    quarantined: usize,
}

/// A fitted group member: β plus the cache-seeding artifacts.
struct Fit {
    beta: Vec<f64>,
    /// Pre-ridge HᵀH (the RLS seed).
    gram: Matrix,
    rows: usize,
    report: SolveReport,
    blocks: usize,
}

type FitResult = std::result::Result<Fit, (SolveError, SolveReport)>;
type TrainResult = std::result::Result<TenantTrained, (SolveError, SolveReport)>;

/// Owned per-tenant training result handed back to `drain`.
struct TenantTrained {
    model: SrElmModel,
    report: SolveReport,
    blocks: usize,
    gram: Matrix,
    rows: usize,
}

/// A queued `Train` with its slot in the drain batch.
struct QueuedTrain {
    slot: usize,
    /// Train-submission index in the drain batch (the fault key).
    fleet_idx: usize,
    arch: Arch,
    m: usize,
    seed: u64,
    data: Windowed,
}

/// Multi-tenant trainer front end: submit → queue → drain (see module
/// docs for grouping, bit-identity, and cache semantics).
pub struct FleetTrainer {
    /// Worker count + wire precision, shared by every grouped stream.
    pub policy: ParallelPolicy,
    /// Samples per H block (fixed: part of the deterministic result).
    pub block_rows: usize,
    /// β-solve strategy every group runs (NARMAX always takes Gram).
    pub strategy: SolveStrategy,
    /// Ridge λ (NARMAX raises it to its floor).
    pub lambda: f64,
    /// Max cached tenant models; inserts beyond this evict the least
    /// recently used entry (ties broken by smaller tenant id).
    pub cache_capacity: usize,
    queue: Vec<FleetRequest>,
    cache: BTreeMap<String, CacheEntry>,
    clock: u64,
}

impl FleetTrainer {
    /// Fleet with `workers` threads and the Gram strategy (the natural
    /// fleet default: fused partials, no per-tenant factor state).
    pub fn new(workers: usize) -> FleetTrainer {
        FleetTrainer::with_policy(ParallelPolicy::with_workers(workers))
    }

    /// Fleet with an explicit policy (worker count + wire precision).
    pub fn with_policy(policy: ParallelPolicy) -> FleetTrainer {
        FleetTrainer {
            policy,
            block_rows: 256,
            strategy: SolveStrategy::Gram,
            lambda: 1e-6,
            cache_capacity: 64,
            queue: Vec::new(),
            cache: BTreeMap::new(),
            clock: 0,
        }
    }

    /// The solo trainer this fleet is contracted to be bit-identical to.
    fn solo(&self) -> CpuElmTrainer {
        CpuElmTrainer {
            policy: self.policy,
            block_rows: self.block_rows,
            strategy: self.strategy,
            lambda: self.lambda,
        }
    }

    /// Queue a request, with the malformed-request screening done **at
    /// submit time** so bad requests fail fast instead of riding to the
    /// drain:
    ///
    /// * A `Train` for a tenant that already has a queued `Train` is
    ///   rejected with [`SolveError::DuplicateTenant`] — the fleet cannot
    ///   decide which model the id should map to.
    /// * An `Update`/`Predict` for a tenant with neither a cached model
    ///   nor a queued `Train` is rejected with
    ///   [`SolveError::UnknownTenant`] — it could never resolve (a queued
    ///   `Train` is enough, because the drain processes trains first).
    ///
    /// The drain-time [`SolveError::UnknownTenant`] outcome still exists
    /// for the cases submit cannot foresee: the backing `Train` failing
    /// in the same drain, or the cached model being evicted between
    /// submit and drain.
    pub fn submit(&mut self, req: FleetRequest) -> Result<()> {
        match &req {
            FleetRequest::Train { tenant, .. } => {
                let dup = self.queue.iter().any(|q| {
                    matches!(q, FleetRequest::Train { tenant: t, .. } if t == tenant)
                });
                if dup {
                    return Err(
                        SolveError::DuplicateTenant { tenant: tenant.clone() }.into()
                    );
                }
            }
            FleetRequest::Update { tenant, .. }
            | FleetRequest::Predict { tenant, .. } => {
                let resolvable = self.cache.contains_key(tenant)
                    || self.queue.iter().any(|q| {
                        matches!(q, FleetRequest::Train { tenant: t, .. } if t == tenant)
                    });
                if !resolvable {
                    return Err(
                        SolveError::UnknownTenant { tenant: tenant.clone() }.into()
                    );
                }
            }
        }
        self.queue.push(req);
        Ok(())
    }

    /// Requests currently queued for the next drain.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Tenants currently holding a cached model.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Whether this tenant has a cached model.
    pub fn has_model(&self, tenant: &str) -> bool {
        self.cache.contains_key(tenant)
    }

    /// The cached model for a tenant (tests pin β bit-identity through
    /// this accessor).
    pub fn model(&self, tenant: &str) -> Option<&SrElmModel> {
        self.cache.get(tenant).map(|e| &e.model)
    }

    /// Snapshot a cached tenant's full warm state for the crash-safe
    /// journal ([`crate::robust::journal`]): the `(arch, s, q, m, seed)`
    /// tuple that regenerates the random parameters deterministically,
    /// the exact β bits, the pre-ridge Gram accumulator, the solve
    /// report, and — when the tenant has absorbed `Update`s — the RLS
    /// covariance and λ. `None` when the tenant has no cached model.
    pub fn snapshot(&self, tenant: &str) -> Option<TenantSnapshot> {
        let e = self.cache.get(tenant)?;
        Some(TenantSnapshot {
            arch: e.model.params.arch,
            s: e.model.params.s,
            q: e.model.params.q,
            m: e.model.params.m,
            seed: e.seed,
            beta: e.model.beta.clone(),
            gram: e.gram.clone(),
            rows: e.rows,
            report: e.report,
            rls: e.rls.as_ref().map(|r| RlsSnapshot {
                p: r.covariance().clone(),
                lambda: r.lambda(),
            }),
        })
    }

    /// Rebuild a tenant's cache entry from a journal snapshot — the
    /// recovery half of [`FleetTrainer::snapshot`]. The random parameters
    /// are regenerated by [`ElmParams::init`] (deterministic in the
    /// seed), β/Gram/P move as exact bits, and a snapshotted RLS filter
    /// resumes through [`OnlineElm::from_state`] — so the restored entry
    /// is bit-identical to the pre-crash one: the same β, and the same
    /// trajectory under any further updates or predicts. LRU metadata
    /// (`last_used`) restarts fresh; eviction order is scheduling state,
    /// not model state, and is not journaled.
    pub fn restore(&mut self, tenant: &str, snap: &TenantSnapshot) -> Result<()> {
        if snap.beta.len() != snap.m
            || snap.gram.rows != snap.m
            || snap.gram.cols != snap.m
        {
            return Err(SolveError::ShapeMismatch {
                context: "fleet restore",
                detail: format!(
                    "snapshot for {tenant:?} has beta {} / gram {}x{} vs M {}",
                    snap.beta.len(),
                    snap.gram.rows,
                    snap.gram.cols,
                    snap.m
                ),
            }
            .into());
        }
        let params = ElmParams::init(snap.arch, snap.s, snap.q, snap.m, snap.seed);
        let rls = match &snap.rls {
            None => None,
            Some(r) => Some(OnlineElm::from_state(
                snap.m,
                r.lambda,
                r.p.clone(),
                snap.beta.clone(),
                snap.rows,
            )?),
        };
        self.cache_insert(
            tenant.to_string(),
            CacheEntry {
                model: SrElmModel { params, beta: snap.beta.clone() },
                report: snap.report,
                gram: snap.gram.clone(),
                rows: snap.rows,
                seed: snap.seed,
                rls,
                last_used: 0, // stamped by cache_insert
            },
        );
        Ok(())
    }

    /// Process the whole queue: trains (grouped by [`GroupKey`]), then
    /// updates, then predicts — outcomes in submission order. An empty
    /// queue drains to an empty vec.
    pub fn drain(&mut self) -> Vec<(String, FleetOutcome)> {
        let queue = std::mem::take(&mut self.queue);
        let names: Vec<String> =
            queue.iter().map(|r| r.tenant().to_string()).collect();
        let mut outcomes: Vec<Option<FleetOutcome>> =
            queue.iter().map(|_| None).collect();

        let mut trains: Vec<QueuedTrain> = Vec::new();
        let mut updates: Vec<(usize, String, Windowed)> = Vec::new();
        let mut predicts: Vec<(usize, String, Windowed)> = Vec::new();
        for (slot, req) in queue.into_iter().enumerate() {
            match req {
                FleetRequest::Train { tenant: _, arch, m, seed, data } => {
                    let fleet_idx = trains.len();
                    trains.push(QueuedTrain { slot, fleet_idx, arch, m, seed, data });
                }
                FleetRequest::Update { tenant, data } => {
                    updates.push((slot, tenant, data));
                }
                FleetRequest::Predict { tenant, data } => {
                    predicts.push((slot, tenant, data));
                }
            }
        }

        // group trains by shape key, first-seen order
        let mut groups: Vec<(GroupKey, Vec<usize>)> = Vec::new();
        for (ti, job) in trains.iter().enumerate() {
            let key =
                GroupKey { arch: job.arch, m: job.m, s: job.data.s, q: job.data.q };
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, members)) => members.push(ti),
                None => groups.push((key, vec![ti])),
            }
        }

        for (_key, members) in &groups {
            let results = self.train_group(&trains, members);
            for (&ti, res) in members.iter().zip(results) {
                let job = &trains[ti];
                outcomes[job.slot] = Some(match res {
                    Ok(t) => {
                        let outcome = FleetOutcome::Trained {
                            report: t.report,
                            blocks: t.blocks,
                        };
                        self.cache_insert(
                            names[job.slot].clone(),
                            CacheEntry {
                                model: t.model,
                                report: t.report,
                                gram: t.gram,
                                rows: t.rows,
                                seed: job.seed,
                                rls: None,
                                last_used: 0, // stamped by cache_insert
                            },
                        );
                        outcome
                    }
                    Err((error, report)) => FleetOutcome::Failed { error, report },
                });
            }
        }

        for (slot, tenant, data) in updates {
            outcomes[slot] = Some(self.apply_update(&tenant, &data));
        }

        self.run_predicts(predicts, &mut outcomes);

        names
            .into_iter()
            .zip(outcomes)
            .map(|(n, o)| (n, o.expect("every request resolved")))
            .collect()
    }

    /// Train one shape group as a block-diagonal stream; results align
    /// with `members`.
    fn train_group(&self, trains: &[QueuedTrain], members: &[usize]) -> Vec<TrainResult> {
        let arch = trains[members[0]].arch;
        let fail_kind = if arch == Arch::Narmax {
            SolveStrategyKind::Gram
        } else {
            strategy_kind(self.strategy)
        };

        // screen each member; a screening failure fails only that member
        let screened: Vec<std::result::Result<(quarantine::Screened<'_>, ElmParams), SolveError>> =
            members
                .iter()
                .map(|&ti| {
                    let job = &trains[ti];
                    quarantine::screen(&job.data)
                        .map(|s| {
                            let params = ElmParams::init(
                                job.arch,
                                s.data().s,
                                s.data().q,
                                job.m,
                                job.seed,
                            );
                            (s, params)
                        })
                        .map_err(|e| to_solve_error(&e))
                })
                .collect();

        let mut positions: Vec<usize> = Vec::new();
        let mut mems: Vec<GroupMember<'_>> = Vec::new();
        for (pos, res) in screened.iter().enumerate() {
            if let Ok((s, params)) = res {
                positions.push(pos);
                mems.push(GroupMember {
                    params,
                    data: s.data(),
                    ehist: None,
                    fleet_idx: trains[members[pos]].fleet_idx,
                    quarantined: s.dropped(),
                });
            }
        }

        let fits = if arch == Arch::Narmax {
            self.narmax_group(&mems)
        } else if self.strategy == SolveStrategy::Gram {
            self.gram_group(&mems, self.lambda)
        } else {
            self.qr_group(&mems)
        };

        let mut out: Vec<Option<TrainResult>> = screened
            .iter()
            .enumerate()
            .map(|(pos, res)| match res {
                Err(e) => Some(Err((
                    e.clone(),
                    failed_report(fail_kind, trains[members[pos]].data.n),
                ))),
                Ok(_) => None,
            })
            .collect();
        for (i, fit) in fits.into_iter().enumerate() {
            let pos = positions[i];
            out[pos] = Some(match fit {
                Ok(f) => {
                    let params =
                        screened[pos].as_ref().expect("screened ok").1.clone();
                    Ok(TenantTrained {
                        model: SrElmModel { params, beta: f.beta },
                        report: f.report,
                        blocks: f.blocks,
                        gram: f.gram,
                        rows: f.rows,
                    })
                }
                Err(e) => Err(e),
            });
        }
        out.into_iter()
            .map(|o| o.expect("every member resolved"))
            .collect()
    }

    /// Grouped Gram strategy: one fused (H block → partials) stream, then
    /// per-member in-order fold + ridge ladder — the byte-for-byte mirror
    /// of the solo `gram_solve` per tenant.
    fn gram_group(&self, mems: &[GroupMember<'_>], lambda: f64) -> Vec<FitResult> {
        let mut reports: Vec<SolveReport> = mems
            .iter()
            .map(|_| SolveReport::new(SolveStrategyKind::Gram))
            .collect();
        let fits = self.gram_stream(mems, lambda, &mut reports);
        mems.iter()
            .zip(fits)
            .zip(reports)
            .map(|((mem, fit), mut report)| {
                report.quarantined_rows += mem.quarantined;
                let blocks = block_ranges(mem.data.n, self.block_rows).len();
                match fit {
                    Ok(f) => Ok(Fit {
                        beta: f.0,
                        gram: f.1,
                        rows: f.2,
                        report,
                        blocks,
                    }),
                    Err(e) => Err((e, report)),
                }
            })
            .collect()
    }

    /// The fused block-diagonal Gram stream shared by the Gram strategy
    /// and both NARMAX passes. Per member: `Ok((β, pre-ridge HᵀH, rows))`
    /// or the first typed error in block order; `reports` (aligned with
    /// `mems`) record retries/rung/λ exactly as the solo path would.
    fn gram_stream(
        &self,
        mems: &[GroupMember<'_>],
        lambda: f64,
        reports: &mut [SolveReport],
    ) -> Vec<std::result::Result<(Vec<f64>, Matrix, usize), SolveError>> {
        let mut tasks: Vec<(usize, usize, usize, usize)> = Vec::new();
        for (ji, mem) in mems.iter().enumerate() {
            let ranges = block_ranges(mem.data.n, self.block_rows);
            for (li, &(lo, hi)) in ranges.iter().enumerate() {
                tasks.push((ji, li, lo, hi));
            }
        }
        let mapped = par_map_isolated(&tasks, self.policy, |_, &(ji, li, lo, hi)| {
            let mem = &mems[ji];
            inject::maybe_panic(inject::Site::Worker, li);
            let (h, y) = fleet_h_block(
                mem.params,
                mem.data,
                mem.ehist,
                lo,
                hi,
                self.policy,
                li,
                mem.fleet_idx,
            );
            Ok((ji, checked_gram_partials(&h, &y, li, mem.params.m)))
        });
        let (flat, retries) = match mapped {
            Ok(v) => v,
            Err(e) => {
                // a worker panicked twice: the whole stream aborted
                let err = to_solve_error(&e);
                for r in reports.iter_mut() {
                    r.rung = DegradationRung::Failed;
                }
                return mems.iter().map(|_| Err(err.clone())).collect();
            }
        };
        let mut per: Vec<Vec<Result<(Matrix, Vec<f64>, usize)>>> =
            mems.iter().map(|_| Vec::new()).collect();
        for (ji, res) in flat {
            per[ji].push(res);
        }
        mems.iter()
            .zip(per)
            .zip(reports.iter_mut())
            .map(|((mem, partials), report)| {
                report.retries += retries;
                let mut ok = Vec::with_capacity(partials.len());
                for p in partials {
                    match p {
                        Ok(v) => ok.push(v),
                        Err(e) => {
                            report.rung = DegradationRung::Failed;
                            return Err(to_solve_error(&e));
                        }
                    }
                }
                let (g, c) = match fold_partials(&ok, mem.params.m) {
                    Ok(v) => v,
                    Err(e) => {
                        report.rung = DegradationRung::Failed;
                        return Err(to_solve_error(&e));
                    }
                };
                let rows = ok.iter().map(|(_, _, r)| *r).sum();
                match ridge_ladder_solve(&g, &c, lambda, true, report) {
                    Ok(beta) => Ok((beta, g, rows)),
                    Err(e) => Err(to_solve_error(&e)),
                }
            })
            .collect()
    }

    /// Grouped TSQR/DirectQr: one flattened block stream, then each
    /// member finishes through `CpuElmTrainer::solve_blocks` — literally
    /// the solo code, which is the bit-identity argument for these
    /// strategies.
    fn qr_group(&self, mems: &[GroupMember<'_>]) -> Vec<FitResult> {
        let kind = strategy_kind(self.strategy);
        let (blocks, retries) = match self.block_stream(mems) {
            Ok(v) => v,
            Err(e) => {
                let err = to_solve_error(&e);
                return mems
                    .iter()
                    .map(|mem| Err((err.clone(), failed_report(kind, mem.quarantined))))
                    .collect();
            }
        };
        let solo = self.solo();
        let m = mems.first().map_or(0, |j| j.params.m);
        mems.iter()
            .zip(blocks)
            .map(|(mem, bl)| {
                let n_blocks = bl.len();
                // fold the RLS gram seed before solve_blocks consumes the
                // blocks (the solo path never needs this; it is the price
                // of warm updates under the factorization strategies)
                let (gram, rows) = gram_seed(&bl, m);
                let mut bd =
                    TrainBreakdown { blocks: n_blocks, ..Default::default() };
                match solo.solve_blocks(
                    mem.params,
                    mem.data,
                    None,
                    self.lambda,
                    bl,
                    retries,
                    &mut bd,
                ) {
                    Ok(beta) => {
                        let mut report = bd.solve_report;
                        report.quarantined_rows += mem.quarantined;
                        Ok(Fit { beta, gram, rows, report, blocks: bd.blocks })
                    }
                    Err(e) => {
                        let mut report = bd.solve_report;
                        if report.strategy == SolveStrategyKind::Unspecified {
                            report.strategy = kind;
                        }
                        report.rung = DegradationRung::Failed;
                        report.quarantined_rows += mem.quarantined;
                        Err((to_solve_error(&e), report))
                    }
                }
            })
            .collect()
    }

    /// Grouped NARMAX two-pass ELS: grouped pass 1 (blocks kept for the
    /// residual matvec), per-member residual history, grouped pass 2 over
    /// the survivors — each pass the mirror of `narmax_pass1` /
    /// `solve_pass`.
    fn narmax_group(&self, mems: &[GroupMember<'_>]) -> Vec<FitResult> {
        let lambda = self.lambda.max(TrainOptions::NARMAX_RIDGE);
        let m = mems.first().map_or(0, |j| j.params.m);
        let mut out: Vec<Option<FitResult>> = mems.iter().map(|_| None).collect();

        // pass 1: blocks with e ≡ 0
        let (blocks, retries1) = match self.block_stream(mems) {
            Ok(v) => v,
            Err(e) => {
                let err = to_solve_error(&e);
                return mems
                    .iter()
                    .map(|mem| {
                        Err((
                            err.clone(),
                            failed_report(SolveStrategyKind::Gram, mem.quarantined),
                        ))
                    })
                    .collect();
            }
        };
        let idx: Vec<(usize, usize)> = blocks
            .iter()
            .enumerate()
            .flat_map(|(ji, bl)| (0..bl.len()).map(move |li| (ji, li)))
            .collect();
        let partials = match par_map(idx, self.policy, |(ji, li)| {
            let (h, y) = &blocks[ji][li];
            Ok((ji, checked_gram_partials(h, y, li, m)))
        }) {
            Ok(v) => v,
            Err(e) => {
                let err = to_solve_error(&e);
                return mems
                    .iter()
                    .map(|mem| {
                        Err((
                            err.clone(),
                            failed_report(SolveStrategyKind::Gram, mem.quarantined),
                        ))
                    })
                    .collect();
            }
        };
        let mut per: Vec<Vec<Result<(Matrix, Vec<f64>, usize)>>> =
            mems.iter().map(|_| Vec::new()).collect();
        for (ji, res) in partials {
            per[ji].push(res);
        }

        let mut ehists: Vec<Option<Vec<f32>>> = mems.iter().map(|_| None).collect();
        for (ji, (mem, partials)) in mems.iter().zip(per).enumerate() {
            let mut report = SolveReport::new(SolveStrategyKind::Gram);
            report.retries = retries1;
            report.quarantined_rows = mem.quarantined;
            let mut ok = Vec::with_capacity(partials.len());
            let mut first_err: Option<SolveError> = None;
            for p in partials {
                match p {
                    Ok(v) => ok.push(v),
                    Err(e) => {
                        first_err = Some(to_solve_error(&e));
                        break;
                    }
                }
            }
            if let Some(e) = first_err {
                report.rung = DegradationRung::Failed;
                out[ji] = Some(Err((e, report)));
                continue;
            }
            let folded = match fold_partials(&ok, m) {
                Ok(v) => v,
                Err(e) => {
                    report.rung = DegradationRung::Failed;
                    out[ji] = Some(Err((to_solve_error(&e), report)));
                    continue;
                }
            };
            let (g, c) = folded;
            match ridge_ladder_solve(&g, &c, lambda, true, &mut report) {
                Ok(beta1) => {
                    let mut yhat = Vec::with_capacity(mem.data.n);
                    for (h, _) in &blocks[ji] {
                        yhat.extend(h.matvec(&beta1));
                    }
                    let resid: Vec<f32> = mem
                        .data
                        .y
                        .iter()
                        .zip(&yhat)
                        .map(|(&y, &p)| y - p as f32)
                        .collect();
                    ehists[ji] = Some(shift_history(&resid, mem.data.q));
                }
                Err(e) => out[ji] = Some(Err((to_solve_error(&e), report))),
            }
        }

        // pass 2 over the survivors, with their residual histories
        let survivors: Vec<usize> =
            (0..mems.len()).filter(|&ji| out[ji].is_none()).collect();
        let mems2: Vec<GroupMember<'_>> = survivors
            .iter()
            .map(|&ji| GroupMember { ehist: ehists[ji].as_deref(), ..mems[ji] })
            .collect();
        let fits2 = self.gram_group(&mems2, lambda);
        for (&ji, fit) in survivors.iter().zip(fits2) {
            out[ji] = Some(fit.map(|mut f| {
                f.blocks *= 2; // both passes cut the same block schedule
                f
            }));
        }
        out.into_iter()
            .map(|o| o.expect("every member resolved"))
            .collect()
    }

    /// One flattened H-block stream for the whole group; per-member block
    /// lists come back in block order.
    #[allow(clippy::type_complexity)]
    fn block_stream(
        &self,
        mems: &[GroupMember<'_>],
    ) -> Result<(Vec<Vec<(HBlock, Vec<f64>)>>, u32)> {
        let mut tasks: Vec<(usize, usize, usize, usize)> = Vec::new();
        for (ji, mem) in mems.iter().enumerate() {
            let ranges = block_ranges(mem.data.n, self.block_rows);
            for (li, &(lo, hi)) in ranges.iter().enumerate() {
                tasks.push((ji, li, lo, hi));
            }
        }
        let (flat, retries) =
            par_map_isolated(&tasks, self.policy, |_, &(ji, li, lo, hi)| {
                let mem = &mems[ji];
                inject::maybe_panic(inject::Site::Worker, li);
                Ok((
                    ji,
                    fleet_h_block(
                        mem.params,
                        mem.data,
                        mem.ehist,
                        lo,
                        hi,
                        self.policy,
                        li,
                        mem.fleet_idx,
                    ),
                ))
            })?;
        let mut per: Vec<Vec<(HBlock, Vec<f64>)>> =
            mems.iter().map(|_| Vec::new()).collect();
        for (ji, hb) in flat {
            per[ji].push(hb);
        }
        Ok((per, retries))
    }

    /// Apply one RLS update to a cached tenant model.
    fn apply_update(&mut self, tenant: &str, data: &Windowed) -> FleetOutcome {
        self.clock += 1;
        let clock = self.clock;
        let lambda_default = self.lambda;
        let block_rows = self.block_rows;
        let policy = self.policy;
        let Some(entry) = self.cache.get_mut(tenant) else {
            return failed(
                SolveError::UnknownTenant { tenant: tenant.to_string() },
                SolveStrategyKind::Online,
            );
        };
        entry.last_used = clock;
        let screened = match quarantine::screen(data) {
            Ok(s) => s,
            Err(e) => return failed(to_solve_error(&e), SolveStrategyKind::Online),
        };
        let data = screened.data();
        if data.s != entry.model.params.s || data.q != entry.model.params.q {
            return failed(
                SolveError::ShapeMismatch {
                    context: "fleet update",
                    detail: format!(
                        "update windows are (s={}, q={}) but tenant {tenant:?} \
                         trained at (s={}, q={})",
                        data.s, data.q, entry.model.params.s, entry.model.params.q
                    ),
                },
                SolveStrategyKind::Online,
            );
        }
        if entry.rls.is_none() {
            let lam = if entry.report.effective_lambda > 0.0 {
                entry.report.effective_lambda
            } else {
                lambda_default
            }
            .max(1e-12);
            match seed_rls(&entry.gram, &entry.model.beta, entry.rows, lam) {
                Ok(r) => entry.rls = Some(r),
                Err(e) => return failed(e, SolveStrategyKind::Online),
            }
        }
        // NARMAX folds H(ehist) rows, with ehist from the cached model's
        // one-pass residuals on the update window — the same refinement
        // the predict path applies
        let ehist = if entry.model.params.arch == Arch::Narmax {
            let mut y0 = Vec::with_capacity(data.n);
            for &(lo, hi) in &block_ranges(data.n, block_rows) {
                let (h, _) =
                    compute_h_block(&entry.model.params, data, None, lo, hi, policy);
                y0.extend(h.matvec(&entry.model.beta));
            }
            let resid: Vec<f32> =
                data.y.iter().zip(&y0).map(|(&y, &p)| y - p as f32).collect();
            Some(shift_history(&resid, data.q))
        } else {
            None
        };
        let params = &entry.model.params;
        let rls = entry.rls.as_mut().expect("seeded above");
        let mut outcome = RlsOutcome::Applied;
        for &(lo, hi) in &block_ranges(data.n, block_rows) {
            let (h, y) =
                compute_h_block(params, data, ehist.as_deref(), lo, hi, policy);
            let rows = h.rows();
            // H entries are f32 nonlinearity outputs: the narrowing cast
            // is exact on either wire
            let hf: Vec<f32> = match h {
                HBlock::F32(hb) => hb.data().to_vec(),
                HBlock::F64(hb) => hb.data().iter().map(|&v| v as f32).collect(),
            };
            let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            match rls.update_block(&hf, &yf, rows) {
                Ok(o) => outcome = merge_outcome(outcome, o),
                Err(e) => {
                    return failed(to_solve_error(&e), SolveStrategyKind::Online)
                }
            }
        }
        let (new_beta, rows_seen) = (rls.beta().to_vec(), rls.rows_seen());
        entry.model.beta = new_beta;
        entry.rows = rows_seen;
        FleetOutcome::Updated { outcome, rows_seen }
    }

    /// Resolve every queued predict: NARMAX per tenant (two-pass),
    /// everything else through one flattened H stream + one packed
    /// group-GEMM.
    fn run_predicts(
        &mut self,
        predicts: Vec<(usize, String, Windowed)>,
        outcomes: &mut [Option<FleetOutcome>],
    ) {
        let kind = strategy_kind(self.strategy);
        let mut narmax_preds: Vec<(usize, SrElmModel, Windowed)> = Vec::new();
        let mut flat_preds: Vec<(usize, SrElmModel, Windowed)> = Vec::new();
        for (slot, tenant, data) in predicts {
            self.clock += 1;
            let clock = self.clock;
            match self.cache.get_mut(&tenant) {
                None => {
                    outcomes[slot] = Some(failed(
                        SolveError::UnknownTenant { tenant },
                        kind,
                    ));
                }
                Some(entry) => {
                    entry.last_used = clock;
                    if data.s != entry.model.params.s
                        || data.q != entry.model.params.q
                    {
                        outcomes[slot] = Some(failed(
                            SolveError::ShapeMismatch {
                                context: "fleet predict",
                                detail: format!(
                                    "predict windows are (s={}, q={}) but tenant \
                                     {tenant:?} trained at (s={}, q={})",
                                    data.s,
                                    data.q,
                                    entry.model.params.s,
                                    entry.model.params.q
                                ),
                            },
                            kind,
                        ));
                    } else if entry.model.params.arch == Arch::Narmax {
                        narmax_preds.push((slot, entry.model.clone(), data));
                    } else {
                        flat_preds.push((slot, entry.model.clone(), data));
                    }
                }
            }
        }
        let solo = self.solo();
        for (slot, model, data) in narmax_preds {
            outcomes[slot] = Some(match solo.predict(&model, &data) {
                Ok(yhat) => FleetOutcome::Predicted { yhat },
                Err(e) => failed(to_solve_error(&e), SolveStrategyKind::Gram),
            });
        }
        if flat_preds.is_empty() {
            return;
        }
        let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
        for (pi, (_, _, data)) in flat_preds.iter().enumerate() {
            for (lo, hi) in block_ranges(data.n, self.block_rows) {
                tasks.push((pi, lo, hi));
            }
        }
        let mapped = par_map(tasks, self.policy, |(pi, lo, hi)| {
            let (_, model, data) = &flat_preds[pi];
            let (h, _) = compute_h_block(&model.params, data, None, lo, hi, self.policy);
            Ok((pi, h.into_f64()))
        });
        match mapped {
            Err(e) => {
                let err = to_solve_error(&e);
                for (slot, _, _) in &flat_preds {
                    outcomes[*slot] = Some(failed(err.clone(), kind));
                }
            }
            Ok(hs) => {
                let betas: Vec<Matrix> = flat_preds
                    .iter()
                    .map(|(_, model, _)| {
                        Matrix::from_vec(model.params.m, 1, model.beta.clone())
                    })
                    .collect();
                let pairs: Vec<(&Matrix, &Matrix)> =
                    hs.iter().map(|(pi, h)| (h, &betas[*pi])).collect();
                let outs = Matrix::matmul_group(&pairs, self.policy);
                let mut yhats: Vec<Vec<f64>> = flat_preds
                    .iter()
                    .map(|(_, _, d)| Vec::with_capacity(d.n))
                    .collect();
                for ((pi, _), out) in hs.iter().zip(outs) {
                    yhats[*pi].extend(out.data());
                }
                for ((slot, _, _), yhat) in flat_preds.iter().zip(yhats) {
                    outcomes[*slot] = Some(FleetOutcome::Predicted { yhat });
                }
            }
        }
    }

    /// Insert under LRU eviction: at capacity, the smallest
    /// `(last_used, tenant)` entry goes (deterministic tie-break).
    fn cache_insert(&mut self, tenant: String, mut entry: CacheEntry) {
        self.clock += 1;
        entry.last_used = self.clock;
        if !self.cache.contains_key(&tenant) && self.cache.len() >= self.cache_capacity
        {
            // BTreeMap iteration is key-ascending, and `min_by_key`
            // keeps the first minimum, so ties on `last_used` evict the
            // smallest tenant id — no per-candidate key clone needed
            let victim = self
                .cache
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            if let Some(v) = victim {
                self.cache.remove(&v);
            }
        }
        self.cache.insert(tenant, entry);
    }
}

/// The solo fused H-block computation plus the fleet's own
/// [`inject::Site::FleetJob`] hooks, keyed by the tenant's
/// train-submission index (worker-count and grouping invariant): a panic
/// at the tenant's first-executed block task, payload corruption on every
/// one of the tenant's blocks. No-ops without the `fault-inject` feature
/// — the clean path is the byte-for-byte solo computation.
#[allow(clippy::too_many_arguments)]
fn fleet_h_block(
    params: &ElmParams,
    data: &Windowed,
    ehist: Option<&[f32]>,
    lo: usize,
    hi: usize,
    policy: ParallelPolicy,
    local_idx: usize,
    fleet_idx: usize,
) -> (HBlock, Vec<f64>) {
    inject::maybe_panic(inject::Site::FleetJob, fleet_idx);
    let (mut h, y) = compute_h_block_inj(params, data, ehist, lo, hi, policy, local_idx);
    match &mut h {
        HBlock::F64(hb) => {
            let (r, c) = (hb.rows, hb.cols);
            inject::corrupt_slice_f64(inject::Site::FleetJob, fleet_idx, hb.data_mut(), r, c);
        }
        HBlock::F32(hb) => {
            let (r, c) = (hb.rows, hb.cols);
            inject::corrupt_slice_f32(inject::Site::FleetJob, fleet_idx, hb.data_mut(), r, c);
        }
    }
    (h, y)
}

/// In-order fold of just the pre-ridge HᵀH (and the row count) over a
/// member's blocks — the RLS covariance seed under the factorization
/// strategies, whose solves never form the Gram matrix themselves.
fn gram_seed(blocks: &[(HBlock, Vec<f64>)], m: usize) -> (Matrix, usize) {
    let mut g = Matrix::zeros(m, m);
    let mut rows = 0usize;
    for (h, y) in blocks {
        let (gl, _c, rl) = block_gram_partials(h, y);
        for (gv, lv) in g.data_mut().iter_mut().zip(gl.data()) {
            *gv += lv;
        }
        rows += rl;
    }
    (g, rows)
}

/// Seed an RLS filter so its state is exactly the batch ridge state over
/// the training rows: P = (G + λI)⁻¹ column-by-column via Cholesky.
fn seed_rls(
    gram: &Matrix,
    beta: &[f64],
    rows: usize,
    lambda: f64,
) -> std::result::Result<OnlineElm, SolveError> {
    let m = beta.len();
    let mut a = gram.clone();
    for i in 0..m {
        a[(i, i)] += lambda;
    }
    let mut p = Matrix::zeros(m, m);
    for j in 0..m {
        let mut e = vec![0.0f64; m];
        e[j] = 1.0;
        let col = cholesky_solve(&a, &e).map_err(|err| to_solve_error(&err))?;
        for (i, &v) in col.iter().enumerate() {
            p[(i, j)] = v;
        }
    }
    OnlineElm::from_state(m, lambda, p, beta.to_vec(), rows)
        .map_err(|e| to_solve_error(&e))
}

/// The report kind a strategy's failures carry.
fn strategy_kind(s: SolveStrategy) -> SolveStrategyKind {
    match s {
        SolveStrategy::Gram => SolveStrategyKind::Gram,
        SolveStrategy::Tsqr => SolveStrategyKind::Tsqr,
        SolveStrategy::DirectQr => SolveStrategyKind::Qr,
    }
}

/// A `Failed` outcome with a rung-`Failed` report of the given kind.
fn failed(error: SolveError, kind: SolveStrategyKind) -> FleetOutcome {
    let mut report = SolveReport::new(kind);
    report.rung = DegradationRung::Failed;
    FleetOutcome::Failed { error, report }
}

/// A rung-`Failed` report recording the quarantined-row count.
fn failed_report(kind: SolveStrategyKind, quarantined: usize) -> SolveReport {
    let mut r = SolveReport::new(kind);
    r.rung = DegradationRung::Failed;
    r.quarantined_rows = quarantined;
    r
}

/// Extract the typed `SolveError` from an `anyhow` chain; anything that
/// somehow is not one (every error this crate raises is) is wrapped as a
/// retried worker panic carrying the rendered message, preserving a
/// typed surface.
fn to_solve_error(err: &anyhow::Error) -> SolveError {
    as_solve_error(err).cloned().unwrap_or_else(|| SolveError::WorkerPanic {
        index: 0,
        retried: true,
        message: format!("{err:#}"),
    })
}

/// Most severe of two per-block RLS outcomes (Reset > Quarantined >
/// Applied); quarantined non-finite counts accumulate.
fn merge_outcome(a: RlsOutcome, b: RlsOutcome) -> RlsOutcome {
    use RlsOutcome::*;
    match (a, b) {
        (Reset, _) | (_, Reset) => Reset,
        (QuarantinedInput { non_finite: x }, QuarantinedInput { non_finite: y }) => {
            QuarantinedInput { non_finite: x + y }
        }
        (q @ QuarantinedInput { .. }, Applied)
        | (Applied, q @ QuarantinedInput { .. }) => q,
        (Applied, Applied) => Applied,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accumulator::SolveStrategy;

    fn toy_data(n: usize, q: usize, phase: f64) -> Windowed {
        let series: Vec<f64> =
            (0..n + q).map(|i| (i as f64 * 0.07 + phase).sin()).collect();
        Windowed::from_series(&series, q).expect("windowed")
    }

    fn train_req(tenant: &str, m: usize, seed: u64, phase: f64) -> FleetRequest {
        FleetRequest::Train {
            tenant: tenant.to_string(),
            arch: Arch::Elman,
            m,
            seed,
            data: toy_data(90, 3, phase),
        }
    }

    #[test]
    fn duplicate_train_rejected_until_drained() {
        let mut fleet = FleetTrainer::new(2);
        fleet.submit(train_req("a", 6, 1, 0.0)).unwrap();
        let err = fleet.submit(train_req("a", 6, 2, 0.1)).unwrap_err();
        assert_eq!(
            as_solve_error(&err).map(SolveError::class),
            Some("duplicate-tenant")
        );
        fleet.drain();
        // after a drain, the id can be retrained
        fleet.submit(train_req("a", 6, 2, 0.1)).unwrap();
    }

    #[test]
    fn unknown_tenant_is_rejected_at_submit_time() {
        let mut fleet = FleetTrainer::new(1);
        for req in [
            FleetRequest::Predict { tenant: "ghost".into(), data: toy_data(40, 3, 0.0) },
            FleetRequest::Update { tenant: "ghost".into(), data: toy_data(40, 3, 0.0) },
        ] {
            let err = fleet.submit(req).unwrap_err();
            assert_eq!(
                as_solve_error(&err).map(SolveError::class),
                Some("unknown-tenant")
            );
        }
        assert_eq!(fleet.queued(), 0, "rejected requests never reach the queue");
        assert!(fleet.drain().is_empty());
    }

    #[test]
    fn queued_train_makes_update_and_predict_submittable() {
        let mut fleet = FleetTrainer::new(2);
        fleet.submit(train_req("a", 6, 1, 0.0)).unwrap();
        // the model is not cached yet, but a queued Train resolves first
        fleet
            .submit(FleetRequest::Update { tenant: "a".into(), data: toy_data(40, 3, 0.5) })
            .unwrap();
        fleet
            .submit(FleetRequest::Predict { tenant: "a".into(), data: toy_data(40, 3, 0.0) })
            .unwrap();
        let out = fleet.drain();
        assert!(matches!(out[0].1, FleetOutcome::Trained { .. }), "{:?}", out[0]);
        assert!(matches!(out[1].1, FleetOutcome::Updated { .. }), "{:?}", out[1]);
        assert!(matches!(out[2].1, FleetOutcome::Predicted { .. }), "{:?}", out[2]);
        // and once cached, submit accepts without any queued train
        fleet
            .submit(FleetRequest::Predict { tenant: "a".into(), data: toy_data(40, 3, 0.0) })
            .unwrap();
    }

    #[test]
    fn drain_time_unknown_tenant_survives_for_failed_backing_train() {
        // submit screening admits a Predict on the strength of a queued
        // Train; if that train then fails, the predict must still come
        // back as a typed drain-time unknown-tenant failure
        let mut fleet = FleetTrainer::new(1);
        let poisoned =
            Windowed::from_series(&vec![f64::NAN; 43], 3).expect("windowed");
        fleet
            .submit(FleetRequest::Train {
                tenant: "p".into(),
                arch: Arch::Elman,
                m: 6,
                seed: 1,
                data: poisoned,
            })
            .unwrap();
        fleet
            .submit(FleetRequest::Predict { tenant: "p".into(), data: toy_data(40, 3, 0.0) })
            .unwrap();
        let out = fleet.drain();
        assert!(
            matches!(&out[0].1, FleetOutcome::Failed { .. }),
            "all-NaN training data must fail: {:?}",
            out[0]
        );
        match &out[1].1 {
            FleetOutcome::Failed { error, report } => {
                assert_eq!(error.class(), "unknown-tenant");
                assert_eq!(report.rung, DegradationRung::Failed);
            }
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_restore_round_trips_bit_identically() {
        let mut fleet = FleetTrainer::new(2);
        fleet.submit(train_req("a", 6, 1, 0.0)).unwrap();
        fleet.drain();
        fleet
            .submit(FleetRequest::Update { tenant: "a".into(), data: toy_data(40, 3, 0.7) })
            .unwrap();
        fleet.drain();
        let snap = fleet.snapshot("a").expect("cached tenant snapshots");
        assert!(snap.rls.is_some(), "updated tenant snapshots its RLS state");

        let mut recovered = FleetTrainer::new(2);
        recovered.restore("a", &snap).unwrap();
        let bits = |b: &[f64]| b.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&recovered.model("a").unwrap().beta),
            bits(&fleet.model("a").unwrap().beta),
            "restored β must be bit-identical"
        );
        // identical further updates walk identical trajectories
        for f in [&mut fleet, &mut recovered] {
            f.submit(FleetRequest::Update { tenant: "a".into(), data: toy_data(30, 3, 1.3) })
                .unwrap();
            f.drain();
        }
        assert_eq!(
            bits(&recovered.model("a").unwrap().beta),
            bits(&fleet.model("a").unwrap().beta),
            "post-restore update trajectories must stay bit-identical"
        );
        assert!(fleet.snapshot("nobody").is_none());
    }

    #[test]
    fn restore_rejects_shape_poisoned_snapshots() {
        let mut fleet = FleetTrainer::new(1);
        fleet.submit(train_req("a", 6, 1, 0.0)).unwrap();
        fleet.drain();
        let mut snap = fleet.snapshot("a").unwrap();
        snap.beta.pop();
        let err = fleet.restore("b", &snap).unwrap_err();
        assert_eq!(
            as_solve_error(&err).map(SolveError::class),
            Some("shape-mismatch")
        );
        assert!(!fleet.has_model("b"));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut fleet = FleetTrainer::new(2);
        fleet.cache_capacity = 2;
        fleet.submit(train_req("a", 6, 1, 0.0)).unwrap();
        fleet.submit(train_req("b", 6, 2, 0.2)).unwrap();
        fleet.drain();
        // touch "a" so "b" is the LRU victim
        fleet
            .submit(FleetRequest::Predict { tenant: "a".into(), data: toy_data(40, 3, 0.0) })
            .unwrap();
        fleet.drain();
        fleet.submit(train_req("c", 6, 3, 0.4)).unwrap();
        fleet.drain();
        assert!(fleet.has_model("a"));
        assert!(!fleet.has_model("b"), "LRU entry should have been evicted");
        assert!(fleet.has_model("c"));
    }

    #[test]
    fn single_tenant_group_matches_solo_gram() {
        let data = toy_data(120, 3, 0.3);
        let solo = CpuElmTrainer {
            policy: ParallelPolicy::with_workers(4),
            block_rows: 256,
            strategy: SolveStrategy::Gram,
            lambda: 1e-6,
        };
        let (model, _) = solo.train(Arch::Elman, &data, 8, 7).unwrap();
        let mut fleet = FleetTrainer::new(4);
        fleet
            .submit(FleetRequest::Train {
                tenant: "t".into(),
                arch: Arch::Elman,
                m: 8,
                seed: 7,
                data,
            })
            .unwrap();
        let out = fleet.drain();
        assert!(matches!(out[0].1, FleetOutcome::Trained { .. }), "{:?}", out[0]);
        assert_eq!(fleet.model("t").unwrap().beta, model.beta, "β must be bitwise solo");
    }

    #[test]
    fn merge_outcome_takes_most_severe() {
        use RlsOutcome::*;
        assert_eq!(merge_outcome(Applied, Applied), Applied);
        assert_eq!(
            merge_outcome(Applied, QuarantinedInput { non_finite: 2 }),
            QuarantinedInput { non_finite: 2 }
        );
        assert_eq!(
            merge_outcome(
                QuarantinedInput { non_finite: 1 },
                QuarantinedInput { non_finite: 2 }
            ),
            QuarantinedInput { non_finite: 3 }
        );
        assert_eq!(merge_outcome(Reset, QuarantinedInput { non_finite: 1 }), Reset);
    }
}
