//! Experiment job descriptions: the (dataset × arch × M × BS × variant)
//! grid the report emitters and benches iterate.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::spec::{registry, DatasetSpec};
use crate::elm::Arch;

/// One training-run description.
#[derive(Debug, Clone)]
pub struct TrainJob {
    /// Which Table-3 dataset to generate.
    pub dataset: DatasetSpec,
    /// Which of the six architectures to train.
    pub arch: Arch,
    /// Hidden width M.
    pub m: usize,
    /// thread-block size / tile width (16 or 32 in the paper)
    pub bs: usize,
    /// "basic" (Alg 2) or "opt" (Alg 3)
    pub variant: &'static str,
    /// Random-parameter seed.
    pub seed: u64,
    /// dataset scale for measured runs (1.0 = the paper's full size)
    pub scale: f64,
}

impl TrainJob {
    /// Human-readable job label for tables and logs.
    pub fn label(&self) -> String {
        format!(
            "{}/{} M={} BS={} {}",
            self.dataset.name,
            self.arch.name(),
            self.m,
            self.bs,
            self.variant
        )
    }

    /// Number of windowed samples at this job's scale.
    pub fn n_samples(&self) -> usize {
        let n = (self.dataset.n_instances as f64 * self.scale).round() as usize;
        n.saturating_sub(self.dataset.q).max(1)
    }
}

/// Provenance label for solve/fold errors: identifies *which* training job
/// a failed block belonged to (`kind/arch q=.. M=..`), without dragging a
/// full [`TrainJob`] into the streaming pipeline. Used by the
/// [`BlockFold`](crate::robust::SolveError::BlockFold) /
/// [`FoldIncomplete`](crate::robust::SolveError::FoldIncomplete) error
/// variants.
pub fn solve_job_label(kind: &str, arch: &str, q: usize, m: usize) -> String {
    format!("{kind}/{arch} q={q} M={m}")
}

/// Fig 3 grid: all datasets × all archs, M = 50, Basic + Opt(BS 16/32).
pub fn fig3_jobs(scale: f64, seed: u64) -> Vec<TrainJob> {
    let mut jobs = Vec::new();
    for d in registry() {
        for arch in crate::elm::ALL_ARCHS {
            for (variant, bs) in [("basic", 16), ("opt", 16), ("opt", 32)] {
                jobs.push(TrainJob {
                    dataset: d.clone(),
                    arch,
                    m: 50,
                    bs,
                    variant,
                    seed,
                    scale,
                });
            }
        }
    }
    jobs
}

/// Fig 4 grid: M sweep at BS = 32 (opt).
pub fn fig4_jobs(scale: f64, seed: u64) -> Vec<TrainJob> {
    let mut jobs = Vec::new();
    for d in registry() {
        // the M sweep is lowered for Q = 10 datasets (manifest grid)
        if d.q != 10 {
            continue;
        }
        for arch in crate::elm::ALL_ARCHS {
            for m in [5usize, 10, 20, 50, 100] {
                jobs.push(TrainJob {
                    dataset: d.clone(),
                    arch,
                    m,
                    bs: 32,
                    variant: "opt",
                    seed,
                    scale,
                });
            }
        }
    }
    jobs
}

/// Table 4 grid: per-dataset M selection, 5 repetitions.
pub fn table4_jobs(scale: f64, seeds: &[u64]) -> Vec<TrainJob> {
    let mut jobs = Vec::new();
    for d in registry() {
        for arch in crate::elm::ALL_ARCHS {
            for &seed in seeds {
                jobs.push(TrainJob {
                    dataset: d.clone(),
                    arch,
                    m: d.table4_m,
                    bs: 32,
                    variant: "opt",
                    seed,
                    scale,
                });
            }
        }
    }
    jobs
}

/// Resolve a dataset by name or fail with the known names.
pub fn dataset(name: &str) -> Result<DatasetSpec> {
    crate::data::spec::by_name(name).ok_or_else(|| {
        let names: Vec<&str> = registry().iter().map(|d| d.name).collect();
        anyhow::anyhow!("unknown dataset {name:?}; known: {names:?}")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_grid_size() {
        // 10 datasets × 6 archs × 3 variant-configs
        assert_eq!(fig3_jobs(1.0, 0).len(), 180);
    }

    #[test]
    fn fig4_grid_only_q10() {
        let jobs = fig4_jobs(1.0, 0);
        assert!(jobs.iter().all(|j| j.dataset.q == 10));
        // 6 Q=10 datasets × 6 archs × 5 Ms
        assert_eq!(jobs.len(), 6 * 6 * 5);
    }

    #[test]
    fn table4_grid_m_selection() {
        let jobs = table4_jobs(1.0, &[1, 2, 3, 4, 5]);
        assert_eq!(jobs.len(), 10 * 6 * 5);
        for j in &jobs {
            assert_eq!(j.m, j.dataset.table4_m);
        }
    }

    #[test]
    fn n_samples_scales() {
        let j = &fig3_jobs(0.1, 0)[0];
        let full = &fig3_jobs(1.0, 0)[0];
        assert!(j.n_samples() < full.n_samples());
        assert!(j.n_samples() > 0);
    }

    #[test]
    fn solve_job_label_carries_provenance() {
        let l = solve_job_label("elm_gram", "elman", 10, 50);
        assert_eq!(l, "elm_gram/elman q=10 M=50");
    }

    #[test]
    fn dataset_lookup() {
        assert!(dataset("aemo").is_ok());
        let err = dataset("nope").unwrap_err().to_string();
        assert!(err.contains("aemo"));
    }
}
