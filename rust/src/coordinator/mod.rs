//! The L3 coordinator: streaming parallel ELM training over PJRT.
//!
//! The paper's contribution is the parallel H kernel; the coordinator is
//! what makes it a deployable trainer:
//!
//! * [`batcher`] — slices a windowed dataset into the fixed-shape row
//!   blocks the AOT executables expect, zero-padding the tail block and
//!   emitting the validity mask the `elm_gram` graph applies.
//! * [`accumulator`] — folds per-block (HᵀH, HᵀY) partials (or raw H
//!   blocks via TSQR) into the normal-equation state and solves for β.
//! * [`pipeline`] — `PrElmTrainer`, the parallel counterpart of
//!   `elm::SrElmModel::train`: block producer → engine pool → accumulator,
//!   with the Fig-6 phase breakdown recorded per run; and `CpuElmTrainer`,
//!   the same pipeline with the batched `arch::h_block` kernels on worker
//!   threads instead of PJRT (offline / no-artifact deployments).
//! * [`job`] — experiment descriptions (arch × dataset × M × variant) used
//!   by the report emitters and benches.
//! * [`fleet`] — `FleetTrainer`, the multi-tenant front end: many small
//!   independent models grouped by shape and trained as block-diagonal
//!   batched streams, with per-tenant β bit-identical to solo training,
//!   an LRU model cache, and RLS warm updates for hot tenants.
//! * [`service`] — `FleetService`, the deadline-aware async front end
//!   wrapped around `FleetTrainer`: bounded admission queue with typed
//!   backpressure, logical-tick deadlines and retry/backoff, an overload
//!   ladder (shed → downgrade → reject), and the crash-safe tenant
//!   journal ([`crate::robust::journal`]).
//!
//! `CpuElmTrainer` honors the [`crate::linalg::Precision`] knob on its
//! [`crate::linalg::ParallelPolicy`]: under `MixedF32` every
//! Gram-pipeline fold (the Gram strategy, the NARMAX passes, the
//! TSQR/DirectQr rank-deficiency fallbacks) streams H blocks over the
//! f32 wire (`gram_widen`/`t_matvec_widen`, f64 accumulation — the
//! artifact ABI's format), still bit-identical across worker counts.

#![forbid(unsafe_code)]
#![deny(missing_docs)]

pub mod accumulator;
pub mod batcher;
pub mod fleet;
pub mod job;
pub mod pipeline;
pub mod service;

pub use accumulator::{GramAccumulator, SolveStrategy};
pub use batcher::{Block, RowBlockBatcher};
pub use fleet::{FleetOutcome, FleetRequest, FleetTrainer, GroupKey};
pub use service::{
    Completion, FleetService, OverloadRung, ServiceConfig, ServiceError, ServiceStats,
};
pub use job::TrainJob;
pub use pipeline::{CpuElmTrainer, PrElmTrainer, TrainBreakdown};
