//! `PrElmTrainer` — the parallel ELM trainer (Basic/Opt-PR-ELM, L3 side).
//!
//! Training streams the dataset through the AOT `elm_gram` executables:
//!
//! ```text
//!   RowBlockBatcher ──▶ worker threads ──▶ EnginePool (PJRT) ──▶ partials
//!        (producer)      (one per engine)        │
//!                                                ▼
//!                       in-order fold ──▶ GramAccumulator ──▶ β solve
//! ```
//!
//! Partials are folded in block order (buffered re-sequencing), so the
//! result is bit-deterministic regardless of worker count — the §7.3
//! robustness requirement.
//!
//! NARMAX trains with the same two-pass ELS as the sequential baseline;
//! the residuals for pass 2 come from a parallel `elm_predict` sweep with
//! pass-1 β (one refinement pass — DESIGN.md §2).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::accumulator::{GramAccumulator, SolveStrategy};
use crate::coordinator::batcher::{Block, RowBlockBatcher};
use crate::coordinator::job::solve_job_label;
use crate::data::window::Windowed;
use crate::elm::arch::{block_ranges, h_block_range_policy, HBlock};
use crate::elm::trainer::{shift_history, SrElmModel};
use crate::elm::{Arch, ElmParams, TrainOptions};
use crate::linalg::matrix32::MatrixF32;
use crate::linalg::policy::{par_map, par_map_isolated};
use crate::linalg::solve::{diag_verdict, lstsq_qr_report};
use crate::linalg::{Matrix, ParallelPolicy, Precision, TsqrAccumulator};
use crate::robust::inject;
use crate::robust::ladder::{all_finite, ridge_ladder_solve};
use crate::robust::quarantine;
use crate::robust::{DeficiencyVerdict, SolveError, SolveReport, SolveStrategyKind};
use crate::runtime::{ArtifactMeta, Buf, EnginePool, Manifest};

/// Fig-6 style phase breakdown of one training run (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainBreakdown {
    /// random parameter initialization
    pub init_s: f64,
    /// host→device literal creation (engine h2d delta)
    pub h2d_s: f64,
    /// artifact execution (H + partial sums)
    pub exec_s: f64,
    /// device→host output fetch
    pub d2h_s: f64,
    /// β solve (Cholesky/QR on the accumulated system)
    pub solve_s: f64,
    /// end-to-end wall clock
    pub total_s: f64,
    /// number of row blocks processed
    pub blocks: usize,
    /// how β was produced: strategy, degradation rung, rank verdict,
    /// effective λ, retry count, quarantined rows (see [`SolveReport`])
    pub solve_report: SolveReport,
}

/// The parallel trainer: owns the manifest + engine pool handles.
pub struct PrElmTrainer {
    pool: EnginePool,
    manifest: Manifest,
    /// ridge λ for the Gram solve
    pub lambda: f64,
    /// run two-pass ELS for NARMAX (needs a matching elm_predict artifact)
    pub narmax_els: bool,
}

impl PrElmTrainer {
    /// Load the manifest under `artifacts_dir` and spin up `workers`
    /// engines.
    pub fn new(artifacts_dir: &Path, workers: usize) -> Result<PrElmTrainer> {
        Ok(PrElmTrainer {
            pool: EnginePool::new(artifacts_dir, workers)?,
            manifest: Manifest::load(artifacts_dir)?,
            lambda: 1e-6,
            narmax_els: true,
        })
    }

    /// The engine pool executing the artifacts.
    pub fn pool(&self) -> &EnginePool {
        &self.pool
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Parallel ELM training; returns the trained model and the phase
    /// breakdown.
    pub fn train(
        &self,
        arch: Arch,
        data: &Windowed,
        m: usize,
        seed: u64,
    ) -> Result<(SrElmModel, TrainBreakdown)> {
        let t_all = Instant::now();
        let meta = self
            .manifest
            .find("elm_gram", arch.name(), data.q, m)
            .context("selecting gram artifact")?
            .clone();
        let stats0 = self.pool.stats();

        let t0 = Instant::now();
        let params = ElmParams::init(arch, data.s, data.q, m, seed);
        let init_s = t0.elapsed().as_secs_f64();

        let mut bd = TrainBreakdown { init_s, ..Default::default() };

        // pass 1 (and only pass for non-NARMAX): zero error history
        let beta = self.gram_pass(&meta, &params, data, None, &mut bd)?;
        let beta = if arch == Arch::Narmax && self.narmax_els {
            // residuals from a parallel predict sweep with pass-1 β
            let model1 = SrElmModel { params: params.clone(), beta };
            let yhat = self.predict_with_ehist(&model1, data, None)?;
            let resid: Vec<f32> = data
                .y
                .iter()
                .zip(&yhat)
                .map(|(&y, &p)| y - p as f32)
                .collect();
            let ehist = shift_history(&resid, data.q);
            self.gram_pass(&meta, &params, data, Some(&ehist), &mut bd)?
        } else {
            beta
        };

        let stats1 = self.pool.stats();
        bd.h2d_s = stats1.h2d_s - stats0.h2d_s;
        bd.exec_s = stats1.exec_s - stats0.exec_s;
        bd.d2h_s = stats1.d2h_s - stats0.d2h_s;
        bd.total_s = t_all.elapsed().as_secs_f64();
        Ok((SrElmModel { params, beta }, bd))
    }

    /// One streaming gram pass → β.
    fn gram_pass(
        &self,
        meta: &ArtifactMeta,
        params: &ElmParams,
        data: &Windowed,
        ehist: Option<&[f32]>,
        bd: &mut TrainBreakdown,
    ) -> Result<Vec<f64>> {
        let m = params.m;
        // NARMAX needs stronger regularization (see TrainOptions::NARMAX_RIDGE)
        let lambda = if params.arch == Arch::Narmax {
            self.lambda.max(crate::elm::TrainOptions::NARMAX_RIDGE)
        } else {
            self.lambda
        };
        let mut acc = GramAccumulator::new(m, lambda);
        let blocks: Vec<Block> = RowBlockBatcher::new(data, meta.rows).collect();
        bd.blocks += blocks.len();
        // provenance label carried by every fold error from this pass
        let label = solve_job_label(&meta.kind, &meta.arch, meta.q, m);
        let quarantined = std::sync::atomic::AtomicUsize::new(0);

        let n_workers = self.pool.n_workers();
        let (result_tx, result_rx) = channel::<(usize, Result<(Vec<f32>, Vec<f32>, usize)>)>();

        std::thread::scope(|scope| -> Result<()> {
            // dispatch: blocks are sharded over workers by index so each
            // worker thread drives its own engine (cache affinity)
            for wid in 0..n_workers {
                let tx = result_tx.clone();
                let blocks = &blocks;
                let pool = &self.pool;
                let meta = &meta;
                let params = &params;
                let quarantined = &quarantined;
                scope.spawn(move || {
                    for (idx, block) in blocks.iter().enumerate() {
                        if idx % n_workers != wid {
                            continue;
                        }
                        let res = (|| {
                            // mask off poisoned rows before they reach the
                            // artifact (the gram graph multiplies rows by
                            // the mask, so a quarantined row contributes
                            // exactly zero)
                            let cleaned;
                            let block = if block.has_non_finite() {
                                let mut b = block.clone();
                                let dropped = b.quarantine_non_finite();
                                quarantined.fetch_add(
                                    dropped,
                                    std::sync::atomic::Ordering::Relaxed,
                                );
                                cleaned = b;
                                &cleaned
                            } else {
                                block
                            };
                            let inputs =
                                assemble_gram_inputs(meta, params, block, ehist, data.q)?;
                            let out = pool.run_on(wid, &meta.name, inputs)?;
                            let hth = out
                                .first()
                                .ok_or_else(|| anyhow!("gram artifact returned no outputs"))?;
                            let hty =
                                out.get(1).ok_or_else(|| anyhow!("gram missing hty"))?;
                            Ok((hth.data.clone(), hty.data.clone(), block.valid))
                        })();
                        if tx.send((idx, res)).is_err() {
                            return; // receiver gone: abort quietly
                        }
                    }
                });
            }
            drop(result_tx);

            // in-order fold for determinism; every error carries its
            // block index, block shape, and the job label
            let mut pending: BTreeMap<usize, (Vec<f32>, Vec<f32>, usize)> = BTreeMap::new();
            let mut next = 0usize;
            for (idx, res) in result_rx {
                let part = res.map_err(|e| {
                    anyhow::Error::from(SolveError::block_fold(
                        idx, meta.rows, m, &label, &e,
                    ))
                })?;
                pending.insert(idx, part);
                while let Some(p) = pending.remove(&next) {
                    acc.push_partials(&p.0, &p.1, p.2).map_err(|e| {
                        anyhow::Error::from(SolveError::block_fold(
                            next, meta.rows, m, &label, &e,
                        ))
                    })?;
                    next += 1;
                }
            }
            if next != blocks.len() {
                return Err(SolveError::FoldIncomplete {
                    folded: next,
                    total: blocks.len(),
                    job: label.clone(),
                }
                .into());
            }
            Ok(())
        })?;

        let t0 = Instant::now();
        let (beta, mut report) = acc.solve_reported()?;
        report.quarantined_rows += quarantined.load(std::sync::atomic::Ordering::Relaxed);
        bd.solve_report = report;
        bd.solve_s += t0.elapsed().as_secs_f64();
        Ok(beta)
    }

    /// Parallel block predict through the `elm_predict` artifacts.
    /// For NARMAX, `ehist` supplies the error feedback (None → zeros —
    /// callers run the two-pass refinement, see `predict`).
    pub fn predict_with_ehist(
        &self,
        model: &SrElmModel,
        data: &Windowed,
        ehist: Option<&[f32]>,
    ) -> Result<Vec<f64>> {
        let params = &model.params;
        let meta = self
            .manifest
            .find("elm_predict", params.arch.name(), data.q, params.m)
            .context("selecting predict artifact")?
            .clone();
        let beta_f32: Vec<f32> = model.beta.iter().map(|&b| b as f32).collect();
        let blocks: Vec<Block> = RowBlockBatcher::new(data, meta.rows).collect();
        let mut out = vec![0f64; data.n];
        let n_workers = self.pool.n_workers();
        let (tx, rx) = channel::<(usize, Result<Vec<f32>>)>();

        std::thread::scope(|scope| -> Result<()> {
            for wid in 0..n_workers {
                let tx = tx.clone();
                let blocks = &blocks;
                let pool = &self.pool;
                let meta = &meta;
                let beta_f32 = &beta_f32;
                scope.spawn(move || {
                    for (idx, block) in blocks.iter().enumerate() {
                        if idx % n_workers != wid {
                            continue;
                        }
                        let res = (|| {
                            let mut inputs =
                                assemble_h_inputs(meta, params, block, ehist, data.q)?;
                            inputs.push(Buf::new(vec![params.m], beta_f32.clone()));
                            let o = pool.run_on(wid, &meta.name, inputs)?;
                            Ok(o.into_iter()
                                .next()
                                .ok_or_else(|| anyhow!("predict returned nothing"))?
                                .data)
                        })();
                        if tx.send((idx, res)).is_err() {
                            return;
                        }
                    }
                });
            }
            drop(tx);
            for (idx, res) in rx {
                let yhat = res?;
                let block = &blocks[idx];
                for r in 0..block.valid {
                    out[block.offset + r] = yhat[r] as f64;
                }
            }
            Ok(())
        })?;
        Ok(out)
    }

    /// One-step-ahead predictions; NARMAX refines once with the first
    /// pass's residuals (parallel ELS, DESIGN.md §2).
    pub fn predict(&self, model: &SrElmModel, data: &Windowed) -> Result<Vec<f64>> {
        if model.params.arch == Arch::Narmax {
            let y0 = self.predict_with_ehist(model, data, None)?;
            let resid: Vec<f32> =
                data.y.iter().zip(&y0).map(|(&y, &p)| y - p as f32).collect();
            let ehist = shift_history(&resid, data.q);
            return self.predict_with_ehist(model, data, Some(&ehist));
        }
        self.predict_with_ehist(model, data, None)
    }

    /// Test RMSE through the parallel predict path.
    pub fn rmse(&self, model: &SrElmModel, data: &Windowed) -> Result<f64> {
        let pred = self.predict(model, data)?;
        let truth: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        Ok(crate::data::stats::rmse(&pred, &truth))
    }
}

/// CPU-native parallel ELM trainer: the same block → accumulate → solve
/// pipeline as [`PrElmTrainer`], with the H blocks produced by the batched
/// [`h_block`](crate::elm::arch::h_block) kernels on scoped worker threads
/// instead of PJRT artifacts.
/// This is the offline twin of the coordinator, and the path that
/// exercises the blocked linalg substrate end to end.
///
/// # Determinism (§7.3)
///
/// Block boundaries are fixed by `block_rows` alone, per-block work is
/// independent, and every reduction is worker-count invariant — Gram
/// partials fold in block order, the TSQR strategy reduces over a fixed
/// pairwise tree, the DirectQr strategy runs the threaded QR whose GEMM
/// splits are fixed schedules — so β is bit-identical for any
/// `policy.workers`. DirectQr additionally produces the *same bits* as
/// the sequential `lstsq_qr` on the assembled H (the e2e conformance
/// anchor).
///
/// # Mixed precision
///
/// `policy.precision` selects the wire format of the whole block pipeline.
/// Under [`Precision::MixedF32`] every H block is **f32-born**: the arch
/// kernels write their activations straight into `MatrixF32`
/// ([`crate::elm::arch::h_block_f32`]) — no f64 materialization and no
/// per-block rounding pass anywhere on the hot path — and every consumer
/// takes the f32 block as-is. The Gram fold runs
/// `MatrixF32::gram_widen`/`t_matvec_widen` (f64 accumulation, the
/// artifact ABI's format), the TSQR strategy feeds f32 leaves to
/// [`TsqrAccumulator::reduce_f32`] (widened exactly at the leaf QR, R/z
/// f64), DirectQr widens exactly at assembly, and predictions use
/// `matvec_widen`. Block memory and wire traffic halve end to end.
///
/// The f32 wire only changes storage width, never block boundaries or
/// fold order, so β stays bit-identical across worker counts; and because
/// H entries are f32 nonlinearity outputs (exactly representable on
/// either wire), the TSQR and DirectQr solves are **bit-identical to
/// their f64-precision twins** — they remain the reference paths the e2e
/// suite anchors to. The Gram strategy's per-block partials are exact
/// re-encodings too: both wires run the same fixed `GRAM_ROW_CHUNK`
/// schedule (`gram_with` / `gram_widen`), so the partials — and hence β —
/// are bit-identical at any `block_rows`.
pub struct CpuElmTrainer {
    /// the one worker-count (+ wire precision) knob, shared with every
    /// threaded linalg path
    pub policy: ParallelPolicy,
    /// samples per H block (fixed: part of the deterministic result)
    pub block_rows: usize,
    /// which β-solve pipeline to run
    pub strategy: SolveStrategy,
    /// ridge λ for the Gram strategy (NARMAX raises it to its floor)
    pub lambda: f64,
}

impl CpuElmTrainer {
    /// Trainer with `workers` threads and the default TSQR strategy.
    pub fn new(workers: usize) -> CpuElmTrainer {
        CpuElmTrainer::with_policy(ParallelPolicy::with_workers(workers))
    }

    /// Trainer with an explicit policy (worker count + wire precision).
    pub fn with_policy(policy: ParallelPolicy) -> CpuElmTrainer {
        CpuElmTrainer {
            policy,
            block_rows: 256,
            strategy: SolveStrategy::Tsqr,
            lambda: 1e-6,
        }
    }

    /// Parallel CPU training; returns the trained model and the phase
    /// breakdown (`exec_s` = H-block computation, `solve_s` = reduction +
    /// β solve).
    pub fn train(
        &self,
        archk: Arch,
        data: &Windowed,
        m: usize,
        seed: u64,
    ) -> Result<(SrElmModel, TrainBreakdown)> {
        let t_all = Instant::now();
        // fault-inject hook: corrupt the raw window *before* screening, so
        // the quarantine is exercised exactly like a poisoned real dataset
        // (no-op without the `fault-inject` feature)
        let injected = inject_data_window(data);
        let data = injected.as_ref().unwrap_or(data);
        // input quarantine: drop non-finite rows up front — one NaN sample
        // would otherwise turn the whole Gram fold (and β) into NaN. The
        // clean path borrows `data` untouched (bit-identity).
        let screened = quarantine::screen(data)?;
        let quarantined = screened.dropped();
        let data = screened.data();
        let t0 = Instant::now();
        let params = ElmParams::init(archk, data.s, data.q, m, seed);
        let mut bd =
            TrainBreakdown { init_s: t0.elapsed().as_secs_f64(), ..Default::default() };

        let beta = if archk == Arch::Narmax {
            // two-pass ELS: pass 1 keeps its H blocks so the residual
            // sweep is an H₁·β₁ matvec, not a full H recomputation
            let lambda = self.lambda.max(TrainOptions::NARMAX_RIDGE);
            let yhat = self.narmax_pass1(&params, data, lambda, &mut bd)?;
            let resid: Vec<f32> =
                data.y.iter().zip(&yhat).map(|(&y, &p)| y - p as f32).collect();
            let ehist = shift_history(&resid, data.q);
            self.solve_pass(&params, data, Some(&ehist), &mut bd)?
        } else {
            self.solve_pass(&params, data, None, &mut bd)?
        };
        bd.solve_report.quarantined_rows += quarantined;
        bd.total_s = t_all.elapsed().as_secs_f64();
        Ok((SrElmModel { params, beta }, bd))
    }

    /// NARMAX pass 1 (e ≡ 0): parallel H blocks → in-order Gram fold →
    /// ridge β₁ → in-order H·β₁ predictions, all from one set of blocks.
    fn narmax_pass1(
        &self,
        params: &ElmParams,
        data: &Windowed,
        lambda: f64,
        bd: &mut TrainBreakdown,
    ) -> Result<Vec<f64>> {
        let m = params.m;
        let ranges = block_ranges(data.n, self.block_rows);
        bd.blocks += ranges.len();
        let t0 = Instant::now();
        let (blocks, exec_retries) =
            par_map_isolated(&ranges, self.policy, |idx, &(lo, hi)| {
                inject::maybe_panic(inject::Site::Worker, idx);
                Ok(compute_h_block_inj(
                    params,
                    data,
                    None,
                    lo,
                    hi,
                    self.policy,
                    idx,
                ))
            })?;
        let idx: Vec<usize> = (0..blocks.len()).collect();
        let partials = par_map(idx, self.policy, |i| {
            let (h, y) = &blocks[i];
            checked_gram_partials(h, y, i, m)
        })?;
        bd.exec_s += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let (g, c) = fold_partials(&partials, m)?;
        let mut report = SolveReport::new(SolveStrategyKind::Gram);
        report.retries = exec_retries;
        // Gram is the primary strategy here, so the base λ is rung 0 of
        // the ladder; pass 2's solve overwrites this report on success
        let ladder = ridge_ladder_solve(&g, &c, lambda, true, &mut report);
        bd.solve_report = report;
        let beta1 = ladder?;
        let mut yhat = Vec::with_capacity(data.n);
        for (h, _) in &blocks {
            yhat.extend(h.matvec(&beta1));
        }
        bd.solve_s += t1.elapsed().as_secs_f64();
        Ok(yhat)
    }

    /// One streaming pass over the dataset → β.
    fn solve_pass(
        &self,
        params: &ElmParams,
        data: &Windowed,
        ehist: Option<&[f32]>,
        bd: &mut TrainBreakdown,
    ) -> Result<Vec<f64>> {
        let ranges = block_ranges(data.n, self.block_rows);
        bd.blocks += ranges.len();
        // NARMAX always takes the ridge path (see TrainOptions::NARMAX_RIDGE)
        let use_gram =
            self.strategy == SolveStrategy::Gram || params.arch == Arch::Narmax;

        let lambda = if params.arch == Arch::Narmax {
            self.lambda.max(TrainOptions::NARMAX_RIDGE)
        } else {
            self.lambda
        };

        if use_gram {
            return self.gram_solve(
                params,
                data,
                ehist,
                lambda,
                true,
                SolveReport::new(SolveStrategyKind::Gram),
                bd,
            );
        }
        let t0 = Instant::now();
        let (blocks, exec_retries) =
            par_map_isolated(&ranges, self.policy, |idx, &(lo, hi)| {
                inject::maybe_panic(inject::Site::Worker, idx);
                Ok(compute_h_block_inj(
                    params,
                    data,
                    ehist,
                    lo,
                    hi,
                    self.policy,
                    idx,
                ))
            })?;
        bd.exec_s += t0.elapsed().as_secs_f64();
        self.solve_blocks(params, data, ehist, lambda, blocks, exec_retries, bd)
    }

    /// The post-block half of [`solve_pass`](Self::solve_pass) for the
    /// factorization strategies: consume already-computed H blocks (in
    /// block order) and produce β via DirectQr assembly or the TSQR
    /// reduction, falling back to the chunked-Gram ridge ladder (which
    /// recomputes H for this dataset) on rank trouble. Shared with the
    /// fleet trainer, whose grouped streams compute many tenants' blocks
    /// in one flattened `par_map` and then finish each tenant through this
    /// exact code path — that sharing is the fleet's bit-identity
    /// guarantee for the TSQR/DirectQr strategies.
    pub(crate) fn solve_blocks(
        &self,
        params: &ElmParams,
        data: &Windowed,
        ehist: Option<&[f32]>,
        lambda: f64,
        blocks: Vec<(HBlock, Vec<f64>)>,
        exec_retries: u32,
        bd: &mut TrainBreakdown,
    ) -> Result<Vec<f64>> {
        debug_assert_ne!(
            self.strategy,
            SolveStrategy::Gram,
            "the Gram strategy folds partials without materializing blocks"
        );
        let m = params.m;
        if self.strategy == SolveStrategy::DirectQr {
            // assemble H in block order and run the threaded direct QR —
            // bit-identical to the sequential `lstsq_qr` on the same H at
            // any worker count (the e2e conformance anchor; f32-born
            // blocks widen exactly at assembly, so MixedF32 keeps the
            // anchor bit for bit). The internal rank guard falls back to
            // the deterministic chunked-Gram ridge, so no outer fallback
            // is needed on Ok.
            let t1 = Instant::now();
            let mut h = Matrix::zeros(data.n, m);
            let mut y = Vec::with_capacity(data.n);
            let mut row = 0usize;
            // consume the block list so each block frees right after its
            // rows are copied (halves the transient 2x H footprint);
            // f32-born rows widen element-wise straight into h — no
            // intermediate f64 block
            for (hb, yb) in blocks {
                match hb {
                    HBlock::F64(hb) => {
                        for r in 0..hb.rows {
                            h.row_mut(row + r).copy_from_slice(hb.row(r));
                        }
                        row += hb.rows;
                    }
                    HBlock::F32(hb) => {
                        for r in 0..hb.rows {
                            let dst = h.row_mut(row + r);
                            for (d, &s) in dst.iter_mut().zip(hb.row(r)) {
                                *d = s as f64;
                            }
                        }
                        row += hb.rows;
                    }
                }
                y.extend(yb);
            }
            if row < m {
                return Err(SolveError::Underdetermined { rows: row, cols: m }.into());
            }
            if row != y.len() {
                // a truncated block shipped fewer H rows than targets —
                // refuse to solve a silently misaligned system
                return Err(SolveError::ShapeMismatch {
                    context: "h assembly",
                    detail: format!("assembled {row} H rows but {} targets", y.len()),
                }
                .into());
            }
            let out = lstsq_qr_report(&h, &y, self.policy);
            bd.solve_s += t1.elapsed().as_secs_f64();
            return match out {
                Ok((beta, mut report)) => {
                    report.retries += exec_retries;
                    bd.solve_report = report;
                    Ok(beta)
                }
                Err(_) => {
                    let mut report = SolveReport::new(SolveStrategyKind::Qr);
                    report.retries = exec_retries + 1;
                    self.gram_solve(
                        params,
                        data,
                        ehist,
                        lambda.max(1e-8),
                        false,
                        report,
                        bd,
                    )
                }
            };
        }

        let t1 = Instant::now();
        // the reduction takes the blocks on the wire they were born on:
        // f32 leaves go straight to reduce_f32 (exact widen at the leaf
        // QR), so no f64 H block ever materializes under MixedF32
        let acc = match self.policy.precision {
            Precision::F64 => TsqrAccumulator::reduce(
                m,
                blocks.into_iter().map(|(h, y)| (h.into_f64(), y)).collect(),
                self.policy,
            )?,
            Precision::MixedF32 => TsqrAccumulator::reduce_f32(
                m,
                blocks
                    .into_iter()
                    .map(|(h, y)| match h {
                        HBlock::F32(h) => (h, y),
                        HBlock::F64(_) => {
                            unreachable!("MixedF32 pipeline produced an f64 block")
                        }
                    })
                    .collect(),
                self.policy,
            )?,
        };
        if acc.rows_seen() < m {
            return Err(SolveError::Underdetermined { rows: acc.rows_seen(), cols: m }
                .into());
        }
        // same rank guard as lstsq_qr: collapsed random features make R's
        // diagonal underflow — and a poisoned leaf makes it non-finite;
        // either way fall back to the ridge ladder on the normal equations
        // instead of amplifying noise or propagating NaN into β. The
        // fallback recomputes H — a deliberate trade: precomputing Gram
        // partials "just in case" would tax every healthy run for a rare
        // degenerate one.
        let mut report = SolveReport::new(SolveStrategyKind::Tsqr);
        report.retries = exec_retries;
        report.verdict =
            acc.r_factor().map_or(DeficiencyVerdict::NotChecked, diag_verdict);
        if report.verdict.is_clean() {
            if let Ok(beta) = acc.solve() {
                if all_finite(&beta) {
                    bd.solve_s += t1.elapsed().as_secs_f64();
                    bd.solve_report = report;
                    return Ok(beta);
                }
            }
            report.retries += 1;
        }
        bd.solve_s += t1.elapsed().as_secs_f64();
        self.gram_solve(params, data, ehist, lambda.max(1e-8), false, report, bd)
    }

    /// Parallel Gram pass: per-block (HᵀH, HᵀY) partials computed on
    /// worker threads with retry-once panic isolation (exec_s) — over the
    /// f32 wire when the policy says [`Precision::MixedF32`] — folded in
    /// block order and solved through the ridge ladder (solve_s).
    ///
    /// When Gram is the primary strategy, `primary_is_ridge` is true and
    /// the base λ is rung 0 of the ladder (`DegradationRung::Primary`); as
    /// the TSQR/DirectQr rank-deficiency fallback the caller passes its
    /// report (strategy + verdict + retries so far) and every rung counts
    /// as degradation. `bd.solve_report` is set either way — including on
    /// ladder exhaustion, so a typed failure still reports its attempts.
    #[allow(clippy::too_many_arguments)]
    fn gram_solve(
        &self,
        params: &ElmParams,
        data: &Windowed,
        ehist: Option<&[f32]>,
        lambda: f64,
        primary_is_ridge: bool,
        mut report: SolveReport,
        bd: &mut TrainBreakdown,
    ) -> Result<Vec<f64>> {
        let m = params.m;
        let ranges = block_ranges(data.n, self.block_rows);
        let t0 = Instant::now();
        let (partials, retries) =
            par_map_isolated(&ranges, self.policy, |idx, &(lo, hi)| {
                inject::maybe_panic(inject::Site::Worker, idx);
                let (h, y) = compute_h_block_inj(
                    params,
                    data,
                    ehist,
                    lo,
                    hi,
                    self.policy,
                    idx,
                );
                checked_gram_partials(&h, &y, idx, m)
            })?;
        report.retries += retries;
        bd.exec_s += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let (g, c) = fold_partials(&partials, m)?;
        let beta = ridge_ladder_solve(&g, &c, lambda, primary_is_ridge, &mut report);
        bd.solve_s += t1.elapsed().as_secs_f64();
        bd.solve_report = report;
        beta
    }

    /// Parallel block predictions: H block × β per chunk, in order.
    fn predict_blocks(
        &self,
        model: &SrElmModel,
        data: &Windowed,
        ehist: Option<&[f32]>,
    ) -> Result<Vec<f64>> {
        let ranges = block_ranges(data.n, self.block_rows);
        let parts = par_map(ranges, self.policy, |(lo, hi)| {
            let (h, _y) =
                compute_h_block(&model.params, data, ehist, lo, hi, self.policy);
            Ok(h.matvec(&model.beta))
        })?;
        Ok(parts.concat())
    }

    /// One-step-ahead predictions; NARMAX refines once with the first
    /// pass's residuals (parallel ELS, mirroring `PrElmTrainer::predict`).
    pub fn predict(&self, model: &SrElmModel, data: &Windowed) -> Result<Vec<f64>> {
        if model.params.arch == Arch::Narmax {
            let y0 = self.predict_blocks(model, data, None)?;
            let resid: Vec<f32> =
                data.y.iter().zip(&y0).map(|(&y, &p)| y - p as f32).collect();
            let ehist = shift_history(&resid, data.q);
            return self.predict_blocks(model, data, Some(&ehist));
        }
        self.predict_blocks(model, data, None)
    }

    /// Test RMSE through the parallel CPU predict path.
    pub fn rmse(&self, model: &SrElmModel, data: &Windowed) -> Result<f64> {
        let pred = self.predict(model, data)?;
        let truth: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        Ok(crate::data::stats::rmse(&pred, &truth))
    }
}

/// In-block-order fold of (HᵀH, HᵀY, rows) partials — the fold order is
/// fixed by block index, never by worker schedule (§7.3 determinism).
pub(crate) fn fold_partials(
    partials: &[(Matrix, Vec<f64>, usize)],
    m: usize,
) -> Result<(Matrix, Vec<f64>)> {
    let mut g = Matrix::zeros(m, m);
    let mut c = vec![0.0f64; m];
    let mut rows = 0usize;
    for (gl, cl, rl) in partials {
        for (gv, lv) in g.data_mut().iter_mut().zip(gl.data()) {
            *gv += lv;
        }
        for (cv, lv) in c.iter_mut().zip(cl) {
            *cv += lv;
        }
        rows += rl;
    }
    if rows < m {
        return Err(SolveError::Underdetermined { rows, cols: m }.into());
    }
    Ok((g, c))
}

/// [`block_gram_partials`] with a typed shape guard (a truncated block's H
/// no longer matches its targets) and the `GramPartial` fault-inject hook
/// applied to the partial, keyed by the block index.
pub(crate) fn checked_gram_partials(
    h: &HBlock,
    y: &[f64],
    idx: usize,
    m: usize,
) -> Result<(Matrix, Vec<f64>, usize)> {
    if h.rows() != y.len() {
        return Err(SolveError::ShapeMismatch {
            context: "gram partials",
            detail: format!("block {idx}: {} H rows vs {} targets", h.rows(), y.len()),
        }
        .into());
    }
    let (mut g, c, rows) = block_gram_partials(h, y);
    inject::corrupt_slice_f64(inject::Site::GramPartial, idx, g.data_mut(), m, m);
    Ok((g, c, rows))
}

/// [`compute_h_block`] plus the `HBlock` fault-inject hooks: payload
/// corruption on the block's own wire, then row truncation — both keyed by
/// the block index (worker-count invariant), both no-ops without the
/// `fault-inject` feature.
pub(crate) fn compute_h_block_inj(
    params: &ElmParams,
    data: &Windowed,
    ehist: Option<&[f32]>,
    lo: usize,
    hi: usize,
    policy: ParallelPolicy,
    idx: usize,
) -> (HBlock, Vec<f64>) {
    let (mut h, y) = compute_h_block(params, data, ehist, lo, hi, policy);
    match &mut h {
        HBlock::F64(hb) => {
            let (r, c) = (hb.rows, hb.cols);
            inject::corrupt_slice_f64(inject::Site::HBlock, idx, hb.data_mut(), r, c);
        }
        HBlock::F32(hb) => {
            let (r, c) = (hb.rows, hb.cols);
            inject::corrupt_slice_f32(inject::Site::HBlock, idx, hb.data_mut(), r, c);
        }
    }
    let rows = h.rows();
    let keep = inject::truncated_rows(inject::Site::HBlock, idx, rows);
    if keep < rows {
        h = truncate_block(h, keep);
    }
    (h, y)
}

/// Drop all but the first `keep` rows of a block (the `TruncateRows`
/// fault), on the block's own wire.
fn truncate_block(h: HBlock, keep: usize) -> HBlock {
    match h {
        HBlock::F64(hb) => {
            let cols = hb.cols;
            HBlock::F64(hb.submatrix(0, keep, 0, cols))
        }
        HBlock::F32(hb) => {
            let mut out = MatrixF32::zeros(keep, hb.cols);
            for r in 0..keep {
                out.row_mut(r).copy_from_slice(hb.row(r));
            }
            HBlock::F32(out)
        }
    }
}

/// `DataWindow` fault-inject hook: a corrupted clone of the raw window
/// when the injector is armed for that site, None otherwise (the no-op
/// path — `armed_for` is a compile-time `false` without the feature).
fn inject_data_window(data: &Windowed) -> Option<Windowed> {
    if !inject::armed_for(inject::Site::DataWindow) {
        return None;
    }
    let mut w = data.clone();
    let (n, sq) = (w.n, w.s * w.q);
    inject::corrupt_slice_f32(inject::Site::DataWindow, 0, &mut w.x, n, sq);
    Some(w)
}

/// One block's (HᵀH, HᵀY, rows) partials on the wire the block was born
/// on: f64 blocks run the f64 kernels, f32-born blocks run the
/// accumulate-widen kernels directly — **no conversion pass in either
/// direction**. The fold that consumes the result is f64 either way, so
/// block order and fold determinism are unaffected; and since H entries
/// are f32 nonlinearity outputs, the two wires produce bit-identical
/// partials (the `linalg::matrix32` exactness contract). Both arms run
/// the *same* fixed `GRAM_ROW_CHUNK` schedule (`gram_with` mirrors
/// `gram_widen`), so the bit-identity holds at any `block_rows`, not
/// just single-chunk blocks.
pub(crate) fn block_gram_partials(h: &HBlock, y: &[f64]) -> (Matrix, Vec<f64>, usize) {
    match h {
        HBlock::F64(h) => (
            h.gram_with(ParallelPolicy::sequential()),
            h.t_matvec(y),
            h.rows,
        ),
        HBlock::F32(hf) => (
            hf.gram_widen(ParallelPolicy::sequential()),
            hf.t_matvec_widen(y),
            hf.rows,
        ),
    }
}

/// One batched H block (on the wire the policy's precision selects —
/// f32-born under `MixedF32` — and through the recurrence traversal its
/// [`RecurrenceMode`](crate::linalg::RecurrenceMode) selects) + widened
/// targets for rows [lo, hi).
pub(crate) fn compute_h_block(
    params: &ElmParams,
    data: &Windowed,
    ehist: Option<&[f32]>,
    lo: usize,
    hi: usize,
    policy: ParallelPolicy,
) -> (HBlock, Vec<f64>) {
    let h = h_block_range_policy(params, data, ehist, lo, hi, policy);
    let y = data.y[lo..hi].iter().map(|&v| v as f64).collect();
    (h, y)
}

/// Inputs for the gram graph: x, [yhist, ehist], params..., y, mask.
fn assemble_gram_inputs(
    meta: &ArtifactMeta,
    params: &ElmParams,
    block: &Block,
    ehist: Option<&[f32]>,
    q: usize,
) -> Result<Vec<Buf>> {
    let mut inputs = assemble_h_inputs(meta, params, block, ehist, q)?;
    // gram appends y and mask after the params
    inputs.push(Buf::new(vec![meta.rows], block.y.clone()));
    inputs.push(Buf::new(vec![meta.rows], block.mask.clone()));
    Ok(inputs)
}

/// Inputs shared by elm_h / elm_predict / elm_gram prefixes.
fn assemble_h_inputs(
    meta: &ArtifactMeta,
    params: &ElmParams,
    block: &Block,
    ehist: Option<&[f32]>,
    q: usize,
) -> Result<Vec<Buf>> {
    let mut inputs = Vec::with_capacity(meta.inputs.len());
    for spec in &meta.inputs {
        let buf = match spec.name.as_str() {
            "x" => Buf::new(spec.shape.clone(), block.x.clone()),
            "yhist" => Buf::new(spec.shape.clone(), block.yhist.clone()),
            "ehist" => {
                let mut e = vec![0f32; spec.len()];
                if let Some(full) = ehist {
                    let lo = block.offset * q;
                    let hi = (block.offset + block.valid) * q;
                    if full.len() < hi {
                        bail!(
                            "ehist has {} values but block at offset {} needs \
                             rows [{}, {}) at q = {q} (i.e. {} values); was the \
                             residual history built for a shorter dataset?",
                            full.len(),
                            block.offset,
                            block.offset,
                            block.offset + block.valid,
                            hi
                        );
                    }
                    e[..block.valid * q].copy_from_slice(&full[lo..hi]);
                }
                Buf::new(spec.shape.clone(), e)
            }
            "y" | "mask" | "beta" => continue, // appended by the caller
            name => Buf::new(spec.shape.clone(), params.buf(name).to_vec()),
        };
        inputs.push(buf);
    }
    Ok(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::{SrElmModel, TrainOptions, ALL_ARCHS};
    use crate::util::rng::Rng;

    fn toy_windowed(n: usize, q: usize, seed: u64) -> Windowed {
        let mut rng = Rng::new(seed);
        let mut y = vec![0.3f64, 0.45];
        for t in 2..n + q {
            let v = 0.5 * y[t - 1] + 0.22 * y[t - 2]
                + 0.12 * (t as f64 * 0.17).sin()
                + 0.05 * rng.normal();
            y.push(v);
        }
        let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let z: Vec<f64> = y.iter().map(|v| (v - lo) / (hi - lo)).collect();
        Windowed::from_series(&z, q).unwrap()
    }

    #[test]
    fn cpu_trainer_matches_sequential_exact_ls() {
        // TSQR strategy is exact least squares: must agree with the
        // sequential QR solve on the same H (up to factorization rounding)
        let w = toy_windowed(500, 6, 1);
        for archk in [Arch::Elman, Arch::Lstm, Arch::Gru, Arch::Fc, Arch::Jordan] {
            let seq = SrElmModel::train(archk, &w, &TrainOptions::new(12, 7)).unwrap();
            let cpu = CpuElmTrainer::new(4);
            let (par, bd) = cpu.train(archk, &w, 12, 7).unwrap();
            assert!(bd.blocks > 0);
            let worst = seq
                .beta
                .iter()
                .zip(&par.beta)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(worst < 1e-6, "{}: |seq - cpu| = {worst}", archk.name());
        }
    }

    #[test]
    fn cpu_trainer_bit_identical_across_worker_counts() {
        let w = toy_windowed(700, 5, 2);
        for strategy in
            [SolveStrategy::Tsqr, SolveStrategy::Gram, SolveStrategy::DirectQr]
        {
            for archk in ALL_ARCHS {
                let mut base: Option<Vec<f64>> = None;
                for workers in [1usize, 2, 4, 8] {
                    let mut t = CpuElmTrainer::new(workers);
                    t.strategy = strategy;
                    t.block_rows = 64;
                    let (model, _) = t.train(archk, &w, 10, 3).unwrap();
                    match &base {
                        None => base = Some(model.beta),
                        Some(b) => assert_eq!(
                            b, &model.beta,
                            "{}/{strategy:?}: β differs at workers={workers}",
                            archk.name()
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn cpu_trainer_narmax_two_pass_is_finite_and_learns() {
        let w = toy_windowed(600, 6, 3);
        let (train, test) = w.split(0.8);
        let cpu = CpuElmTrainer::new(2);
        let (model, _) = cpu.train(Arch::Narmax, &train, 12, 5).unwrap();
        assert!(model.beta.iter().all(|b| b.is_finite()));
        let ymean = test.y.iter().map(|&v| v as f64).sum::<f64>() / test.n as f64;
        let base = (test
            .y
            .iter()
            .map(|&v| (v as f64 - ymean).powi(2))
            .sum::<f64>()
            / test.n as f64)
            .sqrt();
        let rmse = cpu.rmse(&model, &test).unwrap();
        assert!(rmse < base, "narmax rmse {rmse} vs mean baseline {base}");
    }

    #[test]
    fn cpu_trainer_mixed_precision_gram_matches_f64_and_is_worker_invariant() {
        use crate::linalg::Precision;
        let w = toy_windowed(600, 5, 8);
        for archk in ALL_ARCHS {
            // f64 Gram reference
            let mut t64 = CpuElmTrainer::new(2);
            t64.strategy = SolveStrategy::Gram;
            t64.block_rows = 64;
            let (m64, _) = t64.train(archk, &w, 10, 3).unwrap();
            // f32-wire Gram: bit-identical across workers, close to f64
            let mut base: Option<Vec<f64>> = None;
            for workers in [1usize, 2, 4, 8] {
                let mut t = CpuElmTrainer::with_policy(
                    ParallelPolicy::with_workers(workers)
                        .with_precision(Precision::MixedF32),
                );
                t.strategy = SolveStrategy::Gram;
                t.block_rows = 64;
                let (model, _) = t.train(archk, &w, 10, 3).unwrap();
                match &base {
                    None => base = Some(model.beta),
                    Some(b) => assert_eq!(
                        b, &model.beta,
                        "{}: mixed β differs at workers={workers}",
                        archk.name()
                    ),
                }
            }
            let worst = m64
                .beta
                .iter()
                .zip(base.as_ref().unwrap())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            let scale = m64.beta.iter().fold(0.0f64, |s, b| s.max(b.abs())).max(1.0);
            assert!(
                worst < 1e-2 * scale,
                "{}: |f64 - mixed| = {worst} (scale {scale})",
                archk.name()
            );
        }
    }

    #[test]
    fn f32_born_blocks_keep_every_strategy_bit_identical_to_f64() {
        // H entries are f32 nonlinearity outputs, so the f32-born wire is
        // an exact re-encoding of the f64 one: every strategy (f32 Gram
        // kernels, f32 TSQR leaves, DirectQr's exact widen-at-assembly)
        // must reproduce the f64-precision β bit for bit — including the
        // NARMAX two-pass ELS, whose residual sweep runs matvec_widen
        let w = toy_windowed(500, 5, 9);
        for strategy in
            [SolveStrategy::Tsqr, SolveStrategy::Gram, SolveStrategy::DirectQr]
        {
            for archk in ALL_ARCHS {
                let mut t64 = CpuElmTrainer::new(4);
                t64.strategy = strategy;
                t64.block_rows = 64;
                let (m64, _) = t64.train(archk, &w, 10, 3).unwrap();
                let mut t32 = CpuElmTrainer::with_policy(
                    ParallelPolicy::with_workers(4).with_precision(Precision::MixedF32),
                );
                t32.strategy = strategy;
                t32.block_rows = 64;
                let (m32, _) = t32.train(archk, &w, 10, 3).unwrap();
                assert_eq!(
                    m64.beta,
                    m32.beta,
                    "{}/{strategy:?}: f32-born β differs from f64",
                    archk.name()
                );
            }
        }
        // blocks taller than GRAM_ROW_CHUNK (512): both Gram wires run
        // the same fixed chunk schedule, so the bit-identity must hold
        // beyond single-chunk blocks too
        let w_tall = toy_windowed(700, 5, 12);
        let mut g64 = CpuElmTrainer::new(2);
        g64.strategy = SolveStrategy::Gram;
        g64.block_rows = 1024;
        let (m64, _) = g64.train(Arch::Elman, &w_tall, 10, 3).unwrap();
        let mut g32 = CpuElmTrainer::with_policy(
            ParallelPolicy::with_workers(2).with_precision(Precision::MixedF32),
        );
        g32.strategy = SolveStrategy::Gram;
        g32.block_rows = 1024;
        let (m32, _) = g32.train(Arch::Elman, &w_tall, 10, 3).unwrap();
        assert_eq!(m64.beta, m32.beta, "Gram bit-identity broke on a >512-row block");
    }

    #[test]
    fn assemble_h_inputs_rejects_short_ehist() {
        use crate::runtime::manifest::InputSpec;
        let meta = ArtifactMeta {
            name: "elm_predict_test".into(),
            file: String::new(),
            kind: "elm_predict".into(),
            arch: "narmax".into(),
            variant: String::new(),
            rows: 4,
            block_rows: 4,
            s: 1,
            q: 3,
            m: 2,
            inputs: vec![
                InputSpec { name: "x".into(), shape: vec![4, 1, 3] },
                InputSpec { name: "ehist".into(), shape: vec![4, 3] },
            ],
            outputs: vec![],
        };
        let params = ElmParams::init(Arch::Narmax, 1, 3, 2, 1);
        let block = Block {
            x: vec![0.0; 12],
            yhist: vec![0.0; 12],
            y: vec![0.0; 4],
            mask: vec![1.0; 4],
            valid: 4,
            offset: 2,
        };
        // the block covers rows [2, 6) → needs 6·q = 18 ehist values
        let short = vec![0f32; 12];
        let err = assemble_h_inputs(&meta, &params, &block, Some(&short), 3)
            .expect_err("short ehist must be rejected");
        assert!(
            err.to_string().contains("ehist has 12 values"),
            "unhelpful error: {err}"
        );
        let ok = vec![0f32; 18];
        assert!(assemble_h_inputs(&meta, &params, &block, Some(&ok), 3).is_ok());
    }

    #[test]
    fn cpu_trainer_rejects_underdetermined() {
        let w = toy_windowed(30, 4, 4);
        let mut t = CpuElmTrainer::new(2);
        t.strategy = SolveStrategy::Gram;
        assert!(t.train(Arch::Elman, &w, 64, 1).is_err());
    }

    #[test]
    fn block_ranges_tile_exactly() {
        for (n, rows) in [(0usize, 10usize), (5, 10), (10, 10), (101, 25)] {
            let r = block_ranges(n, rows);
            let total: usize = r.iter().map(|(lo, hi)| hi - lo).sum();
            assert_eq!(total, n);
            let mut pos = 0;
            for (lo, hi) in r {
                assert_eq!(lo, pos);
                assert!(hi > lo);
                pos = hi;
            }
        }
    }
}
