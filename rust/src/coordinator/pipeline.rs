//! `PrElmTrainer` — the parallel ELM trainer (Basic/Opt-PR-ELM, L3 side).
//!
//! Training streams the dataset through the AOT `elm_gram` executables:
//!
//! ```text
//!   RowBlockBatcher ──▶ worker threads ──▶ EnginePool (PJRT) ──▶ partials
//!        (producer)      (one per engine)        │
//!                                                ▼
//!                       in-order fold ──▶ GramAccumulator ──▶ β solve
//! ```
//!
//! Partials are folded in block order (buffered re-sequencing), so the
//! result is bit-deterministic regardless of worker count — the §7.3
//! robustness requirement.
//!
//! NARMAX trains with the same two-pass ELS as the sequential baseline;
//! the residuals for pass 2 come from a parallel `elm_predict` sweep with
//! pass-1 β (one refinement pass — DESIGN.md §2).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::accumulator::GramAccumulator;
use crate::coordinator::batcher::{Block, RowBlockBatcher};
use crate::data::window::Windowed;
use crate::elm::trainer::{shift_history, SrElmModel};
use crate::elm::{Arch, ElmParams};
use crate::runtime::{ArtifactMeta, Buf, EnginePool, Manifest};

/// Fig-6 style phase breakdown of one training run (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainBreakdown {
    /// random parameter initialization
    pub init_s: f64,
    /// host→device literal creation (engine h2d delta)
    pub h2d_s: f64,
    /// artifact execution (H + partial sums)
    pub exec_s: f64,
    /// device→host output fetch
    pub d2h_s: f64,
    /// β solve (Cholesky/QR on the accumulated system)
    pub solve_s: f64,
    /// end-to-end wall clock
    pub total_s: f64,
    pub blocks: usize,
}

/// The parallel trainer: owns the manifest + engine pool handles.
pub struct PrElmTrainer {
    pool: EnginePool,
    manifest: Manifest,
    /// ridge λ for the Gram solve
    pub lambda: f64,
    /// run two-pass ELS for NARMAX (needs a matching elm_predict artifact)
    pub narmax_els: bool,
}

impl PrElmTrainer {
    pub fn new(artifacts_dir: &Path, workers: usize) -> Result<PrElmTrainer> {
        Ok(PrElmTrainer {
            pool: EnginePool::new(artifacts_dir, workers)?,
            manifest: Manifest::load(artifacts_dir)?,
            lambda: 1e-6,
            narmax_els: true,
        })
    }

    pub fn pool(&self) -> &EnginePool {
        &self.pool
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Parallel ELM training; returns the trained model and the phase
    /// breakdown.
    pub fn train(
        &self,
        arch: Arch,
        data: &Windowed,
        m: usize,
        seed: u64,
    ) -> Result<(SrElmModel, TrainBreakdown)> {
        let t_all = Instant::now();
        let meta = self
            .manifest
            .find("elm_gram", arch.name(), data.q, m)
            .context("selecting gram artifact")?
            .clone();
        let stats0 = self.pool.stats();

        let t0 = Instant::now();
        let params = ElmParams::init(arch, data.s, data.q, m, seed);
        let init_s = t0.elapsed().as_secs_f64();

        let mut bd = TrainBreakdown { init_s, ..Default::default() };

        // pass 1 (and only pass for non-NARMAX): zero error history
        let beta = self.gram_pass(&meta, &params, data, None, &mut bd)?;
        let beta = if arch == Arch::Narmax && self.narmax_els {
            // residuals from a parallel predict sweep with pass-1 β
            let model1 = SrElmModel { params: params.clone(), beta };
            let yhat = self.predict_with_ehist(&model1, data, None)?;
            let resid: Vec<f32> = data
                .y
                .iter()
                .zip(&yhat)
                .map(|(&y, &p)| y - p as f32)
                .collect();
            let ehist = shift_history(&resid, data.q);
            self.gram_pass(&meta, &params, data, Some(&ehist), &mut bd)?
        } else {
            beta
        };

        let stats1 = self.pool.stats();
        bd.h2d_s = stats1.h2d_s - stats0.h2d_s;
        bd.exec_s = stats1.exec_s - stats0.exec_s;
        bd.d2h_s = stats1.d2h_s - stats0.d2h_s;
        bd.total_s = t_all.elapsed().as_secs_f64();
        Ok((SrElmModel { params, beta }, bd))
    }

    /// One streaming gram pass → β.
    fn gram_pass(
        &self,
        meta: &ArtifactMeta,
        params: &ElmParams,
        data: &Windowed,
        ehist: Option<&[f32]>,
        bd: &mut TrainBreakdown,
    ) -> Result<Vec<f64>> {
        let m = params.m;
        // NARMAX needs stronger regularization (see TrainOptions::NARMAX_RIDGE)
        let lambda = if params.arch == Arch::Narmax {
            self.lambda.max(crate::elm::TrainOptions::NARMAX_RIDGE)
        } else {
            self.lambda
        };
        let mut acc = GramAccumulator::new(m, lambda);
        let blocks: Vec<Block> = RowBlockBatcher::new(data, meta.rows).collect();
        bd.blocks += blocks.len();

        let n_workers = self.pool.n_workers();
        let (result_tx, result_rx) = channel::<(usize, Result<(Vec<f32>, Vec<f32>, usize)>)>();

        std::thread::scope(|scope| -> Result<()> {
            // dispatch: blocks are sharded over workers by index so each
            // worker thread drives its own engine (cache affinity)
            for wid in 0..n_workers {
                let tx = result_tx.clone();
                let blocks = &blocks;
                let pool = &self.pool;
                let meta = &meta;
                let params = &params;
                scope.spawn(move || {
                    for (idx, block) in blocks.iter().enumerate() {
                        if idx % n_workers != wid {
                            continue;
                        }
                        let res = (|| {
                            let inputs =
                                assemble_gram_inputs(meta, params, block, ehist, data.q)?;
                            let out = pool.run_on(wid, &meta.name, inputs)?;
                            let hth = out
                                .first()
                                .ok_or_else(|| anyhow!("gram artifact returned no outputs"))?;
                            let hty =
                                out.get(1).ok_or_else(|| anyhow!("gram missing hty"))?;
                            Ok((hth.data.clone(), hty.data.clone(), block.valid))
                        })();
                        if tx.send((idx, res)).is_err() {
                            return; // receiver gone: abort quietly
                        }
                    }
                });
            }
            drop(result_tx);

            // in-order fold for determinism
            let mut pending: BTreeMap<usize, (Vec<f32>, Vec<f32>, usize)> = BTreeMap::new();
            let mut next = 0usize;
            for (idx, res) in result_rx {
                pending.insert(idx, res?);
                while let Some(p) = pending.remove(&next) {
                    acc.push_partials(&p.0, &p.1, p.2)?;
                    next += 1;
                }
            }
            if next != blocks.len() {
                return Err(anyhow!("folded {next} of {} blocks", blocks.len()));
            }
            Ok(())
        })?;

        let t0 = Instant::now();
        let beta = acc.solve()?;
        bd.solve_s += t0.elapsed().as_secs_f64();
        Ok(beta)
    }

    /// Parallel block predict through the `elm_predict` artifacts.
    /// For NARMAX, `ehist` supplies the error feedback (None → zeros —
    /// callers run the two-pass refinement, see `predict`).
    pub fn predict_with_ehist(
        &self,
        model: &SrElmModel,
        data: &Windowed,
        ehist: Option<&[f32]>,
    ) -> Result<Vec<f64>> {
        let params = &model.params;
        let meta = self
            .manifest
            .find("elm_predict", params.arch.name(), data.q, params.m)
            .context("selecting predict artifact")?
            .clone();
        let beta_f32: Vec<f32> = model.beta.iter().map(|&b| b as f32).collect();
        let blocks: Vec<Block> = RowBlockBatcher::new(data, meta.rows).collect();
        let mut out = vec![0f64; data.n];
        let n_workers = self.pool.n_workers();
        let (tx, rx) = channel::<(usize, Result<Vec<f32>>)>();

        std::thread::scope(|scope| -> Result<()> {
            for wid in 0..n_workers {
                let tx = tx.clone();
                let blocks = &blocks;
                let pool = &self.pool;
                let meta = &meta;
                let beta_f32 = &beta_f32;
                scope.spawn(move || {
                    for (idx, block) in blocks.iter().enumerate() {
                        if idx % n_workers != wid {
                            continue;
                        }
                        let res = (|| {
                            let mut inputs =
                                assemble_h_inputs(meta, params, block, ehist, data.q)?;
                            inputs.push(Buf::new(vec![params.m], beta_f32.clone()));
                            let o = pool.run_on(wid, &meta.name, inputs)?;
                            Ok(o.into_iter()
                                .next()
                                .ok_or_else(|| anyhow!("predict returned nothing"))?
                                .data)
                        })();
                        if tx.send((idx, res)).is_err() {
                            return;
                        }
                    }
                });
            }
            drop(tx);
            for (idx, res) in rx {
                let yhat = res?;
                let block = &blocks[idx];
                for r in 0..block.valid {
                    out[block.offset + r] = yhat[r] as f64;
                }
            }
            Ok(())
        })?;
        Ok(out)
    }

    /// One-step-ahead predictions; NARMAX refines once with the first
    /// pass's residuals (parallel ELS, DESIGN.md §2).
    pub fn predict(&self, model: &SrElmModel, data: &Windowed) -> Result<Vec<f64>> {
        if model.params.arch == Arch::Narmax {
            let y0 = self.predict_with_ehist(model, data, None)?;
            let resid: Vec<f32> =
                data.y.iter().zip(&y0).map(|(&y, &p)| y - p as f32).collect();
            let ehist = shift_history(&resid, data.q);
            return self.predict_with_ehist(model, data, Some(&ehist));
        }
        self.predict_with_ehist(model, data, None)
    }

    /// Test RMSE through the parallel predict path.
    pub fn rmse(&self, model: &SrElmModel, data: &Windowed) -> Result<f64> {
        let pred = self.predict(model, data)?;
        let truth: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        Ok(crate::data::stats::rmse(&pred, &truth))
    }
}

/// Inputs for the gram graph: x, [yhist, ehist], params..., y, mask.
fn assemble_gram_inputs(
    meta: &ArtifactMeta,
    params: &ElmParams,
    block: &Block,
    ehist: Option<&[f32]>,
    q: usize,
) -> Result<Vec<Buf>> {
    let mut inputs = assemble_h_inputs(meta, params, block, ehist, q)?;
    // gram appends y and mask after the params
    inputs.push(Buf::new(vec![meta.rows], block.y.clone()));
    inputs.push(Buf::new(vec![meta.rows], block.mask.clone()));
    Ok(inputs)
}

/// Inputs shared by elm_h / elm_predict / elm_gram prefixes.
fn assemble_h_inputs(
    meta: &ArtifactMeta,
    params: &ElmParams,
    block: &Block,
    ehist: Option<&[f32]>,
    q: usize,
) -> Result<Vec<Buf>> {
    let mut inputs = Vec::with_capacity(meta.inputs.len());
    for spec in &meta.inputs {
        let buf = match spec.name.as_str() {
            "x" => Buf::new(spec.shape.clone(), block.x.clone()),
            "yhist" => Buf::new(spec.shape.clone(), block.yhist.clone()),
            "ehist" => {
                let mut e = vec![0f32; spec.len()];
                if let Some(full) = ehist {
                    let lo = block.offset * q;
                    let hi = (block.offset + block.valid) * q;
                    e[..block.valid * q].copy_from_slice(&full[lo..hi]);
                }
                Buf::new(spec.shape.clone(), e)
            }
            "y" | "mask" | "beta" => continue, // appended by the caller
            name => Buf::new(spec.shape.clone(), params.buf(name).to_vec()),
        };
        inputs.push(buf);
    }
    Ok(inputs)
}
