//! `FleetService` — the deadline-aware asynchronous front end of the
//! multi-tenant fleet trainer.
//!
//! [`FleetTrainer`] is a synchronous, unbounded, all-or-nothing batch: a
//! flooded queue grows without limit, a slow drain blocks every caller,
//! and a crash loses every trained model. This module wraps it in the
//! service layer a real deployment needs, without giving up one bit of
//! the determinism contract:
//!
//! * **Bounded admission with typed backpressure.** [`ServiceConfig::capacity`]
//!   caps the queue; a submit over the cap fails with
//!   [`ServiceError::QueueFull`] instead of growing silently, and the
//!   fleet trainer's submit-time screening (duplicate / unknown tenant)
//!   is mirrored at admission so malformed requests never occupy a slot.
//! * **Logical-tick deadlines.** Time is the [`LogicalClock`] — a `u64`
//!   tick advanced once per [`FleetService::cycle`], never the wall
//!   clock. A request carries an optional absolute deadline tick; it is
//!   checked at admission *and* again when the request is about to join a
//!   drain (group formation). An expired request fails with a typed
//!   [`ServiceError::DeadlineExceeded`] — it is never silently trained.
//! * **Deterministic retry with exponential backoff.** A `Train` whose
//!   [`SolveReport`] lands on a ridge rung, or that fails with a worker
//!   panic, is re-queued up to [`ServiceConfig::max_retries`] times. The
//!   backoff delay is `backoff_base · 2^(attempt-1)` ticks plus a jitter
//!   drawn from an [`Rng`] keyed by `(seed, admission index, attempt)` —
//!   a pure function of the submission sequence, so the whole retry
//!   schedule is bit-reproducible and worker-count invariant.
//! * **Overload ladder.** Mirroring the solve-degradation ladder at the
//!   scheduling level, queue occupancy drives a monotone rung
//!   ([`OverloadRung`]): healthy → shed lowest-priority predicts →
//!   additionally downgrade oversized TSQR groups to the cheaper Gram
//!   strategy → additionally reject new trains at admission. Every shed
//!   is a typed [`ServiceError`]; nothing is dropped silently.
//! * **Crash-safe journal.** Every completed train/update appends the
//!   tenant's full warm state to a [`TenantJournal`];
//!   [`FleetService::warm_from`] replays a (possibly torn) journal into
//!   the cache, restoring bit-identical models and reporting a torn tail
//!   as a typed [`ServiceError::JournalTorn`].
//!
//! **Conformance anchor:** with no capacity bound, no deadlines, and no
//! faults armed, a submission sequence followed by [`FleetService::run_to_idle`]
//! forwards exactly that sequence, in order, into one inner drain — so
//! every tenant β is bit-identical to a synchronous
//! [`FleetTrainer::drain`] of the same submissions, at any worker count
//! (pinned by `tests/service_props.rs`).
//!
//! The inner drain runs on a scoped worker thread (this file is one of
//! the four audited scheduler modules of the thread-confinement lint
//! rule); the service's own bookkeeping is single-threaded and uses only
//! order-preserving containers.

#![forbid(unsafe_code)]

use std::fmt;

use crate::coordinator::accumulator::SolveStrategy;
use crate::coordinator::fleet::{FleetOutcome, FleetRequest, FleetTrainer, GroupKey};
use crate::linalg::policy::LogicalClock;
use crate::robust::journal::{TenantJournal, TenantSnapshot};
use crate::robust::{inject, DegradationRung, SolveError};
use crate::util::rng::Rng;

/// Typed failure surface of the service layer. Solve-level failures keep
/// their [`SolveError`] taxonomy (inside [`FleetOutcome::Failed`]); this
/// enum covers the scheduling decisions stacked on top.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The bounded admission queue is at capacity.
    QueueFull {
        /// Configured capacity.
        capacity: usize,
        /// Requests queued when the submit arrived.
        queued: usize,
    },
    /// The request was refused by a scheduling policy (overload ladder,
    /// admission screening); the reason says which.
    Rejected {
        /// Human-readable policy reason.
        reason: String,
    },
    /// The request's deadline tick passed before it could be scheduled.
    DeadlineExceeded {
        /// The absolute deadline tick the request carried.
        deadline: u64,
        /// The logical tick at which the expiry was detected.
        now: u64,
    },
    /// A transiently-degraded train was retried `attempts` times and
    /// never produced a healthy solve.
    RetriesExhausted {
        /// Retry attempts consumed (= configured `max_retries`).
        attempts: u32,
    },
    /// Journal recovery found a torn/corrupt record (crash mid-append);
    /// everything before it was recovered.
    JournalTorn {
        /// Byte offset of the torn record in the journal.
        offset: usize,
        /// Why the record was rejected.
        reason: String,
    },
}

impl ServiceError {
    /// Stable kebab-case class name (the service-level mirror of
    /// [`SolveError::class`]).
    pub fn class(&self) -> &'static str {
        match self {
            ServiceError::QueueFull { .. } => "queue-full",
            ServiceError::Rejected { .. } => "rejected",
            ServiceError::DeadlineExceeded { .. } => "deadline-exceeded",
            ServiceError::RetriesExhausted { .. } => "retries-exhausted",
            ServiceError::JournalTorn { .. } => "journal-torn",
        }
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueFull { capacity, queued } => write!(
                f,
                "admission queue full: {queued} queued at capacity {capacity}"
            ),
            ServiceError::Rejected { reason } => write!(f, "rejected: {reason}"),
            ServiceError::DeadlineExceeded { deadline, now } => write!(
                f,
                "deadline tick {deadline} exceeded at logical tick {now}"
            ),
            ServiceError::RetriesExhausted { attempts } => {
                write!(f, "degraded solve retried {attempts} time(s) without recovery")
            }
            ServiceError::JournalTorn { offset, reason } => {
                write!(f, "journal torn at byte {offset}: {reason}")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// Scheduling-level degradation rung, driven by queue occupancy (see the
/// module docs). Rungs are cumulative: each adds its measure on top of
/// the previous one's.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum OverloadRung {
    /// Below the shed watermark: every request class is served.
    #[default]
    Healthy,
    /// At/above 1/2 capacity: predicts below the priority floor are shed.
    ShedPredicts,
    /// At/above 3/4 capacity: additionally, oversized TSQR train groups
    /// are downgraded to the Gram strategy for this drain.
    DowngradeGroups,
    /// At/above 9/10 capacity: additionally, new trains are rejected at
    /// admission.
    RejectTrains,
}

impl OverloadRung {
    /// Stable lowercase name for logs and bench metadata.
    pub fn name(self) -> &'static str {
        match self {
            OverloadRung::Healthy => "healthy",
            OverloadRung::ShedPredicts => "shed-predicts",
            OverloadRung::DowngradeGroups => "downgrade-groups",
            OverloadRung::RejectTrains => "reject-trains",
        }
    }
}

/// Service knobs. The defaults make the service behave like the bare
/// trainer (unbounded, no retries beyond two, no shedding) — every knob
/// is opt-in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Admission queue capacity; `None` is unbounded (and keeps the
    /// overload ladder at [`OverloadRung::Healthy`] forever).
    pub capacity: Option<usize>,
    /// How many times a transiently-degraded train is re-queued before
    /// its degraded outcome is accepted (ridge rung) or reported as
    /// [`ServiceError::RetriesExhausted`] (persistent worker panic).
    pub max_retries: u32,
    /// Base backoff in logical ticks; attempt `k` waits
    /// `backoff_base · 2^(k-1)` ticks plus seeded jitter in
    /// `[0, backoff_base)`.
    pub backoff_base: u64,
    /// Seed keying the backoff jitter (per admission index and attempt).
    pub seed: u64,
    /// Predicts with `priority <` this floor are shed at
    /// [`OverloadRung::ShedPredicts`] and above.
    pub shed_priority_floor: u32,
    /// Train groups larger than this are downgraded from TSQR to Gram at
    /// [`OverloadRung::DowngradeGroups`] and above.
    pub downgrade_group_size: usize,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            capacity: None,
            max_retries: 2,
            backoff_base: 4,
            seed: 0,
            shed_priority_floor: 1,
            downgrade_group_size: 4,
        }
    }
}

/// Monotone counters the service keeps (exported by `benches/fleet.rs`
/// as the `shed`/`retries`/`deadline_miss` bench fields).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServiceStats {
    /// Requests that reached a terminal outcome (ok or typed failure).
    pub completed: u64,
    /// Requests shed by the overload ladder (predict sheds + admission
    /// rejections under [`OverloadRung::RejectTrains`]).
    pub shed: u64,
    /// Re-queues of transiently-degraded or panicked requests.
    pub retries: u64,
    /// Requests failed with [`ServiceError::DeadlineExceeded`].
    pub deadline_miss: u64,
    /// Trains that ran under a TSQR→Gram group downgrade.
    pub downgraded: u64,
}

/// One finished request: its admission id, tenant, and either the inner
/// trainer outcome or the typed service-level failure.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The id [`FleetService::submit`] returned for this request.
    pub id: u64,
    /// Tenant the request addressed.
    pub tenant: String,
    /// The terminal outcome.
    pub outcome: std::result::Result<FleetOutcome, ServiceError>,
}

/// One admitted, not-yet-finished request.
struct Pending {
    id: u64,
    /// Admission index — the `Site::ServiceQueue` fault key and the
    /// backoff-jitter key. Assigned at submit, never reused.
    admission: usize,
    req: FleetRequest,
    /// Absolute deadline tick, if any.
    deadline: Option<u64>,
    /// Larger is more important; predicts below the configured floor are
    /// shed under overload.
    priority: u32,
    /// Retry attempts consumed so far.
    attempts: u32,
    /// Earliest logical tick this request may join a drain.
    eligible: u64,
}

/// The async front end (see module docs).
pub struct FleetService {
    trainer: FleetTrainer,
    /// Scheduling knobs (capacity, retries, backoff, ladder thresholds).
    pub config: ServiceConfig,
    clock: LogicalClock,
    queue: Vec<Pending>,
    next_id: u64,
    admitted: usize,
    journal: TenantJournal,
    stats: ServiceStats,
}

impl FleetService {
    /// Wrap a trainer with the default (unbounded, non-shedding) config.
    pub fn new(trainer: FleetTrainer) -> FleetService {
        FleetService::with_config(trainer, ServiceConfig::default())
    }

    /// Wrap a trainer with explicit scheduling knobs.
    pub fn with_config(trainer: FleetTrainer, config: ServiceConfig) -> FleetService {
        FleetService {
            trainer,
            config,
            clock: LogicalClock::new(),
            queue: Vec::new(),
            next_id: 0,
            admitted: 0,
            journal: TenantJournal::new(),
            stats: ServiceStats::default(),
        }
    }

    /// The wrapped trainer (tests pin β bit-identity through its
    /// `model()` accessor).
    pub fn trainer(&self) -> &FleetTrainer {
        &self.trainer
    }

    /// Current logical tick.
    pub fn now(&self) -> u64 {
        self.clock.now()
    }

    /// Requests admitted but not yet finished.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// The monotone service counters.
    pub fn stats(&self) -> ServiceStats {
        self.stats
    }

    /// The crash-safe journal accumulated so far (persist
    /// [`TenantJournal::as_bytes`] to survive a process crash).
    pub fn journal(&self) -> &TenantJournal {
        &self.journal
    }

    /// The current overload rung, a pure function of queue occupancy vs
    /// capacity (always [`OverloadRung::Healthy`] when unbounded).
    pub fn overload_rung(&self) -> OverloadRung {
        let Some(cap) = self.config.capacity else {
            return OverloadRung::Healthy;
        };
        let q = self.queue.len();
        if q * 10 >= cap * 9 {
            OverloadRung::RejectTrains
        } else if q * 4 >= cap * 3 {
            OverloadRung::DowngradeGroups
        } else if q * 2 >= cap {
            OverloadRung::ShedPredicts
        } else {
            OverloadRung::Healthy
        }
    }

    /// Admit a request. `deadline` is an absolute [`LogicalClock`] tick
    /// (`None` = no deadline); `priority` orders predicts under overload
    /// shedding (larger = keep longer). Returns the request id that later
    /// [`Completion`]s carry, or the typed admission failure:
    /// [`ServiceError::QueueFull`], [`ServiceError::DeadlineExceeded`]
    /// (already expired on arrival), or [`ServiceError::Rejected`]
    /// (overload ladder, duplicate queued train, unknown tenant).
    pub fn submit(
        &mut self,
        req: FleetRequest,
        deadline: Option<u64>,
        priority: u32,
    ) -> std::result::Result<u64, ServiceError> {
        let now = self.clock.now();
        if let Some(cap) = self.config.capacity {
            if self.queue.len() >= cap {
                return Err(ServiceError::QueueFull { capacity: cap, queued: self.queue.len() });
            }
        }
        if let Some(d) = deadline {
            if now > d {
                self.stats.deadline_miss += 1;
                return Err(ServiceError::DeadlineExceeded { deadline: d, now });
            }
        }
        match &req {
            FleetRequest::Train { tenant, .. } => {
                if self.overload_rung() >= OverloadRung::RejectTrains {
                    self.stats.shed += 1;
                    return Err(ServiceError::Rejected {
                        reason: format!(
                            "overload rung {} rejects new trains",
                            self.overload_rung().name()
                        ),
                    });
                }
                let dup = self.queue.iter().any(|p| {
                    matches!(&p.req, FleetRequest::Train { tenant: t, .. } if t == tenant)
                });
                if dup {
                    return Err(ServiceError::Rejected {
                        reason: format!("tenant {tenant:?} already has a queued train"),
                    });
                }
            }
            FleetRequest::Update { tenant, .. } | FleetRequest::Predict { tenant, .. } => {
                let resolvable = self.trainer.has_model(tenant)
                    || self.queue.iter().any(|p| {
                        matches!(&p.req, FleetRequest::Train { tenant: t, .. } if t == tenant)
                    });
                if !resolvable {
                    return Err(ServiceError::Rejected {
                        reason: format!(
                            "tenant {tenant:?} has neither a cached model nor a queued train"
                        ),
                    });
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let admission = self.admitted;
        self.admitted += 1;
        self.queue.push(Pending {
            id,
            admission,
            req,
            deadline,
            priority,
            attempts: 0,
            eligible: now,
        });
        Ok(id)
    }

    /// Run one service cycle: advance the clock one tick, shed expired
    /// and overload-shed requests (typed), dispatch every eligible
    /// request into the inner trainer (one scoped-thread drain — two
    /// under a group downgrade), apply the retry/backoff policy to
    /// transiently-degraded trains, and journal every completed
    /// train/update. Returns the completions this cycle produced, in
    /// admission order.
    pub fn cycle(&mut self) -> Vec<Completion> {
        let now = self.clock.advance();
        let rung = self.overload_rung();
        let pendings = std::mem::take(&mut self.queue);
        let mut completions: Vec<Completion> = Vec::new();
        let mut kept: Vec<Pending> = Vec::new();
        let mut candidates: Vec<Pending> = Vec::new();

        // 1. deadline + overload shedding, eligibility partition
        for p in pendings {
            let skewed = inject::deadline_skew(inject::Site::ServiceQueue, p.admission);
            let expired = p.deadline.is_some_and(|d| now > d);
            if expired || skewed {
                self.stats.deadline_miss += 1;
                self.stats.completed += 1;
                completions.push(Completion {
                    id: p.id,
                    tenant: p.req.tenant().to_string(),
                    outcome: Err(ServiceError::DeadlineExceeded {
                        deadline: p.deadline.unwrap_or(now),
                        now,
                    }),
                });
                continue;
            }
            if rung >= OverloadRung::ShedPredicts
                && matches!(p.req, FleetRequest::Predict { .. })
                && p.priority < self.config.shed_priority_floor
            {
                self.stats.shed += 1;
                self.stats.completed += 1;
                completions.push(Completion {
                    id: p.id,
                    tenant: p.req.tenant().to_string(),
                    outcome: Err(ServiceError::Rejected {
                        reason: format!(
                            "overload rung {} shed priority-{} predict",
                            rung.name(),
                            p.priority
                        ),
                    }),
                });
                continue;
            }
            if p.eligible > now {
                kept.push(p);
            } else {
                candidates.push(p);
            }
        }

        // 2. defer updates/predicts whose backing train is still waiting
        // out a backoff window — forwarding them now could only fail
        let waiting_trains: Vec<(String, u64)> = kept
            .iter()
            .filter_map(|p| match &p.req {
                FleetRequest::Train { tenant, .. } => Some((tenant.clone(), p.eligible)),
                _ => None,
            })
            .collect();
        let mut runnable: Vec<Pending> = Vec::new();
        for mut p in candidates {
            let defer = match &p.req {
                FleetRequest::Train { .. } => None,
                FleetRequest::Update { tenant, .. }
                | FleetRequest::Predict { tenant, .. } => waiting_trains
                    .iter()
                    .find(|(t, _)| t == tenant)
                    .map(|&(_, el)| el),
            };
            match defer {
                Some(el) => {
                    p.eligible = el;
                    kept.push(p);
                }
                None => runnable.push(p),
            }
        }

        // 3. injected dispatch panics → retry with backoff
        let mut forward: Vec<Pending> = Vec::new();
        for mut p in runnable {
            let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                inject::maybe_panic(inject::Site::ServiceQueue, p.admission)
            }))
            .is_err();
            if !panicked {
                forward.push(p);
                continue;
            }
            if p.attempts >= self.config.max_retries {
                self.stats.completed += 1;
                completions.push(Completion {
                    id: p.id,
                    tenant: p.req.tenant().to_string(),
                    outcome: Err(ServiceError::RetriesExhausted { attempts: p.attempts }),
                });
            } else {
                p.attempts += 1;
                p.eligible = now + backoff_ticks(&self.config, p.admission, p.attempts);
                self.stats.retries += 1;
                kept.push(p);
            }
        }

        // 4. group-downgrade partition (overload rung ≥ DowngradeGroups,
        // TSQR strategy only): oversized shape groups drain first under
        // the Gram strategy, the rest under the configured strategy
        let mut phase_a: Vec<Pending> = Vec::new();
        let mut phase_b: Vec<Pending> = Vec::new();
        if rung >= OverloadRung::DowngradeGroups
            && self.trainer.strategy == SolveStrategy::Tsqr
        {
            let mut group_sizes: Vec<(GroupKey, usize)> = Vec::new();
            for p in &forward {
                if let FleetRequest::Train { arch, m, data, .. } = &p.req {
                    let key = GroupKey { arch: *arch, m: *m, s: data.s, q: data.q };
                    match group_sizes.iter_mut().find(|(k, _)| *k == key) {
                        Some((_, n)) => *n += 1,
                        None => group_sizes.push((key, 1)),
                    }
                }
            }
            let oversized: Vec<GroupKey> = group_sizes
                .into_iter()
                .filter(|&(_, n)| n > self.config.downgrade_group_size)
                .map(|(k, _)| k)
                .collect();
            for p in forward {
                let downgrade = match &p.req {
                    FleetRequest::Train { arch, m, data, .. } => oversized.contains(
                        &GroupKey { arch: *arch, m: *m, s: data.s, q: data.q },
                    ),
                    _ => false,
                };
                if downgrade {
                    phase_a.push(p);
                } else {
                    phase_b.push(p);
                }
            }
        } else {
            phase_b = forward;
        }

        // 5. dispatch: phase A under Gram (downgrade), phase B under the
        // configured strategy — each one inner drain on a scoped thread
        if !phase_a.is_empty() {
            self.stats.downgraded += phase_a.len() as u64;
            let saved = self.trainer.strategy;
            self.trainer.strategy = SolveStrategy::Gram;
            self.dispatch(phase_a, now, &mut completions, &mut kept);
            self.trainer.strategy = saved;
        }
        if !phase_b.is_empty() {
            self.dispatch(phase_b, now, &mut completions, &mut kept);
        }

        self.queue = kept;
        completions.sort_by_key(|c| c.id);
        completions
    }

    /// Submit a batch into the inner trainer, drain it on a scoped worker
    /// thread, apply the retry policy to the outcomes, and journal the
    /// completions.
    fn dispatch(
        &mut self,
        batch: Vec<Pending>,
        now: u64,
        completions: &mut Vec<Completion>,
        kept: &mut Vec<Pending>,
    ) {
        let mut submitted: Vec<Pending> = Vec::new();
        for p in batch {
            match self.trainer.submit(p.req.clone()) {
                Ok(()) => submitted.push(p),
                Err(e) => {
                    // admission screening raced a cache eviction or a
                    // failed backing train — surface the inner typed error
                    self.stats.completed += 1;
                    completions.push(Completion {
                        id: p.id,
                        tenant: p.req.tenant().to_string(),
                        outcome: Err(ServiceError::Rejected {
                            reason: format!("fleet submit refused: {e:#}"),
                        }),
                    });
                }
            }
        }
        if submitted.is_empty() {
            return;
        }
        let trainer = &mut self.trainer;
        let results = std::thread::scope(|scope| {
            scope
                .spawn(|| trainer.drain())
                .join()
                .expect("service drain thread panicked")
        });
        debug_assert_eq!(results.len(), submitted.len());
        for (p, (tenant, outcome)) in submitted.into_iter().zip(results) {
            let retryable = matches!(&p.req, FleetRequest::Train { .. })
                && match &outcome {
                    FleetOutcome::Trained { report, .. } => {
                        matches!(report.rung, DegradationRung::Ridge { .. })
                    }
                    FleetOutcome::Failed { error, .. } => {
                        matches!(error, SolveError::WorkerPanic { .. })
                    }
                    _ => false,
                };
            if retryable && p.attempts < self.config.max_retries {
                let mut p = p;
                p.attempts += 1;
                p.eligible = now + backoff_ticks(&self.config, p.admission, p.attempts);
                self.stats.retries += 1;
                kept.push(p);
                continue;
            }
            let terminal = match outcome {
                // a persistently panicking train exhausted its retries
                FleetOutcome::Failed { ref error, .. }
                    if retryable && matches!(error, SolveError::WorkerPanic { .. }) =>
                {
                    Err(ServiceError::RetriesExhausted { attempts: p.attempts })
                }
                // a ridge-rung train that exhausted retries is still a
                // model — hand it over with its (degraded) report
                other => Ok(other),
            };
            if matches!(
                terminal,
                Ok(FleetOutcome::Trained { .. }) | Ok(FleetOutcome::Updated { .. })
            ) {
                if let Some(snap) = self.trainer.snapshot(&tenant) {
                    self.journal.append(&tenant, &snap);
                }
            }
            self.stats.completed += 1;
            completions.push(Completion { id: p.id, tenant, outcome: terminal });
        }
    }

    /// Cycle until the queue is empty, fast-forwarding the clock past
    /// backoff windows when nothing is runnable. Returns every completion
    /// in id order. (Bounded by a defensive cycle cap; the retry budget
    /// makes the queue drain in finitely many cycles regardless.)
    pub fn run_to_idle(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        let mut cycles = 0u32;
        while !self.queue.is_empty() && cycles < 100_000 {
            cycles += 1;
            let next = self.clock.now() + 1;
            if self.queue.iter().all(|p| p.eligible > next) {
                let min_eligible =
                    self.queue.iter().map(|p| p.eligible).min().unwrap_or(next);
                self.clock.advance_to(min_eligible - 1);
            }
            out.extend(self.cycle());
        }
        out.sort_by_key(|c| c.id);
        out
    }

    /// Replay a (possibly torn) journal into the wrapped trainer's cache:
    /// every intact record restores its tenant bit-identically (later
    /// records supersede earlier), a torn tail comes back as a typed
    /// [`ServiceError::JournalTorn`], and a snapshot that fails the
    /// restore shape screen is skipped (counted out of the returned
    /// total). Returns `(tenants restored, optional tear)`.
    pub fn warm_from(
        &mut self,
        journal: &TenantJournal,
    ) -> (usize, Option<ServiceError>) {
        let rec = journal.recover();
        let mut applied = 0usize;
        for (tenant, snap) in &rec.snapshots {
            if self.trainer.restore(tenant, snap).is_ok() {
                applied += 1;
            }
        }
        let torn = rec.torn.map(|t| ServiceError::JournalTorn {
            offset: t.offset,
            reason: t.reason,
        });
        (applied, torn)
    }

    /// Snapshot one cached tenant (delegates to
    /// [`FleetTrainer::snapshot`]).
    pub fn snapshot(&self, tenant: &str) -> Option<TenantSnapshot> {
        self.trainer.snapshot(tenant)
    }
}

/// Backoff delay in logical ticks for retry `attempt` (1-based) of the
/// request at `admission`: exponential in the attempt, plus jitter drawn
/// from an [`Rng`] keyed by `(config.seed, admission, attempt)` — a pure
/// function, so the whole retry schedule is bit-reproducible and
/// worker-count invariant.
fn backoff_ticks(config: &ServiceConfig, admission: usize, attempt: u32) -> u64 {
    let base = config.backoff_base.max(1);
    let exp = base.saturating_mul(1u64 << (attempt.saturating_sub(1)).min(16));
    let mut rng = Rng::new(
        config
            .seed
            .wrapping_add(0x5EED_5EED)
            ^ (admission as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (attempt as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
    );
    exp + rng.next_u64() % base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::window::Windowed;
    use crate::elm::Arch;

    fn toy_data(n: usize, q: usize, phase: f64) -> Windowed {
        let series: Vec<f64> =
            (0..n + q).map(|i| (i as f64 * 0.07 + phase).sin()).collect();
        Windowed::from_series(&series, q).expect("windowed")
    }

    fn train_req(tenant: &str, m: usize, seed: u64, phase: f64) -> FleetRequest {
        FleetRequest::Train {
            tenant: tenant.to_string(),
            arch: Arch::Elman,
            m,
            seed,
            data: toy_data(90, 3, phase),
        }
    }

    fn service(workers: usize, config: ServiceConfig) -> FleetService {
        FleetService::with_config(FleetTrainer::new(workers), config)
    }

    #[test]
    fn error_classes_are_distinct_and_display() {
        let all = [
            ServiceError::QueueFull { capacity: 1, queued: 1 },
            ServiceError::Rejected { reason: "r".into() },
            ServiceError::DeadlineExceeded { deadline: 1, now: 2 },
            ServiceError::RetriesExhausted { attempts: 2 },
            ServiceError::JournalTorn { offset: 8, reason: "t".into() },
        ];
        let classes: Vec<&str> = all.iter().map(|e| e.class()).collect();
        let mut dedup = classes.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), all.len(), "classes must be distinct: {classes:?}");
        for e in &all {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn async_service_matches_sync_drain() {
        // the conformance anchor, small edition (1/2/4/8-worker sweep
        // lives in tests/service_props.rs)
        let mut sync = FleetTrainer::new(2);
        sync.submit(train_req("a", 6, 1, 0.0)).unwrap();
        sync.submit(train_req("b", 6, 2, 0.4)).unwrap();
        let _ = sync.drain();

        let mut svc = service(2, ServiceConfig::default());
        svc.submit(train_req("a", 6, 1, 0.0), None, 0).unwrap();
        svc.submit(train_req("b", 6, 2, 0.4), None, 0).unwrap();
        let done = svc.run_to_idle();
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|c| matches!(
            c.outcome,
            Ok(FleetOutcome::Trained { .. })
        )));
        for t in ["a", "b"] {
            let a: Vec<u64> =
                sync.model(t).unwrap().beta.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = svc
                .trainer()
                .model(t)
                .unwrap()
                .beta
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(a, b, "tenant {t} β must be bit-identical to sync drain");
        }
        assert_eq!(svc.stats().completed, 2);
        assert_eq!(svc.stats().retries, 0);
    }

    #[test]
    fn queue_full_is_typed() {
        let mut svc =
            service(1, ServiceConfig { capacity: Some(2), ..ServiceConfig::default() });
        svc.submit(train_req("a", 6, 1, 0.0), None, 0).unwrap();
        svc.submit(train_req("b", 6, 2, 0.1), None, 0).unwrap();
        let err = svc.submit(train_req("c", 6, 3, 0.2), None, 0).unwrap_err();
        assert_eq!(err, ServiceError::QueueFull { capacity: 2, queued: 2 });
    }

    #[test]
    fn expired_deadline_rejected_at_admission_and_at_cycle() {
        let mut svc = service(1, ServiceConfig::default());
        // burn some ticks
        for _ in 0..5 {
            svc.cycle();
        }
        assert_eq!(svc.now(), 5);
        let err = svc.submit(train_req("a", 6, 1, 0.0), Some(3), 0).unwrap_err();
        assert_eq!(err.class(), "deadline-exceeded");
        // admitted alive, but the deadline passes before the next cycle
        // reaches it: deadline 5 expires at tick 6
        svc.submit(train_req("b", 6, 2, 0.1), Some(5), 0).unwrap();
        // hold the request back so the cycle's group formation sees it
        // only after expiry
        svc.queue[0].eligible = 7;
        let mut done = svc.cycle(); // tick 6: not eligible, but expired → shed typed
        done.extend(svc.run_to_idle());
        let all: Vec<&Completion> = done.iter().collect();
        assert_eq!(all.len(), 1);
        match &all[0].outcome {
            Err(ServiceError::DeadlineExceeded { deadline: 5, now }) => {
                assert!(*now > 5);
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert!(!svc.trainer().has_model("b"), "expired request must never train");
        assert_eq!(svc.stats().deadline_miss, 2);
    }

    #[test]
    fn overload_ladder_rungs_follow_occupancy() {
        let mut svc = service(
            1,
            ServiceConfig { capacity: Some(10), ..ServiceConfig::default() },
        );
        assert_eq!(svc.overload_rung(), OverloadRung::Healthy);
        for i in 0..5 {
            svc.submit(train_req(&format!("t{i}"), 6, i as u64, 0.1 * i as f64), None, 0)
                .unwrap();
        }
        assert_eq!(svc.overload_rung(), OverloadRung::ShedPredicts);
        for i in 5..8 {
            svc.submit(train_req(&format!("t{i}"), 6, i as u64, 0.1 * i as f64), None, 0)
                .unwrap();
        }
        assert_eq!(svc.overload_rung(), OverloadRung::DowngradeGroups);
        svc.submit(train_req("t8", 6, 8, 0.8), None, 0).unwrap();
        assert_eq!(svc.overload_rung(), OverloadRung::RejectTrains);
        let err = svc.submit(train_req("t9", 6, 9, 0.9), None, 0).unwrap_err();
        assert_eq!(err.class(), "rejected");
        assert_eq!(svc.stats().shed, 1);
        // rungs are ordered
        assert!(OverloadRung::Healthy < OverloadRung::ShedPredicts);
        assert!(OverloadRung::ShedPredicts < OverloadRung::DowngradeGroups);
        assert!(OverloadRung::DowngradeGroups < OverloadRung::RejectTrains);
    }

    #[test]
    fn low_priority_predicts_shed_under_pressure() {
        let mut svc = service(
            2,
            ServiceConfig { capacity: Some(8), ..ServiceConfig::default() },
        );
        svc.submit(train_req("a", 6, 1, 0.0), None, 0).unwrap();
        svc.run_to_idle();
        // refill to the shed watermark: 4 of 8
        for i in 0..3 {
            svc.submit(train_req(&format!("t{i}"), 6, 10 + i, 0.1), None, 0).unwrap();
        }
        let lo = svc
            .submit(
                FleetRequest::Predict { tenant: "a".into(), data: toy_data(30, 3, 0.0) },
                None,
                0,
            )
            .unwrap();
        let hi = svc
            .submit(
                FleetRequest::Predict { tenant: "a".into(), data: toy_data(30, 3, 0.0) },
                None,
                5,
            )
            .unwrap();
        let done = svc.run_to_idle();
        let find = |id: u64| done.iter().find(|c| c.id == id).unwrap();
        assert_eq!(
            find(lo).outcome.as_ref().unwrap_err().class(),
            "rejected",
            "priority-0 predict shed"
        );
        assert!(
            matches!(find(hi).outcome, Ok(FleetOutcome::Predicted { .. })),
            "priority-5 predict survives: {:?}",
            find(hi).outcome
        );
        assert!(svc.stats().shed >= 1);
    }

    #[test]
    fn admission_screens_unknown_and_duplicate_tenants() {
        let mut svc = service(1, ServiceConfig::default());
        let err = svc
            .submit(
                FleetRequest::Predict { tenant: "ghost".into(), data: toy_data(30, 3, 0.0) },
                None,
                0,
            )
            .unwrap_err();
        assert_eq!(err.class(), "rejected");
        svc.submit(train_req("a", 6, 1, 0.0), None, 0).unwrap();
        let err = svc.submit(train_req("a", 6, 2, 0.1), None, 0).unwrap_err();
        assert_eq!(err.class(), "rejected");
        // queued train makes the tenant addressable before it is cached
        svc.submit(
            FleetRequest::Predict { tenant: "a".into(), data: toy_data(30, 3, 0.0) },
            None,
            0,
        )
        .unwrap();
        let done = svc.run_to_idle();
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|c| c.outcome.is_ok()));
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_exponential() {
        let cfg = ServiceConfig { seed: 42, backoff_base: 4, ..ServiceConfig::default() };
        for admission in [0usize, 3, 17] {
            for attempt in 1..=4u32 {
                let a = backoff_ticks(&cfg, admission, attempt);
                let b = backoff_ticks(&cfg, admission, attempt);
                assert_eq!(a, b, "pure function of (seed, admission, attempt)");
                let floor = 4u64 << (attempt - 1);
                assert!(
                    a >= floor && a < floor + 4,
                    "attempt {attempt}: {a} outside [{floor}, {})",
                    floor + 4
                );
            }
        }
        let other = ServiceConfig { seed: 43, ..cfg };
        let same_everywhere = (0..16u32)
            .all(|k| backoff_ticks(&cfg, k as usize, 1) == backoff_ticks(&other, k as usize, 1));
        assert!(!same_everywhere, "seed must key the jitter");
    }

    #[test]
    fn journal_round_trips_through_warm_from() {
        let mut svc = service(2, ServiceConfig::default());
        svc.submit(train_req("a", 6, 1, 0.0), None, 0).unwrap();
        svc.submit(train_req("b", 6, 2, 0.4), None, 0).unwrap();
        svc.run_to_idle();
        svc.submit(
            FleetRequest::Update { tenant: "a".into(), data: toy_data(40, 3, 0.9) },
            None,
            0,
        )
        .unwrap();
        svc.run_to_idle();
        let journal = svc.journal().clone();
        assert_eq!(journal.record_boundaries().len(), 4, "header + 3 records");

        let mut cold = service(2, ServiceConfig::default());
        let (applied, torn) = cold.warm_from(&journal);
        assert_eq!((applied, torn), (2, None));
        for t in ["a", "b"] {
            let live: Vec<u64> =
                svc.trainer().model(t).unwrap().beta.iter().map(|v| v.to_bits()).collect();
            let rec: Vec<u64> = cold
                .trainer()
                .model(t)
                .unwrap()
                .beta
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(live, rec, "tenant {t} recovery must be bit-identical");
        }
        // torn tail: typed, prefix still applies
        let cut = journal.record_boundaries()[2] + 5;
        let torn_journal = TenantJournal::from_bytes(journal.as_bytes()[..cut].to_vec());
        let mut cold2 = service(2, ServiceConfig::default());
        let (applied, torn) = cold2.warm_from(&torn_journal);
        assert_eq!(applied, 2, "intact prefix restores");
        assert_eq!(torn.as_ref().map(|e| e.class()), Some("journal-torn"));
    }

    #[test]
    fn run_to_idle_fast_forwards_backoff_windows() {
        let mut svc = service(1, ServiceConfig::default());
        svc.submit(train_req("a", 6, 1, 0.0), None, 0).unwrap();
        // artificially push the request deep into the future; run_to_idle
        // must jump there instead of spinning one tick at a time
        svc.queue[0].eligible = 1_000;
        let done = svc.run_to_idle();
        assert_eq!(done.len(), 1);
        assert!(done[0].outcome.is_ok());
        assert_eq!(svc.now(), 1_000, "clock fast-forwarded to the eligible tick");
    }
}
