//! Minimal CSV loader so users can run the trainers on *real* series, not
//! only the Table-3 generators: one numeric column (selectable by index or
//! header name), `#`-comments and blank lines skipped, non-numeric cells
//! rejected with row context.

#![forbid(unsafe_code)]

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Parse a CSV file into one numeric column.
pub fn load_column(path: &Path, column: &str) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    load_column_str(&text, column)
}

/// `column` is a 0-based index ("2") or a header name ("load_mw").
pub fn load_column_str(text: &str, column: &str) -> Result<Vec<f64>> {
    let mut lines = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'));
    let first = match lines.next() {
        Some(l) => l,
        None => bail!("empty CSV"),
    };
    let first_cells = split_row(first);

    // resolve column index; detect whether the first row is a header
    let (idx, header_consumed) = match column.parse::<usize>() {
        Ok(i) => {
            let is_header = first_cells.get(i).map_or(false, |c| c.parse::<f64>().is_err());
            (i, is_header)
        }
        Err(_) => {
            let i = first_cells
                .iter()
                .position(|c| c.eq_ignore_ascii_case(column))
                .with_context(|| {
                    format!("column {column:?} not in header {first_cells:?}")
                })?;
            (i, true)
        }
    };

    let mut out = Vec::new();
    let mut push = |cells: &[String], line_no: usize| -> Result<()> {
        let cell = cells
            .get(idx)
            .with_context(|| format!("row {line_no}: no column {idx}"))?;
        let v: f64 = cell
            .parse()
            .with_context(|| format!("row {line_no}: {cell:?} is not numeric"))?;
        out.push(v);
        Ok(())
    };
    if !header_consumed {
        push(&first_cells, 1)?;
    }
    for (i, line) in lines.enumerate() {
        push(&split_row(line), i + 2)?;
    }
    if out.is_empty() {
        bail!("no data rows");
    }
    Ok(out)
}

/// Split one CSV row (double-quoted fields with `""` escapes supported).
fn split_row(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                cells.push(cur.trim().to_string());
                cur.clear();
            }
            c => cur.push(c),
        }
    }
    cells.push(cur.trim().to_string());
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_by_header_name() {
        let csv = "time,load_mw\n1,10.5\n2,11.25\n3,9.0\n";
        assert_eq!(load_column_str(csv, "load_mw").unwrap(), vec![10.5, 11.25, 9.0]);
    }

    #[test]
    fn loads_by_index_headerless() {
        let csv = "1.0,2.0\n3.0,4.0\n";
        assert_eq!(load_column_str(csv, "1").unwrap(), vec![2.0, 4.0]);
    }

    #[test]
    fn loads_by_index_with_header() {
        let csv = "a,b\n1,2\n3,4\n";
        assert_eq!(load_column_str(csv, "1").unwrap(), vec![2.0, 4.0]);
    }

    #[test]
    fn skips_comments_and_blanks() {
        let csv = "# generated\n\nvalue\n1\n\n# mid comment\n2\n";
        assert_eq!(load_column_str(csv, "value").unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn quoted_fields() {
        let csv = "name,v\n\"a,b\",3.5\n\"say \"\"hi\"\"\",4.5\n";
        assert_eq!(load_column_str(csv, "v").unwrap(), vec![3.5, 4.5]);
    }

    #[test]
    fn errors_have_row_context() {
        let csv = "v\n1.0\nnot_a_number\n";
        let err = format!("{:#}", load_column_str(csv, "v").unwrap_err());
        assert!(err.contains("row 3"), "{err}");
        let err2 = format!("{:#}", load_column_str("a,b\n1,2\n", "zzz").unwrap_err());
        assert!(err2.contains("zzz"), "{err2}");
        assert!(load_column_str("", "0").is_err());
        assert!(load_column_str("header_only\n", "0").is_err());
    }
}
