//! Benchmark data substrate: the ten Table-3 time-series datasets.
//!
//! The paper evaluates on Kaggle/UCI datasets that are not redistributable;
//! per DESIGN.md §3 we build deterministic synthetic generators that match
//! each dataset's published row count, output statistics (mean/std/min/max)
//! and qualitative temporal structure (trend/seasonality/noise regime).
//! ELM training cost depends only on (n, S, Q, M), so the speedup
//! experiments are unaffected by the substitution; the RMSE experiments
//! (Table 4) get realistic learnable structure.

#![forbid(unsafe_code)]

pub mod csv;
pub mod normalize;
pub mod spec;
pub mod stats;
pub mod synth;
pub mod window;

pub use normalize::MinMax;
pub use spec::{registry, DatasetSpec, SizeCategory};
pub use stats::Stats;
pub use window::Windowed;
