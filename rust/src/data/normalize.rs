//! Min-max normalization fitted on the training split only (no test-set
//! leakage). The paper's RMSE magnitudes indicate normalized targets; we
//! report RMSE on the [0, 1] scale and note the paper's "large output =>
//! large RMSE" observation in EXPERIMENTS.md.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy)]
pub struct MinMax {
    pub lo: f64,
    pub hi: f64,
}

impl MinMax {
    /// Fit on a slice (typically the training prefix).
    pub fn fit(xs: &[f64]) -> Result<MinMax> {
        if xs.is_empty() {
            bail!("cannot fit normalizer on empty slice");
        }
        let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        if !(lo.is_finite() && hi.is_finite()) {
            bail!("non-finite values in normalizer input");
        }
        Ok(MinMax { lo, hi })
    }

    #[inline]
    pub fn apply(&self, x: f64) -> f64 {
        let span = (self.hi - self.lo).max(1e-12);
        (x - self.lo) / span
    }

    #[inline]
    pub fn invert(&self, z: f64) -> f64 {
        let span = (self.hi - self.lo).max(1e-12);
        self.lo + z * span
    }

    pub fn apply_all(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.apply(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_train_to_unit_interval() {
        let xs = vec![-5.0, 0.0, 10.0, 2.5];
        let n = MinMax::fit(&xs).unwrap();
        let z = n.apply_all(&xs);
        assert_eq!(z[0], 0.0);
        assert_eq!(z[2], 1.0);
        assert!(z.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn round_trips() {
        let xs = vec![3.0, 7.0, 11.0];
        let n = MinMax::fit(&xs).unwrap();
        for &x in &xs {
            assert!((n.invert(n.apply(x)) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn test_values_can_exceed_unit_interval() {
        // values outside the train range extrapolate, by design
        let n = MinMax::fit(&[0.0, 1.0]).unwrap();
        assert!(n.apply(2.0) > 1.0);
        assert!(n.apply(-1.0) < 0.0);
    }

    #[test]
    fn degenerate_range_does_not_divide_by_zero() {
        let n = MinMax::fit(&[5.0, 5.0]).unwrap();
        assert!(n.apply(5.0).is_finite());
    }

    #[test]
    fn rejects_empty_and_nan() {
        assert!(MinMax::fit(&[]).is_err());
        assert!(MinMax::fit(&[f64::NAN]).is_err());
    }
}
