//! The Table-3 dataset registry: published size, Q, split and output
//! statistics for each of the ten benchmarks, plus generation.

#![forbid(unsafe_code)]

use crate::util::rng::Rng;

use super::synth;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeCategory {
    Small,
    Medium,
    Large,
}

impl SizeCategory {
    pub fn label(&self) -> &'static str {
        match self {
            SizeCategory::Small => "Small",
            SizeCategory::Medium => "Medium",
            SizeCategory::Large => "Large",
        }
    }
}

/// One Table-3 row.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub category: SizeCategory,
    /// published number of instances
    pub n_instances: usize,
    /// published lag-window length Q (exoplanet's 3197 is capped at 64 for
    /// measured runs — DESIGN.md §3; the model runs use the full value)
    pub q: usize,
    pub q_paper: usize,
    /// train fraction (%)
    pub train_pct: usize,
    /// published output statistics
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    /// M used by Table 4 for this dataset ("selected according to size")
    pub table4_m: usize,
}

impl DatasetSpec {
    /// Generate the synthetic series at `scale` of the published length
    /// (deterministic in `seed`), rescaled to the published statistics.
    pub fn generate(&self, scale: f64, seed: u64) -> Vec<f64> {
        let n = ((self.n_instances as f64 * scale).round() as usize).max(self.q + 16);
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        let mut xs = match self.name {
            "japan_population" => synth::japan_population(n, &mut rng),
            "quebec_births" => synth::quebec_births(n, &mut rng),
            "exoplanet" => synth::exoplanet(n, &mut rng),
            "sp500" => synth::sp500(n, &mut rng),
            "aemo" => synth::aemo(n, &mut rng),
            "hourly_weather" => synth::hourly_weather(n, &mut rng),
            "energy_consumption" => synth::energy_consumption(n, &mut rng),
            "electricity_load" => synth::electricity_load(n, &mut rng),
            "stock_prices" => synth::stock_prices(n, &mut rng),
            "temperature" => synth::temperature(n, &mut rng),
            other => panic!("unknown dataset {other}"),
        };
        synth::fit_stats(&mut xs, self.mean, self.std, self.min, self.max);
        xs
    }

    pub fn train_frac(&self) -> f64 {
        self.train_pct as f64 / 100.0
    }
}

/// Stable tiny hash so each dataset gets an independent stream per seed.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// The ten benchmarks, ordered by size as in Table 3.
pub fn registry() -> Vec<DatasetSpec> {
    use SizeCategory::*;
    vec![
        DatasetSpec {
            name: "japan_population",
            category: Small,
            n_instances: 2_540,
            q: 10,
            q_paper: 10,
            train_pct: 80,
            mean: 1.40e6,
            std: 1.40e6,
            min: 1.00e5,
            max: 1.03e8,
            table4_m: 10,
        },
        DatasetSpec {
            name: "quebec_births",
            category: Small,
            n_instances: 5_113,
            q: 10,
            q_paper: 10,
            train_pct: 80,
            mean: 2.51e2,
            std: 4.19e1,
            min: -2.31e1,
            max: 3.66e2,
            table4_m: 10,
        },
        DatasetSpec {
            name: "exoplanet",
            category: Small,
            n_instances: 5_657,
            q: 64,
            q_paper: 3_197,
            train_pct: 80,
            mean: -3.01e2,
            std: 1.45e4,
            min: -6.43e5,
            max: 2.11e5,
            table4_m: 100,
        },
        DatasetSpec {
            name: "sp500",
            category: Medium,
            n_instances: 17_218,
            q: 10,
            q_paper: 10,
            train_pct: 80,
            mean: 8.99e8,
            std: 1.53e9,
            min: 1.00e6,
            max: 1.15e10,
            table4_m: 10,
        },
        DatasetSpec {
            name: "aemo",
            category: Medium,
            n_instances: 17_520,
            q: 10,
            q_paper: 10,
            train_pct: 80,
            mean: 7.98e3,
            std: 1.19e3,
            min: 5.11e3,
            max: 1.38e4,
            table4_m: 10,
        },
        DatasetSpec {
            name: "hourly_weather",
            category: Medium,
            n_instances: 45_300,
            q: 50,
            q_paper: 50,
            train_pct: 80,
            mean: 2.79e2,
            std: 3.78e1,
            min: 0.0,
            max: 3.07e2,
            table4_m: 20,
        },
        DatasetSpec {
            name: "energy_consumption",
            category: Large,
            n_instances: 119_000,
            q: 10,
            q_paper: 10,
            train_pct: 70,
            mean: 1.66e3,
            std: 3.02e2,
            min: 0.0,
            max: 3.05e3,
            table4_m: 10,
        },
        DatasetSpec {
            name: "electricity_load",
            category: Large,
            n_instances: 280_514,
            q: 10,
            q_paper: 10,
            train_pct: 70,
            mean: 2.70e14,
            std: 2.60e14,
            min: 0.0,
            max: 9.90e14,
            table4_m: 10,
        },
        DatasetSpec {
            name: "stock_prices",
            category: Large,
            n_instances: 619_000,
            q: 50,
            q_paper: 50,
            train_pct: 70,
            mean: 4.48e6,
            std: 1.08e7,
            min: 0.0,
            max: 2.06e9,
            table4_m: 20,
        },
        DatasetSpec {
            name: "temperature",
            category: Large,
            n_instances: 998_000,
            q: 50,
            q_paper: 50,
            train_pct: 70,
            mean: 5.07e1,
            std: 2.21e1,
            min: 4.0,
            max: 8.10e1,
            table4_m: 20,
        },
    ]
}

/// Lookup by name.
pub fn by_name(name: &str) -> Option<DatasetSpec> {
    registry().into_iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stats::Stats;

    #[test]
    fn registry_has_ten_ordered_by_size() {
        let r = registry();
        assert_eq!(r.len(), 10);
        for w in r.windows(2) {
            assert!(w[0].n_instances <= w[1].n_instances);
        }
    }

    #[test]
    fn generated_stats_match_table3() {
        // scaled down for test speed; moments should still land close
        for d in registry() {
            let xs = d.generate(0.05, 42);
            let s = Stats::of(&xs);
            assert!(s.min() >= d.min - 1e-9, "{}: min {}", d.name, s.min());
            assert!(s.max() <= d.max + 1e-9, "{}: max {}", d.name, s.max());
            let mean_err = (s.mean() - d.mean).abs() / d.std.max(1.0);
            assert!(mean_err < 0.35, "{}: mean off by {mean_err} std", d.name);
            let std_ratio = s.std() / d.std;
            assert!(
                (0.5..=1.5).contains(&std_ratio),
                "{}: std ratio {std_ratio}",
                d.name
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let d = by_name("aemo").unwrap();
        assert_eq!(d.generate(0.02, 7), d.generate(0.02, 7));
        assert_ne!(d.generate(0.02, 7), d.generate(0.02, 8));
    }

    #[test]
    fn q_capping_only_for_exoplanet() {
        for d in registry() {
            if d.name == "exoplanet" {
                assert_eq!(d.q, 64);
                assert_eq!(d.q_paper, 3197);
            } else {
                assert_eq!(d.q, d.q_paper);
            }
        }
    }

    #[test]
    fn table4_m_follows_paper_rule() {
        // M=100 exoplanet, M=20 for Q=50 datasets, M=10 for the rest
        for d in registry() {
            if d.name == "exoplanet" {
                assert_eq!(d.table4_m, 100);
            } else if d.q == 50 {
                assert_eq!(d.table4_m, 20);
            } else {
                assert_eq!(d.table4_m, 10);
            }
        }
    }
}
