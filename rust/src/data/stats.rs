//! Streaming summary statistics (Welford) used by the generators' tests and
//! the Table 3 report.

#![forbid(unsafe_code)]

#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Stats {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn of(xs: &[f64]) -> Stats {
        let mut s = Stats::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let se: f64 = pred.iter().zip(truth).map(|(p, t)| (p - t) * (p - t)).sum();
    (se / pred.len() as f64).sqrt()
}

/// Mean squared error.
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    let r = rmse(pred, truth);
    r * r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.3 - 7.0).collect();
        let s = Stats::of(&xs);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((s.mean() - mean).abs() < 1e-9);
        assert!((s.var() - var).abs() < 1e-6);
        assert_eq!(s.min(), *xs.iter().min_by(|a, b| a.total_cmp(b)).unwrap());
        assert_eq!(s.max(), *xs.iter().max_by(|a, b| a.total_cmp(b)).unwrap());
    }

    #[test]
    fn rmse_zero_for_identical() {
        let a = vec![1.0, 2.0, 3.0];
        assert_eq!(rmse(&a, &a), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let p = vec![0.0, 0.0];
        let t = vec![3.0, 4.0];
        assert!((rmse(&p, &t) - (12.5f64).sqrt()).abs() < 1e-12);
        assert!((mse(&p, &t) - 12.5).abs() < 1e-9);
    }
}
