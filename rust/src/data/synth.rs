//! Synthetic time-series generators, one per Table-3 benchmark.
//!
//! Each generator produces the dataset's qualitative temporal structure;
//! `fit_stats` then affinely rescales to the published mean/std and clamps
//! to the published min/max. Determinism: same (n, seed) → same series.

#![forbid(unsafe_code)]

use crate::util::rng::Rng;

/// Affine-rescale `xs` to the target mean/std, then clamp to [min, max].
/// Clamping perturbs the moments slightly — the spec tests allow ~10%.
pub fn fit_stats(xs: &mut [f64], mean_t: f64, std_t: f64, min_t: f64, max_t: f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-12);
    for x in xs.iter_mut() {
        *x = mean_t + std_t * (*x - mean) / std;
        *x = x.clamp(min_t, max_t);
    }
}

/// Japan population: per-region census levels as *panel data* — a fixed
/// set of regions with log-normal scale spread (std ≈ mean, max ≫ mean)
/// cycled each "year" with slow per-region growth. Interleaving keeps the
/// train/test marginals aligned (the real dataset is region×year panels).
pub fn japan_population(n: usize, rng: &mut Rng) -> Vec<f64> {
    let k = 8usize; // regions (cycle fits inside a Q = 10 lag window)
    let levels: Vec<f64> = (0..k).map(|_| (rng.normal() * 1.6).exp()).collect();
    let growth: Vec<f64> = (0..k).map(|_| 1.0 + rng.range(-0.002, 0.004)).collect();
    (0..n)
        .map(|i| {
            let region = i % k;
            let year = (i / k) as f64;
            levels[region] * growth[region].powf(year) * (1.0 + 0.01 * rng.normal())
        })
        .collect()
}

/// Quebec births: daily counts with weekly cycle, mild annual cycle, noise.
pub fn quebec_births(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let t = i as f64;
            let weekly = (2.0 * std::f64::consts::PI * t / 7.0).sin();
            let annual = (2.0 * std::f64::consts::PI * t / 365.25).sin();
            weekly * 0.8 + annual * 0.5 + rng.normal() * 0.7
        })
        .collect()
}

/// Exoplanet (Kepler light curves): near-flat flux with deep transit dips
/// and occasional flares — extremely heavy lower tail.
pub fn exoplanet(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    let mut i = 0usize;
    while i < n {
        // flat segment with photon noise
        let seg = (50 + rng.below(200)).min(n - i);
        for _ in 0..seg {
            out.push(rng.normal() * 0.05);
        }
        i += seg;
        if i >= n {
            break;
        }
        // transit dip (deep negative) or flare (positive), short
        let ev = (3 + rng.below(12)).min(n - i);
        let depth = if rng.uniform() < 0.8 { -rng.range(5.0, 40.0) } else { rng.range(2.0, 12.0) };
        for k in 0..ev {
            let shape = (k as f64 / ev as f64 * std::f64::consts::PI).sin();
            out.push(depth * shape + rng.normal() * 0.05);
        }
        i += ev;
    }
    out
}

/// SP500 index level: geometric random walk with drift (1950→present).
pub fn sp500(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut v: f64 = 0.0; // log-price
    (0..n)
        .map(|_| {
            v += 0.0004 + 0.01 * rng.normal();
            v.exp()
        })
        .collect()
}

/// AEMO electricity demand: strong daily + weekly seasonality (half-hourly).
pub fn aemo(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let t = i as f64;
            let daily = (2.0 * std::f64::consts::PI * t / 48.0).sin();
            let weekly = (2.0 * std::f64::consts::PI * t / (48.0 * 7.0)).sin();
            let annual = (2.0 * std::f64::consts::PI * t / (48.0 * 365.0)).cos();
            daily * 1.0 + weekly * 0.3 + annual * 0.5 + rng.normal() * 0.25
        })
        .collect()
}

/// Hourly weather (temperature, Kelvin): annual + daily cycles.
pub fn hourly_weather(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut drift = 0.0;
    (0..n)
        .map(|i| {
            let t = i as f64;
            let annual = (2.0 * std::f64::consts::PI * t / (24.0 * 365.0)).sin();
            let daily = (2.0 * std::f64::consts::PI * t / 24.0).sin();
            drift = 0.995 * drift + 0.1 * rng.normal();
            annual * 1.2 + daily * 0.4 + drift
        })
        .collect()
}

/// PJM hourly energy consumption (MW): daily/weekly cycles + load noise.
pub fn energy_consumption(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut drift = 0.0;
    (0..n)
        .map(|i| {
            let t = i as f64;
            let daily = (2.0 * std::f64::consts::PI * t / 24.0).sin();
            let weekly = if ((t / 24.0) as u64) % 7 >= 5 { -0.5 } else { 0.2 };
            drift = 0.99 * drift + 0.05 * rng.normal();
            daily + weekly + drift + rng.normal() * 0.15
        })
        .collect()
}

/// UCI electricity load (substation level): bursty nonnegative load with
/// huge dynamic range (values up to ~1e15 in the paper's units).
pub fn electricity_load(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut level: f64 = 0.0;
    (0..n)
        .map(|i| {
            let t = i as f64;
            level = 0.999 * level + 0.05 * rng.normal();
            let daily = (2.0 * std::f64::consts::PI * t / 96.0).sin();
            // occasional outage: drop to zero
            if rng.uniform() < 0.01 {
                -10.0
            } else {
                (level + 0.8 * daily).exp()
            }
        })
        .collect()
}

/// S&P-500 per-company stock prices: many independent geometric walks
/// concatenated — heavy right tail across companies.
pub fn stock_prices(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    let mut remaining = n;
    while remaining > 0 {
        let seg = (200 + rng.below(1000)).min(remaining);
        let scale = (rng.normal() * 2.0).exp(); // company price scale
        let mut logp: f64 = 0.0;
        for _ in 0..seg {
            logp += 0.0003 + 0.02 * rng.normal();
            out.push(scale * logp.exp());
        }
        remaining -= seg;
    }
    out
}

/// PMSM motor temperature: slow thermal response to load cycles.
pub fn temperature(n: usize, rng: &mut Rng) -> Vec<f64> {
    let mut temp = 0.0;
    let mut load = 0.0;
    (0..n)
        .map(|_| {
            if rng.uniform() < 0.002 {
                load = rng.range(-1.0, 1.5); // new operating point
            }
            // first-order thermal lag toward the load-dependent steady state
            temp += 0.01 * (load - temp) + 0.01 * rng.normal();
            temp
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stats::Stats;

    #[test]
    fn fit_stats_hits_targets() {
        let mut rng = Rng::new(1);
        let mut xs: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        fit_stats(&mut xs, 100.0, 15.0, 0.0, 1000.0);
        let s = Stats::of(&xs);
        assert!((s.mean() - 100.0).abs() < 2.0);
        assert!((s.std() - 15.0).abs() < 2.0);
        assert!(s.min() >= 0.0 && s.max() <= 1000.0);
    }

    #[test]
    fn generators_are_deterministic() {
        for f in [quebec_births, sp500, aemo, temperature] {
            let a = f(500, &mut Rng::new(9));
            let b = f(500, &mut Rng::new(9));
            assert_eq!(a, b);
        }
    }

    #[test]
    fn generators_have_requested_length() {
        for f in [
            japan_population,
            quebec_births,
            exoplanet,
            sp500,
            aemo,
            hourly_weather,
            energy_consumption,
            electricity_load,
            stock_prices,
            temperature,
        ] {
            assert_eq!(f(1234, &mut Rng::new(3)).len(), 1234);
        }
    }

    #[test]
    fn exoplanet_has_heavy_lower_tail() {
        let xs = exoplanet(20_000, &mut Rng::new(5));
        let s = Stats::of(&xs);
        assert!(s.min() < s.mean() - 10.0 * s.std().max(1e-9) || s.min() < -5.0);
    }

    #[test]
    fn sp500_is_positive_and_growing() {
        let xs = sp500(50_000, &mut Rng::new(6));
        assert!(xs.iter().all(|&x| x > 0.0));
        let first = Stats::of(&xs[..5000]).mean();
        let last = Stats::of(&xs[45_000..]).mean();
        assert!(last > first, "geometric drift should grow: {first} -> {last}");
    }

    #[test]
    fn aemo_has_daily_cycle() {
        // autocorrelation at lag 48 (one day) should be clearly positive
        let xs = aemo(20_000, &mut Rng::new(7));
        let s = Stats::of(&xs);
        let (mean, var) = (s.mean(), s.var());
        let ac: f64 = xs[..xs.len() - 48]
            .iter()
            .zip(&xs[48..])
            .map(|(a, b)| (a - mean) * (b - mean))
            .sum::<f64>()
            / ((xs.len() - 48) as f64 * var);
        assert!(ac > 0.4, "lag-48 autocorrelation {ac}");
    }

    #[test]
    fn temperature_is_smooth() {
        // thermal lag: successive diffs must be small vs the overall range
        let xs = temperature(50_000, &mut Rng::new(8));
        let s = Stats::of(&xs);
        let max_step = xs.windows(2).map(|w| (w[1] - w[0]).abs()).fold(0.0, f64::max);
        assert!(max_step < 0.2 * (s.max() - s.min()));
    }
}
