//! Lag-window embedding: series → (X ∈ n×S×Q, Y ∈ n, yhist ∈ n×Q).
//!
//! Sample i covers series steps [i, i+Q): `x[i, 0, t] = y(i+t)` for
//! t = 0..Q, target `Y[i] = y(i+Q)`. The Jordan/NARMAX feedback history is
//! `yhist[i, k-1] = y(i+Q-k)` (the window read backwards) — teacher
//! forcing per DESIGN.md §2. S = 1 (univariate) throughout the benchmarks;
//! the layout keeps the S axis so multivariate extensions slot in.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// A windowed dataset in the exact f32 layouts the artifacts consume.
#[derive(Debug, Clone)]
pub struct Windowed {
    pub n: usize,
    pub s: usize,
    pub q: usize,
    /// row-major (n, s, q)
    pub x: Vec<f32>,
    /// (n,)
    pub y: Vec<f32>,
    /// row-major (n, q): yhist[i][k-1] = y(t-k), teacher-forced feedback
    pub yhist: Vec<f32>,
}

impl Windowed {
    pub fn from_series(series: &[f64], q: usize) -> Result<Windowed> {
        if series.len() <= q {
            bail!("series of {} too short for Q = {q}", series.len());
        }
        let n = series.len() - q;
        let s = 1usize;
        let mut x = vec![0f32; n * s * q];
        let mut y = vec![0f32; n];
        let mut yhist = vec![0f32; n * q];
        for i in 0..n {
            for t in 0..q {
                x[i * q + t] = series[i + t] as f32;
            }
            y[i] = series[i + q] as f32;
            for k in 1..=q {
                yhist[i * q + (k - 1)] = series[i + q - k] as f32;
            }
        }
        Ok(Windowed { n, s, q, x, y, yhist })
    }

    /// Split at a fraction: (train, test), sequential (time-ordered).
    pub fn split(&self, train_frac: f64) -> (Windowed, Windowed) {
        let n_train = ((self.n as f64 * train_frac).round() as usize).clamp(1, self.n - 1);
        (self.slice(0, n_train), self.slice(n_train, self.n))
    }

    /// Rows [lo, hi).
    pub fn slice(&self, lo: usize, hi: usize) -> Windowed {
        assert!(lo <= hi && hi <= self.n);
        let sq = self.s * self.q;
        Windowed {
            n: hi - lo,
            s: self.s,
            q: self.q,
            x: self.x[lo * sq..hi * sq].to_vec(),
            y: self.y[lo..hi].to_vec(),
            yhist: self.yhist[lo * self.q..hi * self.q].to_vec(),
        }
    }

    /// One row's X window (s*q values).
    pub fn x_row(&self, i: usize) -> &[f32] {
        &self.x[i * self.s * self.q..(i + 1) * self.s * self.q]
    }

    pub fn yhist_row(&self, i: usize) -> &[f32] {
        &self.yhist[i * self.q..(i + 1) * self.q]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(n: usize) -> Vec<f64> {
        (0..n).map(|i| i as f64).collect()
    }

    #[test]
    fn window_alignment() {
        let w = Windowed::from_series(&series(20), 4).unwrap();
        assert_eq!(w.n, 16);
        // sample 0: x = [0,1,2,3], y = 4
        assert_eq!(w.x_row(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(w.y[0], 4.0);
        // yhist[0][k-1] = y(4-k) = [3,2,1,0]
        assert_eq!(w.yhist_row(0), &[3.0, 2.0, 1.0, 0.0]);
        // sample 7: x = [7..11), y = 11
        assert_eq!(w.x_row(7), &[7.0, 8.0, 9.0, 10.0]);
        assert_eq!(w.y[7], 11.0);
    }

    #[test]
    fn yhist_is_reversed_window() {
        let w = Windowed::from_series(&series(30), 5).unwrap();
        for i in 0..w.n {
            let xr = w.x_row(i);
            let yh = w.yhist_row(i);
            for k in 0..5 {
                assert_eq!(yh[k], xr[5 - 1 - k]);
            }
        }
    }

    #[test]
    fn split_is_sequential_and_disjoint() {
        let w = Windowed::from_series(&series(104), 4).unwrap();
        let (tr, te) = w.split(0.8);
        assert_eq!(tr.n, 80);
        assert_eq!(te.n, 20);
        assert_eq!(tr.y[79], w.y[79]);
        assert_eq!(te.y[0], w.y[80]);
    }

    #[test]
    fn split_extremes_clamped() {
        let w = Windowed::from_series(&series(14), 4).unwrap();
        let (tr, te) = w.split(0.0);
        assert_eq!(tr.n, 1);
        assert!(te.n >= 1);
        let (tr2, te2) = w.split(1.0);
        assert_eq!(te2.n, 1);
        assert!(tr2.n >= 1);
    }

    #[test]
    fn too_short_series_rejected() {
        assert!(Windowed::from_series(&series(4), 4).is_err());
        assert!(Windowed::from_series(&series(5), 4).is_ok());
    }
}
