//! Scalar f32 activations; f32 to stay comparable with the XLA artifacts.

#![forbid(unsafe_code)]

#[inline(always)]
pub fn tanh(x: f32) -> f32 {
    x.tanh()
}

#[inline(always)]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_symmetry() {
        for x in [-3.0f32, -0.5, 0.0, 1.25, 8.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn saturation() {
        assert!(sigmoid(40.0) > 0.999_999);
        assert!(sigmoid(-40.0) < 1e-6);
        assert!((tanh(40.0) - 1.0).abs() < 1e-6);
    }
}
