//! Elman recurrence (Eq 6): diagonal self-feedback over the last Q states.

use crate::elm::activation::tanh;
use crate::elm::params::ElmParams;
use crate::linalg::Matrix;

use super::{lift_wx, wx_at, SampleBlock};

/// One sample: h_j(t) = g(w_j·x(t) + b_j + Σ_{k=1..t} α[j,k] h_j(t−k)).
pub fn h_row(p: &ElmParams, x: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w = p.buf("w");
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, q): alpha[j*q + (k-1)]
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + j] = h_j(t-k)
    for t in 0..q {
        for j in 0..m {
            let mut acc = wx_at(w, x, s, q, m, j, t) + b[j];
            for k in 1..=t.min(q) {
                acc += alpha[j * q + (k - 1)] * hist[(k - 1) * m + j];
            }
            out[j] = tanh(acc);
        }
        // shift history: hist[k] <- hist[k-1], hist[0] <- h(t)
        for k in (1..q).rev() {
            let (lo, hi) = hist.split_at_mut(k * m);
            hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
        }
        hist[..m].copy_from_slice(out);
    }
}

/// Whole row block: the input projections come from one block-wide GEMM
/// (`lift_wx`); the diagonal recurrence then runs per sample on the
/// precomputed values.
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    let (q, m) = (p.q, p.m);
    let wx = lift_wx(p.buf("w"), 1, blk, p.s, q, m);
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, q): alpha[j*q + (k-1)]
    let mut h = Matrix::zeros(blk.rows, m);
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + j] = h_j(t-k)
    let mut cur = vec![0f32; m];
    for i in 0..blk.rows {
        hist.iter_mut().for_each(|v| *v = 0.0);
        for t in 0..q {
            let wrow = wx.row(i * q + t);
            for j in 0..m {
                let mut acc = wrow[j] as f32 + b[j];
                for k in 1..=t.min(q) {
                    acc += alpha[j * q + (k - 1)] * hist[(k - 1) * m + j];
                }
                cur[j] = tanh(acc);
            }
            for k in (1..q).rev() {
                let (lo, hi) = hist.split_at_mut(k * m);
                hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
            }
            hist[..m].copy_from_slice(&cur);
        }
        for j in 0..m {
            h[(i, j)] = cur[j] as f64;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::params::Arch;

    #[test]
    fn zero_alpha_is_feedforward() {
        let (s, q, m) = (2, 4, 3);
        let mut p = ElmParams::init(Arch::Elman, s, q, m, 5);
        p.bufs[2].iter_mut().for_each(|a| *a = 0.0);
        let x: Vec<f32> = (0..s * q).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut out = vec![0f32; m];
        h_row(&p, &x, &mut out);
        let w = p.buf("w");
        let b = p.buf("b");
        for j in 0..m {
            let want = (wx_at(w, &x, s, q, m, j, q - 1) + b[j]).tanh();
            assert!((out[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn one_step_recurrence_exact() {
        let (s, q, m) = (1, 2, 2);
        let p = ElmParams::init(Arch::Elman, s, q, m, 9);
        let x = vec![0.7f32, -0.4];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &mut out);
        let (w, b, alpha) = (p.buf("w"), p.buf("b"), p.buf("alpha"));
        for j in 0..m {
            let h1 = (w[j] * x[0] + b[j]).tanh();
            let want = (w[j] * x[1] + b[j] + alpha[j * q] * h1).tanh();
            assert!((out[j] - want).abs() < 1e-6);
        }
    }
}
