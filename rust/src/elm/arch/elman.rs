//! Elman recurrence (Eq 6): diagonal self-feedback over the last Q states.

#![forbid(unsafe_code)]

use crate::elm::activation::tanh;
use crate::elm::params::ElmParams;
use crate::linalg::{Matrix, MatrixF32};

use super::{lift_wx, wx_at, SampleBlock};

/// One sample: h_j(t) = g(w_j·x(t) + b_j + Σ_{k=1..t} α[j,k] h_j(t−k)).
pub fn h_row(p: &ElmParams, x: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w = p.buf("w");
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, q): alpha[j*q + (k-1)]
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + j] = h_j(t-k)
    for t in 0..q {
        for j in 0..m {
            let mut acc = wx_at(w, x, s, q, m, j, t) + b[j];
            for k in 1..=t.min(q) {
                acc += alpha[j * q + (k - 1)] * hist[(k - 1) * m + j];
            }
            out[j] = tanh(acc);
        }
        // shift history: hist[k] <- hist[k-1], hist[0] <- h(t)
        for k in (1..q).rev() {
            let (lo, hi) = hist.split_at_mut(k * m);
            hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
        }
        hist[..m].copy_from_slice(out);
    }
}

/// Whole row block, widened to f64 — an exact cast of [`h_block_f32`]
/// (every H entry is an f32 tanh output, so the widening loses nothing).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Whole row block, **f32-born**: the input projections come from one
/// block-wide GEMM (`lift_wx`); the diagonal recurrence then advances
/// **four samples in lockstep** (lane-contiguous state, index
/// `[j·4 + lane]`, matching the Gram microkernel's width) so the per-j
/// loop streams four independent accumulators per alpha load. Lanes never
/// mix, so every sample's value is bit-identical to the scalar tail path
/// (and to `h_row` up to the lifted-GEMM association, bounded by the
/// property tests). The activations are f32 tanh outputs and are stored
/// straight into `MatrixF32` — no f64 materialization, half the block
/// memory.
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    h_block_f32_from(p, blk, 0)
}

/// [`h_block_f32`] started at timestep `t_start` from a zero state — the
/// warm-up-truncated kernel behind `RecurrenceMode::Chunked`. With
/// `t_start == 0` this *is* the sequential kernel (the same loop over the
/// same range — bit-identical by construction); with `t_start > 0` the
/// lags reaching before `t_start` read the zero history instead of real
/// states, which is exactly the chunked warm-up truncation the envelope
/// suite (`tests/scan_props.rs`) documents. Elman's full-lag diagonal
/// feedback makes its truncation envelope the loosest of the stateful
/// architectures: a lag-`k` term sees a zero instead of a value in
/// `(−1, 1)`, so the warm-up must cover the whole lag window back to
/// `t = 0` for exactness.
pub(crate) fn h_block_f32_from(
    p: &ElmParams,
    blk: &SampleBlock,
    t_start: usize,
) -> MatrixF32 {
    let (q, m) = (p.q, p.m);
    let wx = lift_wx(p.buf("w"), 1, blk, p.s, q, m);
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, q): alpha[j*q + (k-1)]
    let mut h = MatrixF32::zeros(blk.rows, m);

    // 4-wide sample groups: hist4[((k-1)*m + j)*4 + lane] = h_j(t-k) of
    // sample i0 + lane
    let mut hist4 = vec![0f32; q * m * 4];
    let mut cur4 = vec![0f32; m * 4];
    let full = blk.rows - blk.rows % 4;
    for i0 in (0..full).step_by(4) {
        hist4.iter_mut().for_each(|v| *v = 0.0);
        for t in t_start..q {
            let w0 = wx.row(i0 * q + t);
            let w1 = wx.row((i0 + 1) * q + t);
            let w2 = wx.row((i0 + 2) * q + t);
            let w3 = wx.row((i0 + 3) * q + t);
            for j in 0..m {
                let bj = b[j];
                let mut a0 = w0[j] as f32 + bj;
                let mut a1 = w1[j] as f32 + bj;
                let mut a2 = w2[j] as f32 + bj;
                let mut a3 = w3[j] as f32 + bj;
                for k in 1..=t.min(q) {
                    let al = alpha[j * q + (k - 1)];
                    let hb = ((k - 1) * m + j) * 4;
                    a0 += al * hist4[hb];
                    a1 += al * hist4[hb + 1];
                    a2 += al * hist4[hb + 2];
                    a3 += al * hist4[hb + 3];
                }
                let cb = j * 4;
                cur4[cb] = tanh(a0);
                cur4[cb + 1] = tanh(a1);
                cur4[cb + 2] = tanh(a2);
                cur4[cb + 3] = tanh(a3);
            }
            for k in (1..q).rev() {
                let (lo, hi) = hist4.split_at_mut(k * m * 4);
                hi[..m * 4].copy_from_slice(&lo[(k - 1) * m * 4..k * m * 4]);
            }
            hist4[..m * 4].copy_from_slice(&cur4);
        }
        for l in 0..4 {
            for j in 0..m {
                h[(i0 + l, j)] = cur4[j * 4 + l];
            }
        }
    }

    // scalar tail (rows % 4): the original per-sample recurrence
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + j] = h_j(t-k)
    let mut cur = vec![0f32; m];
    for i in full..blk.rows {
        hist.iter_mut().for_each(|v| *v = 0.0);
        for t in t_start..q {
            let wrow = wx.row(i * q + t);
            for j in 0..m {
                let mut acc = wrow[j] as f32 + b[j];
                for k in 1..=t.min(q) {
                    acc += alpha[j * q + (k - 1)] * hist[(k - 1) * m + j];
                }
                cur[j] = tanh(acc);
            }
            for k in (1..q).rev() {
                let (lo, hi) = hist.split_at_mut(k * m);
                hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
            }
            hist[..m].copy_from_slice(&cur);
        }
        for j in 0..m {
            h[(i, j)] = cur[j];
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::params::Arch;

    #[test]
    fn zero_alpha_is_feedforward() {
        let (s, q, m) = (2, 4, 3);
        let mut p = ElmParams::init(Arch::Elman, s, q, m, 5);
        p.bufs[2].iter_mut().for_each(|a| *a = 0.0);
        let x: Vec<f32> = (0..s * q).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut out = vec![0f32; m];
        h_row(&p, &x, &mut out);
        let w = p.buf("w");
        let b = p.buf("b");
        for j in 0..m {
            let want = (wx_at(w, &x, s, q, m, j, q - 1) + b[j]).tanh();
            assert!((out[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn one_step_recurrence_exact() {
        let (s, q, m) = (1, 2, 2);
        let p = ElmParams::init(Arch::Elman, s, q, m, 9);
        let x = vec![0.7f32, -0.4];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &mut out);
        let (w, b, alpha) = (p.buf("w"), p.buf("b"), p.buf("alpha"));
        for j in 0..m {
            let h1 = (w[j] * x[0] + b[j]).tanh();
            let want = (w[j] * x[1] + b[j] + alpha[j * q] * h1).tanh();
            assert!((out[j] - want).abs() < 1e-6);
        }
    }
}
