//! Fully connected recurrence (Eq 9): every neuron sees every neuron's
//! history — the most compute-heavy architecture (Table 2).

use crate::elm::activation::tanh;
use crate::elm::params::ElmParams;
use crate::linalg::Matrix;

use super::{lift_wx, wx_at, SampleBlock};

/// One sample: h_j(t) = g(w_j·x(t) + b_j + Σ_{k=1..t} Σ_l α[j,l,k] h_l(t−k)).
pub fn h_row(p: &ElmParams, x: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w = p.buf("w");
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, m, q): alpha[(j*m + l)*q + (k-1)]
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + l] = h_l(t-k)
    let mut cur = vec![0f32; m];
    for t in 0..q {
        for j in 0..m {
            let mut acc = wx_at(w, x, s, q, m, j, t) + b[j];
            for k in 1..=t.min(q) {
                let hrow = &hist[(k - 1) * m..k * m];
                let arow = &alpha[j * m * q..];
                for (l, hv) in hrow.iter().enumerate() {
                    acc += arow[l * q + (k - 1)] * hv;
                }
            }
            cur[j] = tanh(acc);
        }
        for k in (1..q).rev() {
            let (lo, hi) = hist.split_at_mut(k * m);
            hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
        }
        hist[..m].copy_from_slice(&cur);
        out.copy_from_slice(&cur);
    }
}

/// Whole row block: block-wide GEMM for the input projections, then the
/// fully-connected recurrence per sample on the precomputed values.
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    let (q, m) = (p.q, p.m);
    let wx = lift_wx(p.buf("w"), 1, blk, p.s, q, m);
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, m, q): alpha[(j*m + l)*q + (k-1)]
    let mut h = Matrix::zeros(blk.rows, m);
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + l] = h_l(t-k)
    let mut cur = vec![0f32; m];
    for i in 0..blk.rows {
        hist.iter_mut().for_each(|v| *v = 0.0);
        for t in 0..q {
            let wrow = wx.row(i * q + t);
            for j in 0..m {
                let mut acc = wrow[j] as f32 + b[j];
                for k in 1..=t.min(q) {
                    let hrow = &hist[(k - 1) * m..k * m];
                    let arow = &alpha[j * m * q..];
                    for (l, hv) in hrow.iter().enumerate() {
                        acc += arow[l * q + (k - 1)] * hv;
                    }
                }
                cur[j] = tanh(acc);
            }
            for k in (1..q).rev() {
                let (lo, hi) = hist.split_at_mut(k * m);
                hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
            }
            hist[..m].copy_from_slice(&cur);
        }
        for j in 0..m {
            h[(i, j)] = cur[j] as f64;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::arch::elman;
    use crate::elm::params::Arch;

    #[test]
    fn diagonal_alpha_reduces_to_elman() {
        let (s, q, m) = (1, 4, 3);
        let pe = ElmParams::init(Arch::Elman, s, q, m, 12);
        // FC params with alpha[j,l,k] = delta_jl * elman_alpha[j,k]
        let mut pf = ElmParams::init(Arch::Fc, s, q, m, 12);
        pf.bufs[0] = pe.buf("w").to_vec();
        pf.bufs[1] = pe.buf("b").to_vec();
        let ae = pe.buf("alpha");
        let mut af = vec![0f32; m * m * q];
        for j in 0..m {
            for k in 0..q {
                af[(j * m + j) * q + k] = ae[j * q + k];
            }
        }
        pf.bufs[2] = af;
        let x = vec![0.5f32, -0.2, 0.8, 0.1];
        let mut fe = vec![0f32; m];
        let mut ff = vec![0f32; m];
        elman::h_row(&pe, &x, &mut fe);
        h_row(&pf, &x, &mut ff);
        for j in 0..m {
            assert!((fe[j] - ff[j]).abs() < 1e-6, "{} vs {}", fe[j], ff[j]);
        }
    }

    #[test]
    fn cross_neuron_coupling_matters() {
        let (s, q, m) = (1, 3, 2);
        let p = ElmParams::init(Arch::Fc, s, q, m, 13);
        let x = vec![0.4f32, 0.2, -0.1];
        let mut a = vec![0f32; m];
        h_row(&p, &x, &mut a);
        // zero the off-diagonal coupling: result must change
        let mut p2 = p.clone();
        for j in 0..m {
            for l in 0..m {
                if l != j {
                    for k in 0..q {
                        p2.bufs[2][(j * m + l) * q + k] = 0.0;
                    }
                }
            }
        }
        let mut b = vec![0f32; m];
        h_row(&p2, &x, &mut b);
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-7));
    }
}
