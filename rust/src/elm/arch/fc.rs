//! Fully connected recurrence (Eq 9): every neuron sees every neuron's
//! history — the most compute-heavy architecture (Table 2).

#![forbid(unsafe_code)]

use std::collections::HashMap;

use crate::elm::activation::tanh;
use crate::elm::params::ElmParams;
use crate::linalg::scan::chunk_schedule;
use crate::linalg::{Matrix, MatrixF32, PackedPanels, ParallelPolicy};
use crate::robust::inject;

use super::{lift_wx, wx_at, SampleBlock};

/// One sample: h_j(t) = g(w_j·x(t) + b_j + Σ_{k=1..t} Σ_l α[j,l,k] h_l(t−k)).
pub fn h_row(p: &ElmParams, x: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w = p.buf("w");
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, m, q): alpha[(j*m + l)*q + (k-1)]
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + l] = h_l(t-k)
    let mut cur = vec![0f32; m];
    for t in 0..q {
        for j in 0..m {
            let mut acc = wx_at(w, x, s, q, m, j, t) + b[j];
            for k in 1..=t.min(q) {
                let hrow = &hist[(k - 1) * m..k * m];
                let arow = &alpha[j * m * q..];
                for (l, hv) in hrow.iter().enumerate() {
                    acc += arow[l * q + (k - 1)] * hv;
                }
            }
            cur[j] = tanh(acc);
        }
        for k in (1..q).rev() {
            let (lo, hi) = hist.split_at_mut(k * m);
            hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
        }
        hist[..m].copy_from_slice(&cur);
        out.copy_from_slice(&cur);
    }
}

/// Whole row block, widened to f64 — an exact cast of [`h_block_f32`]
/// (every H entry is an f32 tanh output, exactly representable; the f32
/// coupling GEMMs are bit-identical to the old f64 ones per the
/// `linalg::matrix32` contract).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Whole row block, fully batched and **f32-born**: the input projections
/// come from one block-wide GEMM (`lift_wx`), and the fully-connected
/// recurrence itself is lifted out of the per-sample loop — at timestep t
/// the cross-neuron coupling of *every* sample in the block for lag k is
/// one (rows × M) × (M × M) GEMM,
///
/// ```text
///   Acc_t = WX_t + b + Σ_{k=1..t} H_{t−k} · A_kᵀ ,   H_t = tanh(Acc_t)
/// ```
///
/// where `A_k[j, l] = alpha[j, l, k]` — the per-timestep GEMV of the old
/// scalar loop (strided alpha walks, one sample at a time) becomes q
/// tiled GEMMs per timestep, like the gate projections of the other five
/// architectures. Both coupling operands are f32-born (H_t is a tanh
/// output, A_k an f32 parameter buffer), so the GEMMs run on the f32 wire
/// through [`MatrixF32::matmul_widen_packed`] — **bit-identical** to the
/// widen-first f64 GEMMs they replace (exact f32×f32 products, same tile
/// schedule) at half the operand traffic, with the per-timestep history
/// slabs `hs` resident in f32. Each `A_kᵀ` operand is packed into its
/// [`PackedPanels`] GEMM layout **once** and the pack reused by every
/// timestep that couples at lag k (lag k appears in `q−k` timesteps; the
/// pack-per-call path repacked it each time — packing is pure data
/// movement, so the reuse is bit-neutral). Accumulation is f64 (the widen
/// GEMMs accumulate wide) with one f32 rounding at the tanh, so values
/// match the scalar [`h_block_reference`] / [`h_row`] to f32 round-off
/// (the property suite bounds it at 1e-5).
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    let (q, m) = (p.q, p.m);
    let rows = blk.rows;
    if q == 0 {
        return MatrixF32::zeros(rows, m);
    }
    let wx = lift_wx(p.buf("w"), 1, blk, p.s, q, m);
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, m, q): alpha[(j*m + l)*q + (k-1)]
    // A_kᵀ as f32-wire GEMM operands, each packed once and reused across
    // all timesteps coupling at lag k: akt[k-1] packs [(l, j)] = alpha[j, l, k].
    // Lag k is consumed by the q−k timesteps t ∈ k..q, so lag q (and with it
    // the whole vector when q == 1) is never read and never packed.
    let akt_packs: Vec<PackedPanels<f32>> = (1..q)
        .map(|k| {
            let mut t = MatrixF32::zeros(m, m);
            for j in 0..m {
                for l in 0..m {
                    t[(l, j)] = alpha[(j * m + l) * q + (k - 1)];
                }
            }
            t.pack_panels()
        })
        .collect();
    let seq = ParallelPolicy::sequential();
    // hs[t] = H at timestep t for the whole block (rows × m), f32 resident
    let mut hs: Vec<MatrixF32> = Vec::with_capacity(q);
    let mut acc = Matrix::zeros(rows, m);
    for t in 0..q {
        for i in 0..rows {
            let wrow = wx.row(i * q + t);
            let arow = acc.row_mut(i);
            for j in 0..m {
                arow[j] = wrow[j] + b[j] as f64;
            }
        }
        for k in 1..=t {
            let coupling = hs[t - k].matmul_widen_packed(&akt_packs[k - 1], seq);
            for (av, cv) in acc.data_mut().iter_mut().zip(coupling.data()) {
                *av += cv;
            }
        }
        let mut ht = MatrixF32::zeros(rows, m);
        for (hv, av) in ht.data_mut().iter_mut().zip(acc.data()) {
            *hv = tanh(*av as f32);
        }
        hs.push(ht);
    }
    hs.pop().expect("q >= 1")
}

/// Sequence-parallel FC recurrence: [`h_block_f32`] with the time axis cut
/// into the fixed [`chunk_schedule`] and the **cross-chunk** coupling GEMMs
/// farmed out in parallel — **bit-identical to the sequential kernel at any
/// chunk size and worker count**.
///
/// The trick that makes the parallelism exact: at timestep `t` the
/// coupling term for lag `k` is `H_{t−k} · A_kᵀ`, a *pure* function of an
/// earlier timestep's states. For a chunk `[clo, chi)` every lag reaching
/// **before** the chunk (`t − k < clo`) reads states that are already
/// final when the chunk starts, so those GEMMs — the bulk of the FLOPs at
/// `chunk ≪ q` — are precomputed concurrently over the fixed task list
/// `{(t, k) : t ∈ [clo, chi), k > t − clo}` via the order-preserving
/// parallel map. The serial phase then walks `t` in order, computing the
/// few *intra*-chunk GEMMs as states materialize and folding every
/// coupling term in the oracle's exact ascending-`k` order. A GEMM's bits
/// never depend on where it executes (it runs the identical sequential
/// kernel on identical operands), and the fold order is the oracle's, so
/// the result is the oracle's bits — `tests/scan_props.rs` pins this at
/// chunk sizes {1, 7, 64, q} × 1/2/4/8 workers. With `chunk >= q` the
/// schedule has one chunk, the external task list is empty, and the walk
/// *is* [`h_block_f32`] (scan-of-one-chunk ≡ sequential by construction).
///
/// Under `--features fault-inject` this is a [`inject::Site::ScanChunk`]
/// site: the panic hook fires at chunk starts, keyed by chunk index.
pub fn h_block_f32_chunked(
    p: &ElmParams,
    blk: &SampleBlock,
    chunk: usize,
    policy: ParallelPolicy,
) -> MatrixF32 {
    let (q, m) = (p.q, p.m);
    let rows = blk.rows;
    if q == 0 {
        return MatrixF32::zeros(rows, m);
    }
    let wx = lift_wx(p.buf("w"), 1, blk, p.s, q, m);
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, m, q): alpha[(j*m + l)*q + (k-1)]
    let akt_packs: Vec<PackedPanels<f32>> = (1..q)
        .map(|k| {
            let mut t = MatrixF32::zeros(m, m);
            for j in 0..m {
                for l in 0..m {
                    t[(l, j)] = alpha[(j * m + l) * q + (k - 1)];
                }
            }
            t.pack_panels()
        })
        .collect();
    let seq = ParallelPolicy::sequential();
    let sched = chunk_schedule(q, chunk);
    let mut hs: Vec<MatrixF32> = Vec::with_capacity(q);
    let mut acc = Matrix::zeros(rows, m);
    for (ci, &(clo, chi)) in sched.iter().enumerate() {
        inject::maybe_panic(inject::Site::ScanChunk, ci);
        // phase 1 (parallel): cross-chunk coupling GEMMs — pure functions
        // of earlier chunks' final states. The task list is fixed by
        // (q, chunk) alone and par_map preserves order, so which worker
        // computes a GEMM never matters (and the GEMM itself runs the
        // sequential kernel: identical operands → identical bits).
        let tasks: Vec<(usize, usize)> = (clo..chi)
            .flat_map(|t| (t - clo + 1..=t).map(move |k| (t, k)))
            .collect();
        let hs_ref = &hs;
        let packs = &akt_packs;
        let ext: HashMap<(usize, usize), Matrix> =
            crate::linalg::policy::par_map(tasks, policy, move |(t, k)| {
                Ok(((t, k), hs_ref[t - k].matmul_widen_packed(&packs[k - 1], seq)))
            })
            .expect("pure coupling GEMMs cannot fail")
            .into_iter()
            .collect();
        // phase 2 (serial): the oracle's walk, fold order untouched —
        // external couplings are looked up, intra-chunk ones computed as
        // their source timesteps materialize.
        for t in clo..chi {
            for i in 0..rows {
                let wrow = wx.row(i * q + t);
                let arow = acc.row_mut(i);
                for j in 0..m {
                    arow[j] = wrow[j] + b[j] as f64;
                }
            }
            for k in 1..=t {
                let local;
                let coupling = if t - k >= clo {
                    local = hs[t - k].matmul_widen_packed(&akt_packs[k - 1], seq);
                    &local
                } else {
                    &ext[&(t, k)]
                };
                for (av, cv) in acc.data_mut().iter_mut().zip(coupling.data()) {
                    *av += cv;
                }
            }
            let mut ht = MatrixF32::zeros(rows, m);
            for (hv, av) in ht.data_mut().iter_mut().zip(acc.data()) {
                *hv = tanh(*av as f32);
            }
            hs.push(ht);
        }
    }
    hs.pop().expect("q >= 1")
}

/// The pre-batching scalar block loop (per sample, per timestep, per
/// neuron, strided alpha walks) — kept as the oracle `h_block` is
/// property-tested against and the baseline `benches/linalg.rs` measures
/// the GEMM lift against.
pub fn h_block_reference(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    let (q, m) = (p.q, p.m);
    let wx = lift_wx(p.buf("w"), 1, blk, p.s, q, m);
    let b = p.buf("b");
    let alpha = p.buf("alpha"); // (m, m, q): alpha[(j*m + l)*q + (k-1)]
    let mut h = Matrix::zeros(blk.rows, m);
    let mut hist = vec![0f32; q * m]; // hist[(k-1)*m + l] = h_l(t-k)
    let mut cur = vec![0f32; m];
    for i in 0..blk.rows {
        hist.iter_mut().for_each(|v| *v = 0.0);
        for t in 0..q {
            let wrow = wx.row(i * q + t);
            for j in 0..m {
                let mut acc = wrow[j] as f32 + b[j];
                for k in 1..=t.min(q) {
                    let hrow = &hist[(k - 1) * m..k * m];
                    let arow = &alpha[j * m * q..];
                    for (l, hv) in hrow.iter().enumerate() {
                        acc += arow[l * q + (k - 1)] * hv;
                    }
                }
                cur[j] = tanh(acc);
            }
            for k in (1..q).rev() {
                let (lo, hi) = hist.split_at_mut(k * m);
                hi[..m].copy_from_slice(&lo[(k - 1) * m..k * m]);
            }
            hist[..m].copy_from_slice(&cur);
        }
        for j in 0..m {
            h[(i, j)] = cur[j] as f64;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::arch::elman;
    use crate::elm::params::Arch;

    #[test]
    fn diagonal_alpha_reduces_to_elman() {
        let (s, q, m) = (1, 4, 3);
        let pe = ElmParams::init(Arch::Elman, s, q, m, 12);
        // FC params with alpha[j,l,k] = delta_jl * elman_alpha[j,k]
        let mut pf = ElmParams::init(Arch::Fc, s, q, m, 12);
        pf.bufs[0] = pe.buf("w").to_vec();
        pf.bufs[1] = pe.buf("b").to_vec();
        let ae = pe.buf("alpha");
        let mut af = vec![0f32; m * m * q];
        for j in 0..m {
            for k in 0..q {
                af[(j * m + j) * q + k] = ae[j * q + k];
            }
        }
        pf.bufs[2] = af;
        let x = vec![0.5f32, -0.2, 0.8, 0.1];
        let mut fe = vec![0f32; m];
        let mut ff = vec![0f32; m];
        elman::h_row(&pe, &x, &mut fe);
        h_row(&pf, &x, &mut ff);
        for j in 0..m {
            assert!((fe[j] - ff[j]).abs() < 1e-6, "{} vs {}", fe[j], ff[j]);
        }
    }

    #[test]
    fn batched_block_matches_scalar_reference() {
        // the GEMM-lifted recurrence vs the per-sample scalar loop: only
        // the accumulation width differs (f64 GEMM vs f32 running sum), so
        // values must agree to f32 round-off
        let (s, q, m) = (2, 6, 9);
        let rows = 13; // not a multiple of anything interesting on purpose
        let p = ElmParams::init(Arch::Fc, s, q, m, 31);
        let mut rng = crate::util::rng::Rng::new(7);
        let x: Vec<f32> = rng.normals_f32(rows * s * q);
        let yh = vec![0f32; rows * q];
        let eh = vec![0f32; rows * q];
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        let batched = h_block(&p, &blk);
        let reference = h_block_reference(&p, &blk);
        let diff = batched.max_abs_diff(&reference);
        assert!(diff < 1e-5, "|batched - reference| = {diff}");
        // and both must match the one-sample recurrence
        let mut out = vec![0f32; m];
        for i in 0..rows {
            h_row(&p, &x[i * s * q..(i + 1) * s * q], &mut out);
            for j in 0..m {
                assert!(
                    (batched[(i, j)] - out[j] as f64).abs() < 1e-5,
                    "row {i} col {j}: {} vs {}",
                    batched[(i, j)],
                    out[j]
                );
            }
        }
    }

    #[test]
    fn chunked_executor_is_bitwise_the_sequential_kernel() {
        // the fold order is the oracle's and GEMM bits don't depend on
        // where they run, so every chunk size × worker count must produce
        // the sequential kernel's exact bits (q = 13 leaves a ragged tail
        // at chunks 4 and 7)
        let (s, q, m) = (2, 13, 6);
        let rows = 7;
        let p = ElmParams::init(Arch::Fc, s, q, m, 19);
        let mut rng = crate::util::rng::Rng::new(9);
        let x: Vec<f32> = rng.normals_f32(rows * s * q);
        let yh = vec![0f32; rows * q];
        let eh = vec![0f32; rows * q];
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        let want = h_block_f32(&p, &blk);
        for chunk in [1usize, 4, 7, q, 64] {
            for workers in [1usize, 4] {
                let got = h_block_f32_chunked(
                    &p,
                    &blk,
                    chunk,
                    ParallelPolicy::with_workers(workers),
                );
                assert_eq!(got, want, "chunk={chunk} workers={workers}");
            }
        }
    }

    #[test]
    fn cross_neuron_coupling_matters() {
        let (s, q, m) = (1, 3, 2);
        let p = ElmParams::init(Arch::Fc, s, q, m, 13);
        let x = vec![0.4f32, 0.2, -0.1];
        let mut a = vec![0f32; m];
        h_row(&p, &x, &mut a);
        // zero the off-diagonal coupling: result must change
        let mut p2 = p.clone();
        for j in 0..m {
            for l in 0..m {
                if l != j {
                    for k in 0..q {
                        p2.bufs[2][(j * m + l) * q + k] = 0.0;
                    }
                }
            }
        }
        let mut b = vec![0f32; m];
        h_row(&p2, &x, &mut b);
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-7));
    }
}
