//! GRU recurrence (Eq 11), diagonal. Gate order: [z, r, f] — matching
//! `python/compile/kernels/gru.py`.

#![forbid(unsafe_code)]

use crate::elm::activation::{sigmoid, tanh};
use crate::elm::params::ElmParams;
use crate::linalg::{Matrix, MatrixF32};

use super::{lift_wx, SampleBlock};

/// One sample: runs the 3-gate diagonal cell over the window.
pub fn h_row(p: &ElmParams, x: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w3 = p.buf("w3"); // (s, 3, m)
    let u3 = p.buf("u3"); // (3, m)
    let b3 = p.buf("b3"); // (3, m)
    let mut f_prev = vec![0f32; m];
    for t in 0..q {
        for j in 0..m {
            let wx = |g: usize| -> f32 {
                let mut acc = 0f32;
                for si in 0..s {
                    acc += w3[(si * 3 + g) * m + j] * x[si * q + t];
                }
                acc
            };
            let z = sigmoid(wx(0) + u3[j] * f_prev[j] + b3[j]);
            let r = sigmoid(wx(1) + u3[m + j] * f_prev[j] + b3[m + j]);
            let cand = tanh(wx(2) + u3[2 * m + j] * (r * f_prev[j]) + b3[2 * m + j]);
            out[j] = (1.0 - z) * f_prev[j] + z * cand;
        }
        f_prev.copy_from_slice(out);
    }
}

/// Whole row block, widened to f64 — an exact cast of [`h_block_f32`]
/// (every H entry is an all-f32 gate update, exactly representable).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Whole row block, **f32-born**: one (rows·q) × 3m GEMM lifts every
/// gate's input projection (`w3` is row-major (s, 3m)); the diagonal cell
/// then advances **four samples in lockstep** (lane-contiguous state,
/// index `[j·4 + lane]`): one u3/b3 load drives four independent cells.
/// Lanes never mix, so each sample is bit-identical to the scalar tail.
/// The gate math is all-f32 and the outputs land straight in `MatrixF32`
/// — no f64 materialization.
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    h_block_f32_from(p, blk, 0)
}

/// [`h_block_f32`] started at timestep `t_start` from a zero state — the
/// warm-up-truncated kernel behind `RecurrenceMode::Chunked`. With
/// `t_start == 0` this *is* the sequential kernel (the same loop over the
/// same range — bit-identical by construction). With `t_start > 0` the
/// cell starts from `f = 0` instead of the true carried state; the lag-1
/// leaky update `f ← (1−z)·f + z·cand` with `z ∈ (0, 1)` contracts the
/// initial-state discrepancy geometrically over the warm-up prefix — the
/// envelope the chunked suite documents.
pub(crate) fn h_block_f32_from(
    p: &ElmParams,
    blk: &SampleBlock,
    t_start: usize,
) -> MatrixF32 {
    let (q, m) = (p.q, p.m);
    let wx3 = lift_wx(p.buf("w3"), 3, blk, p.s, q, m);
    let u3 = p.buf("u3"); // (3, m)
    let b3 = p.buf("b3"); // (3, m)
    let mut h = MatrixF32::zeros(blk.rows, m);

    let mut f_prev4 = vec![0f32; m * 4];
    let mut cur4 = vec![0f32; m * 4];
    let full = blk.rows - blk.rows % 4;
    for i0 in (0..full).step_by(4) {
        f_prev4.iter_mut().for_each(|v| *v = 0.0);
        for t in t_start..q {
            let w0 = wx3.row(i0 * q + t);
            let w1 = wx3.row((i0 + 1) * q + t);
            let w2 = wx3.row((i0 + 2) * q + t);
            let w3r = wx3.row((i0 + 3) * q + t);
            let wl = [w0, w1, w2, w3r];
            for j in 0..m {
                let jb = j * 4;
                let (uz, ur, uf) = (u3[j], u3[m + j], u3[2 * m + j]);
                let (bz, br, bf) = (b3[j], b3[m + j], b3[2 * m + j]);
                for l in 0..4 {
                    let fp = f_prev4[jb + l];
                    let wx = |g: usize| wl[l][g * m + j] as f32;
                    let z = sigmoid(wx(0) + uz * fp + bz);
                    let r = sigmoid(wx(1) + ur * fp + br);
                    let cand = tanh(wx(2) + uf * (r * fp) + bf);
                    cur4[jb + l] = (1.0 - z) * fp + z * cand;
                }
            }
            f_prev4.copy_from_slice(&cur4);
        }
        for l in 0..4 {
            for j in 0..m {
                h[(i0 + l, j)] = cur4[j * 4 + l];
            }
        }
    }

    // scalar tail (rows % 4): the original per-sample cell
    let mut f_prev = vec![0f32; m];
    let mut cur = vec![0f32; m];
    for i in full..blk.rows {
        f_prev.iter_mut().for_each(|v| *v = 0.0);
        for t in t_start..q {
            let wrow = wx3.row(i * q + t);
            for j in 0..m {
                let wx = |g: usize| wrow[g * m + j] as f32;
                let z = sigmoid(wx(0) + u3[j] * f_prev[j] + b3[j]);
                let r = sigmoid(wx(1) + u3[m + j] * f_prev[j] + b3[m + j]);
                let cand =
                    tanh(wx(2) + u3[2 * m + j] * (r * f_prev[j]) + b3[2 * m + j]);
                cur[j] = (1.0 - z) * f_prev[j] + z * cand;
            }
            f_prev.copy_from_slice(&cur);
        }
        for j in 0..m {
            h[(i, j)] = cur[j];
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::params::Arch;

    #[test]
    fn closed_update_gate_freezes_zero_state() {
        let (s, q, m) = (1, 4, 3);
        let mut p = ElmParams::init(Arch::Gru, s, q, m, 30);
        for j in 0..m {
            p.bufs[2][j] = -30.0; // b3 z-gate → z = 0
            p.bufs[1][j] = 0.0; // u3 z-gate
        }
        let x = vec![0.5f32, -0.3, 0.2, 0.9];
        let mut out = vec![1f32; m];
        h_row(&p, &x, &mut out);
        for j in 0..m {
            assert!(out[j].abs() < 1e-5, "state must stay at f(0) = 0");
        }
    }

    #[test]
    fn open_update_gate_is_memoryless() {
        let (s, q, m) = (1, 4, 2);
        let mut p = ElmParams::init(Arch::Gru, s, q, m, 31);
        for j in 0..m {
            p.bufs[2][j] = 30.0; // z = 1
            p.bufs[1][j] = 0.0;
            p.bufs[1][2 * m + j] = 0.0; // candidate ignores state
        }
        let x = vec![0.1f32, 0.7, -0.2, 0.4];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &mut out);
        let (w3, b3) = (p.buf("w3"), p.buf("b3"));
        for j in 0..m {
            let want = (w3[2 * m + j] * x[q - 1] + b3[2 * m + j]).tanh();
            assert!((out[j] - want).abs() < 1e-4);
        }
    }
}
