//! Jordan recurrence (Eq 7): output feedback, teacher-forced during
//! training — H(Q) is a direct function of the inputs (DESIGN.md §2).

#![forbid(unsafe_code)]

use crate::elm::activation::tanh;
use crate::elm::params::ElmParams;
use crate::linalg::{Matrix, MatrixF32};

use super::{history_matrix, transposed_param, wx_at, SampleBlock};

/// One sample: h_j = g(w_j·x(Q) + b_j + Σ_k α[j,k] y(t−k)).
pub fn h_row(p: &ElmParams, x: &[f32], yhist: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w = p.buf("w");
    let b = p.buf("b");
    let alpha = p.buf("alpha");
    assert_eq!(yhist.len(), q, "jordan h_row: yhist must hold Q lagged outputs");
    for j in 0..m {
        let mut acc = wx_at(w, x, s, q, m, j, q - 1) + b[j];
        for k in 0..q {
            acc += alpha[j * q + k] * yhist[k];
        }
        out[j] = tanh(acc);
    }
}

/// Whole row block, widened to f64 — an exact cast of [`h_block_f32`]
/// (every H entry is an f32 tanh output, exactly representable).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Whole row block, **f32-born**. Jordan has no hidden-state recurrence
/// (the feedback is the teacher-forced target history), so the entire
/// block is two GEMMs — X_last·W + Yhist·αᵀ — plus bias and elementwise
/// tanh, written straight into `MatrixF32`.
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    let (s, q, m) = (p.s, p.q, p.m);
    let rows = blk.rows;
    // x at t = Q−1 only (Eq 7 reads the window head)
    let mut xl = Matrix::zeros(rows, s);
    for i in 0..rows {
        let xi = blk.x_row(i, s, q);
        for si in 0..s {
            xl[(i, si)] = xi[si * q + (q - 1)] as f64;
        }
    }
    let pre = xl.matmul(&Matrix::from_f32(s, m, p.buf("w")));
    let fb = history_matrix(blk.yhist, rows, q)
        .matmul(&transposed_param(p.buf("alpha"), m, q));
    let b = p.buf("b");
    let mut h = MatrixF32::zeros(rows, m);
    for i in 0..rows {
        for j in 0..m {
            let acc = (pre[(i, j)] + fb[(i, j)]) as f32 + b[j];
            h[(i, j)] = tanh(acc);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::params::Arch;

    #[test]
    fn zero_history_is_feedforward() {
        let (s, q, m) = (1, 5, 4);
        let p = ElmParams::init(Arch::Jordan, s, q, m, 2);
        let x: Vec<f32> = (0..q).map(|i| i as f32 * 0.1).collect();
        let mut out = vec![0f32; m];
        h_row(&p, &x, &vec![0.0; q], &mut out);
        let (w, b) = (p.buf("w"), p.buf("b"));
        for j in 0..m {
            let want = (w[j] * x[q - 1] + b[j]).tanh();
            assert!((out[j] - want).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "jordan h_row: yhist must hold Q lagged outputs")]
    fn short_yhist_rejected_in_release() {
        let (s, q, m) = (1, 5, 4);
        let p = ElmParams::init(Arch::Jordan, s, q, m, 2);
        let x = vec![0.1f32; q];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &vec![0.0; q - 1], &mut out);
    }

    #[test]
    fn feedback_shifts_preactivation_linearly() {
        let (s, q, m) = (1, 3, 2);
        let p = ElmParams::init(Arch::Jordan, s, q, m, 4);
        let x = vec![0.1f32, 0.2, 0.3];
        let yh = vec![0.5f32, -0.2, 0.1];
        let mut a = vec![0f32; m];
        let mut bq = vec![0f32; m];
        h_row(&p, &x, &vec![0.0; q], &mut a);
        h_row(&p, &x, &yh, &mut bq);
        let alpha = p.buf("alpha");
        for j in 0..m {
            let delta: f32 = (0..q).map(|k| alpha[j * q + k] * yh[k]).sum();
            let want = (a[j].atanh() + delta).tanh();
            assert!((bq[j] - want).abs() < 1e-5);
        }
    }
}
