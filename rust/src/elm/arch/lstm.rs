//! LSTM recurrence (Eq 10), diagonal: each neuron's gates see only its own
//! f(t−1). Gate order on the stacked axis: [o, c~, λ (forget), in] —
//! matching `python/compile/kernels/lstm.py`.

#![forbid(unsafe_code)]

use crate::elm::activation::{sigmoid, tanh};
use crate::elm::params::ElmParams;
use crate::linalg::{Matrix, MatrixF32};

use super::{lift_wx, SampleBlock};

/// One sample: runs the 4-gate diagonal cell over the window.
pub fn h_row(p: &ElmParams, x: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w4 = p.buf("w4"); // (s, 4, m): w4[(si*4 + g)*m + j]
    let u4 = p.buf("u4"); // (4, m)
    let b4 = p.buf("b4"); // (4, m)
    let mut f_prev = vec![0f32; m];
    let mut c_prev = vec![0f32; m];
    for t in 0..q {
        for j in 0..m {
            let mut pre = [0f32; 4];
            for g in 0..4 {
                let mut acc = u4[g * m + j] * f_prev[j] + b4[g * m + j];
                for si in 0..s {
                    acc += w4[(si * 4 + g) * m + j] * x[si * q + t];
                }
                pre[g] = acc;
            }
            let o = sigmoid(pre[0]);
            let c_tilde = tanh(pre[1]);
            let lam = sigmoid(pre[2]);
            let inp = sigmoid(pre[3]);
            let c = lam * c_prev[j] + inp * c_tilde;
            c_prev[j] = c;
            out[j] = o * tanh(c);
        }
        f_prev.copy_from_slice(out);
    }
}

/// Whole row block, widened to f64 — an exact cast of [`h_block_f32`]
/// (every H entry is an f32 `o·tanh(c)` product, exactly representable).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Whole row block, **f32-born**: all four gate input projections for
/// every sample and timestep come from one (rows·q) × 4m GEMM — `w4`'s
/// (s, 4, m) layout is row-major (s, 4m), so it feeds the lift unchanged —
/// then the diagonal cell advances **four samples in lockstep**
/// (lane-contiguous f/c state, index `[j·4 + lane]`): one u4/b4 load
/// drives four independent cells. Lanes never mix, so each sample is
/// bit-identical to the scalar tail. The cell math is all-f32 and the
/// outputs land straight in `MatrixF32` — no f64 materialization.
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    h_block_f32_from(p, blk, 0)
}

/// [`h_block_f32`] started at timestep `t_start` from a zero (f, c) state —
/// the warm-up-truncated kernel behind `RecurrenceMode::Chunked`. With
/// `t_start == 0` this *is* the sequential kernel (the same loop over the
/// same range — bit-identical by construction). With `t_start > 0` the
/// cell starts from `f = c = 0` instead of the true carried state; because
/// the recurrence is lag-1 with a sigmoid forget gate `λ ∈ (0, 1)`
/// contracting the cell state every step, the discrepancy decays
/// geometrically over the warm-up prefix — the envelope the chunked suite
/// documents.
pub(crate) fn h_block_f32_from(
    p: &ElmParams,
    blk: &SampleBlock,
    t_start: usize,
) -> MatrixF32 {
    let (q, m) = (p.q, p.m);
    let wx4 = lift_wx(p.buf("w4"), 4, blk, p.s, q, m);
    let u4 = p.buf("u4"); // (4, m)
    let b4 = p.buf("b4"); // (4, m)
    let mut h = MatrixF32::zeros(blk.rows, m);

    let mut f_prev4 = vec![0f32; m * 4];
    let mut c_prev4 = vec![0f32; m * 4];
    let mut cur4 = vec![0f32; m * 4];
    let full = blk.rows - blk.rows % 4;
    for i0 in (0..full).step_by(4) {
        f_prev4.iter_mut().for_each(|v| *v = 0.0);
        c_prev4.iter_mut().for_each(|v| *v = 0.0);
        for t in t_start..q {
            let w0 = wx4.row(i0 * q + t);
            let w1 = wx4.row((i0 + 1) * q + t);
            let w2 = wx4.row((i0 + 2) * q + t);
            let w3 = wx4.row((i0 + 3) * q + t);
            let wl = [w0, w1, w2, w3];
            for j in 0..m {
                let jb = j * 4;
                for l in 0..4 {
                    let fp = f_prev4[jb + l];
                    let pre =
                        |g: usize| u4[g * m + j] * fp + b4[g * m + j] + wl[l][g * m + j] as f32;
                    let o = sigmoid(pre(0));
                    let c_tilde = tanh(pre(1));
                    let lam = sigmoid(pre(2));
                    let inp = sigmoid(pre(3));
                    let c = lam * c_prev4[jb + l] + inp * c_tilde;
                    c_prev4[jb + l] = c;
                    cur4[jb + l] = o * tanh(c);
                }
            }
            f_prev4.copy_from_slice(&cur4);
        }
        for l in 0..4 {
            for j in 0..m {
                h[(i0 + l, j)] = cur4[j * 4 + l];
            }
        }
    }

    // scalar tail (rows % 4): the original per-sample cell
    let mut f_prev = vec![0f32; m];
    let mut c_prev = vec![0f32; m];
    let mut cur = vec![0f32; m];
    for i in full..blk.rows {
        f_prev.iter_mut().for_each(|v| *v = 0.0);
        c_prev.iter_mut().for_each(|v| *v = 0.0);
        for t in t_start..q {
            let wrow = wx4.row(i * q + t);
            for j in 0..m {
                let pre = |g: usize| {
                    u4[g * m + j] * f_prev[j] + b4[g * m + j] + wrow[g * m + j] as f32
                };
                let o = sigmoid(pre(0));
                let c_tilde = tanh(pre(1));
                let lam = sigmoid(pre(2));
                let inp = sigmoid(pre(3));
                let c = lam * c_prev[j] + inp * c_tilde;
                c_prev[j] = c;
                cur[j] = o * tanh(c);
            }
            f_prev.copy_from_slice(&cur);
        }
        for j in 0..m {
            h[(i, j)] = cur[j];
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::params::Arch;

    #[test]
    fn closed_forget_gate_forgets() {
        let (s, q, m) = (1, 5, 2);
        let mut p = ElmParams::init(Arch::Lstm, s, q, m, 20);
        // forget gate (g=2) hard closed; kill all recurrent terms
        for j in 0..m {
            p.bufs[2][2 * m + j] = -30.0; // b4 lambda
            for g in 0..4 {
                p.bufs[1][g * m + j] = 0.0; // u4
            }
        }
        let mut x1 = vec![0.2f32; q];
        let mut out1 = vec![0f32; m];
        h_row(&p, &x1, &mut out1);
        // scramble everything but the last step: output must not change
        for v in x1.iter_mut().take(q - 1) {
            *v = 5.0;
        }
        let mut out2 = vec![0f32; m];
        h_row(&p, &x1, &mut out2);
        for j in 0..m {
            assert!((out1[j] - out2[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn single_step_matches_closed_form() {
        let (s, q, m) = (1, 1, 3);
        let p = ElmParams::init(Arch::Lstm, s, q, m, 21);
        let x = vec![0.6f32];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &mut out);
        let (w4, b4) = (p.buf("w4"), p.buf("b4"));
        for j in 0..m {
            let pre = |g: usize| w4[g * m + j] * x[0] + b4[g * m + j];
            let c = sigmoid(pre(2)) * 0.0 + sigmoid(pre(3)) * pre(1).tanh();
            let want = sigmoid(pre(0)) * c.tanh();
            assert!((out[j] - want).abs() < 1e-6);
        }
    }
}
