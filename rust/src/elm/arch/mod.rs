//! The six H recurrences (Eq 6-11) as plain sequential scalar code — the
//! S-R-ELM baseline. `h_row` computes one sample's H(Q) row; the trainer
//! loops it over the dataset exactly like Algorithm 1.
//!
//! Input contract per sample (matching `data::Windowed`):
//! * `x`     — the lag window, row-major (S, Q): x[s*Q + t]
//! * `yhist` — target history, yhist[k-1] = y(t-k)   (jordan/narmax)
//! * `ehist` — residual history, same alignment      (narmax)

pub mod elman;
pub mod fc;
pub mod gru;
pub mod jordan;
pub mod lstm;
pub mod narmax;

use super::params::{Arch, ElmParams};

/// Dispatch: one sample's H row (length M).
pub fn h_row(p: &ElmParams, x: &[f32], yhist: &[f32], ehist: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), p.s * p.q);
    debug_assert_eq!(out.len(), p.m);
    match p.arch {
        Arch::Elman => elman::h_row(p, x, out),
        Arch::Jordan => jordan::h_row(p, x, yhist, out),
        Arch::Narmax => narmax::h_row(p, x, yhist, ehist, out),
        Arch::Fc => fc::h_row(p, x, out),
        Arch::Lstm => lstm::h_row(p, x, out),
        Arch::Gru => gru::h_row(p, x, out),
    }
}

/// Input projection helper: w[:, j] · x[:, t] for row-major w (S, M) and
/// x (S, Q) — the dot product of Alg 2 line 6.
#[inline]
pub(crate) fn wx_at(w: &[f32], x: &[f32], s: usize, q: usize, m: usize, j: usize, t: usize) -> f32 {
    let mut acc = 0f32;
    for si in 0..s {
        acc += w[si * m + j] * x[si * q + t];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::ALL_ARCHS;
    use crate::util::rng::Rng;

    #[test]
    fn all_archs_produce_finite_bounded_rows() {
        let (s, q, m) = (2, 6, 5);
        let mut rng = Rng::new(3);
        for arch in ALL_ARCHS {
            let p = ElmParams::init(arch, s, q, m, 11);
            let x: Vec<f32> = rng.normals_f32(s * q);
            let yh: Vec<f32> = rng.normals_f32(q).iter().map(|v| v * 0.1).collect();
            let eh: Vec<f32> = rng.normals_f32(q).iter().map(|v| v * 0.1).collect();
            let mut out = vec![0f32; m];
            h_row(&p, &x, &yh, &eh, &mut out);
            for v in &out {
                assert!(v.is_finite() && v.abs() <= 1.0 + 1e-5, "{arch:?}: {v}");
            }
        }
    }

    #[test]
    fn wx_at_matches_naive() {
        let (s, q, m) = (3, 4, 2);
        let w: Vec<f32> = (0..s * m).map(|i| i as f32 * 0.5).collect();
        let x: Vec<f32> = (0..s * q).map(|i| (i as f32).sin()).collect();
        for j in 0..m {
            for t in 0..q {
                let naive: f32 = (0..s).map(|si| w[si * m + j] * x[si * q + t]).sum();
                assert_eq!(wx_at(&w, &x, s, q, m, j, t), naive);
            }
        }
    }
}
