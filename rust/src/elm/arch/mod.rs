//! The six H recurrences (Eq 6-11). Entry points per architecture:
//!
//! * `h_row` — one sample, plain sequential scalar code: the S-R-ELM
//!   baseline, exactly Algorithm 1.
//! * `h_block_f32` — a whole row block at once, **f32-born**: the input
//!   projections (the `wx_at` dots of Alg 2 line 6) are *lifted out of
//!   the recurrence* into one tiled GEMM over the entire block
//!   (`lift_wx`); only the recurrent part still walks the window sample
//!   by sample. Jordan and NARMAX have no hidden-state recurrence, so
//!   their whole H block is pure GEMM + elementwise tanh. This is the
//!   Appleyard-style batched-GEMM fusion the paper's speedups rest on, on
//!   the CPU side. Every activation is an f32 nonlinearity output, so the
//!   block is written straight into `MatrixF32` — the paper's f32 H-block
//!   ABI, at half the f64 footprint.
//! * `h_block` — the same block widened to f64 (an exact cast: nothing is
//!   computed differently and nothing is lost). The single implementation
//!   per architecture is the f32 kernel; [`HBlock`] dispatches which wire
//!   a caller gets.
//!
//! Input contract per sample (matching `data::Windowed`):
//! * `x`     — the lag window, row-major (S, Q): x[s*Q + t]
//! * `yhist` — target history, yhist[k-1] = y(t-k)   (jordan/narmax)
//! * `ehist` — residual history, same alignment      (narmax)

#![forbid(unsafe_code)]

pub mod elman;
pub mod fc;
pub mod gru;
pub mod jordan;
pub mod lstm;
pub mod narmax;

use crate::linalg::scan::{chunk_schedule, RecurrenceMode};
use crate::linalg::{Matrix, MatrixF32, ParallelPolicy, Precision};
use crate::robust::inject;

use super::params::{Arch, ElmParams};

/// A row block of samples in the `data::Windowed` layouts.
pub struct SampleBlock<'a> {
    pub rows: usize,
    /// (rows, s, q) row-major
    pub x: &'a [f32],
    /// (rows, q)
    pub yhist: &'a [f32],
    /// (rows, q) — all zeros when the architecture ignores it
    pub ehist: &'a [f32],
}

impl SampleBlock<'_> {
    pub fn x_row(&self, i: usize, s: usize, q: usize) -> &[f32] {
        &self.x[i * s * q..(i + 1) * s * q]
    }
}

/// A precision-dispatched H block: the f64 wire carries [`Matrix`], the
/// f32 wire carries the **f32-born** [`MatrixF32`] straight from the arch
/// kernels (no f64 materialization, no rounding pass). The two variants
/// hold the *same values* — every H entry is an f32 nonlinearity output,
/// so `F64` is an exact widening of `F32` — which is what lets every
/// consumer (Gram fold, TSQR leaves, DirectQr assembly, predictions)
/// dispatch on the variant without changing results.
pub enum HBlock {
    /// f64-materialized H (the [`Precision::F64`] wire).
    F64(Matrix),
    /// f32-born H (the [`Precision::MixedF32`] wire).
    F32(MatrixF32),
}

impl HBlock {
    /// Row count of the block, whatever the wire.
    pub fn rows(&self) -> usize {
        match self {
            HBlock::F64(h) => h.rows,
            HBlock::F32(h) => h.rows,
        }
    }

    /// Column count (M) of the block, whatever the wire.
    pub fn cols(&self) -> usize {
        match self {
            HBlock::F64(h) => h.cols,
            HBlock::F32(h) => h.cols,
        }
    }

    /// Widen to f64 by value — the identity on the `F64` variant and an
    /// exact cast on the f32-born one (H entries are f32 nonlinearity
    /// outputs).
    pub fn into_f64(self) -> Matrix {
        match self {
            HBlock::F64(h) => h,
            HBlock::F32(h) => h.to_f64(),
        }
    }

    /// H · v on the block's own wire: f64 `matvec` or the widen mirror
    /// `matvec_widen` — bit-identical to each other on f32-born H (see
    /// the `linalg::matrix32` contract), so predictions never depend on
    /// which wire produced the block.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        match self {
            HBlock::F64(h) => h.matvec(v),
            HBlock::F32(h) => h.matvec_widen(v),
        }
    }
}

/// Check the block's buffer lengths against the params' (s, q) at the
/// public kernel boundary. These are real asserts (not `debug_assert!`):
/// a mis-sized `SampleBlock` would silently read wrong strides in release
/// builds otherwise. The inner loops keep `debug_assert!`.
fn assert_block_shape(p: &ElmParams, blk: &SampleBlock) {
    assert_eq!(
        blk.x.len(),
        blk.rows * p.s * p.q,
        "SampleBlock.x has {} values, expected rows*s*q = {}*{}*{}",
        blk.x.len(),
        blk.rows,
        p.s,
        p.q
    );
    assert_eq!(
        blk.yhist.len(),
        blk.rows * p.q,
        "SampleBlock.yhist has {} values, expected rows*q = {}*{}",
        blk.yhist.len(),
        blk.rows,
        p.q
    );
    assert_eq!(
        blk.ehist.len(),
        blk.rows * p.q,
        "SampleBlock.ehist has {} values, expected rows*q = {}*{}",
        blk.ehist.len(),
        blk.rows,
        p.q
    );
}

/// Dispatch: H for a whole row block, (rows × M) widened to f64 — an
/// exact cast of [`h_block_f32`] (see [`HBlock`]).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Dispatch: H for a whole row block, (rows × M) **f32-born** — the
/// activations are f32 nonlinearity outputs and are stored straight into
/// [`MatrixF32`], so the `MixedF32` wire never materializes (or rounds)
/// an f64 block.
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    assert_block_shape(p, blk);
    match p.arch {
        Arch::Elman => elman::h_block_f32(p, blk),
        Arch::Jordan => jordan::h_block_f32(p, blk),
        Arch::Narmax => narmax::h_block_f32(p, blk),
        Arch::Fc => fc::h_block_f32(p, blk),
        Arch::Lstm => lstm::h_block_f32(p, blk),
        Arch::Gru => gru::h_block_f32(p, blk),
    }
}

/// Dispatch: H for a whole row block on the recurrence mode the policy
/// selects, **f32-born** either way. [`RecurrenceMode::Sequential`] routes
/// to the oracle kernels ([`h_block_f32`]); [`RecurrenceMode::Chunked`]
/// routes to the sequence-parallel executors over the fixed
/// [`chunk_schedule`]`(q, chunk)`:
///
/// * **FC** — [`fc::h_block_f32_chunked`]: cross-chunk coupling GEMMs
///   precomputed in parallel, fold order untouched — **bit-identical** to
///   the sequential kernel at any chunk size and worker count.
/// * **Elman / LSTM / GRU** — the warm-up-truncated kernels
///   (`h_block_f32_from`): only the tail chunk plus a `warmup`-step
///   prefix is evaluated, from a zero state. When the warm-up reaches
///   `t = 0` the run is bitwise the sequential kernel; otherwise the
///   initial-state discrepancy is bounded by the documented per-arch
///   envelope (`tests/scan_props.rs`). This O(chunk + warmup) truncation —
///   not thread parallelism — is what makes the long-horizon bench's
///   chunked mode fast; worker scaling still comes from row-block
///   parallelism above this call.
/// * **Jordan / NARMAX** — recurrence-free (pure GEMM + tanh): chunked
///   mode is *identical* to sequential, so they route to the same kernel.
///
/// A schedule of at most one chunk (horizon 0/1, or `chunk >= q`) is the
/// sequential walk by construction and routes to [`h_block_f32`]
/// directly. Under `--features fault-inject` the chunked path is the
/// [`inject::Site::ScanChunk`] site: panics fire at chunk starts and
/// payload/truncation faults on the kernel output (pre-widen, so both
/// [`Precision`] wires fault identically), all keyed by chunk index.
pub fn h_block_f32_with(
    p: &ElmParams,
    blk: &SampleBlock,
    policy: ParallelPolicy,
) -> MatrixF32 {
    let RecurrenceMode::Chunked { chunk, warmup } = policy.recurrence else {
        return h_block_f32(p, blk);
    };
    let sched = chunk_schedule(p.q, chunk);
    if sched.len() <= 1 {
        return h_block_f32(p, blk);
    }
    assert_block_shape(p, blk);
    let tail_ci = sched.len() - 1;
    let mut h = match p.arch {
        Arch::Fc => fc::h_block_f32_chunked(p, blk, chunk, policy),
        // recurrence-free: the whole block is one GEMM + tanh, nothing to
        // chunk — chunked mode is the sequential kernel, exactly
        Arch::Jordan => jordan::h_block_f32(p, blk),
        Arch::Narmax => narmax::h_block_f32(p, blk),
        Arch::Elman | Arch::Lstm | Arch::Gru => {
            inject::maybe_panic(inject::Site::ScanChunk, tail_ci);
            let warm_start = sched[tail_ci].0.saturating_sub(warmup);
            match p.arch {
                Arch::Elman => elman::h_block_f32_from(p, blk, warm_start),
                Arch::Lstm => lstm::h_block_f32_from(p, blk, warm_start),
                _ => gru::h_block_f32_from(p, blk, warm_start),
            }
        }
    };
    // ScanChunk payload/truncation faults fire on the chunked output,
    // keyed by the tail chunk index — deterministic per block, identical
    // on both precision wires (the corruption happens before any widening)
    let (r, c) = (h.rows, h.cols);
    inject::corrupt_slice_f32(inject::Site::ScanChunk, tail_ci, h.data_mut(), r, c);
    let keep = inject::truncated_rows(inject::Site::ScanChunk, tail_ci, r);
    if keep < r {
        h = MatrixF32::from_slice(keep, c, &h.data()[..keep * c]);
    }
    h
}

/// Dispatch: H for a whole row block on the wire `precision` selects —
/// [`Precision::F64`] widens the f32-born kernel output (exact),
/// [`Precision::MixedF32`] hands the f32 block through untouched.
/// Recurrence traversal is [`RecurrenceMode::Sequential`]; callers with a
/// full [`ParallelPolicy`] in hand use [`h_block_policy`].
pub fn h_block_prec(p: &ElmParams, blk: &SampleBlock, precision: Precision) -> HBlock {
    h_block_policy(p, blk, ParallelPolicy::sequential().with_precision(precision))
}

/// Dispatch: H for a whole row block on the wire **and** recurrence mode
/// the policy selects — the precision split of [`h_block_prec`] over the
/// traversal split of [`h_block_f32_with`]. Both wires run the identical
/// f32-born kernel; `F64` is an exact widening of it, so the recurrence
/// mode never interacts with the precision choice.
pub fn h_block_policy(p: &ElmParams, blk: &SampleBlock, policy: ParallelPolicy) -> HBlock {
    match policy.precision {
        Precision::F64 => HBlock::F64(h_block_f32_with(p, blk, policy).to_f64()),
        Precision::MixedF32 => HBlock::F32(h_block_f32_with(p, blk, policy)),
    }
}

/// Lift the input projections of a whole block into one GEMM:
/// returns (rows·q) × (gates·m) with entry [(i·q + t), g·m + j] =
/// Σ_si x[i, si, t] · w[si, g, j] — every `wx_at` dot of the block at once.
/// (`w` is row-major (s, gates·m), which is exactly how the per-arch
/// buffers `w`, `w3`, `w4` are laid out.)
///
/// Both operands are born f32 (the window data and the parameter
/// buffers), so the GEMM runs on the f32 wire through
/// [`MatrixF32::matmul_widen`]: half the operand traffic of the old
/// widen-first f64 GEMM, and **bit-identical** to it — every f32×f32
/// product is exact in f64 and the widen kernel accumulates in the same
/// fixed tile order (see the `linalg::matrix32` contract).
pub(crate) fn lift_wx(
    w: &[f32],
    gates: usize,
    blk: &SampleBlock,
    s: usize,
    q: usize,
    m: usize,
) -> Matrix {
    let gm = gates * m;
    debug_assert_eq!(w.len(), s * gm);
    let rows = blk.rows;
    // Xb: (rows·q, s) — the lag windows transposed so timesteps are rows
    let mut xb = MatrixF32::zeros(rows * q, s);
    for i in 0..rows {
        let xi = blk.x_row(i, s, q);
        for si in 0..s {
            for t in 0..q {
                xb[(i * q + t, si)] = xi[si * q + t];
            }
        }
    }
    let wm = MatrixF32::from_slice(s, gm, w);
    xb.matmul_widen(&wm, ParallelPolicy::sequential())
}

/// Fixed block tiling of [0, n) — the one block-boundary definition every
/// batched-H driver (trainer, CPU pipeline, BPTT forward) shares, so the
/// deterministic-result argument never depends on the call site. Delegates
/// to the linalg substrate's fixed-split schedule
/// ([`crate::linalg::policy::fixed_tiles`]): block boundaries depend on
/// (n, rows) alone, never on a worker count.
pub fn block_ranges(n: usize, rows: usize) -> Vec<(usize, usize)> {
    crate::linalg::policy::fixed_tiles(n, rows)
}

/// Batched H for rows [lo, hi) of a windowed dataset, widened to f64;
/// zeros are substituted when the error history is absent.
pub fn h_block_range(
    p: &ElmParams,
    data: &crate::data::window::Windowed,
    ehist: Option<&[f32]>,
    lo: usize,
    hi: usize,
) -> Matrix {
    h_block_range_prec(p, data, ehist, lo, hi, Precision::F64).into_f64()
}

/// Batched H for rows [lo, hi) on the wire `precision` selects (the
/// `MixedF32` variant is f32-born end to end). The range and the optional
/// error-history buffer are validated here — the public boundary — so a
/// mis-sized caller fails with a message instead of a silent stride bug
/// (or an opaque slice panic) in release builds.
pub fn h_block_range_prec(
    p: &ElmParams,
    data: &crate::data::window::Windowed,
    ehist: Option<&[f32]>,
    lo: usize,
    hi: usize,
    precision: Precision,
) -> HBlock {
    h_block_range_policy(
        p,
        data,
        ehist,
        lo,
        hi,
        ParallelPolicy::sequential().with_precision(precision),
    )
}

/// Batched H for rows [lo, hi) on the wire **and** recurrence mode the
/// policy selects — [`h_block_range_prec`] with the traversal knob
/// exposed (see [`h_block_f32_with`] for the chunked-mode contract). The
/// range and the optional error-history buffer are validated here, the
/// public boundary.
pub fn h_block_range_policy(
    p: &ElmParams,
    data: &crate::data::window::Windowed,
    ehist: Option<&[f32]>,
    lo: usize,
    hi: usize,
    policy: ParallelPolicy,
) -> HBlock {
    let (s, q) = (data.s, data.q);
    assert!(
        lo <= hi && hi <= data.n,
        "h_block_range rows [{lo}, {hi}) out of bounds for n = {}",
        data.n
    );
    let rows = hi - lo;
    let zeros;
    let eh = match ehist {
        Some(e) => {
            assert!(
                e.len() >= hi * q,
                "ehist has {} values, rows [{lo}, {hi}) at q = {q} need {}",
                e.len(),
                hi * q
            );
            &e[lo * q..hi * q]
        }
        None => {
            zeros = vec![0f32; rows * q];
            &zeros[..]
        }
    };
    let blk = SampleBlock {
        rows,
        x: &data.x[lo * s * q..hi * s * q],
        yhist: &data.yhist[lo * q..hi * q],
        ehist: eh,
    };
    h_block_policy(p, &blk, policy)
}

/// Widen a (rows, q) f32 history slab to an f64 matrix (GEMM operand).
pub(crate) fn history_matrix(h: &[f32], rows: usize, q: usize) -> Matrix {
    Matrix::from_f32(rows, q, h)
}

/// Transposed f32 parameter buffer (rows_in, cols_in) → (cols_in, rows_in)
/// f64 matrix — feedback weights enter the GEMM as their transpose.
pub(crate) fn transposed_param(buf: &[f32], rows_in: usize, cols_in: usize) -> Matrix {
    debug_assert_eq!(buf.len(), rows_in * cols_in);
    let mut t = Matrix::zeros(cols_in, rows_in);
    for r in 0..rows_in {
        for c in 0..cols_in {
            t[(c, r)] = buf[r * cols_in + c] as f64;
        }
    }
    t
}

/// Dispatch: one sample's H row (length M).
pub fn h_row(p: &ElmParams, x: &[f32], yhist: &[f32], ehist: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), p.s * p.q, "h_row: x must hold S*Q lag values");
    assert_eq!(out.len(), p.m, "h_row: out must hold M neuron outputs");
    match p.arch {
        Arch::Elman => elman::h_row(p, x, out),
        Arch::Jordan => jordan::h_row(p, x, yhist, out),
        Arch::Narmax => narmax::h_row(p, x, yhist, ehist, out),
        Arch::Fc => fc::h_row(p, x, out),
        Arch::Lstm => lstm::h_row(p, x, out),
        Arch::Gru => gru::h_row(p, x, out),
    }
}

/// Input projection helper: w[:, j] · x[:, t] for row-major w (S, M) and
/// x (S, Q) — the dot product of Alg 2 line 6.
#[inline]
pub(crate) fn wx_at(w: &[f32], x: &[f32], s: usize, q: usize, m: usize, j: usize, t: usize) -> f32 {
    let mut acc = 0f32;
    for si in 0..s {
        acc += w[si * m + j] * x[si * q + t];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::ALL_ARCHS;
    use crate::util::rng::Rng;

    #[test]
    fn all_archs_produce_finite_bounded_rows() {
        let (s, q, m) = (2, 6, 5);
        let mut rng = Rng::new(3);
        for arch in ALL_ARCHS {
            let p = ElmParams::init(arch, s, q, m, 11);
            let x: Vec<f32> = rng.normals_f32(s * q);
            let yh: Vec<f32> = rng.normals_f32(q).iter().map(|v| v * 0.1).collect();
            let eh: Vec<f32> = rng.normals_f32(q).iter().map(|v| v * 0.1).collect();
            let mut out = vec![0f32; m];
            h_row(&p, &x, &yh, &eh, &mut out);
            for v in &out {
                assert!(v.is_finite() && v.abs() <= 1.0 + 1e-5, "{arch:?}: {v}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "h_row: x must hold S*Q lag values")]
    fn h_row_rejects_short_input_in_release() {
        let p = ElmParams::init(Arch::Elman, 2, 6, 5, 11);
        let mut out = vec![0f32; 5];
        h_row(&p, &[0.0; 3], &[0.0; 6], &[0.0; 6], &mut out);
    }

    #[test]
    #[should_panic(expected = "h_row: out must hold M neuron outputs")]
    fn h_row_rejects_short_out_in_release() {
        let p = ElmParams::init(Arch::Elman, 2, 6, 5, 11);
        let mut out = vec![0f32; 4];
        h_row(&p, &[0.0; 12], &[0.0; 6], &[0.0; 6], &mut out);
    }

    #[test]
    fn h_block_matches_h_row_all_archs() {
        let (s, q, m) = (2, 5, 4);
        let rows = 9;
        let mut rng = Rng::new(77);
        let x: Vec<f32> = rng.normals_f32(rows * s * q);
        let yh: Vec<f32> =
            rng.normals_f32(rows * q).iter().map(|v| v * 0.1).collect();
        let eh: Vec<f32> =
            rng.normals_f32(rows * q).iter().map(|v| v * 0.1).collect();
        for arch in ALL_ARCHS {
            let p = ElmParams::init(arch, s, q, m, 5);
            let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
            let hb = h_block(&p, &blk);
            assert_eq!((hb.rows, hb.cols), (rows, m));
            let mut out = vec![0f32; m];
            for i in 0..rows {
                h_row(
                    &p,
                    &x[i * s * q..(i + 1) * s * q],
                    &yh[i * q..(i + 1) * q],
                    &eh[i * q..(i + 1) * q],
                    &mut out,
                );
                for j in 0..m {
                    assert!(
                        (hb[(i, j)] - out[j] as f64).abs() < 1e-5,
                        "{arch:?} row {i} col {j}: {} vs {}",
                        hb[(i, j)],
                        out[j]
                    );
                }
            }
        }
    }

    #[test]
    fn lift_wx_f32_wire_bit_identical_to_f64_gemm() {
        // the f32-wire widen GEMM must reproduce the widen-first f64 GEMM
        // bit for bit (both operands are f32 sources — exact products)
        let (s, q, m) = (3, 5, 4);
        let rows = 70;
        let mut rng = Rng::new(21);
        let x: Vec<f32> = rng.normals_f32(rows * s * q);
        let w: Vec<f32> = rng.normals_f32(s * m);
        let yh = vec![0f32; rows * q];
        let eh = vec![0f32; rows * q];
        let blk = SampleBlock { rows, x: &x, yhist: &yh, ehist: &eh };
        let wire = lift_wx(&w, 1, &blk, s, q, m);
        let mut xb = Matrix::zeros(rows * q, s);
        for i in 0..rows {
            let xi = blk.x_row(i, s, q);
            for si in 0..s {
                for t in 0..q {
                    xb[(i * q + t, si)] = xi[si * q + t] as f64;
                }
            }
        }
        let reference = xb.matmul(&Matrix::from_f32(s, m, &w));
        assert_eq!(wire, reference);
    }

    #[test]
    fn wx_at_matches_naive() {
        let (s, q, m) = (3, 4, 2);
        let w: Vec<f32> = (0..s * m).map(|i| i as f32 * 0.5).collect();
        let x: Vec<f32> = (0..s * q).map(|i| (i as f32).sin()).collect();
        for j in 0..m {
            for t in 0..q {
                let naive: f32 = (0..s).map(|si| w[si * m + j] * x[si * q + t]).sum();
                assert_eq!(wx_at(&w, &x, s, q, m, j, t), naive);
            }
        }
    }
}
