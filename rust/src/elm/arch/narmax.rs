//! NARMAX recurrence (Eq 8): exogenous output + error feedback (F = R = Q).
//! The error history comes from the two-pass extended-least-squares trainer.

#![forbid(unsafe_code)]

use crate::elm::activation::tanh;
use crate::elm::params::ElmParams;
use crate::linalg::{Matrix, MatrixF32};

use super::{history_matrix, transposed_param, wx_at, SampleBlock};

/// One sample: h_j = g(w_j·x(Q) + b_j + Σ_l W'[j,l] y(t−l) + Σ_l W''[j,l] e(t−l)).
pub fn h_row(p: &ElmParams, x: &[f32], yhist: &[f32], ehist: &[f32], out: &mut [f32]) {
    let (s, q, m) = (p.s, p.q, p.m);
    let w = p.buf("w");
    let b = p.buf("b");
    let wp = p.buf("wp");
    let wpp = p.buf("wpp");
    assert_eq!(yhist.len(), q, "narmax h_row: yhist must hold Q lagged outputs");
    assert_eq!(ehist.len(), q, "narmax h_row: ehist must hold Q lagged errors");
    for j in 0..m {
        let mut acc = wx_at(w, x, s, q, m, j, q - 1) + b[j];
        for l in 0..q {
            acc += wp[j * q + l] * yhist[l] + wpp[j * q + l] * ehist[l];
        }
        out[j] = tanh(acc);
    }
}

/// Whole row block, widened to f64 — an exact cast of [`h_block_f32`]
/// (every H entry is an f32 tanh output, exactly representable).
pub fn h_block(p: &ElmParams, blk: &SampleBlock) -> Matrix {
    h_block_f32(p, blk).to_f64()
}

/// Whole row block, **f32-born**. Like Jordan, NARMAX is recurrence-free
/// given the two histories, so the block is three GEMMs — X_last·W +
/// Yhist·W′ᵀ + Ehist·W″ᵀ — plus bias and tanh, written straight into
/// `MatrixF32`.
pub fn h_block_f32(p: &ElmParams, blk: &SampleBlock) -> MatrixF32 {
    let (s, q, m) = (p.s, p.q, p.m);
    let rows = blk.rows;
    let mut xl = Matrix::zeros(rows, s);
    for i in 0..rows {
        let xi = blk.x_row(i, s, q);
        for si in 0..s {
            xl[(i, si)] = xi[si * q + (q - 1)] as f64;
        }
    }
    let pre = xl.matmul(&Matrix::from_f32(s, m, p.buf("w")));
    let fb_y = history_matrix(blk.yhist, rows, q)
        .matmul(&transposed_param(p.buf("wp"), m, q));
    let fb_e = history_matrix(blk.ehist, rows, q)
        .matmul(&transposed_param(p.buf("wpp"), m, q));
    let b = p.buf("b");
    let mut h = MatrixF32::zeros(rows, m);
    for i in 0..rows {
        for j in 0..m {
            let acc = (pre[(i, j)] + fb_y[(i, j)] + fb_e[(i, j)]) as f32 + b[j];
            h[(i, j)] = tanh(acc);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::arch::jordan;
    use crate::elm::params::Arch;

    #[test]
    fn zero_error_matches_jordan_with_wp_as_alpha() {
        let (s, q, m) = (1, 4, 3);
        let pn = ElmParams::init(Arch::Narmax, s, q, m, 6);
        // build a Jordan with identical (w, b) and alpha := wp
        let mut pj = ElmParams::init(Arch::Jordan, s, q, m, 6);
        pj.bufs[0] = pn.buf("w").to_vec();
        pj.bufs[1] = pn.buf("b").to_vec();
        pj.bufs[2] = pn.buf("wp").to_vec();
        let x = vec![0.3f32, -0.1, 0.2, 0.5];
        let yh = vec![0.2f32, 0.1, -0.3, 0.4];
        let mut a = vec![0f32; m];
        let mut b_ = vec![0f32; m];
        h_row(&pn, &x, &yh, &vec![0.0; q], &mut a);
        jordan::h_row(&pj, &x, &yh, &mut b_);
        for j in 0..m {
            assert!((a[j] - b_[j]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "narmax h_row: yhist must hold Q lagged outputs")]
    fn short_yhist_rejected_in_release() {
        let (s, q, m) = (1, 4, 3);
        let p = ElmParams::init(Arch::Narmax, s, q, m, 6);
        let x = vec![0.1f32; q];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &vec![0.0; q - 1], &vec![0.0; q], &mut out);
    }

    #[test]
    #[should_panic(expected = "narmax h_row: ehist must hold Q lagged errors")]
    fn short_ehist_rejected_in_release() {
        let (s, q, m) = (1, 4, 3);
        let p = ElmParams::init(Arch::Narmax, s, q, m, 6);
        let x = vec![0.1f32; q];
        let mut out = vec![0f32; m];
        h_row(&p, &x, &vec![0.0; q], &vec![0.0; q - 1], &mut out);
    }

    #[test]
    fn error_feedback_contributes() {
        let (s, q, m) = (1, 3, 2);
        let p = ElmParams::init(Arch::Narmax, s, q, m, 8);
        let x = vec![0.1f32, 0.0, 0.2];
        let yh = vec![0.1f32, 0.2, 0.3];
        let mut a = vec![0f32; m];
        let mut b = vec![0f32; m];
        h_row(&p, &x, &yh, &vec![0.0; q], &mut a);
        h_row(&p, &x, &yh, &[0.5, -0.5, 0.25], &mut b);
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-6));
    }
}
