//! S-R-ELM: the sequential non-iterative RNN trainer (Algorithm 1).
//!
//! This is the paper's CPU baseline (adopted from Rizk & Awad 2019): build
//! the hidden design matrix H by running each architecture's recurrence
//! (Eq 6-11), then solve `min ‖Hβ − Y‖` by QR. The trainer now computes H
//! in row blocks through the batched `arch::h_block` kernels (input
//! projections lifted into one GEMM per block), so the report tables that
//! time `SrElmModel::train` as "sequential" measure against this batched
//! single-threaded path — parallel-vs-sequential speedups are therefore
//! *conservative* relative to the paper's plain scalar loop. That scalar
//! loop survives as `arch::h_row` / `trainer::hidden_matrix_reference`:
//! the oracle the batched path is tested against, and the seed baseline
//! `benches/linalg.rs` quantifies the batching win against.
//!
//! The architecture recurrences live in [`arch`], one module each, and are
//! bit-compatible (up to f32 rounding) with the Pallas kernels — the
//! integration tests in `rust/tests/pipeline.rs` check rust-vs-artifact
//! numerics on shared inputs.

#![forbid(unsafe_code)]

pub mod activation;
pub mod arch;
pub mod online;
pub mod params;
pub mod stacked;
pub mod trainer;

pub use online::{OnlineElm, RlsOutcome};
pub use params::{param_specs, Arch, ElmParams};
pub use stacked::StackedElmModel;
pub use trainer::{SrElmModel, TrainOptions};

pub const ALL_ARCHS: [Arch; 6] =
    [Arch::Elman, Arch::Jordan, Arch::Narmax, Arch::Fc, Arch::Lstm, Arch::Gru];
