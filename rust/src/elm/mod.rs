//! S-R-ELM: the sequential non-iterative RNN trainer (Algorithm 1).
//!
//! This is the paper's CPU baseline (adopted from Rizk & Awad 2019): build
//! the hidden design matrix H by running each architecture's recurrence
//! (Eq 6-11) sample by sample with plain scalar loops, then solve
//! `min ‖Hβ − Y‖` by QR. Deliberately *not* vectorized — this is the
//! comparator the parallel pipeline's speedups are measured against, so it
//! mirrors what a straightforward NumPy-free sequential implementation does.
//!
//! The architecture recurrences live in [`arch`], one module each, and are
//! bit-compatible (up to f32 rounding) with the Pallas kernels — the
//! integration tests in `rust/tests/pipeline.rs` check rust-vs-artifact
//! numerics on shared inputs.

pub mod activation;
pub mod arch;
pub mod online;
pub mod params;
pub mod stacked;
pub mod trainer;

pub use online::OnlineElm;
pub use params::{param_specs, Arch, ElmParams};
pub use stacked::StackedElmModel;
pub use trainer::{SrElmModel, TrainOptions};

pub const ALL_ARCHS: [Arch; 6] =
    [Arch::Elman, Arch::Jordan, Arch::Narmax, Arch::Fc, Arch::Lstm, Arch::Gru];
