//! Online sequential ELM (OS-ELM, Park & Kim 2017 — §3.1.2 of the paper):
//! recursive least squares over streaming H blocks, so β stays current as
//! samples arrive without re-solving from scratch.
//!
//! State: P = (HᵀH + λI)⁻¹ and β. Block update (Sherman-Morrison-Woodbury):
//!
//! ```text
//!   K = P Hᵀ (I + H P Hᵀ)⁻¹
//!   β ← β + K (y − H β)
//!   P ← P − K H P
//! ```
//!
//! This composes with the coordinator's row-block streaming: the same
//! `elm_h` artifacts produce H blocks; this module folds them. The
//! invariant (tested): after any prefix of blocks, β equals the batch
//! ridge solution over the rows seen so far.
//!
//! # Divergence guard
//!
//! RLS can diverge: a poisoned input block, or covariance drift making
//! S = I + H P Hᵀ numerically indefinite, would silently turn β/P into
//! NaN and corrupt every later update. [`OnlineElm::update_block`] guards
//! both ends — non-finite inputs are quarantined without touching state
//! ([`RlsOutcome::QuarantinedInput`]), and an update whose new β or P is
//! non-finite (or whose S-solve fails) is rolled back by resetting P to
//! the ridge prior I/λ while keeping β ([`RlsOutcome::Reset`]), so the
//! filter re-regularizes instead of propagating poison.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::linalg::{cholesky_solve, Matrix};
use crate::robust::SolveError;

/// What one [`OnlineElm::update_block`] call did to the filter state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RlsOutcome {
    /// The block was folded in normally.
    Applied,
    /// The block contained non-finite values and was skipped; state is
    /// untouched.
    QuarantinedInput {
        /// How many non-finite entries the screen found (h + y).
        non_finite: usize,
    },
    /// The update diverged (S-solve failed, or the new β/P was
    /// non-finite): the block was dropped and the covariance reset to the
    /// ridge prior I/λ, keeping the current β.
    Reset,
}

/// Recursive least-squares state for one output.
pub struct OnlineElm {
    m: usize,
    /// P = (HᵀH + λI)⁻¹, kept symmetric
    p: Matrix,
    beta: Vec<f64>,
    rows_seen: usize,
    lambda: f64,
    /// divergence-guard resets so far (see [`RlsOutcome::Reset`])
    resets: u32,
}

impl OnlineElm {
    /// λ > 0 initializes P = I/λ (ridge prior), so updates are defined
    /// from the first row.
    pub fn new(m: usize, lambda: f64) -> OnlineElm {
        assert!(lambda > 0.0, "online ELM needs a ridge prior");
        let mut p = Matrix::zeros(m, m);
        for i in 0..m {
            p[(i, i)] = 1.0 / lambda;
        }
        OnlineElm { m, p, beta: vec![0.0; m], rows_seen: 0, lambda, resets: 0 }
    }

    /// Resume the filter from an externally computed ridge posterior:
    /// `p` = (HᵀH + λI)⁻¹ over the `rows_seen` rows already absorbed and
    /// `beta` the matching ridge solution. This is the fleet trainer's
    /// batch→online handoff: a tenant trained by the block-diagonal batch
    /// solve streams later rows through RLS *continuing* its batch
    /// posterior instead of restarting from the I/λ prior, which is what
    /// keeps the "β ≡ batch ridge over all rows seen" invariant true
    /// across the handoff. Shapes and finiteness are checked up front —
    /// a poisoned seed must not masquerade as healthy filter state.
    pub fn from_state(
        m: usize,
        lambda: f64,
        p: Matrix,
        beta: Vec<f64>,
        rows_seen: usize,
    ) -> Result<OnlineElm> {
        assert!(lambda > 0.0, "online ELM needs a ridge prior");
        if p.rows != m || p.cols != m || beta.len() != m {
            return Err(SolveError::ShapeMismatch {
                context: "online seed",
                detail: format!(
                    "P is {}x{}, beta has {} vs M {}",
                    p.rows,
                    p.cols,
                    beta.len(),
                    m
                ),
            }
            .into());
        }
        if !p.data().iter().all(|v| v.is_finite())
            || !beta.iter().all(|v| v.is_finite())
        {
            return Err(SolveError::NonFiniteInput { site: "online seed", index: 0 }
                .into());
        }
        Ok(OnlineElm { m, p, beta, rows_seen, lambda, resets: 0 })
    }

    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Divergence-guard resets so far.
    pub fn resets(&self) -> u32 {
        self.resets
    }

    /// The covariance P = (HᵀH + λI)⁻¹ the filter currently holds. Exposed
    /// (read-only) so the fleet's crash-safe journal can snapshot the full
    /// filter state; [`OnlineElm::from_state`] is the matching restore
    /// path, and because both sides move exact f64 bits the round trip is
    /// bit-identical.
    pub fn covariance(&self) -> &Matrix {
        &self.p
    }

    /// Reset the covariance to the ridge prior I/λ (keeping β) and record
    /// it — the [`RlsOutcome::Reset`] recovery.
    fn reset_covariance(&mut self) -> RlsOutcome {
        self.p = Matrix::zeros(self.m, self.m);
        for i in 0..self.m {
            self.p[(i, i)] = 1.0 / self.lambda;
        }
        self.resets += 1;
        RlsOutcome::Reset
    }

    /// Fold one H block (r × M, f32 artifact layout) and its targets.
    /// Reports what happened to the state (see [`RlsOutcome`]) — the guard
    /// never lets a non-finite β or P survive this call.
    pub fn update_block(&mut self, h: &[f32], y: &[f32], rows: usize) -> Result<RlsOutcome> {
        if h.len() != rows * self.m || y.len() != rows {
            return Err(SolveError::ShapeMismatch {
                context: "online update",
                detail: format!(
                    "h {} y {} vs rows {} x M {}",
                    h.len(),
                    y.len(),
                    rows,
                    self.m
                ),
            }
            .into());
        }
        if rows == 0 {
            return Ok(RlsOutcome::Applied);
        }
        // input quarantine: a poisoned block must not touch β or P
        let non_finite = h.iter().filter(|v| !v.is_finite()).count()
            + y.iter().filter(|v| !v.is_finite()).count();
        if non_finite > 0 {
            return Ok(RlsOutcome::QuarantinedInput { non_finite });
        }
        let hb = Matrix::from_f32(rows, self.m, h);
        // S = I + H P Hᵀ  (r × r, SPD)
        let ph_t = {
            // P Hᵀ: M × r
            let mut out = Matrix::zeros(self.m, rows);
            for i in 0..self.m {
                for r in 0..rows {
                    let mut s = 0.0;
                    for k in 0..self.m {
                        s += self.p[(i, k)] * hb[(r, k)];
                    }
                    out[(i, r)] = s;
                }
            }
            out
        };
        let mut s_mat = hb.matmul(&ph_t); // r × r
        for i in 0..rows {
            s_mat[(i, i)] += 1.0;
        }
        // K = P Hᵀ S⁻¹ — solve S Xᵀ = (P Hᵀ)ᵀ column by column via
        // Cholesky. Covariance drift can make S numerically indefinite;
        // that is a divergence, not a caller error → reset-and-report.
        let mut k = Matrix::zeros(self.m, rows);
        for col in 0..self.m {
            // rhs = row `col` of P Hᵀ as a vector over r
            let rhs: Vec<f64> = (0..rows).map(|r| ph_t[(col, r)]).collect();
            let Ok(x) = cholesky_solve(&s_mat, &rhs) else {
                return Ok(self.reset_covariance());
            };
            for r in 0..rows {
                k[(col, r)] = x[r];
            }
        }
        // β += K (y − H β) — staged so a diverged update can be dropped
        let resid: Vec<f64> = (0..rows)
            .map(|r| {
                let pred: f64 =
                    (0..self.m).map(|j| hb[(r, j)] * self.beta[j]).sum();
                y[r] as f64 - pred
            })
            .collect();
        let delta = k.matvec(&resid);
        let beta_new: Vec<f64> =
            self.beta.iter().zip(&delta).map(|(b, d)| b + d).collect();
        // P ← P − K (H P) ; H P = (P Hᵀ)ᵀ
        let mut p_new = self.p.clone();
        for i in 0..self.m {
            for j in 0..self.m {
                let mut s = 0.0;
                for r in 0..rows {
                    s += k[(i, r)] * ph_t[(j, r)];
                }
                p_new[(i, j)] -= s;
            }
        }
        // re-symmetrize (float drift)
        for i in 0..self.m {
            for j in 0..i {
                let avg = 0.5 * (p_new[(i, j)] + p_new[(j, i)]);
                p_new[(i, j)] = avg;
                p_new[(j, i)] = avg;
            }
        }
        // divergence guard: only finite state may be committed
        if !beta_new.iter().all(|v| v.is_finite())
            || !p_new.data().iter().all(|v| v.is_finite())
        {
            return Ok(self.reset_covariance());
        }
        self.beta = beta_new;
        self.p = p_new;
        self.rows_seen += rows;
        Ok(RlsOutcome::Applied)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix as M;
    use crate::util::rng::Rng;

    fn batch_ridge(h: &[f32], y: &[f32], n: usize, m: usize, lambda: f64) -> Vec<f64> {
        let hm = M::from_f32(n, m, h);
        let mut g = hm.gram();
        for i in 0..m {
            g[(i, i)] += lambda;
        }
        let yv: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let c = hm.t_matvec(&yv);
        cholesky_solve(&g, &c).unwrap()
    }

    fn random_problem(n: usize, m: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let h: Vec<f32> = (0..n * m).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (h, y)
    }

    #[test]
    fn online_equals_batch_after_every_prefix() {
        let (n, m, lambda) = (96usize, 6usize, 1e-3);
        let (h, y) = random_problem(n, m, 1);
        let mut online = OnlineElm::new(m, lambda);
        let block = 16;
        let mut seen = 0;
        while seen < n {
            let hi = (seen + block).min(n);
            online
                .update_block(&h[seen * m..hi * m], &y[seen..hi], hi - seen)
                .unwrap();
            seen = hi;
            if seen >= m {
                let batch = batch_ridge(&h[..seen * m], &y[..seen], seen, m, lambda);
                for (a, b) in online.beta().iter().zip(&batch) {
                    assert!((a - b).abs() < 1e-6, "prefix {seen}: {a} vs {b}");
                }
            }
        }
        assert_eq!(online.rows_seen(), n);
    }

    #[test]
    fn block_size_does_not_matter() {
        let (n, m, lambda) = (80usize, 5usize, 1e-2);
        let (h, y) = random_problem(n, m, 2);
        let mut by_1 = OnlineElm::new(m, lambda);
        let mut by_all = OnlineElm::new(m, lambda);
        for i in 0..n {
            by_1.update_block(&h[i * m..(i + 1) * m], &y[i..i + 1], 1).unwrap();
        }
        by_all.update_block(&h, &y, n).unwrap();
        for (a, b) in by_1.beta().iter().zip(by_all.beta()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn from_state_resume_equals_batch_ridge_over_all_rows() {
        // seed the filter with the ridge posterior of a batch prefix, then
        // stream the suffix: β must track the batch ridge over ALL rows —
        // the invariant the fleet's batch→online handoff relies on
        let (n, m, lambda) = (120usize, 5usize, 1e-3);
        let (h, y) = random_problem(n, m, 7);
        let cut = 72usize;
        let hm = M::from_f32(cut, m, &h[..cut * m]);
        let mut g = hm.gram();
        for i in 0..m {
            g[(i, i)] += lambda;
        }
        let mut p0 = M::zeros(m, m);
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let col = cholesky_solve(&g, &e).unwrap();
            for i in 0..m {
                p0[(i, j)] = col[i];
            }
        }
        let beta0 = batch_ridge(&h[..cut * m], &y[..cut], cut, m, lambda);
        let mut o = OnlineElm::from_state(m, lambda, p0, beta0, cut).unwrap();
        let mut seen = cut;
        while seen < n {
            let hi = (seen + 16).min(n);
            o.update_block(&h[seen * m..hi * m], &y[seen..hi], hi - seen).unwrap();
            seen = hi;
            let batch = batch_ridge(&h[..seen * m], &y[..seen], seen, m, lambda);
            for (a, b) in o.beta().iter().zip(&batch) {
                assert!((a - b).abs() < 1e-6, "prefix {seen}: {a} vs {b}");
            }
        }
        assert_eq!(o.rows_seen(), n);
    }

    #[test]
    fn from_state_rejects_bad_seeds() {
        let p = M::zeros(3, 3);
        assert!(OnlineElm::from_state(4, 1e-2, p.clone(), vec![0.0; 4], 0).is_err());
        assert!(OnlineElm::from_state(3, 1e-2, p.clone(), vec![0.0; 2], 0).is_err());
        let mut bad = M::zeros(3, 3);
        bad[(1, 1)] = f64::NAN;
        assert!(OnlineElm::from_state(3, 1e-2, bad, vec![0.0; 3], 0).is_err());
        assert!(
            OnlineElm::from_state(3, 1e-2, p, vec![f64::INFINITY, 0.0, 0.0], 0).is_err()
        );
    }

    #[test]
    fn covariance_round_trips_bit_identically_through_from_state() {
        let (n, m, lambda) = (48usize, 4usize, 1e-3);
        let (h, y) = random_problem(n, m, 9);
        let mut live = OnlineElm::new(m, lambda);
        live.update_block(&h, &y, n).unwrap();
        let restored = OnlineElm::from_state(
            m,
            live.lambda(),
            live.covariance().clone(),
            live.beta().to_vec(),
            live.rows_seen(),
        )
        .unwrap();
        assert_eq!(restored.covariance(), live.covariance());
        // one more identical update on both: bit-identical trajectories
        let (h2, y2) = random_problem(8, m, 10);
        let mut a = live;
        let mut b = restored;
        a.update_block(&h2, &y2, 8).unwrap();
        b.update_block(&h2, &y2, 8).unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(a.beta()), bits(b.beta()));
        assert_eq!(a.covariance(), b.covariance());
    }

    #[test]
    fn empty_block_is_noop() {
        let mut o = OnlineElm::new(4, 1e-2);
        let before = o.beta().to_vec();
        o.update_block(&[], &[], 0).unwrap();
        assert_eq!(o.beta(), &before[..]);
        assert_eq!(o.rows_seen(), 0);
    }

    #[test]
    fn shape_errors_rejected() {
        let mut o = OnlineElm::new(4, 1e-2);
        assert!(o.update_block(&[0.0; 7], &[0.0; 2], 2).is_err());
        assert!(o.update_block(&[0.0; 8], &[0.0; 3], 2).is_err());
    }

    #[test]
    #[should_panic(expected = "ridge prior")]
    fn zero_lambda_rejected() {
        let _ = OnlineElm::new(3, 0.0);
    }

    #[test]
    fn poisoned_block_is_quarantined_without_touching_state() {
        let (n, m, lambda) = (40usize, 4usize, 1e-2);
        let (h, y) = random_problem(n, m, 5);
        let mut o = OnlineElm::new(m, lambda);
        o.update_block(&h, &y, n).unwrap();
        let beta_before = o.beta().to_vec();
        let rows_before = o.rows_seen();

        let mut bad_h = h[..8 * m].to_vec();
        bad_h[3] = f32::NAN;
        bad_h[7] = f32::INFINITY;
        let mut bad_y = y[..8].to_vec();
        bad_y[0] = f32::NAN;
        let out = o.update_block(&bad_h, &bad_y, 8).unwrap();
        assert_eq!(out, RlsOutcome::QuarantinedInput { non_finite: 3 });
        assert_eq!(o.beta(), &beta_before[..]);
        assert_eq!(o.rows_seen(), rows_before);
        assert_eq!(o.resets(), 0);

        // the filter still works after the quarantine
        let out = o.update_block(&h[..8 * m], &y[..8], 8).unwrap();
        assert_eq!(out, RlsOutcome::Applied);
        assert_eq!(o.rows_seen(), rows_before + 8);
    }

    #[test]
    fn divergence_resets_covariance_and_keeps_finite_state() {
        // P = I/λ with λ = 1e-240, and one row of f32::MAX entries:
        // S = 1 + h P hᵀ ≈ 3·(3.4e38)²·1e240 overflows f64 to ∞, so the
        // S-Cholesky must fail. The old code would have propagated that
        // error (or NaN); the guard drops the block and resets P.
        let m = 3usize;
        let mut o = OnlineElm::new(m, 1e-240);
        let huge = vec![f32::MAX; m];
        let out = o.update_block(&huge, &[1.0], 1).unwrap();
        assert_eq!(out, RlsOutcome::Reset);
        assert_eq!(o.resets(), 1);
        assert!(o.beta().iter().all(|v| v.is_finite()));
        assert_eq!(o.rows_seen(), 0, "diverged block must not count");

        // after the reset the filter accepts healthy rows again
        let (h, y) = random_problem(8, m, 6);
        for i in 0..8 {
            let out =
                o.update_block(&h[i * m..(i + 1) * m], &y[i..i + 1], 1).unwrap();
            assert_eq!(out, RlsOutcome::Applied);
        }
        assert!(o.beta().iter().all(|v| v.is_finite()));
        assert_eq!(o.rows_seen(), 8);
        assert_eq!(o.resets(), 1);
    }
}
