//! Online sequential ELM (OS-ELM, Park & Kim 2017 — §3.1.2 of the paper):
//! recursive least squares over streaming H blocks, so β stays current as
//! samples arrive without re-solving from scratch.
//!
//! State: P = (HᵀH + λI)⁻¹ and β. Block update (Sherman-Morrison-Woodbury):
//!
//! ```text
//!   K = P Hᵀ (I + H P Hᵀ)⁻¹
//!   β ← β + K (y − H β)
//!   P ← P − K H P
//! ```
//!
//! This composes with the coordinator's row-block streaming: the same
//! `elm_h` artifacts produce H blocks; this module folds them. The
//! invariant (tested): after any prefix of blocks, β equals the batch
//! ridge solution over the rows seen so far.

use anyhow::{bail, Result};

use crate::linalg::{cholesky_solve, Matrix};

/// Recursive least-squares state for one output.
pub struct OnlineElm {
    m: usize,
    /// P = (HᵀH + λI)⁻¹, kept symmetric
    p: Matrix,
    beta: Vec<f64>,
    rows_seen: usize,
    lambda: f64,
}

impl OnlineElm {
    /// λ > 0 initializes P = I/λ (ridge prior), so updates are defined
    /// from the first row.
    pub fn new(m: usize, lambda: f64) -> OnlineElm {
        assert!(lambda > 0.0, "online ELM needs a ridge prior");
        let mut p = Matrix::zeros(m, m);
        for i in 0..m {
            p[(i, i)] = 1.0 / lambda;
        }
        OnlineElm { m, p, beta: vec![0.0; m], rows_seen: 0, lambda }
    }

    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Fold one H block (r × M, f32 artifact layout) and its targets.
    pub fn update_block(&mut self, h: &[f32], y: &[f32], rows: usize) -> Result<()> {
        if h.len() != rows * self.m || y.len() != rows {
            bail!(
                "online update shapes: h {} y {} vs rows {} x M {}",
                h.len(),
                y.len(),
                rows,
                self.m
            );
        }
        if rows == 0 {
            return Ok(());
        }
        let hb = Matrix::from_f32(rows, self.m, h);
        // S = I + H P Hᵀ  (r × r, SPD)
        let ph_t = {
            // P Hᵀ: M × r
            let mut out = Matrix::zeros(self.m, rows);
            for i in 0..self.m {
                for r in 0..rows {
                    let mut s = 0.0;
                    for k in 0..self.m {
                        s += self.p[(i, k)] * hb[(r, k)];
                    }
                    out[(i, r)] = s;
                }
            }
            out
        };
        let mut s_mat = hb.matmul(&ph_t); // r × r
        for i in 0..rows {
            s_mat[(i, i)] += 1.0;
        }
        // K = P Hᵀ S⁻¹ — solve S Xᵀ = (P Hᵀ)ᵀ column by column via Cholesky
        let mut k = Matrix::zeros(self.m, rows);
        for col in 0..self.m {
            // rhs = row `col` of P Hᵀ as a vector over r
            let rhs: Vec<f64> = (0..rows).map(|r| ph_t[(col, r)]).collect();
            let x = cholesky_solve(&s_mat, &rhs)?;
            for r in 0..rows {
                k[(col, r)] = x[r];
            }
        }
        // β += K (y − H β)
        let resid: Vec<f64> = (0..rows)
            .map(|r| {
                let pred: f64 =
                    (0..self.m).map(|j| hb[(r, j)] * self.beta[j]).sum();
                y[r] as f64 - pred
            })
            .collect();
        let delta = k.matvec(&resid);
        for (b, d) in self.beta.iter_mut().zip(&delta) {
            *b += d;
        }
        // P ← P − K (H P) ; H P = (P Hᵀ)ᵀ
        for i in 0..self.m {
            for j in 0..self.m {
                let mut s = 0.0;
                for r in 0..rows {
                    s += k[(i, r)] * ph_t[(j, r)];
                }
                self.p[(i, j)] -= s;
            }
        }
        // re-symmetrize (float drift)
        for i in 0..self.m {
            for j in 0..i {
                let avg = 0.5 * (self.p[(i, j)] + self.p[(j, i)]);
                self.p[(i, j)] = avg;
                self.p[(j, i)] = avg;
            }
        }
        self.rows_seen += rows;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix as M;
    use crate::util::rng::Rng;

    fn batch_ridge(h: &[f32], y: &[f32], n: usize, m: usize, lambda: f64) -> Vec<f64> {
        let hm = M::from_f32(n, m, h);
        let mut g = hm.gram();
        for i in 0..m {
            g[(i, i)] += lambda;
        }
        let yv: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let c = hm.t_matvec(&yv);
        cholesky_solve(&g, &c).unwrap()
    }

    fn random_problem(n: usize, m: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let h: Vec<f32> = (0..n * m).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (h, y)
    }

    #[test]
    fn online_equals_batch_after_every_prefix() {
        let (n, m, lambda) = (96usize, 6usize, 1e-3);
        let (h, y) = random_problem(n, m, 1);
        let mut online = OnlineElm::new(m, lambda);
        let block = 16;
        let mut seen = 0;
        while seen < n {
            let hi = (seen + block).min(n);
            online
                .update_block(&h[seen * m..hi * m], &y[seen..hi], hi - seen)
                .unwrap();
            seen = hi;
            if seen >= m {
                let batch = batch_ridge(&h[..seen * m], &y[..seen], seen, m, lambda);
                for (a, b) in online.beta().iter().zip(&batch) {
                    assert!((a - b).abs() < 1e-6, "prefix {seen}: {a} vs {b}");
                }
            }
        }
        assert_eq!(online.rows_seen(), n);
    }

    #[test]
    fn block_size_does_not_matter() {
        let (n, m, lambda) = (80usize, 5usize, 1e-2);
        let (h, y) = random_problem(n, m, 2);
        let mut by_1 = OnlineElm::new(m, lambda);
        let mut by_all = OnlineElm::new(m, lambda);
        for i in 0..n {
            by_1.update_block(&h[i * m..(i + 1) * m], &y[i..i + 1], 1).unwrap();
        }
        by_all.update_block(&h, &y, n).unwrap();
        for (a, b) in by_1.beta().iter().zip(by_all.beta()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn empty_block_is_noop() {
        let mut o = OnlineElm::new(4, 1e-2);
        let before = o.beta().to_vec();
        o.update_block(&[], &[], 0).unwrap();
        assert_eq!(o.beta(), &before[..]);
        assert_eq!(o.rows_seen(), 0);
    }

    #[test]
    fn shape_errors_rejected() {
        let mut o = OnlineElm::new(4, 1e-2);
        assert!(o.update_block(&[0.0; 7], &[0.0; 2], 2).is_err());
        assert!(o.update_block(&[0.0; 8], &[0.0; 3], 2).is_err());
    }

    #[test]
    #[should_panic(expected = "ridge prior")]
    fn zero_lambda_rejected() {
        let _ = OnlineElm::new(3, 0.0);
    }
}
