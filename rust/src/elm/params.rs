//! Random ELM parameters, stored as flat f32 buffers in the artifact ABI
//! order (mirrors `python/compile/common.py::param_specs` exactly — this is
//! the cross-layer contract).
//!
//! Initialization: input weights and biases ~ U(-1, 1) (the classic ELM
//! regime); feedback weights are scaled by the number of summed feedback
//! terms (1/Q diagonal, 1/(QM) fully connected) so the Q-term recurrent sums
//! stay O(1) and tanh does not saturate into rank collapse — DESIGN.md §2.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Elman,
    Jordan,
    Narmax,
    Fc,
    Lstm,
    Gru,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Elman => "elman",
            Arch::Jordan => "jordan",
            Arch::Narmax => "narmax",
            Arch::Fc => "fc",
            Arch::Lstm => "lstm",
            Arch::Gru => "gru",
        }
    }

    pub fn parse(s: &str) -> Result<Arch> {
        Ok(match s {
            "elman" => Arch::Elman,
            "jordan" => Arch::Jordan,
            "narmax" => Arch::Narmax,
            "fc" | "fully_connected" => Arch::Fc,
            "lstm" => Arch::Lstm,
            "gru" => Arch::Gru,
            other => bail!("unknown architecture {other:?}"),
        })
    }

    /// Does H(t) feed back hidden state (vs exogenous-only feedback)?
    pub fn is_recurrent(&self) -> bool {
        !matches!(self, Arch::Jordan | Arch::Narmax)
    }

    /// Does the H computation consume the target history (teacher forcing)?
    pub fn uses_yhist(&self) -> bool {
        matches!(self, Arch::Jordan | Arch::Narmax)
    }

    /// Does the H computation consume the error history (NARMAX ELS)?
    pub fn uses_ehist(&self) -> bool {
        matches!(self, Arch::Narmax)
    }
}

/// (name, shape) list in ABI order — must match python param_specs.
pub fn param_specs(arch: Arch, s: usize, q: usize, m: usize) -> Vec<(&'static str, Vec<usize>)> {
    match arch {
        Arch::Elman | Arch::Jordan => {
            vec![("w", vec![s, m]), ("b", vec![m]), ("alpha", vec![m, q])]
        }
        Arch::Narmax => vec![
            ("w", vec![s, m]),
            ("b", vec![m]),
            ("wp", vec![m, q]),
            ("wpp", vec![m, q]),
        ],
        Arch::Fc => vec![("w", vec![s, m]), ("b", vec![m]), ("alpha", vec![m, m, q])],
        Arch::Lstm => vec![("w4", vec![s, 4, m]), ("u4", vec![4, m]), ("b4", vec![4, m])],
        Arch::Gru => vec![("w3", vec![s, 3, m]), ("u3", vec![3, m]), ("b3", vec![3, m])],
    }
}

/// The fixed random parameters of one ELM-trained RNN.
#[derive(Debug, Clone)]
pub struct ElmParams {
    pub arch: Arch,
    pub s: usize,
    pub q: usize,
    pub m: usize,
    /// flat buffers in ABI order
    pub bufs: Vec<Vec<f32>>,
}

impl ElmParams {
    /// Draw the paper's random weights (deterministic in `seed`).
    pub fn init(arch: Arch, s: usize, q: usize, m: usize, seed: u64) -> ElmParams {
        let mut rng = Rng::new(seed);
        let specs = param_specs(arch, s, q, m);
        let bufs = specs
            .iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let scale = feedback_scale(arch, name, q, m);
                let mut rr = rng.fork(fx(name));
                rr.weights(n).into_iter().map(|w| w * scale).collect()
            })
            .collect();
        ElmParams { arch, s, q, m, bufs }
    }

    /// Buffer by ABI name.
    pub fn buf(&self, name: &str) -> &[f32] {
        let specs = param_specs(self.arch, self.s, self.q, self.m);
        let idx = specs
            .iter()
            .position(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("{} has no param {name}", self.arch.name()));
        &self.bufs[idx]
    }

    pub fn total_len(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }
}

/// Feedback terms are summed over Q (diagonal) or Q*M (fully connected);
/// scale to keep the sums O(1).
fn feedback_scale(arch: Arch, name: &str, q: usize, m: usize) -> f32 {
    match (arch, name) {
        (Arch::Fc, "alpha") => 1.0 / (q as f32 * m as f32),
        (_, "alpha") | (_, "wp") | (_, "wpp") => 1.0 / q as f32,
        _ => 1.0,
    }
}

fn fx(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_python_abi() {
        // shapes mirrored from python/compile/common.py
        let specs = param_specs(Arch::Lstm, 3, 7, 5);
        assert_eq!(specs[0], ("w4", vec![3, 4, 5]));
        assert_eq!(specs[1], ("u4", vec![4, 5]));
        assert_eq!(specs[2], ("b4", vec![4, 5]));
        let specs = param_specs(Arch::Fc, 2, 4, 6);
        assert_eq!(specs[2], ("alpha", vec![6, 6, 4]));
        let specs = param_specs(Arch::Narmax, 1, 10, 8);
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[3], ("wpp", vec![8, 10]));
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let a = ElmParams::init(Arch::Elman, 1, 10, 8, 42);
        let b = ElmParams::init(Arch::Elman, 1, 10, 8, 42);
        assert_eq!(a.bufs, b.bufs);
        for w in a.buf("w") {
            assert!(w.abs() <= 1.0);
        }
        for al in a.buf("alpha") {
            assert!(al.abs() <= 0.1 + 1e-6, "alpha scaled by 1/Q");
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = ElmParams::init(Arch::Gru, 1, 5, 4, 1);
        let b = ElmParams::init(Arch::Gru, 1, 5, 4, 2);
        assert_ne!(a.bufs, b.bufs);
    }

    #[test]
    fn buf_lookup_by_name() {
        let p = ElmParams::init(Arch::Narmax, 2, 6, 3, 7);
        assert_eq!(p.buf("w").len(), 6);
        assert_eq!(p.buf("wpp").len(), 18);
    }

    #[test]
    fn arch_parse_round_trip() {
        for a in crate::elm::ALL_ARCHS {
            assert_eq!(Arch::parse(a.name()).unwrap(), a);
        }
        assert!(Arch::parse("transformer").is_err());
    }
}
