//! Multi-layer ELM RNNs — the paper's stated future work (§8: "extending
//! Opt-PR-ELM to RNNs with multiple layers").
//!
//! Layer 1 runs the chosen architecture's recurrence over the raw lag
//! window; each subsequent layer is a random-feature expansion of the
//! previous layer's output (ELM-autoencoder style: tanh(W_l h + b_l) with
//! fixed random W_l); β is solved once against the final layer — the
//! solve stays a single linear system, preserving the non-iterative
//! training property.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::data::window::Windowed;
use crate::linalg::{lstsq_ridge, Matrix};
use crate::util::rng::Rng;

use super::params::{Arch, ElmParams};
use super::trainer::{hidden_matrix, TrainOptions};

/// One random projection layer.
#[derive(Debug, Clone)]
pub struct RandomLayer {
    pub w: Vec<f32>, // (m_in, m_out) row-major
    pub b: Vec<f32>, // (m_out,)
    pub m_in: usize,
    pub m_out: usize,
}

impl RandomLayer {
    fn init(m_in: usize, m_out: usize, rng: &mut Rng) -> RandomLayer {
        // scale 1/sqrt(m_in) keeps pre-activations O(1)
        let s = 1.0 / (m_in as f32).sqrt();
        RandomLayer {
            w: rng.weights(m_in * m_out).iter().map(|v| v * s).collect(),
            b: rng.weights(m_out),
            m_in,
            m_out,
        }
    }

    fn apply(&self, h: &Matrix) -> Matrix {
        let n = h.rows;
        let mut out = Matrix::zeros(n, self.m_out);
        for i in 0..n {
            for j in 0..self.m_out {
                let mut acc = self.b[j] as f64;
                for k in 0..self.m_in {
                    acc += h[(i, k)] * self.w[k * self.m_out + j] as f64;
                }
                out[(i, j)] = acc.tanh();
            }
        }
        out
    }
}

/// A depth-L non-iteratively trained RNN.
pub struct StackedElmModel {
    pub params: ElmParams,
    pub layers: Vec<RandomLayer>,
    pub beta: Vec<f64>,
    ridge: f64,
}

impl StackedElmModel {
    /// `widths`: hidden sizes of layers 2..L (layer 1 width = opts.m).
    pub fn train(
        arch: Arch,
        data: &Windowed,
        opts: &TrainOptions,
        widths: &[usize],
    ) -> Result<StackedElmModel> {
        if arch.uses_ehist() {
            bail!("stacked NARMAX is not defined (error feedback is single-layer)");
        }
        let params = ElmParams::init(arch, data.s, data.q, opts.m, opts.seed);
        let mut rng = Rng::new(opts.seed ^ 0x5AC4ED);
        let mut layers = Vec::new();
        let mut m_in = opts.m;
        for &w in widths {
            if w == 0 {
                bail!("layer width must be positive");
            }
            layers.push(RandomLayer::init(m_in, w, &mut rng));
            m_in = w;
        }
        let ridge = opts.ridge.unwrap_or(1e-8);
        let h_final = forward(&params, &layers, data);
        let y: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        let beta = lstsq_ridge(&h_final, &y, ridge)?;
        Ok(StackedElmModel { params, layers, beta, ridge })
    }

    pub fn predict(&self, data: &Windowed) -> Vec<f64> {
        let h = forward(&self.params, &self.layers, data);
        h.matvec(&self.beta)
    }

    pub fn rmse(&self, data: &Windowed) -> f64 {
        let pred = self.predict(data);
        let truth: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        crate::data::stats::rmse(&pred, &truth)
    }

    pub fn depth(&self) -> usize {
        1 + self.layers.len()
    }

    pub fn ridge(&self) -> f64 {
        self.ridge
    }
}

fn forward(params: &ElmParams, layers: &[RandomLayer], data: &Windowed) -> Matrix {
    let mut h = hidden_matrix(params, data, None);
    for layer in layers {
        h = layer.apply(&h);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::SrElmModel;
    use crate::util::rng::Rng as R;

    fn toy(n: usize, seed: u64) -> Windowed {
        let mut rng = R::new(seed);
        let mut y = vec![0.2f64, 0.5];
        for t in 2..n {
            let v = 0.5 * y[t - 1] + 0.25 * y[t - 2]
                + 0.15 * (t as f64 * 0.21).sin()
                + 0.05 * rng.normal();
            y.push(v);
        }
        let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let z: Vec<f64> = y.iter().map(|v| (v - lo) / (hi - lo)).collect();
        Windowed::from_series(&z, 6).unwrap()
    }

    #[test]
    fn zero_extra_layers_matches_single_layer() {
        let w = toy(400, 1);
        let (train, test) = w.split(0.8);
        let mut opts = TrainOptions::new(12, 5);
        opts.ridge = Some(1e-8);
        let stacked = StackedElmModel::train(Arch::Elman, &train, &opts, &[]).unwrap();
        let flat = SrElmModel::train(Arch::Elman, &train, &opts).unwrap();
        assert_eq!(stacked.depth(), 1);
        let (rs, rf) = (stacked.rmse(&test), flat.rmse(&test));
        assert!((rs - rf).abs() < 1e-9, "{rs} vs {rf}");
    }

    #[test]
    fn deeper_models_still_learn() {
        let w = toy(600, 2);
        let (train, test) = w.split(0.8);
        let ymean = test.y.iter().map(|&v| v as f64).sum::<f64>() / test.n as f64;
        let base = (test
            .y
            .iter()
            .map(|&v| (v as f64 - ymean).powi(2))
            .sum::<f64>()
            / test.n as f64)
            .sqrt();
        for arch in [Arch::Elman, Arch::Lstm, Arch::Gru, Arch::Jordan, Arch::Fc] {
            let model =
                StackedElmModel::train(arch, &train, &TrainOptions::new(16, 3), &[32, 16])
                    .unwrap();
            assert_eq!(model.depth(), 3);
            let rmse = model.rmse(&test);
            assert!(rmse < base, "{}: {rmse} vs mean-baseline {base}", arch.name());
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let w = toy(300, 3);
        let opts = TrainOptions::new(8, 11);
        let a = StackedElmModel::train(Arch::Gru, &w, &opts, &[16]).unwrap();
        let b = StackedElmModel::train(Arch::Gru, &w, &opts, &[16]).unwrap();
        assert_eq!(a.beta, b.beta);
    }

    #[test]
    fn narmax_rejected() {
        let w = toy(200, 4);
        assert!(
            StackedElmModel::train(Arch::Narmax, &w, &TrainOptions::new(8, 1), &[8])
                .is_err()
        );
    }

    #[test]
    fn zero_width_rejected() {
        let w = toy(200, 5);
        assert!(
            StackedElmModel::train(Arch::Elman, &w, &TrainOptions::new(8, 1), &[0])
                .is_err()
        );
    }
}
