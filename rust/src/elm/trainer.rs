//! S-R-ELM (Algorithm 1): train + predict.
//!
//! 1. randomly assign W, α, b          (`ElmParams::init`)
//! 2. compute H(Q) in row blocks       (Eq 6-11, batched `arch::h_block`;
//!    `hidden_matrix_reference` keeps the row-by-row Algorithm-1 loop)
//! 3. β = H†Y via QR back-substitution (`linalg::lstsq_qr`)
//!
//! NARMAX trains with two-pass extended least squares (DESIGN.md §2):
//! pass 1 with e ≡ 0, pass 2 with pass-1 residuals as the error feedback.
//! Prediction is one-step-ahead: the error history for test row i uses the
//! (observed − predicted) residuals of the preceding rows, zeros before the
//! start of the test window.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::window::Windowed;
use crate::linalg::{lstsq_qr, lstsq_ridge, Matrix, MatrixF32, ParallelPolicy, Precision};

use super::arch::{self, HBlock};
use super::params::{Arch, ElmParams};

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub m: usize,
    pub seed: u64,
    /// None → auto (QR per the paper's §4.2; NARMAX gets ridge λ = 1e-6,
    /// see `narmax_ridge`); Some(λ) → ridge normal equations
    pub ridge: Option<f64>,
}

impl TrainOptions {
    pub fn new(m: usize, seed: u64) -> TrainOptions {
        TrainOptions { m, seed, ridge: None }
    }

    /// NARMAX's pass-2 fit consumes teacher-forced residual features, but
    /// prediction regenerates residuals from the model itself; without
    /// regularization the unstable directions of that mismatch blow up
    /// (observed: train RMSE 0.98 vs 0.003 with ridge on stock_prices).
    pub const NARMAX_RIDGE: f64 = 1e-6;

    fn effective_ridge(&self, arch: Arch) -> Option<f64> {
        self.ridge.or(if arch == Arch::Narmax { Some(Self::NARMAX_RIDGE) } else { None })
    }
}

/// A trained non-iterative RNN: fixed random params + solved β.
#[derive(Debug, Clone)]
pub struct SrElmModel {
    pub params: ElmParams,
    pub beta: Vec<f64>,
}

impl SrElmModel {
    /// Sequential ELM training (the paper's CPU baseline).
    pub fn train(archk: Arch, data: &Windowed, opts: &TrainOptions) -> Result<SrElmModel> {
        let params = ElmParams::init(archk, data.s, data.q, opts.m, opts.seed);
        let ridge = opts.effective_ridge(archk);
        let solve = |h: &Matrix, y: &[f64]| -> Result<Vec<f64>> {
            match ridge {
                Some(l) => lstsq_ridge(h, y, l),
                None => lstsq_qr(h, y),
            }
        };
        let y: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();

        if archk == Arch::Narmax {
            // pass 1: e = 0
            let zeros = vec![0f32; data.n * data.q];
            let h1 = hidden_matrix(&params, data, Some(&zeros));
            let beta1 = solve(&h1, &y)?;
            // residuals of pass 1 (training rows, in order)
            let resid: Vec<f32> = h1
                .data()
                .chunks(opts.m)
                .zip(&data.y)
                .map(|(hrow, &yv)| {
                    let pred: f64 = hrow.iter().zip(&beta1).map(|(h, b)| h * b).sum();
                    yv - pred as f32
                })
                .collect();
            // pass 2: ehist[i, k-1] = resid[i-k] (0 before the window start)
            let ehist = shift_history(&resid, data.q);
            let h2 = hidden_matrix(&params, data, Some(&ehist));
            let beta = solve(&h2, &y)?;
            return Ok(SrElmModel { params, beta });
        }

        let h = hidden_matrix(&params, data, None);
        let beta = solve(&h, &y)?;
        Ok(SrElmModel { params, beta })
    }

    /// One-step-ahead predictions over `data` (length n).
    pub fn predict(&self, data: &Windowed) -> Vec<f64> {
        let m = self.params.m;
        let mut out = Vec::with_capacity(data.n);
        let mut hrow = vec![0f32; m];
        if self.params.arch == Arch::Narmax {
            // progressive residuals: e(t-k) known once row t-k is predicted
            let q = data.q;
            let mut resid = vec![0f32; data.n];
            let mut ehist = vec![0f32; q];
            for i in 0..data.n {
                for k in 1..=q {
                    // clamp: see shift_history
                    ehist[k - 1] = if i >= k { resid[i - k].clamp(-1.0, 1.0) } else { 0.0 };
                }
                arch::h_row(&self.params, data.x_row(i), data.yhist_row(i), &ehist, &mut hrow);
                let pred: f64 = hrow.iter().zip(&self.beta).map(|(&h, b)| h as f64 * b).sum();
                resid[i] = data.y[i] - pred as f32;
                out.push(pred);
            }
            return out;
        }
        let eh = vec![0f32; data.q];
        for i in 0..data.n {
            arch::h_row(&self.params, data.x_row(i), data.yhist_row(i), &eh, &mut hrow);
            out.push(hrow.iter().zip(&self.beta).map(|(&h, b)| h as f64 * b).sum());
        }
        out
    }

    /// Test-set RMSE (on the normalized scale the data was prepared in).
    pub fn rmse(&self, data: &Windowed) -> f64 {
        let pred = self.predict(data);
        let truth: Vec<f64> = data.y.iter().map(|&v| v as f64).collect();
        crate::data::stats::rmse(&pred, &truth)
    }
}

/// Row-block height for the batched H computation: big enough that the
/// `lift_wx` GEMM amortizes, small enough that the lifted projections
/// ((rows·q) × g·m f64) stay cache-resident.
pub const H_BLOCK_ROWS: usize = 256;

/// H as an n×M f64 matrix, computed block-wise through the batched
/// [`arch::h_block`] kernels (the input projections of each block are one
/// GEMM). `ehist` overrides the error history (NARMAX); None → zeros.
pub fn hidden_matrix(params: &ElmParams, data: &Windowed, ehist: Option<&[f32]>) -> Matrix {
    hidden_matrix_prec(params, data, ehist, Precision::F64).into_f64()
}

/// H assembled on the wire `precision` selects: [`Precision::F64`]
/// returns the n×M f64 matrix [`hidden_matrix`] has always returned;
/// [`Precision::MixedF32`] stitches the **f32-born** blocks into one
/// `MatrixF32` — same values (H entries are f32 nonlinearity outputs),
/// half the footprint, and no f64 materialization or rounding pass
/// anywhere between the kernels and the consumer.
pub fn hidden_matrix_prec(
    params: &ElmParams,
    data: &Windowed,
    ehist: Option<&[f32]>,
    precision: Precision,
) -> HBlock {
    hidden_matrix_policy(
        params,
        data,
        ehist,
        ParallelPolicy::sequential().with_precision(precision),
    )
}

/// [`hidden_matrix_prec`] with the full [`ParallelPolicy`] in hand: the
/// block stitch additionally honors the policy's
/// [`RecurrenceMode`](crate::linalg::RecurrenceMode) — each row block's
/// recurrence runs through [`arch::h_block_range_policy`], so a
/// `Chunked` policy picks up the sequence-parallel executors on both
/// precision wires. (The policy's worker count parallelizes *inside* the
/// chunked kernels; the block loop here stays a sequential stitch, as it
/// always was — the coordinator's `CpuElmTrainer` is the block-parallel
/// driver.)
pub fn hidden_matrix_policy(
    params: &ElmParams,
    data: &Windowed,
    ehist: Option<&[f32]>,
    policy: ParallelPolicy,
) -> HBlock {
    match policy.precision {
        Precision::F64 => {
            let mut h = Matrix::zeros(data.n, params.m);
            for (lo, hi) in arch::block_ranges(data.n, H_BLOCK_ROWS) {
                let hb =
                    arch::h_block_range_policy(params, data, ehist, lo, hi, policy)
                        .into_f64();
                for r in 0..hi - lo {
                    h.row_mut(lo + r).copy_from_slice(hb.row(r));
                }
            }
            HBlock::F64(h)
        }
        Precision::MixedF32 => {
            let mut h = MatrixF32::zeros(data.n, params.m);
            for (lo, hi) in arch::block_ranges(data.n, H_BLOCK_ROWS) {
                match arch::h_block_range_policy(params, data, ehist, lo, hi, policy) {
                    HBlock::F32(hb) => {
                        for r in 0..hi - lo {
                            h.row_mut(lo + r).copy_from_slice(hb.row(r));
                        }
                    }
                    HBlock::F64(_) => unreachable!("MixedF32 range produced f64"),
                }
            }
            HBlock::F32(h)
        }
    }
}

/// Row-by-row H via the sequential scalar recurrences — the Algorithm-1
/// baseline the batched path is validated against (and the paper's CPU
/// comparator for the speedup tables).
pub fn hidden_matrix_reference(
    params: &ElmParams,
    data: &Windowed,
    ehist: Option<&[f32]>,
) -> Matrix {
    let m = params.m;
    let mut h = Matrix::zeros(data.n, m);
    let zeros = vec![0f32; data.q];
    let mut hrow = vec![0f32; m];
    for i in 0..data.n {
        let eh = match ehist {
            Some(e) => &e[i * data.q..(i + 1) * data.q],
            None => &zeros[..],
        };
        arch::h_row(params, data.x_row(i), data.yhist_row(i), eh, &mut hrow);
        for j in 0..m {
            h[(i, j)] = hrow[j] as f64;
        }
    }
    h
}

/// history[i, k-1] = series[i-k], zero-padded at the start.
///
/// Residual feedback is clamped to [-1, 1] (the normalized-data range):
/// without the clamp the NARMAX moving-average loop can amplify spikes
/// through the feedback path (classic ARMA instability) — DESIGN.md §2.
pub fn shift_history(series: &[f32], q: usize) -> Vec<f32> {
    let n = series.len();
    let mut out = vec![0f32; n * q];
    for i in 0..n {
        for k in 1..=q {
            if i >= k {
                out[i * q + (k - 1)] = series[i - k].clamp(-1.0, 1.0);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elm::ALL_ARCHS;
    use crate::util::rng::Rng;

    /// A learnable synthetic series: AR(2) + sine, normalized to [0, 1].
    fn toy_series(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut y = vec![0.3f64, 0.4];
        for t in 2..n {
            let v = 0.55 * y[t - 1] + 0.25 * y[t - 2]
                + 0.1 * (t as f64 * 0.2).sin()
                + 0.02 * rng.normal();
            y.push(v.clamp(-2.0, 2.0));
        }
        let lo = y.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = y.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        y.iter().map(|v| (v - lo) / (hi - lo)).collect()
    }

    #[test]
    fn all_archs_beat_mean_predictor() {
        let series = toy_series(600, 1);
        let w = Windowed::from_series(&series, 8).unwrap();
        let (train, test) = w.split(0.8);
        let ymean = test.y.iter().map(|&v| v as f64).sum::<f64>() / test.n as f64;
        let base: f64 = (test.y.iter().map(|&v| (v as f64 - ymean).powi(2)).sum::<f64>()
            / test.n as f64)
            .sqrt();
        for archk in ALL_ARCHS {
            let model =
                SrElmModel::train(archk, &train, &TrainOptions::new(16, 7)).unwrap();
            let rmse = model.rmse(&test);
            assert!(
                rmse < base,
                "{}: rmse {rmse} not better than mean-predictor {base}",
                archk.name()
            );
        }
    }

    #[test]
    fn train_is_deterministic_in_seed() {
        let series = toy_series(300, 2);
        let w = Windowed::from_series(&series, 6).unwrap();
        let a = SrElmModel::train(Arch::Elman, &w, &TrainOptions::new(8, 3)).unwrap();
        let b = SrElmModel::train(Arch::Elman, &w, &TrainOptions::new(8, 3)).unwrap();
        assert_eq!(a.beta, b.beta);
        let c = SrElmModel::train(Arch::Elman, &w, &TrainOptions::new(8, 4)).unwrap();
        assert_ne!(a.beta, c.beta);
    }

    #[test]
    fn train_fit_is_least_squares() {
        // residual on the training set must be orthogonal to H's columns
        let series = toy_series(200, 3);
        let w = Windowed::from_series(&series, 5).unwrap();
        let model = SrElmModel::train(Arch::Gru, &w, &TrainOptions::new(6, 5)).unwrap();
        let h = hidden_matrix(&model.params, &w, None);
        let pred = h.matvec(&model.beta);
        let resid: Vec<f64> =
            pred.iter().zip(&w.y).map(|(p, &y)| y as f64 - p).collect();
        for v in h.t_matvec(&resid) {
            assert!(v.abs() < 1e-6, "normal equations violated: {v}");
        }
    }

    #[test]
    fn narmax_second_pass_improves_training_fit() {
        let series = toy_series(400, 4);
        let w = Windowed::from_series(&series, 6).unwrap();
        // pass-1-only model == Jordan-style with zero ehist at predict time:
        let m1 = {
            let params = ElmParams::init(Arch::Narmax, w.s, w.q, 12, 9);
            let zeros = vec![0f32; w.n * w.q];
            let h = hidden_matrix(&params, &w, Some(&zeros));
            let y: Vec<f64> = w.y.iter().map(|&v| v as f64).collect();
            let beta = lstsq_qr(&h, &y).unwrap();
            let pred = h.matvec(&beta);
            crate::data::stats::rmse(&pred, &y)
        };
        let m2 = SrElmModel::train(Arch::Narmax, &w, &TrainOptions::new(12, 9)).unwrap();
        let r2 = m2.rmse(&w);
        // ELS with error feedback must not be (much) worse in-sample
        assert!(r2 < m1 * 1.5, "ELS r2={r2} vs pass1={m1}");
    }

    #[test]
    fn batched_hidden_matrix_matches_reference() {
        let series = toy_series(300, 9);
        let w = Windowed::from_series(&series, 7).unwrap();
        for archk in ALL_ARCHS {
            let params = ElmParams::init(archk, w.s, w.q, 10, 3);
            let batched = hidden_matrix(&params, &w, None);
            let reference = hidden_matrix_reference(&params, &w, None);
            let diff = batched.max_abs_diff(&reference);
            assert!(diff < 1e-5, "{}: |batched - ref| = {diff}", archk.name());
        }
    }

    #[test]
    fn ridge_option_trains() {
        let series = toy_series(150, 6);
        let w = Windowed::from_series(&series, 4).unwrap();
        let mut opts = TrainOptions::new(64, 2); // M > n/2: ill-conditioned
        opts.ridge = Some(1e-6);
        let model = SrElmModel::train(Arch::Elman, &w, &opts).unwrap();
        assert!(model.beta.iter().all(|b| b.is_finite()));
    }

    #[test]
    fn shift_history_alignment() {
        let s = vec![0.1f32, 0.2, 0.3, 0.4];
        let h = shift_history(&s, 2);
        // row 0: no history; row 2: [s[1], s[0]]
        assert_eq!(&h[0..2], &[0.0, 0.0]);
        assert_eq!(&h[2 * 2..3 * 2], &[0.2, 0.1]);
        assert_eq!(&h[3 * 2..4 * 2], &[0.3, 0.2]);
    }

    #[test]
    fn shift_history_clamps_feedback() {
        let s = vec![5.0f32, -7.0, 0.5];
        let h = shift_history(&s, 1);
        assert_eq!(&h[1..2], &[1.0], "positive spike clamped");
        assert_eq!(&h[2..3], &[-1.0], "negative spike clamped");
    }
}
