//! Table 2: per-thread (i, j) memory operations and FLOPs for each RNN
//! architecture under Basic-PR-ELM, and the Opt-PR-ELM read reduction.
//!
//! Implemented exactly as printed in the paper (§5, Table 2); the paper's
//! conventions: S input dimension, Q time dependency, M hidden neurons,
//! F/R the NARMAX output/error feedback lengths (we use F = R = Q).
//! Opt-PR-ELM divides the *tiled* reads (the W·X dot product and the
//! recurrent sum) by TW² and adds the one-per-block b read (§5).

#![forbid(unsafe_code)]

use crate::elm::Arch;

/// Per-thread operation counts over all Q timesteps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCounts {
    pub reads: f64,
    pub writes: f64,
    pub flops: f64,
}

/// Basic-PR-ELM reads (Table 2, column 1).
pub fn read_ops(arch: Arch, s: f64, q: f64, m: f64) -> f64 {
    let (f, r) = (q, q); // NARMAX feedback lengths
    match arch {
        Arch::Elman => q * (2.0 * s + q + 2.0),
        Arch::Jordan => q * (2.0 * s + 1.0 + (q + 1.0) * (0.5 + m)),
        Arch::Narmax => q * (2.0 * s + 1.0) + 2.0 * (2.0 * f + m + r),
        Arch::Fc => q * (2.0 * s + 1.0 + 2.0 * m * q),
        Arch::Lstm => q * (5.0 * s + 13.0),
        Arch::Gru => q * (4.0 * s + 8.0),
    }
}

/// Basic-PR-ELM writes (Table 2, column 2).
pub fn write_ops(arch: Arch, q: f64) -> f64 {
    match arch {
        Arch::Lstm => 5.0 * q,
        Arch::Gru => 3.0 * q,
        _ => q,
    }
}

/// FLOPs (Table 2, column 3) — identical for Basic and Opt.
pub fn flops(arch: Arch, s: f64, q: f64, m: f64) -> f64 {
    let (f, r) = (q, q);
    match arch {
        Arch::Elman => q * (2.0 * s + q + 2.0),
        Arch::Jordan => q * (2.0 * s + 1.0 + (q + 1.0) / 2.0 * (2.0 * s * m + m)),
        Arch::Narmax => q * (2.0 * s + 1.0 + 2.0 * f + r * (2.0 + 2.0 * s * m + m)),
        Arch::Fc => q * (2.0 * s + q + 2.0 * q * m),
        Arch::Lstm => q * (8.0 * s + 18.0),
        Arch::Gru => q * (3.0 * s + 17.0),
    }
}

/// Per-thread counts for a variant. `tw` is the tile width (= BS); the
/// paper's §5: Opt reduces reads by ≈TW² and keeps writes/FLOPs.
pub fn op_counts(arch: Arch, variant: super::Variant, s: usize, q: usize, m: usize, tw: usize) -> OpCounts {
    let (s, q, m) = (s as f64, q as f64, m as f64);
    let base_reads = read_ops(arch, s, q, m);
    let reads = match variant {
        super::Variant::Basic => base_reads,
        super::Variant::Opt => {
            // §5: tiled terms shrink by TW², +1 for the shared b read; the
            // per-step history lives in the register file (H_loc, Alg 3
            // line 5) and is not a memory operation
            base_reads / (tw as f64 * tw as f64) + 1.0
        }
    };
    OpCounts { reads, writes: write_ops(arch, q), flops: flops(arch, s, q, m) }
}

/// Memory-ops-to-FLOPs ratio (§5): > 1 for Basic Elman, ≈ TW²× smaller
/// for Opt — the quantity the shared-memory optimization targets.
pub fn mem_to_flop_ratio(c: &OpCounts) -> f64 {
    (c.reads + c.writes) / c.flops.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::super::Variant;
    use super::*;
    use crate::elm::ALL_ARCHS;

    #[test]
    fn elman_matches_paper_formulas() {
        // §5 worked example: Basic Elman does Q(2S+Q+2) reads and FLOPs, Q writes
        let (s, q) = (3.0, 10.0);
        assert_eq!(read_ops(Arch::Elman, s, q, 50.0), q * (2.0 * s + q + 2.0));
        assert_eq!(flops(Arch::Elman, s, q, 50.0), q * (2.0 * s + q + 2.0));
        assert_eq!(write_ops(Arch::Elman, q), q);
    }

    #[test]
    fn basic_elman_ratio_exceeds_one() {
        // §5: (2S+Q+3)/(2S+Q+2) > 1 limits Basic-PR-ELM
        let c = op_counts(Arch::Elman, Variant::Basic, 3, 10, 50, 32);
        assert!(mem_to_flop_ratio(&c) > 1.0);
    }

    #[test]
    fn opt_reduces_reads_by_about_tw_squared() {
        for arch in ALL_ARCHS {
            for tw in [16usize, 32] {
                let b = op_counts(arch, Variant::Basic, 1, 50, 50, tw);
                let o = op_counts(arch, Variant::Opt, 1, 50, 50, tw);
                let reduction = b.reads / o.reads;
                // §5: "minimizes reads by a factor of ≈ TW²" (the +1+Q
                // constant terms keep it below exactly TW²)
                assert!(
                    reduction > (tw * tw) as f64 * 0.04 && reduction <= (tw * tw) as f64,
                    "{arch:?} tw={tw}: reduction {reduction}"
                );
                assert_eq!(b.flops, o.flops, "FLOPs unchanged by tiling");
                assert_eq!(b.writes, o.writes, "writes unchanged by tiling");
            }
        }
    }

    #[test]
    fn lstm_heavier_than_gru_than_elman() {
        // Table 2 ordering at S=1: LSTM > GRU > Elman in per-step FLOPs
        // for short windows (Q < 13: Q+2 < 20)
        let f_l = flops(Arch::Lstm, 1.0, 10.0, 50.0);
        let f_g = flops(Arch::Gru, 1.0, 10.0, 50.0);
        let f_e = flops(Arch::Elman, 1.0, 10.0, 50.0);
        assert!(f_l > f_g && f_g > f_e, "{f_l} {f_g} {f_e}");
    }

    #[test]
    fn fc_flops_grow_with_m() {
        let f10 = flops(Arch::Fc, 1.0, 10.0, 10.0);
        let f100 = flops(Arch::Fc, 1.0, 10.0, 100.0);
        assert!(f100 > 5.0 * f10);
    }

    #[test]
    fn counts_are_positive_and_finite() {
        for arch in ALL_ARCHS {
            for v in [Variant::Basic, Variant::Opt] {
                let c = op_counts(arch, v, 1, 64, 100, 32);
                assert!(c.reads > 0.0 && c.writes > 0.0 && c.flops > 0.0);
                assert!(c.reads.is_finite() && c.flops.is_finite());
            }
        }
    }
}
