//! Device specifications: the paper's two GPUs (§6.1, §7.4) and its host
//! CPU, plus the two calibration constants the absolute times hinge on.

#![forbid(unsafe_code)]

/// A CUDA-class device.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub cuda_cores: usize,
    pub clock_ghz: f64,
    /// device DRAM bandwidth, GB/s
    pub mem_bw_gbs: f64,
    /// host↔device transfer bandwidth, GB/s (PCIe gen2 x16 effective)
    pub pcie_gbs: f64,
    /// shared memory per SM, KiB
    pub shared_kib: usize,
    pub sm_count: usize,
    /// board power for the §7.5 energy model, W (the paper uses 300)
    pub power_w: f64,
    /// kernel launch + driver overhead per launch, seconds
    pub launch_overhead_s: f64,
}

impl DeviceSpec {
    /// Peak f32 throughput (FMA = 2 FLOPs/clock/core).
    pub fn peak_flops(&self) -> f64 {
        self.cuda_cores as f64 * self.clock_ghz * 1e9 * 2.0
    }
}

/// The paper's §6.1 device: NVIDIA Tesla K20m, 2688 CUDA cores @ 723 MHz,
/// 6 GB GDDR5, 250 GB/s were quoted (matching the paper's text).
pub fn tesla_k20m() -> DeviceSpec {
    DeviceSpec {
        name: "Tesla K20m",
        cuda_cores: 2688,
        clock_ghz: 0.723,
        mem_bw_gbs: 250.0,
        pcie_gbs: 6.0,
        shared_kib: 48,
        sm_count: 13,
        power_w: 300.0,
        launch_overhead_s: 10e-6,
    }
}

/// §7.4 portability device: NVIDIA Quadro K2000 (384 cores @ 954 MHz,
/// 64 GB/s GDDR5).
pub fn quadro_k2000() -> DeviceSpec {
    DeviceSpec {
        name: "Quadro K2000",
        cuda_cores: 384,
        clock_ghz: 0.954,
        mem_bw_gbs: 64.0,
        pcie_gbs: 6.0,
        shared_kib: 48,
        sm_count: 2,
        power_w: 51.0, // board TDP; §7.5's "around 300 W" applies to Tesla
        launch_overhead_s: 10e-6,
    }
}

/// The paper's host: Intel Core i5, 8 GB @ 2133 MHz (§6.1), running the
/// *sequential python* S-R-ELM (Numba/NumPy — §4.2).
///
/// The sequential cost model is two-term:
/// `t_seq = threads × per_thread_overhead + FLOPs / dense_flops` —
/// per-(i, j) python dispatch overhead plus NumPy-vectorized inner math.
/// This is the only host model consistent with the paper's own numbers:
/// Elman (tiny per-element FLOPs) takes 32 min on the largest dataset
/// (overhead-bound ⇒ constant #1), while the FLOP-heavy Jordan/NARMAX/FC
/// runs show the same ≤653× speedups as Elman (vectorized-bound ⇒
/// constant #2; a pure scalar model would predict 10⁴× there).
#[derive(Debug, Clone)]
pub struct HostSpec {
    pub name: &'static str,
    /// CALIBRATION CONSTANT #1: python-level per-(i, j) dispatch
    /// overhead, s (anchored to §7.5's 32-minute Elman run).
    pub per_thread_overhead: f64,
    /// CALIBRATION CONSTANT #2: NumPy/LAPACK dense throughput, FLOP/s
    /// (also used for the host-side QR β solve).
    pub dense_flops: f64,
    /// §7.5's CPU power under heavy compute, W
    pub power_w: f64,
}

pub fn cpu_host() -> HostSpec {
    HostSpec {
        name: "Core i5 host",
        per_thread_overhead: 3.0e-5,
        dense_flops: 2.0e9,
        power_w: 30.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tesla_peak_matches_spec_sheet() {
        // K20m peak SP ≈ 3.5 TFLOPs (2688 × 0.723 GHz × 2)
        let t = tesla_k20m();
        let tflops = t.peak_flops() / 1e12;
        assert!((tflops - 3.887).abs() < 0.1, "{tflops}");
    }

    #[test]
    fn tesla_faster_than_quadro() {
        assert!(tesla_k20m().peak_flops() > 4.0 * quadro_k2000().peak_flops());
        assert!(tesla_k20m().mem_bw_gbs > 3.0 * quadro_k2000().mem_bw_gbs);
    }

    #[test]
    fn host_constants_sane() {
        let h = cpu_host();
        // a single python-level dispatch must cost far more than one
        // vectorized FLOP, else the two-term split is meaningless
        assert!(h.per_thread_overhead > 100.0 / h.dense_flops);
        assert_eq!(h.power_w, 30.0);
    }
}
