//! §7.5 energy accounting: J = W × s with the paper's power figures
//! (CPU ≥ 30 W under heavy compute, GPU ≈ 300 W), and the paper's
//! observation that any speedup above power-ratio (10×) is a net energy
//! win for the GPU.

#![forbid(unsafe_code)]

use super::device::{DeviceSpec, HostSpec};
use super::model::SimResult;

#[derive(Debug, Clone)]
pub struct EnergyReport {
    pub gpu_s: f64,
    pub gpu_joules: f64,
    pub cpu_s: f64,
    pub cpu_joules: f64,
    /// cpu_joules / gpu_joules (paper §7.5: ≈50× for Elman M=50)
    pub energy_ratio: f64,
    /// the break-even speedup: gpu wins energy when speedup > this
    pub break_even_speedup: f64,
}

pub fn energy_report(r: &SimResult, dev: &DeviceSpec, host: &HostSpec) -> EnergyReport {
    EnergyReport {
        gpu_s: r.gpu_total_s,
        gpu_joules: r.gpu_joules,
        cpu_s: r.cpu_total_s,
        cpu_joules: r.cpu_joules,
        energy_ratio: r.cpu_joules / r.gpu_joules.max(1e-12),
        break_even_speedup: dev.power_w / host.power_w,
    }
}

#[cfg(test)]
mod tests {
    use super::super::device::{cpu_host, tesla_k20m};
    use super::super::model::{simulate, SimConfig, Variant};
    use super::*;
    use crate::elm::Arch;

    #[test]
    fn break_even_is_power_ratio() {
        // §7.5: "whenever [the GPU] exhibits a speedup higher than 10,
        // [it is] more power-efficient" (300 W / 30 W)
        let cfg = SimConfig {
            arch: Arch::Elman,
            variant: Variant::Opt,
            n: 100_000,
            s: 1,
            q: 10,
            m: 50,
            bs: 32,
        };
        let r = simulate(&cfg, &tesla_k20m(), &cpu_host());
        let e = energy_report(&r, &tesla_k20m(), &cpu_host());
        assert_eq!(e.break_even_speedup, 10.0);
        // energy ratio = speedup / break-even
        assert!((e.energy_ratio - r.speedup / 10.0).abs() < 1e-6 * e.energy_ratio);
    }

    #[test]
    fn big_runs_save_energy() {
        let cfg = SimConfig {
            arch: Arch::Lstm,
            variant: Variant::Opt,
            n: 500_000,
            s: 1,
            q: 50,
            m: 50,
            bs: 32,
        };
        let r = simulate(&cfg, &tesla_k20m(), &cpu_host());
        let e = energy_report(&r, &tesla_k20m(), &cpu_host());
        assert!(e.energy_ratio > 10.0, "ratio {}", e.energy_ratio);
    }
}
