//! Calibrated GPU performance + energy model (the hardware substitute).
//!
//! The paper's speedup tables were measured on an NVIDIA Tesla K20m and a
//! Quadro K2000 (DESIGN.md §3). Neither is available here, so we build the
//! analytic model those numbers are a function of:
//!
//! * [`counts`] — the paper's §5 / Table 2 per-thread memory-op and FLOP
//!   formulas for every architecture, with the Opt variant's ≈TW² read
//!   reduction.
//! * [`device`] — published device specs (cores, clock, DRAM bandwidth,
//!   shared memory, PCIe) for both GPUs plus the paper's host CPU.
//! * [`model`] — a roofline execution model: kernel time =
//!   max(FLOPs/peak, bytes/bandwidth) + launch overhead, plus host↔device
//!   transfers and the host-side QR β solve (the paper solves β with
//!   NumPy on the host — Fig 6 shows H+β dominating, which this model
//!   reproduces).
//! * [`energy`] — §7.5's energy accounting (30 W CPU vs 300 W GPU).
//!
//! Absolute times are calibrated by two scalar efficiency constants
//! (documented in `device.rs`); the *structure* — who wins, how speedup
//! scales with n, M, Q, BS, and where Basic≈Opt — follows from the
//! operation counts alone.

#![forbid(unsafe_code)]

pub mod counts;
pub mod device;
pub mod energy;
pub mod model;

pub use counts::{flops, read_ops, write_ops, OpCounts};
pub use device::{cpu_host, quadro_k2000, tesla_k20m, DeviceSpec, HostSpec};
pub use energy::EnergyReport;
pub use model::{simulate, SimConfig, SimResult, Variant};
