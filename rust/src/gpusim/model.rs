//! The roofline execution model that regenerates the paper's speedups.
//!
//! One training run decomposes exactly as the paper's Fig 6:
//!
//! ```text
//!   t_total = t_init + t_h2d + t_H + t_d2h + t_beta
//! ```
//!
//! * `t_H`   — the H kernel: max(FLOPs / peak, bytes / DRAM-bandwidth)
//!             per launch + launch overhead; Basic vs Opt differ in the
//!             bytes term by ≈TW² (Table 2 / counts.rs).
//! * `t_h2d` — X, W, α, b transfers; `t_d2h` — H back to the host (the
//!             paper's pipeline solves β on the host with NumPy, §4.2).
//! * `t_beta`— Householder QR of the n×M H on the host: ≈ 2nM² FLOPs.
//! * sequential S-R-ELM: the same FLOPs through the host's scalar loop
//!             plus the identical β solve.
//!
//! The occupancy term caps effective GPU throughput when the grid is too
//! small to fill the device — this is what makes small datasets show
//! small speedups (paper: 24× on Japan population vs 522× on Temperature).

#![forbid(unsafe_code)]

use crate::elm::Arch;

use super::counts::{flops, op_counts};
use super::device::{DeviceSpec, HostSpec};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Basic,
    Opt,
}

/// One simulated configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub arch: Arch,
    pub variant: Variant,
    /// samples
    pub n: usize,
    pub s: usize,
    pub q: usize,
    pub m: usize,
    /// thread-block edge (BS = TW): 16 or 32
    pub bs: usize,
}

/// Simulated timings (seconds) — the Fig 6 decomposition.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub init_s: f64,
    pub h2d_s: f64,
    pub kernel_s: f64,
    pub d2h_s: f64,
    pub beta_s: f64,
    pub gpu_total_s: f64,
    /// sequential S-R-ELM on the host
    pub cpu_total_s: f64,
    pub speedup: f64,
    /// §7.5 energy
    pub gpu_joules: f64,
    pub cpu_joules: f64,
}

/// Fraction of peak the kernel can use given the launch geometry: a grid
/// smaller than the device's thread capacity leaves SMs idle.
fn occupancy(cfg: &SimConfig, dev: &DeviceSpec) -> f64 {
    let total_threads = (cfg.n * cfg.m) as f64;
    // each SM can keep ~2048 threads in flight on Kepler
    let device_threads = (dev.sm_count * 2048) as f64;
    (total_threads / device_threads).min(1.0).max(1.0 / device_threads)
}

/// ALU efficiency of the kernel's instruction mix (transcendentals +
/// address arithmetic keep real kernels well under peak FMA throughput).
const KERNEL_EFF: f64 = 0.35;

/// Per-run session overhead: CUDA context + device allocations (the
/// paper's Numba pipeline pays this on every training run). Calibrated
/// against the paper's small-dataset speedups (≈24× on Japan population:
/// fixed costs, not the kernel, bound the speedup there).
const SESSION_OVERHEAD_S: f64 = 0.03;

pub fn simulate(cfg: &SimConfig, dev: &DeviceSpec, host: &HostSpec) -> SimResult {
    let threads = (cfg.n * cfg.m) as f64;
    let c = op_counts(cfg.arch, cfg.variant, cfg.s, cfg.q, cfg.m, cfg.bs);

    // --- GPU side -------------------------------------------------------
    let total_flops = c.flops * threads;
    let total_bytes = (c.reads + c.writes) * 4.0 * threads;
    let occ = occupancy(cfg, dev);
    let compute_s = total_flops / (dev.peak_flops() * KERNEL_EFF * occ);
    let memory_s = total_bytes / (dev.mem_bw_gbs * 1e9);
    let kernel_s = compute_s.max(memory_s) + dev.launch_overhead_s;

    // transfers (Fig 6: "transfer to" carries X, W, α, b; "transfer from"
    // carries H for the host-side β solve, then β back)
    let x_bytes = (cfg.n * cfg.s * cfg.q) as f64 * 4.0;
    let param_bytes = param_count(cfg.arch, cfg.s, cfg.q, cfg.m) * 4.0;
    let h_bytes = (cfg.n * cfg.m) as f64 * 4.0;
    let h2d_s = (x_bytes + param_bytes) / (dev.pcie_gbs * 1e9) + 20e-6;
    let d2h_s = (h_bytes + cfg.m as f64 * 4.0) / (dev.pcie_gbs * 1e9) + 20e-6;

    // host-side β solve: Householder QR ≈ 2nM² + back-substitution
    let beta_flops = 2.0 * cfg.n as f64 * (cfg.m * cfg.m) as f64;
    let beta_s = beta_flops / host.dense_flops + 1e-4;

    // init: RNG for the parameter buffers (measured <0.01% in the paper)
    let init_s = param_count(cfg.arch, cfg.s, cfg.q, cfg.m) / 1e9 + 1e-6;

    let gpu_total_s = SESSION_OVERHEAD_S + init_s + h2d_s + kernel_s + d2h_s + beta_s;

    // --- sequential side (two-term host model — see device.rs) -----------
    let seq_flops = flops(cfg.arch, cfg.s as f64, cfg.q as f64, cfg.m as f64) * threads;
    let cpu_total_s =
        threads * host.per_thread_overhead + seq_flops / host.dense_flops + beta_s;

    SimResult {
        init_s,
        h2d_s,
        kernel_s,
        d2h_s,
        beta_s,
        gpu_total_s,
        cpu_total_s,
        speedup: cpu_total_s / gpu_total_s,
        gpu_joules: gpu_total_s * dev.power_w,
        cpu_joules: cpu_total_s * host.power_w,
    }
}

/// Total random-parameter count per architecture.
fn param_count(arch: Arch, s: usize, q: usize, m: usize) -> f64 {
    let specs = crate::elm::param_specs(arch, s, q, m);
    specs
        .iter()
        .map(|(_n, shape)| shape.iter().product::<usize>() as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::super::device::{cpu_host, quadro_k2000, tesla_k20m};
    use super::*;
    use crate::data::spec::registry;

    fn sim(name: &str, arch: Arch, variant: Variant, m: usize, bs: usize) -> SimResult {
        let d = registry().into_iter().find(|d| d.name == name).unwrap();
        let cfg = SimConfig {
            arch,
            variant,
            n: d.n_instances - d.q_paper.min(64),
            s: 1,
            q: d.q_paper.min(64),
            m,
            bs,
        };
        simulate(&cfg, &tesla_k20m(), &cpu_host())
    }

    #[test]
    fn speedup_grows_with_dataset_size() {
        // §7.1: 25× small → ~400× large for Elman Basic
        let small = sim("japan_population", Arch::Elman, Variant::Basic, 50, 32);
        let large = sim("temperature", Arch::Elman, Variant::Basic, 50, 32);
        assert!(small.speedup > 3.0 && small.speedup < 150.0, "{}", small.speedup);
        assert!(large.speedup > 100.0, "{}", large.speedup);
        assert!(large.speedup > 3.0 * small.speedup);
    }

    #[test]
    fn paper_anchor_elman_temperature() {
        // Table 5: Elman/Tesla/Temperature Opt(BS=32) speedup = 522.
        // The model must land within ~2× of the paper's number.
        let r = sim("temperature", Arch::Elman, Variant::Opt, 50, 32);
        assert!(
            r.speedup > 522.0 / 2.0 && r.speedup < 522.0 * 2.0,
            "Opt speedup {} vs paper 522",
            r.speedup
        );
    }

    #[test]
    fn opt_beats_basic_when_q_large() {
        // §7.1: Opt ≥ Basic when Q > BS (hourly weather Q = 50 > 32)
        let b = sim("hourly_weather", Arch::Elman, Variant::Basic, 50, 32);
        let o = sim("hourly_weather", Arch::Elman, Variant::Opt, 50, 32);
        assert!(o.gpu_total_s <= b.gpu_total_s);
        assert!(o.speedup >= b.speedup);
    }

    #[test]
    fn basic_close_to_opt_when_q_small() {
        // §7.1: with Q = 10 < BS, num_tiles = 1 and the two variants are
        // within a few percent (the paper observes near-identical bars)
        let b = sim("aemo", Arch::Elman, Variant::Basic, 50, 32);
        let o = sim("aemo", Arch::Elman, Variant::Opt, 50, 32);
        let ratio = b.gpu_total_s / o.gpu_total_s;
        assert!(ratio < 1.6, "basic/opt = {ratio} should be close at Q=10");
    }

    #[test]
    fn tesla_beats_quadro_everywhere() {
        // Table 5: Tesla consistently above Quadro
        for name in ["japan_population", "aemo", "temperature"] {
            let d = registry().into_iter().find(|d| d.name == name).unwrap();
            let cfg = SimConfig {
                arch: Arch::Lstm,
                variant: Variant::Opt,
                n: d.n_instances,
                s: 1,
                q: d.q,
                m: 50,
                bs: 32,
            };
            let t = simulate(&cfg, &tesla_k20m(), &cpu_host());
            let q = simulate(&cfg, &quadro_k2000(), &cpu_host());
            assert!(t.speedup >= q.speedup, "{name}: tesla {} quadro {}", t.speedup, q.speedup);
        }
    }

    #[test]
    fn speedup_grows_with_m() {
        // Fig 4's qualitative claim: speedup grows as M grows (the paper
        // reports ~20× growth from M=5 to M=100 on GRU/energy). The
        // host-side β solve is O(nM²), so the curve flattens at large M;
        // we assert clear growth from the low end and no collapse.
        // Growth holds through the kernel-bound regime (M = 5 → 20); at
        // M ≥ 50 the O(nM²) host β solve flattens/caps the curve in this
        // model (deviation from Fig 4's monotone growth is analyzed in
        // EXPERIMENTS.md — the paper's sequential python costs also grow
        // with M, which the two-constant host model does not capture).
        let s5 = sim("energy_consumption", Arch::Gru, Variant::Opt, 5, 32).speedup;
        let s10 = sim("energy_consumption", Arch::Gru, Variant::Opt, 10, 32).speedup;
        let s20 = sim("energy_consumption", Arch::Gru, Variant::Opt, 20, 32).speedup;
        let s100 = sim("energy_consumption", Arch::Gru, Variant::Opt, 100, 32).speedup;
        assert!(s10 > s5, "m=10 {s10} vs m=5 {s5}");
        assert!(s20 > s10, "m=20 {s20} vs m=10 {s10}");
        assert!(s100 > 0.5 * s5, "m=100 {s100} collapsed vs m=5 {s5}");
    }

    #[test]
    fn energy_anchor_section_7_5() {
        // §7.5: Elman M=50 — Opt-PR-ELM 3.71 s / 1113 J vs S-R-ELM ≈32 min
        // on the CPU (57.6 kJ at 30 W). Anchor within a factor of ~2.5.
        let r = sim("temperature", Arch::Elman, Variant::Opt, 50, 32);
        assert!(
            r.gpu_total_s > 3.71 / 2.5 && r.gpu_total_s < 3.71 * 2.5,
            "gpu {} s vs paper 3.71 s",
            r.gpu_total_s
        );
        assert!(
            r.cpu_total_s > 1920.0 / 2.5 && r.cpu_total_s < 1920.0 * 2.5,
            "cpu {} s vs paper ~1920 s",
            r.cpu_total_s
        );
        assert!(r.cpu_joules > 10.0 * r.gpu_joules, "energy ratio (paper: 50×)");
    }

    #[test]
    fn decomposition_sums_to_total() {
        let r = sim("aemo", Arch::Lstm, Variant::Opt, 10, 32);
        let sum = r.init_s + r.h2d_s + r.kernel_s + r.d2h_s + r.beta_s;
        assert!((sum + super::SESSION_OVERHEAD_S - r.gpu_total_s).abs() < 1e-12);
    }

    #[test]
    fn fig6_h_and_beta_dominate() {
        // Fig 6: compute-H + compute-β take the major share; init < 0.01%
        let r = sim("japan_population", Arch::Lstm, Variant::Opt, 10, 32);
        assert!(r.init_s < 0.01 * r.gpu_total_s);
        assert!(r.h2d_s > r.d2h_s * 0.2, "h2d carries more data than d2h");
    }
}
