//! # opt-pr-elm
//!
//! Production reproduction of *"An Optimized and Energy-Efficient Parallel
//! Implementation of Non-Iteratively Trained Recurrent Neural Networks"*
//! (El Zini, Rizk, Awad, 2019).
//!
//! The crate is the L3 layer of a three-layer stack (see `DESIGN.md`):
//!
//! * [`runtime`] loads AOT-compiled HLO artifacts (lowered from JAX/Pallas by
//!   `python/compile/aot.py`) onto a PJRT CPU client and executes them —
//!   python never runs on the training path.
//! * [`coordinator`] streams datasets through fixed-shape row blocks,
//!   accumulates the ELM normal equations (or TSQR factors) and solves for
//!   the output weights β.
//! * [`elm`] is the sequential S-R-ELM baseline (the paper's comparator),
//!   [`bptt`] the parallel-BPTT comparator driver, [`gpusim`] the calibrated
//!   GPU performance/energy model that regenerates the paper's speedup
//!   tables, [`data`] the ten Table-3 benchmark generators, and [`linalg`]
//!   the dense QR/TSQR/Cholesky substrate.

pub mod bptt;
pub mod coordinator;
pub mod data;
pub mod elm;
pub mod gpusim;
pub mod linalg;
pub mod report;
pub mod robust;
pub mod runtime;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
