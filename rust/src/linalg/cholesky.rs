//! Cholesky factorization + SPD solve for the ridge-regularized normal
//! equations `(HᵀH + λI) β = HᵀY` — the coordinator's streaming path and
//! the rank-deficiency fallback of the QR solve.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::robust::error::SolveError;

use super::matrix::{dot, Matrix};
use super::solve::{solve_lower_triangular, solve_upper_triangular};

/// Lower-triangular L with A = L Lᵀ. Fails with a typed
/// [`SolveError::NotPositiveDefinite`] on non-SPD input — including the
/// NaN pivot case, which the naive `s <= 0.0` test silently passes (every
/// NaN comparison is false) and which used to let a single poisoned Gram
/// entry flow through the factor into β.
///
/// Row-major friendly: the k-sum over already-computed entries is a dot of
/// two contiguous row prefixes (rows i and j), not a strided column walk.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    if a.rows != a.cols {
        return Err(SolveError::ShapeMismatch {
            context: "cholesky",
            detail: format!("requires a square matrix, got {}x{}", a.rows, a.cols),
        }
        .into());
    }
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let s = a[(i, j)] - dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                if !s.is_finite() || s <= 0.0 {
                    return Err(
                        SolveError::NotPositiveDefinite { pivot: i, value: s }.into()
                    );
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve A x = b for SPD A via Cholesky.
pub fn cholesky_solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let y = solve_lower_triangular(&l, b)?;
    let lt = l.transpose();
    solve_upper_triangular(&lt, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robust::error::as_solve_error;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(n + 3, n, &mut rng);
        let mut g = a.gram();
        for i in 0..n {
            g[(i, i)] += 0.5; // safely SPD
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(8, 1);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul(&l.transpose());
        assert!(llt.max_abs_diff(&a) < 1e-10);
        for i in 0..8 {
            for j in i + 1..8 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn solve_recovers_known_solution() {
        let a = spd(12, 2);
        let x_true: Vec<f64> = (0..12).map(|i| (i as f64 * 0.3).cos()).collect();
        let b = a.matvec(&x_true);
        let x = cholesky_solve(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::identity(3);
        a[(1, 1)] = -1.0;
        let err = cholesky(&a).unwrap_err();
        match as_solve_error(&err).expect("typed error") {
            SolveError::NotPositiveDefinite { pivot: 1, value } => {
                assert!(*value <= 0.0);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn rejects_nan_pivot_instead_of_nan_factor() {
        // `s <= 0.0` is false for NaN — without the finiteness guard a
        // poisoned diagonal would sqrt into a NaN factor and a NaN β
        let mut a = spd(4, 3);
        a[(2, 2)] = f64::NAN;
        let err = cholesky(&a).unwrap_err();
        match as_solve_error(&err).expect("typed error") {
            SolveError::NotPositiveDefinite { pivot: 2, value } => {
                assert!(value.is_nan());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn rejects_non_square() {
        assert!(cholesky(&Matrix::zeros(2, 3)).is_err());
    }
}
