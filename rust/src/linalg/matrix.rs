//! Row-major dense f64 matrix with cache-blocked kernels for the two hot
//! products of the ELM solve: `matmul` (GEMM) and `gram` (HᵀH).
//! (f64 so the rust-side solves do not add float error on top of the f32
//! artifacts; H blocks are widened on accumulation.)
//!
//! # Blocking scheme
//!
//! `matmul` packs B once per call into contiguous `KC × NC` (64×64)
//! panels ([`PackedPanels`], built by shape-fixed `(kk, jj)` tile walk),
//! then streams rows of A through each panel **four at a time** with the
//! register-tiled [`simd`](super::simd) microkernels: a 4×8 output tile
//! held in accumulator registers across the panel's k loop on the AVX2
//! path ([`simd::gemm_tile_f64`]), the pre-SIMD 4-wide AXPY loop on the
//! scalar path — the active panel (32 KiB) stays in L1 while A and the
//! output are touched sequentially. The pack is **shared read-only by
//! every output row tile** of the call: the threaded `matmul_with` builds
//! it once and hands every worker the same panels instead of repacking B
//! per row tile (the PR-2 layout repacked B `ceil(m / MM_ROW_TILE)`
//! times). `gram` uses a 4-row microkernel ([`simd::gram4_f64`]) that
//! rank-4-updates the upper triangle, quartering the G write traffic
//! relative to the row-at-a-time loop.
//!
//! # Determinism
//!
//! Tile sizes are compile-time constants, so results are bit-identical
//! run to run. `matmul` additionally accumulates each output element's
//! k-terms in ascending order (outer `kk` tiles ascend, `p` ascends
//! within a tile) and is therefore bit-identical to the unblocked ijk
//! loop — a test asserts this. The SIMD dispatch never weakens this: the
//! AVX2 microkernels keep element-independent accumulators, separate
//! mul+add, and the identical per-element operation sequence, so they are
//! **bit-identical to the scalar kernels** (see the [`simd`](super::simd)
//! contract; the only opt-out is the envelope-documented
//! [`FmaMode::Relaxed`] knob on [`ParallelPolicy`]). `gram` is
//! deterministic but *not* bit-identical to the seed's row-at-a-time
//! loop: the rank-4 microkernel sums four rows' products before the
//! single add into G (tests bound the difference at 1e-12). There is
//! deliberately *no* skip of zero multiplicands: `0 × ∞` must produce
//! NaN, and a data-dependent branch mispredicts on dense data.
//!
//! # Threading
//!
//! `matmul_with` shards the GEMM over *output row tiles* ([`MM_ROW_TILE`]
//! rows each, boundaries fixed by the shape alone): every output element
//! is computed start-to-finish by exactly one worker with the identical
//! inner kernel, so the threaded product is **bit-identical** to the
//! sequential `matmul` at any [`ParallelPolicy`] worker count.
//! `gram_with` shards over *input row chunks* ([`GRAM_ROW_CHUNK`] rows,
//! again shape-fixed) and folds the partial Grams in chunk order; the
//! result is bit-identical across worker counts (including 1) but — like
//! the rank-4 microkernel itself — reassociates sums relative to the
//! single-chunk path, so matrices with more than one chunk are pinned to
//! the explicit AᵀA oracle by tolerance, not bits.

#![forbid(unsafe_code)]

use std::fmt;

use super::policy::{fixed_tiles, par_map, ParallelPolicy};
use super::simd::{self, FmaMode};
use crate::util::rng::Rng;

/// Row-major dense f64 matrix — the substrate's working type. All blocked
/// kernels (`matmul*`, `gram*`) live here; see the module docs for the
/// blocking and determinism contract.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// n×n identity.
    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a slice of equal-length row vectors.
    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix { rows: r, cols: c, data: rows.concat() }
    }

    /// Wrap an owned row-major buffer (length must equal rows·cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Widen a row-major f32 buffer to f64 (exact — every f32 is
    /// f64-representable).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    /// Standard-normal random matrix (deterministic in the `Rng` state).
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    /// The row-major backing buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major backing buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable contiguous slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The cols×rows transpose (materialized copy).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other — cache-blocked GEMM: B is packed once into read-only
    /// [`PackedPanels`], then rows of A stream through each panel four at
    /// a time with the register-tiled [`simd`](super::simd) microkernels
    /// (see the module docs for the blocking/determinism story). Always
    /// runs the exact ([`FmaMode::Exact`]) kernels.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let pack = PackedPanels::pack(&other.data, other.rows, other.cols);
        self.matmul_rows(&pack, 0, self.rows, FmaMode::Exact)
    }

    /// Threaded GEMM: output rows sharded over fixed [`MM_ROW_TILE`]-high
    /// tiles executed by `policy.workers` threads, all reading **one
    /// shared B-panel pack** built up front (packing cost paid once per
    /// call, not once per row tile). Bit-identical to [`Matrix::matmul`]
    /// at any worker count (each output element is produced by one worker
    /// running the identical kernel; the pack only changes data layout,
    /// never arithmetic order) when `policy.fma` is [`FmaMode::Exact`]
    /// (the default). Under [`FmaMode::Relaxed`] the result is still
    /// bit-identical **across worker counts** (the schedule is fixed) but
    /// drifts from the exact kernels within the envelope documented in
    /// [`simd`](super::simd).
    pub fn matmul_with(&self, other: &Matrix, policy: ParallelPolicy) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        let pack = PackedPanels::pack(&other.data, other.rows, other.cols);
        if policy.workers <= 1 || m < 2 * MM_ROW_TILE {
            return self.matmul_rows(&pack, 0, m, policy.fma);
        }
        let tiles = fixed_tiles(m, MM_ROW_TILE);
        let slabs =
            par_map(tiles, policy, |(i0, i1)| Ok(self.matmul_rows(&pack, i0, i1, policy.fma)))
                .expect("matmul worker thread panicked");
        let mut data = Vec::with_capacity(m * n);
        for slab in slabs {
            data.extend_from_slice(&slab.data);
        }
        Matrix { rows: m, cols: n, data }
    }

    /// Packed group-GEMM: many independent `Aᵢ·Bᵢ` products executed as
    /// **one** flattened parallel stream — the kernel substrate of the
    /// fleet trainer's block-diagonal batching. Every `Bᵢ` is packed once
    /// into read-only [`PackedPanels`] up front, then the fixed
    /// [`MM_ROW_TILE`] row tiles of *all* pairs are collected into a
    /// single task list executed by `policy.workers` threads: one
    /// spawn/join barrier for the whole group instead of one per product,
    /// which is where the throughput lives when the group is many small
    /// same-shape GEMMs (Appleyard-style fusion of a model fleet).
    ///
    /// Per-pair results are **bit-identical to [`Matrix::matmul_with`]**
    /// (and, under the default [`FmaMode::Exact`], to [`Matrix::matmul`])
    /// at any worker count: each output tile is produced by the identical
    /// kernel over the identical per-pair pack, tiles never mix pairs
    /// (the stream is block-diagonal over the group), and the tile
    /// schedule is a function of the pair shapes alone.
    pub fn matmul_group(
        pairs: &[(&Matrix, &Matrix)],
        policy: ParallelPolicy,
    ) -> Vec<Matrix> {
        for (a, b) in pairs {
            assert_eq!(a.cols, b.rows, "matmul_group shape mismatch");
        }
        let packs: Vec<PackedPanels<f64>> = pairs
            .iter()
            .map(|(_, b)| PackedPanels::pack(&b.data, b.rows, b.cols))
            .collect();
        // one flat task list: (pair, row tile) in pair-major order — the
        // same fixed tiling matmul_with uses per pair
        let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
        for (p, (a, _)) in pairs.iter().enumerate() {
            for (i0, i1) in fixed_tiles(a.rows, MM_ROW_TILE) {
                tasks.push((p, i0, i1));
            }
        }
        let slabs = par_map(tasks, policy, |(p, i0, i1)| {
            Ok((p, pairs[p].0.matmul_rows(&packs[p], i0, i1, policy.fma)))
        })
        .expect("matmul_group worker thread panicked");
        // stitch per pair: par_map preserves task order, and tasks are
        // pair-major in ascending row-tile order
        let mut outs: Vec<Matrix> = pairs
            .iter()
            .map(|(a, b)| Matrix {
                rows: a.rows,
                cols: b.cols,
                data: Vec::with_capacity(a.rows * b.cols),
            })
            .collect();
        for (p, slab) in slabs {
            outs[p].data.extend_from_slice(&slab.data);
        }
        for (out, (a, b)) in outs.iter_mut().zip(pairs) {
            // zero-row/zero-col pairs produce no tasks; keep the shape
            out.data.resize(a.rows * b.cols, 0.0);
        }
        outs
    }

    /// GEMM restricted to output rows [i0, i1) over a prebuilt B pack: the
    /// shared kernel behind `matmul` (full range) and `matmul_with` (one
    /// tile per call, pack shared across tiles). Row independence makes
    /// every split bit-equivalent. Rows go through the 4-row register-
    /// tiled microkernel in quads, the ≤3 leftover rows through the 1-row
    /// kernel — per output element the accumulation order (ascending
    /// `(kk, p)`) is the same either way.
    fn matmul_rows(&self, pack: &PackedPanels<f64>, i0: usize, i1: usize, fma: FmaMode) -> Matrix {
        debug_assert!(i0 <= i1 && i1 <= self.rows);
        debug_assert_eq!(self.cols, pack.k);
        let (k, n) = (pack.k, pack.n);
        let mut out = Matrix::zeros(i1 - i0, n);
        if i1 == i0 || k == 0 || n == 0 {
            return out;
        }
        for (ki, &(kk, kb)) in pack.k_tiles.iter().enumerate() {
            for (ji, &(jj, jb)) in pack.j_tiles.iter().enumerate() {
                let panel = pack.panel(ki, ji);
                let mut i = i0;
                while i + 4 <= i1 {
                    let arow = |r: usize| {
                        let base = (i + r) * k + kk;
                        &self.data[base..base + kb]
                    };
                    let obase = (i - i0) * n + jj;
                    simd::gemm_tile_f64(
                        [arow(0), arow(1), arow(2), arow(3)],
                        panel,
                        jb,
                        &mut out.data[obase..],
                        n,
                        fma,
                    );
                    i += 4;
                }
                while i < i1 {
                    let base = i * k + kk;
                    let obase = (i - i0) * n + jj;
                    simd::gemm_row_f64(
                        &self.data[base..base + kb],
                        panel,
                        jb,
                        &mut out.data[obase..obase + jb],
                        fma,
                    );
                    i += 1;
                }
            }
        }
        out
    }

    /// self * v
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    /// selfᵀ * v — the row-major AXPY fold (`out += vᵢ · rowᵢ`, ascending
    /// i), dispatched through [`simd::axpy_f64`]; the SIMD path is
    /// bit-identical to the scalar loop (multiplication commutes exactly,
    /// each `out[j]` sees one add per row).
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            simd::axpy_f64(v[i], self.row(i), &mut out);
        }
        out
    }

    /// selfᵀ * self (Gram), exploiting symmetry: rank-4 updates of the
    /// upper triangle (4-row microkernel), mirrored at the end. Always
    /// runs the exact ([`FmaMode::Exact`]) kernels.
    pub fn gram(&self) -> Matrix {
        let mut g = self.gram_rows(0, self.rows, FmaMode::Exact);
        mirror_upper(&mut g);
        g
    }

    /// Threaded Gram: input rows sharded over fixed [`GRAM_ROW_CHUNK`]-high
    /// chunks, per-chunk partial Grams folded in chunk order. Bit-identical
    /// at any [`ParallelPolicy`] worker count (the chunk schedule and fold
    /// order never depend on `workers`); single-chunk inputs are
    /// bit-identical to [`Matrix::gram`] under the default
    /// [`FmaMode::Exact`]. `policy.fma` selects the contraction mode of
    /// the rank-4 lanes (Relaxed: envelope-bounded drift, worker
    /// invariance intact).
    pub fn gram_with(&self, policy: ParallelPolicy) -> Matrix {
        let chunks = fixed_tiles(self.rows, GRAM_ROW_CHUNK);
        if chunks.len() <= 1 {
            let mut g = self.gram_rows(0, self.rows, policy.fma);
            mirror_upper(&mut g);
            return g;
        }
        let partials = par_map(chunks, policy, |(lo, hi)| Ok(self.gram_rows(lo, hi, policy.fma)))
            .expect("gram worker thread panicked");
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for p in partials {
            for (gv, pv) in g.data.iter_mut().zip(&p.data) {
                *gv += pv;
            }
        }
        mirror_upper(&mut g);
        g
    }

    /// Upper-triangle Gram accumulation over rows [r0, r1) — the shared
    /// microkernel behind `gram` (full range, then mirrored) and
    /// `gram_with` (one chunk per call). No mirroring here so partials can
    /// be folded cheaply. Row quads go through [`simd::gram4_f64`] (the
    /// only kernel `fma` reaches); the ≤3 tail rows are plain AXPYs,
    /// always exact.
    fn gram_rows(&self, lo: usize, hi: usize, fma: FmaMode) -> Matrix {
        debug_assert!(lo <= hi && hi <= self.rows);
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        let rows = hi;
        let mut i = lo;
        while i + 4 <= rows {
            let r0 = &self.data[i * n..(i + 1) * n];
            let r1 = &self.data[(i + 1) * n..(i + 2) * n];
            let r2 = &self.data[(i + 2) * n..(i + 3) * n];
            let r3 = &self.data[(i + 3) * n..(i + 4) * n];
            for a in 0..n {
                let x = [r0[a], r1[a], r2[a], r3[a]];
                let grow = &mut g.data[a * n + a..(a + 1) * n];
                simd::gram4_f64(x, [&r0[a..], &r1[a..], &r2[a..], &r3[a..]], grow, fma);
            }
            i += 4;
        }
        while i < rows {
            let r = &self.data[i * n..(i + 1) * n];
            for a in 0..n {
                simd::axpy_f64(r[a], &r[a..], &mut g.data[a * n + a..(a + 1) * n]);
            }
            i += 1;
        }
        g
    }

    /// Copy of the rectangular block rows [r0, r1) × cols [c0, c1).
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let (rb, cb) = (r1 - r0, c1 - c0);
        let mut out = Matrix::zeros(rb, cb);
        for i in 0..rb {
            let base = (r0 + i) * self.cols + c0;
            out.data[i * cb..(i + 1) * cb]
                .copy_from_slice(&self.data[base..base + cb]);
        }
        out
    }

    /// Vertical stack.
    pub fn vstack(top: &Matrix, bottom: &Matrix) -> Matrix {
        assert_eq!(top.cols, bottom.cols);
        let mut data = Vec::with_capacity((top.rows + bottom.rows) * top.cols);
        data.extend_from_slice(&top.data);
        data.extend_from_slice(&bottom.data);
        Matrix { rows: top.rows + bottom.rows, cols: top.cols, data }
    }

    /// Frobenius norm √(Σ xᵢⱼ²).
    pub fn frobenius(&self) -> f64 {
        // lint: fold-order-pinned -- sequential left-to-right over the row-major buffer
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Largest element-wise absolute difference (shape-checked).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            // lint: fold-order-pinned -- max is order-free on the NaN-free abs values
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// GEMM panel depth (k-tile). 64×64 f64 = 32 KiB: one packed panel per L1.
/// A compile-time constant — part of the documented fixed-tile schedule
/// shared by [`Matrix::matmul`] and [`MatrixF32::matmul_widen`](super::MatrixF32::matmul_widen).
pub const KC: usize = 64;
/// GEMM panel width (j-tile); see [`KC`].
pub const NC: usize = 64;
/// Output-row tile height of the threaded GEMM. Fixed (never derived from
/// the worker count): the split schedule is part of the determinism
/// contract — `matmul_with`/`matmul_widen` shard output rows over exactly
/// these tiles whatever the [`ParallelPolicy`] says.
pub const MM_ROW_TILE: usize = 64;
/// Input-row chunk height of the threaded Gram fold (multiple of the
/// 4-row microkernel). Fixed for the same reason as [`MM_ROW_TILE`];
/// shared by `gram_with` and `gram_widen`.
pub const GRAM_ROW_CHUNK: usize = 512;

/// Read-only packed B panels of one GEMM call: B reorganized into
/// contiguous [`KC`]×[`NC`] tiles by a shape-fixed `(kk, jj)` walk, built
/// once and then shared by every output row tile (and every worker thread)
/// of the call. Packing is pure data movement — the multiply/accumulate
/// order of the consuming kernels is untouched, which is why the shared
/// pack preserves the bit-identity contract. Generic over the element type
/// so the f64 GEMM and the f32-wire widen GEMM reuse one layout.
///
/// # Panel-shape contract
///
/// The [`simd`](super::simd) microkernels read panels with unchecked
/// lane-contiguous loads, so the shape invariants below are **asserted in
/// release builds** (at the crate-internal `pack` constructor and at every
/// `panel` fetch, plus a `panel.len() == kb·jb` re-check inside each
/// microkernel call) rather than assumed:
///
/// * tile boundaries come from [`fixed_tiles`]`(k, KC)` / `(n, NC)`:
///   every k-tile is exactly [`KC`] rows and every j-tile exactly [`NC`]
///   columns **except possibly the last one of each axis**, which holds
///   the remainder (`1..=KC` / `1..=NC` — never empty);
/// * panel `(ki, ji)` is stored at `panels[ki · j_tiles.len() + ji]` as a
///   dense row-major `kb × jb` slice (`kb = k_tiles[ki].1`,
///   `jb = j_tiles[ji].1`): element `(p, j)` lives at `p·jb + j`, i.e.
///   the panel's row stride is `jb` itself — there is **no padding**, so
///   a consumer must use the tile's own `jb`, never [`NC`];
/// * the pack source must be a dense row-major `k × n` buffer
///   (`data.len() == k·n`, asserted).
pub struct PackedPanels<T> {
    /// Depth (rows of B) the pack was built from.
    pub(crate) k: usize,
    /// Width (cols of B) the pack was built from.
    pub(crate) n: usize,
    /// `(kk, kb)` per k-tile: start row and height.
    pub(crate) k_tiles: Vec<(usize, usize)>,
    /// `(jj, jb)` per j-tile: start col and width.
    pub(crate) j_tiles: Vec<(usize, usize)>,
    /// Panel `(ki, ji)` at `panels[ki * j_tiles.len() + ji]`, row-major
    /// `kb × jb` within the panel.
    panels: Vec<Vec<T>>,
}

impl<T: Copy> PackedPanels<T> {
    /// Pack a row-major k×n buffer into panels (one allocation per panel,
    /// `(kk, jj)` ascending — the same walk the consuming kernels take).
    /// Asserts the panel-shape contract (see the type docs) — including in
    /// release builds, since the microkernels consume panels unchecked.
    pub(crate) fn pack(data: &[T], k: usize, n: usize) -> PackedPanels<T> {
        assert_eq!(
            data.len(),
            k * n,
            "PackedPanels::pack: buffer len {} != k*n = {}*{}",
            data.len(),
            k,
            n
        );
        let k_tiles: Vec<(usize, usize)> =
            fixed_tiles(k, KC).into_iter().map(|(lo, hi)| (lo, hi - lo)).collect();
        let j_tiles: Vec<(usize, usize)> =
            fixed_tiles(n, NC).into_iter().map(|(lo, hi)| (lo, hi - lo)).collect();
        for (t, &(_, kb)) in k_tiles.iter().enumerate() {
            assert!(
                (kb == KC || t + 1 == k_tiles.len()) && kb > 0 && kb <= KC,
                "PackedPanels::pack: interior k-tile {t} has height {kb} != KC={KC}"
            );
        }
        for (t, &(_, jb)) in j_tiles.iter().enumerate() {
            assert!(
                (jb == NC || t + 1 == j_tiles.len()) && jb > 0 && jb <= NC,
                "PackedPanels::pack: interior j-tile {t} has width {jb} != NC={NC}"
            );
        }
        let mut panels = Vec::with_capacity(k_tiles.len() * j_tiles.len());
        for &(kk, kb) in &k_tiles {
            for &(jj, jb) in &j_tiles {
                let mut p = Vec::with_capacity(kb * jb);
                for r in 0..kb {
                    let base = (kk + r) * n + jj;
                    p.extend_from_slice(&data[base..base + jb]);
                }
                assert_eq!(
                    p.len(),
                    kb * jb,
                    "PackedPanels::pack: panel at (kk={kk}, jj={jj}) is not dense {kb}x{jb}"
                );
                panels.push(p);
            }
        }
        PackedPanels { k, n, k_tiles, j_tiles, panels }
    }

    /// The packed `kb × jb` panel at tile coordinates `(ki, ji)`. Asserts
    /// the coordinates are in range and the panel has its contracted
    /// `kb·jb` length (release builds included — the consuming
    /// microkernels do unchecked loads at `p·jb + j`).
    #[inline]
    pub(crate) fn panel(&self, ki: usize, ji: usize) -> &[T] {
        assert!(
            ki < self.k_tiles.len() && ji < self.j_tiles.len(),
            "PackedPanels::panel: tile ({ki}, {ji}) out of range ({}x{} tiles)",
            self.k_tiles.len(),
            self.j_tiles.len()
        );
        let p = &self.panels[ki * self.j_tiles.len() + ji];
        assert_eq!(
            p.len(),
            self.k_tiles[ki].1 * self.j_tiles[ji].1,
            "PackedPanels::panel: panel ({ki}, {ji}) violates the kb*jb contract"
        );
        p
    }
}

/// Mirror the accumulated upper triangle into the lower one (shared by
/// the f64 Gram and the widen Gram in `matrix32`).
pub(crate) fn mirror_upper(g: &mut Matrix) {
    let n = g.cols;
    for a in 0..n {
        for b in 0..a {
            g[(a, b)] = g[(b, a)];
        }
    }
}

/// Plain sequential dot product (ascending index order — the accumulation
/// order every matvec-shaped path in the substrate shares).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    // lint: fold-order-pinned -- sequential left-to-right in ascending index order
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(4, 4, &mut rng);
        let i = Matrix::identity(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    #[should_panic(expected = "dot: length mismatch")]
    fn dot_rejects_length_mismatch_in_release() {
        dot(&[1.0, 2.0], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn gram_equals_explicit() {
        let mut rng = Rng::new(3);
        let a = Matrix::random(10, 4, &mut rng);
        let g = a.gram();
        let explicit = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&explicit) < 1e-12);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(5, 3, &mut rng);
        let v = vec![1.0, -2.0, 0.5];
        let mv = a.matvec(&v);
        let vm = Matrix::from_vec(3, 1, v.clone());
        let full = a.matmul(&vm);
        for i in 0..5 {
            assert!((mv[i] - full[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn t_matvec_is_transpose_matvec() {
        let mut rng = Rng::new(5);
        let a = Matrix::random(6, 4, &mut rng);
        let v: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let got = a.t_matvec(&v);
        let want = a.transpose().matvec(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn vstack_shapes() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 3);
        let s = Matrix::vstack(&a, &b);
        assert_eq!((s.rows, s.cols), (6, 3));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn matmul_shape_checked() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn matmul_group_bit_identical_to_per_pair_matmul() {
        // varied shapes in one group, including the fleet's M×1 predict
        // columns and a tall pair spanning several MM_ROW_TILE tiles
        let mut rng = Rng::new(11);
        let shapes = [(3usize, 5usize, 2usize), (70, 8, 1), (1, 4, 4), (200, 12, 1)];
        let mats: Vec<(Matrix, Matrix)> = shapes
            .iter()
            .map(|&(m, k, n)| (Matrix::random(m, k, &mut rng), Matrix::random(k, n, &mut rng)))
            .collect();
        let pairs: Vec<(&Matrix, &Matrix)> = mats.iter().map(|(a, b)| (a, b)).collect();
        for workers in [1usize, 2, 4, 8] {
            let policy = ParallelPolicy::with_workers(workers);
            let got = Matrix::matmul_group(&pairs, policy);
            assert_eq!(got.len(), pairs.len());
            for (g, (a, b)) in got.iter().zip(&pairs) {
                let want = a.matmul_with(b, policy);
                assert_eq!(g, &want, "group GEMM diverged at workers={workers}");
                assert_eq!(g, &a.matmul(b), "group GEMM diverged from matmul");
            }
        }
    }

    #[test]
    fn matmul_group_handles_empty_and_degenerate_pairs() {
        assert!(Matrix::matmul_group(&[], ParallelPolicy::with_workers(4)).is_empty());
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 2);
        let c = Matrix::zeros(2, 3);
        let d = Matrix::zeros(3, 0);
        let out = Matrix::matmul_group(&[(&a, &b), (&c, &d)], ParallelPolicy::with_workers(2));
        assert_eq!((out[0].rows, out[0].cols), (0, 2));
        assert_eq!((out[1].rows, out[1].cols), (2, 0));
    }

    /// Unblocked ijk reference (the seed implementation, minus the
    /// zero-skip branch) for validating the tiled kernel.
    fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows);
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                let v = a[(i, k)];
                for j in 0..b.cols {
                    out[(i, j)] += v * b[(k, j)];
                }
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_bit_identical_to_naive() {
        // shapes straddling the 64-wide tile boundaries
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 5, 3), (64, 64, 64),
            (65, 64, 63), (100, 129, 65), (3, 200, 130)]
        {
            let mut rng = Rng::new((m * 1000 + k * 10 + n) as u64);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let blocked = a.matmul(&b);
            let naive = matmul_naive(&a, &b);
            assert_eq!(blocked, naive, "{m}x{k}x{n} not bit-identical");
        }
    }

    #[test]
    fn matmul_propagates_non_finite() {
        // 0 * inf must be NaN — the seed's zero-skip branch dropped it
        let a = Matrix::from_vec(1, 2, vec![0.0, 1.0]);
        let b = Matrix::from_vec(2, 1, vec![f64::INFINITY, 2.0]);
        let c = a.matmul(&b);
        assert!(c[(0, 0)].is_nan(), "0*inf skipped: {}", c[(0, 0)]);
        let g = Matrix::from_vec(2, 2, vec![0.0, f64::INFINITY, 1.0, 1.0]).gram();
        assert!(g.data().iter().any(|v| v.is_nan()), "gram dropped NaN");
    }

    #[test]
    fn threaded_matmul_bit_identical_to_sequential() {
        // spans several MM_ROW_TILE tiles so the threading actually splits
        for &(m, k, n) in &[(129usize, 40usize, 33usize), (256, 64, 64), (300, 7, 130)] {
            let mut rng = Rng::new((m + k + n) as u64);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let seq = a.matmul(&b);
            for workers in [1usize, 2, 4, 8] {
                let par = a.matmul_with(&b, ParallelPolicy::with_workers(workers));
                assert_eq!(par, seq, "{m}x{k}x{n} differs at workers={workers}");
            }
        }
    }

    #[test]
    fn threaded_matmul_degenerate_shapes() {
        let p = ParallelPolicy::with_workers(4);
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        assert_eq!(a.matmul_with(&b, p), a.matmul(&b));
        let a = Matrix::from_vec(1, 1, vec![2.0]);
        let b = Matrix::from_vec(1, 1, vec![-3.0]);
        assert_eq!(a.matmul_with(&b, p)[(0, 0)], -6.0);
    }

    #[test]
    fn threaded_gram_worker_invariant_and_close_to_explicit() {
        // > 1 chunk so the fold is exercised
        let mut rng = Rng::new(42);
        let a = Matrix::random(GRAM_ROW_CHUNK * 2 + 37, 9, &mut rng);
        let base = a.gram_with(ParallelPolicy::sequential());
        for workers in [2usize, 4, 8] {
            let g = a.gram_with(ParallelPolicy::with_workers(workers));
            assert_eq!(g, base, "gram bits differ at workers={workers}");
        }
        let explicit = a.transpose().matmul(&a);
        assert!(base.max_abs_diff(&explicit) < 1e-9);
    }

    #[test]
    fn gram_with_single_chunk_matches_gram() {
        let mut rng = Rng::new(43);
        let a = Matrix::random(GRAM_ROW_CHUNK - 1, 6, &mut rng);
        assert_eq!(a.gram_with(ParallelPolicy::with_workers(8)), a.gram());
    }

    #[test]
    fn packed_panels_shape_contract() {
        // shapes straddling the KC/NC boundaries: all interior tiles full,
        // only the last tile of each axis short, every panel dense kb×jb
        for &(k, n) in &[(1usize, 1usize), (63, 65), (64, 64), (65, 129), (200, 7)] {
            let data: Vec<f64> = (0..k * n).map(|i| i as f64).collect();
            let pack = PackedPanels::pack(&data, k, n);
            assert_eq!(pack.k_tiles.iter().map(|&(_, kb)| kb).sum::<usize>(), k);
            assert_eq!(pack.j_tiles.iter().map(|&(_, jb)| jb).sum::<usize>(), n);
            for (ki, &(kk, kb)) in pack.k_tiles.iter().enumerate() {
                for (ji, &(jj, jb)) in pack.j_tiles.iter().enumerate() {
                    let p = pack.panel(ki, ji);
                    assert_eq!(p.len(), kb * jb, "{k}x{n} panel ({ki},{ji})");
                    // element (p, j) of the panel is B[kk+p, jj+j]
                    for r in 0..kb {
                        for c in 0..jb {
                            assert_eq!(p[r * jb + c], ((kk + r) * n + jj + c) as f64);
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "buffer len")]
    fn packed_panels_rejects_misshapen_buffer() {
        let data = vec![0.0f64; 11]; // not 3*4
        let _ = PackedPanels::pack(&data, 3, 4);
    }

    #[test]
    fn gram_matches_scalar_kernel_oracle_bitwise() {
        // pin the dispatched gram (SIMD on AVX2 hosts) to an oracle built
        // from the *scalar* microkernels: cross-ISA bit-identity at the
        // Matrix level, tail rows included
        for rows in [1usize, 3, 4, 5, 8, 11] {
            let mut rng = Rng::new(rows as u64 + 500);
            let a = Matrix::random(rows, 9, &mut rng);
            let n = a.cols;
            let mut g = Matrix::zeros(n, n);
            let mut i = 0;
            while i + 4 <= rows {
                let r: Vec<&[f64]> = (0..4).map(|r| a.row(i + r)).collect();
                for c in 0..n {
                    let x = [r[0][c], r[1][c], r[2][c], r[3][c]];
                    simd::gram4_f64_scalar(
                        x,
                        [&r[0][c..], &r[1][c..], &r[2][c..], &r[3][c..]],
                        &mut g.data[c * n + c..(c + 1) * n],
                    );
                }
                i += 4;
            }
            while i < rows {
                let r = a.row(i);
                for c in 0..n {
                    simd::axpy_f64_scalar(r[c], &r[c..], &mut g.data[c * n + c..(c + 1) * n]);
                }
                i += 1;
            }
            mirror_upper(&mut g);
            assert_eq!(a.gram(), g, "rows={rows}: dispatched gram != scalar oracle");
        }
    }

    #[test]
    fn gram_tail_rows_covered() {
        // rows % 4 != 0 exercises the scalar tail after the microkernel
        for rows in [1usize, 2, 3, 4, 5, 7, 9] {
            let mut rng = Rng::new(rows as u64 + 100);
            let a = Matrix::random(rows, 6, &mut rng);
            let g = a.gram();
            let explicit = a.transpose().matmul(&a);
            assert!(g.max_abs_diff(&explicit) < 1e-12, "rows={rows}");
        }
    }
}
