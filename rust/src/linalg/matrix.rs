//! Row-major dense f64 matrix. Deliberately small: exactly the operations
//! the ELM solve and the tests need, no general-purpose BLAS ambitions.
//! (f64 so the rust-side solves do not add float error on top of the f32
//! artifacts; H blocks are widened on accumulation.)

use std::fmt;

use crate::util::rng::Rng;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Matrix { rows: r, cols: c, data: rows.concat() }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other  (naive ijk with row-major accumulation: fine at M<=128)
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// self * v
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    /// selfᵀ * v
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            let vi = v[i];
            for j in 0..self.cols {
                out[j] += r[j] * vi;
            }
        }
        out
    }

    /// selfᵀ * self (Gram), exploiting symmetry.
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for i in 0..self.rows {
            let r = self.row(i);
            for a in 0..n {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                for b in a..n {
                    g[(a, b)] += ra * r[b];
                }
            }
        }
        for a in 0..n {
            for b in 0..a {
                g[(a, b)] = g[(b, a)];
            }
        }
        g
    }

    /// Vertical stack.
    pub fn vstack(top: &Matrix, bottom: &Matrix) -> Matrix {
        assert_eq!(top.cols, bottom.cols);
        let mut data = Vec::with_capacity((top.rows + bottom.rows) * top.cols);
        data.extend_from_slice(&top.data);
        data.extend_from_slice(&bottom.data);
        Matrix { rows: top.rows + bottom.rows, cols: top.cols, data }
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(4, 4, &mut rng);
        let i = Matrix::identity(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(3, 5, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_equals_explicit() {
        let mut rng = Rng::new(3);
        let a = Matrix::random(10, 4, &mut rng);
        let g = a.gram();
        let explicit = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&explicit) < 1e-12);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(5, 3, &mut rng);
        let v = vec![1.0, -2.0, 0.5];
        let mv = a.matvec(&v);
        let vm = Matrix::from_vec(3, 1, v.clone());
        let full = a.matmul(&vm);
        for i in 0..5 {
            assert!((mv[i] - full[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn t_matvec_is_transpose_matvec() {
        let mut rng = Rng::new(5);
        let a = Matrix::random(6, 4, &mut rng);
        let v: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let got = a.t_matvec(&v);
        let want = a.transpose().matvec(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn vstack_shapes() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 3);
        let s = Matrix::vstack(&a, &b);
        assert_eq!((s.rows, s.cols), (6, 3));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn matmul_shape_checked() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
