//! `MatrixF32` — f32-storage operands with **accumulate-widen** kernels:
//! multiply f32 panels, accumulate into f64. This is the CPU mirror of the
//! paper's wire format (H blocks are f32 in the artifact ABI while β is
//! solved in higher precision): storing the wide GEMM/Gram operands in f32
//! halves their memory traffic, and widening at the multiply keeps the
//! solve's accumulation in f64.
//!
//! # Kernel contract (schedule, order, drift)
//!
//! Every widen kernel runs the **same fixed-tile schedule as its f64
//! twin** — [`KC`](super::matrix::KC)×[`NC`](super::matrix::NC) packed B
//! panels built once per call and shared read-only by all row tiles,
//! [`MM_ROW_TILE`]-high output row tiles for the GEMM,
//! [`GRAM_ROW_CHUNK`]-high input chunks folded in chunk order
//! for the Gram — and accumulates each output element's terms in the same
//! ascending `(kk, p)` order. Consequences, each pinned by tests:
//!
//! * **Worker invariance** — results are bit-identical at any
//!   [`ParallelPolicy`] worker count, exactly like the f64 paths.
//! * **Exactness on f32 sources** — an f32×f32 product widened to f64 is
//!   exact (24+24 significand bits < 53), so when the operands' values are
//!   exactly f32-representable the widen kernels return **bit-identical**
//!   results to the f64 kernels on the widened operands: 0 ulp kernel
//!   drift. This covers `lift_wx` (both operands come from f32 buffers)
//!   and the H blocks of the recurrent architectures (tanh outputs cast
//!   from f32).
//! * **Bounded drift on f64 sources** — when a [`Matrix`] is rounded to
//!   f32 storage ([`MatrixF32::from_matrix`]), the only error is that one
//!   storage rounding (≤ 2⁻²⁴ relative per operand, values within normal
//!   f32 range). Per element, versus the f64 reference on the unrounded
//!   operands: `|Δ[i,j]| ≤ 2⁻²³·(|A|·|B|)[i,j]` for `matmul_widen` (two
//!   rounded factors per term) and `|Δ[a,b]| ≤ 2⁻²³·(|A|ᵀ·|A|)[a,b]` for
//!   `gram_widen` — i.e. at most ~2 f32 ulps scaled by the absolute-value
//!   product, independent of the accumulation length because the
//!   accumulator stays f64. The property suite asserts this element-wise
//!   bound on random inputs.

#![forbid(unsafe_code)]

use std::fmt;

use super::matrix::{mirror_upper, Matrix, PackedPanels, GRAM_ROW_CHUNK, MM_ROW_TILE};
use super::policy::{fixed_tiles, par_map, ParallelPolicy};
use super::simd::{self, FmaMode};

/// Row-major dense f32 matrix: the storage/wire type of the
/// mixed-precision paths. Products of its entries are accumulated in f64
/// by the `*_widen` kernels (see the module docs for the full contract).
#[derive(Clone, PartialEq)]
pub struct MatrixF32 {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for MatrixF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "MatrixF32 {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl MatrixF32 {
    /// All-zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> MatrixF32 {
        MatrixF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an owned row-major f32 buffer (length must equal rows·cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> MatrixF32 {
        assert_eq!(data.len(), rows * cols);
        MatrixF32 { rows, cols, data }
    }

    /// Copy a row-major f32 slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[f32]) -> MatrixF32 {
        assert_eq!(data.len(), rows * cols);
        MatrixF32 { rows, cols, data: data.to_vec() }
    }

    /// Round an f64 matrix to f32 storage (round-to-nearest; one rounding
    /// of ≤ 2⁻²⁴ relative per entry for values in normal f32 range — the
    /// entirety of the widen kernels' drift versus the f64 reference).
    pub fn from_matrix(a: &Matrix) -> MatrixF32 {
        MatrixF32 {
            rows: a.rows,
            cols: a.cols,
            data: a.data().iter().map(|&x| x as f32).collect(),
        }
    }

    /// Widen back to f64 (exact).
    pub fn to_f64(&self) -> Matrix {
        Matrix::from_f32(self.rows, self.cols, &self.data)
    }

    /// The row-major backing buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access to the row-major backing buffer (the f32-born
    /// `h_block` kernels fill blocks through this).
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Row `i` as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable contiguous slice (block-assembly helper).
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self * other with f32 operands and f64 accumulation — the
    /// accumulate-widen GEMM.
    ///
    /// Schedule: B packed once into shared read-only
    /// [`KC`](super::matrix::KC)×[`NC`](super::matrix::NC)
    /// [`PackedPanels`], output rows sharded over fixed
    /// [`MM_ROW_TILE`]-high tiles across `policy.workers` threads, each
    /// element's k-terms accumulated in ascending `(kk, p)` order by the
    /// register-tiled widen microkernels ([`simd::gemm_tile_widen`] /
    /// [`simd::gemm_row_widen`] — 8-lane f32 wire on AVX2, the pre-SIMD
    /// widening AXPY on the scalar path). Bit-identical at any worker count;
    /// bit-identical to `self.to_f64().matmul(&other.to_f64())` (0 ulp
    /// kernel drift — every f32×f32 product is exact in f64); within
    /// `2⁻²³·(|A|·|B|)[i,j]` of the f64 reference when the operands were
    /// rounded from f64 (see the module contract).
    pub fn matmul_widen(&self, other: &MatrixF32, policy: ParallelPolicy) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul_widen shape mismatch");
        self.matmul_widen_packed(&other.pack_panels(), policy)
    }

    /// The B-operand side of [`MatrixF32::matmul_widen`] as a reusable
    /// artifact: pack `self` once into the read-only
    /// [`KC`](super::matrix::KC)×[`NC`](super::matrix::NC)
    /// [`PackedPanels`] layout the widen GEMM consumes. Callers that
    /// multiply **many different A operands against the same B** (the FC
    /// recurrence's per-timestep coupling GEMMs reuse each `A_kᵀ` up to
    /// `q−k` times) build the pack once and call
    /// [`MatrixF32::matmul_widen_packed`] per product, instead of paying
    /// the pack on every call. Packing is pure data movement, so results
    /// are bit-identical to the pack-per-call path.
    pub fn pack_panels(&self) -> PackedPanels<f32> {
        PackedPanels::pack(&self.data, self.rows, self.cols)
    }

    /// [`MatrixF32::matmul_widen`] against a prebuilt B pack (see
    /// [`MatrixF32::pack_panels`]): `self · B` where `pack` was built from
    /// B. Identical schedule, arithmetic order, and determinism contract
    /// as `matmul_widen` — only the packing cost moves to the caller.
    /// `self.cols` must equal the packed operand's row count (asserted).
    pub fn matmul_widen_packed(&self, pack: &PackedPanels<f32>, policy: ParallelPolicy) -> Matrix {
        assert_eq!(
            self.cols,
            pack.k,
            "matmul_widen_packed: A cols {} != packed B rows {}",
            self.cols,
            pack.k
        );
        let (m, n) = (self.rows, pack.n);
        if policy.workers <= 1 || m < 2 * MM_ROW_TILE {
            return self.matmul_rows_widen(pack, 0, m, policy.fma);
        }
        let tiles = fixed_tiles(m, MM_ROW_TILE);
        let slabs =
            par_map(tiles, policy, |(i0, i1)| Ok(self.matmul_rows_widen(pack, i0, i1, policy.fma)))
                .expect("matmul_widen worker thread panicked");
        let mut data = Vec::with_capacity(m * n);
        for slab in slabs {
            data.extend_from_slice(slab.data());
        }
        Matrix::from_vec(m, n, data)
    }

    /// Widen GEMM restricted to output rows [i0, i1) over a prebuilt
    /// shared pack — the exact structural mirror of the f64
    /// `Matrix::matmul_rows` (4-row register tiles + 1-row tails through
    /// the [`simd`](super::simd) widen microkernels), with the widening at
    /// the multiply.
    fn matmul_rows_widen(
        &self,
        pack: &PackedPanels<f32>,
        i0: usize,
        i1: usize,
        fma: FmaMode,
    ) -> Matrix {
        debug_assert!(i0 <= i1 && i1 <= self.rows);
        debug_assert_eq!(self.cols, pack.k);
        let (k, n) = (pack.k, pack.n);
        let mut out = Matrix::zeros(i1 - i0, n);
        if i1 == i0 || k == 0 || n == 0 {
            return out;
        }
        for (ki, &(kk, kb)) in pack.k_tiles.iter().enumerate() {
            for (ji, &(jj, jb)) in pack.j_tiles.iter().enumerate() {
                let panel = pack.panel(ki, ji);
                let mut i = i0;
                while i + 4 <= i1 {
                    let arow = |r: usize| {
                        let base = (i + r) * k + kk;
                        &self.data[base..base + kb]
                    };
                    let obase = (i - i0) * n + jj;
                    simd::gemm_tile_widen(
                        [arow(0), arow(1), arow(2), arow(3)],
                        panel,
                        jb,
                        &mut out.data_mut()[obase..],
                        n,
                        fma,
                    );
                    i += 4;
                }
                while i < i1 {
                    let base = i * k + kk;
                    let obase = (i - i0) * n + jj;
                    simd::gemm_row_widen(
                        &self.data[base..base + kb],
                        panel,
                        jb,
                        &mut out.data_mut()[obase..obase + jb],
                        fma,
                    );
                    i += 1;
                }
            }
        }
        out
    }

    /// selfᵀ * self with f32 rows and f64 accumulation — the
    /// accumulate-widen Gram.
    ///
    /// Schedule: input rows sharded over fixed [`GRAM_ROW_CHUNK`]-high
    /// chunks, per-chunk partial Grams (4-row rank-4 microkernel, upper
    /// triangle) folded in chunk order, mirrored at the end — structurally
    /// identical to `Matrix::gram_with`. Bit-identical at any worker
    /// count; bit-identical to `self.to_f64().gram_with(policy)` (exact
    /// products); within `2⁻²³·(|A|ᵀ·|A|)[a,b]` of the f64 reference on
    /// f64-rounded operands.
    pub fn gram_widen(&self, policy: ParallelPolicy) -> Matrix {
        let chunks = fixed_tiles(self.rows, GRAM_ROW_CHUNK);
        if chunks.len() <= 1 {
            let mut g = self.gram_rows_widen(0, self.rows, policy.fma);
            mirror_upper(&mut g);
            return g;
        }
        let partials =
            par_map(chunks, policy, |(lo, hi)| Ok(self.gram_rows_widen(lo, hi, policy.fma)))
                .expect("gram_widen worker thread panicked");
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for p in partials {
            for (gv, pv) in g.data_mut().iter_mut().zip(p.data()) {
                *gv += pv;
            }
        }
        mirror_upper(&mut g);
        g
    }

    /// Upper-triangle widen-Gram over rows [lo, hi) — the f32-wire mirror
    /// of `Matrix::gram_rows` (4-row [`simd::gram4_widen`] microkernel,
    /// exact AXPY tail rows, f64 accumulator, no mirroring so partials
    /// fold cheaply).
    fn gram_rows_widen(&self, lo: usize, hi: usize, fma: FmaMode) -> Matrix {
        debug_assert!(lo <= hi && hi <= self.rows);
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        let rows = hi;
        let mut i = lo;
        while i + 4 <= rows {
            let r0 = &self.data[i * n..(i + 1) * n];
            let r1 = &self.data[(i + 1) * n..(i + 2) * n];
            let r2 = &self.data[(i + 2) * n..(i + 3) * n];
            let r3 = &self.data[(i + 3) * n..(i + 4) * n];
            for a in 0..n {
                let x = [r0[a], r1[a], r2[a], r3[a]];
                let grow = &mut g.data_mut()[a * n + a..(a + 1) * n];
                simd::gram4_widen(x, [&r0[a..], &r1[a..], &r2[a..], &r3[a..]], grow, fma);
            }
            i += 4;
        }
        while i < rows {
            let r = &self.data[i * n..(i + 1) * n];
            for a in 0..n {
                simd::axpy_widen(r[a], &r[a..], &mut g.data_mut()[a * n + a..(a + 1) * n]);
            }
            i += 1;
        }
        g
    }

    /// self * v with f32 matrix entries widened at the multiply and an f64
    /// accumulator, ascending index order — the widen mirror of
    /// `Matrix::matvec` (bit-identical to it on f32-representable
    /// entries).
    pub fn matvec_widen(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            // lint: fold-order-pinned -- per-row sequential left-to-right, matching Matrix::matvec
            .map(|i| self.row(i).iter().zip(v).map(|(&h, &x)| h as f64 * x).sum())
            .collect()
    }

    /// selfᵀ * v, widening at the multiply, f64 accumulator, same row-major
    /// sweep (and therefore accumulation order) as `Matrix::t_matvec` —
    /// dispatched through [`simd::axpy_wx`] (bit-identical to the scalar
    /// fold on every ISA path).
    pub fn t_matvec_widen(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            simd::axpy_wx(v[i], self.row(i), &mut out);
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for MatrixF32 {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatrixF32 {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_f32(rows: usize, cols: usize, seed: u64) -> MatrixF32 {
        let mut rng = Rng::new(seed);
        MatrixF32::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal() as f32).collect(),
        )
    }

    #[test]
    fn widen_matmul_bit_identical_to_f64_on_f32_sources() {
        // operands born f32: every product is exact in f64, so the widen
        // kernel must reproduce the f64 tiled GEMM bit for bit
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 5, 3), (65, 64, 63),
            (100, 129, 65), (3, 200, 130)]
        {
            let a = random_f32(m, k, (m * 31 + k * 7 + n) as u64);
            let b = random_f32(k, n, (m + k * 5 + n * 11) as u64);
            let widen = a.matmul_widen(&b, ParallelPolicy::sequential());
            let f64ref = a.to_f64().matmul(&b.to_f64());
            assert_eq!(widen, f64ref, "{m}x{k}x{n} widen != widened f64");
        }
    }

    #[test]
    fn widen_matmul_bit_identical_across_worker_counts() {
        for &(m, k, n) in &[(129usize, 40usize, 33usize), (256, 64, 64), (300, 7, 130)] {
            let a = random_f32(m, k, (m + k + n) as u64);
            let b = random_f32(k, n, (m * 2 + k + n) as u64);
            let seq = a.matmul_widen(&b, ParallelPolicy::sequential());
            for workers in [1usize, 2, 4, 8] {
                let par = a.matmul_widen(&b, ParallelPolicy::with_workers(workers));
                assert_eq!(par, seq, "{m}x{k}x{n} differs at workers={workers}");
            }
        }
    }

    #[test]
    fn widen_gram_bit_identical_to_f64_and_worker_invariant() {
        let a = random_f32(GRAM_ROW_CHUNK * 2 + 37, 9, 42);
        let base = a.gram_widen(ParallelPolicy::sequential());
        assert_eq!(base, a.to_f64().gram_with(ParallelPolicy::sequential()));
        for workers in [2usize, 4, 8] {
            let g = a.gram_widen(ParallelPolicy::with_workers(workers));
            assert_eq!(g, base, "gram_widen bits differ at workers={workers}");
        }
        // single chunk degenerate
        let s = random_f32(17, 6, 43);
        assert_eq!(
            s.gram_widen(ParallelPolicy::with_workers(8)),
            s.to_f64().gram(),
        );
    }

    #[test]
    fn widen_matvecs_match_f64_on_f32_sources() {
        let a = random_f32(23, 7, 5);
        let v: Vec<f64> = (0..7).map(|i| (i as f64 * 0.3).cos()).collect();
        assert_eq!(a.matvec_widen(&v), a.to_f64().matvec(&v));
        let w: Vec<f64> = (0..23).map(|i| (i as f64 * 0.17).sin()).collect();
        assert_eq!(a.t_matvec_widen(&w), a.to_f64().t_matvec(&w));
    }

    #[test]
    fn widen_matmul_propagates_non_finite() {
        // 0 × ∞ must still produce NaN through the widen path
        let a = MatrixF32::from_vec(1, 2, vec![0.0, 1.0]);
        let b = MatrixF32::from_vec(2, 1, vec![f32::INFINITY, 2.0]);
        let c = a.matmul_widen(&b, ParallelPolicy::sequential());
        assert!(c[(0, 0)].is_nan(), "0*inf skipped: {}", c[(0, 0)]);
        let g = MatrixF32::from_vec(2, 2, vec![0.0, f32::INFINITY, 1.0, 1.0])
            .gram_widen(ParallelPolicy::sequential());
        assert!(g.data().iter().any(|v| v.is_nan()), "gram_widen dropped NaN");
    }

    #[test]
    fn packed_reuse_bit_identical_to_pack_per_call() {
        // one B pack shared by several A operands (the FC coupling-GEMM
        // pattern) must reproduce the pack-per-call products bit for bit
        let b = random_f32(40, 33, 77);
        let pack = b.pack_panels();
        for seed in 0..4u64 {
            let a = random_f32(13 + 7 * seed as usize, 40, 100 + seed);
            let per_call = a.matmul_widen(&b, ParallelPolicy::sequential());
            let reused = a.matmul_widen_packed(&pack, ParallelPolicy::sequential());
            assert_eq!(reused, per_call, "seed={seed}");
        }
    }

    #[test]
    #[should_panic(expected = "matmul_widen_packed")]
    fn packed_shape_mismatch_rejected() {
        let b = random_f32(8, 5, 1);
        let a = random_f32(3, 9, 2); // cols 9 != packed rows 8
        let _ = a.matmul_widen_packed(&b.pack_panels(), ParallelPolicy::sequential());
    }

    #[test]
    fn round_trip_and_indexing() {
        let mut m = MatrixF32::zeros(2, 3);
        m[(1, 2)] = 4.5;
        assert_eq!(m[(1, 2)], 4.5);
        assert_eq!(m.row(1), &[0.0, 0.0, 4.5]);
        let f = m.to_f64();
        assert_eq!(f[(1, 2)], 4.5);
        assert_eq!(MatrixF32::from_matrix(&f), m);
    }
}
