//! Dense linear algebra substrate for the ELM solve (β = H†Y, §4.2).
//!
//! The paper replaces the explicit Moore-Penrose pseudo-inverse with a QR
//! factorization + back-substitution. We provide:
//!
//! * [`qr`] — Householder QR (the reference factorization),
//! * [`tsqr`] — communication-avoiding tall-skinny QR over row blocks (the
//!   "parallel QR" of the abstract; the coordinator's streaming accumulator),
//! * [`cholesky`] — SPD factorization for the ridge-regularized normal
//!   equations `(HᵀH + λI) β = HᵀY` (rank-deficiency fallback),
//! * [`solve`] — triangular solves and the user-facing least-squares entry
//!   points.

pub mod cholesky;
pub mod matrix;
pub mod qr;
pub mod solve;
pub mod tsqr;

pub use cholesky::cholesky_solve;
pub use matrix::Matrix;
pub use qr::{householder_qr, QrFactors};
pub use solve::{lstsq_qr, lstsq_ridge, solve_lower_triangular, solve_upper_triangular};
pub use tsqr::TsqrAccumulator;
