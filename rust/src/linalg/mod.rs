//! Dense linear algebra substrate for the ELM solve (β = H†Y, §4.2) —
//! blocked, multi-threaded, and mixed-precision on the hot paths.
//!
//! The paper replaces the explicit Moore-Penrose pseudo-inverse with a QR
//! factorization + back-substitution. We provide:
//!
//! * [`matrix`] — cache-tiled GEMM (packed 64×64 B panels built once per
//!   call and shared read-only by every row tile, 4-wide inner kernel)
//!   and a rank-4 Gram microkernel,
//! * [`matrix32`] — [`MatrixF32`], the f32-storage operand type, with the
//!   accumulate-widen kernels `matmul_widen`/`gram_widen` (f32 wire, f64
//!   accumulation — the paper's H-block format; same fixed-tile schedules
//!   as the f64 kernels, so the determinism contract carries over
//!   unchanged),
//! * [`qr`] — blocked panel Householder QR in the compact-WY
//!   representation (trailing updates as GEMMs); the unblocked scalar loop
//!   survives as `householder_qr_reference`,
//! * [`tsqr`] — communication-avoiding tall-skinny QR over row blocks (the
//!   "parallel QR" of the abstract): streaming left-fold plus a
//!   fixed-topology parallel tree reduction that is bit-identical for any
//!   worker count,
//! * [`cholesky`] — SPD factorization for the ridge-regularized normal
//!   equations `(HᵀH + λI) β = HᵀY` (rank-deficiency fallback),
//! * [`solve`] — triangular solves and the user-facing least-squares entry
//!   points, including the parallel `lstsq_tsqr`,
//! * [`policy`] — [`ParallelPolicy`], the single worker-count (and
//!   [`Precision`] wire-format / [`FmaMode`] contraction) knob every
//!   threaded path shares, and the fixed-split schedules behind the
//!   bit-identical-at-any-worker-count determinism contract,
//! * [`scan`] — sequence-parallel recurrence primitives: the
//!   [`RecurrenceMode`] knob the `elm::arch` kernels consume, the fixed
//!   [`chunk_schedule`](scan::chunk_schedule) of the time axis, and the
//!   blocked affine prefix scan ([`scan::scan_affine`]) for linear
//!   recurrences,
//! * [`simd`] — the pinned-width SIMD microkernels the GEMM/Gram inner
//!   loops dispatch to at runtime (`std::arch` AVX2 register tiles with
//!   the pre-SIMD scalar loops as both fallback and bit-identity oracle).

#![deny(missing_docs)]

pub mod cholesky;
pub mod matrix;
pub mod matrix32;
pub mod policy;
pub mod qr;
pub mod scan;
pub mod simd;
pub mod solve;
pub mod tsqr;

pub use cholesky::cholesky_solve;
pub use matrix::{Matrix, PackedPanels};
pub use matrix32::MatrixF32;
pub use policy::{ParallelPolicy, Precision};
pub use scan::RecurrenceMode;
pub use simd::{FmaMode, IsaPath};
pub use qr::{
    householder_qr, householder_qr_owned, householder_qr_owned_with,
    householder_qr_reference, householder_qr_with, QrFactors,
};
pub use solve::{
    lstsq_qr, lstsq_qr_report, lstsq_qr_with, lstsq_ridge, lstsq_ridge_from_parts,
    lstsq_tsqr, lstsq_tsqr_report, solve_lower_triangular, solve_upper_triangular,
};
pub use tsqr::TsqrAccumulator;
