//! `ParallelPolicy` — the one knob every threaded linalg path shares.
//!
//! The substrate's determinism contract (the paper's §7.3 robustness
//! requirement) is: **work is split along schedules that depend only on
//! problem shape and compile-time tile constants, never on the worker
//! count**. Workers then execute disjoint pieces of that fixed schedule and
//! the pieces are reduced in schedule order. Under that discipline the
//! worker count can only change *when* a piece is computed, not *what* is
//! computed or *in which order* partial results are folded — so every
//! threaded kernel is bit-identical at 1, 2, 4, 8, … workers:
//!
//! * [`Matrix::matmul_with`](super::Matrix::matmul_with) — output row
//!   tiles are disjoint, each computed by the identical inner kernel, so
//!   the result is bit-identical to the sequential tiled GEMM.
//! * [`Matrix::gram_with`](super::Matrix::gram_with) — fixed input row
//!   chunks, partial Grams folded in chunk order.
//! * [`TsqrAccumulator::reduce`](super::TsqrAccumulator::reduce) — fixed
//!   pairwise tree over fixed-height row blocks.
//! * `householder_qr_with` / `lstsq_qr_with` — the trailing panel updates
//!   are `matmul_with` GEMMs, so the factors inherit the GEMM's bit
//!   stability.
//!
//! Callers plumb one `ParallelPolicy` value instead of ad-hoc `workers:
//! usize` arguments; `CpuElmTrainer` and the report timers construct it
//! once per run. The policy also carries the [`Precision`] wire-format
//! knob consumed by the mixed-precision paths (`CpuElmTrainer`'s Gram
//! fold, `bptt::forward_cpu_with`): the f32-wire kernels obey the same
//! fixed-schedule discipline, so switching precision never weakens the
//! worker-count bit-identity guarantee. Likewise the SIMD dispatch of the
//! [`simd`](super::simd) microkernels never changes results — the AVX2
//! paths are bit-identical to the scalar fallback — and the one knob that
//! *can* change bits, the [`FmaMode`] contraction mode, is opt-in,
//! envelope-documented, and still worker-count invariant.

#![forbid(unsafe_code)]

use anyhow::{anyhow, Result};

use crate::robust::error::SolveError;

use super::scan::RecurrenceMode;
use super::simd::FmaMode;

/// Numeric wire format of the substrate's mixed-precision paths.
///
/// The paper keeps H blocks f32 on the wire (the artifact ABI is f32) while
/// β is solved in higher precision; [`Precision`] is the one knob that
/// selects which wire format the CPU pipeline mirrors:
///
/// * [`Precision::F64`] — everything stays f64 end to end. This is the
///   reference path every conformance test anchors to.
/// * [`Precision::MixedF32`] — operands are stored/streamed as f32 and the
///   kernels accumulate into f64 ([`MatrixF32::matmul_widen`] /
///   [`MatrixF32::gram_widen`]), halving the memory traffic of the wide
///   GEMM/Gram operands. For operands whose values are exactly
///   f32-representable the widen kernels are **bit-identical** to the f64
///   reference (every f32×f32 product is exact in f64 and the accumulation
///   order is the same fixed schedule); for f64-sourced operands the drift
///   is bounded by the one storage rounding (see the [`matrix32`] contract).
///
/// Either way the determinism contract is unchanged: results are
/// bit-identical at any worker count.
///
/// [`MatrixF32::matmul_widen`]: super::MatrixF32::matmul_widen
/// [`MatrixF32::gram_widen`]: super::MatrixF32::gram_widen
/// [`matrix32`]: super::matrix32
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// f64 storage, f64 arithmetic — the conformance-tested reference.
    #[default]
    F64,
    /// f32 storage/wire, f64 accumulation (the paper's H-block format).
    MixedF32,
}

/// Worker-count (and wire-precision) policy for the threaded linalg paths.
/// Carries no split information on purpose: splits are fixed by the kernels
/// (see the module docs), the policy only says how many threads execute
/// them and which wire format precision-aware callers use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelPolicy {
    /// Number of worker threads (>= 1). 1 means run on the caller thread.
    pub workers: usize,
    /// Wire format for precision-aware paths (the `CpuElmTrainer` Gram fold
    /// and `bptt::forward_cpu_with`); kernels that take f64 operands ignore
    /// it. Defaults to [`Precision::F64`].
    pub precision: Precision,
    /// Fused-multiply-add contraction mode of the SIMD GEMM/Gram
    /// microkernels. Defaults to [`FmaMode::Exact`] (bit-identical to the
    /// scalar kernels). [`FmaMode::Relaxed`] is an opt-in throughput knob
    /// with a documented error envelope (see [`simd`](super::simd)): it
    /// relinquishes bit-identity with the exact kernels but **never** the
    /// worker-count invariance — the split schedules stay fixed.
    pub fma: FmaMode,
    /// How the `elm::arch` recurrence kernels traverse the time axis.
    /// Defaults to [`RecurrenceMode::Sequential`] (the conformance oracle).
    /// [`RecurrenceMode::Chunked`] switches H-block construction to the
    /// sequence-parallel executors (see [`scan`](super::scan)): exact and
    /// bit-identical for FC/Jordan/NARMAX at any chunk size, warm-up
    /// truncated within a documented envelope for Elman/LSTM/GRU. The
    /// linalg kernels themselves ignore it — only the recurrence
    /// dispatchers consume it — and the chunk schedule is fixed by shape,
    /// so worker-count bit-invariance is unaffected.
    pub recurrence: RecurrenceMode,
}

impl ParallelPolicy {
    /// Single-threaded: everything runs on the caller's thread.
    pub fn sequential() -> ParallelPolicy {
        ParallelPolicy {
            workers: 1,
            precision: Precision::F64,
            fma: FmaMode::Exact,
            recurrence: RecurrenceMode::Sequential,
        }
    }

    /// Explicit worker count (clamped to >= 1).
    pub fn with_workers(workers: usize) -> ParallelPolicy {
        ParallelPolicy {
            workers: workers.max(1),
            precision: Precision::F64,
            fma: FmaMode::Exact,
            recurrence: RecurrenceMode::Sequential,
        }
    }

    /// One worker per available core, capped at 8 (the ELM solve saturates
    /// memory bandwidth before it saturates more cores than that).
    pub fn auto() -> ParallelPolicy {
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        ParallelPolicy {
            workers: cores.clamp(1, 8),
            precision: Precision::F64,
            fma: FmaMode::Exact,
            recurrence: RecurrenceMode::Sequential,
        }
    }

    /// Same worker count, different wire precision (builder style).
    pub fn with_precision(mut self, precision: Precision) -> ParallelPolicy {
        self.precision = precision;
        self
    }

    /// Same worker count and precision, different FMA contraction mode
    /// (builder style). [`FmaMode::Relaxed`] only takes effect on hosts
    /// with AVX2+FMA; everywhere else the kernels stay exact.
    pub fn with_fma(mut self, fma: FmaMode) -> ParallelPolicy {
        self.fma = fma;
        self
    }

    /// Same worker count/precision/FMA mode, different recurrence traversal
    /// (builder style). [`RecurrenceMode::Chunked`] only affects the
    /// `elm::arch` H-block dispatchers; every linalg kernel ignores it.
    pub fn with_recurrence(mut self, recurrence: RecurrenceMode) -> ParallelPolicy {
        self.recurrence = recurrence;
        self
    }
}

impl Default for ParallelPolicy {
    fn default() -> ParallelPolicy {
        ParallelPolicy::sequential()
    }
}

/// Deterministic logical clock — the fleet service's only notion of time.
///
/// The service layer (`coordinator::service`) needs deadlines and
/// exponential backoff, but wall-clock time would break the substrate's
/// bit-reproducibility: two runs of the same submissions would observe
/// different timestamps and make different scheduling decisions. Instead,
/// time is a `u64` tick counter advanced once per service cycle —
/// deadlines and backoff eligibility are compared against ticks, so every
/// scheduling decision is a pure function of the submission sequence (and
/// the configured seed), independent of host load or worker count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct LogicalClock {
    tick: u64,
}

impl LogicalClock {
    /// Clock at tick 0.
    pub fn new() -> LogicalClock {
        LogicalClock { tick: 0 }
    }

    /// Current tick.
    pub fn now(&self) -> u64 {
        self.tick
    }

    /// Advance by one tick and return the new value (saturating — the
    /// clock never wraps back before an already-issued deadline).
    pub fn advance(&mut self) -> u64 {
        self.tick = self.tick.saturating_add(1);
        self.tick
    }

    /// Jump forward to `tick` if it is ahead (used to fast-forward past a
    /// backoff window when the queue is otherwise idle); never moves
    /// backwards.
    pub fn advance_to(&mut self, tick: u64) -> u64 {
        self.tick = self.tick.max(tick);
        self.tick
    }
}

/// Fixed tiling of `[0, n)` into `(lo, hi)` ranges of height `tile` (the
/// last tile may be short). The boundaries are a function of `(n, tile)`
/// alone — **never** of a worker count — which is what makes every parallel
/// schedule over these tiles reduce identically (see the module docs).
pub fn fixed_tiles(n: usize, tile: usize) -> Vec<(usize, usize)> {
    let tile = tile.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(tile));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + tile).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Best-effort text of a caught panic payload (`&'static str` and `String`
/// cover every `panic!` in this crate; anything else is opaque).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Run `f(item)` with panic isolation: a panicking item becomes a typed
/// [`SolveError::WorkerPanic`] carrying the item index and the panic
/// message, instead of unwinding through the thread and poisoning the
/// whole map.
fn call_caught<T, U>(f: &(impl Fn(T) -> Result<U> + Sync), index: usize, item: T) -> Result<U> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item))) {
        Ok(res) => res,
        Err(p) => Err(SolveError::WorkerPanic {
            index,
            retried: false,
            message: panic_message(&*p),
        }
        .into()),
    }
}

/// Order-preserving parallel map over owned items: contiguous chunks are
/// handed to `policy.workers` scoped threads and the per-chunk outputs are
/// reassembled in chunk order, so the result is independent of scheduling.
/// (Shared by the TSQR tree, the threaded GEMM/Gram, and the coordinator's
/// CPU pipeline.)
///
/// A panicking item is caught and reported as a typed
/// [`SolveError::WorkerPanic`] with the item's global index — it cannot be
/// retried here because the closure consumes its item by value; callers
/// that need retry-once semantics use [`par_map_isolated`].
pub(crate) fn par_map<T, U, F>(items: Vec<T>, policy: ParallelPolicy, f: F) -> Result<Vec<U>>
where
    T: Send,
    U: Send,
    F: Fn(T) -> Result<U> + Sync,
{
    let total = items.len();
    let workers = policy.workers.max(1).min(total.max(1));
    if workers == 1 {
        return items
            .into_iter()
            .enumerate()
            .map(|(i, item)| call_caught(&f, i, item))
            .collect();
    }
    // contiguous chunks, sizes differing by at most one; each chunk
    // remembers its global start index for panic provenance
    let base = total / workers;
    let extra = total % workers;
    let mut chunks: Vec<(usize, Vec<T>)> = Vec::with_capacity(workers);
    let mut rest = items;
    let mut start = 0usize;
    for w in 0..workers {
        let take = base + usize::from(w < extra);
        let tail = rest.split_off(take.min(rest.len()));
        chunks.push((start, rest));
        start += take;
        rest = tail;
    }
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|(start, chunk)| {
                scope.spawn(move || {
                    chunk
                        .into_iter()
                        .enumerate()
                        .map(|(k, item)| call_caught(f, start + k, item))
                        .collect::<Result<Vec<U>>>()
                })
            })
            .collect();
        let mut out = Vec::with_capacity(total);
        for h in handles {
            // per-item catch_unwind above makes a thread-level panic
            // unreachable in practice; keep the backstop anyway
            let part = h
                .join()
                .map_err(|_| anyhow!("parallel worker thread panicked"))??;
            out.extend(part);
        }
        Ok(out)
    })
}

/// [`par_map`] over *borrowed* items with **retry-once panic isolation**:
/// the parallel phase catches any panicking item (recording which), then an
/// in-order sequential pass re-runs each panicked item exactly once — a
/// transient fault (the injection harness's `WorkerPanic`, a glitched
/// allocation) recovers with the retry counted, while a deterministic panic
/// surfaces as a typed [`SolveError::WorkerPanic`] with `retried: true`.
///
/// Returns the in-order outputs plus the number of retried items. Output
/// bits are unaffected by retries: item `i`'s output is `f(i, &items[i])`
/// whether it ran in the parallel phase or the retry pass.
pub(crate) fn par_map_isolated<T, U, F>(
    items: &[T],
    policy: ParallelPolicy,
    f: F,
) -> Result<(Vec<U>, u32)>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> Result<U> + Sync,
{
    let total = items.len();
    let workers = policy.workers.max(1).min(total.max(1));
    let catch = |i: usize| {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &items[i]))).ok()
    };
    // phase 1: parallel, panics caught per item (None = panicked)
    let slots: Vec<Option<Result<U>>> = if workers == 1 {
        (0..total).map(catch).collect()
    } else {
        let base = total / workers;
        let extra = total % workers;
        let mut bounds = Vec::with_capacity(workers);
        let mut lo = 0usize;
        for w in 0..workers {
            let hi = lo + base + usize::from(w < extra);
            bounds.push((lo, hi));
            lo = hi;
        }
        let catch = &catch;
        std::thread::scope(|scope| {
            let handles: Vec<_> = bounds
                .into_iter()
                .map(|(lo, hi)| scope.spawn(move || (lo..hi).map(catch).collect::<Vec<_>>()))
                .collect();
            let mut out = Vec::with_capacity(total);
            for h in handles {
                let part = h
                    .join()
                    .map_err(|_| anyhow!("parallel worker thread panicked"))?;
                out.extend(part);
            }
            Ok::<_, anyhow::Error>(out)
        })?
    };
    // phase 2: in order — propagate Errs, retry panicked items once
    let mut retries = 0u32;
    let mut out = Vec::with_capacity(total);
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(res) => out.push(res?),
            None => {
                retries += 1;
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f(i, &items[i])
                })) {
                    Ok(res) => out.push(res?),
                    Err(p) => {
                        return Err(SolveError::WorkerPanic {
                            index: i,
                            retried: true,
                            message: panic_message(&*p),
                        }
                        .into())
                    }
                }
            }
        }
    }
    Ok((out, retries))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_tiles_cover_exactly() {
        for (n, tile) in [(0usize, 7usize), (1, 7), (7, 7), (8, 7), (100, 32)] {
            let tiles = fixed_tiles(n, tile);
            let total: usize = tiles.iter().map(|(lo, hi)| hi - lo).sum();
            assert_eq!(total, n);
            let mut pos = 0;
            for (lo, hi) in tiles {
                assert_eq!(lo, pos);
                assert!(hi > lo && hi - lo <= tile);
                pos = hi;
            }
        }
    }

    #[test]
    fn fixed_tiles_ignore_zero_tile() {
        assert_eq!(fixed_tiles(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn par_map_preserves_order_any_workers() {
        let items: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = items.iter().map(|x| x * 3).collect();
        for workers in [1usize, 2, 4, 8, 64] {
            let got = par_map(items.clone(), ParallelPolicy::with_workers(workers), |x| {
                Ok(x * 3)
            })
            .unwrap();
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn par_map_propagates_errors() {
        let items: Vec<usize> = (0..10).collect();
        let res = par_map(items, ParallelPolicy::with_workers(4), |x| {
            if x == 7 {
                Err(anyhow!("boom"))
            } else {
                Ok(x)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn par_map_turns_panics_into_typed_errors() {
        use crate::robust::error::as_solve_error;
        let items: Vec<usize> = (0..16).collect();
        for workers in [1usize, 4] {
            let err = par_map(items.clone(), ParallelPolicy::with_workers(workers), |x| {
                if x == 11 {
                    panic!("chunk fault at {x}");
                }
                Ok(x)
            })
            .unwrap_err();
            match as_solve_error(&err).expect("typed error") {
                SolveError::WorkerPanic { index: 11, retried: false, message } => {
                    assert!(message.contains("chunk fault"), "{message}");
                }
                other => panic!("wrong variant: {other:?}"),
            }
        }
    }

    #[test]
    fn par_map_isolated_retries_transient_panics_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let items: Vec<usize> = (0..20).collect();
        for workers in [1usize, 4, 8] {
            let fired = AtomicU32::new(0);
            let (out, retries) = par_map_isolated(
                &items,
                ParallelPolicy::with_workers(workers),
                |i, &x| {
                    // item 7 panics exactly once (transient fault), then
                    // succeeds on the sequential retry
                    if i == 7 && fired.fetch_add(1, Ordering::SeqCst) == 0 {
                        panic!("transient fault");
                    }
                    Ok(x * 2)
                },
            )
            .unwrap();
            assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
            assert_eq!(retries, 1, "workers={workers}");
        }
    }

    #[test]
    fn par_map_isolated_reports_persistent_panics_as_retried() {
        use crate::robust::error::as_solve_error;
        let items: Vec<usize> = (0..10).collect();
        let err = par_map_isolated(&items, ParallelPolicy::with_workers(4), |i, &x| {
            if i == 3 {
                panic!("deterministic fault");
            }
            Ok(x)
        })
        .unwrap_err();
        match as_solve_error(&err).expect("typed error") {
            SolveError::WorkerPanic { index: 3, retried: true, message } => {
                assert!(message.contains("deterministic fault"));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn logical_clock_is_monotone() {
        let mut c = LogicalClock::new();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.advance_to(10), 10);
        assert_eq!(c.advance_to(5), 10, "never moves backwards");
        assert_eq!(c.now(), 10);
        assert_eq!(LogicalClock::default(), LogicalClock::new());
    }

    #[test]
    fn policy_constructors_clamp() {
        assert_eq!(ParallelPolicy::with_workers(0).workers, 1);
        assert_eq!(ParallelPolicy::sequential().workers, 1);
        let auto = ParallelPolicy::auto().workers;
        assert!((1..=8).contains(&auto));
    }

    #[test]
    fn precision_defaults_to_f64_and_builds() {
        assert_eq!(ParallelPolicy::sequential().precision, Precision::F64);
        assert_eq!(ParallelPolicy::with_workers(4).precision, Precision::F64);
        assert_eq!(ParallelPolicy::auto().precision, Precision::F64);
        let p = ParallelPolicy::with_workers(4).with_precision(Precision::MixedF32);
        assert_eq!(p.workers, 4);
        assert_eq!(p.precision, Precision::MixedF32);
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn recurrence_defaults_to_sequential_and_builds() {
        assert_eq!(
            ParallelPolicy::sequential().recurrence,
            RecurrenceMode::Sequential
        );
        assert_eq!(
            ParallelPolicy::with_workers(4).recurrence,
            RecurrenceMode::Sequential
        );
        assert_eq!(ParallelPolicy::auto().recurrence, RecurrenceMode::Sequential);
        assert_eq!(RecurrenceMode::default(), RecurrenceMode::Sequential);
        let p = ParallelPolicy::with_workers(4)
            .with_precision(Precision::MixedF32)
            .with_recurrence(RecurrenceMode::Chunked { chunk: 64, warmup: 16 });
        assert_eq!(p.workers, 4);
        assert_eq!(p.precision, Precision::MixedF32);
        assert_eq!(
            p.recurrence,
            RecurrenceMode::Chunked { chunk: 64, warmup: 16 }
        );
    }

    #[test]
    fn fma_defaults_to_exact_and_builds() {
        assert_eq!(ParallelPolicy::sequential().fma, FmaMode::Exact);
        assert_eq!(ParallelPolicy::with_workers(4).fma, FmaMode::Exact);
        assert_eq!(ParallelPolicy::auto().fma, FmaMode::Exact);
        assert_eq!(FmaMode::default(), FmaMode::Exact);
        let p = ParallelPolicy::with_workers(4)
            .with_precision(Precision::MixedF32)
            .with_fma(FmaMode::Relaxed);
        assert_eq!(p.workers, 4);
        assert_eq!(p.precision, Precision::MixedF32);
        assert_eq!(p.fma, FmaMode::Relaxed);
    }
}
