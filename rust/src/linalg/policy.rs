//! `ParallelPolicy` — the one knob every threaded linalg path shares.
//!
//! The substrate's determinism contract (the paper's §7.3 robustness
//! requirement) is: **work is split along schedules that depend only on
//! problem shape and compile-time tile constants, never on the worker
//! count**. Workers then execute disjoint pieces of that fixed schedule and
//! the pieces are reduced in schedule order. Under that discipline the
//! worker count can only change *when* a piece is computed, not *what* is
//! computed or *in which order* partial results are folded — so every
//! threaded kernel is bit-identical at 1, 2, 4, 8, … workers:
//!
//! * [`Matrix::matmul_with`](super::Matrix::matmul_with) — output row
//!   tiles are disjoint, each computed by the identical inner kernel, so
//!   the result is bit-identical to the sequential tiled GEMM.
//! * [`Matrix::gram_with`](super::Matrix::gram_with) — fixed input row
//!   chunks, partial Grams folded in chunk order.
//! * [`TsqrAccumulator::reduce`](super::TsqrAccumulator::reduce) — fixed
//!   pairwise tree over fixed-height row blocks.
//! * `householder_qr_with` / `lstsq_qr_with` — the trailing panel updates
//!   are `matmul_with` GEMMs, so the factors inherit the GEMM's bit
//!   stability.
//!
//! Callers plumb one `ParallelPolicy` value instead of ad-hoc `workers:
//! usize` arguments; `CpuElmTrainer` and the report timers construct it
//! once per run.

use anyhow::{anyhow, Result};

/// Worker-count policy for the threaded linalg paths. Carries no split
/// information on purpose: splits are fixed by the kernels (see the module
/// docs), the policy only says how many threads execute them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelPolicy {
    /// Number of worker threads (>= 1). 1 means run on the caller thread.
    pub workers: usize,
}

impl ParallelPolicy {
    /// Single-threaded: everything runs on the caller's thread.
    pub fn sequential() -> ParallelPolicy {
        ParallelPolicy { workers: 1 }
    }

    /// Explicit worker count (clamped to >= 1).
    pub fn with_workers(workers: usize) -> ParallelPolicy {
        ParallelPolicy { workers: workers.max(1) }
    }

    /// One worker per available core, capped at 8 (the ELM solve saturates
    /// memory bandwidth before it saturates more cores than that).
    pub fn auto() -> ParallelPolicy {
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        ParallelPolicy { workers: cores.clamp(1, 8) }
    }
}

impl Default for ParallelPolicy {
    fn default() -> ParallelPolicy {
        ParallelPolicy::sequential()
    }
}

/// Fixed tiling of `[0, n)` into `(lo, hi)` ranges of height `tile` (the
/// last tile may be short). The boundaries are a function of `(n, tile)`
/// alone — **never** of a worker count — which is what makes every parallel
/// schedule over these tiles reduce identically (see the module docs).
pub fn fixed_tiles(n: usize, tile: usize) -> Vec<(usize, usize)> {
    let tile = tile.max(1);
    let mut out = Vec::with_capacity(n.div_ceil(tile));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + tile).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Order-preserving parallel map over owned items: contiguous chunks are
/// handed to `policy.workers` scoped threads and the per-chunk outputs are
/// reassembled in chunk order, so the result is independent of scheduling.
/// (Shared by the TSQR tree, the threaded GEMM/Gram, and the coordinator's
/// CPU pipeline.)
pub(crate) fn par_map<T, U, F>(items: Vec<T>, policy: ParallelPolicy, f: F) -> Result<Vec<U>>
where
    T: Send,
    U: Send,
    F: Fn(T) -> Result<U> + Sync,
{
    let total = items.len();
    let workers = policy.workers.max(1).min(total.max(1));
    if workers == 1 {
        return items.into_iter().map(&f).collect();
    }
    // contiguous chunks, sizes differing by at most one
    let base = total / workers;
    let extra = total % workers;
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
    let mut rest = items;
    for w in 0..workers {
        let take = base + usize::from(w < extra);
        let tail = rest.split_off(take.min(rest.len()));
        chunks.push(rest);
        rest = tail;
    }
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                scope.spawn(move || {
                    chunk.into_iter().map(f).collect::<Result<Vec<U>>>()
                })
            })
            .collect();
        let mut out = Vec::with_capacity(total);
        for h in handles {
            let part = h
                .join()
                .map_err(|_| anyhow!("parallel worker thread panicked"))??;
            out.extend(part);
        }
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_tiles_cover_exactly() {
        for (n, tile) in [(0usize, 7usize), (1, 7), (7, 7), (8, 7), (100, 32)] {
            let tiles = fixed_tiles(n, tile);
            let total: usize = tiles.iter().map(|(lo, hi)| hi - lo).sum();
            assert_eq!(total, n);
            let mut pos = 0;
            for (lo, hi) in tiles {
                assert_eq!(lo, pos);
                assert!(hi > lo && hi - lo <= tile);
                pos = hi;
            }
        }
    }

    #[test]
    fn fixed_tiles_ignore_zero_tile() {
        assert_eq!(fixed_tiles(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn par_map_preserves_order_any_workers() {
        let items: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = items.iter().map(|x| x * 3).collect();
        for workers in [1usize, 2, 4, 8, 64] {
            let got = par_map(items.clone(), ParallelPolicy::with_workers(workers), |x| {
                Ok(x * 3)
            })
            .unwrap();
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn par_map_propagates_errors() {
        let items: Vec<usize> = (0..10).collect();
        let res = par_map(items, ParallelPolicy::with_workers(4), |x| {
            if x == 7 {
                Err(anyhow!("boom"))
            } else {
                Ok(x)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn policy_constructors_clamp() {
        assert_eq!(ParallelPolicy::with_workers(0).workers, 1);
        assert_eq!(ParallelPolicy::sequential().workers, 1);
        let auto = ParallelPolicy::auto().workers;
        assert!((1..=8).contains(&auto));
    }
}
