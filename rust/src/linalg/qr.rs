//! Householder QR (the paper's §4.2 factorization).
//!
//! `householder_qr` is a blocked panel factorization in the compact-WY
//! representation: PANEL (=32) columns at a time are factored with scalar
//! Householder eliminations on a *packed, column-major* copy of the panel
//! (contiguous dots/axpys instead of stride-n column walks), then the
//! accumulated reflectors are applied to the trailing matrix as three
//! GEMMs through the tiled [`Matrix::matmul`]:
//!
//! ```text
//!   Q_panel = H_1 H_2 … H_nb = I − V T Vᵀ          (forward columnwise T)
//!   C ← C − V · Tᵀ · (Vᵀ C)                         (trailing update)
//! ```
//!
//! The packed panels and their T factors are **kept** on the factor object
//! (`panels` / `ts`): [`QrFactors::apply_qt`] applies Qᵀ panel by panel as
//! three small dense ops per panel (s = Vᵀb, u = Tᵀs, b −= V·u) over the
//! contiguous panel storage, instead of the seed's column-at-a-time walk
//! over the strided `work` matrix. `householder_qr_reference` keeps the
//! unblocked loop (and the column-at-a-time [`QrFactors::apply_qt_reference`])
//! as the numerical baseline the property tests compare against.
//!
//! # Determinism
//!
//! The panel width is a compile-time constant and the factorization's only
//! threaded pieces are the trailing-update GEMMs, routed through
//! [`Matrix::matmul_with`] — which is bit-identical to the sequential GEMM
//! at any [`ParallelPolicy`] worker count — so the factors (and therefore
//! Qᵀb and R) are bit-identical for any worker count. The trailing GEMMs
//! therefore also run on the [`simd`](super::simd) register-tiled
//! microkernels, and the panel reflector applications use the dispatched
//! element-independent `axpy_sub` (`c −= s·v`) — both bit-identical to
//! their scalar twins, so SIMD dispatch never moves a factor bit. The
//! in-panel *dots* (column norms, `vᵀc`) deliberately stay scalar: a SIMD
//! horizontal reduction would reassociate the sum and break the pinned
//! bit-identity with `householder_qr_reference` on n ≤ PANEL inputs. For inputs with
//! n ≤ PANEL the blocked path degenerates to the reference loop and its
//! `work`/`betas` are bit-identical to it; beyond that the trailing GEMM
//! reassociates the update sums, which the tests bound at 1e-10. The
//! panel-resident `apply_qt` likewise reassociates relative to the
//! column-at-a-time loop (bounded by tests, not bitwise).

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use super::matrix::Matrix;
use super::policy::ParallelPolicy;
use super::simd;

/// Panel width of the blocked factorization.
pub const PANEL: usize = 32;

/// Compact QR factors of an m×n matrix (m >= n).
pub struct QrFactors {
    /// Householder vectors stored below the diagonal of the working copy;
    /// column j's vector is v_j with v_j[j] = 1 implied.
    work: Matrix,
    /// beta_j = 2 / (v_jᵀ v_j)
    betas: Vec<f64>,
    /// Packed column-major panels (ml×nb each, ml = m − j0): the
    /// panel-resident V factors `apply_qt` streams. Empty on the
    /// reference path. Deliberate space-for-time trade: this duplicates
    /// the subdiagonal of `work` (~m·n f64, transient — factors are
    /// dropped right after the solve's single Qᵀb) so the apply walks
    /// contiguous memory instead of stride-n columns.
    panels: Vec<Vec<f64>>,
    /// Per-panel upper-triangular T of the compact-WY form (parallel to
    /// `panels`). Empty on the reference path.
    ts: Vec<Matrix>,
    /// Row count of the factored matrix.
    pub m: usize,
    /// Column count of the factored matrix (m >= n).
    pub n: usize,
}

/// Blocked (panel + compact-WY) Householder QR. No pivoting: ELM design
/// matrices are dense and generically full-rank; the ridge path covers the
/// degenerate case.
pub fn householder_qr(a: &Matrix) -> Result<QrFactors> {
    householder_qr_owned_with(a.clone(), ParallelPolicy::sequential())
}

/// Blocked QR with the trailing updates threaded per `policy`. Bit-
/// identical to [`householder_qr`] at any worker count (see module docs).
pub fn householder_qr_with(a: &Matrix, policy: ParallelPolicy) -> Result<QrFactors> {
    householder_qr_owned_with(a.clone(), policy)
}

/// Blocked QR taking the input by value — the TSQR accumulator's path,
/// which would otherwise clone every block.
pub fn householder_qr_owned(a: Matrix) -> Result<QrFactors> {
    householder_qr_owned_with(a, ParallelPolicy::sequential())
}

/// By-value blocked QR with threaded trailing updates.
pub fn householder_qr_owned_with(a: Matrix, policy: ParallelPolicy) -> Result<QrFactors> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        bail!("householder_qr requires rows >= cols, got {m}x{n}");
    }
    let mut w = a;
    let mut betas = vec![0.0; n];
    let mut panels = Vec::with_capacity(n.div_ceil(PANEL));
    let mut ts = Vec::with_capacity(n.div_ceil(PANEL));
    let mut j0 = 0;
    while j0 < n {
        let nb = PANEL.min(n - j0);
        let pan = factor_panel(&mut w, &mut betas, j0, nb);
        let vt = panel_vt(&pan, m - j0, nb);
        let v = vt.transpose(); // shared by T construction and the trailing GEMM
        let t = panel_t(&vt, &v, &betas[j0..j0 + nb]);
        if j0 + nb < n {
            apply_panel_to_trailing(&mut w, &vt, &v, &t, j0, nb, policy);
        }
        panels.push(pan);
        ts.push(t);
        j0 += nb;
    }
    Ok(QrFactors { work: w, betas, panels, ts, m, n })
}

/// Unblocked column-at-a-time Householder QR — the seed implementation,
/// kept as the reference the blocked path is validated against.
pub fn householder_qr_reference(a: &Matrix) -> Result<QrFactors> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        bail!("householder_qr requires rows >= cols, got {m}x{n}");
    }
    let mut w = a.clone();
    let mut betas = vec![0.0; n];

    for j in 0..n {
        // norm of the j-th column below (and including) the diagonal
        let mut norm2 = 0.0;
        for i in j..m {
            norm2 += w[(i, j)] * w[(i, j)];
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let alpha = if w[(j, j)] >= 0.0 { -norm } else { norm };
        // v = x - alpha e1 ; store v (normalized so v[j] = 1)
        let v0 = w[(j, j)] - alpha;
        // v0 can't be 0 since alpha has opposite sign of x0 (or x0 == 0)
        let mut vtv = v0 * v0;
        for i in j + 1..m {
            vtv += w[(i, j)] * w[(i, j)];
        }
        let beta = 2.0 * v0 * v0 / vtv; // after normalization by v0
        // normalize: v[i] /= v0
        for i in j + 1..m {
            w[(i, j)] /= v0;
        }
        // apply H = I - beta v vᵀ to the trailing submatrix
        for col in j + 1..n {
            // s = vᵀ w[:, col]
            let mut s = w[(j, col)];
            for i in j + 1..m {
                s += w[(i, j)] * w[(i, col)];
            }
            s *= beta;
            w[(j, col)] -= s;
            for i in j + 1..m {
                let vij = w[(i, j)];
                w[(i, col)] -= s * vij;
            }
        }
        w[(j, j)] = alpha;
        betas[j] = beta;
    }
    Ok(QrFactors { work: w, betas, panels: Vec::new(), ts: Vec::new(), m, n })
}

/// Factor columns [j0, j0+nb) on a packed column-major copy of the panel
/// (rows j0..m), write the factored panel back into `w`, and return the
/// packed copy (column c holds R values above the diagonal, alpha at it,
/// and the normalized Householder tail below — `apply_qt` streams the
/// tails).
fn factor_panel(w: &mut Matrix, betas: &mut [f64], j0: usize, nb: usize) -> Vec<f64> {
    let m = w.rows;
    let n = w.cols;
    let ml = m - j0; // local row count
    // pack: pan[c * ml + i] = w[(j0 + i, j0 + c)]
    let mut pan = vec![0.0f64; nb * ml];
    for i in 0..ml {
        let base = (j0 + i) * n + j0;
        for c in 0..nb {
            pan[c * ml + i] = w.data()[base + c];
        }
    }

    for c in 0..nb {
        // split so column c is immutable while columns > c are updated
        let (head, tail) = pan.split_at_mut((c + 1) * ml);
        let vc = &mut head[c * ml..];
        let mut norm2 = 0.0;
        for &x in &vc[c..] {
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[j0 + c] = 0.0;
            continue;
        }
        let alpha = if vc[c] >= 0.0 { -norm } else { norm };
        let v0 = vc[c] - alpha;
        let mut vtv = v0 * v0;
        for &x in &vc[c + 1..] {
            vtv += x * x;
        }
        let beta = 2.0 * v0 * v0 / vtv;
        for x in &mut vc[c + 1..] {
            *x /= v0;
        }
        vc[c] = alpha;
        betas[j0 + c] = beta;
        // apply H_c to the remaining panel columns (contiguous slices).
        // The dot stays scalar (a SIMD horizontal reduction would
        // reassociate and break the bit-identity with the reference
        // loop); the rank-1 update is element-independent, so it goes
        // through the dispatched `axpy_sub` — bit-identical on every ISA.
        let vtail = &vc[c + 1..];
        for d in 0..nb - c - 1 {
            let col = &mut tail[d * ml..(d + 1) * ml];
            let mut s = col[c];
            for (vx, cx) in vtail.iter().zip(&col[c + 1..]) {
                s += vx * cx;
            }
            s *= beta;
            col[c] -= s;
            simd::axpy_sub_f64(s, vtail, &mut col[c + 1..]);
        }
    }

    // write back
    for i in 0..ml {
        let base = (j0 + i) * n + j0;
        for c in 0..nb {
            w.data_mut()[base + c] = pan[c * ml + i];
        }
    }
    pan
}

/// Vᵀ of a factored packed panel: row c = panel column c with implied unit
/// diagonal, zeros above it (the R values stored there are masked out).
fn panel_vt(pan: &[f64], ml: usize, nb: usize) -> Matrix {
    let mut vt = Matrix::zeros(nb, ml);
    for c in 0..nb {
        let row = vt.row_mut(c);
        row[c] = 1.0;
        row[c + 1..ml].copy_from_slice(&pan[c * ml + c + 1..(c + 1) * ml]);
    }
    vt
}

/// Forward-columnwise T of the compact-WY form (LAPACK larft):
/// T[c][c] = beta_c, T[0..c, c] = -beta_c * T[0..c, 0..c] * (Vᵀ v_c).
/// A zero beta (H_c = I) yields an all-zero row and column c.
/// `v` must be `vt.transpose()` (the caller shares it with the trailing
/// update).
fn panel_t(vt: &Matrix, v: &Matrix, betas: &[f64]) -> Matrix {
    let nb = vt.rows;
    let vtv = vt.matmul(v);
    let mut t = Matrix::zeros(nb, nb);
    for c in 0..nb {
        let bc = betas[c];
        if bc == 0.0 {
            continue; // H_c = I: zero row/column in T
        }
        for r in 0..c {
            let mut s = 0.0;
            for u in r..c {
                s += t[(r, u)] * vtv[(u, c)];
            }
            t[(r, c)] = -bc * s;
        }
        t[(c, c)] = bc;
    }
    t
}

/// Apply the panel's accumulated reflectors to the trailing matrix:
/// C ← C − V Tᵀ (Vᵀ C), GEMMs threaded per `policy`. `v` must be
/// `vt.transpose()` (shared with `panel_t`).
fn apply_panel_to_trailing(
    w: &mut Matrix,
    vt: &Matrix,
    v: &Matrix,
    t: &Matrix,
    j0: usize,
    nb: usize,
    policy: ParallelPolicy,
) {
    let m = w.rows;
    let n = w.cols;
    let ml = m - j0;
    let c0 = j0 + nb;

    // three GEMMs on the trailing block
    let c_mat = w.submatrix(j0, m, c0, n);
    let w1 = vt.matmul_with(&c_mat, policy); // nb × nt
    let w2 = t.transpose().matmul(&w1); // nb × nt (tiny: stays sequential)
    let d = v.matmul_with(&w2, policy); // ml × nt
    let nt = n - c0;
    for i in 0..ml {
        let base = (j0 + i) * n + c0;
        for j in 0..nt {
            w.data_mut()[base + j] = c_mat[(i, j)] - d[(i, j)];
        }
    }
}

impl QrFactors {
    /// The n×n upper-triangular R.
    pub fn r(&self) -> Matrix {
        let mut r = Matrix::zeros(self.n, self.n);
        for i in 0..self.n {
            for j in i..self.n {
                r[(i, j)] = self.work[(i, j)];
            }
        }
        r
    }

    /// Apply Qᵀ to a length-m vector in place; the first n entries are then
    /// the projection used by the least-squares solve.
    ///
    /// Blocked factors take the panel-resident path: per panel,
    /// s = Vᵀ b_panel, u = Tᵀ s, b_panel −= V u — three contiguous passes
    /// over the packed panel instead of a strided walk per column.
    /// Reference factors (no stored panels) fall back to the
    /// column-at-a-time loop.
    pub fn apply_qt(&self, b: &mut [f64]) {
        assert_eq!(b.len(), self.m);
        if self.panels.is_empty() && self.n > 0 {
            self.apply_qt_reference(b);
            return;
        }
        let mut s = [0.0f64; PANEL];
        let mut u = [0.0f64; PANEL];
        for (pi, (pan, t)) in self.panels.iter().zip(&self.ts).enumerate() {
            let j0 = pi * PANEL;
            let ml = self.m - j0;
            let nb = t.rows;
            let bl = &mut b[j0..];
            // s = Vᵀ b (v_c diagonal 1 implied, tails contiguous in pan)
            for c in 0..nb {
                let tail = &pan[c * ml + c + 1..(c + 1) * ml];
                let mut acc = bl[c];
                for (vx, bx) in tail.iter().zip(&bl[c + 1..ml]) {
                    acc += vx * bx;
                }
                s[c] = acc;
            }
            // u = Tᵀ s (T upper triangular: u[c] sums rows 0..=c)
            for c in 0..nb {
                let mut acc = 0.0;
                for r in 0..=c {
                    acc += t[(r, c)] * s[r];
                }
                u[c] = acc;
            }
            // b -= V u (rank-1 updates through the dispatched `axpy_sub`
            // — element-independent, bit-identical on every ISA path)
            for c in 0..nb {
                let uc = u[c];
                if uc == 0.0 {
                    continue; // zero-beta column (H_c = I) contributes nothing
                }
                bl[c] -= uc;
                let tail = &pan[c * ml + c + 1..(c + 1) * ml];
                simd::axpy_sub_f64(uc, tail, &mut bl[c + 1..ml]);
            }
        }
    }

    /// The seed's column-at-a-time Qᵀb — the oracle the panel-resident
    /// path is pinned to by the property tests, and the execution path for
    /// reference factors.
    pub fn apply_qt_reference(&self, b: &mut [f64]) {
        assert_eq!(b.len(), self.m);
        for j in 0..self.n {
            let beta = self.betas[j];
            if beta == 0.0 {
                continue;
            }
            // s = vᵀ b (v[j] = 1 implied)
            let mut s = b[j];
            for i in j + 1..self.m {
                s += self.work[(i, j)] * b[i];
            }
            s *= beta;
            b[j] -= s;
            for i in j + 1..self.m {
                b[i] -= s * self.work[(i, j)];
            }
        }
    }

    /// Reconstruct the full m×n Q (test/diagnostic use only).
    pub fn q(&self) -> Matrix {
        let mut q = Matrix::zeros(self.m, self.n);
        for (i, col) in (0..self.n).enumerate() {
            // apply Q to e_i: Q = H_0 H_1 ... H_{n-1}; Q e_i = H_0 (... (H_{n-1} e_i))
            let mut e = vec![0.0; self.m];
            e[col] = 1.0;
            for j in (0..self.n).rev() {
                let beta = self.betas[j];
                if beta == 0.0 {
                    continue;
                }
                let mut s = e[j];
                for k in j + 1..self.m {
                    s += self.work[(k, j)] * e[k];
                }
                s *= beta;
                e[j] -= s;
                for k in j + 1..self.m {
                    e[k] -= s * self.work[(k, j)];
                }
            }
            for k in 0..self.m {
                q[(k, i)] = e[k];
            }
        }
        q
    }

    /// Test hook: the per-column betas (shared by both factor layouts).
    pub fn betas(&self) -> &[f64] {
        &self.betas
    }

    /// Test hook: the working matrix holding R and the reflector tails.
    pub fn work(&self) -> &Matrix {
        &self.work
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_qr(m: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(m, n, &mut rng);
        let f = householder_qr(&a).unwrap();
        let q = f.q();
        let r = f.r();
        // A = Q R
        let qr = q.matmul(&r);
        assert!(qr.max_abs_diff(&a) < 1e-10, "A != QR for {m}x{n}");
        // QᵀQ = I
        let qtq = q.transpose().matmul(&q);
        assert!(qtq.max_abs_diff(&Matrix::identity(n)) < 1e-10);
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn qr_reconstructs() {
        check_qr(8, 8, 1);
        check_qr(20, 5, 2);
        check_qr(100, 30, 3);
        check_qr(5, 1, 4);
        // multi-panel shapes (n > PANEL)
        check_qr(120, 33, 5);
        check_qr(200, 80, 6);
        check_qr(90, 90, 7);
    }

    #[test]
    fn blocked_matches_reference_within_panel() {
        // n <= PANEL: the blocked path degenerates to the scalar loop and
        // must match the reference bit for bit
        let mut rng = Rng::new(11);
        let a = Matrix::random(60, PANEL, &mut rng);
        let blocked = householder_qr(&a).unwrap();
        let reference = householder_qr_reference(&a).unwrap();
        assert_eq!(blocked.betas, reference.betas);
        assert_eq!(blocked.work, reference.work);
    }

    #[test]
    fn blocked_matches_reference_multi_panel() {
        for &(m, n, seed) in &[(150usize, 50usize, 21u64), (80, 70, 22), (400, 96, 23)] {
            let mut rng = Rng::new(seed);
            let a = Matrix::random(m, n, &mut rng);
            let rb = householder_qr(&a).unwrap().r();
            let rr = householder_qr_reference(&a).unwrap().r();
            assert!(rb.max_abs_diff(&rr) < 1e-10, "{m}x{n}: R mismatch");
        }
    }

    #[test]
    fn threaded_qr_bit_identical_across_worker_counts() {
        // the trailing updates are matmul_with GEMMs: the factors must be
        // bit-identical whatever the policy says
        let mut rng = Rng::new(31);
        let a = Matrix::random(300, 80, &mut rng);
        let base = householder_qr(&a).unwrap();
        for workers in [2usize, 4, 8] {
            let f = householder_qr_with(&a, ParallelPolicy::with_workers(workers)).unwrap();
            assert_eq!(f.work, base.work, "work differs at workers={workers}");
            assert_eq!(f.betas, base.betas, "betas differ at workers={workers}");
            assert_eq!(f.ts.len(), base.ts.len());
            for (tw, tb) in f.ts.iter().zip(&base.ts) {
                assert_eq!(tw, tb, "T differs at workers={workers}");
            }
        }
    }

    #[test]
    fn qt_application_matches_explicit() {
        let mut rng = Rng::new(9);
        let a = Matrix::random(12, 4, &mut rng);
        let f = householder_qr(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let mut qtb = b.clone();
        f.apply_qt(&mut qtb);
        let explicit = f.q().t_matvec(&b);
        for j in 0..4 {
            assert!((qtb[j] - explicit[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn panel_qt_matches_column_loop_multi_panel() {
        // same factors, both application paths: the panel-resident Qᵀb
        // must track the column-at-a-time oracle over the full vector
        for &(m, n, seed) in &[(150usize, 50usize, 41u64), (90, 90, 42), (400, 96, 43)] {
            let mut rng = Rng::new(seed);
            let a = Matrix::random(m, n, &mut rng);
            let f = householder_qr(&a).unwrap();
            let b: Vec<f64> = (0..m).map(|i| (i as f64 * 0.37).cos()).collect();
            let mut blocked = b.clone();
            let mut scalar = b;
            f.apply_qt(&mut blocked);
            f.apply_qt_reference(&mut scalar);
            let worst = blocked
                .iter()
                .zip(&scalar)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            assert!(worst < 1e-9, "{m}x{n}: panel vs column Qᵀb drift {worst}");
        }
    }

    #[test]
    fn wide_matrix_rejected() {
        let a = Matrix::zeros(3, 5);
        assert!(householder_qr(&a).is_err());
        assert!(householder_qr_reference(&a).is_err());
    }

    #[test]
    fn rank_deficient_does_not_panic() {
        // duplicate columns: QR still completes (R has a zero diagonal)
        let mut rng = Rng::new(10);
        let a = Matrix::random(10, 2, &mut rng);
        let mut dup = Matrix::zeros(10, 4);
        for i in 0..10 {
            dup[(i, 0)] = a[(i, 0)];
            dup[(i, 1)] = a[(i, 1)];
            dup[(i, 2)] = a[(i, 0)];
            dup[(i, 3)] = a[(i, 1)];
        }
        let f = householder_qr(&dup).unwrap();
        let qr = f.q().matmul(&f.r());
        assert!(qr.max_abs_diff(&dup) < 1e-10);
    }

    #[test]
    fn zero_column_handled_in_panel() {
        // an all-zero column inside a panel must yield beta = 0 (H = I)
        let mut rng = Rng::new(12);
        let mut a = Matrix::random(20, 6, &mut rng);
        for i in 0..20 {
            a[(i, 3)] = 0.0;
        }
        let f = householder_qr(&a).unwrap();
        let qr = f.q().matmul(&f.r());
        assert!(qr.max_abs_diff(&a) < 1e-10);
        // and the panel-resident Qᵀb must agree with the column loop
        // around the identity reflector
        let b: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
        let mut blocked = b.clone();
        let mut scalar = b;
        f.apply_qt(&mut blocked);
        f.apply_qt_reference(&mut scalar);
        for (x, y) in blocked.iter().zip(&scalar) {
            assert!((x - y).abs() < 1e-10);
        }
    }
}
