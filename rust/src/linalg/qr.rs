//! Householder QR (the paper's §4.2 factorization, reference version).
//!
//! `householder_qr` produces the compact factors: R (upper triangular,
//! n×n for an m×n input with m >= n) and the Householder vectors, with
//! `apply_qt` to form Qᵀb without materializing Q — exactly what the ELM
//! solve needs (`z = QᵀY`, then back-substitute `Rβ = z`).

use anyhow::{bail, Result};

use super::matrix::Matrix;

/// Compact QR factors of an m×n matrix (m >= n).
pub struct QrFactors {
    /// Householder vectors stored below the diagonal of the working copy;
    /// column j's vector is v_j with v_j[j] = 1 implied.
    work: Matrix,
    /// beta_j = 2 / (v_jᵀ v_j)
    betas: Vec<f64>,
    pub m: usize,
    pub n: usize,
}

/// Householder QR with column-norm stability (no pivoting: ELM design
/// matrices are dense and generically full-rank; the ridge path covers the
/// degenerate case).
pub fn householder_qr(a: &Matrix) -> Result<QrFactors> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        bail!("householder_qr requires rows >= cols, got {m}x{n}");
    }
    let mut w = a.clone();
    let mut betas = vec![0.0; n];

    for j in 0..n {
        // norm of the j-th column below (and including) the diagonal
        let mut norm2 = 0.0;
        for i in j..m {
            norm2 += w[(i, j)] * w[(i, j)];
        }
        let norm = norm2.sqrt();
        if norm == 0.0 {
            betas[j] = 0.0;
            continue;
        }
        let alpha = if w[(j, j)] >= 0.0 { -norm } else { norm };
        // v = x - alpha e1 ; store v (normalized so v[j] = 1)
        let v0 = w[(j, j)] - alpha;
        // v0 can't be 0 since alpha has opposite sign of x0 (or x0 == 0)
        let mut vtv = v0 * v0;
        for i in j + 1..m {
            vtv += w[(i, j)] * w[(i, j)];
        }
        let beta = 2.0 * v0 * v0 / vtv; // after normalization by v0
        // normalize: v[i] /= v0
        for i in j + 1..m {
            w[(i, j)] /= v0;
        }
        // apply H = I - beta v vᵀ to the trailing submatrix
        for col in j + 1..n {
            // s = vᵀ w[:, col]
            let mut s = w[(j, col)];
            for i in j + 1..m {
                s += w[(i, j)] * w[(i, col)];
            }
            s *= beta;
            w[(j, col)] -= s;
            for i in j + 1..m {
                let vij = w[(i, j)];
                w[(i, col)] -= s * vij;
            }
        }
        w[(j, j)] = alpha;
        betas[j] = beta;
    }
    Ok(QrFactors { work: w, betas, m, n })
}

impl QrFactors {
    /// The n×n upper-triangular R.
    pub fn r(&self) -> Matrix {
        let mut r = Matrix::zeros(self.n, self.n);
        for i in 0..self.n {
            for j in i..self.n {
                r[(i, j)] = self.work[(i, j)];
            }
        }
        r
    }

    /// Apply Qᵀ to a length-m vector in place; the first n entries are then
    /// the projection used by the least-squares solve.
    pub fn apply_qt(&self, b: &mut [f64]) {
        assert_eq!(b.len(), self.m);
        for j in 0..self.n {
            let beta = self.betas[j];
            if beta == 0.0 {
                continue;
            }
            // s = vᵀ b (v[j] = 1 implied)
            let mut s = b[j];
            for i in j + 1..self.m {
                s += self.work[(i, j)] * b[i];
            }
            s *= beta;
            b[j] -= s;
            for i in j + 1..self.m {
                b[i] -= s * self.work[(i, j)];
            }
        }
    }

    /// Reconstruct the full m×n Q (test/diagnostic use only).
    pub fn q(&self) -> Matrix {
        let mut q = Matrix::zeros(self.m, self.n);
        for (i, col) in (0..self.n).enumerate() {
            // apply Q to e_i: Q = H_0 H_1 ... H_{n-1}; Q e_i = H_0 (... (H_{n-1} e_i))
            let mut e = vec![0.0; self.m];
            e[col] = 1.0;
            for j in (0..self.n).rev() {
                let beta = self.betas[j];
                if beta == 0.0 {
                    continue;
                }
                let mut s = e[j];
                for k in j + 1..self.m {
                    s += self.work[(k, j)] * e[k];
                }
                s *= beta;
                e[j] -= s;
                for k in j + 1..self.m {
                    e[k] -= s * self.work[(k, j)];
                }
            }
            for k in 0..self.m {
                q[(k, i)] = e[k];
            }
        }
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_qr(m: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(m, n, &mut rng);
        let f = householder_qr(&a).unwrap();
        let q = f.q();
        let r = f.r();
        // A = Q R
        let qr = q.matmul(&r);
        assert!(qr.max_abs_diff(&a) < 1e-10, "A != QR for {m}x{n}");
        // QᵀQ = I
        let qtq = q.transpose().matmul(&q);
        assert!(qtq.max_abs_diff(&Matrix::identity(n)) < 1e-10);
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn qr_reconstructs() {
        check_qr(8, 8, 1);
        check_qr(20, 5, 2);
        check_qr(100, 30, 3);
        check_qr(5, 1, 4);
    }

    #[test]
    fn qt_application_matches_explicit() {
        let mut rng = Rng::new(9);
        let a = Matrix::random(12, 4, &mut rng);
        let f = householder_qr(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64).sin()).collect();
        let mut qtb = b.clone();
        f.apply_qt(&mut qtb);
        let explicit = f.q().t_matvec(&b);
        for j in 0..4 {
            assert!((qtb[j] - explicit[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn wide_matrix_rejected() {
        let a = Matrix::zeros(3, 5);
        assert!(householder_qr(&a).is_err());
    }

    #[test]
    fn rank_deficient_does_not_panic() {
        // duplicate columns: QR still completes (R has a zero diagonal)
        let mut rng = Rng::new(10);
        let a = Matrix::random(10, 2, &mut rng);
        let mut dup = Matrix::zeros(10, 4);
        for i in 0..10 {
            dup[(i, 0)] = a[(i, 0)];
            dup[(i, 1)] = a[(i, 1)];
            dup[(i, 2)] = a[(i, 0)];
            dup[(i, 3)] = a[(i, 1)];
        }
        let f = householder_qr(&dup).unwrap();
        let qr = f.q().matmul(&f.r());
        assert!(qr.max_abs_diff(&dup) < 1e-10);
    }
}
