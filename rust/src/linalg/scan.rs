//! Sequence-parallel recurrence primitives — breaking the time loop.
//!
//! Every `h_block` kernel used to walk timesteps strictly sequentially:
//! the last inherently serial axis in a training path the paper
//! parallelizes everywhere else. Martin & Cundy ("Parallelizing Linear
//! Recurrent Neural Nets Over Sequence Length", arXiv 1709.04057) show
//! that *linear* recurrences `h_t = A_t·h_{t−1} + b_t` admit an exact
//! parallel prefix scan over affine maps, and Hwang & Sung's single-stream
//! chunking motivates the warm-up scheme the nonlinear architectures use.
//! This module provides both halves:
//!
//! * [`RecurrenceMode`] — the policy knob ([`ParallelPolicy::recurrence`])
//!   that selects between the sequential oracle kernels and the chunked
//!   sequence-parallel executors in `elm::arch`.
//! * [`chunk_schedule`] — the fixed chunking of the horizon, a function of
//!   `(horizon, chunk)` alone (it delegates to [`fixed_tiles`]), so the
//!   chunk boundaries — like every other split schedule in the substrate —
//!   never depend on the worker count.
//! * [`Affine`] / [`scan_affine`] — the generic blocked affine prefix scan
//!   for linear recurrences, with composition folded in ascending step
//!   order over the fixed chunk schedule.
//!
//! # Determinism contract
//!
//! [`scan_affine`] obeys the substrate-wide §7.3 discipline: the chunk
//! schedule is fixed by `(horizon, chunk)`, workers execute disjoint chunks
//! via the order-preserving parallel map, and the sequential boundary fold
//! walks composites in chunk order. Consequences, pinned by the in-module
//! tests and `tests/scan_props.rs`:
//!
//! * **Worker-count bit-invariance at any chunk size** — changing the
//!   worker count changes *when* a chunk is processed, never *what* is
//!   computed or in which order results fold.
//! * **Single chunk ≡ sequential, bitwise** — with `chunk >= horizon` the
//!   per-step re-walk starts from `h0` itself and applies the steps one by
//!   one, which *is* the sequential recurrence (scan-of-one-chunk ≡
//!   sequential by construction).
//! * **Multi-chunk drift is reassociation, not error**: later chunks start
//!   from boundary states produced by composed affine maps, which
//!   reassociates the floating-point evaluation versus stepwise
//!   application. The drift per element is bounded by the usual
//!   backward-stable matmul envelope `O(T·n·ε·∏‖A_t‖)`; for bit-exactness
//!   against the sequential oracle use a single chunk (or the FC chunked
//!   executor in `elm::arch::fc`, which keeps the original fold order and
//!   is bit-identical at *every* chunk size).
//!
//! The FC architecture's production path does not route through
//! [`scan_affine`] (its full-lag recurrence composes over `q` lags, not
//! one); `scan_affine` is the reference engine for plain lag-1 linear
//! recurrences and the conformance anchor for the scan discipline itself.

#![forbid(unsafe_code)]

use anyhow::{anyhow, Result};

use super::matrix::Matrix;
use super::policy::{fixed_tiles, par_map, ParallelPolicy};

/// How the `elm::arch` kernels traverse the time axis of the recurrence.
///
/// Carried on [`ParallelPolicy`] and threaded through
/// `arch::h_block_*` → `trainer::hidden_matrix_policy` →
/// `coordinator::CpuElmTrainer`, so both the f64 and f32-born H wires pick
/// the same mode up.
///
/// | Mode | FC | Elman / LSTM / GRU | Jordan / NARMAX |
/// |------|----|--------------------|------------------|
/// | `Sequential` | oracle loop | oracle loop | (recurrence-free) |
/// | `Chunked` | **bit-identical** to `Sequential` at any chunk/worker count (cross-chunk coupling GEMMs precomputed in parallel, folds kept in oracle order) | tail chunk + `warmup` warm-up prefix from a zero state; bit-identical when the warm-up reaches `t = 0`, documented envelope otherwise | identical to `Sequential` (nothing to chunk) |
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecurrenceMode {
    /// Walk the time loop one step at a time — the conformance oracle every
    /// chunked executor is tested against.
    #[default]
    Sequential,
    /// Sequence-parallel traversal over the fixed [`chunk_schedule`].
    Chunked {
        /// Chunk height along the time axis (clamped to >= 1 by consumers).
        /// `chunk >= horizon` degenerates to one chunk, which every
        /// executor guarantees is bitwise identical to `Sequential`.
        chunk: usize,
        /// Warm-up prefix length for the stateful nonlinear architectures
        /// (Elman/LSTM/GRU): each evaluated chunk re-runs `warmup` extra
        /// leading steps from a zero state so the truncated history decays
        /// before the outputs that matter. Ignored by the exact executors
        /// (FC, and the recurrence-free Jordan/NARMAX).
        warmup: usize,
    },
}

/// Fixed chunking of the time axis `[0, horizon)` into `(lo, hi)` ranges of
/// height `chunk` (last chunk may be short). A function of
/// `(horizon, chunk)` alone — never of a worker count — exactly like every
/// other split schedule in the substrate (it *is* [`fixed_tiles`] applied
/// to the time axis).
pub fn chunk_schedule(horizon: usize, chunk: usize) -> Vec<(usize, usize)> {
    fixed_tiles(horizon, chunk)
}

/// An affine map `h ↦ A·h + b` — one step (or one composed chunk) of a
/// linear recurrence `h_t = A_t·h_{t−1} + b_t`.
#[derive(Debug, Clone, PartialEq)]
pub struct Affine {
    /// The linear part (n×n).
    pub a: Matrix,
    /// The offset (length n).
    pub b: Vec<f64>,
}

impl Affine {
    /// The identity map on an `n`-dimensional state.
    pub fn identity(n: usize) -> Affine {
        Affine { a: Matrix::identity(n), b: vec![0.0; n] }
    }

    /// Composition `self ∘ inner`: the map `h ↦ self(inner(h))`, i.e.
    /// `(A₁, b₁) ∘ (A₂, b₂) = (A₁A₂, A₁b₂ + b₁)`. The matmul/matvec run
    /// the sequential kernels — composition is pure, so where it executes
    /// never affects its bits.
    pub fn compose(&self, inner: &Affine) -> Affine {
        let a = self.a.matmul(&inner.a);
        let mut b = self.a.matvec(&inner.b);
        for (bi, &s) in b.iter_mut().zip(self.b.iter()) {
            *bi += s;
        }
        Affine { a, b }
    }

    /// Apply the map to a state: `A·h + b`.
    pub fn apply(&self, h: &[f64]) -> Vec<f64> {
        let mut out = self.a.matvec(h);
        for (oi, &bi) in out.iter_mut().zip(self.b.iter()) {
            *oi += bi;
        }
        out
    }
}

/// Sequential reference for the linear recurrence: step `h0` through every
/// affine map in order, returning all `T` states `h_1..h_T`. This is the
/// oracle [`scan_affine`] is conformance-tested against.
pub fn scan_affine_reference(steps: &[Affine], h0: &[f64]) -> Vec<Vec<f64>> {
    let mut out = Vec::with_capacity(steps.len());
    let mut h = h0.to_vec();
    for s in steps {
        h = s.apply(&h);
        out.push(h.clone());
    }
    out
}

/// Blocked parallel prefix scan for the linear recurrence
/// `h_t = A_t·h_{t−1} + b_t`, returning all `T` states `h_1..h_T`.
///
/// Three phases over the fixed [`chunk_schedule`]`(steps.len(), chunk)`:
///
/// 1. **Compose** (parallel): each chunk folds its steps into one affine
///    composite in ascending step order — pure work, farmed out via the
///    order-preserving parallel map.
/// 2. **Boundary fold** (sequential): composites are applied to `h0` in
///    chunk order, yielding each chunk's entry state.
/// 3. **Re-walk** (parallel): each chunk re-steps from its entry state,
///    emitting the per-step states.
///
/// See the module docs for the determinism contract: bit-invariant across
/// worker counts at any `chunk`, bitwise equal to
/// [`scan_affine_reference`] when the schedule has a single chunk, and
/// within the reassociation envelope otherwise.
///
/// # Errors
///
/// Returns an error if any step's shape disagrees with `h0` (`A` must be
/// n×n and `b` length n), or if a worker fails.
pub fn scan_affine(
    steps: &[Affine],
    h0: &[f64],
    chunk: usize,
    policy: ParallelPolicy,
) -> Result<Vec<Vec<f64>>> {
    let n = h0.len();
    for (t, s) in steps.iter().enumerate() {
        if s.a.rows != n || s.a.cols != n || s.b.len() != n {
            return Err(anyhow!(
                "scan_affine: step {t} has shape A {}x{}, b {} (state is {n})",
                s.a.rows,
                s.a.cols,
                s.b.len()
            ));
        }
    }
    let sched = chunk_schedule(steps.len(), chunk);
    if sched.is_empty() {
        return Ok(Vec::new());
    }
    // phase 1: per-chunk composites, ascending step order inside each chunk
    let composites = par_map(sched.clone(), policy, |(lo, hi)| {
        let mut c = steps[lo].clone();
        for s in &steps[lo + 1..hi] {
            c = s.compose(&c);
        }
        Ok(c)
    })?;
    // phase 2: boundary states, folded sequentially in chunk order
    let mut entry = Vec::with_capacity(sched.len());
    let mut h = h0.to_vec();
    for c in &composites {
        entry.push(h.clone());
        h = c.apply(&h);
    }
    // phase 3: per-chunk stepwise re-walk from the entry state
    let items: Vec<((usize, usize), Vec<f64>)> =
        sched.into_iter().zip(entry).collect();
    let parts = par_map(items, policy, |((lo, hi), start)| {
        let mut out = Vec::with_capacity(hi - lo);
        let mut h = start;
        for s in &steps[lo..hi] {
            h = s.apply(&h);
            out.push(h.clone());
        }
        Ok(out)
    })?;
    Ok(parts.into_iter().flatten().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_steps(t: usize, n: usize, seed: u64) -> Vec<Affine> {
        let mut rng = Rng::new(seed);
        (0..t)
            .map(|_| {
                let mut a = Matrix::random(n, n, &mut rng);
                // keep ∏‖A‖ tame so long scans stay well-scaled
                for v in a.data_mut() {
                    *v *= 0.3;
                }
                let b = (0..n).map(|_| rng.normal()).collect();
                Affine { a, b }
            })
            .collect()
    }

    #[test]
    fn chunk_schedule_is_fixed_tiles_on_the_time_axis() {
        assert_eq!(chunk_schedule(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(chunk_schedule(0, 4), vec![]);
        assert_eq!(chunk_schedule(3, 0), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(chunk_schedule(5, 100), vec![(0, 5)]);
    }

    #[test]
    fn identity_composes_and_applies_trivially() {
        let id = Affine::identity(3);
        let f = &random_steps(1, 3, 1)[0];
        assert_eq!(f.compose(&id), *f);
        let h = vec![0.25, -1.5, 3.0];
        assert_eq!(id.apply(&h), h);
    }

    #[test]
    fn compose_matches_stepwise_application() {
        let steps = random_steps(2, 4, 2);
        let h = vec![0.5, -0.25, 1.0, 2.0];
        let two = steps[1].compose(&steps[0]);
        let stepped = steps[1].apply(&steps[0].apply(&h));
        for (a, b) in two.apply(&h).iter().zip(stepped.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn single_chunk_is_bitwise_sequential() {
        for t in [0usize, 1, 7, 33] {
            let steps = random_steps(t, 5, 3);
            let h0 = vec![0.1; 5];
            let want = scan_affine_reference(&steps, &h0);
            let got = scan_affine(
                &steps,
                &h0,
                t.max(1),
                ParallelPolicy::with_workers(4),
            )
            .unwrap();
            assert_eq!(got, want, "t={t}: one chunk must be the oracle bits");
        }
    }

    #[test]
    fn worker_count_never_changes_bits_at_any_chunk() {
        let steps = random_steps(29, 4, 4);
        let h0 = vec![0.2, -0.3, 0.0, 1.0];
        for chunk in [1usize, 3, 7, 29, 64] {
            let base =
                scan_affine(&steps, &h0, chunk, ParallelPolicy::sequential()).unwrap();
            for workers in [2usize, 4, 8] {
                let got = scan_affine(
                    &steps,
                    &h0,
                    chunk,
                    ParallelPolicy::with_workers(workers),
                )
                .unwrap();
                assert_eq!(got, base, "chunk={chunk} workers={workers}");
            }
        }
    }

    #[test]
    fn multi_chunk_drift_stays_inside_the_reassociation_envelope() {
        let steps = random_steps(64, 4, 5);
        let h0 = vec![0.4, 0.1, -0.7, 0.9];
        let want = scan_affine_reference(&steps, &h0);
        for chunk in [1usize, 5, 16] {
            let got =
                scan_affine(&steps, &h0, chunk, ParallelPolicy::with_workers(4)).unwrap();
            for (t, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                for (a, b) in g.iter().zip(w.iter()) {
                    assert!(
                        (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
                        "chunk={chunk} t={t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn shape_mismatch_is_a_typed_error() {
        let mut steps = random_steps(3, 4, 6);
        steps[1].b.pop();
        let err = scan_affine(&steps, &[0.0; 4], 2, ParallelPolicy::sequential())
            .unwrap_err();
        assert!(err.to_string().contains("step 1"), "{err}");
    }

    #[test]
    fn recurrence_mode_defaults_to_sequential() {
        assert_eq!(RecurrenceMode::default(), RecurrenceMode::Sequential);
    }
}
