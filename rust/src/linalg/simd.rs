//! Pinned-width SIMD microkernels — the innermost arithmetic of the
//! GEMM/Gram substrate, with runtime ISA dispatch and a bit-identity
//! contract against the portable scalar kernels.
//!
//! The blocked kernels of [`matrix`](super::matrix) /
//! [`matrix32`](super::matrix32) used to hand their inner loops
//! (`axpy4` / `axpy4_widen`, the rank-4 Gram row update) to the
//! autovectorizer. That code was vectorizer-*friendly* but the lane width
//! was never pinned: a compiler upgrade, a cost-model change, or a cold
//! inlining decision could silently drop the hot loops back to scalar
//! issue. This module pins them: explicit `std::arch` AVX2 kernels
//! (4-lane f64, 8-lane f32 wire) behind a one-time
//! `is_x86_feature_detected!` dispatch, with the pre-SIMD scalar loops
//! kept verbatim as the portable fallback *and* the reference the SIMD
//! paths are bit-compared against.
//!
//! # Kernel families
//!
//! | kernel | shape | used by |
//! |---|---|---|
//! | [`gemm_tile_f64`] / [`gemm_tile_widen`] | 4×`jb` register tile (4×8 accumulators over a packed B panel) | `matmul_rows` / `matmul_rows_widen` |
//! | [`gemm_row_f64`] / [`gemm_row_widen`] | 1×`jb` row tile (the ≤3 tail rows of a row block) | same |
//! | [`gram4_f64`] / [`gram4_widen`] | rank-4 update of one G row segment | `gram_rows` / `gram_rows_widen` |
//! | [`axpy_f64`] / [`axpy_widen`] / [`axpy_wx`] | `out[j] += a·x[j]` | Gram tail rows, `t_matvec`, `t_matvec_widen` |
//! | [`axpy_sub_f64`] | `out[j] -= a·x[j]` | QR panel reflector application (`factor_panel`, `apply_qt`) |
//!
//! Every family comes in a dispatched flavor (listed above) and a public
//! `*_scalar` twin. The scalar twins are not test scaffolding only — they
//! are the exact code the dispatcher runs on non-AVX2 hardware (and under
//! `OPT_PR_ELM_FORCE_SCALAR=1`), so pinning `dispatched ≡ scalar` in
//! `tests/simd_props.rs` pins cross-ISA reproducibility.
//!
//! # Determinism contract
//!
//! The SIMD kernels are **bit-identical** to their scalar twins, at every
//! shape (including all remainder-lane counts) and in both precisions, by
//! construction:
//!
//! * accumulators are **element-independent** — no horizontal reductions,
//!   no lane shuffles; out element `j` is touched only by lane `j % width`
//!   of its own vector, in exactly the per-element operation sequence of
//!   the scalar loop (ascending `p` within a panel, ascending `(kk, p)`
//!   across panels);
//! * multiplies and adds stay **separate** (`vmulpd` + `vaddpd`, never
//!   contracted) unless [`FmaMode::Relaxed`] is requested, so every lane
//!   performs the same two IEEE roundings the scalar expression performs;
//! * widening conversions (`f32 → f64`) are exact in either ISA;
//! * remainder lanes run the scalar expression itself.
//!
//! Zero multiplicands are never skipped (`0 × ∞` must stay NaN), matching
//! the scalar kernels.
//!
//! # The `FmaMode::Relaxed` envelope
//!
//! [`FmaMode::Relaxed`] (opt-in via
//! [`ParallelPolicy::with_fma`](super::ParallelPolicy::with_fma), default
//! off) lets the vector lanes of the GEMM/Gram microkernels use fused
//! multiply-add when the host has FMA. Each fused term drops the
//! intermediate product rounding, so per output element the drift versus
//! the exact kernels is bounded by the sum of those roundings:
//!
//! ```text
//!   |C_relaxed[i,j] − C_exact[i,j]|  ≤  k · 2⁻⁵³ · (|A|·|B|)[i,j]
//! ```
//!
//! (`k` = inner dimension; `(|A|·|B|)` the absolute-value product — the
//! property suite asserts this bound element-wise). Worker-count
//! invariance is **unchanged** under Relaxed: the schedule stays fixed and
//! every element is still produced whole by one worker. What Relaxed gives
//! up is only bit-identity with the scalar/exact kernels. Remainder lanes
//! stay unfused (they run the scalar expression), and the scalar fallback
//! ignores Relaxed entirely — both inside the documented envelope.

#![deny(unsafe_op_in_unsafe_fn)]

use std::sync::OnceLock;

/// Fused-multiply-add contraction mode of the SIMD GEMM/Gram microkernels.
/// Carried by [`ParallelPolicy`](super::ParallelPolicy); see the module
/// docs for the exact/relaxed contract and the Relaxed error envelope.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FmaMode {
    /// Separate multiply + add in every lane — bit-identical to the scalar
    /// kernels. The default, and the mode every conformance suite pins.
    #[default]
    Exact,
    /// Allow fused multiply-add in the vector lanes when the host has FMA
    /// (falls back to [`FmaMode::Exact`] when it does not). Bounded drift,
    /// documented in the module docs; worker-count bit-invariance is
    /// preserved.
    Relaxed,
}

/// Which instruction-set path the dispatched kernels execute on this host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsaPath {
    /// Portable scalar kernels (the pre-SIMD inner loops, kept verbatim).
    Scalar,
    /// 256-bit AVX2 kernels: 4-lane f64, 8-lane f32 wire.
    Avx2,
}

#[cfg(target_arch = "x86_64")]
fn detect_avx2() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_avx2() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn detect_fma() -> bool {
    is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_fma() -> bool {
    false
}

static ISA: OnceLock<IsaPath> = OnceLock::new();
static FMA: OnceLock<bool> = OnceLock::new();

/// The ISA path every dispatched kernel in this module executes, detected
/// once per process (`is_x86_feature_detected!`) and cached. Setting
/// `OPT_PR_ELM_FORCE_SCALAR=1` in the environment pins the scalar path on
/// any hardware — the escape hatch for cross-ISA reproduction runs and for
/// benchmarking the fallback.
pub fn active_isa() -> IsaPath {
    *ISA.get_or_init(|| {
        let forced = std::env::var("OPT_PR_ELM_FORCE_SCALAR")
            .is_ok_and(|v| v != "0" && !v.is_empty());
        if !forced && detect_avx2() {
            IsaPath::Avx2
        } else {
            IsaPath::Scalar
        }
    })
}

/// Lower-case name of the active ISA path (`"avx2"` / `"scalar"`) — what
/// the bench meta record emits so regression gates know which path a
/// `BENCH_linalg.json` measured.
pub fn isa_name() -> &'static str {
    match active_isa() {
        IsaPath::Scalar => "scalar",
        IsaPath::Avx2 => "avx2",
    }
}

/// Whether [`FmaMode::Relaxed`] can actually fuse on this host: true only
/// when the AVX2 path is active *and* the FMA feature is present. When
/// false, Relaxed silently behaves as [`FmaMode::Exact`].
pub fn fma_available() -> bool {
    *FMA.get_or_init(|| active_isa() == IsaPath::Avx2 && detect_fma())
}

#[inline]
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn use_fma(fma: FmaMode) -> bool {
    fma == FmaMode::Relaxed && fma_available()
}

// ---------------------------------------------------------------------------
// scalar kernels — the pre-SIMD inner loops, verbatim. These are both the
// non-x86 execution path and the bit-identity oracle for the AVX2 path.
// ---------------------------------------------------------------------------

/// `out[j] += a · x[j]`, scalar 4-wide unrolled (the pre-SIMD `axpy4`).
/// Each `out[j]` sees exactly one add per call, so element-wise
/// accumulation order is untouched by the unroll.
pub fn axpy_f64_scalar(a: f64, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), out.len(), "axpy_f64_scalar: length mismatch");
    let n = out.len();
    let mut j = 0;
    while j + 4 <= n {
        out[j] += a * x[j];
        out[j + 1] += a * x[j + 1];
        out[j + 2] += a * x[j + 2];
        out[j + 3] += a * x[j + 3];
        j += 4;
    }
    while j < n {
        out[j] += a * x[j];
        j += 1;
    }
}

/// `out[j] -= a · x[j]`, scalar — the reflector-application update of the
/// QR panels (`c −= s·v`).
pub fn axpy_sub_f64_scalar(a: f64, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), out.len(), "axpy_sub_f64_scalar: length mismatch");
    let n = out.len();
    let mut j = 0;
    while j + 4 <= n {
        out[j] -= a * x[j];
        out[j + 1] -= a * x[j + 1];
        out[j + 2] -= a * x[j + 2];
        out[j + 3] -= a * x[j + 3];
        j += 4;
    }
    while j < n {
        out[j] -= a * x[j];
        j += 1;
    }
}

/// `out[j] += a · x[j]` with f32 operands widened at the multiply into the
/// f64 accumulator (the pre-SIMD `axpy4_widen`). The coefficient widening
/// is exact, so this is precisely [`axpy_wx_scalar`] with `a` pre-widened —
/// one body, bit for bit.
pub fn axpy_widen_scalar(a: f32, x: &[f32], out: &mut [f64]) {
    axpy_wx_scalar(a as f64, x, out);
}

/// `out[j] += a · (x[j] as f64)` with an f64 coefficient and an f32 vector
/// (the `t_matvec_widen` fold: `out[j] += vᵢ · row[j]`).
pub fn axpy_wx_scalar(a: f64, x: &[f32], out: &mut [f64]) {
    assert_eq!(x.len(), out.len(), "axpy_wx_scalar: length mismatch");
    let n = out.len();
    let mut j = 0;
    while j + 4 <= n {
        out[j] += a * x[j] as f64;
        out[j + 1] += a * x[j + 1] as f64;
        out[j + 2] += a * x[j + 2] as f64;
        out[j + 3] += a * x[j + 3] as f64;
        j += 4;
    }
    while j < n {
        out[j] += a * x[j] as f64;
        j += 1;
    }
}

/// Shape contract shared by both GEMM tile flavors (scalar and SIMD):
/// four equal-length A rows, a row-major `kb × jb` panel, and an output
/// slab holding four `jb`-long rows at stride `ldo`. Real (release-mode)
/// asserts — the microkernels index the panel as `panel[p·jb + j]` with
/// unchecked loads, so a misshapen panel must fail loudly, never be
/// misread (see the [`PackedPanels`](super::matrix::PackedPanels)
/// contract).
fn check_gemm_tile<T>(arows: &[&[T]; 4], panel: &[T], jb: usize, out_len: usize, ldo: usize) {
    let kb = arows[0].len();
    assert!(
        arows.iter().all(|r| r.len() == kb),
        "gemm tile: ragged A rows (expected 4 rows of {kb})"
    );
    assert_eq!(
        panel.len(),
        kb * jb,
        "gemm tile: panel len {} != kb*jb = {}*{}",
        panel.len(),
        kb,
        jb
    );
    assert!(jb <= ldo, "gemm tile: jb {jb} exceeds output stride {ldo}");
    assert!(
        out_len >= 3 * ldo + jb,
        "gemm tile: out slab len {out_len} too short for 4 rows at stride {ldo} width {jb}"
    );
}

/// Shape contract of the 1-row GEMM kernels: `panel` is `kb × jb`
/// row-major, `out` exactly `jb` long.
fn check_gemm_row<T>(arow: &[T], panel: &[T], jb: usize, out_len: usize) {
    assert_eq!(
        panel.len(),
        arow.len() * jb,
        "gemm row: panel len {} != kb*jb = {}*{}",
        panel.len(),
        arow.len(),
        jb
    );
    assert_eq!(out_len, jb, "gemm row: out len {out_len} != jb {jb}");
}

/// 4-row GEMM tile, scalar: `out[r·ldo + j] += Σ_p arows[r][p] ·
/// panel[p·jb + j]` — the pre-SIMD row-at-a-time AXPY loop over four rows.
pub fn gemm_tile_f64_scalar(
    arows: [&[f64]; 4],
    panel: &[f64],
    jb: usize,
    out: &mut [f64],
    ldo: usize,
) {
    check_gemm_tile(&arows, panel, jb, out.len(), ldo);
    for (r, arow) in arows.iter().enumerate() {
        let orow = &mut out[r * ldo..r * ldo + jb];
        for (p, &a) in arow.iter().enumerate() {
            axpy_f64_scalar(a, &panel[p * jb..(p + 1) * jb], orow);
        }
    }
}

/// 1-row GEMM tile, scalar (tail rows of a row block).
pub fn gemm_row_f64_scalar(arow: &[f64], panel: &[f64], jb: usize, out: &mut [f64]) {
    check_gemm_row(arow, panel, jb, out.len());
    for (p, &a) in arow.iter().enumerate() {
        axpy_f64_scalar(a, &panel[p * jb..(p + 1) * jb], out);
    }
}

/// 4-row accumulate-widen GEMM tile, scalar: f32 operands, f64
/// accumulators (the pre-SIMD widen AXPY loop over four rows).
pub fn gemm_tile_widen_scalar(
    arows: [&[f32]; 4],
    panel: &[f32],
    jb: usize,
    out: &mut [f64],
    ldo: usize,
) {
    check_gemm_tile(&arows, panel, jb, out.len(), ldo);
    for (r, arow) in arows.iter().enumerate() {
        let orow = &mut out[r * ldo..r * ldo + jb];
        for (p, &a) in arow.iter().enumerate() {
            axpy_widen_scalar(a, &panel[p * jb..(p + 1) * jb], orow);
        }
    }
}

/// 1-row accumulate-widen GEMM tile, scalar.
pub fn gemm_row_widen_scalar(arow: &[f32], panel: &[f32], jb: usize, out: &mut [f64]) {
    check_gemm_row(arow, panel, jb, out.len());
    for (p, &a) in arow.iter().enumerate() {
        axpy_widen_scalar(a, &panel[p * jb..(p + 1) * jb], out);
    }
}

/// Rank-4 Gram row update, scalar: `grow[b] += x₀·r₀[b] + x₁·r₁[b] +
/// x₂·r₂[b] + x₃·r₃[b]` with the sum associated left-to-right — the
/// pre-SIMD 4-row Gram microkernel body, one G row segment per call.
pub fn gram4_f64_scalar(x: [f64; 4], rs: [&[f64]; 4], grow: &mut [f64]) {
    let n = grow.len();
    assert!(
        rs.iter().all(|r| r.len() == n),
        "gram4: row segments must match the output segment length {n}"
    );
    for b in 0..n {
        grow[b] += x[0] * rs[0][b] + x[1] * rs[1][b] + x[2] * rs[2][b] + x[3] * rs[3][b];
    }
}

/// Rank-4 accumulate-widen Gram row update, scalar: f32 rows widened at
/// the multiply, f64 accumulation, same left-to-right association as
/// [`gram4_f64_scalar`].
pub fn gram4_widen_scalar(x: [f32; 4], rs: [&[f32]; 4], grow: &mut [f64]) {
    let n = grow.len();
    assert!(
        rs.iter().all(|r| r.len() == n),
        "gram4_widen: row segments must match the output segment length {n}"
    );
    let (x0, x1, x2, x3) = (x[0] as f64, x[1] as f64, x[2] as f64, x[3] as f64);
    for b in 0..n {
        grow[b] += x0 * rs[0][b] as f64
            + x1 * rs[1][b] as f64
            + x2 * rs[2][b] as f64
            + x3 * rs[3][b] as f64;
    }
}

// ---------------------------------------------------------------------------
// AVX2 kernels. Every body mirrors its scalar twin's per-element operation
// sequence exactly (see the module docs); `$madd` is either separate
// mul+add (exact) or vfmadd (relaxed). Remainder lanes run the scalar
// expression inline.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// acc ← acc + a·b, separate mul + add (two IEEE roundings — the exact
    /// mode's lane operation).
    #[inline]
    #[target_feature(enable = "avx2")]
    // register-only intrinsics are safe-callable inside target_feature fns
    // on newer toolchains, making the explicit block redundant there
    #[allow(unused_unsafe)]
    pub(super) unsafe fn madd_exact(a: __m256d, b: __m256d, acc: __m256d) -> __m256d {
        unsafe { _mm256_add_pd(acc, _mm256_mul_pd(a, b)) }
    }

    /// acc ← fma(a, b, acc), one rounding (the Relaxed mode's lane
    /// operation).
    #[inline]
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    #[allow(unused_unsafe)]
    pub(super) unsafe fn madd_fused(a: __m256d, b: __m256d, acc: __m256d) -> __m256d {
        unsafe { _mm256_fmadd_pd(a, b, acc) }
    }

    macro_rules! axpy_like_body {
        ($a:ident, $x:ident, $out:ident, $combine:ident, $scalar_op:tt) => {{
            let n = $out.len();
            let av = _mm256_set1_pd($a);
            let xp = $x.as_ptr();
            let op = $out.as_mut_ptr();
            let mut j = 0usize;
            while j + 4 <= n {
                let xv = _mm256_loadu_pd(xp.add(j));
                let ov = _mm256_loadu_pd(op.add(j));
                _mm256_storeu_pd(op.add(j), $combine(ov, _mm256_mul_pd(av, xv)));
                j += 4;
            }
            while j < n {
                *op.add(j) = *op.add(j) $scalar_op $a * *xp.add(j);
                j += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_f64(a: f64, x: &[f64], out: &mut [f64]) {
        unsafe { axpy_like_body!(a, x, out, _mm256_add_pd, +) }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_sub_f64(a: f64, x: &[f64], out: &mut [f64]) {
        unsafe { axpy_like_body!(a, x, out, _mm256_sub_pd, -) }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_wx(a: f64, x: &[f32], out: &mut [f64]) {
        unsafe {
            let n = out.len();
            let av = _mm256_set1_pd(a);
            let xp = x.as_ptr();
            let op = out.as_mut_ptr();
            let mut j = 0usize;
            while j + 4 <= n {
                let xv = _mm256_cvtps_pd(_mm_loadu_ps(xp.add(j)));
                let ov = _mm256_loadu_pd(op.add(j));
                _mm256_storeu_pd(op.add(j), _mm256_add_pd(ov, _mm256_mul_pd(av, xv)));
                j += 4;
            }
            while j < n {
                *op.add(j) += a * *xp.add(j) as f64;
                j += 1;
            }
        }
    }

    // 4×jb register-tiled GEMM: the 4×8 C tile lives in 8 ymm accumulators
    // across the whole p loop (loaded from C once, stored once), B panel
    // rows consumed lane-contiguously. Per C element the accumulation
    // order over p is ascending — identical to the scalar AXPY loop.
    macro_rules! gemm_tile_f64_body {
        ($arows:ident, $panel:ident, $jb:ident, $out:ident, $ldo:ident, $madd:ident) => {{
            let kb = $arows[0].len();
            let pp = $panel.as_ptr();
            let op = $out.as_mut_ptr();
            let mut j = 0usize;
            while j + 8 <= $jb {
                let mut c00 = _mm256_loadu_pd(op.add(j));
                let mut c01 = _mm256_loadu_pd(op.add(j + 4));
                let mut c10 = _mm256_loadu_pd(op.add($ldo + j));
                let mut c11 = _mm256_loadu_pd(op.add($ldo + j + 4));
                let mut c20 = _mm256_loadu_pd(op.add(2 * $ldo + j));
                let mut c21 = _mm256_loadu_pd(op.add(2 * $ldo + j + 4));
                let mut c30 = _mm256_loadu_pd(op.add(3 * $ldo + j));
                let mut c31 = _mm256_loadu_pd(op.add(3 * $ldo + j + 4));
                for p in 0..kb {
                    let b0 = _mm256_loadu_pd(pp.add(p * $jb + j));
                    let b1 = _mm256_loadu_pd(pp.add(p * $jb + j + 4));
                    let a0 = _mm256_set1_pd(*$arows[0].get_unchecked(p));
                    c00 = $madd(a0, b0, c00);
                    c01 = $madd(a0, b1, c01);
                    let a1 = _mm256_set1_pd(*$arows[1].get_unchecked(p));
                    c10 = $madd(a1, b0, c10);
                    c11 = $madd(a1, b1, c11);
                    let a2 = _mm256_set1_pd(*$arows[2].get_unchecked(p));
                    c20 = $madd(a2, b0, c20);
                    c21 = $madd(a2, b1, c21);
                    let a3 = _mm256_set1_pd(*$arows[3].get_unchecked(p));
                    c30 = $madd(a3, b0, c30);
                    c31 = $madd(a3, b1, c31);
                }
                _mm256_storeu_pd(op.add(j), c00);
                _mm256_storeu_pd(op.add(j + 4), c01);
                _mm256_storeu_pd(op.add($ldo + j), c10);
                _mm256_storeu_pd(op.add($ldo + j + 4), c11);
                _mm256_storeu_pd(op.add(2 * $ldo + j), c20);
                _mm256_storeu_pd(op.add(2 * $ldo + j + 4), c21);
                _mm256_storeu_pd(op.add(3 * $ldo + j), c30);
                _mm256_storeu_pd(op.add(3 * $ldo + j + 4), c31);
                j += 8;
            }
            while j + 4 <= $jb {
                let mut c0 = _mm256_loadu_pd(op.add(j));
                let mut c1 = _mm256_loadu_pd(op.add($ldo + j));
                let mut c2 = _mm256_loadu_pd(op.add(2 * $ldo + j));
                let mut c3 = _mm256_loadu_pd(op.add(3 * $ldo + j));
                for p in 0..kb {
                    let b0 = _mm256_loadu_pd(pp.add(p * $jb + j));
                    c0 = $madd(_mm256_set1_pd(*$arows[0].get_unchecked(p)), b0, c0);
                    c1 = $madd(_mm256_set1_pd(*$arows[1].get_unchecked(p)), b0, c1);
                    c2 = $madd(_mm256_set1_pd(*$arows[2].get_unchecked(p)), b0, c2);
                    c3 = $madd(_mm256_set1_pd(*$arows[3].get_unchecked(p)), b0, c3);
                }
                _mm256_storeu_pd(op.add(j), c0);
                _mm256_storeu_pd(op.add($ldo + j), c1);
                _mm256_storeu_pd(op.add(2 * $ldo + j), c2);
                _mm256_storeu_pd(op.add(3 * $ldo + j), c3);
                j += 4;
            }
            while j < $jb {
                let mut r = 0usize;
                while r < 4 {
                    let ar = $arows[r];
                    let mut c = *op.add(r * $ldo + j);
                    for p in 0..kb {
                        c += *ar.get_unchecked(p) * *pp.add(p * $jb + j);
                    }
                    *op.add(r * $ldo + j) = c;
                    r += 1;
                }
                j += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_tile_f64(
        arows: [&[f64]; 4],
        panel: &[f64],
        jb: usize,
        out: &mut [f64],
        ldo: usize,
    ) {
        unsafe { gemm_tile_f64_body!(arows, panel, jb, out, ldo, madd_exact) }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn gemm_tile_f64_fma(
        arows: [&[f64]; 4],
        panel: &[f64],
        jb: usize,
        out: &mut [f64],
        ldo: usize,
    ) {
        unsafe { gemm_tile_f64_body!(arows, panel, jb, out, ldo, madd_fused) }
    }

    // widen twin: f32 A entries broadcast as f64, f32 B lanes converted
    // 4-at-a-time (exact) before the f64 madd.
    macro_rules! gemm_tile_widen_body {
        ($arows:ident, $panel:ident, $jb:ident, $out:ident, $ldo:ident, $madd:ident) => {{
            let kb = $arows[0].len();
            let pp = $panel.as_ptr();
            let op = $out.as_mut_ptr();
            let mut j = 0usize;
            while j + 8 <= $jb {
                let mut c00 = _mm256_loadu_pd(op.add(j));
                let mut c01 = _mm256_loadu_pd(op.add(j + 4));
                let mut c10 = _mm256_loadu_pd(op.add($ldo + j));
                let mut c11 = _mm256_loadu_pd(op.add($ldo + j + 4));
                let mut c20 = _mm256_loadu_pd(op.add(2 * $ldo + j));
                let mut c21 = _mm256_loadu_pd(op.add(2 * $ldo + j + 4));
                let mut c30 = _mm256_loadu_pd(op.add(3 * $ldo + j));
                let mut c31 = _mm256_loadu_pd(op.add(3 * $ldo + j + 4));
                for p in 0..kb {
                    let b0 = _mm256_cvtps_pd(_mm_loadu_ps(pp.add(p * $jb + j)));
                    let b1 = _mm256_cvtps_pd(_mm_loadu_ps(pp.add(p * $jb + j + 4)));
                    let a0 = _mm256_set1_pd(*$arows[0].get_unchecked(p) as f64);
                    c00 = $madd(a0, b0, c00);
                    c01 = $madd(a0, b1, c01);
                    let a1 = _mm256_set1_pd(*$arows[1].get_unchecked(p) as f64);
                    c10 = $madd(a1, b0, c10);
                    c11 = $madd(a1, b1, c11);
                    let a2 = _mm256_set1_pd(*$arows[2].get_unchecked(p) as f64);
                    c20 = $madd(a2, b0, c20);
                    c21 = $madd(a2, b1, c21);
                    let a3 = _mm256_set1_pd(*$arows[3].get_unchecked(p) as f64);
                    c30 = $madd(a3, b0, c30);
                    c31 = $madd(a3, b1, c31);
                }
                _mm256_storeu_pd(op.add(j), c00);
                _mm256_storeu_pd(op.add(j + 4), c01);
                _mm256_storeu_pd(op.add($ldo + j), c10);
                _mm256_storeu_pd(op.add($ldo + j + 4), c11);
                _mm256_storeu_pd(op.add(2 * $ldo + j), c20);
                _mm256_storeu_pd(op.add(2 * $ldo + j + 4), c21);
                _mm256_storeu_pd(op.add(3 * $ldo + j), c30);
                _mm256_storeu_pd(op.add(3 * $ldo + j + 4), c31);
                j += 8;
            }
            while j + 4 <= $jb {
                let mut c0 = _mm256_loadu_pd(op.add(j));
                let mut c1 = _mm256_loadu_pd(op.add($ldo + j));
                let mut c2 = _mm256_loadu_pd(op.add(2 * $ldo + j));
                let mut c3 = _mm256_loadu_pd(op.add(3 * $ldo + j));
                for p in 0..kb {
                    let b0 = _mm256_cvtps_pd(_mm_loadu_ps(pp.add(p * $jb + j)));
                    c0 = $madd(_mm256_set1_pd(*$arows[0].get_unchecked(p) as f64), b0, c0);
                    c1 = $madd(_mm256_set1_pd(*$arows[1].get_unchecked(p) as f64), b0, c1);
                    c2 = $madd(_mm256_set1_pd(*$arows[2].get_unchecked(p) as f64), b0, c2);
                    c3 = $madd(_mm256_set1_pd(*$arows[3].get_unchecked(p) as f64), b0, c3);
                }
                _mm256_storeu_pd(op.add(j), c0);
                _mm256_storeu_pd(op.add($ldo + j), c1);
                _mm256_storeu_pd(op.add(2 * $ldo + j), c2);
                _mm256_storeu_pd(op.add(3 * $ldo + j), c3);
                j += 4;
            }
            while j < $jb {
                let mut r = 0usize;
                while r < 4 {
                    let ar = $arows[r];
                    let mut c = *op.add(r * $ldo + j);
                    for p in 0..kb {
                        c += *ar.get_unchecked(p) as f64 * *pp.add(p * $jb + j) as f64;
                    }
                    *op.add(r * $ldo + j) = c;
                    r += 1;
                }
                j += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_tile_widen(
        arows: [&[f32]; 4],
        panel: &[f32],
        jb: usize,
        out: &mut [f64],
        ldo: usize,
    ) {
        unsafe { gemm_tile_widen_body!(arows, panel, jb, out, ldo, madd_exact) }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn gemm_tile_widen_fma(
        arows: [&[f32]; 4],
        panel: &[f32],
        jb: usize,
        out: &mut [f64],
        ldo: usize,
    ) {
        unsafe { gemm_tile_widen_body!(arows, panel, jb, out, ldo, madd_fused) }
    }

    macro_rules! gemm_row_f64_body {
        ($arow:ident, $panel:ident, $jb:ident, $out:ident, $madd:ident) => {{
            let kb = $arow.len();
            let pp = $panel.as_ptr();
            let op = $out.as_mut_ptr();
            let mut j = 0usize;
            while j + 8 <= $jb {
                let mut c0 = _mm256_loadu_pd(op.add(j));
                let mut c1 = _mm256_loadu_pd(op.add(j + 4));
                for p in 0..kb {
                    let av = _mm256_set1_pd(*$arow.get_unchecked(p));
                    c0 = $madd(av, _mm256_loadu_pd(pp.add(p * $jb + j)), c0);
                    c1 = $madd(av, _mm256_loadu_pd(pp.add(p * $jb + j + 4)), c1);
                }
                _mm256_storeu_pd(op.add(j), c0);
                _mm256_storeu_pd(op.add(j + 4), c1);
                j += 8;
            }
            while j + 4 <= $jb {
                let mut c0 = _mm256_loadu_pd(op.add(j));
                for p in 0..kb {
                    let av = _mm256_set1_pd(*$arow.get_unchecked(p));
                    c0 = $madd(av, _mm256_loadu_pd(pp.add(p * $jb + j)), c0);
                }
                _mm256_storeu_pd(op.add(j), c0);
                j += 4;
            }
            while j < $jb {
                let mut c = *op.add(j);
                for p in 0..kb {
                    c += *$arow.get_unchecked(p) * *pp.add(p * $jb + j);
                }
                *op.add(j) = c;
                j += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_row_f64(arow: &[f64], panel: &[f64], jb: usize, out: &mut [f64]) {
        unsafe { gemm_row_f64_body!(arow, panel, jb, out, madd_exact) }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn gemm_row_f64_fma(arow: &[f64], panel: &[f64], jb: usize, out: &mut [f64]) {
        unsafe { gemm_row_f64_body!(arow, panel, jb, out, madd_fused) }
    }

    macro_rules! gemm_row_widen_body {
        ($arow:ident, $panel:ident, $jb:ident, $out:ident, $madd:ident) => {{
            let kb = $arow.len();
            let pp = $panel.as_ptr();
            let op = $out.as_mut_ptr();
            let mut j = 0usize;
            while j + 8 <= $jb {
                let mut c0 = _mm256_loadu_pd(op.add(j));
                let mut c1 = _mm256_loadu_pd(op.add(j + 4));
                for p in 0..kb {
                    let av = _mm256_set1_pd(*$arow.get_unchecked(p) as f64);
                    c0 = $madd(av, _mm256_cvtps_pd(_mm_loadu_ps(pp.add(p * $jb + j))), c0);
                    c1 = $madd(av, _mm256_cvtps_pd(_mm_loadu_ps(pp.add(p * $jb + j + 4))), c1);
                }
                _mm256_storeu_pd(op.add(j), c0);
                _mm256_storeu_pd(op.add(j + 4), c1);
                j += 8;
            }
            while j + 4 <= $jb {
                let mut c0 = _mm256_loadu_pd(op.add(j));
                for p in 0..kb {
                    let av = _mm256_set1_pd(*$arow.get_unchecked(p) as f64);
                    c0 = $madd(av, _mm256_cvtps_pd(_mm_loadu_ps(pp.add(p * $jb + j))), c0);
                }
                _mm256_storeu_pd(op.add(j), c0);
                j += 4;
            }
            while j < $jb {
                let mut c = *op.add(j);
                for p in 0..kb {
                    c += *$arow.get_unchecked(p) as f64 * *pp.add(p * $jb + j) as f64;
                }
                *op.add(j) = c;
                j += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_row_widen(arow: &[f32], panel: &[f32], jb: usize, out: &mut [f64]) {
        unsafe { gemm_row_widen_body!(arow, panel, jb, out, madd_exact) }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn gemm_row_widen_fma(
        arow: &[f32],
        panel: &[f32],
        jb: usize,
        out: &mut [f64],
    ) {
        unsafe { gemm_row_widen_body!(arow, panel, jb, out, madd_fused) }
    }

    // rank-4 Gram row update: per output element the term sum keeps the
    // scalar's left-to-right association (m0, then +m1, +m2, +m3), then one
    // add into G — identical expression tree per lane.
    macro_rules! gram4_f64_body {
        ($x:ident, $rs:ident, $grow:ident, $madd:ident) => {{
            let n = $grow.len();
            let x0 = _mm256_set1_pd($x[0]);
            let x1 = _mm256_set1_pd($x[1]);
            let x2 = _mm256_set1_pd($x[2]);
            let x3 = _mm256_set1_pd($x[3]);
            let (r0, r1, r2, r3) =
                ($rs[0].as_ptr(), $rs[1].as_ptr(), $rs[2].as_ptr(), $rs[3].as_ptr());
            let gp = $grow.as_mut_ptr();
            let mut b = 0usize;
            while b + 4 <= n {
                let mut t = _mm256_mul_pd(x0, _mm256_loadu_pd(r0.add(b)));
                t = $madd(x1, _mm256_loadu_pd(r1.add(b)), t);
                t = $madd(x2, _mm256_loadu_pd(r2.add(b)), t);
                t = $madd(x3, _mm256_loadu_pd(r3.add(b)), t);
                _mm256_storeu_pd(gp.add(b), _mm256_add_pd(_mm256_loadu_pd(gp.add(b)), t));
                b += 4;
            }
            while b < n {
                *gp.add(b) += $x[0] * *r0.add(b)
                    + $x[1] * *r1.add(b)
                    + $x[2] * *r2.add(b)
                    + $x[3] * *r3.add(b);
                b += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gram4_f64(x: [f64; 4], rs: [&[f64]; 4], grow: &mut [f64]) {
        unsafe { gram4_f64_body!(x, rs, grow, madd_exact) }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn gram4_f64_fma(x: [f64; 4], rs: [&[f64]; 4], grow: &mut [f64]) {
        unsafe { gram4_f64_body!(x, rs, grow, madd_fused) }
    }

    macro_rules! gram4_widen_body {
        ($x:ident, $rs:ident, $grow:ident, $madd:ident) => {{
            let n = $grow.len();
            let (x0s, x1s, x2s, x3s) =
                ($x[0] as f64, $x[1] as f64, $x[2] as f64, $x[3] as f64);
            let x0 = _mm256_set1_pd(x0s);
            let x1 = _mm256_set1_pd(x1s);
            let x2 = _mm256_set1_pd(x2s);
            let x3 = _mm256_set1_pd(x3s);
            let (r0, r1, r2, r3) =
                ($rs[0].as_ptr(), $rs[1].as_ptr(), $rs[2].as_ptr(), $rs[3].as_ptr());
            let gp = $grow.as_mut_ptr();
            let mut b = 0usize;
            while b + 4 <= n {
                let mut t = _mm256_mul_pd(x0, _mm256_cvtps_pd(_mm_loadu_ps(r0.add(b))));
                t = $madd(x1, _mm256_cvtps_pd(_mm_loadu_ps(r1.add(b))), t);
                t = $madd(x2, _mm256_cvtps_pd(_mm_loadu_ps(r2.add(b))), t);
                t = $madd(x3, _mm256_cvtps_pd(_mm_loadu_ps(r3.add(b))), t);
                _mm256_storeu_pd(gp.add(b), _mm256_add_pd(_mm256_loadu_pd(gp.add(b)), t));
                b += 4;
            }
            while b < n {
                *gp.add(b) += x0s * *r0.add(b) as f64
                    + x1s * *r1.add(b) as f64
                    + x2s * *r2.add(b) as f64
                    + x3s * *r3.add(b) as f64;
                b += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gram4_widen(x: [f32; 4], rs: [&[f32]; 4], grow: &mut [f64]) {
        unsafe { gram4_widen_body!(x, rs, grow, madd_exact) }
    }

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub(super) unsafe fn gram4_widen_fma(x: [f32; 4], rs: [&[f32]; 4], grow: &mut [f64]) {
        unsafe { gram4_widen_body!(x, rs, grow, madd_fused) }
    }
}

// ---------------------------------------------------------------------------
// dispatched entry points
// ---------------------------------------------------------------------------

/// `out[j] += a · x[j]` — dispatched (always exact; equal lengths
/// asserted). Bit-identical to [`axpy_f64_scalar`] on every ISA path.
pub fn axpy_f64(a: f64, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), out.len(), "axpy_f64: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        return unsafe { avx2::axpy_f64(a, x, out) };
    }
    axpy_f64_scalar(a, x, out);
}

/// `out[j] -= a · x[j]` — dispatched (always exact). Bit-identical to
/// [`axpy_sub_f64_scalar`] on every ISA path.
pub fn axpy_sub_f64(a: f64, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), out.len(), "axpy_sub_f64: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        return unsafe { avx2::axpy_sub_f64(a, x, out) };
    }
    axpy_sub_f64_scalar(a, x, out);
}

/// `out[j] += a · x[j]`, f32 operands widened at the multiply — dispatched
/// (always exact). Bit-identical to [`axpy_widen_scalar`]; delegates to
/// [`axpy_wx`] with the coefficient pre-widened (an exact conversion).
pub fn axpy_widen(a: f32, x: &[f32], out: &mut [f64]) {
    axpy_wx(a as f64, x, out);
}

/// `out[j] += a · (x[j] as f64)`, f64 coefficient × f32 vector —
/// dispatched (always exact). Bit-identical to [`axpy_wx_scalar`].
pub fn axpy_wx(a: f64, x: &[f32], out: &mut [f64]) {
    assert_eq!(x.len(), out.len(), "axpy_wx: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        return unsafe { avx2::axpy_wx(a, x, out) };
    }
    axpy_wx_scalar(a, x, out);
}

/// 4-row register-tiled GEMM microkernel, dispatched: `out[r·ldo + j] +=
/// Σ_p arows[r][p] · panel[p·jb + j]` for `r ∈ 0..4`, `j ∈ 0..jb`, over a
/// row-major `kb × jb` [`PackedPanels`](super::matrix::PackedPanels)
/// panel. Under [`FmaMode::Exact`] bit-identical to
/// [`gemm_tile_f64_scalar`]; under [`FmaMode::Relaxed`] within the module
/// envelope (and still worker-invariant).
pub fn gemm_tile_f64(
    arows: [&[f64]; 4],
    panel: &[f64],
    jb: usize,
    out: &mut [f64],
    ldo: usize,
    fma: FmaMode,
) {
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        check_gemm_tile(&arows, panel, jb, out.len(), ldo);
        if use_fma(fma) {
            return unsafe { avx2::gemm_tile_f64_fma(arows, panel, jb, out, ldo) };
        }
        return unsafe { avx2::gemm_tile_f64(arows, panel, jb, out, ldo) };
    }
    let _ = fma;
    gemm_tile_f64_scalar(arows, panel, jb, out, ldo);
}

/// 1-row GEMM microkernel, dispatched (the ≤3 tail rows of a row block).
/// Same contract as [`gemm_tile_f64`] with `out` exactly `jb` long.
pub fn gemm_row_f64(arow: &[f64], panel: &[f64], jb: usize, out: &mut [f64], fma: FmaMode) {
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        check_gemm_row(arow, panel, jb, out.len());
        if use_fma(fma) {
            return unsafe { avx2::gemm_row_f64_fma(arow, panel, jb, out) };
        }
        return unsafe { avx2::gemm_row_f64(arow, panel, jb, out) };
    }
    let _ = fma;
    gemm_row_f64_scalar(arow, panel, jb, out);
}

/// 4-row accumulate-widen GEMM microkernel, dispatched: f32 operands
/// (8-lane wire), f64 accumulators. Under [`FmaMode::Exact`] bit-identical
/// to [`gemm_tile_widen_scalar`] — and therefore, on f32-born operands, to
/// the f64 kernels on the widened operands.
pub fn gemm_tile_widen(
    arows: [&[f32]; 4],
    panel: &[f32],
    jb: usize,
    out: &mut [f64],
    ldo: usize,
    fma: FmaMode,
) {
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        check_gemm_tile(&arows, panel, jb, out.len(), ldo);
        if use_fma(fma) {
            return unsafe { avx2::gemm_tile_widen_fma(arows, panel, jb, out, ldo) };
        }
        return unsafe { avx2::gemm_tile_widen(arows, panel, jb, out, ldo) };
    }
    let _ = fma;
    gemm_tile_widen_scalar(arows, panel, jb, out, ldo);
}

/// 1-row accumulate-widen GEMM microkernel, dispatched.
pub fn gemm_row_widen(arow: &[f32], panel: &[f32], jb: usize, out: &mut [f64], fma: FmaMode) {
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        check_gemm_row(arow, panel, jb, out.len());
        if use_fma(fma) {
            return unsafe { avx2::gemm_row_widen_fma(arow, panel, jb, out) };
        }
        return unsafe { avx2::gemm_row_widen(arow, panel, jb, out) };
    }
    let _ = fma;
    gemm_row_widen_scalar(arow, panel, jb, out);
}

/// Rank-4 Gram row update, dispatched: `grow[b] += x₀·rs₀[b] + x₁·rs₁[b] +
/// x₂·rs₂[b] + x₃·rs₃[b]` with the scalar's left-to-right term
/// association in every lane. Under [`FmaMode::Exact`] bit-identical to
/// [`gram4_f64_scalar`].
pub fn gram4_f64(x: [f64; 4], rs: [&[f64]; 4], grow: &mut [f64], fma: FmaMode) {
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        let n = grow.len();
        assert!(
            rs.iter().all(|r| r.len() == n),
            "gram4_f64: row segments must match the output segment length {n}"
        );
        if use_fma(fma) {
            return unsafe { avx2::gram4_f64_fma(x, rs, grow) };
        }
        return unsafe { avx2::gram4_f64(x, rs, grow) };
    }
    let _ = fma;
    gram4_f64_scalar(x, rs, grow);
}

/// Rank-4 accumulate-widen Gram row update, dispatched (f32 rows, f64
/// accumulation). Under [`FmaMode::Exact`] bit-identical to
/// [`gram4_widen_scalar`].
pub fn gram4_widen(x: [f32; 4], rs: [&[f32]; 4], grow: &mut [f64], fma: FmaMode) {
    #[cfg(target_arch = "x86_64")]
    if active_isa() == IsaPath::Avx2 {
        let n = grow.len();
        assert!(
            rs.iter().all(|r| r.len() == n),
            "gram4_widen: row segments must match the output segment length {n}"
        );
        if use_fma(fma) {
            return unsafe { avx2::gram4_widen_fma(x, rs, grow) };
        }
        return unsafe { avx2::gram4_widen(x, rs, grow) };
    }
    let _ = fma;
    gram4_widen_scalar(x, rs, grow);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn randv32(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn bits_eq(a: &[f64], b: &[f64]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn isa_detection_is_cached_and_consistent() {
        let first = active_isa();
        assert_eq!(first, active_isa());
        match first {
            IsaPath::Scalar => assert_eq!(isa_name(), "scalar"),
            IsaPath::Avx2 => assert_eq!(isa_name(), "avx2"),
        }
        if fma_available() {
            assert_eq!(first, IsaPath::Avx2, "FMA requires the AVX2 path");
        }
    }

    #[test]
    fn dispatched_axpys_match_scalar_every_tail() {
        for n in 0..=17 {
            let x = randv(n, 10 + n as u64);
            let x32 = randv32(n, 20 + n as u64);
            let base = randv(n, 30 + n as u64);

            let (mut d, mut s) = (base.clone(), base.clone());
            axpy_f64(0.37, &x, &mut d);
            axpy_f64_scalar(0.37, &x, &mut s);
            assert!(bits_eq(&d, &s), "axpy_f64 n={n}");

            let (mut d, mut s) = (base.clone(), base.clone());
            axpy_sub_f64(0.37, &x, &mut d);
            axpy_sub_f64_scalar(0.37, &x, &mut s);
            assert!(bits_eq(&d, &s), "axpy_sub_f64 n={n}");

            let (mut d, mut s) = (base.clone(), base.clone());
            axpy_widen(0.37, &x32, &mut d);
            axpy_widen_scalar(0.37, &x32, &mut s);
            assert!(bits_eq(&d, &s), "axpy_widen n={n}");

            let (mut d, mut s) = (base.clone(), base);
            axpy_wx(0.37, &x32, &mut d);
            axpy_wx_scalar(0.37, &x32, &mut s);
            assert!(bits_eq(&d, &s), "axpy_wx n={n}");
        }
    }

    #[test]
    fn gemm_tile_dispatch_matches_scalar_every_tail() {
        for jb in 1..=17usize {
            for &kb in &[1usize, 5, 64] {
                let ldo = jb + 3; // deliberately strided output
                let a: Vec<Vec<f64>> =
                    (0..4).map(|r| randv(kb, (jb * 10 + kb + r) as u64)).collect();
                let panel = randv(kb * jb, (jb * 100 + kb) as u64);
                let base = randv(3 * ldo + jb, (jb + kb) as u64);
                let (mut d, mut s) = (base.clone(), base);
                gemm_tile_f64(
                    [&a[0], &a[1], &a[2], &a[3]],
                    &panel,
                    jb,
                    &mut d,
                    ldo,
                    FmaMode::Exact,
                );
                gemm_tile_f64_scalar([&a[0], &a[1], &a[2], &a[3]], &panel, jb, &mut s, ldo);
                assert!(bits_eq(&d, &s), "gemm_tile_f64 jb={jb} kb={kb}");
            }
        }
    }

    #[test]
    fn gram4_dispatch_matches_scalar_every_tail() {
        for n in 1..=17usize {
            let rows: Vec<Vec<f64>> = (0..4).map(|r| randv(n, (n + r) as u64)).collect();
            let x = [0.3, -1.2, 0.07, 2.5];
            let base = randv(n, 99 + n as u64);
            let (mut d, mut s) = (base.clone(), base);
            gram4_f64(x, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut d, FmaMode::Exact);
            gram4_f64_scalar(x, [&rows[0], &rows[1], &rows[2], &rows[3]], &mut s);
            assert!(bits_eq(&d, &s), "gram4_f64 n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "panel len")]
    fn misshapen_panel_rejected_in_release() {
        let a = [1.0f64, 2.0];
        let panel = vec![0.0f64; 5]; // kb*jb would be 2*3 = 6
        let mut out = vec![0.0f64; 3];
        gemm_row_f64(&a, &panel, 3, &mut out, FmaMode::Exact);
    }

    #[test]
    #[should_panic(expected = "axpy_f64_scalar: length mismatch")]
    fn axpy_scalar_rejects_length_mismatch_in_release() {
        let x = [1.0f64, 2.0];
        let mut out = vec![0.0f64; 3];
        axpy_f64_scalar(2.0, &x, &mut out);
    }

    #[test]
    #[should_panic(expected = "axpy_sub_f64_scalar: length mismatch")]
    fn axpy_sub_scalar_rejects_length_mismatch_in_release() {
        let x = [1.0f64, 2.0];
        let mut out = vec![0.0f64; 3];
        axpy_sub_f64_scalar(2.0, &x, &mut out);
    }

    #[test]
    #[should_panic(expected = "axpy_wx_scalar: length mismatch")]
    fn axpy_wx_scalar_rejects_length_mismatch_in_release() {
        let x = [1.0f32, 2.0];
        let mut out = vec![0.0f64; 3];
        axpy_wx_scalar(2.0, &x, &mut out);
    }
}
