//! Triangular solves and the user-facing least-squares entry points.
//!
//! Every entry point has a `ParallelPolicy`-threaded form (`lstsq_qr_with`,
//! `lstsq_tsqr`); the policy-free names are sequential wrappers. The
//! threaded forms are **bit-identical** to their sequential twins at any
//! worker count — the GEMM/Gram/TSQR splits are fixed schedules (see
//! [`super::policy`]) — so callers may thread freely without changing β.

use anyhow::{bail, Result};

use super::cholesky::cholesky_solve;
use super::matrix::Matrix;
use super::policy::ParallelPolicy;
use super::qr::householder_qr_with;

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower_triangular(l: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = l.rows;
    if l.cols != n || b.len() != n {
        bail!("triangular solve shape mismatch");
    }
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        let d = l[(i, i)];
        if d.abs() < 1e-300 {
            bail!("singular triangular system at row {i}");
        }
        y[i] = s / d;
    }
    Ok(y)
}

/// Solve R x = b for upper-triangular R (back substitution — Alg. §4.2).
pub fn solve_upper_triangular(r: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = r.rows;
    if r.cols != n || b.len() != n {
        bail!("triangular solve shape mismatch");
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= r[(i, k)] * x[k];
        }
        let d = r[(i, i)];
        if d.abs() < 1e-300 {
            bail!("singular triangular system at row {i}");
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Relative rank check on an upper-triangular factor's diagonal: a pivot
/// below 1e-10 of the largest means the system is numerically
/// rank-deficient — random features can collide — and back-substitution
/// would amplify noise. Shared by the QR and TSQR solve paths.
pub(crate) fn upper_triangular_deficient(r: &Matrix) -> bool {
    let max_diag = (0..r.rows).map(|i| r[(i, i)].abs()).fold(0.0, f64::max);
    max_diag == 0.0 || (0..r.rows).any(|i| r[(i, i)].abs() < 1e-10 * max_diag)
}

/// Least squares min ‖Ax − b‖ via Householder QR: the paper's §4.2 method
/// (QR then back-substitution, never forming the pseudo-inverse).
/// Sequential wrapper around [`lstsq_qr_with`].
pub fn lstsq_qr(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    lstsq_qr_with(a, b, ParallelPolicy::sequential())
}

/// Least squares via the blocked Householder QR with the trailing-update
/// GEMMs (and the rank-deficiency ridge fallback's Gram) threaded per
/// `policy`. Bit-identical to [`lstsq_qr`] at any worker count: the GEMM
/// row tiles and Gram chunks are fixed schedules, and Qᵀb runs the
/// panel-resident single-threaded path either way.
pub fn lstsq_qr_with(a: &Matrix, b: &[f64], policy: ParallelPolicy) -> Result<Vec<f64>> {
    if b.len() != a.rows {
        bail!("lstsq shape mismatch: A is {}x{}, b has {}", a.rows, a.cols, b.len());
    }
    let f = householder_qr_with(a, policy)?;
    let mut z = b.to_vec();
    f.apply_qt(&mut z);
    let r = f.r();
    if upper_triangular_deficient(&r) {
        return lstsq_ridge_from_parts(&a.gram_with(policy), &a.t_matvec(b), 1e-8);
    }
    match solve_upper_triangular(&r, &z[..a.cols]) {
        Ok(x) => Ok(x),
        Err(_) => lstsq_ridge_from_parts(&a.gram_with(policy), &a.t_matvec(b), 1e-8),
    }
}

/// Least squares via the parallel TSQR tree (§4.2): A is split into
/// fixed-height row blocks (independent of the worker count — only the
/// workers executing the tree vary), each factored independently, then
/// reduced pairwise. Bit-identical for any `policy.workers` (see
/// [`super::tsqr`]); the answer matches [`lstsq_qr`] to factorization
/// rounding, including the same rank-deficiency guard and ridge fallback.
pub fn lstsq_tsqr(a: &Matrix, b: &[f64], policy: ParallelPolicy) -> Result<Vec<f64>> {
    if b.len() != a.rows {
        bail!("lstsq shape mismatch: A is {}x{}, b has {}", a.rows, a.cols, b.len());
    }
    if a.rows < a.cols {
        bail!("lstsq_tsqr requires rows >= cols, got {}x{}", a.rows, a.cols);
    }
    // block height: tall enough to amortize the per-block QR, fixed so the
    // tree shape (and therefore the bits) never depends on the worker count
    let block = (4 * a.cols).max(256);
    let mut blocks = Vec::with_capacity(a.rows.div_ceil(block));
    let mut i = 0;
    while i < a.rows {
        let hi = (i + block).min(a.rows);
        blocks.push((a.submatrix(i, hi, 0, a.cols), b[i..hi].to_vec()));
        i = hi;
    }
    let acc = super::tsqr::TsqrAccumulator::reduce(a.cols, blocks, policy)?;
    // TSQR's R has the same diagonal magnitudes as the direct QR's, so the
    // lstsq_qr rank guard applies unchanged
    if acc.r_factor().map_or(true, upper_triangular_deficient) {
        return lstsq_ridge_from_parts(&a.gram_with(policy), &a.t_matvec(b), 1e-8);
    }
    match acc.solve() {
        Ok(x) => Ok(x),
        Err(_) => lstsq_ridge_from_parts(&a.gram_with(policy), &a.t_matvec(b), 1e-8),
    }
}

/// Ridge least squares from the already-accumulated normal equations:
/// solves (G + λI) x = c. This is the coordinator's streaming path — G and
/// c come from the `elm_gram` artifacts block by block.
pub fn lstsq_ridge_from_parts(g: &Matrix, c: &[f64], lambda: f64) -> Result<Vec<f64>> {
    let n = g.rows;
    if g.cols != n || c.len() != n {
        bail!("ridge shape mismatch");
    }
    let mut greg = g.clone();
    // scale-invariant regularization: λ relative to mean diagonal
    let mean_diag = (0..n).map(|i| g[(i, i)]).sum::<f64>() / n as f64;
    let reg = lambda * mean_diag.max(1e-12);
    for i in 0..n {
        greg[(i, i)] += reg;
    }
    cholesky_solve(&greg, c)
}

/// Ridge least squares from (A, b) directly.
pub fn lstsq_ridge(a: &Matrix, b: &[f64], lambda: f64) -> Result<Vec<f64>> {
    lstsq_ridge_from_parts(&a.gram(), &a.t_matvec(b), lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn triangular_solves_invert() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(6, 6, &mut rng);
        let mut l = Matrix::zeros(6, 6);
        let mut r = Matrix::zeros(6, 6);
        for i in 0..6 {
            for j in 0..6 {
                if i >= j {
                    l[(i, j)] = a[(i, j)] + if i == j { 3.0 } else { 0.0 };
                }
                if j >= i {
                    r[(i, j)] = a[(i, j)] + if i == j { 3.0 } else { 0.0 };
                }
            }
        }
        let x: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let bl = l.matvec(&x);
        let br = r.matvec(&x);
        let xl = solve_lower_triangular(&l, &bl).unwrap();
        let xr = solve_upper_triangular(&r, &br).unwrap();
        for i in 0..6 {
            assert!((xl[i] - x[i]).abs() < 1e-10);
            assert!((xr[i] - x[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn lstsq_exact_on_square() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(5, 5, &mut rng);
        let x_true = vec![1.0, -1.0, 2.0, 0.5, -0.25];
        let b = a.matvec(&x_true);
        let x = lstsq_qr(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn lstsq_minimizes_residual() {
        // overdetermined: residual must be orthogonal to the column space
        let mut rng = Rng::new(3);
        let a = Matrix::random(40, 6, &mut rng);
        let b: Vec<f64> = (0..40).map(|i| (i as f64 * 0.17).sin()).collect();
        let x = lstsq_qr(&a, &b).unwrap();
        let ax = a.matvec(&x);
        let resid: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let at_r = a.t_matvec(&resid);
        for v in at_r {
            assert!(v.abs() < 1e-8, "residual not orthogonal: {v}");
        }
    }

    #[test]
    fn ridge_matches_qr_when_well_conditioned() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(60, 8, &mut rng);
        let b: Vec<f64> = (0..60).map(|i| (i as f64 * 0.11).cos()).collect();
        let xq = lstsq_qr(&a, &b).unwrap();
        let xr = lstsq_ridge(&a, &b, 1e-12).unwrap();
        for (q, r) in xq.iter().zip(&xr) {
            assert!((q - r).abs() < 1e-6);
        }
    }

    #[test]
    fn tsqr_matches_qr_and_falls_back_when_deficient() {
        let mut rng = Rng::new(6);
        // well-conditioned: tree solve ≈ direct solve
        let a = Matrix::random(300, 7, &mut rng);
        let b: Vec<f64> = (0..300).map(|i| (i as f64 * 0.13).sin()).collect();
        let xq = lstsq_qr(&a, &b).unwrap();
        let xt = lstsq_tsqr(&a, &b, ParallelPolicy::with_workers(4)).unwrap();
        for (p, q) in xt.iter().zip(&xq) {
            assert!((p - q).abs() < 1e-8, "{p} vs {q}");
        }
        // duplicated column: both paths must take the identical ridge
        // fallback instead of back-substituting through a noise pivot
        let mut dup = Matrix::zeros(300, 8);
        for i in 0..300 {
            for j in 0..7 {
                dup[(i, j)] = a[(i, j)];
            }
            dup[(i, 7)] = a[(i, 0)];
        }
        let xq = lstsq_qr(&dup, &b).unwrap();
        let xt = lstsq_tsqr(&dup, &b, ParallelPolicy::with_workers(4)).unwrap();
        assert!(xt.iter().all(|v| v.is_finite()));
        for (p, q) in xt.iter().zip(&xq) {
            assert!((p - q).abs() < 1e-9, "ridge fallbacks differ: {p} vs {q}");
        }
        // underdetermined stays an error (parity with householder_qr)
        let wide = Matrix::zeros(3, 5);
        assert!(lstsq_tsqr(&wide, &[0.0; 3], ParallelPolicy::with_workers(2)).is_err());
    }

    #[test]
    fn threaded_lstsq_qr_bit_identical_to_sequential() {
        let mut rng = Rng::new(8);
        let a = Matrix::random(500, 60, &mut rng);
        let b: Vec<f64> = (0..500).map(|i| (i as f64 * 0.07).sin()).collect();
        let base = lstsq_qr(&a, &b).unwrap();
        for workers in [2usize, 4, 8] {
            let x = lstsq_qr_with(&a, &b, ParallelPolicy::with_workers(workers)).unwrap();
            assert_eq!(x, base, "β bits differ at workers={workers}");
        }
    }

    #[test]
    fn rank_deficient_falls_back() {
        // exactly duplicated column: QR hits a zero pivot, ridge kicks in
        let mut rng = Rng::new(5);
        let base = Matrix::random(30, 3, &mut rng);
        let mut a = Matrix::zeros(30, 4);
        for i in 0..30 {
            for j in 0..3 {
                a[(i, j)] = base[(i, j)];
            }
            a[(i, 3)] = base[(i, 0)]; // dup of column 0
        }
        let b: Vec<f64> = (0..30).map(|i| i as f64 * 0.05).collect();
        let x = lstsq_qr(&a, &b).unwrap();
        assert!(x.iter().all(|v| v.is_finite()));
        // the fit must still be as good as the rank-3 solution
        let x3 = lstsq_qr(&base, &b).unwrap();
        let r4: f64 = {
            let ax = a.matvec(&x);
            b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum()
        };
        let r3: f64 = {
            let ax = base.matvec(&x3);
            b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum()
        };
        assert!(r4 <= r3 + 1e-6);
    }
}
