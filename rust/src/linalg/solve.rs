//! Triangular solves and the user-facing least-squares entry points.
//!
//! Every entry point has a `ParallelPolicy`-threaded form (`lstsq_qr_with`,
//! `lstsq_tsqr`); the policy-free names are sequential wrappers. The
//! threaded forms are **bit-identical** to their sequential twins at any
//! worker count — the GEMM/Gram/TSQR splits are fixed schedules (see
//! [`super::policy`]) — so callers may thread freely without changing β.
//!
//! # Failure semantics
//!
//! Failures are typed [`SolveError`](crate::robust::SolveError) values
//! (wrapped in `anyhow::Error`), and the least-squares entry points
//! degrade along the uniform ladder in [`crate::robust::ladder`]: primary
//! QR/TSQR back-substitution → ridge normal equations with escalating λ →
//! typed failure. The `_report` variants return the
//! [`SolveReport`](crate::robust::SolveReport) describing which rung
//! produced β; the plain names discard it. Pivot guards are **relative**
//! (1e-10 of the largest |diagonal|, matching the rank-deficiency check),
//! so consistently-scaled-small systems solve instead of tripping the old
//! absolute `1e-300` bail, and non-finite pivots are reported as poisoned
//! inputs rather than silently propagating NaN into β.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::robust::error::SolveError;
use crate::robust::ladder::{all_finite, ridge_ladder_solve, RIDGE_LADDER};
use crate::robust::report::{DeficiencyVerdict, SolveReport, SolveStrategyKind};

use super::cholesky::cholesky_solve;
use super::matrix::Matrix;
use super::policy::ParallelPolicy;
use super::qr::householder_qr_with;

/// Relative pivot/rank tolerance shared by the triangular solves and the
/// deficiency verdict: a pivot below `1e-10 ×` the largest |diagonal| is
/// treated as rank-collapsed.
pub(crate) const RELATIVE_PIVOT_TOL: f64 = 1e-10;

/// Pivot guard shared by both triangular solves: non-finite pivots are
/// poisoned inputs, pivots below the *relative* tolerance are singular.
fn check_pivot(d: f64, row: usize, max_diag: f64) -> Result<()> {
    if !d.is_finite() {
        return Err(SolveError::NonFinitePivot { row }.into());
    }
    if max_diag == 0.0 || d.abs() < RELATIVE_PIVOT_TOL * max_diag {
        return Err(SolveError::SingularPivot { row, pivot: d, max_diag }.into());
    }
    Ok(())
}

fn max_abs_diag(m: &Matrix) -> f64 {
    // lint: fold-order-pinned -- max is order-free on the NaN-free abs values
    (0..m.rows).map(|i| m[(i, i)].abs()).fold(0.0, f64::max)
}

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower_triangular(l: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = l.rows;
    if l.cols != n || b.len() != n {
        return Err(SolveError::ShapeMismatch {
            context: "triangular solve",
            detail: format!("L is {}x{}, b has {}", l.rows, l.cols, b.len()),
        }
        .into());
    }
    let max_diag = max_abs_diag(l);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        let d = l[(i, i)];
        check_pivot(d, i, max_diag)?;
        y[i] = s / d;
    }
    Ok(y)
}

/// Solve R x = b for upper-triangular R (back substitution — Alg. §4.2).
pub fn solve_upper_triangular(r: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = r.rows;
    if r.cols != n || b.len() != n {
        return Err(SolveError::ShapeMismatch {
            context: "triangular solve",
            detail: format!("R is {}x{}, b has {}", r.rows, r.cols, b.len()),
        }
        .into());
    }
    let max_diag = max_abs_diag(r);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= r[(i, k)] * x[k];
        }
        let d = r[(i, i)];
        check_pivot(d, i, max_diag)?;
        x[i] = s / d;
    }
    Ok(x)
}

/// Rank verdict on an upper-triangular factor's diagonal: non-finite
/// entries mean poisoned inputs, a pivot below [`RELATIVE_PIVOT_TOL`] of
/// the largest means numerically collapsed features. Shared by the QR and
/// TSQR solve paths (and, inverted, by [`upper_triangular_deficient`]).
pub(crate) fn diag_verdict(r: &Matrix) -> DeficiencyVerdict {
    for i in 0..r.rows {
        if !r[(i, i)].is_finite() {
            return DeficiencyVerdict::NonFinite { row: i };
        }
    }
    let max_diag = max_abs_diag(r);
    if max_diag == 0.0 {
        return DeficiencyVerdict::RankDeficient { pivot: 0 };
    }
    for i in 0..r.rows {
        if r[(i, i)].abs() < RELATIVE_PIVOT_TOL * max_diag {
            return DeficiencyVerdict::RankDeficient { pivot: i };
        }
    }
    DeficiencyVerdict::FullRank
}

/// True when back-substitution through `r` would amplify noise (rank
/// collapse) or propagate poison (non-finite diagonal) — the guard the
/// QR/TSQR strategies consult before their primary solve.
pub(crate) fn upper_triangular_deficient(r: &Matrix) -> bool {
    !diag_verdict(r).is_clean()
}

/// Least squares min ‖Ax − b‖ via Householder QR: the paper's §4.2 method
/// (QR then back-substitution, never forming the pseudo-inverse).
/// Sequential wrapper around [`lstsq_qr_with`].
pub fn lstsq_qr(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    lstsq_qr_with(a, b, ParallelPolicy::sequential())
}

/// [`lstsq_qr_with`] discarding the report.
pub fn lstsq_qr_with(a: &Matrix, b: &[f64], policy: ParallelPolicy) -> Result<Vec<f64>> {
    lstsq_qr_report(a, b, policy).map(|(x, _)| x)
}

/// Least squares via the blocked Householder QR with the trailing-update
/// GEMMs (and any ridge-fallback Gram) threaded per `policy`, returning
/// the [`SolveReport`] alongside β. Bit-identical to [`lstsq_qr`] at any
/// worker count: the GEMM row tiles and Gram chunks are fixed schedules,
/// and Qᵀb runs the panel-resident single-threaded path either way.
///
/// Degradation: a clean factor back-substitutes (rung `primary`); a
/// deficient/poisoned factor — or a non-finite primary β — climbs the
/// ridge ladder on the normal equations; exhaustion is a typed error.
pub fn lstsq_qr_report(
    a: &Matrix,
    b: &[f64],
    policy: ParallelPolicy,
) -> Result<(Vec<f64>, SolveReport)> {
    let mut report = SolveReport::new(SolveStrategyKind::Qr);
    if b.len() != a.rows {
        return Err(SolveError::ShapeMismatch {
            context: "lstsq",
            detail: format!("A is {}x{}, b has {}", a.rows, a.cols, b.len()),
        }
        .into());
    }
    let f = householder_qr_with(a, policy)?;
    let mut z = b.to_vec();
    f.apply_qt(&mut z);
    let r = f.r();
    report.verdict = diag_verdict(&r);
    if report.verdict.is_clean() {
        if let Ok(x) = solve_upper_triangular(&r, &z[..a.cols]) {
            if all_finite(&x) {
                return Ok((x, report));
            }
        }
        report.retries += 1;
    }
    let beta = ridge_ladder_solve(
        &a.gram_with(policy),
        &a.t_matvec(b),
        RIDGE_LADDER[0],
        false,
        &mut report,
    )?;
    Ok((beta, report))
}

/// [`lstsq_tsqr_report`] discarding the report.
pub fn lstsq_tsqr(a: &Matrix, b: &[f64], policy: ParallelPolicy) -> Result<Vec<f64>> {
    lstsq_tsqr_report(a, b, policy).map(|(x, _)| x)
}

/// Least squares via the parallel TSQR tree (§4.2): A is split into
/// fixed-height row blocks (independent of the worker count — only the
/// workers executing the tree vary), each factored independently, then
/// reduced pairwise. Bit-identical for any `policy.workers` (see
/// [`super::tsqr`]); the answer matches [`lstsq_qr`] to factorization
/// rounding, including the same rank verdict and the same ridge ladder.
pub fn lstsq_tsqr_report(
    a: &Matrix,
    b: &[f64],
    policy: ParallelPolicy,
) -> Result<(Vec<f64>, SolveReport)> {
    let mut report = SolveReport::new(SolveStrategyKind::Tsqr);
    if b.len() != a.rows {
        return Err(SolveError::ShapeMismatch {
            context: "lstsq",
            detail: format!("A is {}x{}, b has {}", a.rows, a.cols, b.len()),
        }
        .into());
    }
    if a.rows < a.cols {
        return Err(SolveError::Underdetermined { rows: a.rows, cols: a.cols }.into());
    }
    // block height: tall enough to amortize the per-block QR, fixed so the
    // tree shape (and therefore the bits) never depends on the worker count
    let block = (4 * a.cols).max(256);
    let mut blocks = Vec::with_capacity(a.rows.div_ceil(block));
    let mut i = 0;
    while i < a.rows {
        let hi = (i + block).min(a.rows);
        blocks.push((a.submatrix(i, hi, 0, a.cols), b[i..hi].to_vec()));
        i = hi;
    }
    let acc = super::tsqr::TsqrAccumulator::reduce(a.cols, blocks, policy)?;
    // TSQR's R has the same diagonal magnitudes as the direct QR's, so the
    // lstsq_qr rank verdict applies unchanged
    report.verdict = acc.r_factor().map_or(DeficiencyVerdict::NotChecked, diag_verdict);
    if report.verdict.is_clean() {
        if let Ok(x) = acc.solve() {
            if all_finite(&x) {
                return Ok((x, report));
            }
        }
        report.retries += 1;
    }
    let beta = ridge_ladder_solve(
        &a.gram_with(policy),
        &a.t_matvec(b),
        RIDGE_LADDER[0],
        false,
        &mut report,
    )?;
    Ok((beta, report))
}

/// Ridge least squares from the already-accumulated normal equations:
/// solves (G + λI) x = c. This is the coordinator's streaming path — G and
/// c come from the `elm_gram` artifacts block by block — and the rung
/// primitive of the degradation ladder.
pub fn lstsq_ridge_from_parts(g: &Matrix, c: &[f64], lambda: f64) -> Result<Vec<f64>> {
    let n = g.rows;
    if g.cols != n || c.len() != n {
        return Err(SolveError::ShapeMismatch {
            context: "ridge solve",
            detail: format!("G is {}x{}, c has {}", g.rows, g.cols, c.len()),
        }
        .into());
    }
    let mut greg = g.clone();
    // scale-invariant regularization: λ relative to mean diagonal
    // lint: fold-order-pinned -- sequential ascending-diagonal sum, one order on every path
    let mean_diag = (0..n).map(|i| g[(i, i)]).sum::<f64>() / n as f64;
    let reg = lambda * mean_diag.max(1e-12);
    for i in 0..n {
        greg[(i, i)] += reg;
    }
    cholesky_solve(&greg, c)
}

/// Ridge least squares from (A, b) directly.
pub fn lstsq_ridge(a: &Matrix, b: &[f64], lambda: f64) -> Result<Vec<f64>> {
    lstsq_ridge_from_parts(&a.gram(), &a.t_matvec(b), lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robust::error::as_solve_error;
    use crate::robust::report::DegradationRung;
    use crate::util::rng::Rng;

    #[test]
    fn triangular_solves_invert() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(6, 6, &mut rng);
        let mut l = Matrix::zeros(6, 6);
        let mut r = Matrix::zeros(6, 6);
        for i in 0..6 {
            for j in 0..6 {
                if i >= j {
                    l[(i, j)] = a[(i, j)] + if i == j { 3.0 } else { 0.0 };
                }
                if j >= i {
                    r[(i, j)] = a[(i, j)] + if i == j { 3.0 } else { 0.0 };
                }
            }
        }
        let x: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let bl = l.matvec(&x);
        let br = r.matvec(&x);
        let xl = solve_lower_triangular(&l, &bl).unwrap();
        let xr = solve_upper_triangular(&r, &br).unwrap();
        for i in 0..6 {
            assert!((xl[i] - x[i]).abs() < 1e-10);
            assert!((xr[i] - x[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn uniformly_tiny_systems_solve_with_relative_pivots() {
        // every pivot is 1e-305 — far below the old absolute 1e-300 bail,
        // but the system is perfectly conditioned (ratio 1.0), so the
        // relative guard lets it solve
        let n = 4;
        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            r[(i, i)] = 1e-305;
        }
        let x_true: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
        let b: Vec<f64> = x_true.iter().map(|&v| v * 1e-305).collect();
        let x = solve_upper_triangular(&r, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
        let x = solve_lower_triangular(&r, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn relatively_tiny_pivot_is_a_typed_singular_error() {
        let mut r = Matrix::identity(3);
        r[(1, 1)] = 1e-12; // 1e-12 of max diag 1.0 — below the 1e-10 guard
        let err = solve_upper_triangular(&r, &[1.0, 1.0, 1.0]).unwrap_err();
        match as_solve_error(&err).expect("typed error") {
            SolveError::SingularPivot { row: 1, .. } => {}
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn nan_pivot_is_a_typed_poison_error_not_nan_output() {
        let mut r = Matrix::identity(3);
        r[(2, 2)] = f64::NAN;
        let err = solve_upper_triangular(&r, &[1.0, 1.0, 1.0]).unwrap_err();
        assert_eq!(
            *as_solve_error(&err).expect("typed error"),
            SolveError::NonFinitePivot { row: 2 }
        );
    }

    #[test]
    fn lstsq_exact_on_square() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(5, 5, &mut rng);
        let x_true = vec![1.0, -1.0, 2.0, 0.5, -0.25];
        let b = a.matvec(&x_true);
        let x = lstsq_qr(&a, &b).unwrap();
        for (g, w) in x.iter().zip(&x_true) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn lstsq_minimizes_residual() {
        // overdetermined: residual must be orthogonal to the column space
        let mut rng = Rng::new(3);
        let a = Matrix::random(40, 6, &mut rng);
        let b: Vec<f64> = (0..40).map(|i| (i as f64 * 0.17).sin()).collect();
        let x = lstsq_qr(&a, &b).unwrap();
        let ax = a.matvec(&x);
        let resid: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let at_r = a.t_matvec(&resid);
        for v in at_r {
            assert!(v.abs() < 1e-8, "residual not orthogonal: {v}");
        }
    }

    #[test]
    fn ridge_matches_qr_when_well_conditioned() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(60, 8, &mut rng);
        let b: Vec<f64> = (0..60).map(|i| (i as f64 * 0.11).cos()).collect();
        let xq = lstsq_qr(&a, &b).unwrap();
        let xr = lstsq_ridge(&a, &b, 1e-12).unwrap();
        for (q, r) in xq.iter().zip(&xr) {
            assert!((q - r).abs() < 1e-6);
        }
    }

    #[test]
    fn tsqr_matches_qr_and_falls_back_when_deficient() {
        let mut rng = Rng::new(6);
        // well-conditioned: tree solve ≈ direct solve
        let a = Matrix::random(300, 7, &mut rng);
        let b: Vec<f64> = (0..300).map(|i| (i as f64 * 0.13).sin()).collect();
        let xq = lstsq_qr(&a, &b).unwrap();
        let xt = lstsq_tsqr(&a, &b, ParallelPolicy::with_workers(4)).unwrap();
        for (p, q) in xt.iter().zip(&xq) {
            assert!((p - q).abs() < 1e-8, "{p} vs {q}");
        }
        // duplicated column: both paths must take the identical ridge
        // fallback instead of back-substituting through a noise pivot
        let mut dup = Matrix::zeros(300, 8);
        for i in 0..300 {
            for j in 0..7 {
                dup[(i, j)] = a[(i, j)];
            }
            dup[(i, 7)] = a[(i, 0)];
        }
        let xq = lstsq_qr(&dup, &b).unwrap();
        let xt = lstsq_tsqr(&dup, &b, ParallelPolicy::with_workers(4)).unwrap();
        assert!(xt.iter().all(|v| v.is_finite()));
        for (p, q) in xt.iter().zip(&xq) {
            assert!((p - q).abs() < 1e-9, "ridge fallbacks differ: {p} vs {q}");
        }
        // underdetermined stays a (now typed) error
        let wide = Matrix::zeros(3, 5);
        let err =
            lstsq_tsqr(&wide, &[0.0; 3], ParallelPolicy::with_workers(2)).unwrap_err();
        assert_eq!(
            *as_solve_error(&err).expect("typed"),
            SolveError::Underdetermined { rows: 3, cols: 5 }
        );
    }

    #[test]
    fn reports_record_rung_and_verdict() {
        let mut rng = Rng::new(7);
        let a = Matrix::random(120, 6, &mut rng);
        let b: Vec<f64> = (0..120).map(|i| (i as f64 * 0.19).sin()).collect();
        // healthy: primary rung, clean verdict, report-free twin bit-equal
        for (x, rep) in [
            lstsq_qr_report(&a, &b, ParallelPolicy::with_workers(2)).unwrap(),
            lstsq_tsqr_report(&a, &b, ParallelPolicy::with_workers(2)).unwrap(),
        ] {
            assert!(all_finite(&x));
            assert_eq!(rep.rung, DegradationRung::Primary);
            assert!(rep.verdict.is_clean());
            assert_eq!(rep.retries, 0);
            assert_eq!(rep.effective_lambda, 0.0);
        }
        // duplicated column: ridge rung 1, deficient verdict, and the β
        // bits equal the direct base-rung ridge call
        let mut dup = Matrix::zeros(120, 7);
        for i in 0..120 {
            for j in 0..6 {
                dup[(i, j)] = a[(i, j)];
            }
            dup[(i, 6)] = a[(i, 1)];
        }
        let want =
            lstsq_ridge_from_parts(&dup.gram(), &dup.t_matvec(&b), RIDGE_LADDER[0])
                .unwrap();
        for (x, rep) in [
            lstsq_qr_report(&dup, &b, ParallelPolicy::with_workers(2)).unwrap(),
            lstsq_tsqr_report(&dup, &b, ParallelPolicy::with_workers(2)).unwrap(),
        ] {
            assert_eq!(x, want, "ladder base rung must be bit-identical");
            assert_eq!(
                rep.rung,
                DegradationRung::Ridge { step: 1, lambda: RIDGE_LADDER[0] }
            );
            assert!(
                matches!(rep.verdict, DeficiencyVerdict::RankDeficient { .. }),
                "{:?}",
                rep.verdict
            );
        }
    }

    #[test]
    fn threaded_lstsq_qr_bit_identical_to_sequential() {
        let mut rng = Rng::new(8);
        let a = Matrix::random(500, 60, &mut rng);
        let b: Vec<f64> = (0..500).map(|i| (i as f64 * 0.07).sin()).collect();
        let base = lstsq_qr(&a, &b).unwrap();
        for workers in [2usize, 4, 8] {
            let x = lstsq_qr_with(&a, &b, ParallelPolicy::with_workers(workers)).unwrap();
            assert_eq!(x, base, "β bits differ at workers={workers}");
        }
    }

    #[test]
    fn rank_deficient_falls_back() {
        // exactly duplicated column: QR hits a zero pivot, ridge kicks in
        let mut rng = Rng::new(5);
        let base = Matrix::random(30, 3, &mut rng);
        let mut a = Matrix::zeros(30, 4);
        for i in 0..30 {
            for j in 0..3 {
                a[(i, j)] = base[(i, j)];
            }
            a[(i, 3)] = base[(i, 0)]; // dup of column 0
        }
        let b: Vec<f64> = (0..30).map(|i| i as f64 * 0.05).collect();
        let x = lstsq_qr(&a, &b).unwrap();
        assert!(x.iter().all(|v| v.is_finite()));
        // the fit must still be as good as the rank-3 solution
        let x3 = lstsq_qr(&base, &b).unwrap();
        let r4: f64 = {
            let ax = a.matvec(&x);
            b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum()
        };
        let r3: f64 = {
            let ax = base.matvec(&x3);
            b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum()
        };
        assert!(r4 <= r3 + 1e-6);
    }
}
