//! TSQR — communication-avoiding tall-skinny QR over row blocks.
//!
//! This is the "parallel QR factorization" of the paper's abstract, and the
//! exact-factorization twin of the streaming Gram accumulator: each incoming
//! H block is reduced to an upper-triangular (R, QᵀY-partial) pair, and
//! pairs are merged by re-factorizing their vertical stack. The final R and
//! z = QᵀY give β by back-substitution without ever materializing H.
//!
//! Numerically this avoids the condition-number squaring of the normal
//! equations — the reason the paper uses QR rather than the explicit
//! pseudo-inverse.

use anyhow::{bail, Result};

use super::matrix::Matrix;
use super::qr::householder_qr;
use super::solve::solve_upper_triangular;

/// Streaming TSQR state: R (n×n upper triangular) and z = Qᵀy (length n).
pub struct TsqrAccumulator {
    n: usize,
    /// current reduced factor, None until the first block arrives
    r: Option<Matrix>,
    z: Vec<f64>,
    rows_seen: usize,
}

impl TsqrAccumulator {
    pub fn new(n_cols: usize) -> TsqrAccumulator {
        TsqrAccumulator { n: n_cols, r: None, z: vec![0.0; n_cols], rows_seen: 0 }
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    /// Fold one (H block, y block) pair into the reduced factors.
    pub fn push_block(&mut self, h: &Matrix, y: &[f64]) -> Result<()> {
        if h.cols != self.n {
            bail!("block has {} cols, accumulator expects {}", h.cols, self.n);
        }
        if h.rows != y.len() {
            bail!("block rows {} != y len {}", h.rows, y.len());
        }
        if h.rows == 0 {
            return Ok(());
        }
        // Local QR of the new block (pad if the block is shorter than n).
        let (hb, yb) = if h.rows < self.n {
            let mut padded = Matrix::zeros(self.n, self.n);
            for i in 0..h.rows {
                padded.row_mut(i).copy_from_slice(h.row(i));
            }
            let mut ypad = vec![0.0; self.n];
            ypad[..y.len()].copy_from_slice(y);
            (padded, ypad)
        } else {
            (h.clone(), y.to_vec())
        };
        let f = householder_qr(&hb)?;
        let mut zb = yb;
        f.apply_qt(&mut zb);
        let r_new = f.r();
        let z_new = zb[..self.n].to_vec();

        match self.r.take() {
            None => {
                self.r = Some(r_new);
                self.z = z_new;
            }
            Some(r_old) => {
                // merge: QR of [R_old; R_new] (2n × n)
                let stacked = Matrix::vstack(&r_old, &r_new);
                let f2 = householder_qr(&stacked)?;
                let mut zz = Vec::with_capacity(2 * self.n);
                zz.extend_from_slice(&self.z);
                zz.extend_from_slice(&z_new);
                f2.apply_qt(&mut zz);
                self.r = Some(f2.r());
                self.z = zz[..self.n].to_vec();
            }
        }
        self.rows_seen += h.rows;
        Ok(())
    }

    /// Merge another accumulator (tree reduction across workers).
    pub fn merge(&mut self, other: TsqrAccumulator) -> Result<()> {
        if other.n != self.n {
            bail!("accumulator width mismatch");
        }
        let Some(r_other) = other.r else { return Ok(()) };
        match self.r.take() {
            None => {
                self.r = Some(r_other);
                self.z = other.z;
            }
            Some(r_old) => {
                let stacked = Matrix::vstack(&r_old, &r_other);
                let f = householder_qr(&stacked)?;
                let mut zz = Vec::with_capacity(2 * self.n);
                zz.extend_from_slice(&self.z);
                zz.extend_from_slice(&other.z);
                f.apply_qt(&mut zz);
                self.r = Some(f.r());
                self.z = zz[..self.n].to_vec();
            }
        }
        self.rows_seen += other.rows_seen;
        Ok(())
    }

    /// Solve R β = z by back-substitution.
    pub fn solve(&self) -> Result<Vec<f64>> {
        let Some(r) = &self.r else { bail!("no blocks accumulated") };
        if self.rows_seen < self.n {
            bail!("underdetermined: {} rows < {} cols", self.rows_seen, self.n);
        }
        solve_upper_triangular(r, &self.z)
    }

    /// |R| diagnostic: the Gram matrix equals RᵀR (test hook).
    pub fn r_factor(&self) -> Option<&Matrix> {
        self.r.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve::lstsq_qr;
    use crate::util::rng::Rng;

    fn random_problem(rows: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(rows, cols, &mut rng);
        let b: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        (a, b)
    }

    fn blocks_of(a: &Matrix, b: &[f64], block: usize) -> Vec<(Matrix, Vec<f64>)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < a.rows {
            let end = (i + block).min(a.rows);
            let rows: Vec<Vec<f64>> = (i..end).map(|r| a.row(r).to_vec()).collect();
            out.push((Matrix::from_rows(&rows), b[i..end].to_vec()));
            i = end;
        }
        out
    }

    #[test]
    fn tsqr_matches_direct_qr() {
        let (a, b) = random_problem(200, 7, 1);
        let direct = lstsq_qr(&a, &b).unwrap();
        for block in [7usize, 16, 33, 200] {
            let mut acc = TsqrAccumulator::new(7);
            for (hb, yb) in blocks_of(&a, &b, block) {
                acc.push_block(&hb, &yb).unwrap();
            }
            let beta = acc.solve().unwrap();
            for (g, w) in beta.iter().zip(&direct) {
                assert!((g - w).abs() < 1e-8, "block={block}");
            }
        }
    }

    #[test]
    fn tsqr_handles_short_blocks() {
        // blocks narrower than n (fewer rows than columns) must still work
        let (a, b) = random_problem(50, 10, 2);
        let mut acc = TsqrAccumulator::new(10);
        for (hb, yb) in blocks_of(&a, &b, 3) {
            acc.push_block(&hb, &yb).unwrap();
        }
        let direct = lstsq_qr(&a, &b).unwrap();
        let beta = acc.solve().unwrap();
        for (g, w) in beta.iter().zip(&direct) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let (a, b) = random_problem(120, 5, 3);
        let blocks = blocks_of(&a, &b, 30);
        // sequential
        let mut seq = TsqrAccumulator::new(5);
        for (hb, yb) in &blocks {
            seq.push_block(hb, yb).unwrap();
        }
        // two workers + merge
        let mut w1 = TsqrAccumulator::new(5);
        let mut w2 = TsqrAccumulator::new(5);
        for (i, (hb, yb)) in blocks.iter().enumerate() {
            if i % 2 == 0 {
                w1.push_block(hb, yb).unwrap();
            } else {
                w2.push_block(hb, yb).unwrap();
            }
        }
        w1.merge(w2).unwrap();
        let b1 = seq.solve().unwrap();
        let b2 = w1.solve().unwrap();
        for (g, w) in b1.iter().zip(&b2) {
            assert!((g - w).abs() < 1e-9);
        }
        assert_eq!(w1.rows_seen(), 120);
    }

    #[test]
    fn gram_identity() {
        // RᵀR must equal HᵀH (up to float error)
        let (a, b) = random_problem(80, 6, 4);
        let mut acc = TsqrAccumulator::new(6);
        for (hb, yb) in blocks_of(&a, &b, 16) {
            acc.push_block(&hb, &yb).unwrap();
        }
        let r = acc.r_factor().unwrap();
        let rtr = r.transpose().matmul(r);
        assert!(rtr.max_abs_diff(&a.gram()) < 1e-9);
    }

    #[test]
    fn underdetermined_rejected() {
        let (a, b) = random_problem(4, 6, 5);
        let mut acc = TsqrAccumulator::new(6);
        acc.push_block(&a, &b).unwrap();
        assert!(acc.solve().is_err());
    }

    #[test]
    fn empty_accumulator_rejected() {
        let acc = TsqrAccumulator::new(3);
        assert!(acc.solve().is_err());
    }

    #[test]
    fn width_mismatch_rejected() {
        let mut acc = TsqrAccumulator::new(4);
        let (a, b) = random_problem(8, 6, 6);
        assert!(acc.push_block(&a, &b).is_err());
    }
}
