//! TSQR — communication-avoiding tall-skinny QR over row blocks.
//!
//! This is the "parallel QR factorization" of the paper's abstract, and the
//! exact-factorization twin of the streaming Gram accumulator: each incoming
//! H block is reduced to an upper-triangular (R, QᵀY-partial) pair, and
//! pairs are merged by re-factorizing their vertical stack. The final R and
//! z = QᵀY give β by back-substitution without ever materializing H.
//!
//! Two reduction modes:
//!
//! * **Streaming** ([`TsqrAccumulator::push_block`]) — left-fold, one block
//!   at a time, blocks taken *by value* (no clone on the hot path). This is
//!   the coordinator's online mode.
//! * **Tree** ([`TsqrAccumulator::reduce`]) — the §4.2 parallel reduction:
//!   every block is factored to its (R, z) leaf independently (sharded over
//!   `std::thread::scope` workers per the [`ParallelPolicy`]), then leaves
//!   are merged pairwise, level by level, in index order — log₂(blocks)
//!   merge depth.
//!
//! # f32-wire leaves
//!
//! Both modes also ingest **f32-born blocks**
//! ([`TsqrAccumulator::push_block_f32`] / [`TsqrAccumulator::reduce_f32`]):
//! the H block stays [`MatrixF32`] — half the traffic — all the way to its
//! leaf, where it is widened *exactly* (f32 → f64 loses nothing) into the
//! QR working matrix. R and z stay f64, so the merge tree, the fixed
//! reduction topology, and [`TsqrAccumulator::solve`] are untouched; on
//! blocks whose values are f32-representable (every `arch::h_block_f32`
//! output) the reduced (R, z) is **bit-identical** to the f64 path's.
//! Nothing rounds f64 → f32 anywhere in the accumulator — the leaves are
//! born f32 upstream or stay f64.
//!
//! # Determinism
//!
//! The tree topology is a function of the block list alone — pairs (2i,
//! 2i+1) at every level, odd tail passed through — and never of the worker
//! count. Workers only execute disjoint subtrees, so the reduced (R, z) is
//! bit-identical for any worker count (the §7.3 robustness requirement);
//! the tests pin this at 1/2/4/8 workers.
//!
//! Numerically TSQR avoids the condition-number squaring of the normal
//! equations — the reason the paper uses QR rather than the explicit
//! pseudo-inverse.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::robust::error::SolveError;
use crate::robust::inject;

use super::matrix::Matrix;
use super::matrix32::MatrixF32;
use super::policy::{par_map, ParallelPolicy};
use super::qr::householder_qr_owned;
use super::solve::solve_upper_triangular;

/// Leaf operand abstraction shared by the f64 and f32-wire tree
/// reductions: a leaf only needs its shape and an (exact, for f32) widen
/// into the f64 QR working matrix.
trait LeafBlock: Send {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn widen(self) -> Matrix;
}

impl LeafBlock for Matrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn widen(self) -> Matrix {
        self
    }
}

impl LeafBlock for MatrixF32 {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn widen(self) -> Matrix {
        self.to_f64()
    }
}

/// Typed width-mismatch error shared by every block/merge entry point.
fn width_mismatch(got: usize, want: usize) -> SolveError {
    SolveError::ShapeMismatch {
        context: "tsqr",
        detail: format!("block has {got} cols, accumulator expects {want}"),
    }
}

/// Typed rows-vs-targets mismatch error.
fn rows_vs_y(rows: usize, y_len: usize) -> SolveError {
    SolveError::ShapeMismatch {
        context: "tsqr",
        detail: format!("block rows {rows} != y len {y_len}"),
    }
}

/// Streaming TSQR state: R (n×n upper triangular) and z = Qᵀy (length n).
pub struct TsqrAccumulator {
    n: usize,
    /// current reduced factor, None until the first block arrives
    r: Option<Matrix>,
    z: Vec<f64>,
    rows_seen: usize,
}

/// One reduced leaf/internal node of the TSQR tree.
type Reduced = (Matrix, Vec<f64>);

/// Factor one (H, y) block to its (R, z) pair, padding short blocks.
fn block_factors(n: usize, h: Matrix, y: &[f64]) -> Result<Reduced> {
    let (hb, yb) = if h.rows < n {
        let mut padded = Matrix::zeros(n, n);
        for i in 0..h.rows {
            padded.row_mut(i).copy_from_slice(h.row(i));
        }
        let mut ypad = vec![0.0; n];
        ypad[..y.len()].copy_from_slice(y);
        (padded, ypad)
    } else {
        (h, y.to_vec())
    };
    let f = householder_qr_owned(hb)?;
    let mut zb = yb;
    f.apply_qt(&mut zb);
    let r = f.r();
    zb.truncate(n);
    Ok((r, zb))
}

/// Merge two reduced pairs: QR of [R_a; R_b] (2n × n).
fn merge_pair(n: usize, a: Reduced, b: Reduced) -> Result<Reduced> {
    let stacked = Matrix::vstack(&a.0, &b.0);
    let f = householder_qr_owned(stacked)?;
    let mut zz = Vec::with_capacity(2 * n);
    zz.extend_from_slice(&a.1);
    zz.extend_from_slice(&b.1);
    f.apply_qt(&mut zz);
    let r = f.r();
    zz.truncate(n);
    Ok((r, zz))
}

impl TsqrAccumulator {
    /// Empty accumulator for an n-column design matrix.
    pub fn new(n_cols: usize) -> TsqrAccumulator {
        TsqrAccumulator { n: n_cols, r: None, z: vec![0.0; n_cols], rows_seen: 0 }
    }

    /// Total rows folded in so far (the underdetermined-solve guard).
    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    /// Fold one (H block, y block) pair into the reduced factors. The
    /// block is taken by value: the local QR factors it in place.
    pub fn push_block(&mut self, h: Matrix, y: &[f64]) -> Result<()> {
        if h.cols != self.n {
            return Err(width_mismatch(h.cols, self.n).into());
        }
        if h.rows != y.len() {
            return Err(rows_vs_y(h.rows, y.len()).into());
        }
        if h.rows == 0 {
            return Ok(());
        }
        let rows = h.rows;
        let (r_new, z_new) = block_factors(self.n, h, y)?;
        match self.r.take() {
            None => {
                self.r = Some(r_new);
                self.z = z_new;
            }
            Some(r_old) => {
                let z_old = std::mem::take(&mut self.z);
                let (r, z) = merge_pair(self.n, (r_old, z_old), (r_new, z_new))?;
                self.r = Some(r);
                self.z = z;
            }
        }
        self.rows_seen += rows;
        Ok(())
    }

    /// Fold one **f32-born** (H block, y block) pair into the reduced
    /// factors: the block arrives as `MatrixF32` (half the wire traffic)
    /// and is widened exactly into the leaf QR — bit-identical to
    /// [`TsqrAccumulator::push_block`] on the widened block, R/z stay f64.
    pub fn push_block_f32(&mut self, h: MatrixF32, y: &[f64]) -> Result<()> {
        if h.cols != self.n {
            return Err(width_mismatch(h.cols, self.n).into());
        }
        self.push_block(h.to_f64(), y)
    }

    /// Merge another accumulator (pairwise tree-reduction step).
    pub fn merge(&mut self, other: TsqrAccumulator) -> Result<()> {
        if other.n != self.n {
            return Err(width_mismatch(other.n, self.n).into());
        }
        let Some(r_other) = other.r else { return Ok(()) };
        match self.r.take() {
            None => {
                self.r = Some(r_other);
                self.z = other.z;
            }
            Some(r_old) => {
                let z_old = std::mem::take(&mut self.z);
                let (r, z) =
                    merge_pair(self.n, (r_old, z_old), (r_other, other.z))?;
                self.r = Some(r);
                self.z = z;
            }
        }
        self.rows_seen += other.rows_seen;
        Ok(())
    }

    /// Parallel tree reduction over a block list: leaves sharded across
    /// `policy.workers` scoped threads, then in-order pairwise merges at
    /// log₂ depth. Bit-identical for any worker count (see module docs).
    pub fn reduce(
        n_cols: usize,
        blocks: Vec<(Matrix, Vec<f64>)>,
        policy: ParallelPolicy,
    ) -> Result<TsqrAccumulator> {
        TsqrAccumulator::reduce_leaves(n_cols, blocks, policy)
    }

    /// [`TsqrAccumulator::reduce`] over **f32-born blocks**: the same
    /// fixed-topology tree, with each leaf's `MatrixF32` widened exactly
    /// into the f64 QR at the moment it is factored. Bit-identical to the
    /// f64 `reduce` on blocks whose values are f32-representable (see the
    /// module's f32-wire section), and for any worker count.
    pub fn reduce_f32(
        n_cols: usize,
        blocks: Vec<(MatrixF32, Vec<f64>)>,
        policy: ParallelPolicy,
    ) -> Result<TsqrAccumulator> {
        TsqrAccumulator::reduce_leaves(n_cols, blocks, policy)
    }

    /// The shared tree-reduction core behind `reduce`/`reduce_f32`.
    fn reduce_leaves<B: LeafBlock>(
        n_cols: usize,
        blocks: Vec<(B, Vec<f64>)>,
        policy: ParallelPolicy,
    ) -> Result<TsqrAccumulator> {
        let mut rows_total = 0usize;
        for (h, y) in &blocks {
            if h.cols() != n_cols {
                return Err(width_mismatch(h.cols(), n_cols).into());
            }
            if h.rows() != y.len() {
                return Err(rows_vs_y(h.rows(), y.len()).into());
            }
            rows_total += h.rows();
        }
        let blocks: Vec<(usize, (B, Vec<f64>))> = blocks
            .into_iter()
            .filter(|(h, _)| h.rows() > 0)
            .enumerate()
            .collect();
        if blocks.is_empty() {
            return Ok(TsqrAccumulator::new(n_cols));
        }

        // leaves: every block factored independently, in parallel (f32
        // leaves widen exactly here, right at the factorization). The
        // fault-inject hook corrupts the widened leaf keyed by its block
        // index — stable across worker counts — and is a no-op without
        // the `fault-inject` feature.
        let mut level = par_map(blocks, policy, move |(idx, (h, y))| {
            let mut hw = h.widen();
            let (rows, cols) = (hw.rows, hw.cols);
            inject::corrupt_slice_f64(
                inject::Site::TsqrLeaf,
                idx,
                hw.data_mut(),
                rows,
                cols,
            );
            block_factors(n_cols, hw, &y)
        })?;

        // in-order pairwise merges until one node remains
        while level.len() > 1 {
            let mut pairs = Vec::with_capacity(level.len() / 2 + 1);
            let mut it = level.into_iter();
            while let (Some(a), b) = (it.next(), it.next()) {
                pairs.push((a, b));
            }
            level = par_map(pairs, policy, move |(a, b)| match b {
                Some(b) => merge_pair(n_cols, a, b),
                None => Ok(a), // odd tail passes through
            })?;
        }

        let (r, z) = level.pop().expect("non-empty level");
        Ok(TsqrAccumulator { n: n_cols, r: Some(r), z, rows_seen: rows_total })
    }

    /// Solve R β = z by back-substitution.
    pub fn solve(&self) -> Result<Vec<f64>> {
        let Some(r) = &self.r else {
            return Err(SolveError::EmptyAccumulator.into());
        };
        if self.rows_seen < self.n {
            return Err(SolveError::Underdetermined {
                rows: self.rows_seen,
                cols: self.n,
            }
            .into());
        }
        solve_upper_triangular(r, &self.z)
    }

    /// |R| diagnostic: the Gram matrix equals RᵀR (test hook).
    pub fn r_factor(&self) -> Option<&Matrix> {
        self.r.as_ref()
    }

    /// The reduced right-hand side z = Qᵀy (test hook).
    pub fn z_factor(&self) -> &[f64] {
        &self.z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve::lstsq_qr;
    use crate::util::rng::Rng;

    fn random_problem(rows: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(rows, cols, &mut rng);
        let b: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        (a, b)
    }

    fn blocks_of(a: &Matrix, b: &[f64], block: usize) -> Vec<(Matrix, Vec<f64>)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < a.rows {
            let end = (i + block).min(a.rows);
            out.push((a.submatrix(i, end, 0, a.cols), b[i..end].to_vec()));
            i = end;
        }
        out
    }

    #[test]
    fn tsqr_matches_direct_qr() {
        let (a, b) = random_problem(200, 7, 1);
        let direct = lstsq_qr(&a, &b).unwrap();
        for block in [7usize, 16, 33, 200] {
            let mut acc = TsqrAccumulator::new(7);
            for (hb, yb) in blocks_of(&a, &b, block) {
                acc.push_block(hb, &yb).unwrap();
            }
            let beta = acc.solve().unwrap();
            for (g, w) in beta.iter().zip(&direct) {
                assert!((g - w).abs() < 1e-8, "block={block}");
            }
        }
    }

    #[test]
    fn tsqr_handles_short_blocks() {
        // blocks narrower than n (fewer rows than columns) must still work
        let (a, b) = random_problem(50, 10, 2);
        let mut acc = TsqrAccumulator::new(10);
        for (hb, yb) in blocks_of(&a, &b, 3) {
            acc.push_block(hb, &yb).unwrap();
        }
        let direct = lstsq_qr(&a, &b).unwrap();
        let beta = acc.solve().unwrap();
        for (g, w) in beta.iter().zip(&direct) {
            assert!((g - w).abs() < 1e-8);
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let (a, b) = random_problem(120, 5, 3);
        let blocks = blocks_of(&a, &b, 30);
        // sequential
        let mut seq = TsqrAccumulator::new(5);
        for (hb, yb) in blocks.clone() {
            seq.push_block(hb, &yb).unwrap();
        }
        // two workers + merge
        let mut w1 = TsqrAccumulator::new(5);
        let mut w2 = TsqrAccumulator::new(5);
        for (i, (hb, yb)) in blocks.into_iter().enumerate() {
            if i % 2 == 0 {
                w1.push_block(hb, &yb).unwrap();
            } else {
                w2.push_block(hb, &yb).unwrap();
            }
        }
        w1.merge(w2).unwrap();
        let b1 = seq.solve().unwrap();
        let b2 = w1.solve().unwrap();
        for (g, w) in b1.iter().zip(&b2) {
            assert!((g - w).abs() < 1e-9);
        }
        assert_eq!(w1.rows_seen(), 120);
    }

    #[test]
    fn tree_reduce_bit_identical_across_worker_counts() {
        let (a, b) = random_problem(610, 9, 8);
        let blocks = blocks_of(&a, &b, 47); // 13 blocks, odd tails in the tree
        let base =
            TsqrAccumulator::reduce(9, blocks.clone(), ParallelPolicy::sequential())
                .unwrap();
        let base_beta = base.solve().unwrap();
        for workers in [2usize, 4, 8] {
            let acc = TsqrAccumulator::reduce(
                9,
                blocks.clone(),
                ParallelPolicy::with_workers(workers),
            )
            .unwrap();
            assert_eq!(
                acc.r_factor().unwrap(),
                base.r_factor().unwrap(),
                "R differs at workers={workers}"
            );
            assert_eq!(acc.z_factor(), base.z_factor(), "z differs at {workers}");
            assert_eq!(acc.solve().unwrap(), base_beta, "β differs at {workers}");
            assert_eq!(acc.rows_seen(), 610);
        }
    }

    #[test]
    fn tree_reduce_matches_streaming_fold() {
        let (a, b) = random_problem(300, 6, 9);
        let blocks = blocks_of(&a, &b, 50);
        let tree =
            TsqrAccumulator::reduce(6, blocks.clone(), ParallelPolicy::with_workers(4))
                .unwrap();
        let mut stream = TsqrAccumulator::new(6);
        for (hb, yb) in blocks {
            stream.push_block(hb, &yb).unwrap();
        }
        let (bt, bs) = (tree.solve().unwrap(), stream.solve().unwrap());
        for (g, w) in bt.iter().zip(&bs) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn tree_reduce_single_and_empty() {
        let (a, b) = random_problem(40, 4, 10);
        let one = TsqrAccumulator::reduce(
            4,
            vec![(a.clone(), b.clone())],
            ParallelPolicy::with_workers(4),
        )
        .unwrap();
        let direct = lstsq_qr(&a, &b).unwrap();
        for (g, w) in one.solve().unwrap().iter().zip(&direct) {
            assert!((g - w).abs() < 1e-8);
        }
        let empty =
            TsqrAccumulator::reduce(4, vec![], ParallelPolicy::with_workers(4)).unwrap();
        assert!(empty.solve().is_err());
    }

    #[test]
    fn f32_leaves_bit_identical_to_f64_path() {
        // f32-born blocks (values exactly f32-representable) must reduce
        // to the identical (R, z) as the f64 path on the widened blocks —
        // both through the tree and the streaming fold
        let (a0, b) = random_problem(230, 8, 12);
        let a32 = MatrixF32::from_matrix(&a0); // test-side f32 birth
        let a = a32.to_f64();
        let blocks64 = blocks_of(&a, &b, 41);
        let blocks32: Vec<(MatrixF32, Vec<f64>)> = blocks64
            .iter()
            .map(|(h, y)| (MatrixF32::from_matrix(h), y.clone()))
            .collect();
        let t64 =
            TsqrAccumulator::reduce(8, blocks64.clone(), ParallelPolicy::with_workers(4))
                .unwrap();
        let t32 =
            TsqrAccumulator::reduce_f32(8, blocks32.clone(), ParallelPolicy::with_workers(4))
                .unwrap();
        assert_eq!(t32.r_factor().unwrap(), t64.r_factor().unwrap(), "R differs");
        assert_eq!(t32.z_factor(), t64.z_factor(), "z differs");
        assert_eq!(t32.rows_seen(), t64.rows_seen());
        assert_eq!(t32.solve().unwrap(), t64.solve().unwrap());
        // streaming fold too
        let mut s64 = TsqrAccumulator::new(8);
        let mut s32 = TsqrAccumulator::new(8);
        for ((h64, y), (h32, _)) in blocks64.into_iter().zip(blocks32) {
            s64.push_block(h64, &y).unwrap();
            s32.push_block_f32(h32, &y).unwrap();
        }
        assert_eq!(s32.r_factor().unwrap(), s64.r_factor().unwrap());
        assert_eq!(s32.z_factor(), s64.z_factor());
    }

    #[test]
    fn f32_reduce_worker_invariant_and_rejects_mismatch() {
        let (a, b) = random_problem(300, 6, 13);
        let blocks: Vec<(MatrixF32, Vec<f64>)> = blocks_of(&a, &b, 37)
            .into_iter()
            .map(|(h, y)| (MatrixF32::from_matrix(&h), y))
            .collect();
        let base =
            TsqrAccumulator::reduce_f32(6, blocks.clone(), ParallelPolicy::sequential())
                .unwrap();
        for workers in [2usize, 4, 8] {
            let acc = TsqrAccumulator::reduce_f32(
                6,
                blocks.clone(),
                ParallelPolicy::with_workers(workers),
            )
            .unwrap();
            assert_eq!(acc.r_factor().unwrap(), base.r_factor().unwrap());
            assert_eq!(acc.z_factor(), base.z_factor());
        }
        // width mismatch rejected on both f32 entry points
        let mut acc = TsqrAccumulator::new(4);
        assert!(acc.push_block_f32(MatrixF32::zeros(8, 6), &[0.0; 8]).is_err());
        assert!(TsqrAccumulator::reduce_f32(
            4,
            vec![(MatrixF32::zeros(8, 6), vec![0.0; 8])],
            ParallelPolicy::with_workers(2)
        )
        .is_err());
    }

    #[test]
    fn gram_identity() {
        // RᵀR must equal HᵀH (up to float error)
        let (a, b) = random_problem(80, 6, 4);
        let mut acc = TsqrAccumulator::new(6);
        for (hb, yb) in blocks_of(&a, &b, 16) {
            acc.push_block(hb, &yb).unwrap();
        }
        let r = acc.r_factor().unwrap();
        let rtr = r.transpose().matmul(r);
        assert!(rtr.max_abs_diff(&a.gram()) < 1e-9);
    }

    #[test]
    fn underdetermined_rejected() {
        let (a, b) = random_problem(4, 6, 5);
        let mut acc = TsqrAccumulator::new(6);
        acc.push_block(a, &b).unwrap();
        assert!(acc.solve().is_err());
    }

    #[test]
    fn empty_accumulator_rejected() {
        let acc = TsqrAccumulator::new(3);
        assert!(acc.solve().is_err());
    }

    #[test]
    fn width_mismatch_rejected() {
        let mut acc = TsqrAccumulator::new(4);
        let (a, b) = random_problem(8, 6, 6);
        assert!(acc.push_block(a, &b).is_err());
        assert!(TsqrAccumulator::reduce(
            4,
            vec![random_problem(8, 6, 7)],
            ParallelPolicy::with_workers(2)
        )
        .is_err());
    }
}
