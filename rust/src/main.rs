//! `repro` — the opt-pr-elm command line.
//!
//! ```text
//! repro report <table2|table3|table4|table5|table6|fig3|fig4|fig5|fig6|energy|all>
//!        [--scale 0.02] [--workers 2] [--seed 7] [--reps 3] [--out results]
//! repro train --dataset aemo --arch lstm [--m 50] [--scale 0.05] [--seq]
//! repro artifacts            # list the AOT executables in the manifest
//! repro datasets             # list the Table-3 benchmark registry
//! ```

#![forbid(unsafe_code)]

use std::path::PathBuf;

use anyhow::{bail, Result};

use opt_pr_elm::coordinator::PrElmTrainer;
use opt_pr_elm::data::spec::registry;
use opt_pr_elm::elm::{Arch, SrElmModel, TrainOptions};
use opt_pr_elm::report::{run_report, write_report, ReportCtx, ALL_REPORTS};
use opt_pr_elm::runtime::{default_artifacts_dir, Manifest};
use opt_pr_elm::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let mut args = Args::parse(argv)?;
    match args.command.as_str() {
        "report" => cmd_report(&mut args),
        "train" => cmd_train(&mut args),
        "artifacts" => cmd_artifacts(&mut args),
        "datasets" => cmd_datasets(&mut args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
repro — Opt-PR-ELM reproduction (see DESIGN.md)
  repro report <id|all> [--scale F] [--workers N] [--seed S] [--reps R] [--out DIR]
      ids: table2 table3 table4 table5 table6 fig3 fig4 fig5 fig6 energy
  repro train --dataset NAME --arch ARCH [--m M] [--scale F] [--seq] [--workers N]
  repro artifacts
  repro datasets
";

fn ctx_from(args: &mut Args) -> Result<ReportCtx> {
    let mut ctx = ReportCtx::new(default_artifacts_dir());
    if let Some(dir) = args.opt("artifacts") {
        ctx.artifacts = PathBuf::from(dir);
    }
    ctx.scale = args.opt_or("scale", "0.02").parse()?;
    ctx.workers = args.opt_usize("workers", 8)?;
    ctx.seed = args.opt_u64("seed", 7)?;
    ctx.reps = args.opt_usize("reps", 3)?;
    Ok(ctx)
}

fn cmd_report(args: &mut Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = PathBuf::from(args.opt_or("out", "results"));
    let ctx = ctx_from(args)?;
    args.finish()?;
    let ids: Vec<&str> = if id == "all" {
        let mut v = ALL_REPORTS.to_vec();
        v.push("energy");
        v
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        eprintln!("== running {id} (scale {}, workers {}) ==", ctx.scale, ctx.workers);
        let t0 = std::time::Instant::now();
        let tables = run_report(id, &ctx)?;
        for t in &tables {
            println!("{}", t.to_markdown());
        }
        let path = write_report(id, &tables, &out)?;
        eprintln!("wrote {path:?} in {:.1}s", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let dataset = args.opt_or("dataset", "aemo");
    let arch = Arch::parse(&args.opt_or("arch", "elman"))?;
    let m = args.opt_usize("m", 50)?;
    let scale: f64 = args.opt_or("scale", "0.05").parse()?;
    let workers = args.opt_usize("workers", 2)?;
    let seed = args.opt_u64("seed", 7)?;
    let seq = args.flag("seq");
    args.finish()?;

    let spec = opt_pr_elm::coordinator::job::dataset(&dataset)?;
    let (train, test) = opt_pr_elm::report::prep::prepare(&spec, scale, seed)?;
    println!(
        "{}: {} train / {} test windows (Q={}, M={m})",
        spec.name, train.n, test.n, train.q
    );
    let t0 = std::time::Instant::now();
    if seq {
        let model = SrElmModel::train(arch, &train, &TrainOptions::new(m, seed))?;
        println!(
            "S-R-ELM ({}): {:.3}s, test RMSE {:.5}",
            arch.name(),
            t0.elapsed().as_secs_f64(),
            model.rmse(&test)
        );
    } else {
        let trainer = PrElmTrainer::new(&default_artifacts_dir(), workers)?;
        let (model, bd) = trainer.train(arch, &train, m, seed)?;
        println!(
            "Opt-PR-ELM ({}): {:.3}s total (init {:.4} h2d {:.4} exec {:.4} d2h {:.4} solve {:.4}), \
             {} blocks, test RMSE {:.5}",
            arch.name(),
            t0.elapsed().as_secs_f64(),
            bd.init_s,
            bd.h2d_s,
            bd.exec_s,
            bd.d2h_s,
            bd.solve_s,
            bd.blocks,
            trainer.rmse(&model, &test)?
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &mut Args) -> Result<()> {
    args.finish()?;
    let manifest = Manifest::load(&default_artifacts_dir())?;
    let mut count = 0usize;
    for a in manifest.all() {
        println!(
            "{:<42} kind={:<12} arch={:<7} rows={:<4} q={:<3} m={}",
            a.name, a.kind, a.arch, a.rows, a.q, a.m
        );
        count += 1;
    }
    eprintln!("{count} artifacts in {:?}", manifest.dir);
    Ok(())
}

fn cmd_datasets(args: &mut Args) -> Result<()> {
    args.finish()?;
    for d in registry() {
        println!(
            "{:<20} {:<7} n={:<7} Q={:<5} train={}% M(table4)={}",
            d.name,
            d.category.label(),
            d.n_instances,
            d.q,
            d.train_pct,
            d.table4_m
        );
    }
    Ok(())
}
