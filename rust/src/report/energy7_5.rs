//! §7.5 energy efficiency: the paper's headline numbers (Elman, M = 50:
//! 3.71 s / 1113 J on the GPU vs 32 min / 57.6 kJ on the CPU) regenerated
//! through gpusim, plus the break-even analysis for every dataset.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::spec::registry;
use crate::elm::Arch;
use crate::gpusim::energy::energy_report;
use crate::gpusim::{cpu_host, simulate, tesla_k20m, SimConfig, Variant};
use crate::util::table::Table;

use super::ReportCtx;

pub fn emit(_ctx: &ReportCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "§7.5 — energy (gpusim, Elman M=50, Opt BS=32, Tesla K20m @ 300 W vs host @ 30 W)",
        &["Dataset", "GPU s", "GPU J", "CPU s", "CPU J", "energy ratio", "break-even speedup"],
    );
    let dev = tesla_k20m();
    let host = cpu_host();
    for d in registry() {
        let cfg = SimConfig {
            arch: Arch::Elman,
            variant: Variant::Opt,
            n: d.n_instances.saturating_sub(d.q_paper.min(64)),
            s: 1,
            q: d.q_paper.min(64),
            m: 50,
            bs: 32,
        };
        let r = simulate(&cfg, &dev, &host);
        let e = energy_report(&r, &dev, &host);
        t.row(vec![
            d.name.to_string(),
            format!("{:.3}", e.gpu_s),
            format!("{:.0}", e.gpu_joules),
            format!("{:.1}", e.cpu_s),
            format!("{:.0}", e.cpu_joules),
            format!("{:.1}", e.energy_ratio),
            format!("{:.0}", e.break_even_speedup),
        ]);
    }
    Ok(vec![t])
}
