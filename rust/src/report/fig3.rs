//! Fig 3: speedup of Basic-PR-ELM vs Opt-PR-ELM (BS 16 / 32) per
//! architecture across the ten datasets, M = 50 — regenerated through the
//! gpusim model at the paper's full sizes. The crossover structure the
//! paper discusses (§7.1: Basic ≈ Opt when Q ≤ BS, Opt wins when Q > BS)
//! falls out of the Table-2 read counts.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::spec::registry;
use crate::elm::ALL_ARCHS;
use crate::gpusim::{cpu_host, simulate, tesla_k20m, SimConfig, Variant};
use crate::util::table::Table;

use super::ReportCtx;

pub fn emit(_ctx: &ReportCtx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for arch in ALL_ARCHS {
        let mut t = Table::new(
            &format!("Fig 3 — {} speedup, M=50 (gpusim, Tesla K20m)", arch.name()),
            &["Dataset", "Q", "Basic", "Opt BS=16", "Opt BS=32"],
        );
        for d in registry() {
            let mk = |variant, bs| SimConfig {
                arch,
                variant,
                n: d.n_instances.saturating_sub(d.q_paper.min(64)),
                s: 1,
                q: d.q_paper.min(64),
                m: 50,
                bs,
            };
            let host = cpu_host();
            let dev = tesla_k20m();
            let b = simulate(&mk(Variant::Basic, 16), &dev, &host);
            let o16 = simulate(&mk(Variant::Opt, 16), &dev, &host);
            let o32 = simulate(&mk(Variant::Opt, 32), &dev, &host);
            t.row(vec![
                d.name.to_string(),
                d.q_paper.min(64).to_string(),
                format!("{:.0}", b.speedup),
                format!("{:.0}", o16.speedup),
                format!("{:.0}", o32.speedup),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn opt32_never_slower_than_basic() {
        let ctx = ReportCtx::new(PathBuf::from("artifacts"));
        for t in emit(&ctx).unwrap() {
            // columns: dataset, q, basic, o16, o32
            let csv = t.to_csv();
            for line in csv.lines().skip(1) {
                let cols: Vec<&str> = line.split(',').collect();
                let basic: f64 = cols[2].parse().unwrap();
                let o32: f64 = cols[4].parse().unwrap();
                assert!(o32 >= basic * 0.99, "{line}");
            }
        }
    }
}
