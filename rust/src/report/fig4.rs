//! Fig 4: Opt-PR-ELM (BS=32) speedup as M grows 5 → 100 — gpusim at the
//! paper's sizes plus the measured pipeline-vs-sequential sweep at
//! `ctx.scale` on this machine. The measured sweep runs the CPU parallel
//! trainer (`CpuElmTrainer`, threaded via one [`ParallelPolicy`]), so it
//! needs no PJRT artifacts and works on offline builds.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::coordinator::CpuElmTrainer;
use crate::data::spec::registry;
use crate::elm::{SrElmModel, TrainOptions, ALL_ARCHS};
use crate::gpusim::{cpu_host, simulate, tesla_k20m, SimConfig, Variant};
use crate::linalg::ParallelPolicy;
use crate::util::table::Table;
use crate::util::timer::time_once;

use super::prep::prepare;
use super::ReportCtx;

const MS: [usize; 5] = [5, 10, 20, 50, 100];

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    // modeled: all six archs on a representative dataset per size class
    let mut model_t = Table::new(
        "Fig 4 — Opt-PR-ELM (BS=32) speedup vs M (gpusim, Tesla, energy_consumption)",
        &["Architecture", "M=5", "M=10", "M=20", "M=50", "M=100"],
    );
    let d = registry().into_iter().find(|d| d.name == "energy_consumption").unwrap();
    for arch in ALL_ARCHS {
        let mut row = vec![arch.name().to_string()];
        for m in MS {
            let cfg = SimConfig {
                arch,
                variant: Variant::Opt,
                n: d.n_instances - d.q,
                s: 1,
                q: d.q,
                m,
                bs: 32,
            };
            let r = simulate(&cfg, &tesla_k20m(), &cpu_host());
            row.push(format!("{:.0}", r.speedup));
        }
        model_t.row(row);
    }

    // measured: this machine's CPU parallel pipeline vs sequential at
    // ctx.scale, one ParallelPolicy for the whole sweep
    let trainer = CpuElmTrainer::with_policy(ParallelPolicy::with_workers(ctx.workers));
    let mut meas_t = Table::new(
        &format!(
            "Fig 4 (measured) — CPU pipeline ({} workers) vs sequential speedup vs M, \
             energy_consumption @ scale {}",
            trainer.policy.workers, ctx.scale
        ),
        &["Architecture", "M=5", "M=10", "M=20", "M=50", "M=100"],
    );
    for arch in ALL_ARCHS {
        let mut row = vec![arch.name().to_string()];
        for m in MS {
            let min_n = ((3 * m + 16 + d.q) as f64 / d.train_frac()) as usize + d.q;
            let scale = ctx.scale.max(min_n as f64 / d.n_instances as f64);
            let (train, _test) = prepare(&d, scale, ctx.seed)?;
            let _ = trainer.train(arch, &train, m, ctx.seed)?; // warm-up
            let (_s, seq_t) = time_once(|| {
                SrElmModel::train(arch, &train, &TrainOptions::new(m, ctx.seed)).unwrap()
            });
            let (_p, par_t) = time_once(|| trainer.train(arch, &train, m, ctx.seed).unwrap());
            row.push(format!("{:.1}", seq_t.as_secs_f64() / par_t.as_secs_f64()));
        }
        meas_t.row(row);
    }
    Ok(vec![model_t, meas_t])
}
