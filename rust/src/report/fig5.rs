//! Fig 5: MSE vs wall-clock — P-BPTT's convergence curve against the
//! Opt-PR-ELM single-shot point (Japan population, LSTM, M = 10).
//! Fully measured on this machine.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::bptt::{BpttArch, BpttTrainer};
use crate::coordinator::PrElmTrainer;
use crate::data::spec::by_name;
use crate::elm::Arch;
use crate::util::table::Table;

use super::prep::prepare;
use super::ReportCtx;

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    let d = by_name("japan_population").expect("registry");
    // japan is small; run it at full size like the paper
    let scale = ctx.scale.max(1.0);
    let (train, test) = prepare(&d, scale, ctx.seed)?;

    // P-BPTT curve
    let bptt = BpttTrainer::new(&ctx.artifacts)?;
    let (bptt_model, log) = bptt.train(BpttArch::Lstm, &train, 10, ctx.seed)?;
    let bptt_test_mse = bptt.mse(&bptt_model, &test)?;

    // Opt-PR-ELM point (warm-up first: steady-state time, not compile)
    let elm = PrElmTrainer::new(&ctx.artifacts, ctx.workers)?;
    let _ = elm.train(Arch::Lstm, &train, 10, ctx.seed)?;
    let t0 = std::time::Instant::now();
    let (elm_model, _bd) = elm.train(Arch::Lstm, &train, 10, ctx.seed)?;
    let elm_time = t0.elapsed().as_secs_f64();
    let elm_rmse = elm.rmse(&elm_model, &test)?;
    let elm_mse = elm_rmse * elm_rmse;

    let mut curve = Table::new(
        "Fig 5 — P-BPTT MSE vs time (Japan population, LSTM, M=10)",
        &["t (s)", "step", "minibatch MSE"],
    );
    // subsample the curve to ~40 points
    let stride = (log.points.len() / 40).max(1);
    for p in log.points.iter().step_by(stride) {
        curve.row(vec![format!("{:.4}", p.t_s), p.step.to_string(), format!("{:.6}", p.mse)]);
    }

    let mut summary = Table::new(
        "Fig 5 summary — Opt-PR-ELM point vs P-BPTT",
        &["algorithm", "time to result (s)", "test MSE"],
    );
    summary.row(vec![
        "Opt-PR-ELM".to_string(),
        format!("{elm_time:.4}"),
        format!("{elm_mse:.6}"),
    ]);
    summary.row(vec![
        "P-BPTT (10 epochs)".to_string(),
        format!("{:.4}", log.total_s),
        format!("{bptt_test_mse:.6}"),
    ]);
    // time for BPTT to first reach the ELM's MSE (the paper's 69 s point)
    if let Some(p) = log.points.iter().find(|p| p.mse <= elm_mse) {
        summary.row(vec![
            "P-BPTT @ ELM-level MSE".to_string(),
            format!("{:.4}", p.t_s),
            format!("{:.6}", p.mse),
        ]);
    }
    Ok(vec![curve, summary])
}
