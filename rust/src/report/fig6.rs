//! Fig 6: runtime decomposition of Opt-PR-ELM (Japan population, M = 10):
//! init / transfer-to / compute-H(+partials) / transfer-from / solve-β.
//! Measured from the pipeline's phase clocks, alongside the gpusim model's
//! decomposition at the paper's scale.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::coordinator::PrElmTrainer;
use crate::data::spec::by_name;
use crate::elm::ALL_ARCHS;
use crate::gpusim::{cpu_host, simulate, tesla_k20m, SimConfig, Variant};
use crate::util::table::Table;

use super::prep::prepare;
use super::ReportCtx;

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    let d = by_name("japan_population").expect("registry");
    let scale = ctx.scale.max(0.5);
    let (train, _test) = prepare(&d, scale, ctx.seed)?;
    let trainer = PrElmTrainer::new(&ctx.artifacts, ctx.workers)?;

    let mut meas = Table::new(
        &format!(
            "Fig 6 (measured) — Opt-PR-ELM phase decomposition (s), japan_population M=10 @ scale {scale}"
        ),
        &[
            "Architecture",
            "init",
            "h2d*",
            "exec H+gram*",
            "d2h*",
            "solve β",
            "total (wall)",
            "blocks",
        ], // * cumulative across engine workers: may exceed wall clock
    );
    for arch in ALL_ARCHS {
        // warm-up: compile the executables so the measured run is steady-state
        let _ = trainer.train(arch, &train, 10, ctx.seed)?;
        let (_m, bd) = trainer.train(arch, &train, 10, ctx.seed)?;
        meas.row(vec![
            arch.name().to_string(),
            format!("{:.5}", bd.init_s),
            format!("{:.5}", bd.h2d_s),
            format!("{:.5}", bd.exec_s),
            format!("{:.5}", bd.d2h_s),
            format!("{:.5}", bd.solve_s),
            format!("{:.5}", bd.total_s),
            bd.blocks.to_string(),
        ]);
    }

    let mut model = Table::new(
        "Fig 6 (gpusim, paper size) — Tesla K20m decomposition (s), japan_population M=10",
        &["Architecture", "init", "h2d", "kernel", "d2h", "beta", "total"],
    );
    for arch in ALL_ARCHS {
        let cfg = SimConfig {
            arch,
            variant: Variant::Opt,
            n: d.n_instances - d.q,
            s: 1,
            q: d.q,
            m: 10,
            bs: 32,
        };
        let r = simulate(&cfg, &tesla_k20m(), &cpu_host());
        model.row(vec![
            arch.name().to_string(),
            format!("{:.6}", r.init_s),
            format!("{:.6}", r.h2d_s),
            format!("{:.6}", r.kernel_s),
            format!("{:.6}", r.d2h_s),
            format!("{:.6}", r.beta_s),
            format!("{:.6}", r.gpu_total_s),
        ]);
    }
    Ok(vec![meas, model])
}
