//! Report emitters: one module per paper table / figure (DESIGN.md §4).
//!
//! Every emitter regenerates the corresponding artifact of the paper's
//! evaluation section — measured on this machine where the experiment is
//! measurable (accuracy, runtimes of our own pipeline vs comparators) and
//! through the calibrated `gpusim` model where the paper's hardware is
//! being substituted (GPU speedups, energy). Emitters return
//! [`crate::util::table::Table`]s; `run_report` writes them under
//! `results/` as markdown + CSV.

#![forbid(unsafe_code)]

pub mod energy7_5;
pub mod fig3;
pub mod prep;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::table::Table;

/// Shared knobs for the measured experiments.
#[derive(Debug, Clone)]
pub struct ReportCtx {
    pub artifacts: PathBuf,
    /// dataset scale for measured runs (1.0 = the paper's full sizes)
    pub scale: f64,
    /// engine-pool workers for the parallel pipeline
    pub workers: usize,
    pub seed: u64,
    /// repetitions for ± std columns (the paper uses 5)
    pub reps: usize,
}

impl ReportCtx {
    pub fn new(artifacts: PathBuf) -> ReportCtx {
        ReportCtx { artifacts, scale: 0.02, workers: 8, seed: 7, reps: 3 }
    }
}

/// All experiment ids, in paper order.
pub const ALL_REPORTS: [&str; 9] =
    ["table2", "table3", "table4", "table5", "table6", "fig3", "fig4", "fig5", "fig6"];

/// Run one experiment by id ("energy" = §7.5) and return its tables.
pub fn run_report(id: &str, ctx: &ReportCtx) -> Result<Vec<Table>> {
    match id {
        "table2" => table2::emit(),
        "table3" => table3::emit(ctx),
        "table4" => table4::emit(ctx),
        "table5" => table5::emit(ctx),
        "table6" => table6::emit(ctx),
        "fig3" => fig3::emit(ctx),
        "fig4" => fig4::emit(ctx),
        "fig5" => fig5::emit(ctx),
        "fig6" => fig6::emit(ctx),
        "energy" => energy7_5::emit(ctx),
        other => bail!("unknown report {other:?}; known: {ALL_REPORTS:?} + energy"),
    }
}

/// Write tables under `out_dir/<id>.md` (+ one CSV per table).
pub fn write_report(id: &str, tables: &[Table], out_dir: &Path) -> Result<PathBuf> {
    std::fs::create_dir_all(out_dir).context("creating results dir")?;
    let md_path = out_dir.join(format!("{id}.md"));
    let mut md = String::new();
    for (i, t) in tables.iter().enumerate() {
        md.push_str(&t.to_markdown());
        md.push('\n');
        let csv_path = out_dir.join(format!("{id}_{i}.csv"));
        std::fs::write(&csv_path, t.to_csv())?;
    }
    std::fs::write(&md_path, md)?;
    Ok(md_path)
}
