//! Shared dataset preparation for the measured experiments: generate →
//! fit min-max on the train prefix → window → sequential split.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::spec::DatasetSpec;
use crate::data::window::Windowed;
use crate::data::MinMax;

pub fn prepare(spec: &DatasetSpec, scale: f64, seed: u64) -> Result<(Windowed, Windowed)> {
    let series = spec.generate(scale, seed);
    let split_at = ((series.len() as f64 * spec.train_frac()) as usize)
        .clamp(1, series.len() - 1);
    let norm = MinMax::fit(&series[..split_at])?;
    let z = norm.apply_all(&series);
    let w = Windowed::from_series(&z, spec.q)?;
    Ok(w.split(spec.train_frac()))
}

/// mean ± std over a set of measurements.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec::registry;

    #[test]
    fn prepares_all_datasets() {
        for d in registry() {
            let (tr, te) = prepare(&d, 0.01, 3).unwrap();
            assert!(tr.n > 0 && te.n > 0);
            assert_eq!(tr.q, d.q);
        }
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
