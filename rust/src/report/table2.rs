//! Table 2: per-thread memory operations and FLOPs per architecture —
//! the paper's symbolic formulas plus evaluations at the benchmark shapes.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::elm::{Arch, ALL_ARCHS};
use crate::gpusim::counts::{mem_to_flop_ratio, op_counts};
use crate::gpusim::Variant;
use crate::util::table::Table;

fn formula(arch: Arch) -> (&'static str, &'static str, &'static str) {
    match arch {
        Arch::Elman => ("Q(2S+Q+2)", "Q", "Q(2S+Q+2)"),
        Arch::Jordan => ("Q(2S+1+(Q+1)(1/2+M))", "Q", "Q(2S+1+(Q+1)/2(2SM+M))"),
        Arch::Narmax => ("Q(2S+1)+2(2F+M+R)", "Q", "Q(2S+1+2F+R(2+2SM+M))"),
        Arch::Fc => ("Q(2S+1+2MQ)", "Q", "Q(2S+Q+2QM)"),
        Arch::Lstm => ("Q(5S+13)", "5Q", "Q(8S+18)"),
        Arch::Gru => ("Q(4S+8)", "3Q", "Q(3S+17)"),
    }
}

pub fn emit() -> Result<Vec<Table>> {
    let mut sym = Table::new(
        "Table 2 — Basic-PR-ELM per-thread operation counts (paper formulas)",
        &["Architecture", "# Read Ops", "# Write Ops", "FLOPS"],
    );
    for arch in ALL_ARCHS {
        let (r, w, f) = formula(arch);
        sym.row(vec![arch.name().to_string(), r.into(), w.into(), f.into()]);
    }

    let mut eval = Table::new(
        "Table 2 (evaluated) — S=1, Q=50, M=50, TW=32",
        &[
            "Architecture",
            "reads (basic)",
            "reads (opt)",
            "writes",
            "FLOPs",
            "mem/FLOP (basic)",
            "mem/FLOP (opt)",
        ],
    );
    for arch in ALL_ARCHS {
        let b = op_counts(arch, Variant::Basic, 1, 50, 50, 32);
        let o = op_counts(arch, Variant::Opt, 1, 50, 50, 32);
        eval.row(vec![
            arch.name().to_string(),
            format!("{:.0}", b.reads),
            format!("{:.2}", o.reads),
            format!("{:.0}", b.writes),
            format!("{:.0}", b.flops),
            format!("{:.3}", mem_to_flop_ratio(&b)),
            format!("{:.4}", mem_to_flop_ratio(&o)),
        ]);
    }
    Ok(vec![sym, eval])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_six_rows_each() {
        let tables = emit().unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].n_rows(), 6);
        assert_eq!(tables[1].n_rows(), 6);
    }
}
