//! Table 3: benchmark descriptions — published row + the statistics our
//! generators actually produce at the requested scale.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::spec::registry;
use crate::data::Stats;
use crate::util::table::{sci, Table};

use super::ReportCtx;

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        &format!("Table 3 — benchmarks (published vs generated @ scale {})", ctx.scale),
        &[
            "Category",
            "Name",
            "# inst (paper)",
            "Q",
            "% train",
            "mean (paper)",
            "mean (gen)",
            "std (paper)",
            "std (gen)",
            "min",
            "max",
        ],
    );
    for d in registry() {
        let xs = d.generate(ctx.scale, ctx.seed);
        let s = Stats::of(&xs);
        t.row(vec![
            d.category.label().to_string(),
            d.name.to_string(),
            d.n_instances.to_string(),
            if d.q == d.q_paper {
                d.q.to_string()
            } else {
                format!("{} (paper {})", d.q, d.q_paper)
            },
            d.train_pct.to_string(),
            sci(d.mean),
            sci(s.mean()),
            sci(d.std),
            sci(s.std()),
            sci(s.min()),
            sci(s.max()),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn emits_ten_rows() {
        let ctx = ReportCtx { scale: 0.01, ..ReportCtx::new(PathBuf::from("artifacts")) };
        let tables = emit(&ctx).unwrap();
        assert_eq!(tables[0].n_rows(), 10);
    }
}
