//! Table 4: average RMSE ± std of S-R-ELM vs Opt-PR-ELM across the ten
//! datasets and six architectures — the §7.3 robustness experiment,
//! *measured* (both trainers run here; no simulation involved).

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::coordinator::PrElmTrainer;
use crate::data::spec::registry;
use crate::elm::{SrElmModel, TrainOptions, ALL_ARCHS};
use crate::util::table::{sci, Table};

use super::prep::{mean_std, prepare};
use super::ReportCtx;

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    let trainer = PrElmTrainer::new(&ctx.artifacts, ctx.workers)?;
    let mut t = Table::new(
        &format!(
            "Table 4 — test RMSE (±std over {} runs) S-R-ELM vs Opt-PR-ELM @ scale {}",
            ctx.reps, ctx.scale
        ),
        &["Dataset", "Algorithm", "elman", "jordan", "narmax", "fc", "lstm", "gru"],
    );
    for d in registry() {
        let m = d.table4_m;
        // guarantee a well-conditioned system: train rows ≥ 8M + 32 (at
        // ~3M the random tanh features are near-collinear and the
        // sequential QR path amplifies noise — exoplanet M=100)
        let min_n = ((8 * m + 32 + d.q) as f64 / d.train_frac()) as usize + d.q;
        let scale = ctx.scale.max(min_n as f64 / d.n_instances as f64);
        let (train, test) = prepare(&d, scale, ctx.seed)?;
        let mut seq_cells = Vec::new();
        let mut par_cells = Vec::new();
        for arch in ALL_ARCHS {
            let mut seq_r = Vec::new();
            let mut par_r = Vec::new();
            for rep in 0..ctx.reps {
                let seed = ctx.seed + 100 * rep as u64;
                let seq =
                    SrElmModel::train(arch, &train, &TrainOptions::new(m, seed))?;
                seq_r.push(seq.rmse(&test));
                let (par, _bd) = trainer.train(arch, &train, m, seed)?;
                par_r.push(trainer.rmse(&par, &test)?);
            }
            let (sm, ss) = mean_std(&seq_r);
            let (pm, ps) = mean_std(&par_r);
            seq_cells.push(format!("{} ± {}", sci(sm), sci(ss)));
            par_cells.push(format!("{} ± {}", sci(pm), sci(ps)));
        }
        let mut row_s = vec![d.name.to_string(), "S-R-ELM".to_string()];
        row_s.extend(seq_cells);
        t.row(row_s);
        let mut row_p = vec![String::new(), "Opt-PR-ELM".to_string()];
        row_p.extend(par_cells);
        t.row(row_p);
    }
    Ok(vec![t])
}
