//! Table 5: Opt-PR-ELM (BS=32, M=50) speedups on the Tesla K20m and the
//! Quadro K2000 — regenerated through the calibrated `gpusim` model at the
//! paper's full dataset sizes, plus a *measured* column: this machine's
//! parallel CPU pipeline (`CpuElmTrainer`, threaded via one
//! [`ParallelPolicy`]) vs the sequential S-R-ELM at `ctx.scale`. The
//! measured column needs no PJRT artifacts, so the emitter runs on
//! offline builds.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::coordinator::CpuElmTrainer;
use crate::data::spec::registry;
use crate::elm::{SrElmModel, TrainOptions, ALL_ARCHS};
use crate::gpusim::{cpu_host, quadro_k2000, simulate, tesla_k20m, SimConfig, Variant};
use crate::linalg::ParallelPolicy;
use crate::util::table::Table;
use crate::util::timer::time_once;

use super::prep::prepare;
use super::ReportCtx;

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    let trainer = CpuElmTrainer::with_policy(ParallelPolicy::with_workers(ctx.workers));
    let m = 50usize;
    let mut t = Table::new(
        "Table 5 — Opt-PR-ELM (BS=32, M=50) speedup per GPU (gpusim @ paper sizes) \
         + measured CPU pipeline speedup",
        &["Architecture", "GPU", "japan", "quebec", "exo", "sp500", "aemo", "weather", "energy", "elec", "stock", "temp"],
    );
    let datasets = registry();
    for arch in ALL_ARCHS {
        for (dev_name, dev) in [("Tesla", tesla_k20m()), ("Quadro", quadro_k2000())] {
            let mut row = vec![arch.name().to_string(), dev_name.to_string()];
            for d in &datasets {
                let cfg = SimConfig {
                    arch,
                    variant: Variant::Opt,
                    n: d.n_instances.saturating_sub(d.q_paper.min(64)),
                    s: 1,
                    q: d.q_paper.min(64),
                    m,
                    bs: 32,
                };
                let r = simulate(&cfg, &dev, &cpu_host());
                row.push(format!("{:.0}", r.speedup));
            }
            t.row(row);
        }
    }

    // measured column: this testbed, Q ∈ {10, 50} datasets (M = 50 grams)
    let mut meas = Table::new(
        &format!(
            "Table 5 (measured on this machine) — CPU parallel pipeline \
             ({} workers) vs sequential S-R-ELM, M=50 @ scale {}",
            trainer.policy.workers, ctx.scale
        ),
        &["Dataset", "Architecture", "seq (s)", "parallel (s)", "speedup"],
    );
    // representative subset at sizes where parallelism is visible: the
    // full medium dataset and 20% of a large one (Q = 10; the Q = 50 FC
    // sequential baseline would take minutes per cell)
    for (name, floor) in [("aemo", 1.0), ("energy_consumption", 0.2)] {
        let d = datasets.iter().find(|d| d.name == name).expect("registry");
        let scale = ctx.scale.max(floor);
        let (train, _test) = prepare(d, scale, ctx.seed)?;
        for arch in ALL_ARCHS {
            // warm-up run: touch every code path once so the timed run
            // measures steady-state execution (page faults, allocator and
            // branch-predictor warmth), mirroring the paper's averages
            // which exclude one-time CUDA jit
            let _ = trainer.train(arch, &train, m, ctx.seed)?;
            let (_m1, seq_t) = time_once(|| {
                SrElmModel::train(arch, &train, &TrainOptions::new(m, ctx.seed)).unwrap()
            });
            let (res, par_t) = time_once(|| trainer.train(arch, &train, m, ctx.seed).unwrap());
            let _ = res;
            meas.row(vec![
                d.name.to_string(),
                arch.name().to_string(),
                format!("{:.3}", seq_t.as_secs_f64()),
                format!("{:.3}", par_t.as_secs_f64()),
                format!("{:.1}", seq_t.as_secs_f64() / par_t.as_secs_f64()),
            ]);
        }
    }
    Ok(vec![t, meas])
}
