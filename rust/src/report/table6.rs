//! Table 6: runtime of Opt-PR-ELM vs P-BPTT (fc / lstm / gru, M = 10) —
//! fully *measured*: both trainers run on this machine through their AOT
//! executables (the paper ran both on the same Tesla K20m; we run both on
//! the same PJRT CPU client, preserving the comparison's symmetry).

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::bptt::{BpttArch, BpttTrainer};
use crate::coordinator::PrElmTrainer;
use crate::data::spec::registry;
use crate::elm::Arch;
use crate::util::table::Table;
use crate::util::timer::time_once;

use super::prep::prepare;
use super::ReportCtx;

pub fn emit(ctx: &ReportCtx) -> Result<Vec<Table>> {
    let elm = PrElmTrainer::new(&ctx.artifacts, ctx.workers)?;
    let bptt = BpttTrainer::new(&ctx.artifacts)?;
    let m = 10usize;
    let mut t = Table::new(
        &format!(
            "Table 6 — runtime (s): Opt-PR-ELM vs P-BPTT (M=10, 10 epochs, batch 64) @ scale {}",
            ctx.scale
        ),
        &[
            "Dataset", "FC elm", "FC bptt", "FC ratio", "LSTM elm", "LSTM bptt", "LSTM ratio",
            "GRU elm", "GRU bptt", "GRU ratio",
        ],
    );
    for d in registry() {
        if d.q != 10 && d.q != 50 {
            continue; // bptt artifacts cover Q ∈ {10, 50}; exoplanet (64) excluded
        }
        // bptt needs ≥ 1 full batch of 64 plus elm needs ≥ M rows
        let min_n = ((200 + d.q) as f64 / d.train_frac()) as usize + d.q;
        let scale = ctx.scale.max(min_n as f64 / d.n_instances as f64);
        let (train, _test) = prepare(&d, scale, ctx.seed)?;
        let mut row = vec![d.name.to_string()];
        for (elm_arch, bptt_arch) in [
            (Arch::Fc, BpttArch::Fc),
            (Arch::Lstm, BpttArch::Lstm),
            (Arch::Gru, BpttArch::Gru),
        ] {
            // warm-up: exclude one-time executable compilation from both
            let _ = elm.train(elm_arch, &train, m, ctx.seed)?;
            let (_m1, t_elm) =
                time_once(|| elm.train(elm_arch, &train, m, ctx.seed).unwrap());
            let (_m2, t_bptt) =
                time_once(|| bptt.train(bptt_arch, &train, m, ctx.seed).unwrap());
            let (e, b) = (t_elm.as_secs_f64(), t_bptt.as_secs_f64());
            row.push(format!("{e:.2}"));
            row.push(format!("{b:.2}"));
            row.push(format!("{:.0}", b / e));
        }
        t.row(row);
    }
    Ok(vec![t])
}
