//! `SolveError` — the typed failure taxonomy of the β-solve pipeline.
//!
//! Every way the solve substrate can refuse to produce β is one variant
//! here, replacing the stringly `anyhow::bail!` messages that
//! `solve.rs`/`tsqr.rs`/`cholesky.rs` used to emit. Public entry points
//! still return `anyhow::Result` (the crate-wide convention), but the
//! error *value* is a `SolveError`, so callers — the fleet coordinator,
//! the fault-injection suite — can `downcast_ref::<SolveError>()` and
//! branch on the failure class instead of grepping message strings.
//!
//! Design notes:
//!
//! * Variants carry owned, `Clone`-able payloads (indices, shapes, labels,
//!   stringified sources) rather than boxed error chains, so a
//!   `SolveError` can cross thread joins and be compared in tests.
//! * Provenance variants ([`SolveError::BlockFold`],
//!   [`SolveError::WorkerPanic`]) name the block/item index and job that
//!   failed — the fix for the old `"folded {next} of {} blocks"` message
//!   that said *how many* blocks folded but never *which one* poisoned
//!   the fold.

#![forbid(unsafe_code)]

use std::fmt;

/// Typed failure taxonomy for the β-solve pipeline (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum SolveError {
    /// Operand shapes disagree (A rows vs b length, G squareness, …).
    ShapeMismatch {
        /// Which operation detected the mismatch.
        context: &'static str,
        /// Human-readable shape detail, e.g. `"A is 30x8, b has 29"`.
        detail: String,
    },
    /// A triangular pivot fell below the relative rank tolerance.
    SingularPivot {
        /// Row of the offending diagonal entry.
        row: usize,
        /// The pivot value.
        pivot: f64,
        /// Largest |diagonal| of the factor (the relative reference).
        max_diag: f64,
    },
    /// A triangular or Cholesky pivot was NaN/Inf — upstream data poisoned
    /// the factor. Distinct from [`SolveError::SingularPivot`] so callers
    /// can tell "rank collapsed" from "inputs were non-finite".
    NonFinitePivot {
        /// Row of the offending diagonal entry.
        row: usize,
    },
    /// Cholesky hit a non-positive (or non-finite) pivot: the matrix is
    /// not positive definite (f32 partial noise or rank collapse).
    NotPositiveDefinite {
        /// Pivot index where the factorization failed.
        pivot: usize,
        /// The offending Schur-complement value (NaN when poisoned).
        value: f64,
    },
    /// Fewer accumulated rows than unknowns — no strategy can solve this.
    Underdetermined {
        /// Rows seen by the accumulator / assembled H.
        rows: usize,
        /// Columns (hidden width M) of the system.
        cols: usize,
    },
    /// A solve input (window, block, partial) contained NaN/Inf.
    NonFiniteInput {
        /// Which pipeline stage found the poison.
        site: &'static str,
        /// Index of the offending block/row within that stage.
        index: usize,
    },
    /// The accumulator was asked to solve before any block arrived.
    EmptyAccumulator,
    /// Every rung of the ridge degradation ladder failed; β cannot be
    /// produced for this system. `last` records the final rung's error.
    LadderExhausted {
        /// The base λ the ladder started from.
        base_lambda: f64,
        /// How many rungs (λ values) were attempted.
        attempts: u32,
        /// Stringified error of the last rung.
        last: String,
    },
    /// A per-block computation failed inside a fold; carries the block's
    /// index, shape, and the job it belonged to (the provenance the old
    /// partial-fold message dropped).
    BlockFold {
        /// Index of the failing block in the fixed block schedule.
        block: usize,
        /// Rows of the failing block.
        rows: usize,
        /// Columns (hidden width M) of the failing block.
        cols: usize,
        /// Job label (dataset/arch/M) the block belonged to.
        job: String,
        /// Stringified underlying error.
        source: String,
    },
    /// The in-order fold ended before every block arrived (a producer
    /// stopped early). Carries the job label for provenance.
    FoldIncomplete {
        /// Blocks folded before the stream ended.
        folded: usize,
        /// Blocks the schedule expected.
        total: usize,
        /// Job label (dataset/arch/M) the fold belonged to.
        job: String,
    },
    /// A worker-thread item panicked. `retried` says whether the
    /// sequential retry also panicked (isolated par_map) or the panic was
    /// caught on first execution (plain par_map, no retry semantics).
    WorkerPanic {
        /// Global item index (block index) that panicked.
        index: usize,
        /// Whether a sequential retry was attempted and also panicked.
        retried: bool,
        /// Panic payload rendered to text, when it was a string.
        message: String,
    },
    /// Input quarantine dropped every row — nothing left to train on.
    AllRowsQuarantined {
        /// Rows the dataset had before screening.
        rows: usize,
    },
    /// Two queued fleet `Train` requests named the same tenant — the
    /// fleet cannot decide which model the id should map to, so the
    /// second submission is rejected up front.
    DuplicateTenant {
        /// The tenant id submitted twice.
        tenant: String,
    },
    /// A fleet `Predict`/`Update` named a tenant with no cached model —
    /// never trained through this fleet, or already LRU-evicted.
    UnknownTenant {
        /// The tenant id that missed the model cache.
        tenant: String,
    },
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolveError::ShapeMismatch { context, detail } => {
                write!(f, "{context} shape mismatch: {detail}")
            }
            SolveError::SingularPivot { row, pivot, max_diag } => write!(
                f,
                "singular triangular system at row {row}: |pivot| = {:.3e} \
                 below relative tolerance of max diag {:.3e}",
                pivot.abs(),
                max_diag
            ),
            SolveError::NonFinitePivot { row } => {
                write!(f, "non-finite pivot at row {row}: factor is poisoned")
            }
            SolveError::NotPositiveDefinite { pivot, value } => write!(
                f,
                "matrix not positive definite at pivot {pivot} (s = {value:.3e})"
            ),
            SolveError::Underdetermined { rows, cols } => {
                write!(f, "underdetermined: {rows} rows < {cols} cols")
            }
            SolveError::NonFiniteInput { site, index } => {
                write!(f, "non-finite values in {site} at index {index}")
            }
            SolveError::EmptyAccumulator => write!(f, "no blocks accumulated"),
            SolveError::LadderExhausted { base_lambda, attempts, last } => write!(
                f,
                "degradation ladder exhausted after {attempts} rungs \
                 (base λ = {base_lambda:.1e}); last error: {last}"
            ),
            SolveError::BlockFold { block, rows, cols, job, source } => write!(
                f,
                "block {block} ({rows}x{cols}) of job {job} failed: {source}"
            ),
            SolveError::FoldIncomplete { folded, total, job } => {
                write!(f, "folded {folded} of {total} blocks for job {job}")
            }
            SolveError::WorkerPanic { index, retried, message } => {
                let phase = if *retried {
                    "panicked again on sequential retry"
                } else {
                    "panicked"
                };
                if message.is_empty() {
                    write!(f, "worker item {index} {phase}")
                } else {
                    write!(f, "worker item {index} {phase}: {message}")
                }
            }
            SolveError::AllRowsQuarantined { rows } => write!(
                f,
                "input quarantine dropped all {rows} rows (every window \
                 contained non-finite values)"
            ),
            SolveError::DuplicateTenant { tenant } => write!(
                f,
                "tenant {tenant:?} already has a queued train request \
                 (one model per tenant id per drain)"
            ),
            SolveError::UnknownTenant { tenant } => write!(
                f,
                "tenant {tenant:?} has no cached model (never trained, or \
                 LRU-evicted) — submit a train request first"
            ),
        }
    }
}

impl std::error::Error for SolveError {}

impl SolveError {
    /// Wrap a per-block failure with its fold provenance (block index,
    /// shape, job label) — the error chain the old fold message dropped.
    pub fn block_fold(
        block: usize,
        rows: usize,
        cols: usize,
        job: &str,
        source: &anyhow::Error,
    ) -> SolveError {
        SolveError::BlockFold {
            block,
            rows,
            cols,
            job: job.to_string(),
            source: format!("{source:#}"),
        }
    }

    /// Short kebab-case class name — stable across payload changes, used
    /// by logs and the fault-injection suite's assertions.
    pub fn class(&self) -> &'static str {
        match self {
            SolveError::ShapeMismatch { .. } => "shape-mismatch",
            SolveError::SingularPivot { .. } => "singular-pivot",
            SolveError::NonFinitePivot { .. } => "non-finite-pivot",
            SolveError::NotPositiveDefinite { .. } => "not-positive-definite",
            SolveError::Underdetermined { .. } => "underdetermined",
            SolveError::NonFiniteInput { .. } => "non-finite-input",
            SolveError::EmptyAccumulator => "empty-accumulator",
            SolveError::LadderExhausted { .. } => "ladder-exhausted",
            SolveError::BlockFold { .. } => "block-fold",
            SolveError::FoldIncomplete { .. } => "fold-incomplete",
            SolveError::WorkerPanic { .. } => "worker-panic",
            SolveError::AllRowsQuarantined { .. } => "all-rows-quarantined",
            SolveError::DuplicateTenant { .. } => "duplicate-tenant",
            SolveError::UnknownTenant { .. } => "unknown-tenant",
        }
    }
}

/// Pull the `SolveError` out of an `anyhow::Error`, walking the context
/// chain (test/diagnostic helper).
pub fn as_solve_error(err: &anyhow::Error) -> Option<&SolveError> {
    err.chain().find_map(|e| e.downcast_ref::<SolveError>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_informative() {
        let e = SolveError::SingularPivot { row: 3, pivot: 1e-14, max_diag: 2.0 };
        let s = e.to_string();
        assert!(s.contains("row 3"), "{s}");
        let e = SolveError::BlockFold {
            block: 7,
            rows: 256,
            cols: 50,
            job: "lorenz/elman M=50".into(),
            source: "engine died".into(),
        };
        let s = e.to_string();
        assert!(s.contains("block 7") && s.contains("256x50") && s.contains("lorenz"), "{s}");
    }

    #[test]
    fn downcasts_through_anyhow() {
        let err: anyhow::Error = SolveError::EmptyAccumulator.into();
        let err = err.context("while solving");
        let found = as_solve_error(&err).expect("downcast");
        assert_eq!(*found, SolveError::EmptyAccumulator);
        assert_eq!(found.class(), "empty-accumulator");
    }

    #[test]
    fn classes_are_distinct() {
        let all = [
            SolveError::ShapeMismatch { context: "x", detail: String::new() }.class(),
            SolveError::SingularPivot { row: 0, pivot: 0.0, max_diag: 0.0 }.class(),
            SolveError::NonFinitePivot { row: 0 }.class(),
            SolveError::NotPositiveDefinite { pivot: 0, value: 0.0 }.class(),
            SolveError::Underdetermined { rows: 0, cols: 1 }.class(),
            SolveError::NonFiniteInput { site: "x", index: 0 }.class(),
            SolveError::EmptyAccumulator.class(),
            SolveError::LadderExhausted { base_lambda: 0.0, attempts: 0, last: String::new() }
                .class(),
            SolveError::BlockFold {
                block: 0,
                rows: 0,
                cols: 0,
                job: String::new(),
                source: String::new(),
            }
            .class(),
            SolveError::FoldIncomplete { folded: 0, total: 0, job: String::new() }.class(),
            SolveError::WorkerPanic { index: 0, retried: false, message: String::new() }
                .class(),
            SolveError::AllRowsQuarantined { rows: 0 }.class(),
            SolveError::DuplicateTenant { tenant: String::new() }.class(),
            SolveError::UnknownTenant { tenant: String::new() }.class(),
        ];
        let mut set = std::collections::HashSet::new();
        for c in all {
            assert!(set.insert(c), "duplicate class {c}");
        }
    }
}
