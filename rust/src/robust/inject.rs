//! Deterministic, seed-keyed fault injection for the training pipeline.
//!
//! The harness corrupts the pipeline at eight sites — data windows, H
//! blocks, sequence-parallel scan chunks, Gram partials, TSQR leaves,
//! worker threads, fleet jobs, service-queue requests — with a taxonomy
//! of faults (NaN/Inf payloads, denormal scaling, rank-collapsed columns,
//! truncated blocks, injected worker panics, deadline skew). Whether a
//! given (site,
//! block-index) pair is corrupted is a pure function of the armed plan's
//! seed and the index — **never** of the worker count or thread schedule —
//! so an injected run is as reproducible as a healthy one (§7.3).
//!
//! # Zero cost when disabled
//!
//! The hook functions below ([`corrupt_slice_f64`], [`corrupt_slice_f32`],
//! [`truncated_rows`], [`maybe_panic`], [`armed_for`]) are always
//! callable, but without the `fault-inject` cargo feature they compile to
//! `#[inline(always)]` no-ops: release builds carry no injection state,
//! no locks, and no branches that matter. The arming API
//! ([`arm`]/[`InjectorGuard`]/[`take_events`]) only exists under the
//! feature.
//!
//! # Arming
//!
//! ```ignore
//! let _g = robust::inject::arm(FaultPlan {
//!     seed: 42,
//!     site: Site::HBlock,
//!     fault: Fault::NanPayload,
//!     period: 1, // every index at the site
//! });
//! // ... run training; faults fire deterministically ...
//! let events = robust::inject::take_events();
//! ```
//!
//! `arm` holds a global mutex for the guard's lifetime, so concurrent
//! tests serialize instead of cross-contaminating each other's plans. An
//! injected worker panic fires **once per (site, index)**: the panic
//! isolation's sequential retry then succeeds, which is exactly the
//! recovery path the suite needs to demonstrate.

#![forbid(unsafe_code)]

/// Pipeline site a fault plan targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Site {
    /// The windowed dataset before any block is cut (quarantine's input).
    DataWindow,
    /// A computed H block, before it reaches its consumer.
    HBlock,
    /// A sequence-parallel recurrence chunk (`RecurrenceMode::Chunked`):
    /// panics fire at chunk starts and payload/truncation faults on the
    /// chunked kernel's output, all keyed by **chunk index** within the
    /// fixed `chunk_schedule` — never by worker count or thread schedule.
    /// Only the chunked dispatch path carries this site; sequential-mode
    /// runs never reach it.
    ScanChunk,
    /// A per-block (HᵀH, HᵀY) Gram partial.
    GramPartial,
    /// A TSQR leaf, right before its local QR factorization.
    TsqrLeaf,
    /// A worker-thread item (panic injection).
    Worker,
    /// One tenant's work inside a fleet group solve: payload faults hit
    /// every H block of the targeted tenant and panics fire at the
    /// tenant's first block task, all keyed by the **tenant's train index
    /// within the drain batch** (submission order) — never by group
    /// composition, worker count, or schedule. The per-tenant isolation
    /// contract (a poisoned tenant must not perturb its group-mates) is
    /// tested through this site.
    FleetJob,
    /// One admitted request in the fleet service's queue
    /// (`coordinator::service`): [`Fault::DeadlineSkew`] marks the request
    /// as past-deadline at its next scheduling check and
    /// [`Fault::WorkerPanic`] panics its dispatch (triggering the
    /// service's retry/backoff path), both keyed by the request's
    /// **admission index** — never by worker count, queue depth, or
    /// schedule. The per-request isolation contract (a shed or retried
    /// request must not perturb any other tenant's β bits) is tested
    /// through this site.
    ServiceQueue,
}

impl Site {
    /// Stable lowercase name for logs and assertions.
    pub fn name(self) -> &'static str {
        match self {
            Site::DataWindow => "data-window",
            Site::HBlock => "h-block",
            Site::ScanChunk => "scan-chunk",
            Site::GramPartial => "gram-partial",
            Site::TsqrLeaf => "tsqr-leaf",
            Site::Worker => "worker",
            Site::FleetJob => "fleet-job",
            Site::ServiceQueue => "service-queue",
        }
    }
}

/// Fault class a plan injects at its site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fault {
    /// Scatter NaN into the payload.
    NanPayload,
    /// Scatter ±Inf into the payload.
    InfPayload,
    /// Scale the whole payload into the denormal range.
    DenormalScale,
    /// Copy column 0 over the last column (rank collapse by duplication).
    DuplicateColumns,
    /// Overwrite column 0 with the constant 1.0 (rank collapse against
    /// any bias-like feature).
    ConstantColumn,
    /// Halve the row count the consumer is told about (truncated block).
    TruncateRows,
    /// Panic the worker item (fires once per index; the retry succeeds).
    WorkerPanic,
    /// Report a queued service request as past its deadline at the next
    /// scheduling check (only [`Site::ServiceQueue`] consumes it — the
    /// payload-corruption hooks ignore it).
    DeadlineSkew,
}

impl Fault {
    /// Stable lowercase name for logs and assertions.
    pub fn name(self) -> &'static str {
        match self {
            Fault::NanPayload => "nan-payload",
            Fault::InfPayload => "inf-payload",
            Fault::DenormalScale => "denormal-scale",
            Fault::DuplicateColumns => "duplicate-columns",
            Fault::ConstantColumn => "constant-column",
            Fault::TruncateRows => "truncate-rows",
            Fault::WorkerPanic => "worker-panic",
            Fault::DeadlineSkew => "deadline-skew",
        }
    }
}

/// One armed injection campaign: which fault, where, how often.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed keying the per-index fire decision (deterministic).
    pub seed: u64,
    /// Site the faults target.
    pub site: Site,
    /// Fault class to inject.
    pub fault: Fault,
    /// Fire roughly one in `period` indices (deterministic in the seed);
    /// `0`/`1` fire at every index of the site.
    pub period: usize,
}

/// One fault that actually fired (drained via [`take_events`]).
#[cfg(feature = "fault-inject")]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectionEvent {
    /// Site the fault fired at.
    pub site: Site,
    /// Index within the site's schedule.
    pub index: usize,
    /// Which fault class fired.
    pub fault: Fault,
}

#[cfg(feature = "fault-inject")]
mod active {
    use super::{Fault, FaultPlan, InjectionEvent, Site};
    use std::sync::{Mutex, MutexGuard, RwLock};

    // arm() serializes campaigns across threads by holding ARM_LOCK for
    // the guard's lifetime; assert failures in a test poison it, so every
    // acquisition shrugs the poison off (the protected state is reset on
    // each arm anyway).
    static ARM_LOCK: Mutex<()> = Mutex::new(());
    static PLAN: RwLock<Option<FaultPlan>> = RwLock::new(None);
    static FIRED_PANICS: Mutex<Vec<(Site, usize)>> = Mutex::new(Vec::new());
    static EVENTS: Mutex<Vec<InjectionEvent>> = Mutex::new(Vec::new());

    /// RAII handle for an armed plan: disarms (and releases the global
    /// arm lock) on drop.
    pub struct InjectorGuard {
        _lock: MutexGuard<'static, ()>,
    }

    impl Drop for InjectorGuard {
        fn drop(&mut self) {
            *PLAN.write().unwrap_or_else(|e| e.into_inner()) = None;
        }
    }

    /// Arm a fault plan; faults fire until the guard drops. Concurrent
    /// arms (parallel tests) block here instead of interleaving.
    pub fn arm(plan: FaultPlan) -> InjectorGuard {
        let lock = ARM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        *PLAN.write().unwrap_or_else(|e| e.into_inner()) = Some(plan);
        FIRED_PANICS.lock().unwrap_or_else(|e| e.into_inner()).clear();
        EVENTS.lock().unwrap_or_else(|e| e.into_inner()).clear();
        InjectorGuard { _lock: lock }
    }

    /// Drain the events fired since [`arm`] (order is nondeterministic
    /// across worker threads; sort before comparing).
    pub fn take_events() -> Vec<InjectionEvent> {
        std::mem::take(&mut *EVENTS.lock().unwrap_or_else(|e| e.into_inner()))
    }

    fn plan() -> Option<FaultPlan> {
        *PLAN.read().unwrap_or_else(|e| e.into_inner())
    }

    pub fn armed_for(site: Site) -> bool {
        plan().is_some_and(|p| p.site == site)
    }

    /// The deterministic per-index fire decision: a pure function of
    /// (plan.seed, index) — never of worker count or schedule.
    fn fires(site: Site, index: usize) -> Option<Fault> {
        let p = plan()?;
        if p.site != site {
            return None;
        }
        if p.period > 1 {
            let mut rng = crate::util::rng::Rng::new(
                p.seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            if rng.next_u64() % p.period as u64 != 0 {
                return None;
            }
        }
        Some(p.fault)
    }

    fn log(site: Site, index: usize, fault: Fault) {
        EVENTS
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(InjectionEvent { site, index, fault });
    }

    /// Shared payload corruption over a row-major slice; the f64/f32
    /// hooks both funnel here via a generic scalar adapter.
    fn corrupt<T: Copy>(
        site: Site,
        index: usize,
        data: &mut [T],
        rows: usize,
        cols: usize,
        nan: T,
        inf: impl Fn(usize) -> T,
        one: T,
        denormal_scale: impl Fn(T) -> T,
        seed_mix: u64,
    ) -> bool {
        let Some(fault) = fires(site, index) else { return false };
        if data.is_empty() {
            return false;
        }
        let fired = match fault {
            Fault::NanPayload | Fault::InfPayload => {
                let mut rng = crate::util::rng::Rng::new(seed_mix ^ index as u64);
                let k = (data.len() / 64).max(1);
                for j in 0..k {
                    let pos = rng.below(data.len());
                    data[pos] = match fault {
                        Fault::NanPayload => nan,
                        _ => inf(j),
                    };
                }
                true
            }
            Fault::DenormalScale => {
                for v in data.iter_mut() {
                    *v = denormal_scale(*v);
                }
                true
            }
            Fault::DuplicateColumns => {
                if cols < 2 {
                    false
                } else {
                    for r in 0..rows {
                        data[r * cols + cols - 1] = data[r * cols];
                    }
                    true
                }
            }
            Fault::ConstantColumn => {
                for r in 0..rows {
                    data[r * cols] = one;
                }
                true
            }
            Fault::TruncateRows | Fault::WorkerPanic | Fault::DeadlineSkew => false,
        };
        if fired {
            log(site, index, fault);
        }
        fired
    }

    pub fn corrupt_slice_f64(
        site: Site,
        index: usize,
        data: &mut [f64],
        rows: usize,
        cols: usize,
    ) -> bool {
        corrupt(
            site,
            index,
            data,
            rows,
            cols,
            f64::NAN,
            |j| if j % 2 == 0 { f64::INFINITY } else { f64::NEG_INFINITY },
            1.0,
            |v| v * 1e-310,
            0xF64,
        )
    }

    pub fn corrupt_slice_f32(
        site: Site,
        index: usize,
        data: &mut [f32],
        rows: usize,
        cols: usize,
    ) -> bool {
        corrupt(
            site,
            index,
            data,
            rows,
            cols,
            f32::NAN,
            |j| if j % 2 == 0 { f32::INFINITY } else { f32::NEG_INFINITY },
            1.0,
            |v| v * 1e-42,
            0xF32,
        )
    }

    pub fn truncated_rows(site: Site, index: usize, rows: usize) -> usize {
        match fires(site, index) {
            Some(Fault::TruncateRows) if rows > 1 => {
                log(site, index, Fault::TruncateRows);
                rows / 2
            }
            _ => rows,
        }
    }

    pub fn deadline_skew(site: Site, index: usize) -> bool {
        if fires(site, index) != Some(Fault::DeadlineSkew) {
            return false;
        }
        log(site, index, Fault::DeadlineSkew);
        true
    }

    pub fn maybe_panic(site: Site, index: usize) {
        if fires(site, index) != Some(Fault::WorkerPanic) {
            return;
        }
        {
            let mut fired = FIRED_PANICS.lock().unwrap_or_else(|e| e.into_inner());
            if fired.contains(&(site, index)) {
                return; // second execution (the retry) succeeds
            }
            fired.push((site, index));
        }
        log(site, index, Fault::WorkerPanic);
        panic!("injected worker panic at {} index {index}", site.name());
    }
}

#[cfg(feature = "fault-inject")]
pub use active::{arm, take_events, InjectorGuard};

/// True when a plan targeting `site` is armed (lets callers skip
/// fault-only work, e.g. cloning the dataset for window corruption).
/// Always `false` without the `fault-inject` feature.
#[cfg(feature = "fault-inject")]
pub fn armed_for(site: Site) -> bool {
    active::armed_for(site)
}

/// See the feature-gated twin; compiled to a constant without
/// `fault-inject`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn armed_for(_site: Site) -> bool {
    false
}

/// Corrupt a row-major f64 payload at `site`/`index` per the armed plan;
/// returns whether a fault fired. No-op without `fault-inject`.
#[cfg(feature = "fault-inject")]
pub fn corrupt_slice_f64(
    site: Site,
    index: usize,
    data: &mut [f64],
    rows: usize,
    cols: usize,
) -> bool {
    active::corrupt_slice_f64(site, index, data, rows, cols)
}

/// See the feature-gated twin; compiled to a no-op without `fault-inject`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn corrupt_slice_f64(
    _site: Site,
    _index: usize,
    _data: &mut [f64],
    _rows: usize,
    _cols: usize,
) -> bool {
    false
}

/// Corrupt a row-major f32 payload at `site`/`index` per the armed plan;
/// returns whether a fault fired. No-op without `fault-inject`.
#[cfg(feature = "fault-inject")]
pub fn corrupt_slice_f32(
    site: Site,
    index: usize,
    data: &mut [f32],
    rows: usize,
    cols: usize,
) -> bool {
    active::corrupt_slice_f32(site, index, data, rows, cols)
}

/// See the feature-gated twin; compiled to a no-op without `fault-inject`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn corrupt_slice_f32(
    _site: Site,
    _index: usize,
    _data: &mut [f32],
    _rows: usize,
    _cols: usize,
) -> bool {
    false
}

/// Row count the consumer should believe: halved when a `TruncateRows`
/// plan fires at this (site, index), unchanged otherwise (and always
/// unchanged without `fault-inject`).
#[cfg(feature = "fault-inject")]
pub fn truncated_rows(site: Site, index: usize, rows: usize) -> usize {
    active::truncated_rows(site, index, rows)
}

/// See the feature-gated twin; identity without `fault-inject`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn truncated_rows(_site: Site, _index: usize, rows: usize) -> usize {
    rows
}

/// True when a `DeadlineSkew` plan fires at this (site, index): the
/// service layer treats the request as past its deadline at the next
/// scheduling check. Fires (and logs an event) every time it is asked —
/// the fire decision stays the pure `(seed, index)` function shared by
/// every hook. Always `false` without `fault-inject`.
#[cfg(feature = "fault-inject")]
pub fn deadline_skew(site: Site, index: usize) -> bool {
    active::deadline_skew(site, index)
}

/// See the feature-gated twin; compiled to a constant without
/// `fault-inject`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn deadline_skew(_site: Site, _index: usize) -> bool {
    false
}

/// Panic the current worker item when a `WorkerPanic` plan fires at this
/// (site, index) — once per index, so the sequential retry succeeds.
/// No-op without `fault-inject`.
#[cfg(feature = "fault-inject")]
pub fn maybe_panic(site: Site, index: usize) {
    active::maybe_panic(site, index)
}

/// See the feature-gated twin; no-op without `fault-inject`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn maybe_panic(_site: Site, _index: usize) {}

#[cfg(all(test, feature = "fault-inject"))]
mod tests {
    use super::*;

    #[test]
    fn disarmed_hooks_are_noops() {
        // no plan armed (take the lock to keep parallel tests out)
        let g = arm(FaultPlan {
            seed: 1,
            site: Site::Worker,
            fault: Fault::WorkerPanic,
            period: 1,
        });
        drop(g);
        let mut data = vec![1.0f64; 8];
        assert!(!corrupt_slice_f64(Site::HBlock, 0, &mut data, 2, 4));
        assert_eq!(data, vec![1.0f64; 8]);
        assert_eq!(truncated_rows(Site::HBlock, 0, 7), 7);
        maybe_panic(Site::Worker, 3); // must not panic
        assert!(!armed_for(Site::Worker));
    }

    #[test]
    fn fire_pattern_is_deterministic_in_seed_and_index() {
        let plan =
            FaultPlan { seed: 9, site: Site::HBlock, fault: Fault::NanPayload, period: 3 };
        let pattern = |p: FaultPlan| -> Vec<usize> {
            let _g = arm(p);
            let mut hits = Vec::new();
            for idx in 0..64 {
                let mut data = vec![1.0f64; 16];
                if corrupt_slice_f64(Site::HBlock, idx, &mut data, 4, 4) {
                    hits.push(idx);
                }
            }
            hits
        };
        let a = pattern(plan);
        let b = pattern(plan);
        assert_eq!(a, b, "same seed must fire at the same indices");
        assert!(!a.is_empty() && a.len() < 64, "period 3 fires a strict subset: {a:?}");
        let c = pattern(FaultPlan { seed: 10, ..plan });
        assert_ne!(a, c, "different seed, different pattern");
    }

    #[test]
    fn payload_faults_do_what_they_say() {
        let base = vec![0.5f64; 12];
        let run = |fault: Fault| -> Vec<f64> {
            let _g = arm(FaultPlan { seed: 3, site: Site::HBlock, fault, period: 1 });
            let mut data = base.clone();
            assert!(corrupt_slice_f64(Site::HBlock, 0, &mut data, 3, 4));
            let ev = take_events();
            assert_eq!(ev.len(), 1);
            assert_eq!(ev[0].fault, fault);
            data
        };
        assert!(run(Fault::NanPayload).iter().any(|v| v.is_nan()));
        assert!(run(Fault::InfPayload).iter().any(|v| v.is_infinite()));
        let den = run(Fault::DenormalScale);
        assert!(den.iter().all(|v| v.is_finite() && v.abs() < f64::MIN_POSITIVE));
        let dup = run(Fault::DuplicateColumns);
        for r in 0..3 {
            assert_eq!(dup[r * 4 + 3], dup[r * 4]);
        }
        let cst = run(Fault::ConstantColumn);
        for r in 0..3 {
            assert_eq!(cst[r * 4], 1.0);
        }
    }

    #[test]
    fn truncation_and_site_filtering() {
        let _g = arm(FaultPlan {
            seed: 5,
            site: Site::HBlock,
            fault: Fault::TruncateRows,
            period: 1,
        });
        assert_eq!(truncated_rows(Site::HBlock, 2, 10), 5);
        // other sites untouched
        assert_eq!(truncated_rows(Site::TsqrLeaf, 2, 10), 10);
        let mut data = vec![1.0f32; 8];
        assert!(!corrupt_slice_f32(Site::GramPartial, 0, &mut data, 2, 4));
        assert!(armed_for(Site::HBlock));
        assert!(!armed_for(Site::Worker));
    }

    #[test]
    fn deadline_skew_fires_deterministically_and_only_at_its_site() {
        let _g = arm(FaultPlan {
            seed: 11,
            site: Site::ServiceQueue,
            fault: Fault::DeadlineSkew,
            period: 3,
        });
        let first: Vec<bool> = (0..32).map(|i| deadline_skew(Site::ServiceQueue, i)).collect();
        let second: Vec<bool> = (0..32).map(|i| deadline_skew(Site::ServiceQueue, i)).collect();
        assert_eq!(first, second, "pure function of (seed, index)");
        let hits = first.iter().filter(|&&b| b).count();
        assert!(hits > 0 && hits < 32, "period 3 fires a strict subset: {hits}");
        // other sites and other hooks untouched
        assert!(!deadline_skew(Site::FleetJob, 0));
        let mut data = vec![1.0f64; 8];
        assert!(!corrupt_slice_f64(Site::ServiceQueue, 0, &mut data, 2, 4));
        assert_eq!(truncated_rows(Site::ServiceQueue, 0, 10), 10);
        maybe_panic(Site::ServiceQueue, 0); // DeadlineSkew plan: must not panic
        let events = take_events();
        assert!(events.iter().all(|e| e.fault == Fault::DeadlineSkew
            && e.site == Site::ServiceQueue));
        assert_eq!(events.len(), 2 * hits, "both sweeps logged");
    }

    #[test]
    fn worker_panic_fires_once_per_index() {
        let _g = arm(FaultPlan {
            seed: 7,
            site: Site::Worker,
            fault: Fault::WorkerPanic,
            period: 1,
        });
        let caught = std::panic::catch_unwind(|| maybe_panic(Site::Worker, 4));
        assert!(caught.is_err(), "first execution must panic");
        maybe_panic(Site::Worker, 4); // retry: must not panic
        let ev = take_events();
        assert_eq!(ev, vec![InjectionEvent {
            site: Site::Worker,
            index: 4,
            fault: Fault::WorkerPanic
        }]);
    }
}
