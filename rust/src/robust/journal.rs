//! Crash-safe tenant journal — append-only, checksummed β/Gram state log.
//!
//! The fleet service (`coordinator::service`) journals every completed
//! train/update so a crashed process can rebuild its warm cache
//! **bit-identically**: the journal stores the exact f64 bit patterns of
//! β, the Gram accumulator, and (when online RLS has run) the RLS
//! covariance P, plus the `(arch, s, q, m, seed)` tuple that
//! deterministically regenerates the random ELM parameters via
//! [`ElmParams::init`](crate::elm::ElmParams::init).
//!
//! ## Format
//!
//! The byte log is an 8-byte magic header (`PALJRN01`) followed by framed
//! records:
//!
//! ```text
//! [u32 LE payload-len][payload bytes][u64 LE FNV-1a(payload)]
//! ```
//!
//! All integers are little-endian; every float is stored as its raw IEEE-754
//! bit pattern (`f64::to_bits`), so round-tripping is exact — including NaN
//! payloads and signed zeros. Later records for the same tenant supersede
//! earlier ones on recovery, which is how post-crash replay after
//! `elm::online` RLS updates converges on the live cache.
//!
//! ## Torn tails
//!
//! A crash mid-append leaves a truncated or corrupted final record.
//! [`TenantJournal::recover`] detects this with the length frame and the
//! FNV-1a checksum, stops at the last intact record, and reports the tear
//! as a typed [`JournalTorn`] — never a panic. Everything before the tear
//! is recovered normally.

#![forbid(unsafe_code)]

use crate::elm::Arch;
use crate::linalg::Matrix;
use crate::robust::report::{
    DeficiencyVerdict, DegradationRung, SolveReport, SolveStrategyKind,
};

/// Magic header identifying a tenant journal byte log (version 01).
pub const JOURNAL_MAGIC: [u8; 8] = *b"PALJRN01";

/// Everything needed to rebuild one tenant's cache entry bit-identically:
/// the deterministic parameter tuple, the trained β bits, the Gram
/// accumulator, the solve provenance, and the optional RLS state.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSnapshot {
    /// Architecture of the tenant's model.
    pub arch: Arch,
    /// Exogenous input width the model was trained with.
    pub s: usize,
    /// Feedback window length Q.
    pub q: usize,
    /// Hidden width M.
    pub m: usize,
    /// Seed that regenerates the random parameters via `ElmParams::init`.
    pub seed: u64,
    /// Trained output weights (exact f64 bits).
    pub beta: Vec<f64>,
    /// Gram accumulator `HᵀH` the fleet trainer cached for RLS seeding.
    pub gram: Matrix,
    /// Rows folded into `gram` / seen by the solve.
    pub rows: usize,
    /// Provenance of the solve that produced β.
    pub report: SolveReport,
    /// Online RLS state, present once `Update` requests have run.
    pub rls: Option<RlsSnapshot>,
}

/// RLS state beyond what the cache entry already carries: the covariance
/// P = (HᵀH + λI)⁻¹ and the λ it was seeded with. β and the row count are
/// shared with the snapshot (they stay in sync after every update).
#[derive(Debug, Clone, PartialEq)]
pub struct RlsSnapshot {
    /// The m×m covariance matrix (exact f64 bits).
    pub p: Matrix,
    /// The ridge λ the RLS state was seeded with.
    pub lambda: f64,
}

/// A detected torn/corrupt journal tail: byte offset of the first
/// unrecoverable record and a human-readable reason.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalTorn {
    /// Byte offset (from the start of the log) of the torn record's frame.
    pub offset: usize,
    /// Why the record was rejected (`"truncated frame"`,
    /// `"checksum mismatch"`, …).
    pub reason: String,
}

/// Result of [`TenantJournal::recover`]: the surviving per-tenant
/// snapshots (in first-appended order, later records superseding earlier
/// ones), how many intact records were replayed, and the tear — if any —
/// that ended the replay.
#[derive(Debug, Clone)]
pub struct Recovered {
    /// One entry per tenant, ordered by first appearance in the journal.
    pub snapshots: Vec<(String, TenantSnapshot)>,
    /// Number of intact records replayed (superseded ones included).
    pub replayed: usize,
    /// The typed tear report when the tail was truncated or corrupt.
    pub torn: Option<JournalTorn>,
}

/// Append-only, checksummed byte log of tenant snapshots (see the module
/// docs for the frame format and the torn-tail contract).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantJournal {
    buf: Vec<u8>,
}

impl Default for TenantJournal {
    fn default() -> TenantJournal {
        TenantJournal::new()
    }
}

impl TenantJournal {
    /// Fresh journal holding only the magic header.
    pub fn new() -> TenantJournal {
        TenantJournal { buf: JOURNAL_MAGIC.to_vec() }
    }

    /// Adopt raw bytes (e.g. read back after a crash). No validation
    /// happens here — [`recover`](TenantJournal::recover) does all of it,
    /// so even a garbage buffer yields a typed report, not a panic.
    pub fn from_bytes(bytes: Vec<u8>) -> TenantJournal {
        TenantJournal { buf: bytes }
    }

    /// The raw byte log (magic header + framed records).
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Total size of the byte log in bytes.
    pub fn len_bytes(&self) -> usize {
        self.buf.len()
    }

    /// Byte offsets of every record boundary: the end of the header, then
    /// the end of each complete record. Truncating the log at any returned
    /// offset simulates a clean crash between appends; truncating anywhere
    /// else simulates a torn append.
    pub fn record_boundaries(&self) -> Vec<usize> {
        let mut bounds = Vec::new();
        if self.buf.len() < JOURNAL_MAGIC.len() {
            return bounds;
        }
        bounds.push(JOURNAL_MAGIC.len());
        let mut pos = JOURNAL_MAGIC.len();
        while pos + 4 <= self.buf.len() {
            let len = read_u32(&self.buf, pos) as usize;
            let end = pos + 4 + len + 8;
            if end > self.buf.len() {
                break;
            }
            bounds.push(end);
            pos = end;
        }
        bounds
    }

    /// Append one tenant snapshot as a framed, checksummed record.
    pub fn append(&mut self, tenant: &str, snap: &TenantSnapshot) {
        let payload = encode_snapshot(tenant, snap);
        self.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        let sum = fnv1a(&payload);
        self.buf.extend_from_slice(&payload);
        self.buf.extend_from_slice(&sum.to_le_bytes());
    }

    /// Replay the log: decode every intact record in order (later records
    /// for the same tenant supersede earlier ones) and stop at the first
    /// truncated or corrupt record, reporting it as a typed
    /// [`JournalTorn`]. Never panics, whatever the bytes.
    pub fn recover(&self) -> Recovered {
        let mut out = Recovered { snapshots: Vec::new(), replayed: 0, torn: None };
        if self.buf.len() < JOURNAL_MAGIC.len() {
            out.torn = Some(JournalTorn {
                offset: 0,
                reason: format!(
                    "log shorter than the {}-byte magic header",
                    JOURNAL_MAGIC.len()
                ),
            });
            return out;
        }
        if self.buf[..JOURNAL_MAGIC.len()] != JOURNAL_MAGIC {
            out.torn = Some(JournalTorn {
                offset: 0,
                reason: "bad magic header".to_string(),
            });
            return out;
        }
        let mut pos = JOURNAL_MAGIC.len();
        while pos < self.buf.len() {
            if pos + 4 > self.buf.len() {
                out.torn = Some(JournalTorn {
                    offset: pos,
                    reason: "truncated frame (partial length prefix)".to_string(),
                });
                return out;
            }
            let len = read_u32(&self.buf, pos) as usize;
            let payload_start = pos + 4;
            let payload_end = payload_start + len;
            let frame_end = payload_end + 8;
            if frame_end > self.buf.len() {
                out.torn = Some(JournalTorn {
                    offset: pos,
                    reason: "truncated frame (record extends past end of log)"
                        .to_string(),
                });
                return out;
            }
            let payload = &self.buf[payload_start..payload_end];
            let stored = read_u64(&self.buf, payload_end);
            if fnv1a(payload) != stored {
                out.torn = Some(JournalTorn {
                    offset: pos,
                    reason: "checksum mismatch".to_string(),
                });
                return out;
            }
            match decode_snapshot(payload) {
                Ok((tenant, snap)) => {
                    out.replayed += 1;
                    match out.snapshots.iter_mut().find(|(t, _)| *t == tenant) {
                        Some((_, slot)) => *slot = snap,
                        None => out.snapshots.push((tenant, snap)),
                    }
                }
                Err(reason) => {
                    out.torn = Some(JournalTorn { offset: pos, reason });
                    return out;
                }
            }
            pos = frame_end;
        }
        out
    }
}

/// FNV-1a over a byte slice — the journal's record checksum.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn read_u32(buf: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap())
}

fn read_u64(buf: &[u8], pos: usize) -> u64 {
    u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap())
}

// --- payload codec ---------------------------------------------------------

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    push_u64(out, v.to_bits());
}

fn push_str(out: &mut Vec<u8>, s: &str) {
    push_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn push_matrix(out: &mut Vec<u8>, m: &Matrix) {
    push_u32(out, m.rows as u32);
    push_u32(out, m.cols as u32);
    for &v in m.data() {
        push_f64(out, v);
    }
}

fn encode_report(out: &mut Vec<u8>, r: &SolveReport) {
    let strat = match r.strategy {
        SolveStrategyKind::Unspecified => 0u8,
        SolveStrategyKind::Qr => 1,
        SolveStrategyKind::Tsqr => 2,
        SolveStrategyKind::Gram => 3,
        SolveStrategyKind::Online => 4,
    };
    out.push(strat);
    match r.rung {
        DegradationRung::Primary => {
            out.push(0);
            push_u32(out, 0);
            push_f64(out, 0.0);
        }
        DegradationRung::Ridge { step, lambda } => {
            out.push(1);
            push_u32(out, step);
            push_f64(out, lambda);
        }
        DegradationRung::Failed => {
            out.push(2);
            push_u32(out, 0);
            push_f64(out, 0.0);
        }
    }
    match r.verdict {
        DeficiencyVerdict::NotChecked => {
            out.push(0);
            push_u64(out, 0);
        }
        DeficiencyVerdict::FullRank => {
            out.push(1);
            push_u64(out, 0);
        }
        DeficiencyVerdict::RankDeficient { pivot } => {
            out.push(2);
            push_u64(out, pivot as u64);
        }
        DeficiencyVerdict::NonFinite { row } => {
            out.push(3);
            push_u64(out, row as u64);
        }
    }
    push_f64(out, r.effective_lambda);
    push_u32(out, r.retries);
    push_u64(out, r.quarantined_rows as u64);
}

fn encode_snapshot(tenant: &str, snap: &TenantSnapshot) -> Vec<u8> {
    let mut out = Vec::new();
    push_str(&mut out, tenant);
    push_str(&mut out, snap.arch.name());
    push_u64(&mut out, snap.s as u64);
    push_u64(&mut out, snap.q as u64);
    push_u64(&mut out, snap.m as u64);
    push_u64(&mut out, snap.seed);
    push_u64(&mut out, snap.rows as u64);
    encode_report(&mut out, &snap.report);
    push_u32(&mut out, snap.beta.len() as u32);
    for &b in &snap.beta {
        push_f64(&mut out, b);
    }
    push_matrix(&mut out, &snap.gram);
    match &snap.rls {
        None => out.push(0),
        Some(r) => {
            out.push(1);
            push_f64(&mut out, r.lambda);
            push_matrix(&mut out, &r.p);
        }
    }
    out
}

/// Sequential cursor over a payload; every read is bounds-checked so a
/// corrupt-but-checksum-colliding payload still decodes to a typed error.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err("payload underrun".to_string());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String, String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "non-UTF-8 string".to_string())
    }

    fn matrix(&mut self) -> Result<Matrix, String> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let n = rows
            .checked_mul(cols)
            .ok_or_else(|| "matrix shape overflow".to_string())?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(self.f64()?);
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }
}

fn decode_report(c: &mut Cursor) -> Result<SolveReport, String> {
    let strategy = match c.u8()? {
        0 => SolveStrategyKind::Unspecified,
        1 => SolveStrategyKind::Qr,
        2 => SolveStrategyKind::Tsqr,
        3 => SolveStrategyKind::Gram,
        4 => SolveStrategyKind::Online,
        t => return Err(format!("unknown strategy tag {t}")),
    };
    let rung_tag = c.u8()?;
    let step = c.u32()?;
    let lambda = c.f64()?;
    let rung = match rung_tag {
        0 => DegradationRung::Primary,
        1 => DegradationRung::Ridge { step, lambda },
        2 => DegradationRung::Failed,
        t => return Err(format!("unknown rung tag {t}")),
    };
    let verdict_tag = c.u8()?;
    let verdict_arg = c.u64()? as usize;
    let verdict = match verdict_tag {
        0 => DeficiencyVerdict::NotChecked,
        1 => DeficiencyVerdict::FullRank,
        2 => DeficiencyVerdict::RankDeficient { pivot: verdict_arg },
        3 => DeficiencyVerdict::NonFinite { row: verdict_arg },
        t => return Err(format!("unknown verdict tag {t}")),
    };
    let effective_lambda = c.f64()?;
    let retries = c.u32()?;
    let quarantined_rows = c.u64()? as usize;
    Ok(SolveReport { strategy, rung, verdict, effective_lambda, retries, quarantined_rows })
}

fn decode_snapshot(payload: &[u8]) -> Result<(String, TenantSnapshot), String> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let tenant = c.string()?;
    let arch_name = c.string()?;
    let arch = Arch::parse(&arch_name).map_err(|e| e.to_string())?;
    let s = c.u64()? as usize;
    let q = c.u64()? as usize;
    let m = c.u64()? as usize;
    let seed = c.u64()?;
    let rows = c.u64()? as usize;
    let report = decode_report(&mut c)?;
    let beta_len = c.u32()? as usize;
    let mut beta = Vec::with_capacity(beta_len);
    for _ in 0..beta_len {
        beta.push(c.f64()?);
    }
    let gram = c.matrix()?;
    let rls = match c.u8()? {
        0 => None,
        1 => {
            let lambda = c.f64()?;
            let p = c.matrix()?;
            Some(RlsSnapshot { p, lambda })
        }
        t => return Err(format!("unknown rls tag {t}")),
    };
    if c.pos != payload.len() {
        return Err("trailing bytes after snapshot".to_string());
    }
    Ok((tenant, TenantSnapshot { arch, s, q, m, seed, beta, gram, rows, report, rls }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(m: usize, seed: u64, bump: f64) -> TenantSnapshot {
        let mut gram = Matrix::zeros(m, m);
        for i in 0..m {
            gram[(i, i)] = 1.0 + bump + i as f64 * 0.25;
        }
        TenantSnapshot {
            arch: Arch::Elman,
            s: 1,
            q: 3,
            m,
            seed,
            beta: (0..m).map(|i| bump + i as f64 * 0.125).collect(),
            gram,
            rows: 40,
            report: SolveReport {
                strategy: SolveStrategyKind::Gram,
                rung: DegradationRung::Ridge { step: 2, lambda: 1e-4 },
                verdict: DeficiencyVerdict::RankDeficient { pivot: 1 },
                effective_lambda: 1e-4,
                retries: 3,
                quarantined_rows: 2,
            },
            rls: Some(RlsSnapshot { p: Matrix::identity(m), lambda: 1e-6 }),
        }
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let mut j = TenantJournal::new();
        let mut a = snap(4, 7, 0.5);
        a.beta[0] = -0.0; // signed zero must survive
        a.beta[1] = f64::NAN; // NaN bits must survive
        j.append("alpha", &a);
        j.append("beta-tenant", &snap(3, 9, 1.5));
        let rec = j.recover();
        assert!(rec.torn.is_none());
        assert_eq!(rec.replayed, 2);
        assert_eq!(rec.snapshots.len(), 2);
        let (name, got) = &rec.snapshots[0];
        assert_eq!(name, "alpha");
        assert_eq!(got.beta[0].to_bits(), (-0.0f64).to_bits());
        assert_eq!(got.beta[1].to_bits(), a.beta[1].to_bits());
        assert_eq!(got.gram, a.gram);
        assert_eq!(got.report, a.report);
        assert_eq!(got.rls, a.rls);
        assert_eq!(rec.snapshots[1].1, snap(3, 9, 1.5));
    }

    #[test]
    fn later_record_supersedes_earlier() {
        let mut j = TenantJournal::new();
        j.append("t", &snap(4, 7, 0.0));
        j.append("t", &snap(4, 7, 9.0));
        let rec = j.recover();
        assert_eq!(rec.replayed, 2);
        assert_eq!(rec.snapshots.len(), 1);
        assert_eq!(rec.snapshots[0].1.beta[0], 9.0);
    }

    #[test]
    fn truncation_at_every_boundary_is_clean() {
        let mut j = TenantJournal::new();
        j.append("a", &snap(4, 1, 0.0));
        j.append("b", &snap(4, 2, 1.0));
        j.append("a", &snap(4, 1, 2.0));
        let bounds = j.record_boundaries();
        assert_eq!(bounds.len(), 4, "header + 3 records");
        for (i, &cut) in bounds.iter().enumerate() {
            let part = TenantJournal::from_bytes(j.as_bytes()[..cut].to_vec());
            let rec = part.recover();
            assert!(rec.torn.is_none(), "cut at boundary {i} must be clean");
            assert_eq!(rec.replayed, i);
        }
    }

    #[test]
    fn torn_tail_is_typed_and_prefix_survives() {
        let mut j = TenantJournal::new();
        j.append("a", &snap(4, 1, 0.0));
        j.append("b", &snap(4, 2, 1.0));
        let bounds = j.record_boundaries();
        // cut mid-way through the second record
        let cut = bounds[1] + (bounds[2] - bounds[1]) / 2;
        let part = TenantJournal::from_bytes(j.as_bytes()[..cut].to_vec());
        let rec = part.recover();
        let torn = rec.torn.expect("mid-record cut must be reported");
        assert_eq!(torn.offset, bounds[1]);
        assert!(torn.reason.contains("truncated"), "{}", torn.reason);
        assert_eq!(rec.replayed, 1, "intact prefix still recovers");
        assert_eq!(rec.snapshots[0].0, "a");
    }

    #[test]
    fn bit_flip_fails_checksum() {
        let mut j = TenantJournal::new();
        j.append("a", &snap(4, 1, 0.0));
        let mut bytes = j.as_bytes().to_vec();
        let mid = JOURNAL_MAGIC.len() + 20;
        bytes[mid] ^= 0x40;
        let rec = TenantJournal::from_bytes(bytes).recover();
        let torn = rec.torn.expect("flipped bit must be detected");
        assert!(torn.reason.contains("checksum"), "{}", torn.reason);
        assert_eq!(rec.replayed, 0);
    }

    #[test]
    fn garbage_bytes_never_panic() {
        for bytes in [
            Vec::new(),
            vec![0u8; 3],
            vec![0xFF; 64],
            JOURNAL_MAGIC.iter().copied().chain([9, 0, 0, 0]).collect(),
        ] {
            let rec = TenantJournal::from_bytes(bytes).recover();
            assert!(rec.torn.is_some());
            assert_eq!(rec.replayed, 0);
        }
    }
}
