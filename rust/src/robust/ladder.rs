//! The explicit degradation ladder: ridge normal equations with
//! escalating λ, ending in a typed failure.
//!
//! Every solve strategy degrades the same way: primary factorization
//! (QR/TSQR back-substitution, or the Gram strategy's ridge at its
//! configured λ) → the rungs of [`RIDGE_LADDER`] → typed
//! [`SolveError::LadderExhausted`]. Each rung's β is validated for
//! finiteness before it is accepted — a rung that "succeeds" with NaN in
//! it counts as failed, which closes the silent-NaN-β hole the old
//! fallbacks had.
//!
//! Bit-compatibility: the first rung is exactly the call the pre-ladder
//! code made (`lstsq_ridge_from_parts` at the caller's base λ), so any
//! solve that used to succeed produces the identical β bits; the ladder
//! only adds behavior where the old code errored out.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::linalg::solve::lstsq_ridge_from_parts;
use crate::linalg::Matrix;

use super::error::SolveError;
use super::report::{DegradationRung, SolveReport};

/// The escalating ridge λ rungs (relative λ — see
/// [`lstsq_ridge_from_parts`]'s scale-invariant regularization). Rungs at
/// or below the caller's base λ are skipped.
pub const RIDGE_LADDER: [f64; 3] = [1e-8, 1e-4, 1e-2];

/// The λ sequence the ladder will attempt from a base λ: the base itself,
/// then every [`RIDGE_LADDER`] rung strictly above it. A non-positive or
/// non-finite base falls back to the ladder's first rung.
pub fn ladder_lambdas(base: f64) -> Vec<f64> {
    let base = if base > 0.0 && base.is_finite() { base } else { RIDGE_LADDER[0] };
    let mut out = vec![base];
    for &l in RIDGE_LADDER.iter() {
        if l > base {
            out.push(l);
        }
    }
    out
}

/// True when every entry is finite (no NaN/Inf). The acceptance gate for
/// every rung's β.
pub fn all_finite(xs: &[f64]) -> bool {
    xs.iter().all(|v| v.is_finite())
}

/// Climb the ridge ladder on an accumulated normal-equation system
/// `(G + λI) β = c`, recording the outcome in `report`.
///
/// `primary_is_ridge` says whether the base-λ attempt *is* the strategy's
/// primary solve (the Gram strategy) — recorded as
/// [`DegradationRung::Primary`] — or a fallback from a failed QR/TSQR
/// primary, where even the base-λ rung counts as degradation
/// ([`DegradationRung::Ridge`] step 1). Every failed rung increments
/// `report.retries`; exhaustion sets [`DegradationRung::Failed`] and
/// returns a typed [`SolveError::LadderExhausted`].
pub fn ridge_ladder_solve(
    g: &Matrix,
    c: &[f64],
    base_lambda: f64,
    primary_is_ridge: bool,
    report: &mut SolveReport,
) -> Result<Vec<f64>> {
    let lambdas = ladder_lambdas(base_lambda);
    let mut attempts = 0u32;
    let mut last = String::new();
    for (i, &lambda) in lambdas.iter().enumerate() {
        attempts += 1;
        match lstsq_ridge_from_parts(g, c, lambda) {
            Ok(beta) if all_finite(&beta) => {
                report.rung = if primary_is_ridge && i == 0 {
                    DegradationRung::Primary
                } else {
                    // with a ridge primary the base rung was step "0", so
                    // escalations are steps 1.. either way
                    let step = if primary_is_ridge { i as u32 } else { i as u32 + 1 };
                    DegradationRung::Ridge { step, lambda }
                };
                report.effective_lambda = lambda;
                return Ok(beta);
            }
            Ok(_) => {
                report.retries += 1;
                last = format!("rung λ={lambda:.1e} produced non-finite β");
            }
            Err(e) => {
                report.retries += 1;
                last = format!("rung λ={lambda:.1e}: {e:#}");
            }
        }
    }
    report.rung = DegradationRung::Failed;
    Err(SolveError::LadderExhausted { base_lambda, attempts, last }.into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robust::error::as_solve_error;
    use crate::robust::report::SolveStrategyKind;
    use crate::util::rng::Rng;

    fn gram_of(rows: usize, cols: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(rows, cols, &mut rng);
        let b: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        (a.gram(), a.t_matvec(&b))
    }

    #[test]
    fn lambda_sequence_skips_rungs_below_base() {
        assert_eq!(ladder_lambdas(1e-8), vec![1e-8, 1e-4, 1e-2]);
        assert_eq!(ladder_lambdas(1e-6), vec![1e-6, 1e-4, 1e-2]);
        assert_eq!(ladder_lambdas(1e-3), vec![1e-3, 1e-2]);
        assert_eq!(ladder_lambdas(0.5), vec![0.5]);
        // degenerate bases fall back to the first rung
        assert_eq!(ladder_lambdas(0.0), vec![1e-8, 1e-4, 1e-2]);
        assert_eq!(ladder_lambdas(f64::NAN), vec![1e-8, 1e-4, 1e-2]);
    }

    #[test]
    fn healthy_system_takes_base_rung_bit_identically() {
        let (g, c) = gram_of(60, 6, 1);
        let direct = lstsq_ridge_from_parts(&g, &c, 1e-6).unwrap();
        let mut report = SolveReport::new(SolveStrategyKind::Gram);
        let beta = ridge_ladder_solve(&g, &c, 1e-6, true, &mut report).unwrap();
        assert_eq!(beta, direct, "base rung must be bit-identical to the direct call");
        assert_eq!(report.rung, DegradationRung::Primary);
        assert_eq!(report.effective_lambda, 1e-6);
        assert_eq!(report.retries, 0);
    }

    #[test]
    fn fallback_base_rung_counts_as_degradation() {
        let (g, c) = gram_of(60, 6, 2);
        let mut report = SolveReport::new(SolveStrategyKind::Tsqr);
        let beta = ridge_ladder_solve(&g, &c, 1e-8, false, &mut report).unwrap();
        assert!(all_finite(&beta));
        assert_eq!(report.rung, DegradationRung::Ridge { step: 1, lambda: 1e-8 });
    }

    #[test]
    fn poisoned_system_exhausts_with_typed_error() {
        let mut g = Matrix::identity(4);
        g[(2, 2)] = f64::NAN;
        let c = vec![1.0; 4];
        let mut report = SolveReport::new(SolveStrategyKind::Gram);
        let err = ridge_ladder_solve(&g, &c, 1e-8, true, &mut report).unwrap_err();
        let se = as_solve_error(&err).expect("typed error");
        assert!(matches!(se, SolveError::LadderExhausted { attempts: 3, .. }), "{se}");
        assert_eq!(report.rung, DegradationRung::Failed);
        assert_eq!(report.retries, 3);
    }

    #[test]
    fn finiteness_gate() {
        assert!(all_finite(&[0.0, -1.0, 1e300]));
        assert!(!all_finite(&[0.0, f64::NAN]));
        assert!(!all_finite(&[f64::INFINITY]));
        assert!(all_finite(&[]));
    }
}
