//! Robustness substrate: typed solve failures, degradation ladder,
//! input quarantine, and deterministic fault injection.
//!
//! The solve pipeline's robustness contract (the ground ROADMAP's fleet
//! trainer stands on) is: **every training call either returns a finite β
//! with a [`SolveReport`] recording how it was produced, or a typed
//! [`SolveError`] — never a silent NaN β and never a propagated worker
//! panic.** This module is that contract's home:
//!
//! * [`error`] — the [`SolveError`] taxonomy replacing the stringly
//!   `anyhow` bails of `solve.rs`/`tsqr.rs`/`cholesky.rs`.
//! * [`report`] — [`SolveReport`]: strategy, degradation rung, rank
//!   verdict, effective λ, retries, quarantined rows; threaded through
//!   `CpuElmTrainer`/`PrElmTrainer` in the `TrainBreakdown`.
//! * [`ladder`] — the uniform degradation ladder (primary factorization →
//!   escalating ridge λ → typed failure) all three `SolveStrategy`
//!   variants share, with a β-finiteness gate on every rung.
//! * [`quarantine`] — non-finite window screening before a poisoned row
//!   reaches the Gram fold; the clean path borrows (bit-identity).
//! * [`inject`] — the seed-keyed fault-injection harness behind the
//!   `fault-inject` cargo feature (no-op hooks otherwise).
//! * [`journal`] — the crash-safe tenant journal: append-only checksummed
//!   β/Gram/RLS state with typed torn-tail recovery, the persistence leg
//!   of the fleet service (`coordinator::service`).
//!
//! Invariant inherited from PRs 2–5: when no fault is injected and no
//! ladder rung fires, every β bit is unchanged — the robustness layer
//! only *adds* behavior where the old code returned NaN, bailed with a
//! string, or panicked.

#![forbid(unsafe_code)]
#![deny(missing_docs)]

pub mod error;
pub mod inject;
pub mod journal;
pub mod ladder;
pub mod quarantine;
pub mod report;

pub use error::{as_solve_error, SolveError};
pub use journal::{JournalTorn, Recovered, RlsSnapshot, TenantJournal, TenantSnapshot};
pub use ladder::{all_finite, ladder_lambdas, ridge_ladder_solve, RIDGE_LADDER};
pub use quarantine::{screen, Screened};
pub use report::{DeficiencyVerdict, DegradationRung, SolveReport, SolveStrategyKind};
