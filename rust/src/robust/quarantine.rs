//! Input quarantine: non-finite window screening before blocks poison
//! the Gram fold.
//!
//! One NaN sample row turns an entire (HᵀH, HᵀY) fold — and hence β —
//! into NaN. The screen runs once per training call, *before* the block
//! schedule is cut: rows whose x-window, y-history, or target contain
//! NaN/Inf are dropped, and the trainer proceeds on the surviving rows
//! with the dropped count recorded in the
//! [`SolveReport`](super::report::SolveReport).
//!
//! The clean path borrows: a dataset with no poisoned rows is returned
//! as-is (`Screened::Clean`), so healthy runs see the identical
//! `Windowed` value — same block boundaries, same bits — as before this
//! module existed.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::data::window::Windowed;

use super::error::SolveError;

/// Outcome of screening a windowed dataset.
pub enum Screened<'a> {
    /// No poisoned rows: the original dataset, borrowed untouched (the
    /// bit-identity path).
    Clean(&'a Windowed),
    /// Some rows dropped: a filtered copy plus the dropped count.
    Filtered {
        /// The surviving rows, re-packed contiguously in order.
        data: Windowed,
        /// How many rows the screen dropped.
        dropped: usize,
    },
}

impl<'a> Screened<'a> {
    /// The dataset to train on (original or filtered).
    pub fn data(&self) -> &Windowed {
        match self {
            Screened::Clean(w) => w,
            Screened::Filtered { data, .. } => data,
        }
    }

    /// Rows the screen dropped (0 on the clean path).
    pub fn dropped(&self) -> usize {
        match self {
            Screened::Clean(_) => 0,
            Screened::Filtered { dropped, .. } => *dropped,
        }
    }
}

/// True when every value the row feeds into H (x window, y-history) and
/// its target is finite.
fn row_is_finite(w: &Windowed, i: usize) -> bool {
    w.x_row(i).iter().all(|v| v.is_finite())
        && w.yhist_row(i).iter().all(|v| v.is_finite())
        && w.y[i].is_finite()
}

/// Screen a windowed dataset for non-finite rows (see the module docs).
/// Errors with a typed [`SolveError::AllRowsQuarantined`] when nothing
/// survives.
pub fn screen(w: &Windowed) -> Result<Screened<'_>> {
    let bad: Vec<usize> = (0..w.n).filter(|&i| !row_is_finite(w, i)).collect();
    if bad.is_empty() {
        return Ok(Screened::Clean(w));
    }
    if bad.len() == w.n {
        return Err(SolveError::AllRowsQuarantined { rows: w.n }.into());
    }
    let sq = w.s * w.q;
    let keep = w.n - bad.len();
    let mut out = Windowed {
        n: keep,
        s: w.s,
        q: w.q,
        x: Vec::with_capacity(keep * sq),
        y: Vec::with_capacity(keep),
        yhist: Vec::with_capacity(keep * w.q),
    };
    for i in 0..w.n {
        if row_is_finite(w, i) {
            out.x.extend_from_slice(w.x_row(i));
            out.yhist.extend_from_slice(w.yhist_row(i));
            out.y.push(w.y[i]);
        }
    }
    Ok(Screened::Filtered { data: out, dropped: bad.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::robust::error::as_solve_error;

    fn toy(n: usize, q: usize) -> Windowed {
        let series: Vec<f64> = (0..n + q).map(|i| (i as f64 * 0.1).sin()).collect();
        Windowed::from_series(&series, q).unwrap()
    }

    #[test]
    fn clean_dataset_is_borrowed_untouched() {
        let w = toy(50, 4);
        let s = screen(&w).unwrap();
        assert_eq!(s.dropped(), 0);
        assert!(matches!(s, Screened::Clean(_)));
        // same allocation, not a copy
        assert!(std::ptr::eq(s.data(), &w));
    }

    #[test]
    fn poisoned_rows_are_dropped_and_counted() {
        let mut w = toy(50, 4);
        w.x[3 * 4 + 1] = f32::NAN; // row 3's window
        w.y[10] = f32::INFINITY; // row 10's target
        w.yhist[20 * 4] = f32::NAN; // row 20's feedback history
        let s = screen(&w).unwrap();
        assert_eq!(s.dropped(), 3);
        let d = s.data();
        assert_eq!(d.n, 47);
        assert!(d.x.iter().all(|v| v.is_finite()));
        assert!(d.y.iter().all(|v| v.is_finite()));
        assert!(d.yhist.iter().all(|v| v.is_finite()));
        // surviving rows keep their content and order: old row 4 is new row 3
        assert_eq!(d.x_row(3), w.x_row(4));
        assert_eq!(d.y[3], w.y[4]);
        assert_eq!(d.yhist_row(3), w.yhist_row(4));
    }

    #[test]
    fn all_poisoned_is_a_typed_error() {
        let mut w = toy(8, 3);
        for v in w.y.iter_mut() {
            *v = f32::NAN;
        }
        let err = screen(&w).unwrap_err();
        let se = as_solve_error(&err).expect("typed error");
        assert_eq!(*se, SolveError::AllRowsQuarantined { rows: 8 });
    }
}
