//! `SolveReport` — provenance of *how* a β was produced.
//!
//! Every trained model's solve carries one: which strategy ran, which
//! degradation rung finally produced β, what the rank verdict on the
//! triangular factor was, the effective ridge λ, how many retries (failed
//! rungs + panic retries) it took, and how many input rows quarantine
//! dropped. The report is `Copy` so it rides inside
//! [`TrainBreakdown`](crate::coordinator::TrainBreakdown) without touching
//! that struct's derive set.

#![forbid(unsafe_code)]

/// Which β-solve pipeline produced (or attempted) the solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolveStrategyKind {
    /// No solve has run yet (the `Default` placeholder).
    #[default]
    Unspecified,
    /// Householder QR on the assembled H (`lstsq_qr` / DirectQr strategy).
    Qr,
    /// Communication-avoiding TSQR tree over row blocks.
    Tsqr,
    /// Ridge normal equations folded from (HᵀH, HᵀY) partials.
    Gram,
    /// Recursive least squares (`elm::online`).
    Online,
}

impl SolveStrategyKind {
    /// Stable lowercase name for logs and bench metadata.
    pub fn name(self) -> &'static str {
        match self {
            SolveStrategyKind::Unspecified => "unspecified",
            SolveStrategyKind::Qr => "qr",
            SolveStrategyKind::Tsqr => "tsqr",
            SolveStrategyKind::Gram => "gram",
            SolveStrategyKind::Online => "online",
        }
    }
}

/// Which rung of the degradation ladder produced β.
///
/// The ladder is: primary factorization (QR/TSQR back-substitution, or the
/// Gram strategy's ridge at its configured λ) → ridge normal equations
/// with escalating λ (see [`super::ladder::RIDGE_LADDER`]) → typed
/// failure. `step` counts rungs taken *beyond* the primary, starting at 1.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum DegradationRung {
    /// The strategy's primary solve succeeded — no degradation.
    #[default]
    Primary,
    /// A ridge fallback rung produced β.
    Ridge {
        /// 1-based rung index beyond the primary solve.
        step: u32,
        /// The λ that succeeded (relative, see `lstsq_ridge_from_parts`).
        lambda: f64,
    },
    /// Every rung failed; the solve returned a typed error.
    Failed,
}

impl DegradationRung {
    /// Stable rung family name: `"primary"`, `"ridge"`, or `"failed"` —
    /// the vocabulary `ci/check_bench.py` validates in bench metadata.
    pub fn name(self) -> &'static str {
        match self {
            DegradationRung::Primary => "primary",
            DegradationRung::Ridge { .. } => "ridge",
            DegradationRung::Failed => "failed",
        }
    }

    /// Detailed label, e.g. `"ridge[2]@1.0e-4"`.
    pub fn label(self) -> String {
        match self {
            DegradationRung::Primary => "primary".to_string(),
            DegradationRung::Ridge { step, lambda } => {
                format!("ridge[{step}]@{lambda:.1e}")
            }
            DegradationRung::Failed => "failed".to_string(),
        }
    }
}

/// Rank verdict on the triangular factor the primary solve produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeficiencyVerdict {
    /// The strategy never produced a factor to check (Gram path, or the
    /// factorization itself failed).
    #[default]
    NotChecked,
    /// Every pivot cleared the relative rank tolerance.
    FullRank,
    /// A pivot fell below the relative tolerance — collapsed features.
    RankDeficient {
        /// First deficient pivot row.
        pivot: usize,
    },
    /// The factor diagonal contained NaN/Inf — poisoned inputs.
    NonFinite {
        /// First non-finite diagonal row.
        row: usize,
    },
}

impl DeficiencyVerdict {
    /// True when the factor is safe to back-substitute through.
    pub fn is_clean(self) -> bool {
        matches!(self, DeficiencyVerdict::FullRank)
    }
}

/// Provenance of one β solve (see the module docs). `Copy + Default` by
/// design: it lives inside `TrainBreakdown`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SolveReport {
    /// Which solve pipeline ran.
    pub strategy: SolveStrategyKind,
    /// Which degradation rung produced β.
    pub rung: DegradationRung,
    /// Rank verdict on the primary factor (when one was produced).
    pub verdict: DeficiencyVerdict,
    /// The ridge λ in effect for the rung that produced β (0.0 for an
    /// unregularized primary QR/TSQR solve).
    pub effective_lambda: f64,
    /// Failed attempts before β: failed ladder rungs + worker-panic
    /// retries.
    pub retries: u32,
    /// Input rows dropped by the non-finite quarantine screen.
    pub quarantined_rows: usize,
}

impl SolveReport {
    /// Fresh report for a strategy about to run its primary solve.
    pub fn new(strategy: SolveStrategyKind) -> SolveReport {
        SolveReport { strategy, ..SolveReport::default() }
    }

    /// Rung family name (`"primary"` / `"ridge"` / `"failed"`), the value
    /// benches export as the `solve_report` metadata field.
    pub fn rung_name(&self) -> &'static str {
        self.rung.name()
    }

    /// One-line summary for logs:
    /// `"tsqr primary λ=0.0e0 retries=0 quarantined=0"`.
    pub fn summary(&self) -> String {
        format!(
            "{} {} λ={:.1e} retries={} quarantined={}",
            self.strategy.name(),
            self.rung.label(),
            self.effective_lambda,
            self.retries,
            self.quarantined_rows
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_primary_unspecified() {
        let r = SolveReport::default();
        assert_eq!(r.strategy, SolveStrategyKind::Unspecified);
        assert_eq!(r.rung, DegradationRung::Primary);
        assert_eq!(r.verdict, DeficiencyVerdict::NotChecked);
        assert_eq!(r.retries, 0);
        assert_eq!(r.quarantined_rows, 0);
    }

    #[test]
    fn rung_names_and_labels() {
        assert_eq!(DegradationRung::Primary.name(), "primary");
        let r = DegradationRung::Ridge { step: 2, lambda: 1e-4 };
        assert_eq!(r.name(), "ridge");
        assert!(r.label().starts_with("ridge[2]@"), "{}", r.label());
        assert_eq!(DegradationRung::Failed.name(), "failed");
    }

    #[test]
    fn summary_mentions_strategy_and_rung() {
        let mut r = SolveReport::new(SolveStrategyKind::Tsqr);
        r.rung = DegradationRung::Ridge { step: 1, lambda: 1e-8 };
        r.retries = 1;
        let s = r.summary();
        assert!(s.contains("tsqr") && s.contains("ridge[1]") && s.contains("retries=1"), "{s}");
    }

    #[test]
    fn verdict_cleanliness() {
        assert!(DeficiencyVerdict::FullRank.is_clean());
        assert!(!DeficiencyVerdict::NotChecked.is_clean());
        assert!(!DeficiencyVerdict::RankDeficient { pivot: 0 }.is_clean());
        assert!(!DeficiencyVerdict::NonFinite { row: 0 }.is_clean());
    }
}
