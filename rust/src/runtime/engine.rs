//! Single-threaded PJRT executor: text-parse → compile (cached) → execute.
//!
//! `Engine` owns a `PjRtClient` (`!Send`); thread-safe access goes through
//! [`super::pool::EnginePool`]. The execute path validates every input
//! against the manifest ABI before touching PJRT, so shape bugs surface as
//! readable errors rather than XLA aborts.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactMeta, Manifest};
// Offline builds compile against the API-compatible shim; the `pjrt`
// feature switches every `xla::` path below to the real crate.
#[cfg(not(feature = "pjrt"))]
use super::xla_shim as xla;

/// A host-side f32 tensor (the only dtype in the ABI).
#[derive(Debug, Clone, PartialEq)]
pub struct Buf {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Buf {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Buf {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Buf { dims, data }
    }

    pub fn vec(data: Vec<f32>) -> Buf {
        Buf { dims: vec![data.len()], data }
    }

    pub fn scalarish(v: f32) -> Buf {
        Buf { dims: vec![1], data: vec![v] }
    }
}

/// Owns the PJRT client and the compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative phase timings (Fig 6 decomposition)
    pub stats: EngineStats,
}

/// Cumulative time spent in each phase of artifact execution — the paper's
/// Fig 6 runtime decomposition (h2d = literal creation / transfer-in,
/// d2h = output fetch / transfer-out).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub compile_s: f64,
    pub h2d_s: f64,
    pub exec_s: f64,
    pub d2h_s: f64,
    pub executions: u64,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: HashMap::new(), stats: EngineStats::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Pre-compile an artifact (idempotent).
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.stats.compile_s += t0.elapsed().as_secs_f64();
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute by artifact name with positional inputs; returns the output
    /// tuple as host bufs (order per `meta.outputs`).
    pub fn run(&mut self, name: &str, inputs: &[Buf]) -> Result<Vec<Buf>> {
        self.prepare(name)?;
        let meta = self.manifest.get(name)?.clone();
        validate_inputs(&meta, inputs)?;
        let exe = self.cache.get(name).expect("prepared above");

        // h2d: host vecs -> literals
        let t0 = std::time::Instant::now();
        let mut literals = Vec::with_capacity(inputs.len());
        for b in inputs {
            let dims: Vec<i64> = b.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&b.data)
                .reshape(&dims)
                .with_context(|| format!("reshaping input to {dims:?}"))?;
            literals.push(lit);
        }
        self.stats.h2d_s += t0.elapsed().as_secs_f64();

        // execute
        let t1 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        self.stats.exec_s += t1.elapsed().as_secs_f64();

        // d2h: buffers -> literals -> host vecs (root is a tuple)
        let t2 = std::time::Instant::now();
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact {name}: {} outputs, manifest declares {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            out.push(Buf::new(dims, data));
        }
        self.stats.d2h_s += t2.elapsed().as_secs_f64();
        self.stats.executions += 1;
        Ok(out)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

fn validate_inputs(meta: &ArtifactMeta, inputs: &[Buf]) -> Result<()> {
    if inputs.len() != meta.inputs.len() {
        bail!(
            "artifact {}: got {} inputs, ABI declares {}",
            meta.name,
            inputs.len(),
            meta.inputs.len()
        );
    }
    for (b, spec) in inputs.iter().zip(&meta.inputs) {
        if b.data.len() != spec.len() {
            bail!(
                "artifact {} input {:?}: got {} elements, ABI wants {:?} = {}",
                meta.name,
                spec.name,
                b.data.len(),
                spec.shape,
                spec.len()
            );
        }
        if b.dims != spec.shape {
            bail!(
                "artifact {} input {:?}: dims {:?} != ABI {:?}",
                meta.name,
                spec.name,
                b.dims,
                spec.shape
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buf_constructors() {
        let b = Buf::vec(vec![1.0, 2.0]);
        assert_eq!(b.dims, vec![2]);
        let s = Buf::scalarish(3.0);
        assert_eq!(s.data, vec![3.0]);
    }

    #[test]
    fn input_validation_catches_arity_and_shape() {
        let meta = ArtifactMeta {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            kind: "elm_h".into(),
            arch: "elman".into(),
            variant: "opt".into(),
            rows: 4,
            block_rows: 2,
            s: 1,
            q: 3,
            m: 2,
            inputs: vec![super::super::manifest::InputSpec {
                name: "x".into(),
                shape: vec![4, 1, 3],
            }],
            outputs: vec!["h".into()],
        };
        assert!(validate_inputs(&meta, &[]).is_err());
        let wrong_len = Buf::vec(vec![0.0; 5]);
        assert!(validate_inputs(&meta, &[wrong_len]).is_err());
        let wrong_dims = Buf::new(vec![12], vec![0.0; 12]);
        assert!(validate_inputs(&meta, &[wrong_dims]).is_err());
        let ok = Buf::new(vec![4, 1, 3], vec![0.0; 12]);
        assert!(validate_inputs(&meta, &[ok]).is_ok());
    }
}
